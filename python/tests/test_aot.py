"""AOT export checks: artifact regeneration, determinism, and the HLO-text
contract the rust runtime depends on (parameter count / output tuple arity).
"""

import json
import os
import re

import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_fleet_step_is_deterministic():
    a = aot.lower_fleet_step(8)
    b = aot.lower_fleet_step(8)
    assert a == b


def test_hlo_text_has_18_parameters():
    text = aot.lower_fleet_step(8)
    params = set(re.findall(r"parameter\((\d+)\)", text))
    assert params == {str(i) for i in range(18)}, sorted(params)


def test_hlo_entry_returns_tuple_of_9():
    text = aot.lower_fleet_step(8)
    # The entry computation's ROOT is a 9-tuple (return_tuple=True).
    m = re.search(r"ENTRY .*?\{(.*?)\n\}", text, re.S)
    assert m, "no ENTRY computation"
    root_lines = [l for l in m.group(1).splitlines() if "ROOT" in l]
    assert len(root_lines) == 1
    root = root_lines[0]
    assert root.count("f32[8,9]") + root.count("f32[8]") + root.count(
        "s32[8]"
    ) + root.count("f32[]") >= 1
    # Tuple arity: count top-level commas in the shape tuple.
    shape = re.search(r"tuple\(", root)
    assert shape is not None


def test_batch_size_appears_in_shapes():
    text = aot.lower_fleet_step(16)
    assert "f32[16,9]" in text
    assert "s32[16]" in text


def test_saucb_module_lowers():
    text = aot.lower_saucb(8)
    assert "ENTRY" in text
    assert "f32[8,9]" in text


@pytest.mark.skipif(
    not os.path.isdir(ART), reason="artifacts not built (run `make artifacts`)"
)
def test_manifest_matches_artifacts():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["k"] == 9
    assert len(manifest["input_order"]) == 18
    assert len(manifest["output_order"]) == 9
    for fname in manifest["fleet_step"].values():
        assert os.path.exists(os.path.join(ART, fname)), fname
