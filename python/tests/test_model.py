"""L2 correctness: the exported fleet step vs the pure-jnp reference, plus
behavioral checks (convergence of the vectorized EnergyUCB, bookkeeping
invariants) and a tiny end-to-end rollout in python.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import fleet_step

jax.config.update("jax_platform_name", "cpu")

K = 9


def mk_state(b, k=K):
    return {
        "n": jnp.zeros((b, k), jnp.float32),
        "mean": jnp.zeros((b, k), jnp.float32),
        "prev": jnp.full((b,), k - 1, jnp.int32),
        "t": jnp.float32(1.0),
        "remaining": jnp.ones((b,), jnp.float32),
        "cum_energy": jnp.zeros((b,), jnp.float32),
        "cum_regret": jnp.zeros((b,), jnp.float32),
        "switches": jnp.zeros((b,), jnp.float32),
    }


def mk_params(b, k=K, seed=0, best_arm=2):
    rng = np.random.default_rng(seed)
    reward_mean = -1.0 - 0.02 * rng.uniform(1.0, 10.0, (b, k)).astype(np.float32)
    reward_mean[:, best_arm] = -0.95
    return {
        "reward_mean": jnp.asarray(reward_mean),
        "reward_sigma": jnp.full((b, k), 0.05, jnp.float32),
        "energy_step": jnp.full((b, k), 20.0, jnp.float32),
        "progress": jnp.full((b, k), 1e-3, jnp.float32),
        "feasible": jnp.ones((b, k), jnp.float32),
    }


HYPER = {
    "alpha": jnp.float32(0.05),
    "lam": jnp.float32(0.03),
    "mu_init": jnp.float32(0.0),
    "prior_n": jnp.float32(3.0),
}


def call_fleet_step(state, params, noise, hyper=HYPER):
    return fleet_step(
        state["n"], state["mean"], state["prev"], state["t"],
        state["remaining"], state["cum_energy"], state["cum_regret"],
        state["switches"], params["reward_mean"], params["reward_sigma"],
        params["energy_step"], params["progress"], params["feasible"],
        noise, hyper["alpha"], hyper["lam"], hyper["mu_init"], hyper["prior_n"],
    )


def unpack(out):
    keys = ["n", "mean", "prev", "t", "remaining", "cum_energy",
            "cum_regret", "switches"]
    return dict(zip(keys, out[:8])), out[8]


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 8, 64]))
def test_step_matches_ref(seed, b):
    rng = np.random.default_rng(seed)
    state = mk_state(b)
    # Randomize state a bit.
    state["n"] = jnp.asarray(rng.integers(0, 50, (b, K)).astype(np.float32))
    state["mean"] = jnp.asarray(rng.uniform(-1.5, -0.5, (b, K)).astype(np.float32))
    state["t"] = jnp.float32(rng.integers(1, 5000))
    params = mk_params(b, seed=seed)
    noise = jnp.asarray(rng.normal(size=(b,)).astype(np.float32))
    out_state, sel = unpack(call_fleet_step(state, params, noise))
    ref_state, ref_sel = ref.fleet_step_ref(state, params, noise, HYPER)
    np.testing.assert_array_equal(np.asarray(sel), np.asarray(ref_sel))
    for key in out_state:
        np.testing.assert_allclose(
            np.asarray(out_state[key]), np.asarray(ref_state[key]),
            rtol=1e-6, atol=1e-6, err_msg=key,
        )


def rollout(b, steps, seed=0, params=None):
    state = mk_state(b)
    params = params or mk_params(b, seed=seed)
    rng = np.random.default_rng(seed)
    sels = []
    step = jax.jit(call_fleet_step)
    for _ in range(steps):
        noise = jnp.asarray(rng.normal(size=(b,)).astype(np.float32))
        out = step(state, params, noise)
        state, sel = unpack(out)
        sels.append(np.asarray(sel))
    return state, np.stack(sels)


def test_vectorized_energyucb_converges():
    b, steps, best = 32, 1500, 2
    state, sels = rollout(b, steps)
    late = sels[steps // 2 :]
    frac_best = (late == best).mean()
    assert frac_best > 0.85, frac_best


def test_counts_sum_to_steps():
    b, steps = 16, 200
    state, _ = rollout(b, steps)
    np.testing.assert_allclose(np.asarray(state["n"]).sum(axis=1), steps)


def test_remaining_monotone_and_completion_freezes():
    b, steps = 8, 60
    params = mk_params(b)
    # Huge progress: finish in ~4 steps.
    params["progress"] = jnp.full((b, K), 0.3, jnp.float32)
    state, _ = rollout(b, steps, params=params)
    assert (np.asarray(state["remaining"]) == 0.0).all()
    # Energy/counters frozen after completion: about 4 steps' worth.
    energy = np.asarray(state["cum_energy"])
    assert (energy < 20.0 * 6 + 0.3 * 6).all(), energy.max()
    assert (np.asarray(state["n"]).sum(axis=1) <= 5).all()


def test_regret_nonnegative_and_grows_for_rr():
    b, steps = 4, 300
    state, _ = rollout(b, steps)
    regret = np.asarray(state["cum_regret"])
    assert (regret >= -1e-5).all()
    assert (regret > 0).any()


def test_switch_penalty_reduces_switches():
    b, steps = 32, 1200

    def run(lam):
        hyper = dict(HYPER)
        hyper["lam"] = jnp.float32(lam)
        state = mk_state(b)
        params = mk_params(b, seed=7)
        # Near-tie arms to provoke oscillation.
        rm = np.full((b, K), -1.0, np.float32)
        rm[:, 3] = -0.99
        params["reward_mean"] = jnp.asarray(rm)
        rng = np.random.default_rng(7)
        step = jax.jit(call_fleet_step)
        for _ in range(steps):
            noise = jnp.asarray(rng.normal(size=(b,)).astype(np.float32))
            state, _ = unpack(step(state, params, noise, hyper))
        return np.asarray(state["switches"]).mean()

    assert run(0.05) < 0.6 * run(0.0)


def test_feasibility_mask_respected_in_rollout():
    b, steps = 8, 300
    params = mk_params(b)
    feas = np.ones((b, K), np.float32)
    feas[:, :4] = 0.0  # low arms infeasible
    params["feasible"] = jnp.asarray(feas)
    _, sels = rollout(b, steps, params=params)
    assert (sels >= 4).all()


def test_fleet_scan_equals_repeated_steps():
    from compile.model import fleet_scan

    b, s = 8, 5
    rng = np.random.default_rng(42)
    state = mk_state(b)
    params = mk_params(b, seed=42)
    noise_seq = jnp.asarray(rng.normal(size=(s, b)).astype(np.float32))

    # Sequential single steps.
    seq = dict(state)
    for i in range(s):
        seq, _ = unpack(call_fleet_step(seq, params, noise_seq[i]))

    # One scanned call.
    out = fleet_scan(
        state["n"], state["mean"], state["prev"], state["t"],
        state["remaining"], state["cum_energy"], state["cum_regret"],
        state["switches"], params["reward_mean"], params["reward_sigma"],
        params["energy_step"], params["progress"], params["feasible"],
        noise_seq, HYPER["alpha"], HYPER["lam"], HYPER["mu_init"],
        HYPER["prior_n"],
    )
    scanned, _ = unpack(out)
    for key in seq:
        np.testing.assert_allclose(
            np.asarray(scanned[key]), np.asarray(seq[key]),
            rtol=1e-6, atol=1e-6, err_msg=key,
        )
