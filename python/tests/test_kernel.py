"""L1 correctness: Pallas SA-UCB kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, parameter ranges, and masks; every case asserts
allclose between `saucb.saucb_select` (interpret mode) and `ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.saucb import saucb_select

jax.config.update("jax_platform_name", "cpu")


def run_both(mu, n, prev, feas, alpha, lam, t, block_b=128):
    idx_k, sel_k = saucb_select(
        jnp.asarray(mu), jnp.asarray(n), jnp.asarray(prev), jnp.asarray(feas),
        jnp.float32(alpha), jnp.float32(lam), jnp.float32(t), block_b=block_b,
    )
    idx_r, sel_r = ref.saucb_index_ref(
        jnp.asarray(mu), jnp.asarray(n), jnp.asarray(prev), jnp.asarray(feas),
        jnp.float32(alpha), jnp.float32(lam), jnp.float32(t),
    )
    return (np.asarray(idx_k), np.asarray(sel_k)), (np.asarray(idx_r), np.asarray(sel_r))


@st.composite
def saucb_case(draw):
    b = draw(st.sampled_from([1, 3, 8, 64, 128, 256]))
    k = draw(st.sampled_from([2, 5, 9, 16]))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    mu = rng.uniform(-2.0, 0.0, size=(b, k)).astype(np.float32)
    n = rng.integers(0, 500, size=(b, k)).astype(np.float32)
    prev = rng.integers(0, k, size=(b,)).astype(np.int32)
    feas = (rng.uniform(size=(b, k)) > draw(st.sampled_from([0.0, 0.3]))).astype(
        np.float32
    )
    # Guarantee at least one feasible arm per row.
    feas[np.arange(b), rng.integers(0, k, size=(b,))] = 1.0
    alpha = draw(st.sampled_from([0.0, 0.05, 0.3]))
    lam = draw(st.sampled_from([0.0, 0.03, 0.2]))
    t = draw(st.sampled_from([1.0, 2.0, 100.0, 48000.0]))
    return mu, n, prev, feas, alpha, lam, t


@settings(max_examples=60, deadline=None)
@given(saucb_case())
def test_kernel_matches_ref(case):
    (idx_k, sel_k), (idx_r, sel_r) = run_both(*case)
    np.testing.assert_allclose(idx_k, idx_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(sel_k, sel_r)


def test_switching_penalty_breaks_tie_toward_prev():
    b, k = 4, 9
    mu = np.zeros((b, k), np.float32)
    n = np.ones((b, k), np.float32) * 10
    prev = np.array([0, 3, 5, 8], np.int32)
    feas = np.ones((b, k), np.float32)
    (_, sel), _ = run_both(mu, n, prev, feas, alpha=0.0, lam=0.05, t=100.0)
    np.testing.assert_array_equal(sel, prev)


def test_mask_excludes_infeasible():
    b, k = 2, 9
    mu = np.zeros((b, k), np.float32)
    mu[:, 0] = 1.0  # best arm ...
    feas = np.ones((b, k), np.float32)
    feas[:, 0] = 0.0  # ... but masked out
    n = np.ones((b, k), np.float32)
    prev = np.zeros((b,), np.int32)
    (_, sel), _ = run_both(mu, n, prev, feas, alpha=0.0, lam=0.0, t=10.0)
    assert (sel != 0).all()


def test_zero_counts_use_max1_guard():
    b, k = 1, 3
    mu = np.zeros((b, k), np.float32)
    n = np.zeros((b, k), np.float32)
    prev = np.zeros((b,), np.int32)
    feas = np.ones((b, k), np.float32)
    (idx, _), (idx_r, _) = run_both(mu, n, prev, feas, 0.1, 0.0, 1.0)
    assert np.isfinite(idx).all()
    np.testing.assert_allclose(idx, idx_r, rtol=1e-6)


def test_argmax_first_on_ties():
    b, k = 1, 5
    mu = np.zeros((b, k), np.float32)
    n = np.full((b, k), 7.0, np.float32)
    prev = np.array([9999 % k], np.int32)
    feas = np.ones((b, k), np.float32)
    (_, sel), _ = run_both(mu, n, prev, feas, alpha=0.0, lam=0.0, t=10.0)
    assert sel[0] == 0


def test_block_sizes_agree():
    rng = np.random.default_rng(0)
    b, k = 256, 9
    mu = rng.uniform(-2, 0, (b, k)).astype(np.float32)
    n = rng.integers(0, 100, (b, k)).astype(np.float32)
    prev = rng.integers(0, k, (b,)).astype(np.int32)
    feas = np.ones((b, k), np.float32)
    out = []
    for block in (32, 64, 128, 256):
        (_, sel), _ = run_both(mu, n, prev, feas, 0.05, 0.03, 500.0, block_b=block)
        out.append(sel)
    for s in out[1:]:
        np.testing.assert_array_equal(out[0], s)


def test_mu_hat_shrinkage():
    n = jnp.array([[0.0, 1.0, 100.0]])
    mean = jnp.array([[-5.0, -1.0, -1.0]])
    mu = ref.mu_hat_ref(n, mean, jnp.float32(0.0), jnp.float32(3.0))
    mu = np.asarray(mu)[0]
    assert mu[0] == 0.0                 # no data -> prior
    assert -1.0 < mu[1] < 0.0           # shrunk toward prior
    assert abs(mu[2] - (-1.0)) < 0.05   # data dominates
