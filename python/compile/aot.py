"""AOT export: lower the L2 fleet step to HLO text artifacts.

Run once at build time (`make artifacts`); the rust coordinator loads the
artifacts through PJRT and python never appears on the run path.

HLO **text** is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
  artifacts/fleet_step_b{B}.hlo.txt   for B in --batches (default 64,256,1024)
  artifacts/saucb_b{B}.hlo.txt        kernel-only module (runtime smoke test)
  artifacts/manifest.json             shapes/dtypes/ordering contract
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.saucb import saucb_select
from .model import fleet_step, fleet_step_specs, fleet_scan, fleet_scan_specs

K = 9  # 0.8 .. 1.6 GHz in 0.1 steps (paper S4.1)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fleet_step(b: int) -> str:
    specs = fleet_step_specs(b, K)
    return to_hlo_text(jax.jit(fleet_step).lower(*specs))


def lower_fleet_scan(s: int, b: int) -> str:
    specs = fleet_scan_specs(s, b, K)
    return to_hlo_text(jax.jit(fleet_scan).lower(*specs))


def lower_saucb(b: int) -> str:
    f32 = jnp.float32
    bk = jax.ShapeDtypeStruct((b, K), f32)
    bb_i = jax.ShapeDtypeStruct((b,), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), f32)
    return to_hlo_text(
        jax.jit(saucb_select).lower(bk, bk, bb_i, bk, scalar, scalar, scalar)
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batches", default="64,256,1024")
    ap.add_argument("--scan-steps", type=int, default=16)
    args = ap.parse_args()
    batches = [int(x) for x in args.batches.split(",") if x]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"k": K, "fleet_step": {}, "saucb": {}, "input_order": [
        "n[B,K]f32", "mean[B,K]f32", "prev[B]i32", "t[]f32", "remaining[B]f32",
        "cum_energy[B]f32", "cum_regret[B]f32", "switches[B]f32",
        "reward_mean[B,K]f32", "reward_sigma[B,K]f32", "energy_step[B,K]f32",
        "progress[B,K]f32", "feasible[B,K]f32", "noise[B]f32",
        "alpha[]f32", "lam[]f32", "mu_init[]f32", "prior_n[]f32",
    ], "output_order": [
        "n", "mean", "prev", "t", "remaining", "cum_energy", "cum_regret",
        "switches", "sel",
    ]}

    for b in batches:
        path = os.path.join(args.out_dir, f"fleet_step_b{b}.hlo.txt")
        text = lower_fleet_step(b)
        with open(path, "w") as f:
            f.write(text)
        manifest["fleet_step"][str(b)] = os.path.basename(path)
        print(f"wrote {path} ({len(text)} chars)")
        spath = os.path.join(args.out_dir, f"fleet_scan_b{b}_s{args.scan_steps}.hlo.txt")
        stext = lower_fleet_scan(args.scan_steps, b)
        with open(spath, "w") as f:
            f.write(stext)
        manifest.setdefault("fleet_scan", {})[str(b)] = {
            "file": os.path.basename(spath), "steps": args.scan_steps,
        }
        print(f"wrote {spath} ({len(stext)} chars)")

    # Kernel-only module at the smallest batch for runtime smoke tests.
    b = batches[0]
    path = os.path.join(args.out_dir, f"saucb_b{b}.hlo.txt")
    text = lower_saucb(b)
    with open(path, "w") as f:
        f.write(text)
    manifest["saucb"][str(b)] = os.path.basename(path)
    print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
