"""L2: the vectorized EnergyUCB fleet step (JAX), calling the L1 kernel.

One call advances B independent (app, seed) bandit environments by one
10 ms decision interval: SA-UCB selection (Pallas kernel), reward draw from
the calibrated per-arm distributions, incremental mean update, progress /
energy / regret / switch accounting. The rust fleet engine loads the
AOT-lowered HLO of `fleet_step` and drives it in a loop, feeding the state
outputs back as inputs (device-resident buffers; python never runs at
request time).

Input order (must match rust/src/fleet/engine.rs and the manifest):
  0  n           (B,K) f32   pull counts
  1  mean        (B,K) f32   empirical means
  2  prev        (B,)  i32   previous arm
  3  t           ()    f32   1-based decision step
  4  remaining   (B,)  f32   remaining work fraction
  5  cum_energy  (B,)  f32   Joules
  6  cum_regret  (B,)  f32   normalized-reward units
  7  switches    (B,)  f32
  8  reward_mean (B,K) f32   true expected reward per arm (normalized)
  9  reward_sigma(B,K) f32   reward noise std per arm
  10 energy_step (B,K) f32   true Joules per interval per arm
  11 progress    (B,K) f32   work fraction per interval per arm
  12 feasible    (B,K) f32   QoS mask (1 = selectable)
  13 noise       (B,)  f32   standard normal draws for this step
  14 alpha       ()    f32
  15 lam         ()    f32
  16 mu_init     ()    f32
  17 prior_n     ()    f32
Outputs: (n', mean', prev', t', remaining', cum_energy', cum_regret',
          switches', sel).
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.saucb import saucb_select


def fleet_step(
    n,
    mean,
    prev,
    t,
    remaining,
    cum_energy,
    cum_regret,
    switches,
    reward_mean,
    reward_sigma,
    energy_step,
    progress,
    feasible,
    noise,
    alpha,
    lam,
    mu_init,
    prior_n,
):
    """One fleet decision step. See module docstring for the contract."""
    b = n.shape[0]
    rows = jnp.arange(b)
    active = (remaining > 0.0).astype(n.dtype)

    mu_hat = ref.mu_hat_ref(n, mean, mu_init, prior_n)
    _, sel = saucb_select(mu_hat, n, prev, feasible, alpha, lam, t)

    r = reward_mean[rows, sel] + reward_sigma[rows, sel] * noise
    n_sel = n[rows, sel] + active
    new_n = n.at[rows, sel].set(n_sel)
    delta = (r - mean[rows, sel]) / jnp.maximum(n_sel, 1.0) * active
    new_mean = mean.at[rows, sel].add(delta)

    switched = (sel != prev).astype(n.dtype) * active
    # Switch constants come from the shared contract in kernels/ref.py
    # (mirroring rust sim::freq::SwitchCost) — never restate them here.
    useful = 1.0 - ref.SWITCH_STALL_FRAC * switched
    prog = progress[rows, sel] * useful * active
    new_remaining = jnp.maximum(remaining - prog, 0.0)
    step_energy = (energy_step[rows, sel] + ref.SWITCH_ENERGY_J * switched) * active
    best = jnp.max(jnp.where(feasible > 0, reward_mean, ref.NEG_LARGE), axis=1)
    regret = (best - reward_mean[rows, sel]) * active

    return (
        new_n,
        new_mean,
        jnp.where(active > 0, sel, prev).astype(jnp.int32),
        t + 1.0,
        new_remaining,
        cum_energy + step_energy,
        cum_regret + regret,
        switches + switched,
        sel,
    )


def fleet_step_specs(b, k):
    """ShapeDtypeStructs for jit-lowering `fleet_step` at batch B, K arms."""
    f32 = jnp.float32
    bk = jax.ShapeDtypeStruct((b, k), f32)
    bb = jax.ShapeDtypeStruct((b,), f32)
    scalar = jax.ShapeDtypeStruct((), f32)
    prev = jax.ShapeDtypeStruct((b,), jnp.int32)
    return (
        bk,      # n
        bk,      # mean
        prev,    # prev
        scalar,  # t
        bb,      # remaining
        bb,      # cum_energy
        bb,      # cum_regret
        bb,      # switches
        bk,      # reward_mean
        bk,      # reward_sigma
        bk,      # energy_step
        bk,      # progress
        bk,      # feasible
        bb,      # noise
        scalar,  # alpha
        scalar,  # lam
        scalar,  # mu_init
        scalar,  # prior_n
    )


def fleet_scan(
    n,
    mean,
    prev,
    t,
    remaining,
    cum_energy,
    cum_regret,
    switches,
    reward_mean,
    reward_sigma,
    energy_step,
    progress,
    feasible,
    noise_seq,
    alpha,
    lam,
    mu_init,
    prior_n,
):
    """S decision steps per call via lax.scan (noise_seq: (S, B) f32).

    Same input order as `fleet_step` with `noise` widened to (S, B); same
    output order (sel is the last step's selection). Amortizes PJRT
    dispatch + host<->literal packing by S x on the rust fleet hot path
    (EXPERIMENTS.md §Perf).
    """

    def body(carry, noise):
        out = fleet_step(
            *carry,
            reward_mean,
            reward_sigma,
            energy_step,
            progress,
            feasible,
            noise,
            alpha,
            lam,
            mu_init,
            prior_n,
        )
        return out[:8], out[8]

    carry0 = (n, mean, prev, t, remaining, cum_energy, cum_regret, switches)
    carry, sels = jax.lax.scan(body, carry0, noise_seq)
    return (*carry, sels[-1])


def fleet_scan_specs(s, b, k):
    """ShapeDtypeStructs for jit-lowering `fleet_scan` at S steps, batch B."""
    specs = list(fleet_step_specs(b, k))
    specs[13] = jax.ShapeDtypeStruct((s, b), jnp.float32)  # noise_seq
    return tuple(specs)
