"""L1 Pallas kernel: the switching-aware UCB index + masked argmax.

This is the fleet engine's per-step hot spot: for B independent controller
states it computes SA-UCB_{i,t} = mu_hat + alpha*sqrt(ln t / max(1, n)) -
lambda*1{i != prev} over K arms, applies the QoS feasibility mask, and takes
the row argmax (first index on ties, matching the rust L3 policy).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's target is
an Intel PVC GPU, but the *controller* math has no matmul — on a TPU this is
pure VPU work. The BlockSpec tiles the batch dimension into VMEM-sized rows
(TB x K, K = 9 fits one lane group); scalars (alpha, lambda, t) ride in as a
tiny broadcast block. Exported with interpret=True: CPU PJRT cannot execute
Mosaic custom-calls, and correctness is what the artifact path validates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows per grid step. 128 keeps the working set (5 * TB * K * 4B ~ 23 KiB)
# far under VMEM even with double buffering.
DEFAULT_BLOCK_B = 128


def _saucb_kernel(scal_ref, mu_ref, n_ref, prev_ref, feas_ref, idx_ref, sel_ref):
    """One (TB, K) tile: index computation + masked argmax."""
    mu = mu_ref[...]
    n = n_ref[...]
    prev = prev_ref[...]
    feas = feas_ref[...]
    alpha = scal_ref[0]
    lam = scal_ref[1]
    t = scal_ref[2]

    bonus = alpha * jnp.sqrt(jnp.log(jnp.maximum(t, 2.0)) / jnp.maximum(n, 1.0))
    arms = jax.lax.broadcasted_iota(jnp.int32, mu.shape, 1)
    penalty = lam * (arms != prev[:, None]).astype(mu.dtype)
    idx = mu + bonus - penalty
    idx = jnp.where(feas > 0, idx, jnp.asarray(ref.NEG_LARGE, mu.dtype))
    idx_ref[...] = idx
    sel_ref[...] = jnp.argmax(idx, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b",))
def saucb_select(mu_hat, counts, prev, feasible, alpha, lam, t, *, block_b=DEFAULT_BLOCK_B):
    """Pallas-backed SA-UCB index + argmax over a (B, K) fleet.

    Args mirror `ref.saucb_index_ref`; alpha/lam/t are scalar () arrays.
    B must be a multiple of `block_b` (the AOT export picks matching sizes).
    Returns (idx (B, K) f32, sel (B,) i32).
    """
    b, k = mu_hat.shape
    if b % block_b != 0:
        # Fall back to a single whole-array block for odd sizes.
        block_b = b
    scal = jnp.stack(
        [
            jnp.asarray(alpha, mu_hat.dtype),
            jnp.asarray(lam, mu_hat.dtype),
            jnp.asarray(t, mu_hat.dtype),
        ]
    )
    grid = (b // block_b,)
    return pl.pallas_call(
        _saucb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),  # scalars, broadcast
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), mu_hat.dtype),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=True,  # CPU PJRT target; see module docstring
    )(scal, mu_hat, counts, prev, feasible)
