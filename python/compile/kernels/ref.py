"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: `pytest python/tests` sweeps shapes,
dtypes, and parameter ranges asserting the Pallas implementations match
these to float tolerance. Keep them boring and obviously-correct.
"""

import jax
import jax.numpy as jnp

NEG_LARGE = -3.0e38  # effectively -inf for f32 masking without NaN risk

# Shared switch-cost contract — the python-side single source for the DVFS
# transition constants baked into exported artifacts. Mirrors the rust
# definition `sim::freq::SwitchCost::default()` (150 µs stall of a 10 ms
# decision interval, 0.3 J per node-level transition); the rust native
# engine derives the same values via `FleetParams::from_apps`, and the
# cross-engine tests keep the two in lockstep.
SWITCH_STALL_FRAC = 0.015
SWITCH_ENERGY_J = 0.3


def saucb_index_ref(mu_hat, counts, prev, feasible, alpha, lam, t):
    """Switching-aware UCB index (paper Eq. 5) + masked argmax.

    Args:
      mu_hat:   (B, K) prior-shrunk mean rewards.
      counts:   (B, K) pull counts (float).
      prev:     (B,)  int32 previous arm.
      feasible: (B, K) {0,1} mask (QoS-constrained variant; all-ones =
                unconstrained).
      alpha, lam, t: scalars (t is the 1-based decision step).

    Returns:
      idx: (B, K) SA-UCB values (masked entries ~ -inf).
      sel: (B,)  int32 argmax arm (first index on ties).
    """
    mu_hat = jnp.asarray(mu_hat)
    counts = jnp.asarray(counts)
    bonus = alpha * jnp.sqrt(
        jnp.log(jnp.maximum(t, 2.0)) / jnp.maximum(counts, 1.0)
    )
    arms = jax.lax.broadcasted_iota(jnp.int32, mu_hat.shape, 1)
    penalty = lam * (arms != prev[:, None]).astype(mu_hat.dtype)
    idx = mu_hat + bonus - penalty
    idx = jnp.where(feasible > 0, idx, jnp.asarray(NEG_LARGE, mu_hat.dtype))
    sel = jnp.argmax(idx, axis=1).astype(jnp.int32)
    return idx, sel


def mu_hat_ref(n, mean, mu_init, prior_n):
    """Prior-shrunk mean: (prior_n*mu_init + n*mean) / (prior_n + n).

    Safe at n = prior_n = 0 (returns mu_init).
    """
    denom = prior_n + n
    return jnp.where(
        denom > 0.0,
        (prior_n * mu_init + n * mean) / jnp.maximum(denom, 1e-12),
        mu_init,
    )


def fleet_step_ref(state, params, noise, hyper):
    """One vectorized EnergyUCB decision step over a fleet of B independent
    environments — the pure-jnp reference for the exported model.

    state: dict with n (B,K), mean (B,K), prev (B,) i32, t () f32,
           remaining (B,), cum_energy (B,), cum_regret (B,), switches (B,)
    params: dict with reward_mean, reward_sigma, energy_step, progress,
           feasible — all (B,K) f32
    noise: (B,) standard normal draws for this step
    hyper: dict with alpha, lam, mu_init, prior_n — () f32

    Returns (new_state, sel).
    """
    n, mean = state["n"], state["mean"]
    prev, t = state["prev"], state["t"]
    remaining = state["remaining"]
    b = n.shape[0]
    rows = jnp.arange(b)

    active = (remaining > 0.0).astype(n.dtype)

    mu_hat = mu_hat_ref(n, mean, hyper["mu_init"], hyper["prior_n"])
    _, sel = saucb_index_ref(
        mu_hat, n, prev, params["feasible"], hyper["alpha"], hyper["lam"], t
    )

    r = params["reward_mean"][rows, sel] + params["reward_sigma"][rows, sel] * noise
    # Incremental mean update on the selected arm (frozen once done).
    n_sel = n[rows, sel] + active
    new_n = n.at[rows, sel].set(n_sel)
    delta = (r - mean[rows, sel]) / jnp.maximum(n_sel, 1.0) * active
    new_mean = mean.at[rows, sel].add(delta)

    switched = (sel != prev).astype(n.dtype) * active
    useful = 1.0 - SWITCH_STALL_FRAC * switched
    prog = params["progress"][rows, sel] * useful * active
    new_remaining = jnp.maximum(remaining - prog, 0.0)
    step_energy = (
        params["energy_step"][rows, sel] + SWITCH_ENERGY_J * switched
    ) * active
    best = jnp.max(
        jnp.where(params["feasible"] > 0, params["reward_mean"], NEG_LARGE), axis=1
    )
    regret = (best - params["reward_mean"][rows, sel]) * active

    new_state = {
        "n": new_n,
        "mean": new_mean,
        "prev": jnp.where(active > 0, sel, prev).astype(jnp.int32),
        "t": t + 1.0,
        "remaining": new_remaining,
        "cum_energy": state["cum_energy"] + step_energy,
        "cum_regret": state["cum_regret"] + regret,
        "switches": state["switches"] + switched,
    }
    return new_state, sel
