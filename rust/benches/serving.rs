//! Serving-tier throughput: the bursty arrival process and the
//! contextual decide/observe loop it feeds, across B ∈ {1, 32, 1024}
//! (EXPERIMENTS.md §Serving / §Perf).
//!
//! Three shapes per batch size, all reported as env-steps/s:
//!   * `arrivals` — `ServingModel::step` alone (Poisson draws + queue
//!     bookkeeping), the cost of synthesizing the feature stream,
//!   * `linucb` — `BatchLinUcb` select/update over a frozen (B, D)
//!     context grid, the pure decision-plane cost (Sherman–Morrison
//!     rank-1 updates, no inversions),
//!   * `serve+decide` — the composed loop the serving fleet runs:
//!     advance every model, pack the context grid, select, observe.

use energyucb::bandit::batch::BatchPolicy;
use energyucb::bandit::{BatchLinUcb, CONTEXT_DIM};
use energyucb::util::bench::{black_box, Bench};
use energyucb::workload::serving::{ServingCfg, ServingModel};

fn models(batch: usize) -> Vec<ServingModel> {
    (0..batch)
        .map(|e| ServingModel::new(ServingCfg { seed: e as u64, ..ServingCfg::default() }))
        .collect()
}

fn main() {
    let b = Bench::default();
    let k = 9usize;

    for batch in [1usize, 32, 1024] {
        // Arrival process alone: Poisson sampling, burst episodes, queue
        // and EMA bookkeeping per environment.
        {
            let mut fleet = models(batch);
            let mut i = 0u64;
            b.case(&format!("arrivals/B={batch}"), batch as f64, || {
                let scale = 0.5 + 0.5 * ((i % 9) as f64 / 8.0);
                for m in fleet.iter_mut() {
                    black_box(m.step(scale));
                }
                i += 1;
            });
        }

        // Decision plane alone: contextual select + rank-1 update over a
        // frozen feature grid.
        {
            let mut policy = BatchLinUcb::new(batch, k, CONTEXT_DIM, 1.0, 1.0);
            let feasible = vec![1.0f32; batch * k];
            let active = vec![1.0f32; batch];
            let progress = vec![1e-3f64; batch];
            let mut reward = vec![0.0f64; batch];
            let mut sel = vec![0i32; batch];
            let mut ctx = vec![0.0f64; batch * CONTEXT_DIM];
            for (j, c) in ctx.iter_mut().enumerate() {
                *c = 0.1 + 0.8 * ((j % 7) as f64 / 6.0);
            }
            let mut t = 0u64;
            b.case(&format!("linucb/B={batch}"), batch as f64, || {
                t += 1;
                policy.select_into_ctx(t, &feasible, &ctx, CONTEXT_DIM, &mut sel);
                for e in 0..batch {
                    reward[e] = -1.0 - 0.01 * sel[e] as f64;
                }
                policy.update_batch(&sel, &reward, &progress, &active);
                black_box(&sel);
            });
        }

        // The composed serving loop: workload advance under the chosen
        // service scale, (B, D) grid packing, select, observe.
        {
            let mut fleet = models(batch);
            let mut policy = BatchLinUcb::new(batch, k, CONTEXT_DIM, 1.0, 1.0);
            let feasible = vec![1.0f32; batch * k];
            let active = vec![1.0f32; batch];
            let progress = vec![1e-3f64; batch];
            let mut reward = vec![0.0f64; batch];
            let mut sel = vec![0i32; batch];
            let mut ctx = vec![0.0f64; batch * CONTEXT_DIM];
            let mut t = 0u64;
            b.case(&format!("serve+decide/B={batch}"), batch as f64, || {
                t += 1;
                for (e, m) in fleet.iter_mut().enumerate() {
                    let scale = (1 + sel[e].max(0) as usize) as f64 / k as f64;
                    let f = m.step(scale);
                    ctx[e * CONTEXT_DIM..(e + 1) * CONTEXT_DIM].copy_from_slice(&f);
                    reward[e] = -(1.0 + f[0]);
                }
                policy.select_into_ctx(t, &feasible, &ctx, CONTEXT_DIM, &mut sel);
                policy.update_batch(&sel, &reward, &progress, &active);
                black_box(&sel);
            });
        }
    }
}
