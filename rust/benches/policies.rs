//! L3 hot-path micro-benchmarks: per-decision latency of every policy.
//!
//! The controller has a 10 ms decision budget on the real system; every
//! `select`+`update` pair must be orders of magnitude below that (target:
//! < 1 µs for EnergyUCB — see EXPERIMENTS.md §Perf).

use energyucb::bandit::{
    ConstrainedEnergyUcb, EnergyTs, EnergyUcb, EnergyUcbConfig, EpsilonGreedy, Policy,
    RoundRobin, Ucb1,
};
use energyucb::rl::{DrlCap, DrlCapMode, RlPower};
use energyucb::util::bench::{black_box, Bench};
use energyucb::util::Rng;

fn bench_policy(b: &Bench, name: &str, policy: &mut dyn Policy) {
    let mut rng = Rng::new(7);
    let mut t = 0u64;
    // Pre-warm with some history so we measure steady state.
    for _ in 0..500 {
        t += 1;
        let arm = policy.select(t);
        policy.update(arm, rng.normal(-1.0, 0.05), 1e-4);
    }
    b.case(&format!("decide+update/{name}"), 1.0, || {
        t += 1;
        let arm = policy.select(black_box(t));
        policy.update(arm, black_box(rng.normal(-1.0, 0.05)), 1e-4);
    });
}

fn main() {
    let b = Bench::default();
    let k = 9;
    println!("# policy decision latency (k = {k} arms)");
    bench_policy(&b, "EnergyUCB", &mut EnergyUcb::new(k, EnergyUcbConfig::default()));
    bench_policy(
        &b,
        "ConstrainedEnergyUCB",
        &mut ConstrainedEnergyUcb::new(k, EnergyUcbConfig::default(), 0.05),
    );
    bench_policy(&b, "UCB1", &mut Ucb1::new(k, 0.04));
    bench_policy(&b, "EpsilonGreedy", &mut EpsilonGreedy::new(k, 0.05, 0.0, 1));
    bench_policy(&b, "EnergyTS", &mut EnergyTs::default_for(k, 1));
    bench_policy(&b, "RRFreq", &mut RoundRobin::new(k));
    bench_policy(&b, "RL-Power", &mut RlPower::new(k, 1));
    bench_policy(&b, "DRLCap-Online", &mut DrlCap::new(k, DrlCapMode::Online, 1));

    // Decision budget check.
    println!("\n(decision budget on the real system: 10 ms = 10,000,000 ns)");
}
