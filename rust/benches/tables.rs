//! Table/figure regeneration benches: wall-clock of each paper experiment
//! in quick mode (the harness itself is part of the deliverable; this
//! keeps its cost visible and regressions caught).

use energyucb::experiments::{all_experiments, ExpContext};
use energyucb::util::bench::human_time;

fn main() {
    let ctx = ExpContext {
        quick: true,
        reps: 1,
        out_dir: std::env::temp_dir().join("energyucb_bench_results"),
        ..ExpContext::default()
    };
    println!("# experiment harness wall-clock (quick mode, reps=1)");
    for exp in all_experiments() {
        let t0 = std::time::Instant::now();
        let result = exp.run(&ctx);
        let dt = t0.elapsed().as_nanos() as f64;
        match result {
            Ok(_) => println!("bench exp/{:<40} {:>12}", exp.id(), human_time(dt)),
            Err(e) => println!("bench exp/{:<40} FAILED: {e:#}", exp.id()),
        }
    }
    let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_bench_results"));
}
