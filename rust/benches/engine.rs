//! Batch-policy stepping throughput: SoA-batched vs scalar-loop across
//! B ∈ {1, 32, 256, 4096} — the hot-loop comparison behind the
//! batch-native policy core (EXPERIMENTS.md §Engine / §Perf).
//!
//! Three shapes per batch size:
//!   * `native`  — the bit-pinned EnergyUCB fleet step (`FleetState`
//!     grids, reused `StepScratch` buffers),
//!   * `batched` — the generic runner driving the SoA `BatchEnergyUcb`
//!     (same arithmetic, policy-owned grids),
//!   * `scalar-loop` — the generic runner driving B scalar `EnergyUcb`
//!     instances through the `Scalar` bridge (the f64 per-env baseline
//!     the SoA path is measured against).

use energyucb::bandit::batch::{BatchEnergyUcb, BatchPolicy, Scalar};
use energyucb::bandit::{EnergyUcb, EnergyUcbConfig};
use energyucb::fleet::{native, policy_step, FleetHyper, FleetParams, FleetState, StepScratch};
use energyucb::sim::freq::FreqDomain;
use energyucb::util::bench::{black_box, Bench};
use energyucb::util::Rng;
use energyucb::workload::calibration;

fn params_for(batch: usize) -> FleetParams {
    let freqs = FreqDomain::aurora();
    let apps: Vec<_> = calibration::all_apps();
    let assigned: Vec<&_> = apps.iter().cycle().take(batch).collect();
    FleetParams::from_apps(&assigned, &freqs, 0.01)
}

fn main() {
    let b = Bench::default();
    let hyper = FleetHyper::default();
    let k = 9usize;

    for batch in [1usize, 32, 256, 4096] {
        let params = params_for(batch);

        // Bit-pinned native EnergyUCB step (state-grid path).
        {
            let mut state = FleetState::fresh(batch, k);
            let mut scratch = StepScratch::new(batch);
            let mut noise = vec![0.0f32; batch];
            let mut rng = Rng::new(1);
            let mut step_idx = 0u64;
            b.case(&format!("native/B={batch}"), batch as f64, || {
                native::step_noise_into(&params, step_idx, &mut rng, &mut noise);
                native::native_step_into(&mut state, &params, &hyper, &noise, &mut scratch);
                black_box(&scratch.sel);
                step_idx += 1;
                if state.all_done() {
                    state = FleetState::fresh(batch, k);
                    step_idx = 0;
                }
            });
        }

        // Generic runner + SoA batch policy (identical trajectories).
        {
            let mut state = FleetState::fresh(batch, k);
            let mut policy = BatchEnergyUcb::with_initial_arm(batch, k, hyper, k - 1);
            let mut scratch = StepScratch::new(batch);
            let mut noise = vec![0.0f32; batch];
            let mut rng = Rng::new(1);
            let mut step_idx = 0u64;
            b.case(&format!("batched/B={batch}"), batch as f64, || {
                native::step_noise_into(&params, step_idx, &mut rng, &mut noise);
                policy_step(&mut state, &params, &mut policy, &noise, &mut scratch);
                black_box(&scratch.sel);
                step_idx += 1;
                if state.all_done() {
                    state = FleetState::fresh(batch, k);
                    policy.reset();
                    step_idx = 0;
                }
            });
        }

        // Generic runner + scalar loop over the bridge (the baseline the
        // SoA iteration is measured against).
        {
            let mut state = FleetState::fresh(batch, k);
            let mut policy = Scalar::new(
                (0..batch)
                    .map(|_| EnergyUcb::new(k, EnergyUcbConfig::default()))
                    .collect::<Vec<_>>(),
            );
            let mut scratch = StepScratch::new(batch);
            let mut noise = vec![0.0f32; batch];
            let mut rng = Rng::new(1);
            let mut step_idx = 0u64;
            b.case(&format!("scalar-loop/B={batch}"), batch as f64, || {
                native::step_noise_into(&params, step_idx, &mut rng, &mut noise);
                policy_step(&mut state, &params, &mut policy, &noise, &mut scratch);
                black_box(&scratch.sel);
                step_idx += 1;
                if state.all_done() {
                    state = FleetState::fresh(batch, k);
                    policy.reset();
                    step_idx = 0;
                }
            });
        }
    }
}
