//! Batch-policy stepping throughput: SoA-batched vs scalar-loop across
//! B ∈ {1, 32, 256, 4096} — the hot-loop comparison behind the
//! batch-native policy core (EXPERIMENTS.md §Engine / §Perf).
//!
//! Three shapes per batch size, all reported as env-steps/s:
//!   * `native`  — the bit-pinned EnergyUCB fleet step (`FleetState`
//!     grids, reused `StepScratch` buffers), timed per step,
//!   * `batched` — the batch-native control loop (`policy_run`) driving
//!     the SoA `BatchEnergyUcb` (same arithmetic, policy-owned grids),
//!     timed over a fixed-length run,
//!   * `scalar-loop` — the same loop driving B scalar `EnergyUcb`
//!     instances through the `Scalar` bridge (the f64 per-env baseline
//!     the SoA path is measured against).
//!
//! The loop-level drive-vs-native overhead comparison at matched
//! granularity lives in `benches/controller.rs`.

use energyucb::bandit::batch::{BatchEnergyUcb, BatchPolicy, Scalar};
use energyucb::bandit::{BatchLinUcb, EnergyUcb, EnergyUcbConfig, CONTEXT_DIM};
use energyucb::fleet::{native, policy_run, FleetHyper, FleetParams, FleetState, StepScratch};
use energyucb::sim::freq::FreqDomain;
use energyucb::util::bench::{black_box, Bench};
use energyucb::util::Rng;
use energyucb::workload::calibration;

fn params_for(batch: usize) -> FleetParams {
    let freqs = FreqDomain::aurora();
    let apps: Vec<_> = calibration::all_apps();
    let assigned: Vec<&_> = apps.iter().cycle().take(batch).collect();
    FleetParams::from_apps(&assigned, &freqs, 0.01)
}

/// Steps per measured run for the loop-driven shapes: long enough to
/// amortize the fresh-state setup, short enough that B = 4096 stays
/// inside a bench sample.
const RUN_STEPS: u64 = 200;

fn main() {
    let b = Bench::default();
    let hyper = FleetHyper::default();
    let k = 9usize;

    for batch in [1usize, 32, 256, 4096] {
        let params = params_for(batch);

        // Bit-pinned native EnergyUCB step (state-grid path).
        {
            let mut state = FleetState::fresh(batch, k);
            let mut scratch = StepScratch::new(batch);
            let mut noise = vec![0.0f32; batch];
            let mut rng = Rng::new(1);
            let mut step_idx = 0u64;
            b.case(&format!("native/B={batch}"), batch as f64, || {
                native::step_noise_into(&params, step_idx, &mut rng, &mut noise);
                native::native_step_into(&mut state, &params, &hyper, &noise, &mut scratch);
                black_box(&scratch.sel);
                step_idx += 1;
                if state.all_done() {
                    state = FleetState::fresh(batch, k);
                    step_idx = 0;
                }
            });
        }

        // Batch-native control loop + SoA batch policy (identical
        // trajectories to `native`, policy-owned grids).
        {
            b.case(
                &format!("batched/B={batch}"),
                (batch as u64 * RUN_STEPS) as f64,
                || {
                    let mut state = FleetState::fresh(batch, k);
                    let mut policy = BatchEnergyUcb::with_initial_arm(batch, k, hyper, k - 1);
                    let mut rng = Rng::new(1);
                    black_box(policy_run(
                        &mut state,
                        &params,
                        &mut policy,
                        &mut rng,
                        RUN_STEPS,
                    ));
                },
            );
        }

        // Same loop, B scalar policies over the bridge (the baseline the
        // SoA iteration is measured against).
        {
            b.case(
                &format!("scalar-loop/B={batch}"),
                (batch as u64 * RUN_STEPS) as f64,
                || {
                    let mut state = FleetState::fresh(batch, k);
                    let mut policy = Scalar::new(
                        (0..batch)
                            .map(|_| EnergyUcb::new(k, EnergyUcbConfig::default()))
                            .collect::<Vec<_>>(),
                    );
                    let mut rng = Rng::new(1);
                    black_box(policy_run(
                        &mut state,
                        &params,
                        &mut policy,
                        &mut rng,
                        RUN_STEPS,
                    ));
                },
            );
        }

        // Context-carrying select/update (the serving tier's decision
        // plane) at the same batch widths, over a frozen feature grid —
        // timed per step like `native` so the per-env cost of the
        // contextual path reads off directly against the context-free one.
        {
            let mut policy = BatchLinUcb::new(batch, k, CONTEXT_DIM, 1.0, 1.0);
            let feasible = vec![1.0f32; batch * k];
            let active = vec![1.0f32; batch];
            let progress = vec![1e-3f64; batch];
            let mut reward = vec![0.0f64; batch];
            let mut sel = vec![0i32; batch];
            let mut rng = Rng::new(1);
            let mut ctx = vec![0.0f64; batch * CONTEXT_DIM];
            for c in ctx.iter_mut() {
                *c = rng.uniform();
            }
            let mut t = 0u64;
            b.case(&format!("ctx-select/B={batch}"), batch as f64, || {
                t += 1;
                policy.select_into_ctx(t, &feasible, &ctx, CONTEXT_DIM, &mut sel);
                for e in 0..batch {
                    reward[e] = -1.0 - 0.01 * sel[e] as f64;
                }
                policy.update_batch(&sel, &reward, &progress, &active);
                black_box(&sel);
            });
        }
    }
}
