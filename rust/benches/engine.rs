//! Batch-policy stepping throughput: SoA-batched vs scalar-loop across
//! B ∈ {1, 32, 256, 4096} — the hot-loop comparison behind the
//! batch-native policy core (EXPERIMENTS.md §Engine / §Perf) — plus a
//! per-kernel decision-core sweep at B ∈ {10k, 100k, 500k}.
//!
//! Three shapes per batch size, all reported as env-steps/s:
//!   * `native`  — the bit-pinned EnergyUCB fleet step (`FleetState`
//!     grids, reused `StepScratch` buffers), timed per step,
//!   * `batched` — the batch-native control loop (`policy_run`) driving
//!     the SoA `BatchEnergyUcb` (same arithmetic, policy-owned grids),
//!     timed over a fixed-length run,
//!   * `scalar-loop` — the same loop driving B scalar `EnergyUcb`
//!     instances through the `Scalar` bridge (the f64 per-env baseline
//!     the SoA path is measured against).
//!
//! The big-B sweep times the raw SA-UCB select and grid-update kernels
//! (`saucb_select_into_with` / `grid_update_batch_with`) on every kernel
//! the host can run, so scalar-vs-portable-vs-SSE2-vs-AVX2 gains read
//! off directly. All kernels are bit-identical by contract
//! (`tests/simd_conformance.rs`) — only the speed differs.
//!
//! Every case lands in a machine-readable bench-summary JSON
//! (`BENCH_engine.json`, or `$BENCH_SUMMARY_OUT`; see EXPERIMENTS.md
//! §Perf for the recording workflow).
//!
//! The loop-level drive-vs-native overhead comparison at matched
//! granularity lives in `benches/controller.rs`.

use energyucb::bandit::batch::{
    active_kernel, grid_update_batch_with, saucb_select_into_with, BatchEnergyUcb, BatchPolicy,
    Kernel, Scalar,
};
use energyucb::bandit::{BatchLinUcb, EnergyUcb, EnergyUcbConfig, CONTEXT_DIM};
use energyucb::fleet::{native, policy_run, FleetHyper, FleetParams, FleetState, StepScratch};
use energyucb::sim::freq::FreqDomain;
use energyucb::util::bench::{black_box, Bench, Summary};
use energyucb::util::Rng;
use energyucb::workload::calibration;

fn params_for(batch: usize) -> FleetParams {
    let freqs = FreqDomain::aurora();
    let apps: Vec<_> = calibration::all_apps();
    let assigned: Vec<&_> = apps.iter().cycle().take(batch).collect();
    FleetParams::from_apps(&assigned, &freqs, 0.01)
}

/// Steps per measured run for the loop-driven shapes: long enough to
/// amortize the fresh-state setup, short enough that B = 4096 stays
/// inside a bench sample.
const RUN_STEPS: u64 = 200;

/// A synthesized decision-core workload at batch size `b`: mid-run grids
/// with mixed pull counts, discrete means, ~1-in-8 masked arms, and
/// every 16th environment frozen.
struct KernelGrids {
    n: Vec<f32>,
    mean: Vec<f32>,
    prev: Vec<i32>,
    feasible: Vec<f32>,
    reward: Vec<f64>,
    active: Vec<f32>,
}

fn kernel_grids(b: usize, k: usize, seed: u64) -> KernelGrids {
    let mut rng = Rng::new(seed);
    let mut g = KernelGrids {
        n: Vec::with_capacity(b * k),
        mean: Vec::with_capacity(b * k),
        prev: Vec::with_capacity(b),
        feasible: Vec::with_capacity(b * k),
        reward: Vec::with_capacity(b),
        active: Vec::with_capacity(b),
    };
    for e in 0..b {
        for i in 0..k {
            g.n.push(rng.index(40) as f32);
            g.mean.push(-0.25 * rng.index(8) as f32);
            // Keep the max-frequency arm feasible (the mask-builder
            // contract), mask ~1 in 8 of the rest.
            g.feasible.push(if i == k - 1 || !rng.chance(0.125) { 1.0 } else { 0.0 });
        }
        g.prev.push(rng.index(k + 1) as i32 - 1);
        g.reward.push(-1.0 - 0.25 * rng.index(8) as f64);
        g.active.push(if e % 16 == 15 { 0.0 } else { 1.0 });
    }
    g
}

fn main() {
    let b = Bench::default();
    let hyper = FleetHyper::default();
    let k = 9usize;
    let mut summary = Summary::new("engine");
    summary.note("kernel", active_kernel().name());
    summary.note("run_steps", &RUN_STEPS.to_string());

    for batch in [1usize, 32, 256, 4096] {
        let params = params_for(batch);

        // Bit-pinned native EnergyUCB step (state-grid path).
        {
            let mut state = FleetState::fresh(batch, k);
            let mut scratch = StepScratch::new(batch);
            let mut noise = vec![0.0f32; batch];
            let mut rng = Rng::new(1);
            let mut step_idx = 0u64;
            summary.push(b.case(&format!("native/B={batch}"), batch as f64, || {
                native::step_noise_into(&params, step_idx, &mut rng, &mut noise);
                native::native_step_into(&mut state, &params, &hyper, &noise, &mut scratch);
                black_box(&scratch.sel);
                step_idx += 1;
                if state.all_done() {
                    state = FleetState::fresh(batch, k);
                    step_idx = 0;
                }
            }));
        }

        // Batch-native control loop + SoA batch policy (identical
        // trajectories to `native`, policy-owned grids).
        {
            summary.push(b.case(
                &format!("batched/B={batch}"),
                (batch as u64 * RUN_STEPS) as f64,
                || {
                    let mut state = FleetState::fresh(batch, k);
                    let mut policy = BatchEnergyUcb::with_initial_arm(batch, k, hyper, k - 1);
                    let mut rng = Rng::new(1);
                    black_box(policy_run(
                        &mut state,
                        &params,
                        &mut policy,
                        &mut rng,
                        RUN_STEPS,
                    ));
                },
            ));
        }

        // Same loop, B scalar policies over the bridge (the baseline the
        // SoA iteration is measured against).
        {
            summary.push(b.case(
                &format!("scalar-loop/B={batch}"),
                (batch as u64 * RUN_STEPS) as f64,
                || {
                    let mut state = FleetState::fresh(batch, k);
                    let mut policy = Scalar::new(
                        (0..batch)
                            .map(|_| EnergyUcb::new(k, EnergyUcbConfig::default()))
                            .collect::<Vec<_>>(),
                    );
                    let mut rng = Rng::new(1);
                    black_box(policy_run(
                        &mut state,
                        &params,
                        &mut policy,
                        &mut rng,
                        RUN_STEPS,
                    ));
                },
            ));
        }

        // Context-carrying select/update (the serving tier's decision
        // plane) at the same batch widths, over a frozen feature grid —
        // timed per step like `native` so the per-env cost of the
        // contextual path reads off directly against the context-free one.
        {
            let mut policy = BatchLinUcb::new(batch, k, CONTEXT_DIM, 1.0, 1.0);
            let feasible = vec![1.0f32; batch * k];
            let active = vec![1.0f32; batch];
            let progress = vec![1e-3f64; batch];
            let mut reward = vec![0.0f64; batch];
            let mut sel = vec![0i32; batch];
            let mut rng = Rng::new(1);
            let mut ctx = vec![0.0f64; batch * CONTEXT_DIM];
            for c in ctx.iter_mut() {
                *c = rng.uniform();
            }
            let mut t = 0u64;
            summary.push(b.case(&format!("ctx-select/B={batch}"), batch as f64, || {
                t += 1;
                policy.select_into_ctx(t, &feasible, &ctx, CONTEXT_DIM, &mut sel);
                for e in 0..batch {
                    reward[e] = -1.0 - 0.01 * sel[e] as f64;
                }
                policy.update_batch(&sel, &reward, &progress, &active);
                black_box(&sel);
            }));
        }
    }

    // Raw decision-core kernels at fleet scale, per kernel tier.
    for &big in &[10_000usize, 100_000, 500_000] {
        let grids = kernel_grids(big, k, 42);
        let mut sel = vec![0i32; big];
        for kernel in Kernel::available() {
            let name = kernel.name();
            summary.push(b.case(
                &format!("saucb-select/{name}/B={big}"),
                big as f64,
                || {
                    saucb_select_into_with(
                        kernel,
                        &grids.n,
                        &grids.mean,
                        &grids.prev,
                        250.0,
                        &grids.feasible,
                        &hyper,
                        k,
                        &mut sel,
                    );
                    black_box(&sel);
                },
            ));
        }
        // Selections from the last kernel feed the update cases — every
        // kernel produced the same `sel` (bit-identity contract).
        for kernel in Kernel::available() {
            let name = kernel.name();
            let mut n = grids.n.clone();
            let mut mean = grids.mean.clone();
            let mut prev = grids.prev.clone();
            summary.push(b.case(
                &format!("grid-update/{name}/B={big}"),
                big as f64,
                || {
                    grid_update_batch_with(
                        kernel,
                        &mut n,
                        &mut mean,
                        &mut prev,
                        &sel,
                        &grids.reward,
                        &grids.active,
                        k,
                    );
                    black_box(&mean);
                },
            ));
        }
    }

    match summary.write() {
        Ok(path) => println!("bench-summary JSON -> {}", path.display()),
        Err(e) => eprintln!("bench-summary write failed: {e}"),
    }
}
