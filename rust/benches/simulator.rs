//! Substrate throughput: node decision-interval rate (the quantity that
//! bounds how fast Table-1-scale sweeps run) and end-to-end session rate.

use energyucb::bandit::{EnergyUcb, EnergyUcbConfig, StaticPolicy};
use energyucb::control::{run_session, SessionCfg};
use energyucb::sim::freq::FreqDomain;
use energyucb::sim::node::Node;
use energyucb::util::bench::{black_box, Bench};
use energyucb::workload::calibration;

fn main() {
    let b = Bench::default();
    let freqs = FreqDomain::aurora();

    println!("# node simulator throughput");
    let app = calibration::app("tealeaf").unwrap();
    {
        let mut node = Node::new(app.clone(), freqs.clone(), 0.01, 1);
        let mut arm = 8usize;
        b.case("node.step (fixed freq)", 1.0, || {
            if node.done() {
                node = Node::new(app.clone(), freqs.clone(), 0.01, 1);
            }
            black_box(node.step(arm));
        });
        let mut node2 = Node::new(app.clone(), freqs.clone(), 0.01, 2);
        b.case("node.step (switch every step)", 1.0, || {
            if node2.done() {
                node2 = Node::new(app.clone(), freqs.clone(), 0.01, 2);
            }
            arm = if arm == 0 { 8 } else { 0 };
            black_box(node2.step(arm));
        });
    }

    println!("\n# full sessions (steps/s incl. policy, GEOPM plumbing, metrics)");
    for (label, fast_app) in [
        ("clvleaf static", true),
        ("clvleaf EnergyUCB", false),
    ] {
        let app = calibration::app("clvleaf").unwrap();
        let steps = (app.t_max_s / 0.01) as f64;
        b.case(&format!("session/{label}"), steps, || {
            if fast_app {
                let mut p = StaticPolicy::new(9, 8);
                black_box(run_session(&app, &mut p, &SessionCfg::default()));
            } else {
                let mut p = EnergyUcb::new(9, EnergyUcbConfig::default());
                black_box(run_session(&app, &mut p, &SessionCfg::default()));
            }
        });
    }
}
