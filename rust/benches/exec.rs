//! Executor scaling bench: wall-clock of a fixed session grid at
//! increasing `--jobs`, plus the table1-quick end-to-end wall-clock at
//! jobs = 1 vs jobs = all-cores. This regenerates the before/after numbers
//! recorded in EXPERIMENTS.md §Perf (the acceptance target: table1 ≥ 3×
//! faster at 8 jobs on an 8-core box).

use std::time::Instant;

use energyucb::bandit::{EnergyUcb, EnergyUcbConfig};
use energyucb::control::{run_session, SessionCfg};
use energyucb::exec::{available_jobs, run_indexed};
use energyucb::experiments::{ExpContext, Experiment};
use energyucb::workload::calibration;

fn main() {
    let cores = available_jobs();
    println!("# executor scaling ({cores} cores available)");

    // Fixed-size grid: 32 bounded EnergyUCB sessions on clvleaf.
    let app = calibration::app("clvleaf").unwrap();
    let cells = 32usize;
    let run_grid = |jobs: usize| -> (std::time::Duration, f64) {
        let t0 = Instant::now();
        let energies = run_indexed(jobs, cells, |i| {
            let mut policy = EnergyUcb::new(9, EnergyUcbConfig::default());
            let cfg = SessionCfg { seed: 100 + i as u64, max_steps: 2_000, ..SessionCfg::default() };
            run_session(&app, &mut policy, &cfg).metrics.gpu_energy_kj
        });
        (t0.elapsed(), energies.iter().sum())
    };

    let (base_wall, base_sum) = run_grid(1);
    println!(
        "bench exec/grid32/jobs=1   {:>8.3} s  (reference)",
        base_wall.as_secs_f64()
    );
    let mut jobs = 2;
    while jobs <= cores.max(2) {
        let (wall, sum) = run_grid(jobs);
        assert_eq!(sum, base_sum, "executor output changed with jobs={jobs}");
        println!(
            "bench exec/grid32/jobs={jobs:<3} {:>8.3} s  ({:.2}x, byte-identical ✓)",
            wall.as_secs_f64(),
            base_wall.as_secs_f64() / wall.as_secs_f64().max(1e-9)
        );
        jobs *= 2;
    }

    // End-to-end: table1 in quick mode, 1 job vs all cores.
    println!("\n# table1 (quick, reps=2) end-to-end");
    let table1 = energyucb::experiments::table1::Table1;
    let out = std::env::temp_dir().join("energyucb_exec_bench");
    let mut walls = Vec::new();
    for jobs in [1usize, cores] {
        let ctx = ExpContext {
            quick: true,
            reps: 2,
            jobs,
            out_dir: out.clone(),
            ..ExpContext::default()
        };
        let t0 = Instant::now();
        let report = table1.run(&ctx).expect("table1 runs");
        let wall = t0.elapsed();
        walls.push((jobs, wall, report.text));
        println!("bench exec/table1-quick/jobs={jobs:<3} {:>8.3} s", wall.as_secs_f64());
    }
    if let [(_, w1, t1), (j, wn, tn)] = &walls[..] {
        assert_eq!(t1, tn, "table1 report changed between jobs=1 and jobs={j}");
        println!(
            "table1-quick speedup at jobs={j}: {:.2}x (report byte-identical ✓)",
            w1.as_secs_f64() / wn.as_secs_f64().max(1e-9)
        );
    }
    let _ = std::fs::remove_dir_all(&out);
}
