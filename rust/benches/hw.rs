//! Live-hardware backend overhead: one apply+sample decision interval of
//! [`HwBackend`] over the deterministic MockDriver, across device counts.
//! This is the control-plane cost a live session pays per interval on top
//! of the driver's own call latency (which the `hw.*_latency_us` gauges
//! measure in situ), so it bounds how fine a dt_s the hw tier can pace.

use energyucb::control::{SessionCfg, StepSample, TelemetryBackend};
use energyucb::hw::{HwBackend, HwTuning, MockDriver};
use energyucb::util::bench::{black_box, Bench};
use energyucb::workload::calibration;

fn main() {
    let b = Bench::default();
    let app = calibration::app("tealeaf").unwrap();
    let cfg = SessionCfg::default();
    let freqs = cfg.domain();

    println!("# hw backend apply+sample interval (device-intervals/s; mock driver)");
    for devices in [1usize, 4, 16] {
        let driver = MockDriver::calibrated(&app, &freqs, devices, cfg.dt_s, cfg.seed);
        let mut backend = HwBackend::new(Box::new(driver), &cfg, HwTuning::default()).unwrap();
        let mut out = vec![StepSample::default(); devices];
        let mut sel = vec![0i32; devices];
        let mut arm = 0i32;
        b.case(&format!("mock/B={devices}"), devices as f64, || {
            // Alternate arms so half the intervals exercise a real clock
            // switch through the driver, half the same-arm fast path.
            arm = (arm + 1) % 2;
            sel.fill(arm);
            backend.apply(&sel).unwrap();
            backend.sample_into(&mut out).unwrap();
            black_box(&out);
            if backend.done() {
                // Long runs outlive the virtual workload: start a fresh
                // one so every iteration measures the live path.
                let driver = MockDriver::calibrated(&app, &freqs, devices, cfg.dt_s, cfg.seed);
                backend = HwBackend::new(Box::new(driver), &cfg, HwTuning::default()).unwrap();
            }
        });
    }
}
