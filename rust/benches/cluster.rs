//! Cluster scheduler bench: fixed-wave vs work-stealing wall-clock on a
//! staggered-duration scenario (the straggler workload waves are worst
//! at), plus report-equality assertions across schedulers and job counts.
//! Regenerates the numbers recorded in EXPERIMENTS.md §Perf.

use std::time::Instant;

use energyucb::cluster::{ClusterConfig, Leader, ScenarioSchedule};
use energyucb::exec::available_jobs;

fn main() {
    let cores = available_jobs();
    let jobs = cores.min(8);
    let nodes = 4 * jobs;
    println!("# cluster scheduling ({cores} cores; {jobs} jobs, {nodes} nodes)");

    // Staggered arrivals: step budgets 25–100 % of 6,000 decisions, so
    // every wave of `jobs` nodes contains one straggler at 4x the budget
    // of its shortest member.
    let schedule = ScenarioSchedule::preset("staggered", 2026).unwrap();
    let assignments = schedule.assignments(nodes).unwrap();
    let leader = Leader::new(ClusterConfig { jobs, ..ClusterConfig::default() });

    let t0 = Instant::now();
    let waves = leader.run_waves(&assignments).unwrap();
    let wave_wall = t0.elapsed();
    println!("bench cluster/staggered/waves     {:>8.3} s  (reference)", wave_wall.as_secs_f64());

    let t0 = Instant::now();
    let stealing = leader.run(&assignments).unwrap();
    let steal_wall = t0.elapsed();
    let speedup = wave_wall.as_secs_f64() / steal_wall.as_secs_f64().max(1e-9);
    println!(
        "bench cluster/staggered/stealing  {:>8.3} s  ({speedup:.2}x vs waves)",
        steal_wall.as_secs_f64()
    );
    assert_eq!(
        stealing.render(),
        waves.render(),
        "schedulers must produce identical reports"
    );
    if jobs > 1 {
        // With one worker both schedulers degenerate to a serial loop.
        assert!(
            speedup > 1.0,
            "work stealing should beat fixed waves on staggered durations ({speedup:.2}x)"
        );
    }

    // Determinism across job counts (the §Cluster contract).
    let serial = Leader::new(ClusterConfig { jobs: 1, ..ClusterConfig::default() })
        .run(&assignments)
        .unwrap();
    assert_eq!(serial.render(), stealing.render(), "report changed with jobs");
    println!("report byte-identical at jobs = 1 / {jobs} ✓");
}
