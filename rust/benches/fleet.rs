//! Fleet engine throughput: native vs HLO (PJRT) across batch sizes — the
//! L1/L2 perf surface. Regenerates the §Perf numbers in EXPERIMENTS.md.

use std::path::Path;

use energyucb::fleet::{native, FleetEngine, FleetHyper, FleetParams, FleetState};
use energyucb::runtime::XlaRuntime;
use energyucb::sim::freq::FreqDomain;
use energyucb::util::bench::{black_box, Bench};
use energyucb::util::Rng;
use energyucb::workload::calibration;

fn params_for(batch: usize) -> FleetParams {
    let freqs = FreqDomain::aurora();
    let apps: Vec<_> = calibration::all_apps();
    let assigned: Vec<&_> = apps.iter().cycle().take(batch).collect();
    FleetParams::from_apps(&assigned, &freqs, 0.01)
}

fn main() {
    let b = Bench::default();
    let hyper = FleetHyper::default();

    println!("# native fleet step (env-steps/s; reused noise/step buffers)");
    for batch in [64usize, 256, 1024] {
        let params = params_for(batch);
        let mut state = FleetState::fresh(batch, 9);
        let mut scratch = energyucb::fleet::StepScratch::new(batch);
        let mut noise = vec![0.0f32; batch];
        let mut rng = Rng::new(1);
        let mut step_idx = 0u64;
        b.case(&format!("native/B={batch}"), batch as f64, || {
            native::step_noise_into(&params, step_idx, &mut rng, &mut noise);
            native::native_step_into(&mut state, &params, &hyper, &noise, &mut scratch);
            black_box(&scratch.sel);
            step_idx += 1;
            if state.all_done() {
                state = FleetState::fresh(batch, 9);
                step_idx = 0;
            }
        });
    }

    let art = Path::new("artifacts");
    if !art.join("fleet_step_b64.hlo.txt").exists() {
        println!("\n(artifacts missing — run `make artifacts` for the HLO/PJRT cases)");
        return;
    }
    let runtime = XlaRuntime::cpu().expect("PJRT CPU");
    println!("\n# HLO fleet step via PJRT (env-steps/s; includes host<->literal packing)");
    for batch in [64usize, 256, 1024] {
        if !art.join(format!("fleet_step_b{batch}.hlo.txt")).exists() {
            continue;
        }
        let params = params_for(batch);
        let engine =
            FleetEngine::load(&runtime, art, params.clone(), hyper).expect("load engine");
        let mut state = FleetState::fresh(batch, 9);
        let mut rng = Rng::new(1);
        let mut step_idx = 0u64;
        b.case(&format!("hlo/B={batch}"), batch as f64, || {
            let noise = native::step_noise(&params, step_idx, &mut rng);
            black_box(engine.step(&mut state, &noise).expect("step"));
            step_idx += 1;
            if state.all_done() {
                state = FleetState::fresh(batch, 9);
                step_idx = 0;
            }
        });
        if engine.has_scan() {
            use energyucb::fleet::engine::SCAN_STEPS;
            let mut state = FleetState::fresh(batch, 9);
            let mut rng = Rng::new(1);
            let mut step_idx = 0u64;
            b.case(
                &format!("hlo-scan/B={batch} (S={SCAN_STEPS})"),
                (batch * SCAN_STEPS) as f64,
                || {
                    let mut noise_seq = Vec::with_capacity(SCAN_STEPS * batch);
                    for s in 0..SCAN_STEPS {
                        noise_seq.extend(native::step_noise(&params, step_idx + s as u64, &mut rng));
                    }
                    black_box(engine.step_scan(&mut state, &noise_seq).expect("scan"));
                    step_idx += SCAN_STEPS as u64;
                    if state.all_done() {
                        state = FleetState::fresh(batch, 9);
                        step_idx = 0;
                    }
                },
            );
        }
    }
}
