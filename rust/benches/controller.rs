//! Drive-loop overhead: the batch-native controller path (`policy_run` =
//! `Controller` + `FleetBackend` + `drive`) against the direct fleet
//! loop (`native_run`) at B ∈ {1, 32, 1024} — the cost of the sans-IO
//! decision core's bookkeeping (per-env normalizers, regret, samples)
//! on top of the identical environment arithmetic. Both shapes run the
//! pinned EnergyUCB fleet over the same calibrated parameters and are
//! reported as env-steps/s, so the gap between the `native` and `drive`
//! rows at matched B is the controller overhead (EXPERIMENTS.md §Perf).

use energyucb::fleet::{native, policy_run, FleetHyper, FleetParams, FleetState};
use energyucb::sim::freq::FreqDomain;
use energyucb::util::bench::{black_box, Bench};
use energyucb::util::Rng;
use energyucb::workload::calibration;

fn params_for(batch: usize) -> FleetParams {
    let freqs = FreqDomain::aurora();
    let apps: Vec<_> = calibration::all_apps();
    let assigned: Vec<&_> = apps.iter().cycle().take(batch).collect();
    FleetParams::from_apps(&assigned, &freqs, 0.01)
}

/// Steps per measured run: long enough to amortize fresh-state setup,
/// short enough that B = 1024 stays inside a bench sample.
const RUN_STEPS: u64 = 200;

fn main() {
    let b = Bench::default();
    let hyper = FleetHyper::default();
    let k = 9usize;

    for batch in [1usize, 32, 1024] {
        let params = params_for(batch);

        // Direct fleet loop (the bit-pinned reference path).
        b.case(
            &format!("native/B={batch}"),
            (batch as u64 * RUN_STEPS) as f64,
            || {
                let mut state = FleetState::fresh(batch, k);
                let mut rng = Rng::new(1);
                black_box(native::native_run(
                    &mut state, &params, &hyper, &mut rng, RUN_STEPS,
                ));
            },
        );

        // The same fleet through the batch-native controller (identical
        // trajectories; adds per-env metrics/regret/normalizer state).
        b.case(
            &format!("drive/B={batch}"),
            (batch as u64 * RUN_STEPS) as f64,
            || {
                let mut state = FleetState::fresh(batch, k);
                let mut policy = energyucb::bandit::batch::BatchEnergyUcb::with_initial_arm(
                    batch,
                    k,
                    hyper,
                    k - 1,
                );
                let mut rng = Rng::new(1);
                black_box(policy_run(&mut state, &params, &mut policy, &mut rng, RUN_STEPS));
            },
        );
    }
}
