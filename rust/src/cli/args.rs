//! Minimal argument-parsing substrate (clap is not in the offline crate
//! set): positionals, `--key value` options, and `--flag` booleans, with
//! typed accessors and unknown-option rejection.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Args {
    positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum ArgError {
    MissingValue(String),
    BadValue(String, String),
    Unknown(String),
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::MissingValue(name) => write!(f, "option --{name} expects a value"),
            ArgError::BadValue(name, value) => {
                write!(f, "invalid value for --{name}: {value}")
            }
            ArgError::Unknown(name) => write!(f, "unknown option --{name}"),
        }
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse raw arguments. `known_flags` take no value; any other `--x`
    /// consumes the next token as its value.
    pub fn parse<S: AsRef<str>>(raw: &[S], known_flags: &[&str]) -> Result<Args, ArgError> {
        let mut positional = Vec::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = raw.iter().map(|s| s.as_ref().to_string()).peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let name = name.to_string();
                if known_flags.contains(&name.as_str()) {
                    flags.push(name);
                } else if let Some((k, v)) = name.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else {
                    match it.next() {
                        Some(v) if !v.starts_with("--") => {
                            options.insert(name, v);
                        }
                        _ => return Err(ArgError::MissingValue(name)),
                    }
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Args { positional, options, flags })
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, ArgError> {
        self.typed(name, |v| v.parse::<usize>().ok())
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, ArgError> {
        self.typed(name, |v| v.parse::<u64>().ok())
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, ArgError> {
        self.typed(name, |v| v.parse::<f64>().ok())
    }

    fn typed<T>(
        &self,
        name: &str,
        parse: impl Fn(&str) -> Option<T>,
    ) -> Result<Option<T>, ArgError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => parse(v)
                .map(Some)
                .ok_or_else(|| ArgError::BadValue(name.to_string(), v.to_string())),
        }
    }

    /// Reject any option not in `allowed` (flags were validated at parse).
    pub fn ensure_known(&self, allowed: &[&str]) -> Result<(), ArgError> {
        for key in self.options.keys() {
            if !allowed.contains(&key.as_str()) {
                return Err(ArgError::Unknown(key.clone()));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            &["exp", "table1", "--reps", "5", "--quick", "--seed=9"],
            &["quick"],
        )
        .unwrap();
        assert_eq!(a.positional(), ["exp", "table1"]);
        assert_eq!(a.get_usize("reps").unwrap(), Some(5));
        assert_eq!(a.get_u64("seed").unwrap(), Some(9));
        assert!(a.flag("quick"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse(&["--reps"], &[]).unwrap_err();
        assert_eq!(e, ArgError::MissingValue("reps".into()));
        let e = Args::parse(&["--reps", "--other", "1"], &[]).unwrap_err();
        assert_eq!(e, ArgError::MissingValue("reps".into()));
    }

    #[test]
    fn bad_typed_value() {
        let a = Args::parse(&["--reps", "abc"], &[]).unwrap();
        assert!(matches!(a.get_usize("reps"), Err(ArgError::BadValue(_, _))));
    }

    #[test]
    fn unknown_rejection() {
        let a = Args::parse(&["--bogus", "1"], &[]).unwrap();
        assert!(a.ensure_known(&["reps"]).is_err());
        assert!(a.ensure_known(&["bogus"]).is_ok());
    }
}
