//! The `energyucb` launcher: subcommand dispatch.
//!
//! ```text
//! energyucb exp <id>|all [--reps N] [--seed S] [--out DIR] [--policy NAME] [--quick]
//! energyucb run [--config cfg.toml] [--app NAME] [--policy NAME] [--reps N]
//!               [--backend sim|mock|nvml] [--devices N]
//! energyucb devices [--config cfg.toml] [--backend mock|nvml]
//! energyucb replay --in FILE [--policy NAME]
//! energyucb sweep --replay FILE [--policies a,b,..] [--alpha L] [--lambda L] [--jobs J]
//! energyucb fleet [--apps a,b,..] [--batch B] [--steps N] [--native] [--delta D]
//!                 [--policy NAME[,NAME,...]] [--record-telemetry] [--record-out FILE]
//! energyucb cluster [--nodes N] [--jobs J] [--scenario NAME] [--config cfg.toml]
//!                   [--shards K] [--transport in-process|subprocess|tcp]
//!                   [--listen ADDR] [--shard-timeout SECS] [--workers N]
//! energyucb cluster-worker [--connect HOST:PORT] [--die-after-events N]
//! energyucb list
//! ```

pub mod args;

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::bandit::{BatchPolicy, Policy};
use crate::config::ExperimentConfig;
use crate::control::{
    drive, run_repeated, run_repeated_serving, sweep_replay, Controller, Recording,
    RepeatedMetrics, ReplayBackend, ReplayHeader, RunResult, SessionCfg, SimBackend,
    SweepCandidate, TelemetryBackend,
};
use crate::experiments::{all_experiments, experiment_by_id, ExpContext};
use crate::fleet::{fleet_controller, native, FleetBackend, FleetHyper, FleetParams, FleetState};
use crate::sim::freq::FreqDomain;
use crate::util::table::{fnum, fnum_sep, Table};
use crate::util::Rng;
use crate::workload::calibration;
use crate::workload::model::AppModel;
use crate::workload::serving::{ServingCfg, ServingModel};
use args::Args;

pub const USAGE: &str = "\
energyucb — online GPU energy optimization with switching-aware bandits

USAGE:
  energyucb exp <id>|all [--reps N] [--seed S] [--out DIR] [--jobs J]
                [--policy NAME] [--quick]
  energyucb run [--config FILE] [--app NAME] [--policy NAME] [--reps N] [--seed S]
                [--serving] [--record-telemetry] [--record-out FILE]
                [--backend sim|mock|nvml] [--devices N]
  energyucb devices [--config FILE] [--backend mock|nvml] [--devices N]
  energyucb replay --in FILE [--policy NAME]
  energyucb sweep --replay FILE [--policies NAME,NAME,...] [--alpha A,A,...]
                  [--lambda L,L,...] [--jobs J]
  energyucb fleet [--apps a,b,...] [--batch B] [--steps N] [--delta D] [--native]
                  [--policy NAME[,NAME,...]] [--serving]
                  [--record-telemetry] [--record-out FILE]
  energyucb cluster [--nodes N] [--jobs J] [--scenario NAME] [--config FILE]
                    [--seed S] [--heartbeat H] [--csv PATH] [--shards K] [--waves]
                    [--transport in-process|subprocess|tcp] [--listen ADDR]
                    [--shard-timeout SECS] [--shard-retries N] [--workers N]
                    [--chaos-kill W[:N]]
  energyucb list
  energyucb help

Experiments regenerate the paper's tables/figures (see `energyucb list`).
--jobs shards the experiment grid across J worker threads (default: all
cores); output is byte-identical at any J (see EXPERIMENTS.md).

Run drives the sans-IO controller against the simulated GEOPM backend.
--serving (or a [serving] config table) layers the inference-serving
scenario on top: a bursty diurnal arrival process feeds a per-step
workload context (queue depth, token rate, batch occupancy, util ratio)
to contextual policies (linucb/clinucb), and the report gains a QoS
column — the fraction of steps whose queue depth exceeded the TTFT-style
budget (EXPERIMENTS.md §Serving).
--record-telemetry tees every sample to a JSONL log (default
<out_dir>/telemetry_<app>.jsonl; requires --reps 1). `replay` feeds a
recorded log back through the controller: with the recording's own
policy the report is byte-identical to the original run; with --policy
it evaluates a different policy counterfactually on the frozen telemetry
(EXPERIMENTS.md §Controller).

--backend selects where run's telemetry comes from: sim (default), mock
(the deterministic fault-scriptable hardware driver; --devices N maps one
controller row per mock GPU), or nvml (live GPUs via a dlopen'd
libnvidia-ml; needs a build with --features nvml and the clock-management
privilege `nvidia-smi -lgc` uses). The [hw] config table sets the default
backend, device count, safety-rail tuning (min_dwell_steps,
watchdog_errors), and scripted mock faults; `devices` enumerates the
GPUs the active driver sees. Hardware runs record through the same
telemetry grammar, so `replay` and `sweep --replay` consume a mock or
live trace unchanged (EXPERIMENTS.md §Live hardware).

Sweep evaluates many policies against one frozen recording (session or
fleet), fanned out over --jobs threads with byte-identical output at any
J. --policies lists named policies; --alpha/--lambda build an EnergyUCB
hyper-parameter grid (cross product). Without either, the recording's
own policy is swept (EXPERIMENTS.md §Sweeps).

Fleet runs B lockstep environments through the batch policy core
(EXPERIMENTS.md §Engine). --policy selects any policy from `energyucb
list`; a comma-separated list builds a mixed-policy fleet (env e runs
policy e mod len). Non-default policies run on the native engine (the
HLO artifacts encode EnergyUCB). --serving attaches a per-row serving
workload (seeds staggered per row) whose context reaches contextual
policies. --record-telemetry tees the fleet run to a batched JSONL log
(default <out_dir>/telemetry_fleet.jsonl) that `sweep --replay`
evaluates counterfactually.

Cluster runs a simulated multi-node fleet on the work-stealing executor.
Scenarios: uniform | mixed | staggered | hetero | chaos, or a [cluster]
config file with [[cluster.scenario]] app-mix entries (see configs/
cluster_mixed.toml). --shards K partitions the fleet across K shard
batches; --transport picks the carrier: in-process (no serialization),
subprocess (JSONL pipe to cluster-worker children; the --shards default),
or tcp (the leader listens on --listen, default 127.0.0.1:0, and remote
`energyucb cluster-worker --connect HOST:PORT` processes dial in —
--workers N spawns that many local workers for you). A worker that hangs
or dies is detected within --shard-timeout SECS (default 120) and its
shard is requeued onto survivors; --shard-retries N caps how many times
a dead shard is requeued before the run fails (default 2; 0 = fail
fast); --chaos-kill W[:N] makes spawned worker W die after N event
frames to exercise exactly that path. Reports are
byte-identical at any --jobs, --shards, and transport — including
requeue runs; --waves uses the legacy fixed-wave scheduler (perf
baseline).";

/// Entry point used by main(); returns the process exit code.
pub fn dispatch<S: AsRef<str>>(raw: &[S]) -> Result<i32> {
    let argv: Vec<String> = raw.iter().map(|s| s.as_ref().to_string()).collect();
    let Some(cmd) = argv.first().cloned() else {
        println!("{USAGE}");
        return Ok(2);
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "exp" => cmd_exp(rest),
        "run" => cmd_run(rest),
        "devices" => cmd_devices(rest),
        "replay" => cmd_replay(rest),
        "sweep" => cmd_sweep(rest),
        "fleet" => cmd_fleet(rest),
        "cluster" => cmd_cluster(rest),
        // Hidden: the shard-worker half of `cluster --shards` / TCP mode
        // (frames on stdin or a `--connect` socket — EXPERIMENTS.md
        // §Cluster).
        "cluster-worker" => cmd_cluster_worker(rest),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => bail!("unknown command: {other}\n{USAGE}"),
    }
}

fn cmd_exp(rest: &[String]) -> Result<i32> {
    let args = Args::parse(rest, &["quick"])?;
    args.ensure_known(&["reps", "seed", "out", "jobs", "policy"])?;
    let Some(id) = args.positional().first() else {
        bail!("exp: missing experiment id (try `energyucb list`)");
    };
    let mut ctx = ExpContext::default();
    if let Some(r) = args.get_usize("reps")? {
        ctx.reps = r;
    }
    if let Some(s) = args.get_u64("seed")? {
        ctx.seed = s;
    }
    if let Some(o) = args.get("out") {
        ctx.out_dir = PathBuf::from(o);
    }
    if let Some(j) = args.get_usize("jobs")? {
        if j == 0 {
            bail!("exp: --jobs must be >= 1");
        }
        ctx.jobs = j;
    }
    if let Some(name) = args.get("policy") {
        // Policy selector for experiments that take one (currently the
        // fleet-backed `impact`); fixed-comparison experiments ignore it.
        ctx.policy = Some(parse_policy_name(name)?);
    }
    ctx.quick = args.flag("quick");

    let experiments = if id == "all" {
        all_experiments()
    } else {
        vec![experiment_by_id(id).with_context(|| format!("unknown experiment: {id}"))?]
    };
    for exp in experiments {
        eprintln!("== {} — {} ==", exp.id(), exp.title());
        let report = exp.run(&ctx)?;
        println!("# {} — {}\n", exp.id(), exp.title());
        println!("{}", report.text);
        let path = report.write(&ctx.out_dir)?;
        eprintln!("wrote {}", path.display());
    }
    Ok(0)
}

/// The `run`/`replay` report table (shared so record→replay output is
/// byte-comparable). `qos` appends the TTFT-budget violation column —
/// only serving/contextual reports carry it, so context-free output
/// stays byte-identical to the pre-serving grammar.
fn session_table(qos: bool) -> Table {
    let mut cols = vec![
        "app", "policy", "energy (kJ)", "saved (kJ)", "regret (kJ)", "time (s)", "switches",
    ];
    if qos {
        cols.push("QoS viol");
    }
    Table::new(cols)
}

/// One `run`/`replay` report row from per-run metrics. Saved energy goes
/// through [`RunMetrics::saved_energy_kj`] so budget-capped sessions
/// compare against the same completed work fraction (full runs are
/// arithmetically identical to the old max-arm-baseline formula).
///
/// [`RunMetrics::saved_energy_kj`]: crate::control::RunMetrics::saved_energy_kj
fn session_table_row(
    table: &mut Table,
    app: &AppModel,
    freqs: &FreqDomain,
    policy_name: &str,
    runs: &[crate::control::RunMetrics],
    qos: bool,
) {
    let agg = RepeatedMetrics::from_runs(runs);
    let saved_mean = crate::util::stats::mean(
        &runs.iter().map(|r| r.saved_energy_kj(app, freqs)).collect::<Vec<_>>(),
    );
    let mut cells = vec![
        app.name.to_string(),
        policy_name.to_string(),
        fnum_sep(agg.energy_mean_kj, 2),
        fnum(saved_mean, 2),
        fnum(agg.energy_mean_kj - app.optimal_energy_kj(), 2),
        fnum(agg.time_mean_s, 2),
        fnum(agg.switches_mean, 0),
    ];
    if qos {
        let viols: Vec<f64> = runs.iter().filter_map(|r| r.qos_violation_frac).collect();
        cells.push(if viols.is_empty() {
            "-".to_string()
        } else {
            fnum(crate::util::stats::mean(&viols), 3)
        });
    }
    table.row(cells);
}

fn cmd_run(rest: &[String]) -> Result<i32> {
    let args = Args::parse(rest, &["trace", "record-telemetry", "serving"])?;
    args.ensure_known(&[
        "config", "app", "policy", "reps", "seed", "alpha", "lambda", "delta", "ridge",
        "record-out", "backend", "devices",
    ])?;
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {path}"))?;
            ExperimentConfig::from_toml(&text)?
        }
        None => ExperimentConfig::default(),
    };
    if let Some(app) = args.get("app") {
        cfg.apps = vec![app.to_string()];
    }
    if let Some(name) = args.get("policy") {
        let mut toml = format!("[policy]\nname = \"{name}\"\n");
        if let Some(a) = args.get_f64("alpha")? {
            toml.push_str(&format!("alpha = {a}\n"));
        }
        if let Some(l) = args.get_f64("lambda")? {
            toml.push_str(&format!("lambda = {l}\n"));
        }
        if let Some(d) = args.get_f64("delta")? {
            toml.push_str(&format!("delta = {d}\n"));
        }
        if let Some(r) = args.get_f64("ridge")? {
            toml.push_str(&format!("ridge = {r}\n"));
        }
        cfg.policy = ExperimentConfig::from_toml(&toml)?.policy;
    }
    if let Some(r) = args.get_usize("reps")? {
        cfg.reps = r;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    let record = args.flag("record-telemetry");
    if record && cfg.reps != 1 {
        bail!("run: --record-telemetry records one session (use --reps 1)");
    }
    if !record && args.get("record-out").is_some() {
        bail!("run: --record-out requires --record-telemetry");
    }
    if record && args.get("record-out").is_some() && cfg.apps.len() > 1 {
        bail!("run: --record-out names one log; multiple apps would overwrite it");
    }
    // --serving enables the inference-serving scenario with the config's
    // [serving] table (or defaults); a [serving] table alone enables it
    // too, so shipped configs work without the flag.
    let serving: Option<ServingCfg> = if args.flag("serving") {
        Some(cfg.serving.clone().unwrap_or_default())
    } else {
        cfg.serving.clone()
    };

    // Backend selection: --backend overrides the [hw] table's default;
    // absent both, the simulated GEOPM service.
    let backend_name = match args.get("backend") {
        Some(b) => b.to_string(),
        None => cfg.hw.as_ref().map(|h| h.backend.clone()).unwrap_or_else(|| "sim".into()),
    };
    if backend_name != "sim" {
        if serving.is_some() {
            bail!("run: --serving is simulation-only (hardware backends have no serving model)");
        }
        return cmd_run_hw(&args, &cfg, &backend_name, record);
    }

    let freqs = cfg.freqs.clone().with_switch_cost(cfg.switch_cost);
    let mut table = session_table(serving.is_some());
    for name in &cfg.apps {
        let app = calibration::app(name).with_context(|| format!("unknown app {name}"))?;
        if app.energy_kj.len() != freqs.k() {
            bail!(
                "run: [freq] domain has {} arms but app {name} is calibrated for {}",
                freqs.k(),
                app.energy_kj.len()
            );
        }
        let mut policy: Box<dyn Policy> = cfg.build_policy(freqs.k(), cfg.seed);
        let scfg = SessionCfg {
            seed: cfg.seed,
            dt_s: cfg.dt_s,
            reward_form: cfg.reward_form,
            record_trace: args.flag("trace"),
            freqs: cfg.freqs.clone(),
            switch_cost: cfg.switch_cost,
            ..SessionCfg::default()
        };
        let results = if record {
            let path = match args.get("record-out") {
                Some(p) => PathBuf::from(p),
                None => PathBuf::from(&cfg.out_dir).join(format!("telemetry_{name}.jsonl")),
            };
            let result =
                record_session(&app, policy.as_mut(), &scfg, &cfg.policy, serving.as_ref(), &path)?;
            eprintln!("recorded telemetry to {}", path.display());
            vec![result]
        } else if let Some(srv) = &serving {
            run_repeated_serving(&app, policy.as_mut(), &scfg, srv, cfg.reps, cfg.seed)
        } else {
            run_repeated(&app, policy.as_mut(), &scfg, cfg.reps, cfg.seed)
        };
        let runs: Vec<_> = results.iter().map(|r| r.metrics.clone()).collect();
        session_table_row(&mut table, &app, &freqs, &policy.name(), &runs, serving.is_some());
        if args.flag("trace") {
            if let Some(tr) = &results[0].trace {
                let path = PathBuf::from(&cfg.out_dir).join(format!("trace_{name}.csv"));
                tr.write_csv(&path)?;
                eprintln!("wrote {}", path.display());
            }
        }
    }
    println!("{}", table.render());
    Ok(0)
}

/// Run one session with the [`Recording`] tee: same semantics as one
/// `run_repeated` rep (reset, seed from `cfg`), plus a telemetry log at
/// `path` replayable by `energyucb replay`.
fn record_session(
    app: &AppModel,
    policy: &mut dyn Policy,
    scfg: &SessionCfg,
    policy_cfg: &crate::config::PolicyConfig,
    serving: Option<&ServingCfg>,
    path: &std::path::Path,
) -> Result<RunResult> {
    policy.reset();
    let mut header =
        ReplayHeader::session(app.name.to_string(), Some(policy_cfg.clone()), scfg.clone());
    if let Some(s) = serving {
        // Contextual recordings declare the context grammar (and QoS
        // budget) up front so replay scores violations identically.
        header = header.with_context(Some(s.ttft_budget));
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating telemetry log {}", path.display()))?;
    let sink = std::io::BufWriter::new(file);
    let mut inner = SimBackend::new(app, scfg);
    if let Some(s) = serving {
        inner = inner.with_serving(ServingModel::new(s.clone()));
    }
    let mut backend = Recording::new(inner, sink, &header)?;
    let controller = Controller::new(app, policy, scfg)
        .with_qos_budget(serving.map(|s| s.ttft_budget));
    let result = drive(controller, &mut backend)?
        .pop()
        .expect("B = 1 drive yields exactly one result");
    backend.finish()?;
    Ok(result)
}

/// Build the configured hardware driver (`--backend mock|nvml`). `app`
/// and the session geometry calibrate the mock's virtual counters; the
/// nvml driver enumerates the host instead and rejects mock-only knobs.
fn build_hw_driver(
    backend_name: &str,
    app: &AppModel,
    scfg: &SessionCfg,
    hw: &crate::config::HwFileConfig,
    devices_flag: Option<usize>,
) -> Result<Box<dyn crate::hw::GpuDriver>> {
    match backend_name {
        "mock" => {
            let devices = devices_flag.unwrap_or(hw.devices);
            if devices == 0 {
                bail!("--devices must be >= 1");
            }
            let faults = hw
                .parsed_faults()
                .map_err(|e| anyhow::anyhow!("hw.faults: {e}"))?;
            Ok(Box::new(
                crate::hw::MockDriver::calibrated(
                    app,
                    &scfg.domain(),
                    devices,
                    scfg.dt_s,
                    scfg.seed,
                )
                .with_faults(faults),
            ))
        }
        "nvml" => {
            if devices_flag.is_some() {
                bail!("--devices applies to the mock backend (nvml enumerates the host)");
            }
            if !hw.faults.is_empty() {
                bail!("[hw] faults apply to the mock backend only");
            }
            crate::hw::nvml_driver()
        }
        other => bail!("unknown backend {other} (sim|mock|nvml)"),
    }
}

/// `run --backend mock|nvml`: drive the controller against the
/// live-hardware backend — one controller row per detected GPU — with
/// the same report table and (optionally) the same [`Recording`] tee as
/// the simulated path, so a hardware trace replays byte-for-byte through
/// `replay` (one device) or `sweep --replay` (multi-device).
fn cmd_run_hw(
    args: &Args,
    cfg: &ExperimentConfig,
    backend_name: &str,
    record: bool,
) -> Result<i32> {
    if cfg.reps != 1 {
        bail!("run: hardware backends drive one live session (use --reps 1)");
    }
    if cfg.apps.len() != 1 {
        bail!("run: hardware backends run one app per invocation");
    }
    let name = &cfg.apps[0];
    let app = calibration::app(name).with_context(|| format!("unknown app {name}"))?;
    let freqs = cfg.freqs.clone().with_switch_cost(cfg.switch_cost);
    if app.energy_kj.len() != freqs.k() {
        bail!(
            "run: [freq] domain has {} arms but app {name} is calibrated for {}",
            freqs.k(),
            app.energy_kj.len()
        );
    }
    let hw = cfg.hw.clone().unwrap_or_default();
    let tuning = crate::hw::HwTuning {
        min_dwell_steps: hw.min_dwell_steps,
        watchdog_errors: hw.watchdog_errors,
    };
    let scfg = SessionCfg {
        seed: cfg.seed,
        dt_s: cfg.dt_s,
        reward_form: cfg.reward_form,
        record_trace: args.flag("trace"),
        freqs: cfg.freqs.clone(),
        switch_cost: cfg.switch_cost,
        ..SessionCfg::default()
    };
    let driver = build_hw_driver(backend_name, &app, &scfg, &hw, args.get_usize("devices")?)
        .map_err(|e| e.context("run"))?;
    eprintln!("run: {} driver, backend {backend_name}", driver.name());
    let mut backend = crate::hw::HwBackend::new(driver, &scfg, tuning)?;
    for w in backend.warnings() {
        eprintln!("{w}");
    }
    let b = backend.b();
    let record_path = record.then(|| match args.get("record-out") {
        Some(p) => PathBuf::from(p),
        None => PathBuf::from(&cfg.out_dir).join(format!("telemetry_{name}.jsonl")),
    });
    let make_sink = |path: &std::path::Path| -> Result<std::io::BufWriter<std::fs::File>> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating telemetry log {}", path.display()))?;
        Ok(std::io::BufWriter::new(file))
    };

    let mut results = if b == 1 {
        // Scalar tier: identical construction to the sim path (and to
        // `replay`'s rebuild), so record→replay is byte-for-byte.
        let mut policy: Box<dyn Policy> = cfg.build_policy(freqs.k(), cfg.seed);
        policy.reset();
        let controller = Controller::new(&app, policy.as_mut(), &scfg);
        if let Some(path) = &record_path {
            let header =
                ReplayHeader::session(app.name.to_string(), Some(cfg.policy.clone()), scfg.clone());
            let mut rec = Recording::new(backend, make_sink(path)?, &header)?;
            let mut results = drive(controller, &mut rec)?;
            rec.inner().export_telemetry(&mut results[0].telemetry);
            rec.finish()?;
            results
        } else {
            let mut results = drive(controller, &mut backend)?;
            backend.export_telemetry(&mut results[0].telemetry);
            results
        }
    } else {
        // One controller row per GPU: the batch tier over B copies of the
        // app's ground truth. Multi-device recordings use the fleet
        // header grammar, which `sweep --replay` consumes — so the
        // controller is built exactly the way sweep rebuilds it from the
        // header (fleet_controller over FleetParams::from_apps), keeping
        // live and swept reports byte-identical.
        let refs: Vec<&AppModel> = vec![&app; b];
        let params = FleetParams::from_apps(&refs, &scfg.domain(), scfg.dt_s);
        let driver_policy = cfg.policy.build_batch(b, freqs.k(), cfg.seed);
        let controller = fleet_controller(&params, driver_policy, scfg.max_steps);
        if let Some(path) = &record_path {
            let header = ReplayHeader::fleet(
                vec![app.name.to_string(); b],
                Some(cfg.policy.clone()),
                scfg.clone(),
                None,
            );
            let mut rec = Recording::new(backend, make_sink(path)?, &header)?;
            let mut results = drive(controller, &mut rec)?;
            for r in &mut results {
                rec.inner().export_telemetry(&mut r.telemetry);
            }
            rec.finish()?;
            results
        } else {
            let mut results = drive(controller, &mut backend)?;
            for r in &mut results {
                backend.export_telemetry(&mut r.telemetry);
            }
            results
        }
    };
    if let Some(path) = &record_path {
        eprintln!("recorded telemetry to {}", path.display());
    }
    let mut table = session_table(false);
    for r in &results {
        session_table_row(&mut table, &app, &freqs, &r.metrics.policy, &[r.metrics.clone()], false);
    }
    println!("{}", table.render());
    if args.flag("trace") {
        if let Some(tr) = results[0].trace.take() {
            let path = PathBuf::from(&cfg.out_dir).join(format!("trace_{name}.csv"));
            tr.write_csv(&path)?;
            eprintln!("wrote {}", path.display());
        }
    }
    Ok(0)
}

/// Enumerate the GPUs the active hardware driver sees
/// (`energyucb devices [--backend mock|nvml]`): index, name, core-clock
/// range, supported-step count, board power limit. Deterministic under
/// the mock driver (pinned by CLI tests).
fn cmd_devices(rest: &[String]) -> Result<i32> {
    let args = Args::parse(rest, &[])?;
    args.ensure_known(&["config", "backend", "devices"])?;
    let cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {path}"))?;
            ExperimentConfig::from_toml(&text)?
        }
        None => ExperimentConfig::default(),
    };
    let hw = cfg.hw.clone().unwrap_or_default();
    // `devices` is hardware-only, so default to the mock driver even
    // when no [hw] table selected a backend.
    let backend_name = args.get("backend").unwrap_or(&hw.backend);
    if backend_name == "sim" {
        bail!("devices: the sim backend has no enumerable devices (try --backend mock)");
    }
    let name = cfg.apps.first().context("devices: config lists no apps")?;
    let app = calibration::app(name).with_context(|| format!("unknown app {name}"))?;
    let scfg = SessionCfg {
        seed: cfg.seed,
        dt_s: cfg.dt_s,
        freqs: cfg.freqs.clone(),
        switch_cost: cfg.switch_cost,
        ..SessionCfg::default()
    };
    let driver = build_hw_driver(backend_name, &app, &scfg, &hw, args.get_usize("devices")?)
        .map_err(|e| e.context("devices"))?;
    eprintln!("driver: {}", driver.name());
    println!("{}", crate::hw::devices_table(driver.as_ref())?);
    Ok(0)
}

/// Feed a recorded telemetry log back through the controller
/// (`energyucb replay --in run.jsonl [--policy NAME]`). Without
/// `--policy` the recording's own policy config is rebuilt, reproducing
/// the original report byte-for-byte; with it, the chosen policy is
/// evaluated counterfactually against the frozen sample stream (energy
/// totals remain the recorded run's — only decisions and regret change).
fn cmd_replay(rest: &[String]) -> Result<i32> {
    let args = Args::parse(rest, &[])?;
    args.ensure_known(&["in", "policy"])?;
    let Some(path) = args.get("in") else {
        bail!("replay: --in FILE is required");
    };
    let mut backend = ReplayBackend::open(std::path::Path::new(path))?;
    let header = backend.header().clone();
    // `replay` renders exactly one session; a batch recording has B rows
    // and (for counterfactual policies) needs a batch driver — that is
    // the sweep tier's job.
    if !header.envs.is_empty() {
        bail!(
            "replay: {path} is a fleet recording (B = {}); use `energyucb sweep --replay {path}`",
            header.b()
        );
    }
    let app = calibration::app(&header.app)
        .with_context(|| format!("recording references unknown app {}", header.app))?;
    let scfg = header.session.clone();
    // A recording is untrusted input: re-run the same validations
    // cmd_run / resolve_plans apply, as errors rather than the
    // controller's internal asserts.
    if app.energy_kj.len() != scfg.freqs.k() {
        bail!(
            "replay: recording's frequency domain has {} arms but app {} is calibrated for {}",
            scfg.freqs.k(),
            header.app,
            app.energy_kj.len()
        );
    }
    let policy_cfg = match args.get("policy") {
        Some(name) => parse_policy_name(name)?,
        None => header
            .policy
            .clone()
            .context("recording carries no policy config; pass --policy NAME")?,
    };
    if let crate::config::PolicyConfig::Static { arm } = &policy_cfg {
        if *arm >= scfg.freqs.k() {
            bail!("replay: static arm {arm} out of range (K = {})", scfg.freqs.k());
        }
    }
    let mut policy = policy_cfg.build(scfg.freqs.k(), scfg.seed);
    // Fresh-run contract: reset == freshly built, matching the recorded
    // session's starting state byte-for-byte. The policy is built at the
    // header's K, so its arity always matches the recorded arm range
    // (ReplayBackend validated every recorded arm against K on load).
    policy.reset();
    // Contextual recordings carry their QoS budget in the header; scoring
    // it here (not in the backend) keeps replay byte-identical to the
    // recorded run's report.
    let controller = Controller::new(&app, policy.as_mut(), &scfg)
        .with_qos_budget(header.context.and_then(|c| c.qos_budget));
    let result = drive(controller, &mut backend)?
        .pop()
        .expect("B = 1 drive yields exactly one result");
    let freqs = scfg.freqs.clone().with_switch_cost(scfg.switch_cost);
    // Column presence mirrors the recording's context declaration, the
    // same predicate `run` uses (serving configured), so record→replay
    // reports are byte-identical even in degenerate zero-context runs.
    let qos = header.context.is_some();
    let mut table = session_table(qos);
    let runs = [result.metrics.clone()];
    session_table_row(&mut table, &app, &freqs, &result.metrics.policy, &runs, qos);
    println!("{}", table.render());
    eprintln!("replayed {} recorded steps from {path}", result.metrics.steps);
    Ok(0)
}

/// Parse a single policy name (plus optional CLI hyper knobs rendered
/// elsewhere) through the `[policy]` schema, so CLI names and config names
/// can never drift.
fn parse_policy_name(name: &str) -> Result<crate::config::PolicyConfig> {
    let toml = format!("[policy]\nname = \"{name}\"\n");
    Ok(ExperimentConfig::from_toml(&toml)
        .with_context(|| format!("unknown policy: {name}"))?
        .policy)
}

/// Evaluate many policies against one frozen telemetry recording
/// (`energyucb sweep --replay rec.jsonl ...`). Record once, evaluate
/// many: every candidate sees the identical recorded sample stream, so
/// the report is a pure function of (recording, candidate list) and
/// byte-identical at any `--jobs` (EXPERIMENTS.md §Sweeps).
fn cmd_sweep(rest: &[String]) -> Result<i32> {
    let args = Args::parse(rest, &[])?;
    args.ensure_known(&["replay", "policies", "alpha", "lambda", "jobs"])?;
    let Some(path) = args.get("replay") else {
        bail!("sweep: --replay FILE is required");
    };
    let trace = ReplayBackend::open(std::path::Path::new(path))?;
    let header = trace.header().clone();

    let mut candidates: Vec<SweepCandidate> = Vec::new();
    if let Some(spec) = args.get("policies") {
        for name in spec.split(',') {
            candidates.push(SweepCandidate::new(parse_policy_name(name.trim())?));
        }
    }
    // --alpha/--lambda build an EnergyUCB hyper grid (cross product),
    // rendered through the [policy] schema so knob names cannot drift
    // from the config surface. Labels carry the grid point.
    let grid_axis = |key: &str| -> Result<Vec<Option<f64>>> {
        match args.get(key) {
            None => Ok(vec![None]),
            Some(spec) => spec
                .split(',')
                .map(|v| {
                    v.trim()
                        .parse::<f64>()
                        .map(Some)
                        .map_err(|_| anyhow::anyhow!("sweep: --{key}: bad number {v:?}"))
                })
                .collect(),
        }
    };
    if args.get("alpha").is_some() || args.get("lambda").is_some() {
        for a in &grid_axis("alpha")? {
            for l in &grid_axis("lambda")? {
                let mut toml = "[policy]\nname = \"energyucb\"\n".to_string();
                let mut tags = Vec::new();
                if let Some(a) = a {
                    toml.push_str(&format!("alpha = {a}\n"));
                    tags.push(format!("a={a}"));
                }
                if let Some(l) = l {
                    toml.push_str(&format!("lambda = {l}\n"));
                    tags.push(format!("l={l}"));
                }
                candidates.push(SweepCandidate::labeled(
                    format!("energyucb[{}]", tags.join(",")),
                    ExperimentConfig::from_toml(&toml)?.policy,
                ));
            }
        }
    }
    if candidates.is_empty() {
        // No explicit candidates: sweep the recording's own policy (a
        // determinism self-check — the report equals `energyucb replay`).
        candidates.push(SweepCandidate::new(header.policy.clone().context(
            "sweep: recording carries no policy config; pass --policies NAME[,NAME,...]",
        )?));
    }
    let jobs = match args.get_usize("jobs")? {
        Some(0) => bail!("sweep: --jobs must be >= 1"),
        Some(j) => j,
        None => crate::exec::available_jobs(),
    };

    let outcomes = sweep_replay(&trace, &candidates, jobs)?;
    let scfg = &header.session;
    if header.envs.is_empty() {
        // Session recording: one row per candidate in the same table as
        // `run`/`replay`, so a single-candidate sweep of the recorded
        // policy is byte-identical to the replay report (CI `cmp`s this).
        let app = calibration::app(&header.app)
            .with_context(|| format!("recording references unknown app {}", header.app))?;
        let freqs = scfg.domain();
        let qos = header.context.is_some();
        let mut table = session_table(qos);
        for out in &outcomes {
            let runs = [out.results[0].metrics.clone()];
            session_table_row(&mut table, &app, &freqs, &out.label, &runs, qos);
        }
        println!("{}", table.render());
    } else {
        // Fleet recording: aggregate the B rows per candidate.
        let mut table = Table::new(vec![
            "policy", "envs", "mean energy (kJ)", "mean regret", "switches (mean)",
        ]);
        for out in &outcomes {
            let kj: Vec<f64> = out.results.iter().map(|r| r.metrics.gpu_energy_kj).collect();
            let regret: Vec<f64> =
                out.results.iter().map(|r| r.metrics.cumulative_regret).collect();
            let sw: Vec<f64> =
                out.results.iter().map(|r| r.metrics.switches as f64).collect();
            table.row(vec![
                out.label.clone(),
                out.results.len().to_string(),
                fnum_sep(crate::util::stats::mean(&kj), 2),
                fnum(crate::util::stats::mean(&regret), 2),
                fnum(crate::util::stats::mean(&sw), 0),
            ]);
        }
        println!("{}", table.render());
    }
    // Diagnostics on stderr so stdout stays byte-comparable.
    eprintln!(
        "swept {} candidate(s) over {} recorded steps from {path} ({jobs} jobs)",
        outcomes.len(),
        trace.len(),
    );
    Ok(0)
}

fn cmd_fleet(rest: &[String]) -> Result<i32> {
    let args = Args::parse(rest, &["native", "record-telemetry", "serving"])?;
    args.ensure_known(&[
        "apps", "batch", "steps", "seed", "delta", "artifacts", "policy", "record-out",
    ])?;
    let record = args.flag("record-telemetry");
    if !record && args.get("record-out").is_some() {
        bail!("fleet: --record-out requires --record-telemetry");
    }
    let freqs = FreqDomain::aurora();
    let batch = args.get_usize("batch")?.unwrap_or(64);
    let steps = args.get_u64("steps")?.unwrap_or(10_000);
    let seed = args.get_u64("seed")?.unwrap_or(2026);
    let names: Vec<String> = match args.get("apps") {
        Some(s) => s.split(',').map(str::to_string).collect(),
        None => calibration::APP_NAMES.iter().map(|s| s.to_string()).collect(),
    };
    let apps: Vec<_> = names
        .iter()
        .map(|n| calibration::app(n).with_context(|| format!("unknown app {n}")))
        .collect::<Result<Vec<_>>>()?;
    let assigned: Vec<&_> = apps.iter().cycle().take(batch).collect();
    let mut params = FleetParams::from_apps(&assigned, &freqs, 0.01);
    if let Some(delta) = args.get_f64("delta")? {
        params.constrain(&assigned, &freqs, delta);
    }
    if let Some(spec) = args.get("policy") {
        params.policies = spec
            .split(',')
            .map(parse_policy_name)
            .collect::<Result<Vec<_>>>()?;
    }
    // A QoS mask only reaches policies whose batched form honors it; the
    // scalar bridge delegates feasibility to the wrapped policy, so
    // combining --delta with a bridge-backed policy would silently run
    // unconstrained (and make the feasible-best regret baseline lie).
    // Mixed lists always route through the bridge (build_fleet_policy),
    // even when every entry would honor the mask natively on its own.
    if args.get_f64("delta")?.is_some() {
        if params.policies.len() > 1 {
            bail!(
                "fleet: --delta cannot combine with a mixed-policy list — mixed fleets \
                 run via the scalar bridge, which ignores the QoS mask"
            );
        }
        if let Some(bad) = params.policies.iter().find(|p| !p.batch_honors_mask()) {
            bail!(
                "fleet: --delta needs a mask-honoring batched policy, but {bad:?} \
                 runs via the scalar bridge (which ignores the QoS mask)"
            );
        }
    }
    let hyper = FleetHyper::default();
    let mut state = FleetState::fresh(batch, freqs.k());
    let mut rng = Rng::new(seed);
    let serving_flag = args.flag("serving");
    // One serving model per fleet row, seeds staggered so rows see
    // decorrelated arrival streams.
    let serving_models = || -> Vec<ServingModel> {
        (0..batch)
            .map(|e| {
                ServingModel::new(ServingCfg { seed: seed + e as u64, ..ServingCfg::default() })
            })
            .collect()
    };
    let qos_budget = serving_flag.then(|| ServingCfg::default().ttft_budget);

    let t0 = std::time::Instant::now();
    let engine_name: String;
    if record || serving_flag || !params.policies.is_empty() {
        // Policy-selected and recorded fleets run the generic batch-policy
        // engine (the HLO artifacts encode EnergyUCB only and have no
        // telemetry tap; the engine is bit-identical to `--native` for the
        // pinned EnergyUCB fleet).
        if !args.flag("native") {
            if !params.policies.is_empty() {
                eprintln!("fleet: --policy implies the native engine");
            } else if serving_flag {
                eprintln!("fleet: --serving implies the native engine");
            } else {
                eprintln!("fleet: --record-telemetry implies the native engine");
            }
        }
        let mut policy = crate::fleet::build_fleet_policy(&params, &hyper, seed);
        if record {
            let path = match args.get("record-out") {
                Some(p) => PathBuf::from(p),
                None => PathBuf::from("results").join("telemetry_fleet.jsonl"),
            };
            // Provenance for `sweep --replay`: the roster (one name per
            // row), the policy when a single config can rebuild the run
            // (mixed fleets can't — sweeps must name candidates), and the
            // QoS mask when --delta constrained it.
            let policy_cfg = match params.policies.len() {
                0 => Some(crate::config::PolicyConfig::EnergyUcb(
                    crate::bandit::EnergyUcbConfig::default(),
                )),
                1 => Some(params.policies[0].clone()),
                _ => None,
            };
            let feasible = args
                .get_f64("delta")?
                .map(|_| params.feasible.iter().map(|&x| x as f64).collect());
            let scfg = SessionCfg {
                seed,
                dt_s: params.dt_s,
                max_steps: steps,
                freqs: freqs.clone(),
                ..SessionCfg::default()
            };
            let env_names: Vec<String> =
                names.iter().cycle().take(batch).cloned().collect();
            let mut header = ReplayHeader::fleet(env_names, policy_cfg, scfg, feasible);
            if let Some(budget) = qos_budget {
                header = header.with_context(Some(budget));
            }
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
            let file = std::fs::File::create(&path)
                .with_context(|| format!("creating telemetry log {}", path.display()))?;
            let sink = std::io::BufWriter::new(file);
            {
                let controller = fleet_controller(&params, Box::new(policy.as_mut()), steps)
                    .with_qos_budget(qos_budget);
                let mut inner = FleetBackend::new(&mut state, &params, &mut rng);
                if serving_flag {
                    inner = inner.with_serving(serving_models());
                }
                let mut backend = Recording::new(inner, sink, &header)?;
                drive(controller, &mut backend)?;
                backend.finish()?;
            }
            eprintln!("recorded fleet telemetry to {}", path.display());
        } else if serving_flag {
            // Serving fleets run the generic drive loop so per-row context
            // reaches the batch policy (policy_run has no context path).
            let controller = fleet_controller(&params, Box::new(policy.as_mut()), steps)
                .with_qos_budget(qos_budget);
            let mut backend =
                FleetBackend::new(&mut state, &params, &mut rng).with_serving(serving_models());
            drive(controller, &mut backend)?;
        } else {
            crate::fleet::policy_run(&mut state, &params, policy.as_mut(), &mut rng, steps);
        }
        engine_name = format!("native:{}", policy.name());
    } else if args.flag("native") {
        native::native_run(&mut state, &params, &hyper, &mut rng, steps);
        engine_name = "native".into();
    } else {
        let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
        let runtime = crate::runtime::XlaRuntime::cpu()?;
        let engine = crate::fleet::FleetEngine::load(&runtime, &dir, params.clone(), hyper)?;
        engine.run(&mut state, &mut rng, steps)?;
        engine_name = "hlo".into();
    }
    let dt = t0.elapsed();
    let done = batch - state.active_count();
    let steps_done = (state.t - 1.0) as u64;
    println!(
        "fleet[{engine_name}]: B={batch} steps={steps_done} done={done}/{batch} \
         wall={:.2}s ({:.0} env-steps/s)",
        dt.as_secs_f64(),
        batch as f64 * steps_done as f64 / dt.as_secs_f64().max(1e-9)
    );
    // Per-app mean energy of completed envs.
    let mut table = Table::new(vec!["app", "envs", "done", "mean kJ (completed)"]);
    for name in &names {
        let mut kj = Vec::new();
        let mut total = 0usize;
        for (e, assigned_name) in names.iter().cycle().take(batch).enumerate() {
            if assigned_name == name {
                total += 1;
                if state.remaining[e] <= 0.0 {
                    kj.push(state.energy_kj(e));
                }
            }
        }
        table.row(vec![
            name.clone(),
            total.to_string(),
            kj.len().to_string(),
            if kj.is_empty() {
                "-".into()
            } else {
                fnum_sep(crate::util::stats::mean(&kj), 2)
            },
        ]);
    }
    println!("{}", table.render());
    Ok(0)
}

fn cmd_cluster(rest: &[String]) -> Result<i32> {
    use crate::cluster::{ClusterConfig, Leader, ScenarioSchedule, Tcp, DEFAULT_SHARD_TIMEOUT};
    use crate::config::ClusterFileConfig;
    use std::process::{Command, Stdio};
    use std::time::Duration;

    let args = Args::parse(rest, &["waves"])?;
    args.ensure_known(&[
        "nodes", "jobs", "scenario", "config", "seed", "heartbeat", "csv", "shards",
        "transport", "listen", "shard-timeout", "shard-retries", "workers", "chaos-kill",
    ])?;
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            ClusterFileConfig::from_toml(&text)?
        }
        None => ClusterFileConfig::default(),
    };
    if let Some(s) = args.get_u64("seed")? {
        cfg.schedule.seed = s;
    }
    if let Some(name) = args.get("scenario") {
        // A preset replaces the whole schedule; combining it with a config
        // file would silently drop the file's mix/arrivals/hetero setup.
        if args.get("config").is_some() {
            bail!("cluster: --scenario and --config are mutually exclusive");
        }
        cfg.schedule = ScenarioSchedule::preset(name, cfg.schedule.seed)
            .with_context(|| {
                format!("unknown scenario: {name} (uniform|mixed|staggered|hetero|chaos)")
            })?;
    }
    if let Some(n) = args.get_usize("nodes")? {
        if n == 0 {
            bail!("cluster: --nodes must be >= 1");
        }
        cfg.nodes = n;
    }
    if let Some(j) = args.get_usize("jobs")? {
        if j == 0 {
            bail!("cluster: --jobs must be >= 1");
        }
        cfg.jobs = Some(j);
    }
    if let Some(h) = args.get_u64("heartbeat")? {
        if h == 0 {
            bail!("cluster: --heartbeat must be >= 1");
        }
        cfg.heartbeat_steps = h;
    }
    if let Some(s) = args.get_usize("shards")? {
        if s == 0 {
            bail!("cluster: --shards must be >= 1");
        }
        cfg.shards = Some(s);
    }
    if let Some(t) = args.get("transport") {
        cfg.transport = Some(t.to_string());
    }
    if let Some(l) = args.get("listen") {
        cfg.listen = Some(l.to_string());
    }
    if let Some(s) = args.get_f64("shard-timeout")? {
        if !(s > 0.0) {
            bail!("cluster: --shard-timeout must be > 0 seconds");
        }
        cfg.shard_timeout_s = Some(s);
    }
    if let Some(r) = args.get_usize("shard-retries")? {
        cfg.shard_retries = Some(r);
    }
    if args.flag("waves") && cfg.shards.is_some() {
        bail!("cluster: --waves and --shards are mutually exclusive");
    }
    if args.flag("waves") && cfg.transport.is_some() {
        bail!("cluster: --waves and --transport are mutually exclusive");
    }

    // Resolve the shard transport. An explicit name wins (config file or
    // CLI); otherwise --shards implies the historical subprocess path and
    // an unsharded run stays on the in-process pool.
    let transport_name = match cfg.transport.as_deref() {
        Some(t @ ("in-process" | "subprocess" | "tcp")) => t,
        Some(other) => {
            bail!("cluster: unknown transport {other:?} (in-process|subprocess|tcp)")
        }
        None => {
            if cfg.shards.is_some() {
                "subprocess"
            } else {
                "in-process"
            }
        }
    };
    if matches!(transport_name, "subprocess" | "tcp") && cfg.shards.is_none() {
        bail!("cluster: --transport {transport_name} requires --shards K");
    }
    if transport_name != "tcp" {
        if cfg.listen.is_some() {
            bail!("cluster: --listen requires --transport tcp");
        }
        if args.get("workers").is_some() {
            bail!("cluster: --workers requires --transport tcp");
        }
        if args.get("chaos-kill").is_some() {
            bail!("cluster: --chaos-kill requires --transport tcp");
        }
    }
    let workers = match args.get_usize("workers")? {
        Some(0) => bail!("cluster: --workers must be >= 1"),
        w => w,
    };
    // `--chaos-kill W[:N]`: spawned worker W exits abruptly after writing
    // its Nth event frame — a scripted mid-stream death for exercising
    // the leader's requeue path end to end.
    let chaos_kill: Option<(usize, u64)> = match args.get("chaos-kill") {
        None => None,
        Some(spec) => {
            let (w, n) = spec.split_once(':').unwrap_or((spec, "1"));
            let w = w
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("cluster: --chaos-kill: bad worker index {w:?}"))?;
            let n = n
                .parse::<u64>()
                .ok()
                .filter(|&n| n >= 1)
                .with_context(|| format!("cluster: --chaos-kill: bad event count {n:?}"))?;
            Some((w, n))
        }
    };
    if let Some((victim, _)) = chaos_kill {
        match workers {
            None => bail!("cluster: --chaos-kill needs --workers (it names a spawned worker)"),
            Some(w) if victim >= w => {
                bail!("cluster: --chaos-kill worker index {victim} out of range (--workers {w})")
            }
            Some(_) => {}
        }
    }
    let shard_timeout = Duration::from_secs_f64(
        cfg.shard_timeout_s.unwrap_or(DEFAULT_SHARD_TIMEOUT.as_secs_f64()),
    );

    let jobs = cfg.jobs.unwrap_or_else(crate::exec::available_jobs);
    let mut ccfg = ClusterConfig {
        jobs,
        policy: cfg.policy.clone(),
        session: SessionCfg::default(),
        heartbeat_steps: cfg.heartbeat_steps,
        ..ClusterConfig::default()
    };
    if let Some(r) = cfg.shard_retries {
        ccfg.shard_retries = r;
    }
    let leader = Leader::new(ccfg);
    let assignments =
        cfg.schedule.assignments(cfg.nodes).map_err(|e| anyhow::anyhow!("cluster: {e}"))?;
    let mode = if args.flag("waves") {
        "fixed waves".to_string()
    } else if let Some(s) = cfg.shards {
        format!("{s} {transport_name} shards")
    } else {
        "work-stealing".to_string()
    };
    eprintln!("cluster: {} nodes, scenario {}, {jobs} jobs ({mode})", cfg.nodes, cfg.schedule.name);
    let t0 = std::time::Instant::now();
    let report = if args.flag("waves") {
        leader.run_waves(&assignments)?
    } else if let Some(shards) = cfg.shards {
        match transport_name {
            // Sharded semantics (partition + requeue machinery) on the
            // in-process pool — the serialization-free reference.
            "in-process" => {
                leader.run_sharded(&assignments, shards, &crate::cluster::InProcess)?
            }
            // Workers are this same binary re-entered as `cluster-worker`;
            // assignments reach them only via the JSONL wire protocol.
            "subprocess" => {
                let transport =
                    crate::cluster::Subprocess::current_exe()?.with_timeout(shard_timeout);
                leader.run_sharded(&assignments, shards, &transport)?
            }
            "tcp" => {
                let transport =
                    Tcp::listen(cfg.listen.as_deref().unwrap_or("127.0.0.1:0"), shard_timeout)?;
                let addr = transport.local_addr()?;
                eprintln!(
                    "cluster: listening on {addr} \
                     (join with `energyucb cluster-worker --connect {addr}`)"
                );
                // Convenience/chaos harness: spawn local workers that dial
                // the listener, exactly as remote hosts would.
                let mut children = Vec::new();
                if let Some(w) = workers {
                    let exe =
                        std::env::current_exe().context("resolving current executable")?;
                    for i in 0..w {
                        let mut c = Command::new(&exe);
                        c.arg("cluster-worker").arg("--connect").arg(addr.to_string());
                        if let Some((victim, n)) = chaos_kill {
                            if victim == i {
                                c.arg("--die-after-events").arg(n.to_string());
                            }
                        }
                        c.stdin(Stdio::null()).stdout(Stdio::null()).stderr(Stdio::inherit());
                        let child = c
                            .spawn()
                            .with_context(|| format!("spawning cluster worker {i}"))?;
                        children.push(child);
                    }
                }
                let outcome = leader.run_sharded(&assignments, shards, &transport);
                // Closing the listener and pooled connections EOFs every
                // worker's socket; they exit cleanly and get reaped before
                // the run result (success *or* failure) propagates.
                drop(transport);
                for mut child in children {
                    let _ = child.wait();
                }
                outcome?
            }
            other => unreachable!("validated transport {other}"),
        }
    } else {
        leader.run(&assignments)?
    };
    let wall = t0.elapsed();
    // Deterministic report on stdout; timing on stderr so stdout stays
    // byte-identical across --jobs.
    print!("{}", report.render());
    let sim_seconds: f64 = report.nodes.iter().map(|n| n.metrics.exec_time_s).sum();
    eprintln!(
        "wall {:.2}s, simulated {:.0} node-seconds ({:.0}x real time)",
        wall.as_secs_f64(),
        sim_seconds,
        sim_seconds / wall.as_secs_f64().max(1e-9)
    );
    if let Some(path) = args.get("csv") {
        let path = PathBuf::from(path);
        report.to_csv().write_to(&path)?;
        eprintln!("wrote {}", path.display());
    }
    Ok(0)
}

/// The shard-worker half of `cluster --shards` / `--transport tcp`
/// (hidden subcommand).
///
/// Protocol (framed JSONL, one `cluster::wire::Frame` per line): the
/// input carries `config`, then one `assign` per node, then `run`; the
/// output streams one `event` per `WorkerEvent` as the shard executes,
/// then a terminal `end` (or `error`) frame. Assignments reach this
/// process only through the wire — there is no shared state with the
/// leader.
///
/// Two carriers, one grammar: without flags the batch arrives on stdin
/// and the process serves exactly one shard (the pipe transport);
/// `--connect HOST:PORT` dials a `cluster --transport tcp` leader and
/// serves batches over the socket until the leader hangs up.
/// `--die-after-events N` is a test/chaos hook: the worker exits abruptly
/// after writing its Nth event frame, simulating a crashed host.
fn cmd_cluster_worker(rest: &[String]) -> Result<i32> {
    let args = Args::parse(rest, &[])?;
    args.ensure_known(&["connect", "die-after-events"])?;
    if !args.positional().is_empty() {
        bail!("cluster-worker: unexpected arguments (assignments arrive as frames, not argv)");
    }
    let die_after = args.get_u64("die-after-events")?;
    match args.get("connect") {
        Some(addr) => {
            let conn = std::net::TcpStream::connect(addr)
                .with_context(|| format!("connecting to cluster leader at {addr}"))?;
            let _ = conn.set_nodelay(true); // frames are small and latency-bound
            let reader = std::io::BufReader::new(
                conn.try_clone().context("cloning leader connection")?,
            );
            serve_worker_batches(reader, conn, false, die_after)
        }
        None => serve_worker_batches(
            std::io::stdin().lock(),
            std::io::stdout(),
            true,
            die_after,
        ),
    }
}

/// Report a worker-side protocol failure as an `error` frame (and exit
/// code 1) so the leader can surface the reason verbatim. Write errors
/// are ignored — if the leader is already gone there is nobody to tell.
fn worker_fail<W: std::io::Write>(out: &mut W, message: String) -> Result<i32> {
    use crate::cluster::Frame;
    let _ = writeln!(out, "{}", Frame::Error { message }.encode_line());
    let _ = out.flush();
    Ok(1)
}

/// The worker's serve loop, generic over the frame carrier: read one
/// `config`/`assign`*/`run` batch from `input`, run it on the in-process
/// shard engine, stream `event`* + `end` to `output`, repeat.
///
/// `once` encodes the carrier's lifecycle: on stdin (`once = true`) the
/// process serves exactly one batch, and EOF before `run` is a protocol
/// error; on a socket (`once = false`) the connection outlives batches,
/// so EOF at a batch *boundary* is the leader's normal hang-up (clean
/// exit 0) while EOF inside a partial batch is still an error.
fn serve_worker_batches<R, W>(
    mut input: R,
    mut output: W,
    once: bool,
    die_after: Option<u64>,
) -> Result<i32>
where
    R: std::io::BufRead,
    W: std::io::Write + Send,
{
    use crate::cluster::{transport, ClusterConfig, Frame, NodeAssignment};

    // Events written across *all* batches, so `--die-after-events N`
    // counts process lifetime, not per-shard progress.
    let mut written: u64 = 0;
    loop {
        let mut cfg: Option<ClusterConfig> = None;
        let mut shard: Vec<NodeAssignment> = Vec::new();
        let mut launched = false;
        let mut mid_batch = false;
        let mut line = String::new();
        loop {
            line.clear();
            let n = input.read_line(&mut line).context("reading leader frames")?;
            if n == 0 {
                break; // EOF
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            match Frame::decode_line(trimmed) {
                Ok(Frame::Config { jobs, heartbeat_steps, policy, session }) => {
                    mid_batch = true;
                    cfg = Some(ClusterConfig {
                        jobs,
                        policy,
                        session,
                        heartbeat_steps,
                        ..ClusterConfig::default()
                    });
                }
                Ok(Frame::Assign(a)) => {
                    mid_batch = true;
                    shard.push(a);
                }
                Ok(Frame::Run) => {
                    launched = true;
                    break;
                }
                Ok(other) => return worker_fail(&mut output, format!("unexpected frame: {other:?}")),
                Err(e) => return worker_fail(&mut output, e.to_string()),
            }
        }
        if !launched {
            if once || mid_batch {
                return worker_fail(&mut output, "input ended before a run frame".to_string());
            }
            return Ok(0); // leader hung up between batches: end of service
        }
        let Some(cfg) = cfg else {
            return worker_fail(&mut output, "no config frame before run".to_string());
        };
        if cfg.jobs == 0 {
            return worker_fail(&mut output, "config jobs must be >= 1".to_string());
        }

        let streamed = transport::run_shard_with(&cfg, &shard, |ev| {
            writeln!(output, "{}", Frame::Event(ev).encode_line())?;
            // Per-line flush so no frame is stranded in a block buffer if
            // this process dies mid-shard (cheap: <= 50 heartbeats/node).
            output.flush()?;
            written += 1;
            if die_after.is_some_and(|n| written >= n) {
                // Chaos hook: die like a crashed host — no error frame, no
                // terminal frame, just a severed stream.
                std::process::exit(137);
            }
            Ok(())
        });
        match streamed {
            Ok(()) => {
                writeln!(output, "{}", Frame::End { nodes: shard.len() }.encode_line())?;
                output.flush().context("flushing terminal frame")?;
                if once {
                    return Ok(0);
                }
            }
            Err(e) => return worker_fail(&mut output, format!("{e:#}")),
        }
    }
}

fn cmd_list() -> Result<i32> {
    println!("experiments:");
    for e in all_experiments() {
        println!("  {:8} {}", e.id(), e.title());
    }
    println!("\napps (calibrated to the paper's Table 1):");
    let freqs = FreqDomain::aurora();
    for app in calibration::all_apps() {
        println!(
            "  {:10} {:13?} T(1.6GHz)={:>6.1}s  optimal={}  E*={:.2} kJ",
            app.name,
            app.class,
            app.t_max_s,
            freqs.label(app.optimal_arm()),
            app.optimal_energy_kj()
        );
    }
    println!(
        "\npolicies: energyucb constrained ucb1 swucb egreedy energyts rrfreq static rlpower \
         drlcap linucb clinucb"
    );
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn help_and_list_work() {
        assert_eq!(dispatch(&["help"]).unwrap(), 0);
        assert_eq!(dispatch(&["list"]).unwrap(), 0);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&["frobnicate"]).is_err());
    }

    #[test]
    fn exp_requires_id() {
        assert!(dispatch(&["exp"]).is_err());
        assert!(dispatch(&["exp", "not-an-exp"]).is_err());
    }

    #[test]
    fn exp_rejects_zero_jobs() {
        assert!(dispatch(&["exp", "fig1b", "--jobs", "0"]).is_err());
    }

    #[test]
    fn run_single_quick_session() {
        // tealeaf + static policy completes fast.
        let code = dispatch(&[
            "run", "--app", "tealeaf", "--policy", "static", "--reps", "1",
        ])
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn devices_enumerates_the_mock_driver() {
        assert_eq!(dispatch(&["devices"]).unwrap(), 0); // defaults to mock
        assert_eq!(dispatch(&["devices", "--backend", "mock", "--devices", "2"]).unwrap(), 0);
        assert!(dispatch(&["devices", "--backend", "sim"]).is_err());
        assert!(dispatch(&["devices", "--backend", "warp"]).is_err());
        assert!(dispatch(&["devices", "--devices", "0"]).is_err());
    }

    #[test]
    fn hw_run_records_and_replays() {
        let dir = std::env::temp_dir().join(format!("energyucb_cli_hw_{}", std::process::id()));
        let log = dir.join("hw.jsonl");
        let log_s = log.to_str().unwrap().to_string();
        let code = dispatch(&[
            "run", "--app", "tealeaf", "--policy", "static", "--backend", "mock", "--seed", "5",
            "--record-telemetry", "--record-out", &log_s,
        ])
        .unwrap();
        assert_eq!(code, 0);
        // A mock-hardware trace is a standard telemetry recording: the
        // session replays (and counterfactual-replays) unchanged.
        assert_eq!(dispatch(&["replay", "--in", &log_s]).unwrap(), 0);
        assert_eq!(dispatch(&["replay", "--in", &log_s, "--policy", "rrfreq"]).unwrap(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hw_run_rejects_bad_invocations() {
        assert!(
            dispatch(&["run", "--app", "tealeaf", "--backend", "mock", "--reps", "2"]).is_err()
        );
        assert!(
            dispatch(&["run", "--app", "tealeaf", "--backend", "mock", "--serving"]).is_err()
        );
        assert!(dispatch(&["run", "--app", "tealeaf", "--backend", "warp"]).is_err());
        assert!(dispatch(&[
            "run", "--app", "tealeaf", "--backend", "mock", "--devices", "0"
        ])
        .is_err());
        // Without the nvml feature the backend fails fast with a rebuild
        // hint; --devices is mock-only under any build.
        assert!(dispatch(&[
            "run", "--app", "tealeaf", "--backend", "nvml", "--devices", "2"
        ])
        .is_err());
    }

    #[test]
    fn record_and_replay_round_trip() {
        let dir =
            std::env::temp_dir().join(format!("energyucb_cli_replay_{}", std::process::id()));
        let log = dir.join("rec.jsonl");
        let log_s = log.to_str().unwrap().to_string();
        let code = dispatch(&[
            "run", "--app", "tealeaf", "--policy", "static", "--reps", "1", "--seed", "9",
            "--record-telemetry", "--record-out", &log_s,
        ])
        .unwrap();
        assert_eq!(code, 0);
        // Replay with the recorded policy config.
        assert_eq!(dispatch(&["replay", "--in", &log_s]).unwrap(), 0);
        // Counterfactual replay with a different policy.
        assert_eq!(dispatch(&["replay", "--in", &log_s, "--policy", "rrfreq"]).unwrap(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn record_and_replay_reject_bad_invocations() {
        // Recording is one session by definition.
        assert!(dispatch(&[
            "run", "--app", "tealeaf", "--policy", "static", "--reps", "2",
            "--record-telemetry",
        ])
        .is_err());
        // --record-out without --record-telemetry is a flag-soup error.
        assert!(
            dispatch(&["run", "--app", "tealeaf", "--record-out", "x.jsonl"]).is_err()
        );
        assert!(dispatch(&["replay"]).is_err());
        assert!(dispatch(&["replay", "--in", "/nonexistent/rec.jsonl"]).is_err());
        assert!(dispatch(&["replay", "--bogus", "1"]).is_err());
    }

    #[test]
    fn replay_rejects_tampered_recordings_without_panicking() {
        use crate::control::{BackendTotals, ReplayHeader, TelemetryFrame};
        let dir =
            std::env::temp_dir().join(format!("energyucb_cli_tamper_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let end = TelemetryFrame::End {
            totals: vec![BackendTotals::default()],
            steps: None,
            truncated: false,
        }
        .encode_line();

        // Domain/calibration mismatch: a 1-arm domain against tealeaf's
        // 9-entry table must be a CLI error, not the controller assert.
        let bad_domain = dir.join("bad_domain.jsonl");
        let header = ReplayHeader::session(
            "tealeaf".into(),
            None,
            SessionCfg {
                freqs: crate::sim::freq::FreqDomain::new(vec![1.0]),
                ..SessionCfg::default()
            },
        );
        let text = format!("{}\n{end}\n", TelemetryFrame::Header(header).encode_line());
        std::fs::write(&bad_domain, text).unwrap();
        let path = bad_domain.to_str().unwrap().to_string();
        assert!(dispatch(&["replay", "--in", &path, "--policy", "rrfreq"]).is_err());

        // Out-of-range static arm in the recorded policy config (the
        // config parser can't produce this; a hand-edited wire can).
        let bad_arm = dir.join("bad_arm.jsonl");
        let header = ReplayHeader::session(
            "tealeaf".into(),
            Some(crate::config::PolicyConfig::Static { arm: 12 }),
            SessionCfg::default(),
        );
        let text = format!("{}\n{end}\n", TelemetryFrame::Header(header).encode_line());
        std::fs::write(&bad_arm, text).unwrap();
        let path = bad_arm.to_str().unwrap().to_string();
        assert!(dispatch(&["replay", "--in", &path]).is_err());

        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sweep_round_trip_over_a_session_recording() {
        let dir =
            std::env::temp_dir().join(format!("energyucb_cli_sweep_{}", std::process::id()));
        let log = dir.join("rec.jsonl");
        let log_s = log.to_str().unwrap().to_string();
        assert_eq!(
            dispatch(&[
                "run", "--app", "tealeaf", "--policy", "static", "--reps", "1", "--seed",
                "9", "--record-telemetry", "--record-out", &log_s,
            ])
            .unwrap(),
            0
        );
        // Recording's own policy (no explicit candidates).
        assert_eq!(dispatch(&["sweep", "--replay", &log_s]).unwrap(), 0);
        // Named candidates, parallel.
        assert_eq!(
            dispatch(&[
                "sweep", "--replay", &log_s, "--policies", "static,rrfreq,energyucb",
                "--jobs", "2",
            ])
            .unwrap(),
            0
        );
        // Hyper-parameter grid (2 alphas x 2 lambdas).
        assert_eq!(
            dispatch(&[
                "sweep", "--replay", &log_s, "--alpha", "0.2,0.4", "--lambda", "0.005,0.02",
            ])
            .unwrap(),
            0
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sweep_rejects_bad_invocations() {
        assert!(dispatch(&["sweep"]).is_err());
        assert!(dispatch(&["sweep", "--replay", "/nonexistent/rec.jsonl"]).is_err());
        let dir =
            std::env::temp_dir().join(format!("energyucb_cli_sweepbad_{}", std::process::id()));
        let log = dir.join("rec.jsonl");
        let log_s = log.to_str().unwrap().to_string();
        assert_eq!(
            dispatch(&[
                "run", "--app", "tealeaf", "--policy", "static", "--reps", "1",
                "--record-telemetry", "--record-out", &log_s,
            ])
            .unwrap(),
            0
        );
        assert!(dispatch(&["sweep", "--replay", &log_s, "--jobs", "0"]).is_err());
        assert!(dispatch(&["sweep", "--replay", &log_s, "--policies", "bogus"]).is_err());
        assert!(dispatch(&["sweep", "--replay", &log_s, "--alpha", "fast"]).is_err());
        assert!(dispatch(&["sweep", "--replay", &log_s, "--bogus", "1"]).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fleet_records_and_sweeps_batched_telemetry() {
        let dir =
            std::env::temp_dir().join(format!("energyucb_cli_fleetrec_{}", std::process::id()));
        let log = dir.join("fleet.jsonl");
        let log_s = log.to_str().unwrap().to_string();
        assert_eq!(
            dispatch(&[
                "fleet", "--apps", "tealeaf,clvleaf", "--batch", "3", "--steps", "150",
                "--seed", "12", "--record-telemetry", "--record-out", &log_s,
            ])
            .unwrap(),
            0
        );
        // The batched recording sweeps counterfactually...
        assert_eq!(
            dispatch(&[
                "sweep", "--replay", &log_s, "--policies", "energyucb,ucb1,rrfreq",
                "--jobs", "2",
            ])
            .unwrap(),
            0
        );
        // ...and the recorded default policy replays without --policies.
        assert_eq!(dispatch(&["sweep", "--replay", &log_s]).unwrap(), 0);
        // The scalar replay tier refuses batch recordings (B = 3 rows
        // cannot render as one session) and points at sweep.
        let err = dispatch(&["replay", "--in", &log_s]).unwrap_err().to_string();
        assert!(err.contains("sweep"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fleet_record_flags_validate() {
        assert!(dispatch(&[
            "fleet", "--apps", "tealeaf", "--batch", "2", "--steps", "50", "--record-out",
            "x.jsonl",
        ])
        .is_err());
    }

    #[test]
    fn run_serving_records_replays_and_sweeps_contextual_policies() {
        let dir =
            std::env::temp_dir().join(format!("energyucb_cli_serving_{}", std::process::id()));
        let log = dir.join("serving.jsonl");
        let log_s = log.to_str().unwrap().to_string();
        // Record a contextual session (static keeps the sim short; the
        // trace still carries the context frames and QoS budget).
        assert_eq!(
            dispatch(&[
                "run", "--app", "tealeaf", "--policy", "static", "--serving", "--reps", "1",
                "--seed", "9", "--record-telemetry", "--record-out", &log_s,
            ])
            .unwrap(),
            0
        );
        // Replay reproduces the contextual report (QoS column included).
        assert_eq!(dispatch(&["replay", "--in", &log_s]).unwrap(), 0);
        // Contextual candidates evaluate against the frozen contextual
        // trace alongside a context-free baseline.
        assert_eq!(
            dispatch(&[
                "sweep", "--replay", &log_s, "--policies", "linucb,clinucb,ucb1", "--jobs",
                "2",
            ])
            .unwrap(),
            0
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fleet_serving_runs_contextual_policies() {
        for policy in ["linucb", "clinucb"] {
            let code = dispatch(&[
                "fleet", "--apps", "tealeaf", "--batch", "3", "--steps", "150", "--serving",
                "--policy", policy,
            ])
            .unwrap();
            assert_eq!(code, 0, "{policy}");
        }
        // --serving without --policy runs the default fleet on the
        // generic engine (context flows, EnergyUCB ignores it).
        assert_eq!(
            dispatch(&["fleet", "--apps", "tealeaf", "--batch", "2", "--steps", "100", "--serving"])
                .unwrap(),
            0
        );
    }

    #[test]
    fn cluster_shard_retries_flag_parses_and_rejects_garbage() {
        assert_eq!(
            dispatch(&[
                "cluster", "--nodes", "3", "--jobs", "2", "--scenario", "staggered", "--seed",
                "5", "--shard-retries", "1",
            ])
            .unwrap(),
            0
        );
        assert!(dispatch(&["cluster", "--shard-retries", "x"]).is_err());
        assert!(dispatch(&["cluster", "--shard-retries", "-1"]).is_err());
    }

    #[test]
    fn cluster_small_run() {
        let code = dispatch(&[
            "cluster", "--nodes", "3", "--jobs", "2", "--scenario", "staggered", "--seed", "5",
        ])
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn cluster_rejects_bad_args() {
        assert!(dispatch(&["cluster", "--nodes", "0"]).is_err());
        assert!(dispatch(&["cluster", "--jobs", "0"]).is_err());
        assert!(dispatch(&["cluster", "--shards", "0"]).is_err());
        assert!(dispatch(&["cluster", "--scenario", "bogus"]).is_err());
        assert!(dispatch(&["cluster", "--bogus", "1"]).is_err());
        // A preset replaces the schedule wholesale; combining conflicts.
        assert!(
            dispatch(&["cluster", "--scenario", "mixed", "--config", "configs/x.toml"]).is_err()
        );
        // The wave baseline predates sharding; the combination is refused
        // (both rejections above and here happen before any spawn).
        assert!(dispatch(&["cluster", "--waves", "--shards", "2"]).is_err());
    }

    #[test]
    fn cluster_rejects_inconsistent_transport_flags() {
        // Remote transports shard by definition.
        assert!(dispatch(&["cluster", "--transport", "tcp"]).is_err());
        assert!(dispatch(&["cluster", "--transport", "subprocess"]).is_err());
        assert!(dispatch(&["cluster", "--transport", "carrier-pigeon", "--shards", "2"]).is_err());
        // TCP-only knobs without the TCP transport.
        assert!(dispatch(&["cluster", "--listen", "127.0.0.1:0"]).is_err());
        assert!(dispatch(&["cluster", "--workers", "2"]).is_err());
        assert!(dispatch(&["cluster", "--chaos-kill", "0"]).is_err());
        // Deadlines and worker counts must be positive and well-formed.
        assert!(dispatch(&["cluster", "--shard-timeout", "0", "--shards", "2"]).is_err());
        assert!(dispatch(&["cluster", "--shard-timeout", "-3", "--shards", "2"]).is_err());
        assert!(dispatch(&[
            "cluster", "--transport", "tcp", "--shards", "2", "--workers", "0",
        ])
        .is_err());
        // chaos-kill: bad specs, missing --workers, out-of-range index.
        for spec in ["x", "0:0", "0:x"] {
            assert!(
                dispatch(&[
                    "cluster", "--transport", "tcp", "--shards", "2", "--workers", "2",
                    "--chaos-kill", spec,
                ])
                .is_err(),
                "{spec}"
            );
        }
        assert!(dispatch(&[
            "cluster", "--transport", "tcp", "--shards", "2", "--chaos-kill", "0",
        ])
        .is_err());
        assert!(dispatch(&[
            "cluster", "--transport", "tcp", "--shards", "2", "--workers", "2",
            "--chaos-kill", "2",
        ])
        .is_err());
        // --waves predates transports entirely.
        assert!(dispatch(&["cluster", "--waves", "--transport", "in-process"]).is_err());
    }

    #[test]
    fn cluster_in_process_transport_runs_sharded() {
        // `--transport in-process --shards K` exercises the shard+requeue
        // machinery with no serialization — cheap enough for a unit test.
        let code = dispatch(&[
            "cluster", "--nodes", "3", "--jobs", "2", "--scenario", "staggered", "--seed", "5",
            "--transport", "in-process", "--shards", "2",
        ])
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn cluster_worker_rejects_cli_arguments() {
        // The worker takes frames on stdin/socket, never argv (and erroring
        // here means the test harness never reads from the real stdin).
        assert!(dispatch(&["cluster-worker", "--jobs", "2"]).is_err());
        // Positionals are rejected too, as is dialing a dead leader.
        assert!(dispatch(&["cluster-worker", "frames.jsonl"]).is_err());
        assert!(dispatch(&["cluster-worker", "--die-after-events", "zero"]).is_err());
    }

    #[test]
    fn fleet_native_small() {
        let code = dispatch(&[
            "fleet", "--apps", "tealeaf", "--batch", "4", "--steps", "200", "--native",
        ])
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_runs_batched_non_energyucb_policies() {
        // The acceptance surface: non-EnergyUCB policies batched through
        // `energyucb fleet` (native SoA impls and the scalar bridge).
        for policy in ["ucb1", "swucb", "egreedy", "energyts", "static", "constrained"] {
            let code = dispatch(&[
                "fleet", "--apps", "tealeaf", "--batch", "3", "--steps", "150", "--policy",
                policy,
            ])
            .unwrap();
            assert_eq!(code, 0, "{policy}");
        }
    }

    #[test]
    fn fleet_runs_mixed_policy_fleets() {
        let code = dispatch(&[
            "fleet", "--apps", "tealeaf,clvleaf", "--batch", "6", "--steps", "150", "--policy",
            "energyucb,ucb1,rrfreq",
        ])
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_rejects_unknown_policy() {
        assert!(dispatch(&[
            "fleet", "--apps", "tealeaf", "--batch", "2", "--steps", "50", "--policy", "bogus",
        ])
        .is_err());
    }

    #[test]
    fn fleet_rejects_delta_with_mask_ignoring_policies() {
        // The scalar bridge ignores the QoS mask; silently running an
        // unconstrained fleet when --delta was asked for would lie.
        assert!(dispatch(&[
            "fleet", "--apps", "tealeaf", "--batch", "2", "--steps", "50", "--delta", "0.05",
            "--policy", "energyts",
        ])
        .is_err());
        // Mixed lists always run bridged, even if each entry would honor
        // the mask natively on its own — the combination is refused too.
        assert!(dispatch(&[
            "fleet", "--apps", "tealeaf", "--batch", "2", "--steps", "50", "--delta", "0.05",
            "--policy", "ucb1,swucb",
        ])
        .is_err());
        // Mask-honoring batched policies accept the combination.
        assert_eq!(
            dispatch(&[
                "fleet", "--apps", "tealeaf", "--batch", "2", "--steps", "50", "--delta",
                "0.05", "--policy", "ucb1",
            ])
            .unwrap(),
            0
        );
    }
}
