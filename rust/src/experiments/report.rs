//! Experiment execution context and report plumbing.

use std::path::{Path, PathBuf};

use crate::util::io::{self, Json};

/// Shared knobs for experiment runs.
#[derive(Clone, Debug)]
pub struct ExpContext {
    /// Repetitions for stochastic policies (paper: 10).
    pub reps: usize,
    /// Base seed; run r uses seed + r.
    pub seed: u64,
    /// Output directory for JSON/CSV results.
    pub out_dir: PathBuf,
    /// Quick mode: fewer reps / shorter horizons (CI-friendly).
    pub quick: bool,
    /// Worker threads for the experiment executor (`--jobs`; default: the
    /// machine's available parallelism). Output is byte-identical at any
    /// value — see EXPERIMENTS.md §Executor.
    pub jobs: usize,
    /// Policy selector (`--policy`) for experiments parameterized by one
    /// (the fleet-backed `impact`); `None` = each experiment's default.
    /// Fixed-comparison experiments (tables/figures) ignore it.
    pub policy: Option<crate::config::PolicyConfig>,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            reps: 10,
            seed: 2026,
            out_dir: PathBuf::from("results"),
            quick: false,
            jobs: crate::exec::available_jobs(),
            policy: None,
        }
    }
}

impl ExpContext {
    /// Quick-mode preset (used by tests and `--quick`).
    pub fn quick() -> ExpContext {
        ExpContext { reps: 2, quick: true, ..ExpContext::default() }
    }

    /// Effective repetition count.
    pub fn effective_reps(&self) -> usize {
        if self.quick {
            self.reps.min(2)
        } else {
            self.reps
        }
    }
}

/// The rendered output of one experiment.
#[derive(Clone, Debug)]
pub struct Report {
    pub id: String,
    /// Human-readable text (tables, comparisons) — printed to stdout.
    pub text: String,
    /// Machine-readable results.
    pub json: Json,
}

impl Report {
    pub fn new(id: &str) -> Report {
        Report { id: id.to_string(), text: String::new(), json: Json::obj() }
    }

    pub fn push_text(&mut self, s: impl AsRef<str>) {
        self.text.push_str(s.as_ref());
        if !s.as_ref().ends_with('\n') {
            self.text.push('\n');
        }
    }

    /// Write `results/<id>.json` (and return its path).
    pub fn write(&self, out_dir: &Path) -> std::io::Result<PathBuf> {
        let path = out_dir.join(format!("{}.json", self.id));
        io::write_file(&path, &self.json.render())?;
        let txt = out_dir.join(format!("{}.txt", self.id));
        io::write_file(&txt, &self.text)?;
        Ok(path)
    }
}

/// Relative deviation helper for paper-vs-ours lines.
pub fn rel_dev(ours: f64, paper: f64) -> f64 {
    if paper == 0.0 {
        return 0.0;
    }
    (ours - paper) / paper.abs()
}

/// Format a paper-vs-ours comparison cell: "ours (paper P, Δ+x.x%)".
pub fn vs_paper(ours: f64, paper: f64, digits: usize) -> String {
    format!(
        "{:.d$} (paper {:.d$}, Δ{:+.1}%)",
        ours,
        paper,
        rel_dev(ours, paper) * 100.0,
        d = digits
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_caps_reps() {
        let ctx = ExpContext::quick();
        assert_eq!(ctx.effective_reps(), 2);
        let full = ExpContext::default();
        assert_eq!(full.effective_reps(), 10);
    }

    #[test]
    fn report_write_roundtrip() {
        let dir = std::env::temp_dir().join(format!("energyucb_rep_{}", std::process::id()));
        let mut r = Report::new("test_exp");
        r.push_text("hello");
        r.json.set("x", 1.0);
        let path = r.write(&dir).unwrap();
        assert!(path.exists());
        assert!(dir.join("test_exp.txt").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn vs_paper_formats() {
        let s = vs_paper(99.0, 100.0, 2);
        assert!(s.contains("99.00"), "{s}");
        assert!(s.contains("-1.0%"), "{s}");
    }
}
