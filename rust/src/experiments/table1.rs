//! Table 1: energy consumption (kJ) of every method on every application,
//! plus the Saved Energy and Energy Regret rows.
//!
//! Methods: 9 static frequencies, RRFreq, ε-greedy, EnergyTS, RL-Power,
//! DRLCap (+Online, +Cross), EnergyUCB. DRLCap follows the paper's
//! protocol: the first 20 % of execution trains and is energy-scaled by
//! 1.25× for fairness against fully-online methods (see
//! [`scored_energy_kj`] for why the scaling lands on the 20 %);
//! DRLCap-Cross is pre-trained on the *other* benchmarks.

use anyhow::Result;

use super::fig1::scale_app;
use super::paper;
use super::report::{ExpContext, Report};
use super::Experiment;
use crate::bandit::{
    EnergyTs, EnergyUcb, EnergyUcbConfig, EpsilonGreedy, Policy, RoundRobin, StaticPolicy,
};
use crate::control::{run_session, RunResult, SessionCfg};
use crate::exec::{reduce_reps, run_indexed, CellGrid};
use crate::rl::{DrlCap, DrlCapMode, RlPower};
use crate::sim::freq::FreqDomain;
use crate::util::io::{Csv, Json};
use crate::util::stats::mean;
use crate::util::table::{fnum_sep, Table};
use crate::workload::calibration;
use crate::workload::model::AppModel;

/// A method under evaluation: name + per-seed policy factory. `Send + Sync`
/// so the executor can build fresh per-cell policies on worker threads.
pub struct Method {
    pub name: &'static str,
    factory: Box<dyn Fn(u64) -> Box<dyn Policy> + Send + Sync>,
    /// Apply the paper's 20 %/80 % + 1.25× energy protocol.
    pub pretrain_scaled: bool,
    /// Needs cross-benchmark pretraining (DRLCap-Cross).
    pub cross: bool,
}

impl Method {
    fn new(
        name: &'static str,
        factory: impl Fn(u64) -> Box<dyn Policy> + Send + Sync + 'static,
    ) -> Method {
        Method { name, factory: Box::new(factory), pretrain_scaled: false, cross: false }
    }

    pub fn build(&self, seed: u64) -> Box<dyn Policy> {
        (self.factory)(seed)
    }
}

/// The dynamic method roster in the paper's row order.
pub fn dynamic_methods(k: usize) -> Vec<Method> {
    vec![
        Method::new("RRFreq", move |_s| Box::new(RoundRobin::new(k))),
        Method::new("ε-greedy", move |s| Box::new(EpsilonGreedy::new(k, 0.05, 0.0, s))),
        Method::new("EnergyTS", move |s| Box::new(EnergyTs::default_for(k, s))),
        Method::new("RL-Power", move |s| Box::new(RlPower::new(k, s))),
        Method {
            name: "DRLCap",
            factory: Box::new(move |s| Box::new(DrlCap::new(k, DrlCapMode::PretrainDeploy, s))),
            pretrain_scaled: true,
            cross: false,
        },
        Method::new("DRLCap-Online", move |s| {
            Box::new(DrlCap::new(k, DrlCapMode::Online, s))
        }),
        Method {
            name: "DRLCap-Cross",
            factory: Box::new(move |s| Box::new(DrlCap::new(k, DrlCapMode::Online, s))),
            pretrain_scaled: false,
            cross: true,
        },
        Method::new("EnergyUCB", move |_s| {
            Box::new(EnergyUcb::new(k, EnergyUcbConfig::default()))
        }),
    ]
}

/// One Table-1 cell: a single seeded run of `method` on `app`, applying the
/// DRLCap protocol where flagged. Pure in `(method, app, seed)` — the unit
/// the executor shards across cores.
pub fn method_energy_cell(
    method: &Method,
    app: &AppModel,
    seed: u64,
    cfg: &SessionCfg,
) -> f64 {
    let mut policy = if method.cross {
        build_cross_policy(app, seed)
    } else {
        method.build(seed)
    };
    let cfg = SessionCfg { seed, ..cfg.clone() };
    let res = run_session(app, policy.as_mut(), &cfg);
    scored_energy_kj(method, &res)
}

/// Table-1 energy of a method on an app: mean over `reps` seeded cells,
/// seeds `seed0..seed0+reps`.
pub fn method_energy_kj(
    method: &Method,
    app: &AppModel,
    reps: usize,
    seed0: u64,
    cfg: &SessionCfg,
) -> f64 {
    let energies: Vec<f64> = (0..reps)
        .map(|r| method_energy_cell(method, app, seed0 + r as u64, cfg))
        .collect();
    mean(&energies)
}

/// Apply the paper's DRLCap fairness scaling if flagged.
///
/// The paper's text says the *remaining 80 %* is scaled by 1.25×, but its
/// published rows are only arithmetically consistent with scaling the
/// *training 20 %* (scaling the 80 % would put DRLCap's implied raw energy
/// below the best static frequency — impossible). We implement what the
/// numbers say: scored = 1.25·E(first 20 %) + E(rest). Recorded in
/// EXPERIMENTS.md §Deviations.
pub fn scored_energy_kj(method: &Method, res: &RunResult) -> f64 {
    if method.pretrain_scaled {
        let total = res.metrics.gpu_energy_kj * 1_000.0;
        let e20 = res.energy_at_progress_j(0.2);
        (1.25 * e20 + (total - e20)) / 1_000.0
    } else {
        res.metrics.gpu_energy_kj
    }
}

/// DRLCap-Cross: pre-train on every *other* benchmark, deploy frozen.
fn build_cross_policy(target: &AppModel, seed: u64) -> Box<dyn Policy> {
    let k = FreqDomain::aurora().k();
    let mut transitions = Vec::new();
    for other in calibration::all_apps() {
        if other.name == target.name {
            continue;
        }
        // Short online episodes on a shrunk copy of the donor benchmark.
        let donor_app = scale_app(&other, 16.0);
        let mut donor = DrlCap::new(k, DrlCapMode::Online, seed ^ 0xCAFE);
        let cfg = SessionCfg { seed, max_steps: 1500, ..SessionCfg::default() };
        let _ = run_session(&donor_app, &mut donor, &cfg);
        transitions.extend(donor.replay_snapshot());
    }
    let mut cross = DrlCap::new(k, DrlCapMode::CrossDeploy, seed);
    cross.pretrain_on(&transitions, 1);
    Box::new(cross)
}

pub struct Table1;

impl Experiment for Table1 {
    fn id(&self) -> &'static str {
        "table1"
    }

    fn title(&self) -> &'static str {
        "Table 1: energy consumption (kJ) across methods and applications"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let freqs = FreqDomain::aurora();
        let apps: Vec<AppModel> = calibration::all_apps()
            .iter()
            .map(|a| if ctx.quick { scale_app(a, 16.0) } else { a.clone() })
            .collect();
        let reps = ctx.effective_reps();
        let cfg = SessionCfg::default();

        let mut header: Vec<String> = vec!["Methods".into()];
        header.extend(apps.iter().map(|a| a.name.to_string()));
        let mut table = Table::new(header);
        let mut csv = Csv::new();
        csv.row(&{
            let mut h = vec!["method".to_string()];
            h.extend(apps.iter().map(|a| a.name.to_string()));
            h
        });
        let mut json_rows = Vec::new();

        let push_row = |label: &str, values: &[f64], table: &mut Table, csv: &mut Csv,
                            json_rows: &mut Vec<Json>| {
            let mut cells = vec![label.to_string()];
            cells.extend(values.iter().map(|v| fnum_sep(*v, 2)));
            table.row(cells);
            csv.row_mixed(label, values, 3);
            let mut j = Json::obj();
            j.set("method", label);
            j.set("kj", values.to_vec());
            json_rows.push(j);
        };

        // Static rows: one cell per (arm, app), sharded across the pool and
        // reduced in stable order before rendering (descending frequency,
        // like the paper).
        let methods = dynamic_methods(freqs.k());
        let static_grid = CellGrid::new(freqs.k(), apps.len(), 1);
        eprintln!(
            "table1: {} static cells + {} dynamic cells across {} jobs",
            static_grid.len(),
            methods.len() * apps.len() * reps,
            ctx.jobs
        );
        let static_cells = run_indexed(ctx.jobs, static_grid.len(), |cell| {
            let (arm, a, _) = static_grid.unpack(cell);
            let mut policy = StaticPolicy::new(freqs.k(), arm);
            let res = run_session(
                &apps[a],
                &mut policy,
                &SessionCfg { seed: ctx.seed, ..cfg.clone() },
            );
            res.metrics.gpu_energy_kj
        });
        let mut static_energy = vec![vec![0.0; apps.len()]; freqs.k()];
        for arm in 0..freqs.k() {
            for a in 0..apps.len() {
                static_energy[arm][a] = static_cells[static_grid.pack(arm, a, 0)];
            }
        }
        for arm in (0..freqs.k()).rev() {
            push_row(
                &freqs.label(arm),
                &static_energy[arm],
                &mut table,
                &mut csv,
                &mut json_rows,
            );
        }
        table.rule();

        // Dynamic + RL methods: (method × app × rep) cells, seed = base + rep
        // (the mapping the sequential harness used), mean over the rep axis
        // via the stable Welford reduce.
        let dyn_grid = CellGrid::new(methods.len(), apps.len(), reps);
        let dyn_cells = run_indexed(ctx.jobs, dyn_grid.len(), |cell| {
            let (m, a, r) = dyn_grid.unpack(cell);
            method_energy_cell(&methods[m], &apps[a], ctx.seed + r as u64, &cfg)
        });
        let dyn_means = reduce_reps(&dyn_cells, reps);
        let mut ucb_row = vec![0.0; apps.len()];
        for (m, method) in methods.iter().enumerate() {
            let row: Vec<f64> = (0..apps.len())
                .map(|a| dyn_means[dyn_grid.group(m, a)].mean())
                .collect();
            if method.name == "EnergyUCB" {
                ucb_row = row.clone();
            }
            push_row(method.name, &row, &mut table, &mut csv, &mut json_rows);
        }
        table.rule();

        // Saved Energy and Energy Regret rows (vs our measured statics).
        let saved: Vec<f64> = (0..apps.len())
            .map(|a| static_energy[freqs.k() - 1][a] - ucb_row[a])
            .collect();
        let best_static: Vec<f64> = (0..apps.len())
            .map(|a| {
                (0..freqs.k())
                    .map(|arm| static_energy[arm][a])
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let regret: Vec<f64> =
            (0..apps.len()).map(|a| ucb_row[a] - best_static[a]).collect();
        push_row("Saved Energy", &saved, &mut table, &mut csv, &mut json_rows);
        push_row("Energy Regret", &regret, &mut table, &mut csv, &mut json_rows);

        report.push_text(table.render());

        // Paper-vs-ours for the EnergyUCB row (full mode only; quick mode
        // rescales the workload so absolute kJ differ by design).
        if !ctx.quick {
            let mut cmp = Table::new(vec!["app", "EnergyUCB kJ (ours)", "paper", "Δ%"]);
            let paper_row = &paper::TABLE1_DYNAMIC[7];
            for (a, app) in apps.iter().enumerate() {
                let dev = super::report::rel_dev(ucb_row[a], paper_row.kj[a]);
                cmp.row(vec![
                    app.name.to_string(),
                    fnum_sep(ucb_row[a], 2),
                    fnum_sep(paper_row.kj[a], 2),
                    format!("{:+.2}", dev * 100.0),
                ]);
            }
            report.push_text("\nEnergyUCB vs paper:\n");
            report.push_text(cmp.render());
        }

        // Shape assertions recorded in the report.
        let wins = (0..apps.len())
            .filter(|&a| saved[a] > 0.0)
            .count();
        report.push_text(format!(
            "EnergyUCB saves energy vs the 1.6 GHz default on {wins}/{} apps; \
             mean energy regret {:.2} kJ.",
            apps.len(),
            mean(&regret)
        ));
        report.json.set("rows", Json::Arr(json_rows));
        report.json.set("saved_energy", saved);
        report.json.set("energy_regret", regret);
        let _ = csv.write_to(&ctx.out_dir.join("table1.csv"));
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_matches_paper_rows() {
        let methods = dynamic_methods(9);
        let names: Vec<&str> = methods.iter().map(|m| m.name).collect();
        let paper_names: Vec<&str> =
            paper::TABLE1_DYNAMIC.iter().map(|r| r.method).collect();
        assert_eq!(names, paper_names);
    }

    #[test]
    fn sequential_mean_matches_cell_decomposition() {
        // method_energy_kj (the sequential seed-mapping reference) must
        // agree with mean-of-cells — the equivalence the executor's grid
        // path relies on.
        let app = scale_app(&calibration::app("tealeaf").unwrap(), 32.0);
        let method = &dynamic_methods(9)[0]; // RRFreq: deterministic policy
        let cfg = SessionCfg::default();
        let reps = 2;
        let seq = method_energy_kj(method, &app, reps, 5, &cfg);
        let cells: Vec<f64> =
            (0..reps).map(|r| method_energy_cell(method, &app, 5 + r as u64, &cfg)).collect();
        assert_eq!(seq, mean(&cells));
    }

    #[test]
    fn drlcap_scaling_applies() {
        let m = &dynamic_methods(9)[4];
        assert_eq!(m.name, "DRLCap");
        assert!(m.pretrain_scaled);
        // Synthetic result: 1000 J total, uniform accumulation.
        let res = RunResult {
            metrics: crate::control::RunMetrics {
                app: "x".into(),
                policy: "DRLCap".into(),
                gpu_energy_kj: 1.0,
                exec_time_s: 1.0,
                switches: 0,
                switch_energy_j: 0.0,
                switch_time_s: 0.0,
                cumulative_regret: 0.0,
                steps: 100,
                completed: 1.0,
                qos_violation_frac: None,
            },
            trace: None,
            energy_checkpoints_j: (1..=100).map(|i| i as f64 * 10.0).collect(),
            telemetry: crate::telemetry::Recorder::new(),
        };
        let scored = scored_energy_kj(m, &res);
        // E20 = 200 J, scaled = 1.25*200 + 800 = 1050 J.
        assert!((scored - 1.05).abs() < 1e-9, "{scored}");
    }

    #[test]
    fn quick_table1_shape() {
        // Quick mode: shrunk workloads, 2 reps — verifies the full table
        // machinery end-to-end.
        let ctx = ExpContext {
            quick: true,
            reps: 1,
            out_dir: std::env::temp_dir().join("energyucb_t1_test"),
            ..ExpContext::default()
        };
        let report = Table1.run(&ctx).unwrap();
        assert!(report.text.contains("EnergyUCB"));
        assert!(report.text.contains("Saved Energy"));
        // EnergyUCB should beat RRFreq on most apps.
        let rows = match report.json.get("rows") {
            Some(Json::Arr(rows)) => rows.clone(),
            _ => panic!(),
        };
        let find = |name: &str| -> Vec<f64> {
            rows.iter()
                .find(|r| matches!(r.get("method"), Some(Json::Str(s)) if s == name))
                .map(|r| match r.get("kj") {
                    Some(Json::Arr(xs)) => xs
                        .iter()
                        .map(|x| match x {
                            Json::Num(v) => *v,
                            _ => 0.0,
                        })
                        .collect(),
                    _ => vec![],
                })
                .unwrap()
        };
        let ucb = find("EnergyUCB");
        let rr = find("RRFreq");
        let wins = ucb.iter().zip(&rr).filter(|(u, r)| u < r).count();
        assert!(wins >= 6, "EnergyUCB beats RRFreq on {wins}/9");
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_t1_test"));
    }
}
