//! Fig. 3: cumulative-regret curves — EnergyUCB flattens, RRFreq grows
//! linearly, the other dynamic/RL methods sit in between.
//!
//! Regret is accounted in raw reward units (−E·R per interval), matching
//! the paper's magnitudes (tealeaf @ t=4000: EnergyUCB ≈ 1.99 k vs RRFreq
//! ≈ 25.51 k).

use anyhow::Result;

use super::fig1::scale_app;
use super::paper;
use super::report::{ExpContext, Report};
use super::Experiment;
use crate::bandit::{EnergyTs, EnergyUcb, EnergyUcbConfig, EpsilonGreedy, Policy, RoundRobin};
use crate::control::{run_session, SessionCfg};
use crate::exec::{run_indexed, CellGrid};
use crate::rl::RlPower;
use crate::util::io::{Csv, Json};
use crate::util::table::{fnum, Table};
use crate::workload::calibration;

/// Apps plotted (the paper shows a grid; tealeaf carries the anchor).
const APPS: [&str; 4] = ["tealeaf", "clvleaf", "miniswp", "pot3d"];

/// Downsample a cumulative series to at most `n` evenly-spaced (t, value)
/// points, always keeping the endpoint.
fn downsample(cum: &[f64], n: usize) -> Vec<(u64, f64)> {
    if cum.is_empty() {
        return Vec::new();
    }
    let stride = (cum.len() / n.max(1)).max(1);
    let mut out: Vec<(u64, f64)> = cum
        .iter()
        .enumerate()
        .filter(|(i, _)| i % stride == 0)
        .map(|(i, r)| ((i + 1) as u64, *r))
        .collect();
    let last = (cum.len() as u64, *cum.last().unwrap());
    if out.last() != Some(&last) {
        out.push(last);
    }
    out
}

pub struct Fig3;

impl Experiment for Fig3 {
    fn id(&self) -> &'static str {
        "fig3"
    }

    fn title(&self) -> &'static str {
        "Fig. 3: cumulative regret of dynamic methods over time"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let mut json_apps = Vec::new();
        let reps = ctx.effective_reps();

        // Quick mode shrinks the horizon moderately (4x): regret-curve
        // separation needs a few thousand steps to show.
        let apps: Vec<_> = APPS
            .iter()
            .map(|name| {
                let app0 = calibration::app(name).unwrap();
                if ctx.quick {
                    scale_app(&app0, 4.0)
                } else {
                    app0
                }
            })
            .collect();
        type Factory = Box<dyn Fn(u64) -> Box<dyn Policy> + Send + Sync>;
        let factories: Vec<Factory> = vec![
            Box::new(|_s| Box::new(EnergyUcb::new(9, EnergyUcbConfig::default()))),
            Box::new(|s| Box::new(EpsilonGreedy::new(9, 0.05, 0.0, s))),
            Box::new(|s| Box::new(EnergyTs::default_for(9, s))),
            Box::new(|s| Box::new(RlPower::new(9, s))),
            Box::new(|_s| Box::new(RoundRobin::new(9))),
        ];

        // One cell per (app × method × rep) traced session; curves are
        // averaged over the rep axis afterwards, in rep order.
        let grid = CellGrid::new(apps.len(), factories.len(), reps);
        eprintln!("fig3: {} traced cells across {} jobs", grid.len(), ctx.jobs);
        let cells = run_indexed(ctx.jobs, grid.len(), |cell| {
            let (a, m, r) = grid.unpack(cell);
            let mut policy = factories[m](ctx.seed + r as u64);
            let cfg = SessionCfg {
                seed: ctx.seed + r as u64,
                record_trace: true,
                ..SessionCfg::default()
            };
            let res = run_session(&apps[a], policy.as_mut(), &cfg);
            let trace = res.trace.expect("trace recorded");
            (policy.name(), trace.cumulative_regret())
        });

        for (a, name) in APPS.iter().enumerate() {
            let mut table = Table::new(vec![
                "method", "t=1000", "t=2000", "t=4000", "final", "final/steps",
            ]);
            let mut csv = Csv::new();
            csv.row(&["method", "t", "cumulative_regret"]);
            let mut json_methods = Vec::new();
            let mut anchor: Vec<(String, f64)> = Vec::new();
            for m in 0..factories.len() {
                // Average the cumulative-regret curve over repetitions
                // (the paper averages 10 runs).
                let mut cum_avg: Vec<f64> = Vec::new();
                let mut min_len = usize::MAX;
                let mut name_p = String::new();
                for r in 0..reps {
                    let (cell_name, cum) = &cells[grid.pack(a, m, r)];
                    name_p = cell_name.clone();
                    min_len = min_len.min(cum.len());
                    if cum_avg.len() < cum.len() {
                        cum_avg.resize(cum.len(), 0.0);
                    }
                    for (i, v) in cum.iter().enumerate() {
                        cum_avg[i] += v / reps as f64;
                    }
                }
                cum_avg.truncate(min_len.max(1));
                let cum = cum_avg;
                let at = |t: usize| cum.get(t.min(cum.len()) - 1).copied().unwrap_or(0.0);
                table.row(vec![
                    name_p.clone(),
                    fnum(at(1000), 1),
                    fnum(at(2000), 1),
                    fnum(at(4000), 1),
                    fnum(*cum.last().unwrap(), 1),
                    fnum(cum.last().unwrap() / cum.len() as f64, 3),
                ]);
                for (t, r) in downsample(&cum, 100) {
                    csv.row(&[name_p.clone(), t.to_string(), format!("{r:.3}")]);
                }
                anchor.push((name_p.clone(), at(4000)));
                let mut j = Json::obj();
                j.set("method", name_p);
                j.set("final_regret", *cum.last().unwrap());
                j.set(
                    "series",
                    Json::Arr(
                        downsample(&cum, 50)
                            .into_iter()
                            .map(|(t, r)| {
                                let mut o = Json::obj();
                                o.set("t", t as i64);
                                o.set("regret", r);
                                o
                            })
                            .collect(),
                    ),
                );
                json_methods.push(j);
            }
            let name = *name;
            report.push_text(format!("--- {name} ---"));
            report.push_text(table.render());
            if name == "tealeaf" && !ctx.quick {
                let ucb = anchor.iter().find(|(n, _)| n == "EnergyUCB").unwrap().1;
                let rr = anchor.iter().find(|(n, _)| n == "RRFreq").unwrap().1;
                let (p_ucb, p_rr) = paper::FIG3_TEALEAF_T4000;
                report.push_text(format!(
                    "tealeaf @ t=4000: EnergyUCB {ucb:.0} (paper {p_ucb:.0}), RRFreq {rr:.0} \
                     (paper {p_rr:.0}); ratio ours {:.1}x vs paper {:.1}x",
                    rr / ucb.max(1.0),
                    p_rr / p_ucb
                ));
            }
            let _ = csv.write_to(&ctx.out_dir.join(format!("fig3_{name}.csv")));
            let mut j = Json::obj();
            j.set("app", name);
            j.set("methods", Json::Arr(json_methods));
            json_apps.push(j);
        }
        report.json.set("apps", Json::Arr(json_apps));
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig3_orders_methods() {
        let ctx = ExpContext {
            quick: true,
            out_dir: std::env::temp_dir().join("energyucb_f3_test"),
            ..ExpContext::quick()
        };
        let report = Fig3.run(&ctx).unwrap();
        // RRFreq's regret must dominate EnergyUCB's in aggregate. (Per-app
        // separation needs the full horizon — pot3d's arm gaps are ~1 % —
        // and is recorded from the full run in EXPERIMENTS.md.)
        let apps = match report.json.get("apps") {
            Some(Json::Arr(a)) => a.clone(),
            _ => panic!(),
        };
        let mut rr_total = 0.0;
        let mut ucb_total = 0.0;
        for app in &apps {
            let methods = match app.get("methods") {
                Some(Json::Arr(m)) => m,
                _ => panic!(),
            };
            let get = |name: &str| {
                methods
                    .iter()
                    .find(
                        |m| matches!(m.get("method"), Some(Json::Str(s)) if s == name),
                    )
                    .and_then(|m| m.get_num("final_regret"))
                    .unwrap()
            };
            ucb_total += get("EnergyUCB");
            rr_total += get("RRFreq");
        }
        assert!(rr_total > 1.6 * ucb_total, "rr={rr_total} ucb={ucb_total}");
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_f3_test"));
    }
}
