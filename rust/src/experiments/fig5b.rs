//! Fig. 5(b): QoS analysis on clvleaf and miniswp — execution time across
//! static frequencies, overlaid with unconstrained EnergyUCB and the
//! constrained variant under a δ = 0.05 slowdown budget.

use anyhow::Result;

use super::fig1::scale_app;
use super::paper;
use super::report::{ExpContext, Report};
use super::Experiment;
use crate::bandit::{ConstrainedEnergyUcb, EnergyUcb, EnergyUcbConfig, Policy, StaticPolicy};
use crate::control::{run_repeated, SessionCfg};
use crate::sim::freq::FreqDomain;
use crate::util::io::Json;
use crate::util::stats::mean;
use crate::util::table::{fnum, Table};
use crate::workload::calibration;

const APPS: [&str; 2] = ["clvleaf", "miniswp"];
const DELTA: f64 = 0.05;

pub struct Fig5b;

impl Experiment for Fig5b {
    fn id(&self) -> &'static str {
        "fig5b"
    }

    fn title(&self) -> &'static str {
        "Fig. 5(b): QoS — execution time, unconstrained vs δ=0.05-constrained"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let freqs = FreqDomain::aurora();
        let reps = ctx.effective_reps();
        let mut json_apps = Vec::new();
        for name in APPS {
            let app0 = calibration::app(name).unwrap();
            let app = if ctx.quick { scale_app(&app0, 8.0) } else { app0.clone() };
            let scale = if ctx.quick { 8.0 } else { 1.0 };
            let mut table = Table::new(vec!["config", "exec time (s)", "slowdown %", "energy (kJ)"]);

            // Static curve.
            let mut t_max = 0.0;
            for arm in (0..freqs.k()).rev() {
                let mut policy = StaticPolicy::new(freqs.k(), arm);
                let res = &run_repeated(&app, &mut policy, &SessionCfg::default(), 1, ctx.seed)[0];
                let t = res.metrics.exec_time_s * scale;
                if arm == freqs.max_arm() {
                    t_max = t;
                }
                table.row(vec![
                    freqs.label(arm),
                    fnum(t, 2),
                    fnum((t / t_max - 1.0) * 100.0, 2),
                    fnum(res.metrics.gpu_energy_kj * scale, 2),
                ]);
            }
            table.rule();

            // Unconstrained and constrained EnergyUCB.
            let mut json_app = Json::obj();
            json_app.set("app", name);
            let variants: Vec<(&str, Box<dyn Policy>)> = vec![
                (
                    "EnergyUCB (unconstrained)",
                    Box::new(EnergyUcb::new(9, EnergyUcbConfig::default())),
                ),
                (
                    "Constrained (δ=0.05)",
                    Box::new(ConstrainedEnergyUcb::new(9, EnergyUcbConfig::default(), DELTA)),
                ),
            ];
            for (label, mut policy) in variants {
                let results =
                    run_repeated(&app, policy.as_mut(), &SessionCfg::default(), reps, ctx.seed);
                let t =
                    mean(&results.iter().map(|r| r.metrics.exec_time_s * scale).collect::<Vec<_>>());
                let kj = mean(
                    &results
                        .iter()
                        .map(|r| r.metrics.gpu_energy_kj * scale)
                        .collect::<Vec<_>>(),
                );
                let slowdown = t / t_max - 1.0;
                table.row(vec![
                    label.to_string(),
                    fnum(t, 2),
                    fnum(slowdown * 100.0, 2),
                    fnum(kj, 2),
                ]);
                let key = if label.starts_with("Constrained") {
                    "constrained_slowdown"
                } else {
                    "unconstrained_slowdown"
                };
                json_app.set(key, slowdown);
                json_app.set(format!("{key}_energy_kj"), kj);
            }
            report.push_text(format!("--- {name} ---"));
            report.push_text(table.render());
            json_apps.push(json_app);
        }

        if !ctx.quick {
            for ((name, p_unc), (_, p_con)) in
                paper::FIG5B_UNCONSTRAINED.iter().zip(paper::FIG5B_CONSTRAINED.iter())
            {
                report.push_text(format!(
                    "paper {name}: unconstrained slowdown {:.2}%, constrained {:.2}% (δ=5%)",
                    p_unc * 100.0,
                    p_con * 100.0
                ));
            }
        }
        report.push_text(
            "Shape: the constrained variant keeps slowdown within the 5% budget \
             without reverting to 1.6 GHz, still saving energy vs the default.",
        );
        report.json.set("apps", Json::Arr(json_apps));
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_constrained_respects_budget() {
        let ctx = ExpContext {
            quick: true,
            reps: 2,
            out_dir: std::env::temp_dir().join("energyucb_f5b_test"),
            ..ExpContext::default()
        };
        let report = Fig5b.run(&ctx).unwrap();
        let apps = match report.json.get("apps") {
            Some(Json::Arr(a)) => a.clone(),
            _ => panic!(),
        };
        for app in &apps {
            let con = app.get_num("constrained_slowdown").unwrap();
            let unc = app.get_num("unconstrained_slowdown").unwrap();
            // Budget respected with a small estimation margin.
            assert!(con <= 0.07, "constrained slowdown {con}");
            // Constrained never slower than unconstrained (clvleaf's
            // unconstrained optimum is ~14% slow).
            assert!(con <= unc + 0.02, "con {con} unc {unc}");
        }
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_f5b_test"));
    }
}
