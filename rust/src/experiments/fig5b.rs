//! Fig. 5(b): QoS analysis on clvleaf and miniswp — execution time across
//! static frequencies, overlaid with unconstrained EnergyUCB and the
//! constrained variant under a δ = 0.05 slowdown budget.

use anyhow::Result;

use super::fig1::scale_app;
use super::paper;
use super::report::{ExpContext, Report};
use super::Experiment;
use crate::bandit::{ConstrainedEnergyUcb, EnergyUcb, EnergyUcbConfig, Policy, StaticPolicy};
use crate::control::{run_session, SessionCfg};
use crate::exec::{run_indexed, CellGrid};
use crate::sim::freq::FreqDomain;
use crate::util::io::Json;
use crate::util::stats::mean;
use crate::util::table::{fnum, Table};
use crate::workload::calibration;

const APPS: [&str; 2] = ["clvleaf", "miniswp"];
const DELTA: f64 = 0.05;

pub struct Fig5b;

impl Experiment for Fig5b {
    fn id(&self) -> &'static str {
        "fig5b"
    }

    fn title(&self) -> &'static str {
        "Fig. 5(b): QoS — execution time, unconstrained vs δ=0.05-constrained"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let freqs = FreqDomain::aurora();
        let reps = ctx.effective_reps();
        let mut json_apps = Vec::new();
        let scale = if ctx.quick { 8.0 } else { 1.0 };
        let apps: Vec<_> = APPS
            .iter()
            .map(|name| {
                let app0 = calibration::app(name).unwrap();
                if ctx.quick {
                    scale_app(&app0, 8.0)
                } else {
                    app0
                }
            })
            .collect();

        // Static curve: one cell per (app × arm).
        let static_grid = CellGrid::new(apps.len(), freqs.k(), 1);
        // Controller runs: (app × {unconstrained, constrained} × rep) cells.
        let var_grid = CellGrid::new(apps.len(), 2, reps);
        eprintln!(
            "fig5b: {} static + {} controller cells across {} jobs",
            static_grid.len(),
            var_grid.len(),
            ctx.jobs
        );
        let statics = run_indexed(ctx.jobs, static_grid.len(), |cell| {
            let (a, arm, _) = static_grid.unpack(cell);
            let mut policy = StaticPolicy::new(freqs.k(), arm);
            let cfg = SessionCfg { seed: ctx.seed, ..SessionCfg::default() };
            let m = run_session(&apps[a], &mut policy, &cfg).metrics;
            (m.exec_time_s, m.gpu_energy_kj)
        });
        let labels = ["EnergyUCB (unconstrained)", "Constrained (δ=0.05)"];
        let controller = run_indexed(ctx.jobs, var_grid.len(), |cell| {
            let (a, v, r) = var_grid.unpack(cell);
            let mut policy: Box<dyn Policy> = if v == 0 {
                Box::new(EnergyUcb::new(9, EnergyUcbConfig::default()))
            } else {
                Box::new(ConstrainedEnergyUcb::new(9, EnergyUcbConfig::default(), DELTA))
            };
            let cfg = SessionCfg { seed: ctx.seed + r as u64, ..SessionCfg::default() };
            let m = run_session(&apps[a], policy.as_mut(), &cfg).metrics;
            (m.exec_time_s, m.gpu_energy_kj)
        });

        for (a, name) in APPS.iter().enumerate() {
            let mut table =
                Table::new(vec!["config", "exec time (s)", "slowdown %", "energy (kJ)"]);
            let t_max = statics[static_grid.pack(a, freqs.max_arm(), 0)].0 * scale;
            for arm in (0..freqs.k()).rev() {
                let (exec_s, kj) = statics[static_grid.pack(a, arm, 0)];
                let t = exec_s * scale;
                table.row(vec![
                    freqs.label(arm),
                    fnum(t, 2),
                    fnum((t / t_max - 1.0) * 100.0, 2),
                    fnum(kj * scale, 2),
                ]);
            }
            table.rule();

            let mut json_app = Json::obj();
            json_app.set("app", *name);
            for (v, label) in labels.iter().enumerate() {
                let t = mean(
                    &(0..reps)
                        .map(|r| controller[var_grid.pack(a, v, r)].0 * scale)
                        .collect::<Vec<_>>(),
                );
                let kj = mean(
                    &(0..reps)
                        .map(|r| controller[var_grid.pack(a, v, r)].1 * scale)
                        .collect::<Vec<_>>(),
                );
                let slowdown = t / t_max - 1.0;
                table.row(vec![
                    label.to_string(),
                    fnum(t, 2),
                    fnum(slowdown * 100.0, 2),
                    fnum(kj, 2),
                ]);
                let key = if label.starts_with("Constrained") {
                    "constrained_slowdown"
                } else {
                    "unconstrained_slowdown"
                };
                json_app.set(key, slowdown);
                json_app.set(format!("{key}_energy_kj"), kj);
            }
            report.push_text(format!("--- {name} ---"));
            report.push_text(table.render());
            json_apps.push(json_app);
        }

        if !ctx.quick {
            for ((name, p_unc), (_, p_con)) in
                paper::FIG5B_UNCONSTRAINED.iter().zip(paper::FIG5B_CONSTRAINED.iter())
            {
                report.push_text(format!(
                    "paper {name}: unconstrained slowdown {:.2}%, constrained {:.2}% (δ=5%)",
                    p_unc * 100.0,
                    p_con * 100.0
                ));
            }
        }
        report.push_text(
            "Shape: the constrained variant keeps slowdown within the 5% budget \
             without reverting to 1.6 GHz, still saving energy vs the default.",
        );
        report.json.set("apps", Json::Arr(json_apps));
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_constrained_respects_budget() {
        let ctx = ExpContext {
            quick: true,
            reps: 2,
            out_dir: std::env::temp_dir().join("energyucb_f5b_test"),
            ..ExpContext::default()
        };
        let report = Fig5b.run(&ctx).unwrap();
        let apps = match report.json.get("apps") {
            Some(Json::Arr(a)) => a.clone(),
            _ => panic!(),
        };
        for app in &apps {
            let con = app.get_num("constrained_slowdown").unwrap();
            let unc = app.get_num("unconstrained_slowdown").unwrap();
            // Budget respected with a small estimation margin.
            assert!(con <= 0.07, "constrained slowdown {con}");
            // Constrained never slower than unconstrained (clvleaf's
            // unconstrained optimum is ~14% slow).
            assert!(con <= unc + 0.02, "con {con} unc {unc}");
        }
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_f5b_test"));
    }
}
