//! The paper's published numbers, embedded for paper-vs-measured reporting.
//!
//! Everything here is *output-side only*: experiment code never feeds these
//! into the simulation (the calibration tables in
//! `workload::calibration` hold the static rows because those define the
//! substituted hardware), they are printed next to our measurements so
//! EXPERIMENTS.md can record the comparison.

use crate::workload::calibration::APP_NAMES;

/// Table 1 dynamic/RL rows (kJ), app order = [`APP_NAMES`].
pub struct PaperRow {
    pub method: &'static str,
    pub kj: [f64; 9],
}

pub const TABLE1_DYNAMIC: [PaperRow; 8] = [
    PaperRow {
        method: "RRFreq",
        kj: [105.76, 103.24, 93.24, 168.22, 129.12, 1187.86, 125.07, 1282.21, 781.75],
    },
    PaperRow {
        method: "ε-greedy",
        kj: [100.86, 100.88, 91.32, 168.28, 130.08, 1106.65, 123.24, 1273.75, 785.02],
    },
    PaperRow {
        method: "EnergyTS",
        kj: [99.17, 100.79, 91.76, 168.02, 129.50, 1104.55, 123.95, 1268.31, 784.18],
    },
    PaperRow {
        method: "RL-Power",
        kj: [99.42, 102.11, 92.85, 170.08, 130.94, 1132.27, 124.92, 1248.66, 778.94],
    },
    PaperRow {
        method: "DRLCap",
        kj: [101.88, 103.97, 93.77, 175.92, 131.86, 1168.33, 125.41, 1231.56, 785.53],
    },
    PaperRow {
        method: "DRLCap-Online",
        kj: [108.95, 108.04, 96.23, 181.27, 135.62, 1243.73, 128.89, 1261.81, 796.15],
    },
    PaperRow {
        method: "DRLCap-Cross",
        kj: [98.85, 102.84, 92.02, 169.80, 134.94, 1183.86, 126.35, 1291.55, 789.25],
    },
    PaperRow {
        method: "EnergyUCB",
        kj: [94.25, 99.06, 90.08, 162.72, 124.93, 1095.89, 122.73, 1127.17, 750.90],
    },
];

/// Table 1 bottom rows.
pub const SAVED_ENERGY: [f64; 9] = [-0.31, 10.73, 10.57, 24.41, 6.2, 257.52, 11.88, 150.54, 21.31];
pub const ENERGY_REGRET: [f64; 9] = [0.54, 0.45, 1.67, 3.98, 1.55, 5.65, 2.26, 12.88, 3.7];

/// Table 2 ablation (kJ, mean): [EnergyUCB, w/o Opt.Ini., w/o Penalty].
pub const TABLE2: [(&str, [f64; 3]); 3] = [
    ("sph_exa", [1095.89, 1116.71, 1102.70]),
    ("llama", [1127.17, 1199.18, 1133.42]),
    ("diffusion", [750.90, 788.33, 753.66]),
];

/// Fig. 4 switching analysis on llama: (switches, energy kJ, time s).
pub const FIG4_WO_PENALTY: (f64, f64, f64) = (20_850.0, 6.25, 3.12);
pub const FIG4_WITH_PENALTY: (f64, f64, f64) = (3_120.0, 0.93, 0.46);

/// Fig. 1(b) pot3d measurements: (GHz, kW, s, kJ).
pub const FIG1B: [(f64, f64, f64, f64); 3] = [
    (1.6, 2.277, 56.42, 128.46),
    (1.1, 2.011, 59.78, 120.21),
    (0.8, 1.690, 75.02, 126.78),
];

/// Fig. 1(a) pot3d node energy shares (GPU, CPU, other).
pub const FIG1A_POT3D: (f64, f64, f64) = (0.7510, 0.1655, 0.0835);

/// Fig. 5(b) QoS: unconstrained slowdowns and constrained (δ=0.05) ones.
pub const FIG5B_UNCONSTRAINED: [(&str, f64); 2] = [("clvleaf", 0.1446), ("miniswp", 0.0626)];
pub const FIG5B_CONSTRAINED: [(&str, f64); 2] = [("clvleaf", 0.0405), ("miniswp", 0.0482)];

/// Fig. 3 anchor: tealeaf cumulative regret at t = 4000.
pub const FIG3_TEALEAF_T4000: (f64, f64) = (1_990.0, 25_510.0); // (EnergyUCB, RRFreq)

/// Look up an app's column index in the paper's ordering.
pub fn app_col(name: &str) -> Option<usize> {
    APP_NAMES.iter().position(|n| *n == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    #[test]
    fn saved_energy_consistent_with_table1() {
        // Paper's own arithmetic: saved = default(1.6) - EnergyUCB row.
        let ucb = &TABLE1_DYNAMIC[7];
        assert_eq!(ucb.method, "EnergyUCB");
        for (col, app) in calibration::all_apps().iter().enumerate() {
            let default = app.energy_kj[8];
            let saved = default - ucb.kj[col];
            assert!(
                (saved - SAVED_ENERGY[col]).abs() < 0.02,
                "{}: {saved} vs {}",
                app.name,
                SAVED_ENERGY[col]
            );
        }
    }

    #[test]
    fn energy_regret_consistent_with_table1() {
        let ucb = &TABLE1_DYNAMIC[7];
        for (col, app) in calibration::all_apps().iter().enumerate() {
            let regret = ucb.kj[col] - app.optimal_energy_kj();
            assert!(
                (regret - ENERGY_REGRET[col]).abs() < 0.02,
                "{}: {regret} vs {}",
                app.name,
                ENERGY_REGRET[col]
            );
        }
    }

    #[test]
    fn fig4_switch_cost_arithmetic() {
        // 0.3 J and 150 us per switch reproduce the paper's overhead rows.
        let (n, kj, s) = FIG4_WO_PENALTY;
        assert!((n * 0.3 / 1000.0 - kj).abs() < 0.01);
        assert!((n * 150e-6 - s).abs() < 0.01);
    }

    #[test]
    fn app_col_lookup() {
        assert_eq!(app_col("lbm"), Some(0));
        assert_eq!(app_col("diffusion"), Some(8));
        assert_eq!(app_col("nope"), None);
    }
}
