//! Social-impact extrapolation (paper §1, Contributions): scale the
//! per-node savings to the full Aurora system (10,620 nodes) and translate
//! to household-equivalents — the paper's "9,000 U.S. residents / 69,000
//! people in under-resourced regions" claim. Uses the fleet engine when
//! artifacts are present (thousands of seeds), falling back to the native
//! fleet otherwise.

use anyhow::Result;

use super::report::{ExpContext, Report};
use super::Experiment;
use crate::exec::{cell_rng, run_indexed};
use crate::fleet::{build_fleet_policy, policy_run, FleetHyper, FleetParams, FleetState};
use crate::runtime::XlaRuntime;
use crate::sim::freq::FreqDomain;

use crate::util::table::{fnum, fnum_sep, Table};
use crate::util::Rng;
use crate::workload::calibration;

/// Aurora node count (paper §4.2).
pub const AURORA_NODES: f64 = 10_620.0;
/// Daily electricity use: ~12.1 kWh per U.S. resident, ~1.6 kWh in
/// under-resourced regions (derived from the paper's 9,149/69,342 ratio on
/// sph_exa's 257.52 kJ/run saving).
pub const KWH_PER_US_RESIDENT_DAY: f64 = 12.1;
pub const KWH_PER_UNDERRESOURCED_DAY: f64 = 1.6;

/// kJ saved per node-run -> daily people-equivalents at fleet scale,
/// assuming back-to-back runs for 24 h.
pub fn people_equivalents(saved_kj_per_run: f64, run_time_s: f64) -> (f64, f64) {
    let runs_per_day = 86_400.0 / run_time_s;
    let saved_kwh_day = saved_kj_per_run * runs_per_day * AURORA_NODES / 3_600.0;
    (
        saved_kwh_day / KWH_PER_US_RESIDENT_DAY,
        saved_kwh_day / KWH_PER_UNDERRESOURCED_DAY,
    )
}

pub struct Impact;

impl Experiment for Impact {
    fn id(&self) -> &'static str {
        "impact"
    }

    fn title(&self) -> &'static str {
        "Social impact: fleet-scale energy savings extrapolation (sph_exa, llama)"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let freqs = FreqDomain::aurora();
        // Fleet of B seeds of the flagship app (sph_exa: the paper's
        // headline 257.52 kJ saving).
        let b = if ctx.quick { 64 } else { 256 };
        let app = calibration::app("sph_exa").unwrap();
        let apps = vec![&app; b];
        let mut params = FleetParams::from_apps(&apps, &freqs, 0.01);
        // `--policy` threads through to the fleet (default: the paper's
        // EnergyUCB, the bit-pinned artifact path).
        params.policies = ctx.policy.clone().into_iter().collect();
        let hyper = FleetHyper::default();
        let max_steps = if ctx.quick { 4_000 } else { 80_000 };

        // Prefer the HLO engine when artifacts exist (exercises the AOT
        // path at fleet scale); otherwise the sharded native engine.
        let art_dir = std::path::Path::new("artifacts");
        let engine_used;
        let (energy_kj, remaining): (Vec<f64>, Vec<f64>);
        // The HLO path needs the exported artifact, a live PJRT runtime
        // (absent in stub builds without the `xla` feature), AND the
        // default EnergyUCB policy (artifacts encode it) — fall back to
        // the native batch-policy engine in any other case.
        let runtime = if params.policies.is_empty()
            && art_dir.join(format!("fleet_step_b{b}.hlo.txt")).exists()
        {
            XlaRuntime::cpu()
                .map_err(|e| eprintln!("impact: PJRT unavailable, using native engine ({e})"))
                .ok()
        } else {
            None
        };
        if let Some(runtime) = runtime {
            // The artifact's batch size is fixed at export, so the HLO path
            // runs unsharded (its lockstep batch IS the parallelism).
            let mut state = FleetState::fresh(b, freqs.k());
            let mut rng = Rng::new(ctx.seed);
            let engine =
                crate::fleet::FleetEngine::load(&runtime, art_dir, params.clone(), hyper)?;
            engine.run(&mut state, &mut rng, max_steps)?;
            energy_kj = (0..b).map(|e| state.energy_kj(e)).collect();
            remaining = state.remaining.iter().map(|r| *r as f64).collect();
            engine_used = "hlo";
        } else {
            // Native fallback: shard the fleet into fixed-size chunks, one
            // cell per chunk with an order-independent RNG keyed by chunk
            // index — results are identical at any --jobs value (the chunk
            // layout never depends on the worker count).
            const CHUNK: usize = 32;
            let n_chunks = (b + CHUNK - 1) / CHUNK;
            let chunk_results = run_indexed(ctx.jobs, n_chunks, |c| {
                let lo = c * CHUNK;
                let hi = (lo + CHUNK).min(b);
                let mut chunk_params = FleetParams::from_apps(&apps[lo..hi], &freqs, 0.01);
                chunk_params.policies = params.policies.clone();
                let mut state = FleetState::fresh(hi - lo, freqs.k());
                let mut rng = cell_rng(ctx.seed, c as u64);
                // One stepping core for every selector: the default
                // (empty) selection is the batched EnergyUCB, bit-identical
                // to the pre-selector native_run path.
                let mut policy = build_fleet_policy(
                    &chunk_params,
                    &hyper,
                    ctx.seed.wrapping_add(lo as u64),
                );
                policy_run(&mut state, &chunk_params, policy.as_mut(), &mut rng, max_steps);
                let kj: Vec<f64> = (0..hi - lo).map(|e| state.energy_kj(e)).collect();
                let rem: Vec<f64> =
                    state.remaining.iter().map(|r| *r as f64).collect();
                (kj, rem)
            });
            let mut kj = Vec::with_capacity(b);
            let mut rem = Vec::with_capacity(b);
            for (ck, cr) in chunk_results {
                kj.extend(ck);
                rem.extend(cr);
            }
            energy_kj = kj;
            remaining = rem;
            engine_used = "native";
        }

        // Mean energy over completed (or truncated) envs, extrapolated to
        // full completion by remaining fraction.
        let mut total_kj = 0.0;
        for e in 0..b {
            let done_frac = (1.0 - remaining[e]).max(1e-3);
            total_kj += energy_kj[e] / done_frac;
        }
        let mean_kj = total_kj / b as f64;
        let default_kj = app.energy_kj[freqs.max_arm()];
        let saved = default_kj - mean_kj;
        let (us, under) = people_equivalents(saved, app.t_max_s * 1.2);

        let mut table = Table::new(vec!["quantity", "value"]);
        table.row(vec!["engine".to_string(), engine_used.to_string()]);
        table.row(vec!["fleet size (seeds)".to_string(), b.to_string()]);
        table.row(vec!["mean energy (kJ/run)".to_string(), fnum_sep(mean_kj, 2)]);
        table.row(vec!["default 1.6 GHz (kJ/run)".to_string(), fnum_sep(default_kj, 2)]);
        table.row(vec!["saved (kJ/run/node)".to_string(), fnum(saved, 2)]);
        table.row(vec![
            "US-resident day-equivalents (fleet)".to_string(),
            fnum_sep(us.round(), 0),
        ]);
        table.row(vec![
            "under-resourced day-equivalents".to_string(),
            fnum_sep(under.round(), 0),
        ]);
        report.push_text(table.render());
        report.push_text(
            "Paper: sph_exa saves 257.52 kJ/node-run; at 10,620 nodes that's \
             ~9,149 US residents or ~69,342 people in under-resourced regions per day.",
        );
        report.json.set("engine", engine_used);
        report.json.set("saved_kj", saved);
        report.json.set("us_equivalents", us);
        report.json.set("under_equivalents", under);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn people_equivalents_match_paper_scale() {
        // Paper anchor: 257.52 kJ saved per sph_exa run.
        let (us, under) = people_equivalents(257.52, 480.0 * 1.2);
        // Same order of magnitude as 9,149 / 69,342.
        assert!(us > 4_000.0 && us < 20_000.0, "{us}");
        assert!(under > 30_000.0 && under < 160_000.0, "{under}");
        assert!((under / us - KWH_PER_US_RESIDENT_DAY / KWH_PER_UNDERRESOURCED_DAY).abs() < 0.1);
    }

    #[test]
    fn quick_impact_runs() {
        let ctx = ExpContext {
            quick: true,
            out_dir: std::env::temp_dir().join("energyucb_imp_test"),
            ..ExpContext::quick()
        };
        let report = Impact.run(&ctx).unwrap();
        let saved = report.json.get_num("saved_kj").unwrap();
        assert!(saved > 0.0, "saved {saved}");
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_imp_test"));
    }

    #[test]
    fn impact_accepts_policy_selector() {
        // `--policy` threads into the fleet the extrapolation runs on.
        let ctx = ExpContext {
            quick: true,
            policy: Some(crate::config::PolicyConfig::Ucb1 { alpha: 0.05 }),
            out_dir: std::env::temp_dir().join("energyucb_imp_pol_test"),
            ..ExpContext::quick()
        };
        let report = Impact.run(&ctx).unwrap();
        assert!(report.json.get_num("saved_kj").unwrap().is_finite());
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_imp_pol_test"));
    }
}
