//! Experiment harness: one module per table/figure of the paper.
//!
//! Every experiment implements [`Experiment`]: it runs the workloads,
//! prints the paper's rows/series next to our measurements, and writes
//! machine-readable results (JSON + CSV) under the output directory. The
//! registry maps experiment ids (`fig1a`, `table1`, ...) to
//! implementations; `energyucb exp <id>` and the bench harness both go
//! through it.

pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5a;
pub mod fig5b;
pub mod impact;
pub mod paper;
pub mod registry;
pub mod sweeps;
pub mod report;
pub mod table1;
pub mod table2;

pub use registry::{all_experiments, experiment_by_id};
pub use report::{ExpContext, Report};

/// One reproducible experiment (a paper table or figure).
pub trait Experiment {
    /// Short id used on the CLI ("table1", "fig3", ...).
    fn id(&self) -> &'static str;
    /// Human title.
    fn title(&self) -> &'static str;
    /// Execute, printing progress to stderr, returning the report.
    fn run(&self, ctx: &ExpContext) -> anyhow::Result<Report>;
}
