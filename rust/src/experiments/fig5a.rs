//! Fig. 5(a): reward-formulation analysis — r = E·R (the paper's choice)
//! vs the squared variants E²·R and E·R², which amplify counter noise and
//! converge worse.

use anyhow::Result;

use super::fig1::scale_app;
use super::report::{ExpContext, Report};
use super::Experiment;
use crate::bandit::{EnergyUcb, EnergyUcbConfig, RewardForm};
use crate::control::{run_session, SessionCfg};
use crate::exec::{reduce_reps, run_indexed, CellGrid};
use crate::util::io::Json;
use crate::util::table::{fnum_sep, Table};
use crate::workload::calibration;

pub struct Fig5a;

impl Experiment for Fig5a {
    fn id(&self) -> &'static str {
        "fig5a"
    }

    fn title(&self) -> &'static str {
        "Fig. 5(a): impact of the reward formulation (E*R vs E^2*R vs E*R^2)"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let reps = ctx.effective_reps();
        let forms = [
            RewardForm::EnergyRatio,
            RewardForm::EnergySquaredRatio,
            RewardForm::EnergyRatioSquared,
        ];
        let mut table = Table::new(vec!["app", "E*R (kJ)", "E^2*R (kJ)", "E*R^2 (kJ)"]);
        let mut json_rows = Vec::new();
        let mut er_best = 0usize;

        let apps: Vec<_> = calibration::all_apps()
            .iter()
            .map(|app0| {
                if ctx.quick {
                    // Quick mode: shrink the three longest runs harder.
                    if matches!(app0.name, "sph_exa" | "llama" | "diffusion") {
                        scale_app(app0, 32.0)
                    } else {
                        scale_app(app0, 8.0)
                    }
                } else {
                    app0.clone()
                }
            })
            .collect();
        let napps = apps.len();

        // (app × form × rep) cells, mean over the rep axis.
        let grid = CellGrid::new(apps.len(), forms.len(), reps);
        eprintln!("fig5a: {} cells across {} jobs", grid.len(), ctx.jobs);
        let cell_energies = run_indexed(ctx.jobs, grid.len(), |cell| {
            let (a, fm, r) = grid.unpack(cell);
            let mut policy = EnergyUcb::new(9, EnergyUcbConfig::default());
            let cfg = SessionCfg {
                seed: ctx.seed + r as u64,
                reward_form: forms[fm],
                ..SessionCfg::default()
            };
            run_session(&apps[a], &mut policy, &cfg).metrics.gpu_energy_kj
        });
        let aggregates = reduce_reps(&cell_energies, reps);

        for (a, app) in apps.iter().enumerate() {
            let mut cells = vec![app.name.to_string()];
            let mut means = Vec::new();
            let mut j = Json::obj();
            j.set("app", app.name);
            for (fm, form) in forms.iter().enumerate() {
                let m = aggregates[grid.group(a, fm)].mean();
                cells.push(fnum_sep(m, 2));
                means.push(m);
                j.set(form.name(), m);
            }
            if means[0] <= means[1] + 1e-9 && means[0] <= means[2] + 1e-9 {
                er_best += 1;
            }
            table.row(cells);
            json_rows.push(j);
        }
        report.push_text(table.render());
        report.push_text(format!(
            "E*R is the best (or tied-best) formulation on {er_best}/{napps} apps. \
             Paper: squared variants amplify counter-noise fluctuations — e.g. \
             miniswp ~185 kJ vs ~167 kJ (+10.8%), clvleaf >100 kJ vs ~90 kJ (+11.1%).",
        ));
        report.json.set("rows", Json::Arr(json_rows));
        report.json.set("er_best_count", er_best);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_reward_forms_favor_er() {
        let ctx = ExpContext {
            quick: true,
            reps: 2,
            out_dir: std::env::temp_dir().join("energyucb_f5a_test"),
            ..ExpContext::default()
        };
        let report = Fig5a.run(&ctx).unwrap();
        // Aggregate criterion (single-app gaps can be sub-noise in quick
        // mode): summed energy under E*R must not exceed either squared
        // variant's sum. Full-mode per-app wins recorded in EXPERIMENTS.md.
        let rows = match report.json.get("rows") {
            Some(Json::Arr(rows)) => rows.clone(),
            _ => panic!(),
        };
        let total = |form: &str| -> f64 {
            rows.iter().map(|r| r.get_num(form).unwrap()).sum()
        };
        let er = total("E*R");
        assert!(er <= total("E^2*R") * 1.01, "E*R {er} vs E^2*R {}", total("E^2*R"));
        assert!(er <= total("E*R^2") * 1.01, "E*R {er} vs E*R^2 {}", total("E*R^2"));
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_f5a_test"));
    }
}
