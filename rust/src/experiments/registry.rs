//! Experiment registry: id → implementation.

use super::{fig1, fig3, fig4, fig5a, fig5b, impact, sweeps, table1, table2, Experiment};

/// Every experiment, in paper order.
pub fn all_experiments() -> Vec<Box<dyn Experiment>> {
    vec![
        Box::new(fig1::Fig1a),
        Box::new(fig1::Fig1b),
        Box::new(table1::Table1),
        Box::new(fig3::Fig3),
        Box::new(table2::Table2),
        Box::new(fig4::Fig4),
        Box::new(fig5a::Fig5a),
        Box::new(fig5b::Fig5b),
        Box::new(impact::Impact),
        Box::new(sweeps::Sweeps),
    ]
}

/// Find by id.
pub fn experiment_by_id(id: &str) -> Option<Box<dyn Experiment>> {
    all_experiments().into_iter().find(|e| e.id() == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_table_and_figure() {
        let ids: Vec<&str> = all_experiments().iter().map(|e| e.id()).collect();
        for required in
            ["fig1a", "fig1b", "table1", "fig3", "table2", "fig4", "fig5a", "fig5b"]
        {
            assert!(ids.contains(&required), "{required} missing");
        }
    }

    #[test]
    fn lookup_by_id() {
        assert!(experiment_by_id("table1").is_some());
        assert!(experiment_by_id("nope").is_none());
    }

    #[test]
    fn ids_are_unique() {
        let mut ids: Vec<&str> = all_experiments().iter().map(|e| e.id()).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }
}
