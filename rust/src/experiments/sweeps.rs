//! Hyper-parameter sensitivity sweeps — the design-choice ablations called
//! out in DESIGN.md (beyond the paper's Table 2): α (exploration), λ
//! (switching penalty), and the optimistic prior weight, each swept on a
//! representative app pair (one small-gap, one noisy).

use anyhow::Result;

use super::fig1::scale_app;
use super::report::{ExpContext, Report};
use super::Experiment;
use crate::bandit::{EnergyUcb, EnergyUcbConfig};
use crate::control::{run_session, SessionCfg};
use crate::exec::{reduce_reps, run_indexed, CellGrid};
use crate::util::io::Json;
use crate::util::table::{fnum, Table};
use crate::workload::calibration;

const APPS: [&str; 2] = ["tealeaf", "llama"];

pub struct Sweeps;

impl Experiment for Sweeps {
    fn id(&self) -> &'static str {
        "sweeps"
    }

    fn title(&self) -> &'static str {
        "Sensitivity: α / λ / prior_n sweeps around the defaults"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let reps = ctx.effective_reps();
        let base = EnergyUcbConfig::default();
        let mut json_rows = Vec::new();

        type Knob = (&'static str, Vec<f64>, fn(EnergyUcbConfig, f64) -> EnergyUcbConfig);
        let knobs: Vec<Knob> = vec![
            ("alpha", vec![0.005, 0.02, 0.035, 0.08, 0.2, 0.5], |c, v| EnergyUcbConfig {
                alpha: v,
                ..c
            }),
            ("lambda", vec![0.0, 0.005, 0.01, 0.05, 0.2], |c, v| EnergyUcbConfig {
                lambda: v,
                ..c
            }),
            ("prior_n", vec![0.0, 0.3, 1.0, 3.0, 10.0], |c, v| EnergyUcbConfig {
                prior_n: v,
                ..c
            }),
        ];

        let apps: Vec<_> = APPS
            .iter()
            .map(|name| {
                let app0 = calibration::app(name).unwrap();
                if ctx.quick {
                    scale_app(&app0, 16.0)
                } else {
                    app0
                }
            })
            .collect();

        for (knob, values, apply) in knobs {
            let mut table = Table::new({
                let mut h = vec![knob.to_string()];
                for app in APPS {
                    h.push(format!("{app} regret kJ"));
                    h.push(format!("{app} switches"));
                }
                h
            });
            // (value × app × rep) cells for this knob; EnergyUCB is
            // RNG-free, so fresh per-cell policies at seed base+rep match
            // the old reset-loop runs.
            let grid = CellGrid::new(values.len(), apps.len(), reps);
            eprintln!("sweeps/{knob}: {} cells across {} jobs", grid.len(), ctx.jobs);
            let cell_results = run_indexed(ctx.jobs, grid.len(), |cell| {
                let (vi, a, r) = grid.unpack(cell);
                let mut policy = EnergyUcb::new(9, apply(base, values[vi]));
                let cfg = SessionCfg { seed: ctx.seed + r as u64, ..SessionCfg::default() };
                let m = run_session(&apps[a], &mut policy, &cfg).metrics;
                (m.gpu_energy_kj, m.switches as f64)
            });
            let energy_agg =
                reduce_reps(&cell_results.iter().map(|c| c.0).collect::<Vec<_>>(), reps);
            let switch_agg =
                reduce_reps(&cell_results.iter().map(|c| c.1).collect::<Vec<_>>(), reps);

            for (vi, v) in values.iter().enumerate() {
                let mut cells = vec![format!("{v}")];
                let mut j = Json::obj();
                j.set("knob", knob);
                j.set("value", *v);
                for (a, name) in APPS.iter().enumerate() {
                    let regret =
                        energy_agg[grid.group(vi, a)].mean() - apps[a].optimal_energy_kj();
                    let switches = switch_agg[grid.group(vi, a)].mean();
                    cells.push(fnum(regret, 2));
                    cells.push(fnum(switches, 0));
                    j.set(format!("{name}_regret_kj"), regret);
                    j.set(format!("{name}_switches"), switches);
                }
                table.row(cells);
                json_rows.push(j);
            }
            report.push_text(format!("--- {knob} sweep (defaults: α={}, λ={}, prior_n={}) ---", base.alpha, base.lambda, base.prior_n));
            report.push_text(table.render());
        }
        report.push_text(
            "Reading: regret is U-shaped in α (under/over-exploration), switches fall \
             monotonically in λ while regret grows past the hysteresis sweet spot, and \
             the optimistic prior trades early-sample robustness against revisit cost.",
        );
        report.json.set("rows", Json::Arr(json_rows));
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_runs_and_has_all_knobs() {
        let ctx = ExpContext {
            quick: true,
            reps: 1,
            out_dir: std::env::temp_dir().join("energyucb_sw_test"),
            ..ExpContext::default()
        };
        let report = Sweeps.run(&ctx).unwrap();
        for knob in ["alpha", "lambda", "prior_n"] {
            assert!(report.text.contains(&format!("--- {knob} sweep")), "{knob}");
        }
        // Huge alpha must cost more regret than the default on tealeaf.
        let rows = match report.json.get("rows") {
            Some(Json::Arr(r)) => r.clone(),
            _ => panic!(),
        };
        let regret_at = |knob: &str, v: f64| {
            rows.iter()
                .find(|r| {
                    matches!(r.get("knob"), Some(Json::Str(s)) if s == knob)
                        && r.get_num("value") == Some(v)
                })
                .and_then(|r| r.get_num("tealeaf_regret_kj"))
                .unwrap()
        };
        assert!(regret_at("alpha", 0.5) > regret_at("alpha", 0.035));
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_sw_test"));
    }
}
