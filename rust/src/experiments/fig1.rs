//! Fig. 1 (motivation): (a) node energy split across components per app;
//! (b) the pot3d performance–energy trade-off across 1.6/1.1/0.8 GHz.

use anyhow::Result;

use super::paper;
use super::report::{vs_paper, ExpContext, Report};
use super::Experiment;
use crate::bandit::StaticPolicy;
use crate::control::{run_session, SessionCfg};
use crate::exec::run_indexed;
use crate::sim::freq::FreqDomain;
use crate::util::io::Json;
use crate::util::table::{fnum, Table};
use crate::workload::calibration;

pub struct Fig1a;

impl Experiment for Fig1a {
    fn id(&self) -> &'static str {
        "fig1a"
    }

    fn title(&self) -> &'static str {
        "Fig. 1(a): component energy distribution per HPC application"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let freqs = FreqDomain::aurora();
        let mut table = Table::new(vec!["app", "GPU %", "CPU %", "other %", "total kJ"]);
        let mut json_rows = Vec::new();
        // One cell per app (run at the default frequency to completion —
        // the motivation figure's setting), reduced in suite order.
        let all = calibration::all_apps();
        let results = run_indexed(ctx.jobs, all.len(), |a| {
            let mut policy = StaticPolicy::labeled(freqs.k(), freqs.max_arm(), "1.6 GHz");
            let cfg = SessionCfg { seed: ctx.seed, ..SessionCfg::default() };
            let app_run = if ctx.quick { scale_app(&all[a], 8.0) } else { all[a].clone() };
            let res = run_session(&app_run, &mut policy, &cfg);
            (res.metrics.gpu_energy_kj, res.metrics.exec_time_s)
        });
        for (app, (gpu, exec_time_s)) in all.iter().zip(results) {
            // CPU/other accounted by the node model.
            let cpu = app.cpu_kw * exec_time_s;
            let other = app.other_kw * exec_time_s;
            let total = gpu + cpu + other;
            table.row(vec![
                app.name.to_string(),
                fnum(100.0 * gpu / total, 2),
                fnum(100.0 * cpu / total, 2),
                fnum(100.0 * other / total, 2),
                fnum(total, 1),
            ]);
            let mut j = Json::obj();
            j.set("app", app.name);
            j.set("gpu_frac", gpu / total);
            j.set("cpu_frac", cpu / total);
            j.set("other_frac", other / total);
            json_rows.push(j);

            if app.name == "pot3d" && !ctx.quick {
                let (pg, pc, po) = paper::FIG1A_POT3D;
                report.push_text(format!(
                    "pot3d shares — GPU {}, CPU {}, other {}",
                    vs_paper(gpu / total, pg, 3),
                    vs_paper(cpu / total, pc, 3),
                    vs_paper(other / total, po, 3)
                ));
            }
        }
        report.push_text(table.render());
        report.push_text("GPUs dominate node energy for every application (paper: >4x CPUs on pot3d).");
        report.json.set("rows", Json::Arr(json_rows));
        Ok(report)
    }
}

pub struct Fig1b;

impl Experiment for Fig1b {
    fn id(&self) -> &'static str {
        "fig1b"
    }

    fn title(&self) -> &'static str {
        "Fig. 1(b): pot3d performance-energy trade-off (1.6/1.1/0.8 GHz)"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let freqs = FreqDomain::aurora();
        let app = calibration::app("pot3d").expect("pot3d");
        let app_run = if ctx.quick { scale_app(&app, 8.0) } else { app.clone() };
        let scale = if ctx.quick { 8.0 } else { 1.0 };
        let mut table =
            Table::new(vec!["GHz", "power kW", "time s", "energy kJ", "paper kJ (Fig.1b)"]);
        let mut json_rows = Vec::new();
        // One cell per plotted frequency.
        let cells = run_indexed(ctx.jobs, paper::FIG1B.len(), |i| {
            let (ghz, _, _, _) = paper::FIG1B[i];
            let arm = freqs.index_of_ghz(ghz).unwrap();
            let mut policy = StaticPolicy::new(freqs.k(), arm);
            let cfg = SessionCfg { seed: ctx.seed, ..SessionCfg::default() };
            let res = run_session(&app_run, &mut policy, &cfg);
            (res.metrics.exec_time_s, res.metrics.gpu_energy_kj)
        });
        for ((ghz, p_kw, t_s, e_kj), (exec_time_s, gpu_kj)) in
            paper::FIG1B.into_iter().zip(cells)
        {
            let time = exec_time_s * scale;
            let energy = gpu_kj * scale;
            let power = energy / time;
            table.row(vec![
                format!("{ghz:.1}"),
                fnum(power, 3),
                fnum(time, 2),
                fnum(energy, 2),
                format!("{e_kj:.2} ({p_kw:.3} kW x {t_s:.2} s)"),
            ]);
            let mut j = Json::obj();
            j.set("ghz", ghz);
            j.set("power_kw", power);
            j.set("time_s", time);
            j.set("energy_kj", energy);
            json_rows.push(j);
        }
        report.push_text(table.render());
        report.push_text(
            "Shape check: energy dips at 1.1 GHz and rises again at 0.8 GHz \
             (the non-monotone trade-off motivating online control).",
        );
        report.json.set("rows", Json::Arr(json_rows));
        Ok(report)
    }
}

/// Shrink an app's execution length by `factor` for quick mode. Power and
/// the optimal-arm structure are preserved exactly; energies scale by
/// 1/factor.
pub(crate) fn scale_app(
    app: &crate::workload::model::AppModel,
    factor: f64,
) -> crate::workload::model::AppModel {
    let mut a = app.clone();
    a.t_max_s /= factor;
    for e in a.energy_kj.iter_mut() {
        *e /= factor;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1a_runs_quick() {
        let ctx = ExpContext::quick();
        let report = Fig1a.run(&ctx).unwrap();
        assert!(report.text.contains("pot3d"));
        assert!(report.text.contains("GPU %"));
    }

    #[test]
    fn fig1b_shape_holds() {
        let ctx = ExpContext::quick();
        let report = Fig1b.run(&ctx).unwrap();
        // Extract the three energies from JSON.
        let rows = match report.json.get("rows") {
            Some(crate::util::io::Json::Arr(rows)) => rows.clone(),
            _ => panic!("no rows"),
        };
        let energy = |i: usize| rows[i].get_num("energy_kj").unwrap();
        let (e16, e11, e08) = (energy(0), energy(1), energy(2));
        assert!(e11 < e16, "{e11} {e16}");
        assert!(e11 < e08, "{e11} {e08}");
    }

    #[test]
    fn scale_app_preserves_structure() {
        let app = calibration::app("sph_exa").unwrap();
        let scaled = scale_app(&app, 8.0);
        assert_eq!(scaled.optimal_arm(), app.optimal_arm());
        let f = FreqDomain::aurora();
        assert!((scaled.power_kw(&f, 8) - app.power_kw(&f, 8)).abs() < 1e-9);
    }
}
