//! Fig. 4: switching-cost analysis on llama — the switching-aware penalty
//! suppresses frequency oscillation, shrinking the controller's own
//! overhead (#switches, switch energy, switch time) by several ×.

use anyhow::Result;

use super::fig1::scale_app;
use super::paper;
use super::report::{ExpContext, Report};
use super::Experiment;
use crate::bandit::{EnergyUcb, EnergyUcbConfig};
use crate::control::{run_session, SessionCfg};
use crate::exec::{run_indexed, CellGrid};
use crate::util::io::Json;
use crate::util::stats::mean;
use crate::util::table::{fnum, fnum_sep, Table};
use crate::workload::calibration;

pub struct Fig4;

impl Experiment for Fig4 {
    fn id(&self) -> &'static str {
        "fig4"
    }

    fn title(&self) -> &'static str {
        "Fig. 4: switching cost with vs without the switching-aware penalty (llama)"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let app0 = calibration::app("llama").unwrap();
        let app = if ctx.quick { scale_app(&app0, 16.0) } else { app0.clone() };
        let reps = ctx.effective_reps();

        // Regime 2 (supplementary): degraded telemetry. The paper's
        // measured 20.85k switches over ~43k intervals imply its reward
        // stream kept near-optimal arms statistically tied essentially
        // forever; in our calibrated (stationary) simulator a consistent
        // estimator converges and oscillation dies out, so the calibrated
        // regime shows the converged scale while this regime reproduces
        // the paper's oscillation-suppression mechanism. Full analysis in
        // EXPERIMENTS.md §Deviations.
        let mut noisy = app.clone();
        noisy.noise = crate::workload::model::NoiseSpec {
            energy_frac: 0.25,
            util_std: 0.10,
            spike_prob: 0.05,
            spike_mult: 6.0,
            ..noisy.noise
        };

        let regimes: [(&str, &crate::workload::model::AppModel); 2] =
            [("calibrated", &app), ("noisy telemetry", &noisy)];
        let configs = [
            ("w/o Penalty", EnergyUcbConfig { lambda: 0.0, ..EnergyUcbConfig::default() }),
            ("with Penalty", EnergyUcbConfig::default()),
        ];

        // (regime × variant × rep) cells; EnergyUCB is RNG-free, so fresh
        // per-cell policies at seed base+rep match the old reset-loop runs.
        let grid = CellGrid::new(regimes.len(), configs.len(), reps);
        eprintln!("fig4: {} cells across {} jobs", grid.len(), ctx.jobs);
        let cells = run_indexed(ctx.jobs, grid.len(), |cell| {
            let (g, v, r) = grid.unpack(cell);
            let mut policy = EnergyUcb::new(9, configs[v].1);
            let cfg = SessionCfg { seed: ctx.seed + r as u64, ..SessionCfg::default() };
            let m = run_session(regimes[g].1, &mut policy, &cfg).metrics;
            (m.switches as f64, m.switch_energy_j / 1_000.0, m.switch_time_s, m.gpu_energy_kj)
        });

        let mut all_json = Vec::new();
        let mut reductions = Vec::new();
        for (g, (regime, _)) in regimes.iter().enumerate() {
            let mut table = Table::new(vec![
                "variant",
                "switches",
                "switch energy (kJ)",
                "switch time (s)",
                "total energy (kJ)",
            ]);
            let mut measured = Vec::new();
            for (v, (label, _)) in configs.iter().enumerate() {
                let reps_of = |f: &dyn Fn(&(f64, f64, f64, f64)) -> f64| -> Vec<f64> {
                    (0..reps).map(|r| f(&cells[grid.pack(g, v, r)])).collect()
                };
                let switches = mean(&reps_of(&|c| c.0));
                let sw_kj = mean(&reps_of(&|c| c.1));
                let sw_s = mean(&reps_of(&|c| c.2));
                let kj = mean(&reps_of(&|c| c.3));
                table.row(vec![
                    label.to_string(),
                    fnum(switches, 0),
                    fnum(sw_kj, 3),
                    fnum(sw_s, 3),
                    fnum_sep(kj, 2),
                ]);
                let mut j = Json::obj();
                j.set("regime", *regime);
                j.set("variant", *label);
                j.set("switches", switches);
                j.set("switch_energy_kj", sw_kj);
                j.set("switch_time_s", sw_s);
                j.set("total_energy_kj", kj);
                measured.push(j);
            }
            let get = |i: usize, k: &str| measured[i].get_num(k).unwrap();
            let reduction = get(0, "switches") / get(1, "switches").max(1.0);
            reductions.push(reduction);
            report.push_text(format!("--- regime: {regime} ---"));
            report.push_text(table.render());
            report.push_text(format!("penalty reduces switches by {reduction:.1}x\n"));
            all_json.extend(measured);
        }

        report.push_text(format!(
            "Paper (llama): {:.0} -> {:.0} switches (6.7x), overhead {:.2} kJ -> {:.2} kJ, \
             {:.2} s -> {:.2} s.",
            paper::FIG4_WO_PENALTY.0,
            paper::FIG4_WITH_PENALTY.0,
            paper::FIG4_WO_PENALTY.1,
            paper::FIG4_WITH_PENALTY.1,
            paper::FIG4_WO_PENALTY.2,
            paper::FIG4_WITH_PENALTY.2,
        ));
        report.push_text(
            "Per-switch cost model: 150 µs + 0.3 J (paper §4.4) — overhead rows are \
             switches × cost by construction, matching the paper's arithmetic.",
        );
        report.json.set("variants", Json::Arr(all_json));
        report.json.set("reduction_factor", reductions[0]);
        report.json.set("reduction_factor_noisy", reductions[1]);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn penalty_reduces_switching_quick() {
        let ctx = ExpContext {
            quick: true,
            reps: 2,
            out_dir: std::env::temp_dir().join("energyucb_f4_test"),
            ..ExpContext::default()
        };
        let report = Fig4.run(&ctx).unwrap();
        // The noisy-telemetry regime must show clear oscillation
        // suppression (the calibrated regime converges to few switches).
        let red = report.json.get_num("reduction_factor_noisy").unwrap();
        assert!(red > 1.25, "reduction {red}");
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_f4_test"));
    }
}
