//! Table 2: ablation of EnergyUCB's components on the three most
//! energy-intensive applications (sph_exa, llama, diffusion):
//! full vs w/o Opt. Ini. (round-robin warm-up, no prior shrinkage) vs
//! w/o Penalty (λ = 0). Mean ± std over repetitions.

use anyhow::Result;

use super::fig1::scale_app;
use super::paper;
use super::report::{ExpContext, Report};
use super::Experiment;
use crate::bandit::{EnergyUcb, EnergyUcbConfig, InitStrategy};
use crate::control::{run_session, SessionCfg};
use crate::exec::{reduce_reps, run_indexed, CellGrid};
use crate::util::io::Json;
use crate::util::table::{fnum_sep, Table};
use crate::workload::calibration;

const APPS: [&str; 3] = ["sph_exa", "llama", "diffusion"];

/// The three ablation variants in paper column order.
pub fn variants() -> Vec<(&'static str, EnergyUcbConfig)> {
    let full = EnergyUcbConfig::default();
    vec![
        ("EnergyUCB", full),
        (
            "w/o Opt. Ini.",
            EnergyUcbConfig { init: InitStrategy::WarmupRoundRobin, ..full },
        ),
        ("w/o Penalty", EnergyUcbConfig { lambda: 0.0, ..full }),
    ]
}

pub struct Table2;

impl Experiment for Table2 {
    fn id(&self) -> &'static str {
        "table2"
    }

    fn title(&self) -> &'static str {
        "Table 2: ablation of optimistic initialization and the switching penalty"
    }

    fn run(&self, ctx: &ExpContext) -> Result<Report> {
        let mut report = Report::new(self.id());
        let reps = ctx.effective_reps();
        let mut table = Table::new(vec![
            "app",
            "EnergyUCB (kJ)",
            "w/o Opt. Ini. (kJ)",
            "w/o Penalty (kJ)",
        ]);
        let mut json_rows = Vec::new();
        let mut ordered_ok = 0;
        let mut opt_ini_worse = 0;

        // (app × variant × rep) cells; EnergyUCB holds no internal RNG, so a
        // fresh per-cell policy at seed `base + rep` reproduces the previous
        // reset-and-rerun loop exactly.
        let apps: Vec<_> = APPS
            .iter()
            .map(|name| {
                let app0 = calibration::app(name).unwrap();
                if ctx.quick {
                    scale_app(&app0, 16.0)
                } else {
                    app0
                }
            })
            .collect();
        let variant_list = variants();
        let grid = CellGrid::new(apps.len(), variant_list.len(), reps);
        eprintln!("table2: {} cells across {} jobs", grid.len(), ctx.jobs);
        let cell_energies = run_indexed(ctx.jobs, grid.len(), |cell| {
            let (a, v, r) = grid.unpack(cell);
            let mut policy = EnergyUcb::new(9, variant_list[v].1);
            let cfg = SessionCfg { seed: ctx.seed + r as u64, ..SessionCfg::default() };
            run_session(&apps[a], &mut policy, &cfg).metrics.gpu_energy_kj
        });
        let aggregates = reduce_reps(&cell_energies, reps);

        for (a, name) in APPS.iter().enumerate() {
            let mut cells = vec![name.to_string()];
            let mut means = Vec::new();
            let mut stds = Vec::new();
            let mut j = Json::obj();
            j.set("app", *name);
            for (v, (label, _)) in variant_list.iter().enumerate() {
                let w = &aggregates[grid.group(a, v)];
                let (m, s) = (w.mean(), w.sample_std());
                cells.push(format!("{} ± {:.2}", fnum_sep(m, 2), s));
                means.push(m);
                stds.push(s);
                let mut vj = Json::obj();
                vj.set("mean_kj", m);
                vj.set("std_kj", s);
                j.set(*label, vj);
            }
            // Shape: full best-or-tied (within one pooled std) vs both
            // ablations; and the w/o Opt. Ini. degradation specifically.
            let tol1 = (stds[0] + stds[1]) / 2.0;
            let tol2 = (stds[0] + stds[2]) / 2.0;
            if means[0] <= means[1] + tol1 && means[0] <= means[2] + tol2 {
                ordered_ok += 1;
            }
            if means[1] > means[0] - stds[0] {
                opt_ini_worse += 1;
            }
            table.row(cells);
            json_rows.push(j);
        }
        report.push_text(table.render());
        report.push_text(format!(
            "Full EnergyUCB is best-or-statistically-tied on {ordered_ok}/{} apps; \
             w/o Opt. Ini. degrades (or ties) on {opt_ini_worse}/{} \
             (paper: full best on 3/3, with w/o Opt. Ini. the larger degradation).",
            APPS.len(),
            APPS.len()
        ));
        if !ctx.quick {
            let mut cmp = Table::new(vec!["app", "variant", "ours kJ", "paper kJ"]);
            for (row, (name, paper_vals)) in json_rows.iter().zip(paper::TABLE2) {
                for (vi, label) in ["EnergyUCB", "w/o Opt. Ini.", "w/o Penalty"]
                    .iter()
                    .enumerate()
                {
                    let ours = row
                        .get(label)
                        .and_then(|v| v.get_num("mean_kj"))
                        .unwrap_or(f64::NAN);
                    cmp.row(vec![
                        name.to_string(),
                        label.to_string(),
                        fnum_sep(ours, 2),
                        fnum_sep(paper_vals[vi], 2),
                    ]);
                }
            }
            report.push_text(cmp.render());
        }
        report.json.set("rows", Json::Arr(json_rows));
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_variants_in_order() {
        let v = variants();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].0, "EnergyUCB");
        assert_eq!(v[1].1.init, InitStrategy::WarmupRoundRobin);
        assert_eq!(v[2].1.lambda, 0.0);
    }

    #[test]
    fn quick_ablation_orders_variants() {
        let ctx = ExpContext {
            quick: true,
            reps: 2,
            out_dir: std::env::temp_dir().join("energyucb_t2_test"),
            ..ExpContext::default()
        };
        let report = Table2.run(&ctx).unwrap();
        assert!(report.text.contains("w/o Opt. Ini."));
        // At least 2 of 3 apps should show full best-or-tied even in quick
        // mode (stochastic; full-mode numbers recorded in EXPERIMENTS.md).
        assert!(
            report.text.contains("on 2/3") || report.text.contains("on 3/3"),
            "{}",
            report.text
        );
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("energyucb_t2_test"));
    }
}
