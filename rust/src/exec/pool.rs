//! Bounded std::thread worker pool over an indexed cell space.
//!
//! Same no-new-deps pattern as `cluster::leader` (scoped std threads, no
//! rayon/tokio), but work-stealing by atomic index instead of fixed waves:
//! experiment cells vary in cost by orders of magnitude (a static lbm run
//! vs a DRLCap-Cross pretrain), so waves would leave cores idle behind the
//! slowest cell of each wave.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default (the machine's available
/// parallelism; 1 if it cannot be queried).
pub fn available_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Evaluate `f(0..n)` across at most `jobs` worker threads and return the
/// results **in index order** regardless of completion order.
///
/// `f` must be a pure function of the index (the executor's determinism
/// contract): with that, the output is identical for every `jobs` value.
/// `jobs <= 1` runs inline on the caller's thread with no pool at all —
/// the reference execution the parallel path must (and does) reproduce.
/// A panicking cell propagates the panic to the caller after the pool
/// drains, like the sequential loop would.
pub fn run_indexed<T, F>(jobs: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs = jobs.max(1).min(n.max(1));
    if jobs <= 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let collected: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i)));
                }
                // One lock per worker lifetime, not per cell.
                collected.lock().unwrap().extend(local);
            });
        }
    });

    let mut results = collected.into_inner().unwrap();
    results.sort_unstable_by_key(|(i, _)| *i);
    assert_eq!(results.len(), n, "worker pool lost cells");
    results.into_iter().map(|(_, v)| v).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        // Uneven cell costs force out-of-order completion.
        let out = run_indexed(4, 64, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i * 3
        });
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn identical_across_job_counts() {
        // A seeded-RNG cell function: pure in the index.
        let cell = |i: usize| {
            let mut rng = crate::util::Rng::new(1000 + i as u64);
            (0..100).map(|_| rng.uniform()).sum::<f64>()
        };
        let sequential = run_indexed(1, 40, cell);
        for jobs in [2, 3, 8, 64] {
            assert_eq!(run_indexed(jobs, 40, cell), sequential, "jobs={jobs}");
        }
    }

    #[test]
    fn handles_edge_sizes() {
        assert_eq!(run_indexed(8, 0, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(8, 1, |i| i + 1), vec![1]);
        assert_eq!(run_indexed(1, 3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn actually_runs_on_multiple_threads() {
        use std::collections::BTreeSet;
        use std::sync::Mutex;
        let ids: Mutex<BTreeSet<std::thread::ThreadId>> = Mutex::new(BTreeSet::new());
        run_indexed(4, 64, |_| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(ids.lock().unwrap().len() > 1, "pool never left the caller thread");
    }
}
