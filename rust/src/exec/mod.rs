//! Deterministic parallel experiment executor.
//!
//! Every paper experiment is a grid of independent *cells* — one
//! (app × method × seed) simulation whose result depends only on the cell's
//! coordinates. This module runs such grids across a bounded worker pool
//! and reduces the results in stable cell order, so experiment output
//! (tables, CSVs, JSON) is **byte-identical at `--jobs 1` and `--jobs N`**.
//!
//! The determinism contract (documented in EXPERIMENTS.md §Executor):
//!
//! 1. A cell is a pure function of its index: it derives its RNG/seed from
//!    the cell coordinates (never from shared mutable state) and performs
//!    no I/O. All file writes happen in the caller after the reduce.
//! 2. Scheduling only decides *when* a cell runs, never *what* it
//!    computes; results are re-ordered by cell index before any reduction.
//! 3. Reductions run sequentially in cell order on the caller's thread,
//!    so floating-point accumulation order is fixed — the reduce is the
//!    same arithmetic at every `--jobs` value. Variance aggregates use
//!    [`Welford::merge`] (parallel Welford / Chan et al.) in stable rep
//!    order.

pub mod grid;
pub mod pool;

pub use grid::{cell_rng, CellGrid};
pub use pool::{available_jobs, run_indexed};

use crate::util::stats::Welford;

/// Reduce a rep-major cell vector (`reps` consecutive values per group)
/// into one [`Welford`] accumulator per group, accumulating in stable rep
/// order. `values.len()` must be a multiple of `reps`. (Sharded partial
/// accumulators would combine with [`Welford::merge`]; with per-cell
/// scalars a sequential push in rep order is the same fixed-order
/// arithmetic, stated more directly.)
pub fn reduce_reps(values: &[f64], reps: usize) -> Vec<Welford> {
    assert!(reps > 0, "reduce_reps: reps must be > 0");
    assert_eq!(values.len() % reps, 0, "reduce_reps: ragged grid");
    values
        .chunks(reps)
        .map(|chunk| {
            let mut acc = Welford::new();
            for &x in chunk {
                acc.push(x);
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_reps_matches_sequential_welford() {
        let values: Vec<f64> = (0..12).map(|i| (i as f64).cos() * 5.0).collect();
        let reduced = reduce_reps(&values, 4);
        assert_eq!(reduced.len(), 3);
        for (g, w) in reduced.iter().enumerate() {
            let mut seq = Welford::new();
            for &x in &values[g * 4..(g + 1) * 4] {
                seq.push(x);
            }
            assert_eq!(w.count(), 4);
            assert_eq!(w.mean(), seq.mean());
            assert_eq!(w.sample_std(), seq.sample_std());
        }
    }

    #[test]
    #[should_panic]
    fn reduce_reps_rejects_ragged() {
        reduce_reps(&[1.0, 2.0, 3.0], 2);
    }
}
