//! Cell-grid indexing and per-cell RNG derivation.

use crate::util::rng::SplitMix64;
use crate::util::Rng;

/// A three-axis experiment grid: `rows × cols × reps`, flattened row-major
/// with the rep axis fastest. Rows/cols are whatever the experiment sweeps
/// (apps × methods, regimes × variants, ...); reps is the seed axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CellGrid {
    pub rows: usize,
    pub cols: usize,
    pub reps: usize,
}

impl CellGrid {
    pub fn new(rows: usize, cols: usize, reps: usize) -> CellGrid {
        CellGrid { rows, cols, reps }
    }

    /// Total number of cells.
    pub fn len(&self) -> usize {
        self.rows * self.cols * self.reps
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat index of `(row, col, rep)`.
    #[inline]
    pub fn pack(&self, row: usize, col: usize, rep: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols && rep < self.reps);
        (row * self.cols + col) * self.reps + rep
    }

    /// Inverse of [`Self::pack`].
    #[inline]
    pub fn unpack(&self, cell: usize) -> (usize, usize, usize) {
        debug_assert!(cell < self.len());
        let rep = cell % self.reps;
        let rc = cell / self.reps;
        (rc / self.cols, rc % self.cols, rep)
    }

    /// Flat index of the `(row, col)` group (rep axis collapsed) — the
    /// index into a [`super::reduce_reps`] output.
    #[inline]
    pub fn group(&self, row: usize, col: usize) -> usize {
        row * self.cols + col
    }
}

/// Derive an independent RNG stream for one cell, keyed by `(base, cell)`.
///
/// Unlike `Rng::fork`, which mutates a parent stream (and therefore depends
/// on fork *order*), this is a pure function of its arguments: every worker
/// can derive its cell's stream without coordination, and the stream is
/// identical at any `--jobs` value. Distinct cells get decorrelated streams
/// via SplitMix64 over the golden-ratio-scaled cell key (the same
/// construction `Rng::fork` uses internally).
pub fn cell_rng(base_seed: u64, cell: u64) -> Rng {
    let mut sm =
        SplitMix64::new(base_seed ^ cell.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1));
    Rng::new(sm.next_u64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let g = CellGrid::new(3, 4, 5);
        assert_eq!(g.len(), 60);
        let mut seen = std::collections::BTreeSet::new();
        for row in 0..3 {
            for col in 0..4 {
                for rep in 0..5 {
                    let cell = g.pack(row, col, rep);
                    assert_eq!(g.unpack(cell), (row, col, rep));
                    assert!(seen.insert(cell), "duplicate cell {cell}");
                }
            }
        }
        assert_eq!(*seen.iter().next().unwrap(), 0);
        assert_eq!(*seen.iter().last().unwrap(), 59);
    }

    #[test]
    fn rep_axis_is_fastest() {
        let g = CellGrid::new(2, 2, 3);
        assert_eq!(g.pack(0, 0, 0), 0);
        assert_eq!(g.pack(0, 0, 2), 2);
        assert_eq!(g.pack(0, 1, 0), 3);
        assert_eq!(g.pack(1, 0, 0), 6);
        assert_eq!(g.group(1, 1), 3);
    }

    #[test]
    fn cell_rng_is_pure_and_decorrelated() {
        let a1: Vec<u64> = {
            let mut r = cell_rng(42, 7);
            (0..32).map(|_| r.next_u64()).collect()
        };
        let a2: Vec<u64> = {
            let mut r = cell_rng(42, 7);
            (0..32).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a1, a2, "not pure in (base, cell)");
        let b: Vec<u64> = {
            let mut r = cell_rng(42, 8);
            (0..32).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a1, b, "adjacent cells correlated");
        let c: Vec<u64> = {
            let mut r = cell_rng(43, 7);
            (0..32).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a1, c, "base seed ignored");
    }
}
