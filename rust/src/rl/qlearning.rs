//! RL-Power baseline (paper §4.1): online tabular Q-learning, adapted from
//! CPU power capping to GPU frequency control.
//!
//! We retain the original's learning and decision mechanism — a tabular
//! Q(s, a) over a discretized counter-derived state with ε-greedy
//! exploration — and restrict the action space to the GPU frequency arms.
//! The state is (current arm, reward-level bucket), both derived from the
//! same counter stream the bandits see.

use crate::bandit::Policy;
use crate::util::Rng;

/// Number of reward buckets in the state discretization.
const REWARD_BUCKETS: usize = 6;
/// Normalized-reward range mapped onto the buckets.
const R_LO: f64 = -1.5;
const R_HI: f64 = -0.5;

#[derive(Clone, Debug)]
pub struct RlPower {
    k: usize,
    /// Q-table: state-major, `q[state * k + action]`.
    q: Vec<f64>,
    lr: f64,
    gamma: f64,
    eps0: f64,
    eps_decay: f64,
    state: usize,
    last_action: Option<usize>,
    t: u64,
    rng: Rng,
    /// Construction seed, so `reset()` restores fresh-run behavior
    /// byte-for-byte (the policy-contract suite pins this).
    seed: u64,
}

impl RlPower {
    pub fn new(k: usize, seed: u64) -> RlPower {
        RlPower {
            k,
            q: vec![0.0; k * REWARD_BUCKETS * k],
            lr: 0.15,
            gamma: 0.9,
            eps0: 0.3,
            eps_decay: 400.0,
            state: 0,
            last_action: None,
            t: 0,
            rng: Rng::new(seed),
            seed,
        }
    }

    fn n_states(&self) -> usize {
        self.k * REWARD_BUCKETS
    }

    fn bucket(reward: f64) -> usize {
        let x = ((reward - R_LO) / (R_HI - R_LO)).clamp(0.0, 1.0 - 1e-9);
        (x * REWARD_BUCKETS as f64) as usize
    }

    fn encode(&self, arm: usize, reward: f64) -> usize {
        arm * REWARD_BUCKETS + Self::bucket(reward)
    }

    fn epsilon(&self) -> f64 {
        self.eps0.min(self.eps_decay / self.t.max(1) as f64).max(0.02)
    }

    fn greedy(&self, state: usize) -> usize {
        let row = &self.q[state * self.k..(state + 1) * self.k];
        crate::util::stats::argmax(&row.to_vec())
    }

    /// Max Q over actions in `state`.
    fn max_q(&self, state: usize) -> f64 {
        let row = &self.q[state * self.k..(state + 1) * self.k];
        row.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

impl Policy for RlPower {
    fn name(&self) -> String {
        "RL-Power".into()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select(&mut self, t: u64) -> usize {
        self.t = t;
        if self.rng.chance(self.epsilon()) {
            self.rng.index(self.k)
        } else {
            self.greedy(self.state)
        }
    }

    fn update(&mut self, arm: usize, reward: f64, _progress: f64) {
        let next_state = self.encode(arm, reward);
        debug_assert!(next_state < self.n_states());
        // Q(s, a) += lr * (r + γ max_a' Q(s', a') − Q(s, a)).
        let idx = self.state * self.k + arm;
        let target = reward + self.gamma * self.max_q(next_state);
        self.q[idx] += self.lr * (target - self.q[idx]);
        self.state = next_state;
        self.last_action = Some(arm);
    }

    fn reset(&mut self) {
        self.q.iter_mut().for_each(|x| *x = 0.0);
        self.state = 0;
        self.last_action = None;
        self.t = 0;
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bucket_edges() {
        assert_eq!(RlPower::bucket(-2.0), 0);
        assert_eq!(RlPower::bucket(-1.5), 0);
        assert_eq!(RlPower::bucket(-0.5), REWARD_BUCKETS - 1);
        assert_eq!(RlPower::bucket(0.0), REWARD_BUCKETS - 1);
        assert!(RlPower::bucket(-1.0) < REWARD_BUCKETS);
    }

    #[test]
    fn epsilon_decays_but_floors() {
        let mut p = RlPower::new(9, 1);
        p.t = 1;
        let e1 = p.epsilon();
        p.t = 100_000;
        let e2 = p.epsilon();
        assert!(e1 > e2);
        assert!(e2 >= 0.02);
    }

    #[test]
    fn learns_stationary_optimum_eventually() {
        // Stationary bandit-like environment (state barely matters).
        let means = [-1.3, -1.0, -1.2];
        let mut p = RlPower::new(3, 2);
        let mut rng = Rng::new(7);
        let mut late_pulls = [0u64; 3];
        for t in 1..=20_000u64 {
            let arm = p.select(t);
            let r = rng.normal(means[arm], 0.05);
            p.update(arm, r, 0.0);
            if t > 15_000 {
                late_pulls[arm] += 1;
            }
        }
        // Converges more slowly than the bandits, but the best arm should
        // dominate late decisions.
        assert!(
            late_pulls[1] > late_pulls[0] && late_pulls[1] > late_pulls[2],
            "{late_pulls:?}"
        );
    }

    #[test]
    fn reset_zeroes_q() {
        let mut p = RlPower::new(3, 3);
        p.update(1, -1.0, 0.0);
        assert!(p.q.iter().any(|&v| v != 0.0));
        p.reset();
        assert!(p.q.iter().all(|&v| v == 0.0));
    }
}
