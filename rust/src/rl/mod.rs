//! Reinforcement-learning baselines (paper §4.1): RL-Power (tabular
//! Q-learning, adapted from CPU power capping) and DRLCap (deep RL with the
//! pretrain/online/cross evaluation protocol), plus the from-scratch
//! neural-net and replay-buffer substrates they need.

pub mod drlcap;
pub mod nn;
pub mod qlearning;
pub mod replay;

pub use drlcap::{DrlCap, DrlCapMode};
pub use nn::Mlp;
pub use qlearning::RlPower;
pub use replay::{ReplayBuffer, Transition};
