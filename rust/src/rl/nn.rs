//! From-scratch tiny neural network substrate for the DRL baselines.
//!
//! A two-layer MLP (tanh hidden) with plain SGD, just enough to reimplement
//! DRLCap's Q-network. No external linear-algebra crates are available
//! offline, so weights are flat `Vec<f64>`s and the backward pass is
//! hand-derived.

use crate::util::Rng;

/// Fully-connected layer y = W x + b.
#[derive(Clone, Debug)]
struct Dense {
    w: Vec<f64>, // out × in, row-major
    b: Vec<f64>,
    n_in: usize,
    n_out: usize,
}

impl Dense {
    fn new(n_in: usize, n_out: usize, rng: &mut Rng) -> Dense {
        // Xavier-ish init.
        let scale = (2.0 / (n_in + n_out) as f64).sqrt();
        let w = (0..n_in * n_out).map(|_| rng.normal(0.0, scale)).collect();
        Dense { w, b: vec![0.0; n_out], n_in, n_out }
    }

    fn forward(&self, x: &[f64], out: &mut Vec<f64>) {
        debug_assert_eq!(x.len(), self.n_in);
        out.clear();
        for o in 0..self.n_out {
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let mut acc = self.b[o];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out.push(acc);
        }
    }
}

/// Two-layer MLP: in → hidden (tanh) → out (linear).
#[derive(Clone, Debug)]
pub struct Mlp {
    l1: Dense,
    l2: Dense,
    /// Scratch buffers reused across calls (no allocation on the hot path).
    h_pre: Vec<f64>,
    h: Vec<f64>,
    out: Vec<f64>,
}

impl Mlp {
    pub fn new(n_in: usize, n_hidden: usize, n_out: usize, seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        Mlp {
            l1: Dense::new(n_in, n_hidden, &mut rng),
            l2: Dense::new(n_hidden, n_out, &mut rng),
            h_pre: Vec::with_capacity(n_hidden),
            h: Vec::with_capacity(n_hidden),
            out: Vec::with_capacity(n_out),
        }
    }

    pub fn n_in(&self) -> usize {
        self.l1.n_in
    }

    pub fn n_out(&self) -> usize {
        self.l2.n_out
    }

    /// Forward pass; the returned slice is valid until the next call.
    pub fn forward(&mut self, x: &[f64]) -> &[f64] {
        self.l1.forward(x, &mut self.h_pre);
        self.h.clear();
        self.h.extend(self.h_pre.iter().map(|v| v.tanh()));
        let (l2, h, out) = (&self.l2, &self.h, &mut self.out);
        l2.forward(h, out);
        &self.out
    }

    /// One SGD step on the squared error of output unit `target_idx`
    /// against `target`, for input `x`. Returns the pre-update prediction.
    ///
    /// This is the Q-learning update: only the selected action's head
    /// receives gradient.
    pub fn sgd_step(&mut self, x: &[f64], target_idx: usize, target: f64, lr: f64) -> f64 {
        let pred = {
            let out = self.forward(x);
            out[target_idx]
        };
        let err = pred - target; // dL/dpred for L = (pred-target)^2 / 2
        // Grad through l2 (only row target_idx active).
        let n_h = self.h.len();
        let row_start = target_idx * n_h;
        // dL/dh before l2 weights update.
        let mut dh: Vec<f64> = (0..n_h)
            .map(|j| err * self.l2.w[row_start + j])
            .collect();
        // Update l2.
        for j in 0..n_h {
            self.l2.w[row_start + j] -= lr * err * self.h[j];
        }
        self.l2.b[target_idx] -= lr * err;
        // Through tanh.
        for j in 0..n_h {
            dh[j] *= 1.0 - self.h[j] * self.h[j];
        }
        // Update l1.
        let n_in = self.l1.n_in;
        for j in 0..n_h {
            let row = &mut self.l1.w[j * n_in..(j + 1) * n_in];
            for (wi, xi) in row.iter_mut().zip(x) {
                *wi -= lr * dh[j] * xi;
            }
            self.l1.b[j] -= lr * dh[j];
        }
        pred
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn forward_shapes() {
        let mut m = Mlp::new(4, 8, 3, 1);
        let y = m.forward(&[0.1, -0.2, 0.3, 0.0]);
        assert_eq!(y.len(), 3);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic_init() {
        let mut a = Mlp::new(4, 8, 3, 7);
        let mut b = Mlp::new(4, 8, 3, 7);
        let x = [0.5, 0.5, -0.5, 1.0];
        assert_eq!(a.forward(&x).to_vec(), b.forward(&x).to_vec());
    }

    #[test]
    fn learns_a_linear_target() {
        // Fit y0 = 2*x0 - x1 on one output head.
        let mut m = Mlp::new(2, 16, 2, 3);
        let mut rng = Rng::new(11);
        for _ in 0..4000 {
            let x = [rng.uniform_range(-1.0, 1.0), rng.uniform_range(-1.0, 1.0)];
            let y = 2.0 * x[0] - x[1];
            m.sgd_step(&x, 0, y, 0.02);
        }
        let mut mse = 0.0;
        let n = 200;
        for _ in 0..n {
            let x = [rng.uniform_range(-1.0, 1.0), rng.uniform_range(-1.0, 1.0)];
            let y = 2.0 * x[0] - x[1];
            let pred = m.forward(&x)[0];
            mse += (pred - y) * (pred - y);
        }
        mse /= n as f64;
        assert!(mse < 0.02, "mse={mse}");
    }

    #[test]
    fn only_selected_head_learns() {
        let mut m = Mlp::new(2, 8, 2, 5);
        let x = [0.3, -0.7];
        let before1 = m.forward(&x)[1];
        for _ in 0..50 {
            m.sgd_step(&x, 0, 5.0, 0.05);
        }
        let after = m.forward(&x);
        // Head 0 moved toward 5, head 1 moved much less (only via shared
        // hidden layer).
        assert!((after[0] - 5.0).abs() < 1.0, "{}", after[0]);
        assert!((after[1] - before1).abs() < 2.0);
    }

    #[test]
    fn sgd_returns_pre_update_prediction() {
        let mut m = Mlp::new(2, 4, 1, 9);
        let x = [0.1, 0.2];
        let direct = m.forward(&x)[0];
        let reported = m.sgd_step(&x, 0, 1.0, 0.01);
        assert!((direct - reported).abs() < 1e-12);
    }
}
