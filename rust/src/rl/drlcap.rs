//! DRLCap baseline (paper §4.1): deep-RL GPU frequency capping, plus the
//! paper's two variants.
//!
//! The Q-network is a tiny MLP over counter-derived features; training uses
//! an experience-replay buffer. The paper's evaluation protocol:
//!
//! * **DRLCap** — trains during the first 20 % of each execution, then
//!   deploys the learned policy greedily (the harness scales the remaining
//!   80 %'s energy by 1.25× for fairness vs fully-online methods);
//! * **DRLCap-Online** — learns online for the whole run;
//! * **DRLCap-Cross** — pre-trained on *other* benchmarks, deployed (with
//!   frozen weights) on the target.

use super::nn::Mlp;
use super::replay::{ReplayBuffer, Transition};
use crate::bandit::Policy;
use crate::util::stats::Ema;
use crate::util::Rng;

/// Operating mode (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrlCapMode {
    /// Train for the first `train progress <= 0.2`, deploy greedily after.
    PretrainDeploy,
    /// Learn online for the whole execution.
    Online,
    /// Frozen pre-trained network (use [`DrlCap::pretrain_on`] first).
    CrossDeploy,
}

const HIDDEN: usize = 24;
const BATCH: usize = 8;
const REPLAY_CAP: usize = 512;
/// Train every Nth transition (amortizes the replay sweep; DQN-style
/// update-to-data ratio < 1).
const TRAIN_EVERY: u64 = 4;

#[derive(Clone, Debug)]
pub struct DrlCap {
    k: usize,
    mode: DrlCapMode,
    net: Mlp,
    replay: ReplayBuffer,
    gamma: f64,
    lr: f64,
    eps0: f64,
    /// Cumulative application progress (defines the 20 % boundary).
    progress_done: f64,
    train_frac: f64,
    reward_ema: Ema,
    last_state: Option<Vec<f64>>,
    last_action: Option<usize>,
    frozen: bool,
    t: u64,
    rng: Rng,
}

impl DrlCap {
    pub fn new(k: usize, mode: DrlCapMode, seed: u64) -> DrlCap {
        DrlCap {
            k,
            mode,
            net: Mlp::new(Self::n_features(k), HIDDEN, k, seed ^ 0xD8_1C4B),
            replay: ReplayBuffer::new(REPLAY_CAP),
            gamma: 0.9,
            lr: 0.01,
            eps0: 0.25,
            progress_done: 0.0,
            train_frac: 0.2,
            reward_ema: Ema::new(0.05),
            last_state: None,
            last_action: None,
            frozen: mode == DrlCapMode::CrossDeploy,
            t: 0,
            rng: Rng::new(seed),
        }
    }

    fn n_features(k: usize) -> usize {
        // one-hot arm + [reward, reward_ema, progress_rate, t_frac]
        k + 4
    }

    /// Whether the policy is currently learning.
    pub fn training(&self) -> bool {
        match self.mode {
            DrlCapMode::Online => true,
            DrlCapMode::PretrainDeploy => self.progress_done < self.train_frac,
            DrlCapMode::CrossDeploy => !self.frozen,
        }
    }

    pub fn mode(&self) -> DrlCapMode {
        self.mode
    }

    /// The fraction of progress used for training (the 20 % boundary).
    pub fn train_frac(&self) -> f64 {
        self.train_frac
    }

    fn features(&self, arm: usize, reward: f64, progress: f64) -> Vec<f64> {
        let mut f = vec![0.0; Self::n_features(self.k)];
        f[arm] = 1.0;
        f[self.k] = reward;
        f[self.k + 1] = self.reward_ema.value().unwrap_or(reward);
        f[self.k + 2] = progress * 1e3; // per-10ms progress, rescaled O(1)
        f[self.k + 3] = (self.t as f64 / 10_000.0).min(1.0);
        f
    }

    fn epsilon(&self) -> f64 {
        if !self.training() {
            return 0.0;
        }
        // Fully-online DQN needs sustained exploration to keep the value
        // estimates honest without any pre-training (the paper's
        // DRLCap-Online converges slowest); the pretrain window can anneal
        // harder because deployment is greedy afterwards.
        let floor = match self.mode {
            DrlCapMode::Online => 0.2,
            _ => 0.05,
        };
        self.eps0.min(300.0 / self.t.max(1) as f64).max(floor)
    }

    fn greedy(&mut self, state: &[f64]) -> usize {
        let q = self.net.forward(state);
        crate::util::stats::argmax(&q.to_vec())
    }

    fn train_batch(&mut self) {
        if self.replay.len() < BATCH {
            return;
        }
        // Sample indices first (borrow discipline), then train.
        let samples: Vec<Transition> = self
            .replay
            .sample(BATCH, &mut self.rng)
            .into_iter()
            .cloned()
            .collect();
        for tr in samples {
            let max_next = {
                let q = self.net.forward(&tr.next_state);
                q.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            };
            let target = tr.reward + self.gamma * max_next;
            self.net.sgd_step(&tr.state, tr.action, target, self.lr);
        }
    }

    /// Pre-train on transitions from other benchmarks (DRLCap-Cross).
    /// `episodes` is a list of (state, action, reward, next_state) streams.
    pub fn pretrain_on(&mut self, transitions: &[Transition], passes: usize) {
        self.frozen = false;
        for _ in 0..passes {
            for tr in transitions {
                self.replay.push(tr.clone());
                self.train_batch();
            }
        }
        self.frozen = true;
    }

    /// Export the replay contents (used to feed Cross pre-training).
    pub fn replay_snapshot(&self) -> Vec<Transition> {
        let mut out = Vec::new();
        let mut rng = Rng::new(0xC0FFEE);
        if self.replay.is_empty() {
            return out;
        }
        for tr in self.replay.sample(self.replay.len(), &mut rng) {
            out.push(tr.clone());
        }
        out
    }
}

impl Policy for DrlCap {
    fn name(&self) -> String {
        match self.mode {
            DrlCapMode::PretrainDeploy => "DRLCap".into(),
            DrlCapMode::Online => "DRLCap-Online".into(),
            DrlCapMode::CrossDeploy => "DRLCap-Cross".into(),
        }
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select(&mut self, t: u64) -> usize {
        self.t = t;
        let state = match &self.last_state {
            Some(s) => s.clone(),
            // Cold start: begin from the default max frequency's context.
            None => self.features(self.k - 1, -1.0, 0.0),
        };
        if self.rng.chance(self.epsilon()) {
            self.rng.index(self.k)
        } else {
            self.greedy(&state)
        }
    }

    fn update(&mut self, arm: usize, reward: f64, progress: f64) {
        self.reward_ema.push(reward);
        self.progress_done += progress;
        let next_state = self.features(arm, reward, progress);
        if let (Some(state), Some(_)) = (&self.last_state, &self.last_action) {
            if self.training() {
                self.replay.push(Transition {
                    state: state.clone(),
                    action: arm,
                    reward,
                    next_state: next_state.clone(),
                });
                if self.t % TRAIN_EVERY == 0 {
                    self.train_batch();
                }
            }
        }
        self.last_state = Some(next_state);
        self.last_action = Some(arm);
    }

    fn reset(&mut self) {
        // Keep the network for CrossDeploy (that's the whole point);
        // otherwise re-init.
        if self.mode != DrlCapMode::CrossDeploy {
            self.net = Mlp::new(Self::n_features(self.k), HIDDEN, self.k, 0xD8_1C4B);
            self.replay.clear();
        }
        self.progress_done = 0.0;
        self.reward_ema = Ema::new(0.05);
        self.last_state = None;
        self.last_action = None;
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn mode_controls_training_window() {
        let mut p = DrlCap::new(9, DrlCapMode::PretrainDeploy, 1);
        assert!(p.training());
        p.progress_done = 0.25;
        assert!(!p.training());
        let p = DrlCap::new(9, DrlCapMode::Online, 1);
        assert!(p.training());
    }

    #[test]
    fn greedy_after_training_window() {
        let mut p = DrlCap::new(9, DrlCapMode::PretrainDeploy, 2);
        p.progress_done = 0.5;
        p.t = 10_000;
        assert_eq!(p.epsilon(), 0.0);
    }

    #[test]
    fn learns_to_prefer_good_arm_online() {
        let means = [-1.4, -1.0, -1.3];
        let mut p = DrlCap::new(3, DrlCapMode::Online, 3);
        let mut rng = Rng::new(8);
        let mut late = [0u64; 3];
        for t in 1..=8000u64 {
            let arm = p.select(t);
            let r = rng.normal(means[arm], 0.05);
            p.update(arm, r, 1e-4);
            if t > 6000 {
                late[arm] += 1;
            }
        }
        assert!(late[1] > late[0] && late[1] > late[2], "{late:?}");
    }

    #[test]
    fn cross_deploy_keeps_frozen_weights() {
        let mut donor = DrlCap::new(3, DrlCapMode::Online, 4);
        let mut rng = Rng::new(9);
        for t in 1..=1000u64 {
            let arm = donor.select(t);
            donor.update(arm, rng.normal(-1.0, 0.05), 1e-4);
        }
        let transitions = donor.replay_snapshot();
        assert!(!transitions.is_empty());
        let mut cross = DrlCap::new(3, DrlCapMode::CrossDeploy, 5);
        cross.pretrain_on(&transitions, 2);
        assert!(!cross.training());
        // Updates must not change the network while frozen.
        let state = cross.features(0, -1.0, 1e-4);
        let q_before = {
            let mut c = cross.clone();
            c.net.forward(&state).to_vec()
        };
        for t in 1..=50u64 {
            let arm = cross.select(t);
            cross.update(arm, -1.0, 1e-4);
        }
        let q_after = {
            let mut c = cross.clone();
            c.net.forward(&state).to_vec()
        };
        assert_eq!(q_before, q_after);
    }

    #[test]
    fn reset_restores_cold_start() {
        let mut p = DrlCap::new(3, DrlCapMode::Online, 6);
        p.update(1, -1.0, 0.1);
        p.reset();
        assert_eq!(p.progress_done, 0.0);
        assert!(p.last_state.is_none());
    }
}
