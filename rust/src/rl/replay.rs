//! Fixed-capacity experience replay buffer for the DRL baselines.

use crate::util::Rng;

/// One transition (s, a, r, s').
#[derive(Clone, Debug, PartialEq)]
pub struct Transition {
    pub state: Vec<f64>,
    pub action: usize,
    pub reward: f64,
    pub next_state: Vec<f64>,
}

/// Ring-buffer replay memory with uniform sampling.
#[derive(Clone, Debug)]
pub struct ReplayBuffer {
    cap: usize,
    buf: Vec<Transition>,
    head: usize,
}

impl ReplayBuffer {
    pub fn new(cap: usize) -> ReplayBuffer {
        assert!(cap > 0);
        ReplayBuffer { cap, buf: Vec::with_capacity(cap), head: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.cap {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.cap;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Sample `n` transitions uniformly with replacement.
    pub fn sample<'a>(&'a self, n: usize, rng: &mut Rng) -> Vec<&'a Transition> {
        assert!(!self.buf.is_empty());
        (0..n).map(|_| &self.buf[rng.index(self.buf.len())]).collect()
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: f64) -> Transition {
        Transition { state: vec![v], action: 0, reward: v, next_state: vec![v] }
    }

    #[test]
    fn fills_then_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(3);
        for i in 0..5 {
            rb.push(t(i as f64));
        }
        assert_eq!(rb.len(), 3);
        // 0 and 1 evicted; contents are {2, 3, 4} in some order.
        let rewards: Vec<f64> = rb.buf.iter().map(|x| x.reward).collect();
        for v in [2.0, 3.0, 4.0] {
            assert!(rewards.contains(&v), "{rewards:?}");
        }
    }

    #[test]
    fn sampling_covers_buffer() {
        let mut rb = ReplayBuffer::new(10);
        for i in 0..10 {
            rb.push(t(i as f64));
        }
        let mut rng = Rng::new(1);
        let samples = rb.sample(1000, &mut rng);
        let distinct: std::collections::HashSet<u64> =
            samples.iter().map(|s| s.reward as u64).collect();
        assert!(distinct.len() >= 9, "{distinct:?}");
    }

    #[test]
    fn clear_empties() {
        let mut rb = ReplayBuffer::new(3);
        rb.push(t(1.0));
        rb.clear();
        assert!(rb.is_empty());
    }
}
