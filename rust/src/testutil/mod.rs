//! Test-support substrates (property-testing mini-framework).

pub mod proptest_lite;

pub use proptest_lite::{forall, forall_seeded, gens, Gen};
