//! Property-based testing mini-framework (proptest is not in the offline
//! crate set). Provides seeded generators, a `forall` runner with failure
//! reporting, and greedy shrinking for a few common shapes.
//!
//! Usage:
//! ```ignore
//! forall(100, gens::vec_f64(-2.0, 0.0, 1..=9), |xs| {
//!     let i = argmax(xs);
//!     xs.iter().all(|x| xs[i] >= *x)
//! });
//! ```

use crate::util::Rng;

/// A seeded value generator with an optional shrinker.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate simpler values (tried in order during shrinking).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run `cases` random cases of `prop` over `gen`; on failure, greedily
/// shrink and panic with the minimal counterexample.
///
/// The case count can be overridden globally through the `PROPTEST_CASES`
/// environment variable (the CI deep-run leg sets `PROPTEST_CASES=500`).
pub fn forall<G: Gen>(cases: usize, gen: G, prop: impl Fn(&G::Value) -> bool) {
    forall_seeded(0xEC0_57A7E, cases, gen, prop)
}

/// Case count after applying the `PROPTEST_CASES` environment override.
pub fn case_count(default_cases: usize) -> usize {
    case_count_from(std::env::var("PROPTEST_CASES").ok().as_deref(), default_cases)
}

fn case_count_from(var: Option<&str>, default_cases: usize) -> usize {
    var.and_then(|s| s.parse::<usize>().ok()).filter(|n| *n > 0).unwrap_or(default_cases)
}

/// `forall` with an explicit base seed (deterministic). On failure the
/// panic message carries the replay seed: re-run the same property locally
/// with `forall_seeded(<seed>, ...)` to reproduce a CI counterexample.
pub fn forall_seeded<G: Gen>(
    seed: u64,
    cases: usize,
    gen: G,
    prop: impl Fn(&G::Value) -> bool,
) {
    let cases = case_count(cases);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            let minimal = shrink_loop(&gen, value, &prop);
            panic!(
                "property falsified (case {case}/{cases})\n\
                 replay seed: {seed:#x} — rerun with forall_seeded({seed:#x}, ...)\n\
                 minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut failing: G::Value,
    prop: &impl Fn(&G::Value) -> bool,
) -> G::Value {
    // Greedy: keep taking the first shrink candidate that still fails.
    'outer: for _ in 0..1_000 {
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

/// Stock generators.
pub mod gens {
    use super::Gen;
    use crate::util::Rng;

    /// Uniform f64 in [lo, hi); shrinks toward lo and 0.
    pub struct F64 {
        pub lo: f64,
        pub hi: f64,
    }

    impl Gen for F64 {
        type Value = f64;
        fn generate(&self, rng: &mut Rng) -> f64 {
            rng.uniform_range(self.lo, self.hi)
        }
        fn shrink(&self, v: &f64) -> Vec<f64> {
            let mut out = Vec::new();
            if *v != self.lo {
                out.push(self.lo);
                out.push(self.lo + (*v - self.lo) / 2.0);
            }
            if self.lo <= 0.0 && 0.0 < *v {
                out.push(0.0);
            }
            out
        }
    }

    /// Uniform usize in [lo, hi]; shrinks toward lo.
    pub struct USize {
        pub lo: usize,
        pub hi: usize,
    }

    impl Gen for USize {
        type Value = usize;
        fn generate(&self, rng: &mut Rng) -> usize {
            self.lo + rng.index(self.hi - self.lo + 1)
        }
        fn shrink(&self, v: &usize) -> Vec<usize> {
            let mut out = Vec::new();
            if *v > self.lo {
                out.push(self.lo);
                out.push(self.lo + (*v - self.lo) / 2);
            }
            out
        }
    }

    /// Vec of f64 with length in a range; shrinks by halving length, then
    /// element-wise toward lo.
    pub struct VecF64 {
        pub lo: f64,
        pub hi: f64,
        pub min_len: usize,
        pub max_len: usize,
    }

    impl Gen for VecF64 {
        type Value = Vec<f64>;
        fn generate(&self, rng: &mut Rng) -> Vec<f64> {
            let len = self.min_len + rng.index(self.max_len - self.min_len + 1);
            (0..len).map(|_| rng.uniform_range(self.lo, self.hi)).collect()
        }
        fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
            let mut out = Vec::new();
            if v.len() > self.min_len {
                let shorter: Vec<f64> =
                    v[..self.min_len.max(v.len() / 2)].to_vec();
                out.push(shorter);
            }
            // Zero out one element at a time.
            for i in 0..v.len() {
                if v[i] != self.lo {
                    let mut w = v.clone();
                    w[i] = self.lo;
                    out.push(w);
                }
            }
            out
        }
    }

    /// Vec of usize with length in a range; shrinks by halving length,
    /// then per-element toward `lo` (first jump-to-lo, then halving the
    /// distance, so single-element minima are found).
    pub struct VecUSize {
        pub lo: usize,
        pub hi: usize,
        pub min_len: usize,
        pub max_len: usize,
    }

    impl Gen for VecUSize {
        type Value = Vec<usize>;
        fn generate(&self, rng: &mut Rng) -> Vec<usize> {
            let len = self.min_len + rng.index(self.max_len - self.min_len + 1);
            (0..len).map(|_| self.lo + rng.index(self.hi - self.lo + 1)).collect()
        }
        fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
            let mut out = Vec::new();
            if v.len() > self.min_len {
                out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
                // Drop one element at a time (catches order-dependent bugs
                // that length-halving jumps over).
                for i in 0..v.len() {
                    let mut w = v.clone();
                    w.remove(i);
                    out.push(w);
                }
            }
            // Per-element shrinking toward lo.
            for i in 0..v.len() {
                if v[i] > self.lo {
                    let mut w = v.clone();
                    w[i] = self.lo;
                    out.push(w);
                    let mut w = v.clone();
                    w[i] = self.lo + (v[i] - self.lo) / 2;
                    out.push(w);
                }
            }
            out
        }
    }

    /// Optional value: `None` about a quarter of the time; shrinks toward
    /// `None` first, then through the inner generator's shrinks.
    pub struct OptionOf<G>(pub G);

    impl<G: Gen> Gen for OptionOf<G> {
        type Value = Option<G::Value>;
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            if rng.index(4) == 0 {
                None
            } else {
                Some(self.0.generate(rng))
            }
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            match v {
                None => Vec::new(),
                Some(inner) => std::iter::once(None)
                    .chain(self.0.shrink(inner).into_iter().map(Some))
                    .collect(),
            }
        }
    }

    /// Uniform choice from a fixed list of values; shrinks toward earlier
    /// list positions (order the list simplest-first).
    pub struct OneOf<T: Clone + std::fmt::Debug + PartialEq>(pub Vec<T>);

    impl<T: Clone + std::fmt::Debug + PartialEq> Gen for OneOf<T> {
        type Value = T;
        fn generate(&self, rng: &mut Rng) -> T {
            assert!(!self.0.is_empty(), "OneOf: empty choice list");
            self.0[rng.index(self.0.len())].clone()
        }
        fn shrink(&self, v: &T) -> Vec<T> {
            match self.0.iter().position(|x| x == v) {
                Some(pos) => self.0[..pos].to_vec(),
                None => Vec::new(),
            }
        }
    }

    /// Pair of independent generators.
    pub struct Pair<A, B>(pub A, pub B);

    impl<A: Gen, B: Gen> Gen for Pair<A, B> {
        type Value = (A::Value, B::Value);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (self.0.generate(rng), self.1.generate(rng))
        }
        fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
            let mut out: Vec<Self::Value> =
                self.0.shrink(a).into_iter().map(|a2| (a2, b.clone())).collect();
            out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::gens::*;
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(200, F64 { lo: 0.0, hi: 1.0 }, |x| *x >= 0.0 && *x < 1.0);
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn failing_property_panics_with_counterexample() {
        forall(200, F64 { lo: 0.0, hi: 1.0 }, |x| *x < 0.5);
    }

    #[test]
    fn shrinking_minimizes_vec() {
        // Capture the panic message and check the counterexample shrank.
        let result = std::panic::catch_unwind(|| {
            forall(
                100,
                VecF64 { lo: 0.0, hi: 1.0, min_len: 1, max_len: 16 },
                |xs| xs.iter().sum::<f64>() < 3.0,
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // Minimal failing vec should be short (shrunk from up to 16 elems).
        let len = msg.matches(',').count() + 1;
        assert!(len <= 8, "weak shrink: {msg}");
    }

    #[test]
    fn pair_generator_composes() {
        forall(
            100,
            Pair(USize { lo: 1, hi: 9 }, F64 { lo: -1.0, hi: 0.0 }),
            |(k, x)| *k >= 1 && *x <= 0.0,
        );
    }

    #[test]
    fn vec_usize_respects_bounds() {
        forall(200, VecUSize { lo: 2, hi: 9, min_len: 1, max_len: 6 }, |xs| {
            (1..=6).contains(&xs.len()) && xs.iter().all(|x| (2..=9).contains(x))
        });
    }

    #[test]
    fn vec_usize_shrinks_per_element() {
        // Falsify "no element equals 7" by greedy shrinking from a fixed
        // failing input; the minimum is exactly [7] — every other element
        // removed, and the offending element itself not shrunk past the
        // boundary (per-element shrinking must preserve failure).
        let g = VecUSize { lo: 0, hi: 9, min_len: 1, max_len: 8 };
        let prop = |xs: &Vec<usize>| !xs.contains(&7);
        let mut failing = vec![3, 7, 2, 9];
        'outer: loop {
            for cand in g.shrink(&failing) {
                if !prop(&cand) {
                    failing = cand;
                    continue 'outer;
                }
            }
            break;
        }
        assert_eq!(failing, vec![7]);
    }

    #[test]
    fn failure_message_prints_replay_seed() {
        let result = std::panic::catch_unwind(|| {
            // Fails on the very first case at any PROPTEST_CASES value.
            forall_seeded(0xBAD_5EED, 50, VecUSize { lo: 0, hi: 9, min_len: 1, max_len: 8 }, |_| {
                false
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("replay seed: 0xbad5eed"), "missing replay seed: {msg}");
        // Always-false property shrinks to the simplest value: [lo].
        assert!(msg.contains("[0]"), "weak shrink: {msg}");
    }

    #[test]
    fn option_of_generates_both_variants_and_shrinks_to_none() {
        let g = OptionOf(USize { lo: 1, hi: 5 });
        let mut rng = Rng::new(11);
        let mut nones = 0;
        let mut somes = 0;
        for _ in 0..200 {
            match g.generate(&mut rng) {
                None => nones += 1,
                Some(v) => {
                    assert!((1..=5).contains(&v));
                    somes += 1;
                }
            }
        }
        assert!(nones > 10 && somes > 100, "nones={nones} somes={somes}");
        assert_eq!(g.shrink(&Some(4))[0], None);
        assert!(g.shrink(&Some(4)).contains(&Some(1)));
        assert!(g.shrink(&None).is_empty());
    }

    #[test]
    fn one_of_picks_from_list_and_shrinks_to_earlier() {
        let g = OneOf(vec![1usize, 2, 7, 64]);
        forall(100, OneOf(vec![1usize, 2, 7, 64]), |v| [1, 2, 7, 64].contains(v));
        assert_eq!(g.shrink(&7), vec![1, 2]);
        assert!(g.shrink(&1).is_empty());
    }

    #[test]
    fn proptest_cases_override_parses() {
        // The pure half of the env override (mutating the real process env
        // here would race parallel tests; the CI deep leg exercises the
        // env-var path end to end with PROPTEST_CASES=500).
        assert_eq!(super::case_count_from(Some("500"), 100), 500);
        assert_eq!(super::case_count_from(Some("3"), 100), 3);
        assert_eq!(super::case_count_from(Some("0"), 100), 100);
        assert_eq!(super::case_count_from(Some("junk"), 100), 100);
        assert_eq!(super::case_count_from(None, 100), 100);
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        let g = F64 { lo: 0.0, hi: 1.0 };
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        for _ in 0..10 {
            a.push(g.generate(&mut r1));
            b.push(g.generate(&mut r2));
        }
        assert_eq!(a, b);
    }
}
