//! Property-based testing mini-framework (proptest is not in the offline
//! crate set). Provides seeded generators, a `forall` runner with failure
//! reporting, and greedy shrinking for a few common shapes.
//!
//! Usage:
//! ```ignore
//! forall(100, gens::vec_f64(-2.0, 0.0, 1..=9), |xs| {
//!     let i = argmax(xs);
//!     xs.iter().all(|x| xs[i] >= *x)
//! });
//! ```

use crate::util::Rng;

/// A seeded value generator with an optional shrinker.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate simpler values (tried in order during shrinking).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run `cases` random cases of `prop` over `gen`; on failure, greedily
/// shrink and panic with the minimal counterexample.
pub fn forall<G: Gen>(cases: usize, gen: G, prop: impl Fn(&G::Value) -> bool) {
    forall_seeded(0xEC0_57A7E, cases, gen, prop)
}

/// `forall` with an explicit base seed (deterministic).
pub fn forall_seeded<G: Gen>(
    seed: u64,
    cases: usize,
    gen: G,
    prop: impl Fn(&G::Value) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if !prop(&value) {
            let minimal = shrink_loop(&gen, value, &prop);
            panic!(
                "property falsified (case {case}/{cases}, seed {seed:#x})\n\
                 minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen>(
    gen: &G,
    mut failing: G::Value,
    prop: &impl Fn(&G::Value) -> bool,
) -> G::Value {
    // Greedy: keep taking the first shrink candidate that still fails.
    'outer: for _ in 0..1_000 {
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

/// Stock generators.
pub mod gens {
    use super::Gen;
    use crate::util::Rng;

    /// Uniform f64 in [lo, hi); shrinks toward lo and 0.
    pub struct F64 {
        pub lo: f64,
        pub hi: f64,
    }

    impl Gen for F64 {
        type Value = f64;
        fn generate(&self, rng: &mut Rng) -> f64 {
            rng.uniform_range(self.lo, self.hi)
        }
        fn shrink(&self, v: &f64) -> Vec<f64> {
            let mut out = Vec::new();
            if *v != self.lo {
                out.push(self.lo);
                out.push(self.lo + (*v - self.lo) / 2.0);
            }
            if self.lo <= 0.0 && 0.0 < *v {
                out.push(0.0);
            }
            out
        }
    }

    /// Uniform usize in [lo, hi]; shrinks toward lo.
    pub struct USize {
        pub lo: usize,
        pub hi: usize,
    }

    impl Gen for USize {
        type Value = usize;
        fn generate(&self, rng: &mut Rng) -> usize {
            self.lo + rng.index(self.hi - self.lo + 1)
        }
        fn shrink(&self, v: &usize) -> Vec<usize> {
            let mut out = Vec::new();
            if *v > self.lo {
                out.push(self.lo);
                out.push(self.lo + (*v - self.lo) / 2);
            }
            out
        }
    }

    /// Vec of f64 with length in a range; shrinks by halving length, then
    /// element-wise toward lo.
    pub struct VecF64 {
        pub lo: f64,
        pub hi: f64,
        pub min_len: usize,
        pub max_len: usize,
    }

    impl Gen for VecF64 {
        type Value = Vec<f64>;
        fn generate(&self, rng: &mut Rng) -> Vec<f64> {
            let len = self.min_len + rng.index(self.max_len - self.min_len + 1);
            (0..len).map(|_| rng.uniform_range(self.lo, self.hi)).collect()
        }
        fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
            let mut out = Vec::new();
            if v.len() > self.min_len {
                let shorter: Vec<f64> =
                    v[..self.min_len.max(v.len() / 2)].to_vec();
                out.push(shorter);
            }
            // Zero out one element at a time.
            for i in 0..v.len() {
                if v[i] != self.lo {
                    let mut w = v.clone();
                    w[i] = self.lo;
                    out.push(w);
                }
            }
            out
        }
    }

    /// Pair of independent generators.
    pub struct Pair<A, B>(pub A, pub B);

    impl<A: Gen, B: Gen> Gen for Pair<A, B> {
        type Value = (A::Value, B::Value);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (self.0.generate(rng), self.1.generate(rng))
        }
        fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
            let mut out: Vec<Self::Value> =
                self.0.shrink(a).into_iter().map(|a2| (a2, b.clone())).collect();
            out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::gens::*;
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(200, F64 { lo: 0.0, hi: 1.0 }, |x| *x >= 0.0 && *x < 1.0);
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn failing_property_panics_with_counterexample() {
        forall(200, F64 { lo: 0.0, hi: 1.0 }, |x| *x < 0.5);
    }

    #[test]
    fn shrinking_minimizes_vec() {
        // Capture the panic message and check the counterexample shrank.
        let result = std::panic::catch_unwind(|| {
            forall(
                100,
                VecF64 { lo: 0.0, hi: 1.0, min_len: 1, max_len: 16 },
                |xs| xs.iter().sum::<f64>() < 3.0,
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // Minimal failing vec should be short (shrunk from up to 16 elems).
        let len = msg.matches(',').count() + 1;
        assert!(len <= 8, "weak shrink: {msg}");
    }

    #[test]
    fn pair_generator_composes() {
        forall(
            100,
            Pair(USize { lo: 1, hi: 9 }, F64 { lo: -1.0, hi: 0.0 }),
            |(k, x)| *k >= 1 && *x <= 0.0,
        );
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        let g = F64 { lo: 0.0, hi: 1.0 };
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        for _ in 0..10 {
            a.push(g.generate(&mut r1));
            b.push(g.generate(&mut r2));
        }
        assert_eq!(a, b);
    }
}
