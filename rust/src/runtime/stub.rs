//! Dependency-free runtime stand-in (default build, feature `xla` off).
//!
//! Keeps the whole PJRT call surface compiling without the vendored `xla`
//! closure: literals are plain host buffers (packing round-trips exactly),
//! while client construction and module execution return descriptive
//! errors. Call sites already handle the artifacts-missing case by falling
//! back to the native fleet engine or skipping, so the stub degrades to
//! precisely that behavior.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

const UNAVAILABLE: &str = "PJRT runtime unavailable: built without the `xla` feature \
     (vendored xla closure not present); use the native engine";

/// Host-side stand-in for an XLA literal: typed buffer + dims.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

/// Stand-in PJRT client. [`XlaRuntime::cpu`] always errors — constructing a
/// real client needs the xla_extension shared library.
pub struct XlaRuntime {
    _private: (),
}

impl XlaRuntime {
    /// Always fails in the stub build (no PJRT client available).
    pub fn cpu() -> Result<XlaRuntime> {
        bail!("{UNAVAILABLE}");
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    /// Unreachable in practice (no client can be constructed); kept for API
    /// parity.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModule> {
        bail!("cannot load {}: {UNAVAILABLE}", path.display());
    }

    /// Resolve an artifact by name under `dir` (or
    /// [`super::ARTIFACT_DIR`]).
    pub fn artifact_path(dir: Option<&Path>, name: &str) -> PathBuf {
        dir.unwrap_or_else(|| Path::new(super::ARTIFACT_DIR)).join(name)
    }
}

/// Stand-in compiled module; execution always errors.
pub struct LoadedModule {
    path: PathBuf,
}

impl LoadedModule {
    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        bail!("cannot execute {}: {UNAVAILABLE}", self.path.display());
    }

    pub fn run_borrowed(&self, _inputs: &[&Literal]) -> Result<Vec<Literal>> {
        bail!("cannot execute {}: {UNAVAILABLE}", self.path.display());
    }
}

/// Host-side literal helpers (same signatures as the PJRT backend).
pub mod literal {
    use anyhow::{bail, Result};

    use super::Literal;

    /// f32 matrix (row-major) -> rank-2 literal.
    pub fn mat_f32(data: &[f32], rows: usize, cols: usize) -> Result<Literal> {
        assert_eq!(data.len(), rows * cols);
        Ok(Literal::F32 { data: data.to_vec(), dims: vec![rows, cols] })
    }

    /// f32 vector -> rank-1 literal.
    pub fn vec_f32(data: &[f32]) -> Literal {
        Literal::F32 { data: data.to_vec(), dims: vec![data.len()] }
    }

    /// i32 vector -> rank-1 literal.
    pub fn vec_i32(data: &[i32]) -> Literal {
        Literal::I32 { data: data.to_vec(), dims: vec![data.len()] }
    }

    /// f32 scalar (rank 0).
    pub fn scalar_f32(x: f32) -> Literal {
        Literal::F32 { data: vec![x], dims: vec![] }
    }

    /// Extract a literal into Vec<f32>.
    pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            Literal::I32 { .. } => bail!("literal is i32, expected f32"),
        }
    }

    /// Extract a literal into Vec<i32>.
    pub fn to_vec_i32(lit: &Literal) -> Result<Vec<i32>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            Literal::F32 { .. } => bail!("literal is f32, expected i32"),
        }
    }

    /// Extract a rank-0 f32.
    pub fn to_scalar_f32(lit: &Literal) -> Result<f32> {
        match lit {
            Literal::F32 { data, .. } if !data.is_empty() => Ok(data[0]),
            _ => bail!("literal is not a non-empty f32 buffer"),
        }
    }
}
