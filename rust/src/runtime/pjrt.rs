//! The real PJRT backend (feature `xla`): thin wrapper over the vendored
//! `xla` crate. See the module docs in [`super`] for the artifact contract.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use xla::Literal;

/// A PJRT client (CPU).
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Construct the CPU PJRT client.
    pub fn cpu() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModule> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModule { exe, path: path.to_path_buf() })
    }

    /// Resolve an artifact by name under `dir` (or
    /// [`super::ARTIFACT_DIR`]).
    pub fn artifact_path(dir: Option<&Path>, name: &str) -> PathBuf {
        dir.unwrap_or_else(|| Path::new(super::ARTIFACT_DIR)).join(name)
    }
}

/// A compiled executable ready to run.
pub struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    path: PathBuf,
}

impl LoadedModule {
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Execute with host literals; returns the decomposed output tuple
    /// (artifacts are lowered with `return_tuple=True`, so the raw result
    /// is always a 1-buffer tuple).
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let result = self.exe.execute::<Literal>(inputs).context("execute")?;
        let literal = result[0][0].to_literal_sync().context("to_literal_sync")?;
        literal.to_tuple().context("decomposing output tuple")
    }

    /// Like [`Self::run`] but over borrowed literals — callers can mix
    /// per-step state literals with long-lived constants without copying
    /// the constants each step (the fleet engine's hot path).
    pub fn run_borrowed(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let result = self.exe.execute::<&Literal>(inputs).context("execute")?;
        let literal = result[0][0].to_literal_sync().context("to_literal_sync")?;
        literal.to_tuple().context("decomposing output tuple")
    }
}

/// Host-side literal helpers for the fleet engine's input packing.
pub mod literal {
    use anyhow::Result;

    use super::Literal;

    /// f32 matrix (row-major) -> rank-2 literal.
    pub fn mat_f32(data: &[f32], rows: usize, cols: usize) -> Result<Literal> {
        assert_eq!(data.len(), rows * cols);
        Ok(Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    /// f32 vector -> rank-1 literal.
    pub fn vec_f32(data: &[f32]) -> Literal {
        Literal::vec1(data)
    }

    /// i32 vector -> rank-1 literal.
    pub fn vec_i32(data: &[i32]) -> Literal {
        Literal::vec1(data)
    }

    /// f32 scalar (rank 0).
    pub fn scalar_f32(x: f32) -> Literal {
        Literal::scalar(x)
    }

    /// Extract a literal into Vec<f32>.
    pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }

    /// Extract a literal into Vec<i32>.
    pub fn to_vec_i32(lit: &Literal) -> Result<Vec<i32>> {
        Ok(lit.to_vec::<i32>()?)
    }

    /// Extract a rank-0 f32.
    pub fn to_scalar_f32(lit: &Literal) -> Result<f32> {
        Ok(lit.get_first_element::<f32>()?)
    }
}
