//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the rust hot path.
//!
//! Two interchangeable backends behind one API:
//!
//! * **`pjrt`** (feature `xla`) — wraps the vendored `xla` crate
//!   (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//!   `execute`). HLO **text** is the interchange format (see
//!   `python/compile/aot.py`): jax ≥ 0.5 emits protos with 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects; the text parser
//!   reassigns ids.
//! * **`stub`** (default) — a dependency-free stand-in with the same
//!   surface. Literal packing round-trips on the host; constructing a
//!   client or executing a module returns a descriptive error, so every
//!   caller (fleet engine, `impact`, the cross-validation tests) falls back
//!   to the native engine or skips exactly as it does when artifacts are
//!   missing.

// The `xla` feature is declared ahead of its dependency: the vendored
// `xla` crate that backs `runtime::pjrt` is not in the offline closure
// yet (ROADMAP.md: "re-add `xla = { path = ... }` when the offline
// closure is restored"). Without this guard `cargo build --features xla`
// died on an unresolved `extern crate xla` deep inside `pjrt.rs` — fail
// up front with the actual story instead. Delete this block when the
// vendored crate is wired back in.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature needs the vendored `xla` crate, which is not checked in: \
     restore the offline xla closure and re-add `xla = { path = \"vendor/xla\" }` \
     to rust/Cargo.toml (see ROADMAP.md), or build without `--features xla` \
     to use the same-API stub runtime"
);

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{literal, Literal, LoadedModule, XlaRuntime};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{literal, Literal, LoadedModule, XlaRuntime};

/// Default artifact directory relative to the repo root.
pub const ARTIFACT_DIR: &str = "artifacts";

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::{Path, PathBuf};

    // Client construction is exercised in the integration tests (it needs
    // the xla_extension shared library); here only pure helpers.

    #[test]
    fn artifact_path_joins() {
        let p = XlaRuntime::artifact_path(None, "fleet_step_b64.hlo.txt");
        assert_eq!(p, PathBuf::from("artifacts/fleet_step_b64.hlo.txt"));
        let p = XlaRuntime::artifact_path(Some(Path::new("/x")), "m.hlo.txt");
        assert_eq!(p, PathBuf::from("/x/m.hlo.txt"));
    }

    #[test]
    fn literal_roundtrip() {
        let m = literal::mat_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        assert_eq!(literal::to_vec_f32(&m).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = literal::vec_i32(&[7, 8]);
        assert_eq!(literal::to_vec_i32(&v).unwrap(), vec![7, 8]);
        let s = literal::scalar_f32(2.5);
        assert_eq!(literal::to_scalar_f32(&s).unwrap(), 2.5);
    }
}
