//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the rust hot path.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`). HLO **text**
//! is the interchange format (see `python/compile/aot.py`): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Default artifact directory relative to the repo root.
pub const ARTIFACT_DIR: &str = "artifacts";

/// A PJRT client (CPU).
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Construct the CPU PJRT client.
    pub fn cpu() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModule> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModule { exe, path: path.to_path_buf() })
    }

    /// Resolve an artifact by name under `dir` (or [`ARTIFACT_DIR`]).
    pub fn artifact_path(dir: Option<&Path>, name: &str) -> PathBuf {
        dir.unwrap_or_else(|| Path::new(ARTIFACT_DIR)).join(name)
    }
}

/// A compiled executable ready to run.
pub struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    path: PathBuf,
}

impl LoadedModule {
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Execute with host literals; returns the decomposed output tuple
    /// (artifacts are lowered with `return_tuple=True`, so the raw result
    /// is always a 1-buffer tuple).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs).context("execute")?;
        let literal = result[0][0].to_literal_sync().context("to_literal_sync")?;
        literal.to_tuple().context("decomposing output tuple")
    }

    /// Like [`Self::run`] but over borrowed literals — callers can mix
    /// per-step state literals with long-lived constants without copying
    /// the constants each step (the fleet engine's hot path).
    pub fn run_borrowed(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<&xla::Literal>(inputs).context("execute")?;
        let literal = result[0][0].to_literal_sync().context("to_literal_sync")?;
        literal.to_tuple().context("decomposing output tuple")
    }
}

/// Host-side literal helpers for the fleet engine's input packing.
pub mod literal {
    use anyhow::Result;

    /// f32 matrix (row-major) -> rank-2 literal.
    pub fn mat_f32(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        assert_eq!(data.len(), rows * cols);
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    /// f32 vector -> rank-1 literal.
    pub fn vec_f32(data: &[f32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// i32 vector -> rank-1 literal.
    pub fn vec_i32(data: &[i32]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// f32 scalar (rank 0).
    pub fn scalar_f32(x: f32) -> xla::Literal {
        xla::Literal::scalar(x)
    }

    /// Extract a literal into Vec<f32>.
    pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }

    /// Extract a literal into Vec<i32>.
    pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
        Ok(lit.to_vec::<i32>()?)
    }

    /// Extract a rank-0 f32.
    pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
        Ok(lit.get_first_element::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Client construction is exercised in the integration tests (it needs
    // the xla_extension shared library); here only pure helpers.

    #[test]
    fn artifact_path_joins() {
        let p = XlaRuntime::artifact_path(None, "fleet_step_b64.hlo.txt");
        assert_eq!(p, PathBuf::from("artifacts/fleet_step_b64.hlo.txt"));
        let p = XlaRuntime::artifact_path(Some(Path::new("/x")), "m.hlo.txt");
        assert_eq!(p, PathBuf::from("/x/m.hlo.txt"));
    }

    #[test]
    fn literal_roundtrip() {
        let m = literal::mat_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        assert_eq!(literal::to_vec_f32(&m).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let v = literal::vec_i32(&[7, 8]);
        assert_eq!(literal::to_vec_i32(&v).unwrap(), vec![7, 8]);
        let s = literal::scalar_f32(2.5);
        assert_eq!(literal::to_scalar_f32(&s).unwrap(), 2.5);
    }
}
