//! Fault-injection policy for liveness testing: panics mid-run.
//!
//! [`PanicAfter`] behaves like a static policy (always the top arm) until
//! a configured decision count, then panics inside `select`. The cluster
//! tests use it to simulate a node worker dying mid-wave/mid-shard and
//! assert the leader detects the loss instead of blocking forever. It is
//! config-buildable (`policy = "panicafter"`, `after = N`) and wire-codable
//! so subprocess/TCP workers can be crashed deterministically too, but it
//! is deliberately absent from `energyucb list`: it is a test vehicle, not
//! a baseline.

use super::Policy;

/// A policy that panics on the first `select` after `after` decisions.
#[derive(Clone, Debug)]
pub struct PanicAfter {
    k: usize,
    after: u64,
    t: u64,
}

impl PanicAfter {
    pub fn new(k: usize, after: u64) -> Self {
        PanicAfter { k, after, t: 0 }
    }
}

impl Policy for PanicAfter {
    fn name(&self) -> String {
        format!("PanicAfter[{}]", self.after)
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select(&mut self, _t: u64) -> usize {
        self.t += 1;
        if self.t > self.after {
            panic!("PanicAfter: injected fault at decision {}", self.t);
        }
        self.k - 1
    }

    fn update(&mut self, _arm: usize, _reward: f64, _progress: f64) {}

    fn reset(&mut self) {
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_top_arm_until_the_injected_fault() {
        let mut p = PanicAfter::new(9, 3);
        assert_eq!(p.name(), "PanicAfter[3]");
        for t in 1..=3 {
            assert_eq!(p.select(t), 8);
        }
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.select(4);
        }))
        .is_err();
        assert!(panicked, "decision 4 must panic");
    }

    #[test]
    fn reset_rearms_the_fault() {
        let mut p = PanicAfter::new(9, 2);
        p.select(1);
        p.select(2);
        p.reset();
        // Post-reset the budget starts over: two more selects are fine.
        assert_eq!(p.select(1), 8);
        assert_eq!(p.select(2), 8);
    }
}
