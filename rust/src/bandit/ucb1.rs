//! Standard UCB1 (Auer et al. 2002) with the classic play-each-arm-once
//! initialization. Kept as an explicit baseline and as the λ=0 / no-prior
//! reference point for EnergyUCB.

use super::Policy;

#[derive(Clone, Debug)]
pub struct Ucb1 {
    alpha: f64,
    n: Vec<u64>,
    mean: Vec<f64>,
}

impl Ucb1 {
    pub fn new(k: usize, alpha: f64) -> Ucb1 {
        assert!(k > 0 && alpha >= 0.0);
        Ucb1 { alpha, n: vec![0; k], mean: vec![0.0; k] }
    }

    pub fn index(&self, i: usize, t: u64) -> f64 {
        if self.n[i] == 0 {
            return f64::INFINITY;
        }
        self.mean[i] + self.alpha * ((t.max(2) as f64).ln() / self.n[i] as f64).sqrt()
    }
}

impl Policy for Ucb1 {
    fn name(&self) -> String {
        "UCB1".into()
    }

    fn k(&self) -> usize {
        self.n.len()
    }

    fn select(&mut self, t: u64) -> usize {
        // Play each arm once first.
        if let Some(i) = self.n.iter().position(|&n| n == 0) {
            return i;
        }
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for i in 0..self.k() {
            let v = self.index(i, t);
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    fn update(&mut self, arm: usize, reward: f64, _progress: f64) {
        self.n[arm] += 1;
        self.mean[arm] += (reward - self.mean[arm]) / self.n[arm] as f64;
    }

    fn reset(&mut self) {
        self.n.iter_mut().for_each(|x| *x = 0);
        self.mean.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn plays_each_arm_once_first() {
        let mut p = Ucb1::new(5, 0.1);
        for t in 1..=5u64 {
            let arm = p.select(t);
            assert_eq!(arm, (t - 1) as usize);
            p.update(arm, -1.0, 0.0);
        }
    }

    #[test]
    fn converges_to_best() {
        let means = [-1.2, -1.0, -1.1];
        let mut p = Ucb1::new(3, 0.1);
        let mut rng = Rng::new(4);
        let mut pulls = [0u64; 3];
        for t in 1..=3000u64 {
            let arm = p.select(t);
            pulls[arm] += 1;
            p.update(arm, rng.normal(means[arm], 0.05), 0.0);
        }
        assert!(pulls[1] > 2500, "{pulls:?}");
    }

    #[test]
    fn unplayed_arm_has_infinite_index() {
        let p = Ucb1::new(2, 0.1);
        assert!(p.index(0, 5).is_infinite());
    }
}
