//! Contextual LinUCB: per-arm ridge regression over a workload feature
//! vector, batched SoA-style like the rest of the policy core.
//!
//! Frequencies are arms; the context is the serving tier's per-step
//! feature vector (queue depth, arrival rate, batch occupancy, recent
//! util ratio — see `workload::serving`), following AGFT's vLLM
//! autoscaler shape. Each (environment, arm) pair keeps a D-dimensional
//! ridge regression maintained purely by Sherman–Morrison rank-1 updates
//! — `A⁻¹` is carried directly, no matrix inversion anywhere:
//!
//! ```text
//! score(x) = θ·x + α √(xᵀ A⁻¹ x),   θ = A⁻¹ b
//! A⁻¹ ← A⁻¹ − (A⁻¹x)(A⁻¹x)ᵀ / (1 + xᵀA⁻¹x),   b ← b + r·x
//! ```
//!
//! Determinism contract (matches `bandit::batch`): all arithmetic is
//! f64 in a fixed operation order, argmax ties break to the first index
//! (strict `>` scan from arm 0), and a B = 1 batch *is* the scalar
//! policy — [`LinUcb`] wraps a B = 1 [`BatchLinUcb`], so the two are
//! byte-for-byte identical by construction (the conformance suite pins
//! it anyway). On the context-free select path the policy scores a
//! constant bias vector `[1, 0, ..., 0]`, reducing to a ridge-mean UCB —
//! this covers the first decision of a run (no sample observed yet) and
//! keeps context-free drives well-defined.

use super::batch::BatchPolicy;
use super::Policy;

/// Dimension of the serving workload feature vector (queue depth,
/// arrival rate, batch occupancy, util ratio). The config surface
/// defaults to this; the telemetry grammar records the dimension per
/// trace.
pub const CONTEXT_DIM: usize = 4;

/// Batched Contextual LinUCB over row-major SoA grids: `a_inv` is
/// (B, K, D, D), `b_vec` is (B, K, D). See module docs for the math and
/// the determinism contract.
#[derive(Clone, Debug)]
pub struct BatchLinUcb {
    alpha: f64,
    ridge: f64,
    b: usize,
    k: usize,
    d: usize,
    /// Per-(env, arm) inverse design matrix, row-major (B, K, D, D).
    a_inv: Vec<f64>,
    /// Per-(env, arm) reward-weighted context sum, row-major (B, K, D).
    b_vec: Vec<f64>,
    /// Context active at the last selection, row-major (B, D) — the
    /// update pairs rewards with the context they were selected under.
    last_ctx: Vec<f64>,
    /// Scratch: A⁻¹x for the arm being scored/updated (length D).
    /// Policy-owned so both the scorer and the update are allocation-free
    /// in the hot loop (mirrors `fleet::native::StepScratch`).
    v: Vec<f64>,
}

/// Number of matrix rows processed per chunk in [`matvec_rows_into`].
const MATVEC_LANES: usize = 4;

/// Row-chunked matvec `out = M·x` for a row-major (D, D) matrix: rows go
/// [`MATVEC_LANES`] at a time through independent per-lane accumulators,
/// remainder rows through the plain scalar dot. Each lane walks the
/// columns strictly ascending, so every `out[r]` is the exact
/// left-to-right accumulation chain of the original nested loop —
/// bit-identical results, while the independent lanes let the
/// autovectorizer keep MATVEC_LANES f64 FMA-free multiply-add streams in
/// flight instead of one serial dependency chain.
fn matvec_rows_into(m: &[f64], x: &[f64], d: usize, out: &mut [f64]) {
    debug_assert_eq!(m.len(), d * d);
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(out.len(), d);
    const L: usize = MATVEC_LANES;
    let chunks = d / L;
    for chunk in 0..chunks {
        let r0 = chunk * L;
        let mut acc = [0.0f64; L];
        for (c, &xc) in x.iter().enumerate() {
            for (l, a) in acc.iter_mut().enumerate() {
                *a += m[(r0 + l) * d + c] * xc;
            }
        }
        out[r0..r0 + L].copy_from_slice(&acc);
    }
    for r in chunks * L..d {
        let row = &m[r * d..(r + 1) * d];
        let mut vr = 0.0;
        for (c, &xc) in x.iter().enumerate() {
            vr += row[c] * xc;
        }
        out[r] = vr;
    }
}

impl BatchLinUcb {
    pub fn new(b: usize, k: usize, d: usize, alpha: f64, ridge: f64) -> BatchLinUcb {
        assert!(b > 0 && k > 0 && d > 0);
        assert!(alpha >= 0.0, "alpha must be non-negative");
        assert!(ridge > 0.0, "ridge must be positive");
        let mut p = BatchLinUcb {
            alpha,
            ridge,
            b,
            k,
            d,
            a_inv: vec![0.0; b * k * d * d],
            b_vec: vec![0.0; b * k * d],
            last_ctx: vec![0.0; b * d],
            v: vec![0.0; d],
        };
        p.reset();
        p
    }

    /// Context dimension D.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Write the context-free bias vector `[1, 0, ..., 0]` into every
    /// environment's `last_ctx` row.
    fn stash_bias_ctx(&mut self) {
        self.last_ctx.iter_mut().for_each(|x| *x = 0.0);
        for e in 0..self.b {
            self.last_ctx[e * self.d] = 1.0;
        }
    }

    /// Masked argmax of `θ·x + α√(xᵀA⁻¹x)` per environment against the
    /// stashed contexts. Stages `v = A⁻¹x` through the policy-owned
    /// scratch via the row-chunked [`matvec_rows_into`], then folds the
    /// two dots in row order — the same accumulation chains as the
    /// original interleaved loop (the `chunked_scorer_matches_reference_
    /// bitwise` test pins it against the preserved reference).
    fn score_into(&mut self, feasible: &[f32], sel: &mut [i32]) {
        let (b, k, d) = (self.b, self.k, self.d);
        let alpha = self.alpha;
        debug_assert_eq!(feasible.len(), b * k);
        debug_assert_eq!(sel.len(), b);
        let Self { a_inv, b_vec, last_ctx, v, .. } = self;
        for e in 0..b {
            let x = &last_ctx[e * d..(e + 1) * d];
            let mut best_arm = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            for i in 0..k {
                if feasible[e * k + i] <= 0.0 {
                    continue;
                }
                let cell = (e * k + i) * d * d;
                let bv = &b_vec[(e * k + i) * d..(e * k + i + 1) * d];
                // v = A⁻¹x; θ·x = bᵀA⁻¹x = b·v (A⁻¹ stays symmetric
                // under Sherman–Morrison), so one matvec scores the arm.
                matvec_rows_into(&a_inv[cell..cell + d * d], x, d, v);
                let mut mean = 0.0;
                let mut quad = 0.0;
                for r in 0..d {
                    mean += bv[r] * v[r];
                    quad += x[r] * v[r];
                }
                let score = mean + alpha * quad.max(0.0).sqrt();
                if score > best_v {
                    best_v = score;
                    best_arm = i;
                }
            }
            sel[e] = best_arm as i32;
        }
    }

    /// The pre-chunking scorer, preserved verbatim as the conformance
    /// reference for [`score_into`] (test-only).
    #[cfg(test)]
    fn score_into_reference(&mut self, feasible: &[f32], sel: &mut [i32]) {
        let (b, k, d) = (self.b, self.k, self.d);
        for e in 0..b {
            let x = &self.last_ctx[e * d..(e + 1) * d];
            let mut best_arm = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            for i in 0..k {
                if feasible[e * k + i] <= 0.0 {
                    continue;
                }
                let cell = (e * k + i) * d * d;
                let bv = &self.b_vec[(e * k + i) * d..(e * k + i + 1) * d];
                let mut mean = 0.0;
                let mut quad = 0.0;
                for r in 0..d {
                    let row = &self.a_inv[cell + r * d..cell + (r + 1) * d];
                    let mut vr = 0.0;
                    for (c, &xc) in x.iter().enumerate() {
                        vr += row[c] * xc;
                    }
                    mean += bv[r] * vr;
                    quad += x[r] * vr;
                }
                let score = mean + self.alpha * quad.max(0.0).sqrt();
                if score > best_v {
                    best_v = score;
                    best_arm = i;
                }
            }
            sel[e] = best_arm as i32;
        }
    }
}

impl BatchPolicy for BatchLinUcb {
    fn name(&self) -> String {
        "LinUCB".into()
    }

    fn b(&self) -> usize {
        self.b
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select_into(&mut self, _t: u64, feasible: &[f32], sel: &mut [i32]) {
        self.stash_bias_ctx();
        self.score_into(feasible, sel);
    }

    fn select_into_ctx(
        &mut self,
        _t: u64,
        feasible: &[f32],
        ctx: &[f64],
        d: usize,
        sel: &mut [i32],
    ) {
        assert_eq!(d, self.d, "context dimension mismatch");
        assert_eq!(ctx.len(), self.b * d, "context grid must be (B, D)");
        self.last_ctx.copy_from_slice(ctx);
        self.score_into(feasible, sel);
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], _progress: &[f64], active: &[f32]) {
        let (k, d) = (self.k, self.d);
        let Self { a_inv, b_vec, last_ctx, v, .. } = self;
        for e in 0..sel.len() {
            if active[e] <= 0.0 {
                continue;
            }
            let arm = sel[e] as usize;
            debug_assert!(arm < k);
            let x = &last_ctx[e * d..(e + 1) * d];
            let cell = (e * k + arm) * d * d;
            // v = A⁻¹x and denom = 1 + xᵀA⁻¹x for the rank-1 downdate;
            // the chunked matvec and the row-order denom fold reproduce
            // the original interleaved accumulation chains exactly.
            matvec_rows_into(&a_inv[cell..cell + d * d], x, d, v);
            let mut denom = 1.0;
            for r in 0..d {
                denom += x[r] * v[r];
            }
            if denom > 1e-12 {
                for r in 0..d {
                    let vr = v[r];
                    for c in 0..d {
                        a_inv[cell + r * d + c] -= vr * v[c] / denom;
                    }
                }
            }
            let bv = &mut b_vec[(e * k + arm) * d..(e * k + arm + 1) * d];
            for (r, &xc) in x.iter().enumerate() {
                bv[r] += reward[e] * xc;
            }
        }
    }

    fn reset(&mut self) {
        self.a_inv.iter_mut().for_each(|x| *x = 0.0);
        let inv_ridge = 1.0 / self.ridge;
        for cell in 0..self.b * self.k {
            let base = cell * self.d * self.d;
            for r in 0..self.d {
                self.a_inv[base + r * self.d + r] = inv_ridge;
            }
        }
        self.b_vec.iter_mut().for_each(|x| *x = 0.0);
        self.stash_bias_ctx();
    }
}

/// Scalar Contextual LinUCB: a B = 1 [`BatchLinUcb`] behind the
/// [`Policy`] trait, so sessions, replay, and the cluster tier run it
/// unchanged. Byte-for-byte identical to the batch policy at B = 1 by
/// construction (they share the arithmetic).
pub struct LinUcb {
    inner: BatchLinUcb,
    feas: Vec<f32>,
    sel: [i32; 1],
}

impl LinUcb {
    pub fn new(k: usize, d: usize, alpha: f64, ridge: f64) -> LinUcb {
        LinUcb { inner: BatchLinUcb::new(1, k, d, alpha, ridge), feas: vec![1.0; k], sel: [0] }
    }
}

impl Policy for LinUcb {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn k(&self) -> usize {
        self.inner.k()
    }

    fn select(&mut self, t: u64) -> usize {
        self.inner.select_into(t, &self.feas, &mut self.sel);
        self.sel[0] as usize
    }

    fn select_ctx(&mut self, t: u64, ctx: &[f64]) -> usize {
        let d = self.inner.d();
        self.inner.select_into_ctx(t, &self.feas, ctx, d, &mut self.sel);
        self.sel[0] as usize
    }

    fn update(&mut self, arm: usize, reward: f64, progress: f64) {
        self.inner.update_batch(&[arm as i32], &[reward], &[progress], &[1.0]);
    }

    fn reset(&mut self) {
        self.inner.reset();
    }
}

/// Scalar QoS-constrained Contextual LinUCB: a B = 1 [`BatchCLinUcb`]
/// behind the [`Policy`] trait (same bridge shape as [`LinUcb`]).
pub struct CLinUcb {
    inner: BatchCLinUcb,
    feas: Vec<f32>,
    sel: [i32; 1],
}

impl CLinUcb {
    pub fn new(k: usize, d: usize, alpha: f64, ridge: f64, delta: f64) -> CLinUcb {
        CLinUcb {
            inner: BatchCLinUcb::new(1, k, d, alpha, ridge, delta),
            feas: vec![1.0; k],
            sel: [0],
        }
    }
}

impl Policy for CLinUcb {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn k(&self) -> usize {
        self.inner.k()
    }

    fn select(&mut self, t: u64) -> usize {
        self.inner.select_into(t, &self.feas, &mut self.sel);
        self.sel[0] as usize
    }

    fn select_ctx(&mut self, t: u64, ctx: &[f64]) -> usize {
        let d = self.inner.inner.d();
        self.inner.select_into_ctx(t, &self.feas, ctx, d, &mut self.sel);
        self.sel[0] as usize
    }

    fn update(&mut self, arm: usize, reward: f64, progress: f64) {
        self.inner.update_batch(&[arm as i32], &[reward], &[progress], &[1.0]);
    }

    fn reset(&mut self) {
        self.inner.reset();
    }
}

/// QoS-constrained Contextual LinUCB: the slowdown-budget machinery of
/// [`BatchConstrainedEnergyUcb`][super::batch::BatchConstrainedEnergyUcb]
/// — clean-progress running means, optimistic unmeasured arms, a
/// measurement dwell on just-switched-to arms — wrapped around the
/// LinUCB scorer. Estimates are f64 to match the LinUCB core (the f32
/// constrained EnergyUCB remains the artifact-contract reference).
#[derive(Clone, Debug)]
pub struct BatchCLinUcb {
    inner: BatchLinUcb,
    delta: f64,
    /// Running mean of clean per-interval progress, row-major (B, K).
    p_hat: Vec<f64>,
    p_count: Vec<f64>,
    /// Previous selected arm per environment (-1 = none yet) — the
    /// LinUCB core carries no switching state, so the dwell logic
    /// tracks its own.
    prev: Vec<i32>,
    /// Combined caller × estimated feasibility, rebuilt each select.
    mask: Vec<f32>,
}

impl BatchCLinUcb {
    pub fn new(b: usize, k: usize, d: usize, alpha: f64, ridge: f64, delta: f64) -> BatchCLinUcb {
        assert!((0.0..1.0).contains(&delta), "delta must be in [0,1)");
        BatchCLinUcb {
            inner: BatchLinUcb::new(b, k, d, alpha, ridge),
            delta,
            p_hat: vec![0.0; b * k],
            p_count: vec![0.0; b * k],
            prev: vec![-1; b],
            mask: vec![1.0; b * k],
        }
    }

    /// Estimated-feasible mask entry for (env, arm): optimistic until
    /// both the arm and the max-frequency arm have clean progress
    /// samples (same rule as the constrained EnergyUCB).
    fn estimated_feasible(&self, e: usize, i: usize) -> bool {
        let k = self.inner.k;
        let row = e * k;
        let max_arm = k - 1;
        if i == max_arm {
            return true; // f_max has zero slowdown by definition
        }
        if self.p_count[row + i] <= 0.0 || self.p_count[row + max_arm] <= 0.0 {
            return true; // optimism: unknown arms stay feasible
        }
        let p_max = self.p_hat[row + max_arm];
        if p_max <= 0.0 {
            return true;
        }
        1.0 - self.p_hat[row + i] / p_max <= self.delta
    }

    fn build_mask(&mut self, feasible: &[f32]) {
        let (b, k) = (self.inner.b, self.inner.k);
        for e in 0..b {
            for i in 0..k {
                let idx = e * k + i;
                self.mask[idx] =
                    if self.estimated_feasible(e, i) { feasible[idx] } else { 0.0 };
            }
        }
        // The intersection keeps the max-frequency arm wherever the
        // caller's mask does — guard the invariant at the build site.
        super::batch::debug_assert_feasible_rows(&self.mask, k);
    }

    /// Measurement dwell: a just-switched-to arm has no clean progress
    /// sample yet — hold it one more interval so its slowdown estimate
    /// comes from a steady-state reading.
    fn dwell(&self, sel: &mut [i32]) {
        let k = self.inner.k;
        for e in 0..sel.len() {
            let p = self.prev[e];
            if p >= 0 && self.p_count[e * k + p as usize] <= 0.0 {
                sel[e] = p;
            }
        }
    }
}

impl BatchPolicy for BatchCLinUcb {
    fn name(&self) -> String {
        format!("Constrained LinUCB (δ={})", self.delta)
    }

    fn b(&self) -> usize {
        self.inner.b
    }

    fn k(&self) -> usize {
        self.inner.k
    }

    fn select_into(&mut self, t: u64, feasible: &[f32], sel: &mut [i32]) {
        self.build_mask(feasible);
        self.inner.select_into(t, &self.mask, sel);
        self.dwell(sel);
    }

    fn select_into_ctx(
        &mut self,
        t: u64,
        feasible: &[f32],
        ctx: &[f64],
        d: usize,
        sel: &mut [i32],
    ) {
        self.build_mask(feasible);
        self.inner.select_into_ctx(t, &self.mask, ctx, d, sel);
        self.dwell(sel);
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], progress: &[f64], active: &[f32]) {
        let k = self.inner.k;
        // Progress estimates first (they need the pre-update `prev` to
        // tell clean steady-state samples from switch-tainted ones).
        for e in 0..sel.len() {
            if active[e] <= 0.0 {
                continue;
            }
            let clean = self.prev[e] == sel[e];
            if clean && progress[e] > 0.0 {
                let idx = e * k + sel[e] as usize;
                self.p_count[idx] += 1.0;
                self.p_hat[idx] += (progress[e] - self.p_hat[idx]) / self.p_count[idx];
            }
            self.prev[e] = sel[e];
        }
        self.inner.update_batch(sel, reward, progress, active);
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.p_hat.iter_mut().for_each(|x| *x = 0.0);
        self.p_count.iter_mut().for_each(|x| *x = 0.0);
        self.prev.iter_mut().for_each(|x| *x = -1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ones(b: usize, k: usize) -> Vec<f32> {
        vec![1.0; b * k]
    }

    /// Context-dependent rewards: arm `ctx_best` is optimal when the
    /// first feature is high, arm 0 when it is low.
    fn ctx_reward(arm: usize, ctx: &[f64], ctx_best: usize) -> f64 {
        let load = ctx[0];
        let target = if load > 0.5 { ctx_best } else { 0 };
        -1.0 - 0.2 * (arm as f64 - target as f64).abs()
    }

    #[test]
    fn b1_batch_matches_scalar_exactly() {
        let (k, d) = (5, 4);
        let mut batch = BatchLinUcb::new(1, k, d, 0.4, 1.0);
        let mut scalar = LinUcb::new(k, d, 0.4, 1.0);
        let feas = ones(1, k);
        let mut sel = [0i32];
        for t in 1..=200u64 {
            let load = if t % 7 < 3 { 0.9 } else { 0.1 };
            let ctx = [load, 0.3, 0.5, 0.8];
            batch.select_into_ctx(t, &feas, &ctx, d, &mut sel);
            let s = scalar.select_ctx(t, &ctx);
            assert_eq!(sel[0] as usize, s, "t={t}");
            let r = ctx_reward(s, &ctx, 3);
            batch.update_batch(&sel, &[r], &[1e-3], &[1.0]);
            scalar.update(s, r, 1e-3);
        }
    }

    #[test]
    fn learns_context_dependent_arms() {
        let (k, d) = (5, 4);
        let mut p = BatchLinUcb::new(1, k, d, 0.4, 1.0);
        let feas = ones(1, k);
        let mut sel = [0i32];
        let mut drive = |p: &mut BatchLinUcb, steps: std::ops::RangeInclusive<u64>| {
            let mut picks = Vec::new();
            for t in steps {
                let load = if t % 2 == 0 { 0.9 } else { 0.1 };
                let ctx = [load, 0.3, 0.5, 0.8];
                p.select_into_ctx(t, &feas, &ctx, d, &mut sel);
                picks.push((load, sel[0] as usize));
                let r = ctx_reward(sel[0] as usize, &ctx, 3);
                p.update_batch(&sel, &[r], &[1e-3], &[1.0]);
            }
            picks
        };
        drive(&mut p, 1..=800);
        // After training, the policy must map high load -> arm 3 and
        // low load -> arm 0.
        for (load, arm) in drive(&mut p, 801..=900) {
            if load > 0.5 {
                assert_eq!(arm, 3, "high-load pick");
            } else {
                assert_eq!(arm, 0, "low-load pick");
            }
        }
    }

    #[test]
    fn reset_restores_fresh_trajectories() {
        let (k, d) = (4, 4);
        let mut p = BatchLinUcb::new(2, k, d, 0.3, 1.0);
        let feas = ones(2, k);
        let mut drive = |p: &mut BatchLinUcb| {
            let mut sel = [0i32; 2];
            let mut hist = Vec::new();
            for t in 1..=120u64 {
                let ctx =
                    [0.1 * (t % 10) as f64, 0.4, 0.6, 0.2, 0.9 - 0.08 * (t % 10) as f64, 0.1, 0.3, 0.7];
                p.select_into_ctx(t, &feas, &ctx, d, &mut sel);
                let r = [-(1.0 + 0.1 * sel[0] as f64), -(1.0 + 0.05 * sel[1] as f64)];
                p.update_batch(&sel, &r, &[1e-3; 2], &[1.0; 2]);
                hist.push(sel);
            }
            hist
        };
        let first = drive(&mut p);
        p.reset();
        let second = drive(&mut p);
        assert_eq!(first, second);
    }

    #[test]
    fn feasibility_mask_is_honored() {
        let (k, d) = (4, 4);
        let mut p = BatchLinUcb::new(1, k, d, 0.5, 1.0);
        let mut feas = ones(1, k);
        feas[2] = 0.0;
        let mut sel = [0i32];
        for t in 1..=100u64 {
            let ctx = [0.8, 0.2, 0.4, 0.6];
            p.select_into_ctx(t, &feas, &ctx, d, &mut sel);
            assert_ne!(sel[0], 2);
            // Arm 2 pays best — only the mask keeps the policy off it.
            let r = if sel[0] == 2 { -0.5 } else { -1.0 - 0.1 * sel[0] as f64 };
            p.update_batch(&sel, &[r], &[1e-3], &[1.0]);
        }
    }

    #[test]
    fn frozen_envs_do_not_learn() {
        let (k, d) = (3, 4);
        let mut p = BatchLinUcb::new(2, k, d, 0.3, 1.0);
        let snapshot = p.clone();
        p.update_batch(&[1, 1], &[-1.0, -1.0], &[1e-3; 2], &[0.0, 0.0]);
        assert_eq!(p.a_inv, snapshot.a_inv);
        assert_eq!(p.b_vec, snapshot.b_vec);
    }

    #[test]
    fn constrained_excludes_measured_slow_arms() {
        let (k, d) = (9, 4);
        let progress_of =
            |arm: usize| 1e-3 / (0.5 + 0.5 * (1.6 / (0.8 + 0.1 * arm as f64)));
        let mut p = BatchCLinUcb::new(1, k, d, 0.4, 1.0, 0.05);
        let feas = ones(1, k);
        let mut sel = [0i32];
        for t in 1..=600u64 {
            let ctx = [0.5, 0.5, 0.5, 0.5];
            p.select_into_ctx(t, &feas, &ctx, d, &mut sel);
            let arm = sel[0] as usize;
            // Cheap-at-low-frequency rewards: only the constraint keeps
            // the policy near the top arms.
            let reward = -1.0 - 0.03 * (k - 1 - arm) as f64;
            p.update_batch(&sel, &[reward], &[progress_of(arm)], &[1.0]);
        }
        for t in 601..=700u64 {
            let ctx = [0.5, 0.5, 0.5, 0.5];
            p.select_into_ctx(t, &feas, &ctx, d, &mut sel);
            let arm = sel[0] as usize;
            let true_s = 1.0 - progress_of(arm) / progress_of(k - 1);
            p.update_batch(&sel, &[-1.0], &[progress_of(arm)], &[1.0]);
            assert!(true_s <= 0.07, "picked arm {arm} with slowdown {true_s}");
        }
    }

    #[test]
    fn chunked_scorer_matches_reference_bitwise() {
        use crate::util::Rng;
        // Shapes straddle the 4-row lane width: d < L, d = L, d with a
        // remainder, d a multiple of L.
        for &(b, k, d, seed) in
            &[(1usize, 5usize, 4usize, 1u64), (3, 9, 7, 2), (2, 4, 1, 3), (4, 3, 12, 4), (2, 6, 5, 5)]
        {
            let mut p = BatchLinUcb::new(b, k, d, 0.4, 1.0);
            let mut rng = Rng::new(seed);
            let mut sel = vec![0i32; b];
            let mut sel_ref = vec![0i32; b];
            let mut ctx = vec![0.0f64; b * d];
            let progress = vec![1e-3f64; b];
            for t in 1..=60u64 {
                for c in ctx.iter_mut() {
                    *c = rng.uniform_range(-1.0, 1.0);
                }
                let feas: Vec<f32> =
                    (0..b * k).map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 }).collect();
                let mut reference = p.clone();
                p.select_into_ctx(t, &feas, &ctx, d, &mut sel);
                reference.last_ctx.copy_from_slice(&ctx);
                reference.score_into_reference(&feas, &mut sel_ref);
                assert_eq!(sel, sel_ref, "b={b} k={k} d={d} t={t}");
                let reward: Vec<f64> = sel
                    .iter()
                    .map(|&s| -1.0 - 0.1 * s as f64 + rng.uniform_range(-0.1, 0.1))
                    .collect();
                let active: Vec<f32> =
                    (0..b).map(|e| if t % 5 == 0 && e == 0 { 0.0 } else { 1.0 }).collect();
                p.update_batch(&sel, &reward, &progress, &active);
            }
        }
    }

    #[test]
    fn context_free_select_falls_back_to_bias_vector() {
        // Without context the scorer sees a constant feature, so LinUCB
        // degrades to a ridge-mean UCB and still finds the best arm.
        let k = 4;
        let mut p = BatchLinUcb::new(1, k, CONTEXT_DIM, 0.4, 1.0);
        let feas = ones(1, k);
        let mut sel = [0i32];
        for t in 1..=400u64 {
            p.select_into(t, &feas, &mut sel);
            let r = -1.0 - 0.1 * (sel[0] as f64 - 2.0).abs();
            p.update_batch(&sel, &[r], &[1e-3], &[1.0]);
        }
        p.select_into(401, &feas, &mut sel);
        assert_eq!(sel[0], 2);
    }
}
