//! EnergyTS baseline (paper §4.1): Gaussian Thompson sampling.
//!
//! Maintains a Normal posterior over each arm's mean reward with a fixed
//! observation-noise scale and samples one draw per arm per step, playing
//! the argmax. Bayesian exploration without confidence bonuses.

use super::Policy;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct EnergyTs {
    /// Prior mean (0 = optimistic for negative rewards).
    prior_mean: f64,
    /// Prior std-dev (breadth of initial exploration).
    prior_std: f64,
    /// Assumed observation noise std-dev.
    obs_std: f64,
    n: Vec<u64>,
    mean: Vec<f64>,
    rng: Rng,
    /// Construction seed, so `reset()` restores fresh-run behavior
    /// byte-for-byte (the policy-contract suite pins this).
    seed: u64,
}

impl EnergyTs {
    pub fn new(k: usize, prior_mean: f64, prior_std: f64, obs_std: f64, seed: u64) -> EnergyTs {
        assert!(k > 0 && prior_std > 0.0 && obs_std > 0.0);
        EnergyTs {
            prior_mean,
            prior_std,
            obs_std,
            n: vec![0; k],
            mean: vec![0.0; k],
            rng: Rng::new(seed),
            seed,
        }
    }

    /// Defaults for the normalized reward scale: weakly-informative prior
    /// and a conservative observation-noise assumption (the counter stream
    /// is heavy-tailed, so a Gaussian TS must assume generous noise or its
    /// posterior over-tightens on glitched samples).
    pub fn default_for(k: usize, seed: u64) -> EnergyTs {
        EnergyTs::new(k, 0.0, 0.4, 0.2, seed)
    }

    /// Posterior (mean, std) for arm `i` under the conjugate Normal model.
    pub fn posterior(&self, i: usize) -> (f64, f64) {
        let n = self.n[i] as f64;
        let prior_prec = 1.0 / (self.prior_std * self.prior_std);
        let obs_prec = n / (self.obs_std * self.obs_std);
        let prec = prior_prec + obs_prec;
        let mean = (self.prior_mean * prior_prec + self.mean[i] * obs_prec) / prec;
        (mean, (1.0 / prec).sqrt())
    }
}

impl Policy for EnergyTs {
    fn name(&self) -> String {
        "EnergyTS".into()
    }

    fn k(&self) -> usize {
        self.n.len()
    }

    fn select(&mut self, _t: u64) -> usize {
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for i in 0..self.k() {
            let (m, s) = self.posterior(i);
            let draw = self.rng.normal(m, s);
            if draw > best_v {
                best_v = draw;
                best = i;
            }
        }
        best
    }

    fn update(&mut self, arm: usize, reward: f64, _progress: f64) {
        self.n[arm] += 1;
        self.mean[arm] += (reward - self.mean[arm]) / self.n[arm] as f64;
    }

    fn reset(&mut self) {
        self.n.iter_mut().for_each(|x| *x = 0);
        self.mean.iter_mut().for_each(|x| *x = 0.0);
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn posterior_tightens_with_data() {
        let mut p = EnergyTs::default_for(2, 1);
        let (_, s0) = p.posterior(0);
        for _ in 0..100 {
            p.update(0, -1.0, 0.0);
        }
        let (m, s1) = p.posterior(0);
        assert!(s1 < s0 / 5.0, "s0={s0} s1={s1}");
        assert!((m - (-1.0)).abs() < 0.05, "{m}");
    }

    #[test]
    fn converges_to_best_arm() {
        let means = [-1.2, -1.0, -1.15];
        let mut p = EnergyTs::default_for(3, 2);
        let mut rng = Rng::new(6);
        let mut pulls = [0u64; 3];
        for t in 1..=4000u64 {
            let arm = p.select(t);
            pulls[arm] += 1;
            p.update(arm, rng.normal(means[arm], 0.05), 0.0);
        }
        assert!(pulls[1] > 3200, "{pulls:?}");
    }

    #[test]
    fn prior_drives_initial_exploration() {
        let mut p = EnergyTs::default_for(9, 3);
        let mut seen = [false; 9];
        for t in 1..=300u64 {
            let arm = p.select(t);
            seen[arm] = true;
            p.update(arm, -1.0, 0.0);
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 8, "{seen:?}");
    }
}
