//! Static-frequency policies (paper §4.1): hold one frequency for the
//! whole execution. Arm K-1 (1.6 GHz) is the Aurora default configuration
//! and the "Saved Energy" reference point.

use super::Policy;

#[derive(Clone, Debug)]
pub struct StaticPolicy {
    k: usize,
    arm: usize,
    label: String,
}

impl StaticPolicy {
    pub fn new(k: usize, arm: usize) -> StaticPolicy {
        assert!(arm < k, "static arm {arm} out of range (k={k})");
        StaticPolicy { k, arm, label: format!("Static[arm {arm}]") }
    }

    /// With a human-readable frequency label ("1.6 GHz").
    pub fn labeled(k: usize, arm: usize, label: impl Into<String>) -> StaticPolicy {
        let mut p = StaticPolicy::new(k, arm);
        p.label = label.into();
        p
    }

    pub fn arm(&self) -> usize {
        self.arm
    }
}

impl Policy for StaticPolicy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select(&mut self, _t: u64) -> usize {
        self.arm
    }

    fn update(&mut self, _arm: usize, _reward: f64, _progress: f64) {}

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_same_arm() {
        let mut p = StaticPolicy::new(9, 4);
        assert!((1..100u64).all(|t| p.select(t) == 4));
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range() {
        StaticPolicy::new(3, 3);
    }

    #[test]
    fn label() {
        let p = StaticPolicy::labeled(9, 8, "1.6 GHz");
        assert_eq!(p.name(), "1.6 GHz");
    }
}
