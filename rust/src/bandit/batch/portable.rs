//! Portable lane-chunked kernels: fixed-width chunks (8×f32 for the
//! SA-UCB core, 4×f64 for the scalar-faithful policies) written as plain
//! Rust over small arrays, so the autovectorizer can map lanes onto
//! whatever vector unit the target has. This is the default kernel on
//! non-x86_64 hosts and the model the `core::arch` paths in
//! [`super::x86`] implement with explicit intrinsics.
//!
//! ## Why lane-chunking preserves the bit contract
//!
//! Every per-arm score is an *elementwise* function of that arm's grid
//! cells — there is no cross-arm accumulation — and every IEEE-754
//! operation used (add, sub, mul, div, sqrt, max) is exactly rounded, so
//! computing `LANES` arms at once yields, per lane, the same bits as the
//! scalar loop: Rust never reassociates or contracts float expressions,
//! and none of the kernels use FMA or approximate reciprocal ops.
//!
//! The only cross-arm step is the masked argmax, and its lane-order
//! argument is what the conformance suite pins:
//!
//! * Within the chunk scan, each lane keeps a running `(best, arm)` pair
//!   updated on strict `>`. Lane `l` therefore ends holding the *lowest*
//!   arm index among arms `≡ l (mod LANES)` that achieve that lane's
//!   maximum (later equal values never displace it).
//! * The horizontal merge picks the maximum lane value, breaking value
//!   ties toward the lowest stored arm index. The winning value equals
//!   the scalar scan's maximum, and among all arms achieving it the
//!   lowest index wins — exactly the scalar first-index rule.
//! * Remainder arms (`k % LANES`) run the verbatim scalar body,
//!   continuing the same strict-`>` scan at indices above every chunked
//!   arm, where strict `>` is again exactly the first-index rule.
//!
//! The f64 policies' `continue`-on-infeasible scan is replaced by
//! masking infeasible lanes to `-inf`: feasible scores are always finite
//! (counts ≥ 1 after the warm-start pass, windowed means and bonuses
//! finite), so a masked lane can never win over a feasible arm, and an
//! all-masked row falls back to arm 0 exactly like the scalar scan.

use super::{SaUcbHyper, NEG_LARGE};

/// f32 lanes per chunk in the SA-UCB kernels.
pub(super) const LANES_F32: usize = 8;
/// f64 lanes per chunk in the UCB1/SW-UCB kernels.
pub(super) const LANES_F64: usize = 4;

/// Horizontal argmax merge over per-lane `(best value, best arm)` pairs:
/// maximum value, ties toward the lowest stored arm index (see module
/// docs). With zero chunks there is nothing to merge and the caller's
/// remainder scan starts from the scalar init state `(-inf, arm 0)`.
pub(super) fn merge_lanes_f32(lane_v: &[f32], lane_arm: &[i32], chunks: usize) -> (f32, i32) {
    if chunks == 0 {
        return (f32::NEG_INFINITY, 0);
    }
    let mut best_v = f32::NEG_INFINITY;
    let mut best_arm = i32::MAX;
    for (&v, &arm) in lane_v.iter().zip(lane_arm) {
        if v > best_v || (v == best_v && arm < best_arm) {
            best_v = v;
            best_arm = arm;
        }
    }
    (best_v, best_arm)
}

/// f64 twin of [`merge_lanes_f32`].
pub(super) fn merge_lanes_f64(lane_v: &[f64], lane_arm: &[i32], chunks: usize) -> (f64, i32) {
    if chunks == 0 {
        return (f64::NEG_INFINITY, 0);
    }
    let mut best_v = f64::NEG_INFINITY;
    let mut best_arm = i32::MAX;
    for (&v, &arm) in lane_v.iter().zip(lane_arm) {
        if v > best_v || (v == best_v && arm < best_arm) {
            best_v = v;
            best_arm = arm;
        }
    }
    (best_v, best_arm)
}

/// Portable lane-chunked SA-UCB select.
#[allow(clippy::too_many_arguments)]
pub(super) fn saucb_select_into(
    n: &[f32],
    mean: &[f32],
    prev: &[i32],
    t: f32,
    feasible: &[f32],
    hyper: &SaUcbHyper,
    k: usize,
    sel: &mut [i32],
) {
    const L: usize = LANES_F32;
    let b = prev.len();
    let ln_t = t.max(2.0).ln();
    let (alpha, lambda, mu_init, prior_n) =
        (hyper.alpha, hyper.lambda, hyper.mu_init, hyper.prior_n);
    let prior_mu = prior_n * mu_init;
    let chunks = k / L;
    for e in 0..b {
        let row = e * k;
        let prev_e = prev[e];
        let mut lane_v = [f32::NEG_INFINITY; L];
        let mut lane_arm = [0i32; L];
        for c in 0..chunks {
            let base = row + c * L;
            let arm0 = (c * L) as i32;
            let mut v = [0.0f32; L];
            for l in 0..L {
                let ni = n[base + l];
                let denom = prior_n + ni;
                // Computed unconditionally, selected per lane: the
                // discarded branch's value never reaches a result (and
                // with denom == 0 both operands of the division are
                // finite, so no stray NaN is even produced).
                let raw = (prior_mu + ni * mean[base + l]) / denom.max(1e-12);
                let mu_hat = if denom > 0.0 { raw } else { mu_init };
                let bonus = alpha * (ln_t / ni.max(1.0)).sqrt();
                let penalty = if arm0 + l as i32 != prev_e { lambda } else { 0.0 };
                let vl = mu_hat + bonus - penalty;
                v[l] = if feasible[base + l] > 0.0 { vl } else { NEG_LARGE };
            }
            for l in 0..L {
                if v[l] > lane_v[l] {
                    lane_v[l] = v[l];
                    lane_arm[l] = arm0 + l as i32;
                }
            }
        }
        let (mut best_v, mut best_arm) = merge_lanes_f32(&lane_v, &lane_arm, chunks);
        for i in (chunks * L)..k {
            // The scalar reference body, continuing the strict-> scan.
            let ni = n[row + i];
            let denom = prior_n + ni;
            let mu_hat = if denom > 0.0 {
                (prior_mu + ni * mean[row + i]) / denom.max(1e-12)
            } else {
                mu_init
            };
            let bonus = alpha * (ln_t / ni.max(1.0)).sqrt();
            let penalty = if i as i32 != prev_e { lambda } else { 0.0 };
            let mut v = mu_hat + bonus - penalty;
            if feasible[row + i] <= 0.0 {
                v = NEG_LARGE;
            }
            if v > best_v {
                best_v = v;
                best_arm = i as i32;
            }
        }
        sel[e] = best_arm;
    }
}

/// Portable lane-chunked incremental-mean update: gather the selected
/// cells, compute the fold on arrays, scatter back. Cell indices are
/// unique within a chunk (one per environment), so gather-then-scatter
/// cannot alias; each lane's arithmetic chain is the scalar body's.
pub(super) fn grid_update_batch(
    n: &mut [f32],
    mean: &mut [f32],
    prev: &mut [i32],
    sel: &[i32],
    reward: &[f64],
    active: &[f32],
    k: usize,
) {
    const L: usize = LANES_F32;
    let b = sel.len();
    let chunks = b / L;
    for c in 0..chunks {
        let e0 = c * L;
        let mut idx = [0usize; L];
        let mut n_new = [0.0f32; L];
        let mut m_new = [0.0f32; L];
        for l in 0..L {
            let e = e0 + l;
            let i = e * k + sel[e] as usize;
            idx[l] = i;
            let a = active[e];
            let r = reward[e] as f32;
            let n_sel = n[i] + a;
            n_new[l] = n_sel;
            let delta = (r - mean[i]) / n_sel.max(1.0) * a;
            m_new[l] = mean[i] + delta;
        }
        for l in 0..L {
            n[idx[l]] = n_new[l];
            mean[idx[l]] = m_new[l];
            let e = e0 + l;
            if active[e] > 0.0 {
                prev[e] = sel[e];
            }
        }
    }
    for e in (chunks * L)..b {
        // The scalar reference body.
        let a = active[e];
        let s = sel[e] as usize;
        let idx = e * k + s;
        let r = reward[e] as f32;
        let n_sel = n[idx] + a;
        n[idx] = n_sel;
        let delta = (r - mean[idx]) / n_sel.max(1.0) * a;
        mean[idx] += delta;
        if a > 0.0 {
            prev[e] = sel[e];
        }
    }
}

/// Portable lane-chunked UCB1 select. The warm-start scan ("play each
/// feasible arm once, in index order") stays scalar — it is a short
/// early-exit search, not arithmetic — and implies every feasible arm
/// has `n ≥ 1` when the scoring loop runs, keeping feasible scores
/// finite (the masking-equivalence precondition, see module docs).
pub(super) fn ucb1_select_into(
    n: &[u64],
    mean: &[f64],
    alpha: f64,
    t: u64,
    feasible: &[f32],
    k: usize,
    sel: &mut [i32],
) {
    const L: usize = LANES_F64;
    let b = sel.len();
    let ln_t = (t.max(2) as f64).ln();
    let chunks = k / L;
    for e in 0..b {
        let row = e * k;
        if let Some(i) = (0..k).find(|&i| feasible[row + i] > 0.0 && n[row + i] == 0) {
            sel[e] = i as i32;
            continue;
        }
        let mut lane_v = [f64::NEG_INFINITY; L];
        let mut lane_arm = [0i32; L];
        for c in 0..chunks {
            let base = row + c * L;
            let arm0 = (c * L) as i32;
            let mut v = [0.0f64; L];
            for l in 0..L {
                let vl = mean[base + l] + alpha * (ln_t / n[base + l] as f64).sqrt();
                v[l] = if feasible[base + l] > 0.0 { vl } else { f64::NEG_INFINITY };
            }
            for l in 0..L {
                if v[l] > lane_v[l] {
                    lane_v[l] = v[l];
                    lane_arm[l] = arm0 + l as i32;
                }
            }
        }
        let (mut best_v, mut best_arm) = merge_lanes_f64(&lane_v, &lane_arm, chunks);
        for i in (chunks * L)..k {
            // The scalar reference body.
            if feasible[row + i] <= 0.0 {
                continue;
            }
            let v = mean[row + i] + alpha * (ln_t / n[row + i] as f64).sqrt();
            if v > best_v {
                best_v = v;
                best_arm = i as i32;
            }
        }
        sel[e] = best_arm;
    }
}

/// Portable lane-chunked SW-UCB select (same masking argument as UCB1:
/// windowed sums and bonuses of feasible arms are finite, so `-inf`
/// masking is equivalent to the scalar `continue`).
#[allow(clippy::too_many_arguments)]
pub(super) fn swucb_select_into(
    sum: &[f64],
    n: &[u64],
    prev: &[i32],
    alpha: f64,
    lambda: f64,
    horizon: f64,
    feasible: &[f32],
    k: usize,
    sel: &mut [i32],
) {
    const L: usize = LANES_F64;
    let b = sel.len();
    let ln_h = horizon.ln();
    let chunks = k / L;
    for e in 0..b {
        let row = e * k;
        let prev_e = prev[e];
        let mut lane_v = [f64::NEG_INFINITY; L];
        let mut lane_arm = [0i32; L];
        for c in 0..chunks {
            let base = row + c * L;
            let arm0 = (c * L) as i32;
            let mut v = [0.0f64; L];
            for l in 0..L {
                let ni = n[base + l];
                let bonus = alpha * (ln_h / (ni.max(1) as f64)).sqrt();
                let m = if ni > 0 { sum[base + l] / ni as f64 } else { 0.0 };
                let arm = arm0 + l as i32;
                let penalty = if prev_e >= 0 && prev_e != arm { lambda } else { 0.0 };
                let vl = m + bonus - penalty;
                v[l] = if feasible[base + l] > 0.0 { vl } else { f64::NEG_INFINITY };
            }
            for l in 0..L {
                if v[l] > lane_v[l] {
                    lane_v[l] = v[l];
                    lane_arm[l] = arm0 + l as i32;
                }
            }
        }
        let (mut best_v, mut best_arm) = merge_lanes_f64(&lane_v, &lane_arm, chunks);
        for i in (chunks * L)..k {
            // The scalar reference body.
            if feasible[row + i] <= 0.0 {
                continue;
            }
            let ni = n[row + i];
            let bonus = alpha * (ln_h / (ni.max(1) as f64)).sqrt();
            let mean = if ni > 0 { sum[row + i] / ni as f64 } else { 0.0 };
            let penalty = if prev_e >= 0 && prev_e != i as i32 { lambda } else { 0.0 };
            let v = mean + bonus - penalty;
            if v > best_v {
                best_v = v;
                best_arm = i as i32;
            }
        }
        sel[e] = best_arm;
    }
}
