//! Decision-kernel dispatch: one enum naming every select/update kernel
//! implementation, runtime CPU-feature detection, and the force-scalar
//! escape hatch.
//!
//! Every kernel is **bit-identical** by contract (`tests/simd_conformance.rs`
//! pins SIMD == scalar bit-for-bit across the full shape matrix), so
//! dispatch is purely a performance choice — switching kernels can never
//! change a trajectory. Resolution order, applied once per process and
//! cached:
//!
//! 1. `ENERGYUCB_FORCE_SCALAR` (any non-empty value other than `0`) pins
//!    the preserved scalar reference — the conformance escape hatch.
//! 2. `ENERGYUCB_KERNEL=scalar|portable|sse2|avx2` picks an explicit
//!    kernel; names the host cannot run (or typos) fall through to
//!    auto-detection rather than crashing a run.
//! 3. Auto-detection: AVX2 where the CPU reports it, the always-present
//!    SSE2 baseline elsewhere on x86_64, and the portable lane-chunked
//!    kernel on every other architecture.

use std::sync::atomic::{AtomicU8, Ordering};

/// A decision-kernel implementation. `Scalar` is the preserved pre-SIMD
/// reference (`batch::scalar`); the others are the lane-chunked rewrites
/// it is the conformance baseline for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// The scalar conformance reference (verbatim pre-SIMD loops).
    Scalar,
    /// Portable fixed-width lane chunks (8×f32 / 4×f64) in plain Rust —
    /// the autovectorizer maps lanes onto whatever the target offers.
    Portable,
    /// `core::arch` 128-bit f32 path (part of the x86_64 baseline ISA).
    #[cfg(target_arch = "x86_64")]
    Sse2,
    /// `core::arch` 256-bit f32 path (runtime-detected).
    #[cfg(target_arch = "x86_64")]
    Avx2,
}

impl Kernel {
    /// The `ENERGYUCB_KERNEL` grammar name (also used in bench labels).
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Portable => "portable",
            #[cfg(target_arch = "x86_64")]
            Kernel::Sse2 => "sse2",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => "avx2",
        }
    }

    /// Parse a kernel name (case-insensitive); `None` for unknown names
    /// and for `core::arch` names on foreign architectures.
    pub fn parse(name: &str) -> Option<Kernel> {
        match name.to_ascii_lowercase().as_str() {
            "scalar" => Some(Kernel::Scalar),
            "portable" => Some(Kernel::Portable),
            #[cfg(target_arch = "x86_64")]
            "sse2" => Some(Kernel::Sse2),
            #[cfg(target_arch = "x86_64")]
            "avx2" => Some(Kernel::Avx2),
            _ => None,
        }
    }

    /// Can the running host execute this kernel?
    pub fn supported(self) -> bool {
        match self {
            Kernel::Scalar | Kernel::Portable => true,
            // SSE2 is part of the x86_64 baseline ISA.
            #[cfg(target_arch = "x86_64")]
            Kernel::Sse2 => true,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => is_x86_feature_detected!("avx2"),
        }
    }

    /// Every kernel the running host can execute, scalar first — the
    /// conformance matrix and the bench sweep iterate this.
    pub fn available() -> Vec<Kernel> {
        // `mut` is only exercised on x86_64 (the cfg block below).
        #[allow(unused_mut)]
        let mut out = vec![Kernel::Scalar, Kernel::Portable];
        #[cfg(target_arch = "x86_64")]
        {
            out.push(Kernel::Sse2);
            if Kernel::Avx2.supported() {
                out.push(Kernel::Avx2);
            }
        }
        out
    }
}

/// Cached dispatch decision: 0 = unresolved, otherwise `encode(kernel)`.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn encode(k: Kernel) -> u8 {
    match k {
        Kernel::Scalar => 1,
        Kernel::Portable => 2,
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse2 => 3,
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => 4,
    }
}

fn decode(code: u8) -> Option<Kernel> {
    match code {
        1 => Some(Kernel::Scalar),
        2 => Some(Kernel::Portable),
        #[cfg(target_arch = "x86_64")]
        3 => Some(Kernel::Sse2),
        #[cfg(target_arch = "x86_64")]
        4 => Some(Kernel::Avx2),
        _ => None,
    }
}

/// The kernel the dispatching free functions route to. Resolved once
/// (env + CPU detection) and cached; racing first calls resolve to the
/// same answer, so the relaxed ordering is fine.
pub(super) fn active() -> Kernel {
    match decode(ACTIVE.load(Ordering::Relaxed)) {
        Some(k) => k,
        None => {
            let k = detect();
            ACTIVE.store(encode(k), Ordering::Relaxed);
            k
        }
    }
}

/// Pin dispatch to `kernel` for the rest of the process (benches, tests).
pub(super) fn force(kernel: Kernel) {
    ACTIVE.store(encode(kernel), Ordering::Relaxed);
}

fn env_truthy(var: &str) -> bool {
    std::env::var(var).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

fn detect() -> Kernel {
    if env_truthy("ENERGYUCB_FORCE_SCALAR") {
        return Kernel::Scalar;
    }
    if let Ok(name) = std::env::var("ENERGYUCB_KERNEL") {
        if let Some(k) = Kernel::parse(&name) {
            if k.supported() {
                return k;
            }
        }
        // Unknown or host-unsupported names fall through to detection:
        // a typo cannot change results (kernels are bit-identical) and
        // must not crash a run on a weaker host.
    }
    auto()
}

#[cfg(target_arch = "x86_64")]
fn auto() -> Kernel {
    if is_x86_feature_detected!("avx2") {
        Kernel::Avx2
    } else {
        Kernel::Sse2
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn auto() -> Kernel {
    Kernel::Portable
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip_through_parse() {
        for k in Kernel::available() {
            assert_eq!(Kernel::parse(k.name()), Some(k));
            assert_eq!(Kernel::parse(&k.name().to_ascii_uppercase()), Some(k));
        }
        assert_eq!(Kernel::parse("neon"), None);
        assert_eq!(Kernel::parse(""), None);
    }

    #[test]
    fn encode_decode_round_trips() {
        for k in Kernel::available() {
            assert_eq!(decode(encode(k)), Some(k));
        }
        assert_eq!(decode(0), None);
        assert_eq!(decode(255), None);
    }

    #[test]
    fn available_kernels_are_supported_and_lead_with_scalar() {
        let ks = Kernel::available();
        assert_eq!(ks[0], Kernel::Scalar);
        assert!(ks.contains(&Kernel::Portable));
        assert!(ks.iter().all(|k| k.supported()));
    }

    #[test]
    fn active_resolves_to_a_supported_kernel() {
        let k = active();
        assert!(k.supported());
        // Cached: a second resolution returns the same kernel.
        assert_eq!(active(), k);
    }
}
