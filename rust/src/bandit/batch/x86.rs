//! `core::arch` x86_64 kernels for the f32 SA-UCB core: a 4-lane SSE2
//! path (always available — SSE2 is part of the x86_64 baseline ISA) and
//! an 8-lane AVX2 path behind runtime detection ([`super::dispatch`]).
//!
//! Bit-exactness: only exactly-rounded vector operations are used —
//! add/sub/mul/div/sqrt/max, compares, and bitwise blends. Never the
//! approximate `rcpps`/`rsqrtps`, and never FMA (scalar Rust does not
//! contract `a * b + c` either, so fusing here would *break* parity).
//! Each lane therefore computes bit-for-bit what the scalar reference
//! computes; the horizontal argmax merge reuses the lane-order argument
//! (and helper) from [`super::portable`]. `_mm*_max_ps` differs from
//! `f32::max` only on NaN/±0 operands, which the SA-UCB operands (counts
//! ≥ 0, positive epsilons) cannot produce.
//!
//! The f64 UCB1/SW-UCB selects stay on the portable kernels: their cost
//! is dominated by u64→f64 conversions and short-row scans, which
//! SSE2/AVX2 cannot improve without changing the operation stream.

use core::arch::x86_64::*;

use super::portable::merge_lanes_f32;
use super::{SaUcbHyper, NEG_LARGE};

/// 8-lane AVX2 SA-UCB select.
///
/// # Safety
/// Requires the `avx2` CPU feature (the dispatcher only routes here
/// after `is_x86_feature_detected!("avx2")`).
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(super) unsafe fn saucb_select_into_avx2(
    n: &[f32],
    mean: &[f32],
    prev: &[i32],
    t: f32,
    feasible: &[f32],
    hyper: &SaUcbHyper,
    k: usize,
    sel: &mut [i32],
) {
    const L: usize = 8;
    let b = prev.len();
    let ln_t = t.max(2.0).ln();
    let (alpha, lambda, mu_init, prior_n) =
        (hyper.alpha, hyper.lambda, hyper.mu_init, hyper.prior_n);
    let prior_mu = prior_n * mu_init;
    let chunks = k / L;

    let v_alpha = _mm256_set1_ps(alpha);
    let v_lambda = _mm256_set1_ps(lambda);
    let v_mu_init = _mm256_set1_ps(mu_init);
    let v_prior_n = _mm256_set1_ps(prior_n);
    let v_prior_mu = _mm256_set1_ps(prior_mu);
    let v_ln_t = _mm256_set1_ps(ln_t);
    let v_one = _mm256_set1_ps(1.0);
    let v_eps = _mm256_set1_ps(1e-12);
    let v_zero = _mm256_setzero_ps();
    let v_neg_large = _mm256_set1_ps(NEG_LARGE);
    let v_lane = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);

    for e in 0..b {
        let row = e * k;
        let prev_e = prev[e];
        let v_prev = _mm256_set1_epi32(prev_e);
        let mut v_best = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut v_best_arm = _mm256_setzero_si256();
        for c in 0..chunks {
            let base = row + c * L;
            let v_ni = _mm256_loadu_ps(n.as_ptr().add(base));
            let v_mean = _mm256_loadu_ps(mean.as_ptr().add(base));
            let v_feas = _mm256_loadu_ps(feasible.as_ptr().add(base));
            // mu_hat: prior-shrunk mean where denom > 0, mu_init where
            // denom == 0 (the discarded branch's value is finite and
            // dropped by the blend, matching the scalar conditional).
            let v_denom = _mm256_add_ps(v_prior_n, v_ni);
            let v_raw = _mm256_div_ps(
                _mm256_add_ps(v_prior_mu, _mm256_mul_ps(v_ni, v_mean)),
                _mm256_max_ps(v_denom, v_eps),
            );
            let m_denom = _mm256_cmp_ps::<_CMP_GT_OQ>(v_denom, v_zero);
            let v_mu_hat = _mm256_blendv_ps(v_mu_init, v_raw, m_denom);
            let v_bonus = _mm256_mul_ps(
                v_alpha,
                _mm256_sqrt_ps(_mm256_div_ps(v_ln_t, _mm256_max_ps(v_ni, v_one))),
            );
            // Penalty λ on every arm except prev (andnot: mask-cleared).
            let v_arm = _mm256_add_epi32(_mm256_set1_epi32((c * L) as i32), v_lane);
            let m_prev = _mm256_cmpeq_epi32(v_arm, v_prev);
            let v_penalty = _mm256_andnot_ps(_mm256_castsi256_ps(m_prev), v_lambda);
            let v_score = _mm256_sub_ps(_mm256_add_ps(v_mu_hat, v_bonus), v_penalty);
            let m_feas = _mm256_cmp_ps::<_CMP_GT_OQ>(v_feas, v_zero);
            let v_masked = _mm256_blendv_ps(v_neg_large, v_score, m_feas);
            // Per-lane running argmax on strict > (first-index within
            // each lane's residue class; see portable module docs).
            let m_gt = _mm256_cmp_ps::<_CMP_GT_OQ>(v_masked, v_best);
            v_best = _mm256_blendv_ps(v_best, v_masked, m_gt);
            v_best_arm = _mm256_blendv_epi8(v_best_arm, v_arm, _mm256_castps_si256(m_gt));
        }
        let mut lane_v = [0.0f32; L];
        let mut lane_arm = [0i32; L];
        _mm256_storeu_ps(lane_v.as_mut_ptr(), v_best);
        _mm256_storeu_si256(lane_arm.as_mut_ptr() as *mut __m256i, v_best_arm);
        let (mut best_v, mut best_arm) = merge_lanes_f32(&lane_v, &lane_arm, chunks);
        for i in (chunks * L)..k {
            // The scalar reference body, continuing the strict-> scan.
            let ni = n[row + i];
            let denom = prior_n + ni;
            let mu_hat = if denom > 0.0 {
                (prior_mu + ni * mean[row + i]) / denom.max(1e-12)
            } else {
                mu_init
            };
            let bonus = alpha * (ln_t / ni.max(1.0)).sqrt();
            let penalty = if i as i32 != prev_e { lambda } else { 0.0 };
            let mut v = mu_hat + bonus - penalty;
            if feasible[row + i] <= 0.0 {
                v = NEG_LARGE;
            }
            if v > best_v {
                best_v = v;
                best_arm = i as i32;
            }
        }
        sel[e] = best_arm;
    }
}

/// 4-lane SSE2 SA-UCB select. Safe to call on any x86_64 host (SSE2 is
/// baseline); SSE2 has no `blendv`, so blends are and/andnot/or.
#[allow(clippy::too_many_arguments)]
pub(super) fn saucb_select_into_sse2(
    n: &[f32],
    mean: &[f32],
    prev: &[i32],
    t: f32,
    feasible: &[f32],
    hyper: &SaUcbHyper,
    k: usize,
    sel: &mut [i32],
) {
    const L: usize = 4;
    let b = prev.len();
    let ln_t = t.max(2.0).ln();
    let (alpha, lambda, mu_init, prior_n) =
        (hyper.alpha, hyper.lambda, hyper.mu_init, hyper.prior_n);
    let prior_mu = prior_n * mu_init;
    let chunks = k / L;

    // Safety: all intrinsics below are SSE2, statically present in the
    // x86_64 baseline target; loads stay in-bounds (base + 4 <= row + k).
    unsafe {
        let v_alpha = _mm_set1_ps(alpha);
        let v_lambda = _mm_set1_ps(lambda);
        let v_mu_init = _mm_set1_ps(mu_init);
        let v_prior_n = _mm_set1_ps(prior_n);
        let v_prior_mu = _mm_set1_ps(prior_mu);
        let v_ln_t = _mm_set1_ps(ln_t);
        let v_one = _mm_set1_ps(1.0);
        let v_eps = _mm_set1_ps(1e-12);
        let v_zero = _mm_setzero_ps();
        let v_neg_large = _mm_set1_ps(NEG_LARGE);
        let v_lane = _mm_setr_epi32(0, 1, 2, 3);

        for e in 0..b {
            let row = e * k;
            let prev_e = prev[e];
            let v_prev = _mm_set1_epi32(prev_e);
            let mut v_best = _mm_set1_ps(f32::NEG_INFINITY);
            let mut v_best_arm = _mm_setzero_si128();
            for c in 0..chunks {
                let base = row + c * L;
                let v_ni = _mm_loadu_ps(n.as_ptr().add(base));
                let v_mean = _mm_loadu_ps(mean.as_ptr().add(base));
                let v_feas = _mm_loadu_ps(feasible.as_ptr().add(base));
                let v_denom = _mm_add_ps(v_prior_n, v_ni);
                let v_raw = _mm_div_ps(
                    _mm_add_ps(v_prior_mu, _mm_mul_ps(v_ni, v_mean)),
                    _mm_max_ps(v_denom, v_eps),
                );
                let m_denom = _mm_cmpgt_ps(v_denom, v_zero);
                let v_mu_hat = blend_ps(v_mu_init, v_raw, m_denom);
                let v_bonus = _mm_mul_ps(
                    v_alpha,
                    _mm_sqrt_ps(_mm_div_ps(v_ln_t, _mm_max_ps(v_ni, v_one))),
                );
                let v_arm = _mm_add_epi32(_mm_set1_epi32((c * L) as i32), v_lane);
                let m_prev = _mm_cmpeq_epi32(v_arm, v_prev);
                let v_penalty = _mm_andnot_ps(_mm_castsi128_ps(m_prev), v_lambda);
                let v_score = _mm_sub_ps(_mm_add_ps(v_mu_hat, v_bonus), v_penalty);
                let m_feas = _mm_cmpgt_ps(v_feas, v_zero);
                let v_masked = blend_ps(v_neg_large, v_score, m_feas);
                let m_gt = _mm_cmpgt_ps(v_masked, v_best);
                v_best = blend_ps(v_best, v_masked, m_gt);
                v_best_arm = blend_si128(v_best_arm, v_arm, _mm_castps_si128(m_gt));
            }
            let mut lane_v = [0.0f32; L];
            let mut lane_arm = [0i32; L];
            _mm_storeu_ps(lane_v.as_mut_ptr(), v_best);
            _mm_storeu_si128(lane_arm.as_mut_ptr() as *mut __m128i, v_best_arm);
            let (mut best_v, mut best_arm) = merge_lanes_f32(&lane_v, &lane_arm, chunks);
            for i in (chunks * L)..k {
                // The scalar reference body, continuing the strict-> scan.
                let ni = n[row + i];
                let denom = prior_n + ni;
                let mu_hat = if denom > 0.0 {
                    (prior_mu + ni * mean[row + i]) / denom.max(1e-12)
                } else {
                    mu_init
                };
                let bonus = alpha * (ln_t / ni.max(1.0)).sqrt();
                let penalty = if i as i32 != prev_e { lambda } else { 0.0 };
                let mut v = mu_hat + bonus - penalty;
                if feasible[row + i] <= 0.0 {
                    v = NEG_LARGE;
                }
                if v > best_v {
                    best_v = v;
                    best_arm = i as i32;
                }
            }
            sel[e] = best_arm;
        }
    }
}

/// `mask ? b : a` per f32 lane (SSE2 has no `blendv_ps`).
#[inline(always)]
fn blend_ps(a: __m128, b: __m128, mask: __m128) -> __m128 {
    unsafe { _mm_or_ps(_mm_and_ps(mask, b), _mm_andnot_ps(mask, a)) }
}

/// `mask ? b : a` per 128-bit integer lane group.
#[inline(always)]
fn blend_si128(a: __m128i, b: __m128i, mask: __m128i) -> __m128i {
    unsafe { _mm_or_si128(_mm_and_si128(mask, b), _mm_andnot_si128(mask, a)) }
}

/// 8-lane AVX2 incremental-mean update: gather the selected cells
/// (`vgatherdps`), fold on registers, scalar scatter (indices are unique
/// per chunk — one cell per environment — so no aliasing). The f64→f32
/// reward narrowing uses `vcvtpd2ps`, the same round-to-nearest-even as
/// the scalar `as f32` cast.
///
/// # Safety
/// Requires the `avx2` CPU feature. Grid cell indices must fit in i32
/// (`b * k <= i32::MAX`; a fleet that large would need > 8 GiB of grid
/// memory — debug-asserted).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn grid_update_batch_avx2(
    n: &mut [f32],
    mean: &mut [f32],
    prev: &mut [i32],
    sel: &[i32],
    reward: &[f64],
    active: &[f32],
    k: usize,
) {
    const L: usize = 8;
    let b = sel.len();
    debug_assert!(b.saturating_mul(k) <= i32::MAX as usize);
    let chunks = b / L;
    let v_one = _mm256_set1_ps(1.0);
    for c in 0..chunks {
        let e0 = c * L;
        let mut idx = [0i32; L];
        for (l, slot) in idx.iter_mut().enumerate() {
            let e = e0 + l;
            *slot = (e * k + sel[e] as usize) as i32;
        }
        let v_idx = _mm256_loadu_si256(idx.as_ptr() as *const __m256i);
        let v_n = _mm256_i32gather_ps::<4>(n.as_ptr(), v_idx);
        let v_m = _mm256_i32gather_ps::<4>(mean.as_ptr(), v_idx);
        let v_a = _mm256_loadu_ps(active.as_ptr().add(e0));
        let r_lo = _mm256_cvtpd_ps(_mm256_loadu_pd(reward.as_ptr().add(e0)));
        let r_hi = _mm256_cvtpd_ps(_mm256_loadu_pd(reward.as_ptr().add(e0 + 4)));
        let v_r = _mm256_set_m128(r_hi, r_lo);
        let v_n_sel = _mm256_add_ps(v_n, v_a);
        let v_delta = _mm256_mul_ps(
            _mm256_div_ps(_mm256_sub_ps(v_r, v_m), _mm256_max_ps(v_n_sel, v_one)),
            v_a,
        );
        let v_m_new = _mm256_add_ps(v_m, v_delta);
        let mut n_new = [0.0f32; L];
        let mut m_new = [0.0f32; L];
        _mm256_storeu_ps(n_new.as_mut_ptr(), v_n_sel);
        _mm256_storeu_ps(m_new.as_mut_ptr(), v_m_new);
        for l in 0..L {
            let i = idx[l] as usize;
            n[i] = n_new[l];
            mean[i] = m_new[l];
            let e = e0 + l;
            if active[e] > 0.0 {
                prev[e] = sel[e];
            }
        }
    }
    for e in (chunks * L)..b {
        // The scalar reference body.
        let a = active[e];
        let s = sel[e] as usize;
        let idx = e * k + s;
        let r = reward[e] as f32;
        let n_sel = n[idx] + a;
        n[idx] = n_sel;
        let delta = (r - mean[idx]) / n_sel.max(1.0) * a;
        mean[idx] += delta;
        if a > 0.0 {
            prev[e] = sel[e];
        }
    }
}
