//! The scalar decision kernels — the preserved pre-SIMD reference
//! implementations and the conformance baseline every lane-chunked
//! kernel is pinned against, bit-for-bit (`tests/simd_conformance.rs`).
//!
//! The loop bodies are the original per-arm scans verbatim, with one
//! class of change: loop-invariant subexpressions (`ln t`, the
//! hyper-parameter field reloads, `prior_n·mu_init`) are hoisted out of
//! the arm loops. Each hoist is provably value-preserving — a pure
//! function of per-call constants computed once instead of per arm, the
//! same IEEE operation on the same operands — so the f32/f64 streams are
//! unchanged and the scalar baseline in `benches/engine.rs` measures the
//! decision arithmetic, not redundant loads.

use super::{SaUcbHyper, NEG_LARGE};

/// Scalar SA-UCB select: the reference for [`super::saucb_select_into`].
#[allow(clippy::too_many_arguments)]
pub(super) fn saucb_select_into(
    n: &[f32],
    mean: &[f32],
    prev: &[i32],
    t: f32,
    feasible: &[f32],
    hyper: &SaUcbHyper,
    k: usize,
    sel: &mut [i32],
) {
    let b = prev.len();
    let ln_t = t.max(2.0).ln();
    let (alpha, lambda, mu_init, prior_n) =
        (hyper.alpha, hyper.lambda, hyper.mu_init, hyper.prior_n);
    let prior_mu = prior_n * mu_init;
    for e in 0..b {
        let row = e * k;
        let prev_e = prev[e];
        let mut best_arm = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for i in 0..k {
            let ni = n[row + i];
            let denom = prior_n + ni;
            let mu_hat = if denom > 0.0 {
                (prior_mu + ni * mean[row + i]) / denom.max(1e-12)
            } else {
                mu_init
            };
            let bonus = alpha * (ln_t / ni.max(1.0)).sqrt();
            let penalty = if i as i32 != prev_e { lambda } else { 0.0 };
            let mut v = mu_hat + bonus - penalty;
            if feasible[row + i] <= 0.0 {
                v = NEG_LARGE;
            }
            if v > best_v {
                best_v = v;
                best_arm = i;
            }
        }
        sel[e] = best_arm as i32;
    }
}

/// Scalar incremental-mean update: the reference for
/// [`super::grid_update_batch`].
pub(super) fn grid_update_batch(
    n: &mut [f32],
    mean: &mut [f32],
    prev: &mut [i32],
    sel: &[i32],
    reward: &[f64],
    active: &[f32],
    k: usize,
) {
    for e in 0..sel.len() {
        let a = active[e];
        let s = sel[e] as usize;
        let idx = e * k + s;
        let r = reward[e] as f32;
        let n_sel = n[idx] + a;
        n[idx] = n_sel;
        let delta = (r - mean[idx]) / n_sel.max(1.0) * a;
        mean[idx] += delta;
        if a > 0.0 {
            prev[e] = sel[e];
        }
    }
}

/// Scalar UCB1 select (the `BatchUcb1` arm scan, extracted): play each
/// feasible arm once in index order, then the masked UCB argmax. The
/// reference for [`super::ucb1_select_into`].
pub(super) fn ucb1_select_into(
    n: &[u64],
    mean: &[f64],
    alpha: f64,
    t: u64,
    feasible: &[f32],
    k: usize,
    sel: &mut [i32],
) {
    let b = sel.len();
    let ln_t = (t.max(2) as f64).ln();
    for e in 0..b {
        let row = e * k;
        // Play each (feasible) arm once first, in index order.
        if let Some(i) = (0..k).find(|&i| feasible[row + i] > 0.0 && n[row + i] == 0) {
            sel[e] = i as i32;
            continue;
        }
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for i in 0..k {
            if feasible[row + i] <= 0.0 {
                continue;
            }
            let v = mean[row + i] + alpha * (ln_t / n[row + i] as f64).sqrt();
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        sel[e] = best as i32;
    }
}

/// Scalar SW-UCB select (the `BatchSwUcb` arm scan, extracted):
/// windowed-mean UCB with switching penalty and optimistic unseen arms.
/// The reference for [`super::swucb_select_into`].
#[allow(clippy::too_many_arguments)]
pub(super) fn swucb_select_into(
    sum: &[f64],
    n: &[u64],
    prev: &[i32],
    alpha: f64,
    lambda: f64,
    horizon: f64,
    feasible: &[f32],
    k: usize,
    sel: &mut [i32],
) {
    let b = sel.len();
    let ln_h = horizon.ln();
    for e in 0..b {
        let row = e * k;
        let prev_e = prev[e];
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for i in 0..k {
            if feasible[row + i] <= 0.0 {
                continue;
            }
            let ni = n[row + i];
            let bonus = alpha * (ln_h / (ni.max(1) as f64)).sqrt();
            // Optimistic (mean 0) when unseen inside the window.
            let mean = if ni > 0 { sum[row + i] / ni as f64 } else { 0.0 };
            let penalty = if prev_e >= 0 && prev_e != i as i32 { lambda } else { 0.0 };
            let v = mean + bonus - penalty;
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        sel[e] = best as i32;
    }
}
