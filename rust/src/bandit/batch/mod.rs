//! Batch-native policy core: B independent bandit environments stepped
//! through one SoA (structure-of-arrays) decision surface.
//!
//! This module is the **single source of decision arithmetic** for all
//! three execution tiers:
//!
//! * the scalar session path (`control::session`) drives a B = 1
//!   [`Scalar`] bridge,
//! * the native fleet (`fleet::native`) calls [`saucb_select_into`] /
//!   [`grid_update_batch`] directly on the `FleetState` grids (the AOT
//!   artifact state contract), and
//! * the generic fleet runner (`fleet::policy`) drives any
//!   [`BatchPolicy`] — native SoA implementations where they exist, the
//!   [`Scalar`] bridge everywhere else.
//!
//! ## Determinism contract (EXPERIMENTS.md §Engine)
//!
//! Grids are row-major `(B, K)` slices. Argmax ties break to the first
//! index (strict `>` scan from arm 0). The SA-UCB family
//! ([`BatchEnergyUcb`], [`BatchConstrainedEnergyUcb`]) computes in f32
//! with exactly the operation order of the python reference
//! (`python/compile/kernels/ref.py`), so fleet trajectories stay
//! bit-identical to the exported HLO artifacts. The remaining native
//! batch policies ([`BatchUcb1`], [`BatchSwUcb`], [`BatchEpsilonGreedy`])
//! compute in f64 with exactly their scalar counterpart's operation
//! order, so a B = 1 batch reproduces the scalar trajectory bit-for-bit.
//! Rewards and progress cross the trait boundary as f64 (an f32-core
//! policy casts back — exact, because the fleet synthesizes rewards in
//! f32 and f32→f64→f32 round-trips losslessly); feasibility and
//! active masks are f32 `{0, 1}`, matching the artifact layout.
//!
//! ## Kernel dispatch (EXPERIMENTS.md §Engine)
//!
//! The free select/update functions dispatch to one of several
//! bit-identical kernel implementations (see [`Kernel`]): the preserved
//! scalar reference ([`scalar`]), a portable lane-chunked rewrite
//! ([`portable`]), and `core::arch` SSE2/AVX2 paths on x86_64
//! ([`x86`]). Dispatch is resolved once per process —
//! `ENERGYUCB_FORCE_SCALAR`, then `ENERGYUCB_KERNEL`, then CPU feature
//! detection — and is *purely* a performance choice: the conformance
//! suite (`tests/simd_conformance.rs`) pins every kernel against the
//! scalar reference bit-for-bit, so trajectories (and the fleet HLO
//! artifact contract) cannot depend on the host's vector unit. The
//! `*_with` variants take an explicit [`Kernel`] for benches and
//! conformance tests.

use std::collections::VecDeque;

use super::energyucb::EnergyUcbConfig;
use super::Policy;
use crate::util::Rng;

mod dispatch;
mod portable;
mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;

pub use dispatch::Kernel;

/// The kernel the free select/update functions currently dispatch to
/// (resolved once; see [`Kernel`] and the module docs for the order).
pub fn active_kernel() -> Kernel {
    dispatch::active()
}

/// Pin dispatch to `kernel` for the rest of the process (benches,
/// conformance runs). Safe at any point — kernels are bit-identical —
/// but panics if the host cannot execute the requested kernel.
pub fn force_kernel(kernel: Kernel) {
    assert!(kernel.supported(), "kernel '{}' not supported on this host", kernel.name());
    dispatch::force(kernel);
}

/// Effectively -inf for f32 masking without NaN risk (matches the python
/// reference's `NEG_LARGE`).
pub const NEG_LARGE: f32 = -3.0e38;

/// SA-UCB hyper-parameters in the f32 artifact layout (the same values as
/// [`EnergyUcbConfig`], narrowed). Re-exported as `fleet::FleetHyper`.
#[derive(Clone, Copy, Debug)]
pub struct SaUcbHyper {
    pub alpha: f32,
    pub lambda: f32,
    pub mu_init: f32,
    pub prior_n: f32,
}

impl From<&EnergyUcbConfig> for SaUcbHyper {
    fn from(c: &EnergyUcbConfig) -> SaUcbHyper {
        SaUcbHyper {
            alpha: c.alpha as f32,
            lambda: c.lambda as f32,
            mu_init: c.mu_init as f32,
            prior_n: c.prior_n as f32,
        }
    }
}

impl Default for SaUcbHyper {
    fn default() -> Self {
        (&EnergyUcbConfig::default()).into()
    }
}

/// A batch of frequency-selection policies advanced in lockstep: one
/// decision per environment per step, over caller-provided buffers — the
/// hot loop performs no allocations.
///
/// `feasible` is the row-major `(B, K)` QoS mask (`1.0` = allowed). The
/// SA-UCB family honors it exactly (masked arms get [`NEG_LARGE`]); the
/// other native batch policies restrict their scans to feasible arms
/// (identical to their scalar behavior when the mask is all-ones); the
/// [`Scalar`] bridge ignores it — wrapped scalar policies own their
/// feasibility (e.g. `ConstrainedEnergyUcb`).
pub trait BatchPolicy: Send {
    /// Display name ("EnergyUCB", "UCB1", "Mixed[...]", ...).
    fn name(&self) -> String;

    /// Number of environments.
    fn b(&self) -> usize;

    /// Number of arms.
    fn k(&self) -> usize;

    /// Choose one arm per environment for decision step `t` (1-based),
    /// writing into `sel` (length B).
    fn select_into(&mut self, t: u64, feasible: &[f32], sel: &mut [i32]);

    /// Context-carrying selection: `ctx` is the row-major `(B, D)`
    /// workload feature grid (`ctx[e*d..(e+1)*d]` is environment `e`'s
    /// feature vector — the serving tier's queue depth / arrival rate /
    /// occupancy / util ratio). Context-free policies ignore the grid
    /// and fall through to [`select_into`], so every existing policy is
    /// trivially context-compatible and the context-free fleet HLO
    /// bit-contract is untouched. Contextual policies
    /// ([`super::linucb::BatchLinUcb`]) override this.
    ///
    /// [`select_into`]: BatchPolicy::select_into
    fn select_into_ctx(&mut self, t: u64, feasible: &[f32], ctx: &[f64], d: usize, sel: &mut [i32]) {
        let _ = (ctx, d);
        self.select_into(t, feasible, sel)
    }

    /// Feed back the observed rewards: `reward[e]` / `progress[e]` were
    /// observed under arm `sel[e]`. `active[e]` ∈ {0, 1} freezes finished
    /// environments (their stats must not move).
    fn update_batch(&mut self, sel: &[i32], reward: &[f64], progress: &[f64], active: &[f32]);

    /// Reset all learned state (fresh run, byte-for-byte).
    fn reset(&mut self);
}

/// SA-UCB index + masked argmax over SoA grids — the paper's Eq. 5 in f32
/// with exactly the operation order of `kernels/ref.py::saucb_index_ref`
/// (the bit-level contract with the exported HLO artifacts).
///
/// `prev[e] = -1` means "no previous arm": every arm then carries the
/// penalty λ, a uniform shift that cannot change the argmax — the scalar
/// `prev = None` semantics.
///
/// ## All-infeasible rows
///
/// A row whose mask is entirely zero has no meaningful argmax: every arm
/// scores [`NEG_LARGE`] and the first-index tie-break pins `sel[e] = 0`,
/// deterministically, on every kernel (the conformance suite includes
/// all-zero rows). This is a *pinned fallback*, not a sanctioned input —
/// arm 0 is the lowest frequency, the opposite of a safe QoS default —
/// so mask builders must keep at least one feasible arm per row. The
/// shipped builders do (the QoS constraint always keeps the
/// max-frequency arm) and [`debug_assert_feasible_rows`] guards them in
/// debug builds.
#[allow(clippy::too_many_arguments)]
pub fn saucb_select_into(
    n: &[f32],
    mean: &[f32],
    prev: &[i32],
    t: f32,
    feasible: &[f32],
    hyper: &SaUcbHyper,
    k: usize,
    sel: &mut [i32],
) {
    saucb_select_into_with(dispatch::active(), n, mean, prev, t, feasible, hyper, k, sel);
}

/// [`saucb_select_into`] on an explicit kernel — the conformance-suite
/// and bench entry point (all kernels are bit-identical by contract).
#[allow(clippy::too_many_arguments)]
pub fn saucb_select_into_with(
    kernel: Kernel,
    n: &[f32],
    mean: &[f32],
    prev: &[i32],
    t: f32,
    feasible: &[f32],
    hyper: &SaUcbHyper,
    k: usize,
    sel: &mut [i32],
) {
    let b = prev.len();
    debug_assert_eq!(n.len(), b * k);
    debug_assert_eq!(mean.len(), b * k);
    debug_assert_eq!(feasible.len(), b * k);
    debug_assert_eq!(sel.len(), b);
    assert!(kernel.supported(), "kernel '{}' not supported on this host", kernel.name());
    match kernel {
        Kernel::Scalar => scalar::saucb_select_into(n, mean, prev, t, feasible, hyper, k, sel),
        Kernel::Portable => portable::saucb_select_into(n, mean, prev, t, feasible, hyper, k, sel),
        #[cfg(target_arch = "x86_64")]
        Kernel::Sse2 => x86::saucb_select_into_sse2(n, mean, prev, t, feasible, hyper, k, sel),
        #[cfg(target_arch = "x86_64")]
        // Safety: supported() just confirmed AVX2 on this host.
        Kernel::Avx2 => unsafe {
            x86::saucb_select_into_avx2(n, mean, prev, t, feasible, hyper, k, sel)
        },
    }
}

/// Incremental-mean grid update (Algorithm 1 line 12, vectorized): for each
/// environment, fold `reward[e]` into the selected arm's `(n, mean)` cell
/// and advance `prev` — all masked by `active`. f32, exactly the operation
/// order of `kernels/ref.py::fleet_step_ref`'s update block. Rewards arrive
/// as f64 and are narrowed; callers on the f32 fleet path synthesized them
/// in f32, so the narrowing is exact.
pub fn grid_update_batch(
    n: &mut [f32],
    mean: &mut [f32],
    prev: &mut [i32],
    sel: &[i32],
    reward: &[f64],
    active: &[f32],
    k: usize,
) {
    grid_update_batch_with(dispatch::active(), n, mean, prev, sel, reward, active, k);
}

/// [`grid_update_batch`] on an explicit kernel.
#[allow(clippy::too_many_arguments)]
pub fn grid_update_batch_with(
    kernel: Kernel,
    n: &mut [f32],
    mean: &mut [f32],
    prev: &mut [i32],
    sel: &[i32],
    reward: &[f64],
    active: &[f32],
    k: usize,
) {
    debug_assert_eq!(sel.len(), prev.len());
    debug_assert_eq!(reward.len(), prev.len());
    debug_assert_eq!(active.len(), prev.len());
    assert!(kernel.supported(), "kernel '{}' not supported on this host", kernel.name());
    match kernel {
        Kernel::Scalar => scalar::grid_update_batch(n, mean, prev, sel, reward, active, k),
        #[cfg(target_arch = "x86_64")]
        // Safety: supported() just confirmed AVX2 on this host.
        Kernel::Avx2 => unsafe {
            x86::grid_update_batch_avx2(n, mean, prev, sel, reward, active, k)
        },
        // The SSE2 tier reuses the portable chunked update: the fold is
        // gather/scatter-bound and SSE2 has no gather instruction.
        _ => portable::grid_update_batch(n, mean, prev, sel, reward, active, k),
    }
}

/// Masked UCB1 select over SoA grids (the [`BatchUcb1`] arm scan as a
/// free kernel — f64, exactly the scalar `Ucb1` operation order). Plays
/// each feasible arm once in index order, then the UCB argmax;
/// all-infeasible rows pin `sel[e] = 0` like [`saucb_select_into`].
pub fn ucb1_select_into(
    n: &[u64],
    mean: &[f64],
    alpha: f64,
    t: u64,
    feasible: &[f32],
    k: usize,
    sel: &mut [i32],
) {
    ucb1_select_into_with(dispatch::active(), n, mean, alpha, t, feasible, k, sel);
}

/// [`ucb1_select_into`] on an explicit kernel. The `core::arch` tiers
/// route to the portable f64 kernel (the f32 SA-UCB core is where
/// explicit intrinsics pay; see `batch::x86` docs).
#[allow(clippy::too_many_arguments)]
pub fn ucb1_select_into_with(
    kernel: Kernel,
    n: &[u64],
    mean: &[f64],
    alpha: f64,
    t: u64,
    feasible: &[f32],
    k: usize,
    sel: &mut [i32],
) {
    let b = sel.len();
    debug_assert_eq!(n.len(), b * k);
    debug_assert_eq!(mean.len(), b * k);
    debug_assert_eq!(feasible.len(), b * k);
    assert!(kernel.supported(), "kernel '{}' not supported on this host", kernel.name());
    match kernel {
        Kernel::Scalar => scalar::ucb1_select_into(n, mean, alpha, t, feasible, k, sel),
        _ => portable::ucb1_select_into(n, mean, alpha, t, feasible, k, sel),
    }
}

/// Masked SW-UCB select over SoA grids (the [`BatchSwUcb`] arm scan as a
/// free kernel — f64, exactly the scalar `SlidingWindowUcb` operation
/// order). `horizon` is the effective window `min(t, w).max(2)`;
/// all-infeasible rows pin `sel[e] = 0`.
#[allow(clippy::too_many_arguments)]
pub fn swucb_select_into(
    sum: &[f64],
    n: &[u64],
    prev: &[i32],
    alpha: f64,
    lambda: f64,
    horizon: f64,
    feasible: &[f32],
    k: usize,
    sel: &mut [i32],
) {
    swucb_select_into_with(
        dispatch::active(),
        sum,
        n,
        prev,
        alpha,
        lambda,
        horizon,
        feasible,
        k,
        sel,
    );
}

/// [`swucb_select_into`] on an explicit kernel (`core::arch` tiers route
/// to the portable f64 kernel, like UCB1).
#[allow(clippy::too_many_arguments)]
pub fn swucb_select_into_with(
    kernel: Kernel,
    sum: &[f64],
    n: &[u64],
    prev: &[i32],
    alpha: f64,
    lambda: f64,
    horizon: f64,
    feasible: &[f32],
    k: usize,
    sel: &mut [i32],
) {
    let b = sel.len();
    debug_assert_eq!(sum.len(), b * k);
    debug_assert_eq!(n.len(), b * k);
    debug_assert_eq!(prev.len(), b);
    debug_assert_eq!(feasible.len(), b * k);
    assert!(kernel.supported(), "kernel '{}' not supported on this host", kernel.name());
    match kernel {
        Kernel::Scalar => {
            scalar::swucb_select_into(sum, n, prev, alpha, lambda, horizon, feasible, k, sel)
        }
        _ => portable::swucb_select_into(sum, n, prev, alpha, lambda, horizon, feasible, k, sel),
    }
}

/// Debug-assert that every `(B, K)` mask row keeps at least one feasible
/// arm — the upstream guard for the all-infeasible fallback documented
/// on [`saucb_select_into`]. Mask *builders* call this right after
/// construction so a constraint bug surfaces where the mask is made, not
/// as a silent arm-0 pin deep in a fleet run. Release builds compile it
/// away (the select kernels themselves stay assert-free so the
/// conformance suite can fuzz all-zero rows).
pub fn debug_assert_feasible_rows(feasible: &[f32], k: usize) {
    if cfg!(debug_assertions) && k > 0 {
        for (e, row) in feasible.chunks_exact(k).enumerate() {
            debug_assert!(
                row.iter().any(|&f| f > 0.0),
                "mask row {e}: all {k} arms infeasible — select would pin arm 0"
            );
        }
    }
}

/// Batched EnergyUCB (SA-UCB + optimistic prior) over owned SoA grids —
/// the fleet's native controller. f32, bit-identical to
/// `fleet::native::native_step`'s decision path (both call the same core
/// functions). Supports the fleet contract: optimistic initialization, no
/// discounting (the scalar `EnergyUcb` covers the warmup/discount
/// ablations; `PolicyConfig::build_batch` bridges those configurations).
#[derive(Clone, Debug)]
pub struct BatchEnergyUcb {
    hyper: SaUcbHyper,
    b: usize,
    k: usize,
    n: Vec<f32>,
    mean: Vec<f32>,
    prev: Vec<i32>,
    init_prev: i32,
}

impl BatchEnergyUcb {
    /// Scalar semantics: no previous arm at start (`prev = -1`).
    pub fn new(b: usize, k: usize, hyper: SaUcbHyper) -> BatchEnergyUcb {
        Self::with_init_prev(b, k, hyper, -1)
    }

    /// Fleet semantics: every environment starts pinned to `arm` (the
    /// system default frequency, arm K-1 on Aurora), so the first
    /// departure from it is penalized — matching `FleetState::fresh`.
    pub fn with_initial_arm(b: usize, k: usize, hyper: SaUcbHyper, arm: usize) -> BatchEnergyUcb {
        assert!(arm < k);
        Self::with_init_prev(b, k, hyper, arm as i32)
    }

    fn with_init_prev(b: usize, k: usize, hyper: SaUcbHyper, init_prev: i32) -> BatchEnergyUcb {
        assert!(b > 0 && k > 0);
        BatchEnergyUcb {
            hyper,
            b,
            k,
            n: vec![0.0; b * k],
            mean: vec![0.0; b * k],
            prev: vec![init_prev; b],
            init_prev,
        }
    }

    /// Pull-count grid, row-major (B, K).
    pub fn counts(&self) -> &[f32] {
        &self.n
    }

    /// Empirical-mean grid, row-major (B, K).
    pub fn means(&self) -> &[f32] {
        &self.mean
    }

    /// Previous arm per environment (-1 = none yet).
    pub fn prev(&self) -> &[i32] {
        &self.prev
    }
}

impl BatchPolicy for BatchEnergyUcb {
    fn name(&self) -> String {
        if self.hyper.lambda == 0.0 {
            "EnergyUCB w/o Penalty".into()
        } else {
            "EnergyUCB".into()
        }
    }

    fn b(&self) -> usize {
        self.b
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select_into(&mut self, t: u64, feasible: &[f32], sel: &mut [i32]) {
        saucb_select_into(
            &self.n,
            &self.mean,
            &self.prev,
            t as f32,
            feasible,
            &self.hyper,
            self.k,
            sel,
        );
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], _progress: &[f64], active: &[f32]) {
        grid_update_batch(&mut self.n, &mut self.mean, &mut self.prev, sel, reward, active, self.k);
    }

    fn reset(&mut self) {
        self.n.iter_mut().for_each(|x| *x = 0.0);
        self.mean.iter_mut().for_each(|x| *x = 0.0);
        self.prev.iter_mut().for_each(|x| *x = self.init_prev);
    }
}

/// Batched QoS-constrained EnergyUCB (§3.3): per-environment progress
/// estimates restrict the SA-UCB argmax to the estimated-feasible set,
/// intersected with the caller's mask. Mirrors the scalar
/// `ConstrainedEnergyUcb` semantics — measurement dwell on unmeasured
/// previous arms, switch-tainted progress samples discarded — in the f32
/// core (estimates in f32; the scalar variant remains the f64 reference).
#[derive(Clone, Debug)]
pub struct BatchConstrainedEnergyUcb {
    inner: BatchEnergyUcb,
    delta: f32,
    /// Running mean of clean per-interval progress, row-major (B, K).
    p_hat: Vec<f32>,
    p_count: Vec<f32>,
    /// Combined caller × estimated feasibility, rebuilt each select.
    mask: Vec<f32>,
}

impl BatchConstrainedEnergyUcb {
    pub fn new(b: usize, k: usize, hyper: SaUcbHyper, delta: f32) -> BatchConstrainedEnergyUcb {
        assert!((0.0..1.0).contains(&delta), "delta must be in [0,1)");
        BatchConstrainedEnergyUcb {
            inner: BatchEnergyUcb::new(b, k, hyper),
            delta,
            p_hat: vec![0.0; b * k],
            p_count: vec![0.0; b * k],
            mask: vec![1.0; b * k],
        }
    }

    /// Fleet-semantics constructor (see [`BatchEnergyUcb::with_initial_arm`]).
    pub fn with_initial_arm(
        b: usize,
        k: usize,
        hyper: SaUcbHyper,
        delta: f32,
        arm: usize,
    ) -> BatchConstrainedEnergyUcb {
        let mut p = Self::new(b, k, hyper, delta);
        p.inner = BatchEnergyUcb::with_initial_arm(b, k, hyper, arm);
        p
    }

    /// Estimated-feasible mask entry for (env, arm): optimistic until both
    /// the arm and the max-frequency arm have clean progress samples.
    fn estimated_feasible(&self, e: usize, i: usize) -> bool {
        let k = self.inner.k;
        let row = e * k;
        let max_arm = k - 1;
        if i == max_arm {
            return true; // f_max has zero slowdown by definition
        }
        if self.p_count[row + i] <= 0.0 || self.p_count[row + max_arm] <= 0.0 {
            return true; // optimism: unknown arms stay feasible
        }
        let p_max = self.p_hat[row + max_arm];
        if p_max <= 0.0 {
            return true;
        }
        1.0 - self.p_hat[row + i] / p_max <= self.delta
    }
}

impl BatchPolicy for BatchConstrainedEnergyUcb {
    fn name(&self) -> String {
        format!("Constrained EnergyUCB (δ={})", self.delta)
    }

    fn b(&self) -> usize {
        self.inner.b
    }

    fn k(&self) -> usize {
        self.inner.k
    }

    fn select_into(&mut self, t: u64, feasible: &[f32], sel: &mut [i32]) {
        let (b, k) = (self.inner.b, self.inner.k);
        for e in 0..b {
            for i in 0..k {
                let idx = e * k + i;
                self.mask[idx] =
                    if self.estimated_feasible(e, i) { feasible[idx] } else { 0.0 };
            }
        }
        // The intersected mask always keeps the max-frequency arm (zero
        // slowdown by definition) wherever the caller's mask does — guard
        // that invariant where the mask is built.
        debug_assert_feasible_rows(&self.mask, k);
        saucb_select_into(
            &self.inner.n,
            &self.inner.mean,
            &self.inner.prev,
            t as f32,
            &self.mask,
            &self.inner.hyper,
            k,
            sel,
        );
        // Measurement dwell: a just-switched-to arm has no clean progress
        // sample yet — hold it one more interval so its slowdown estimate
        // comes from a steady-state reading.
        for e in 0..b {
            let p = self.inner.prev[e];
            if p >= 0 && self.p_count[e * k + p as usize] <= 0.0 {
                sel[e] = p;
            }
        }
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], progress: &[f64], active: &[f32]) {
        let k = self.inner.k;
        // Progress estimates first (they need the pre-update `prev` to tell
        // clean steady-state samples from switch-tainted ones).
        for e in 0..sel.len() {
            let clean = self.inner.prev[e] == sel[e];
            let prog = progress[e] as f32;
            if active[e] > 0.0 && clean && prog > 0.0 {
                let idx = e * k + sel[e] as usize;
                self.p_count[idx] += 1.0;
                self.p_hat[idx] += (prog - self.p_hat[idx]) / self.p_count[idx];
            }
        }
        self.inner.update_batch(sel, reward, progress, active);
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.p_hat.iter_mut().for_each(|x| *x = 0.0);
        self.p_count.iter_mut().for_each(|x| *x = 0.0);
    }
}

/// Batched UCB1 — f64, exactly the scalar [`super::Ucb1`] arithmetic per
/// environment, so a B = 1 batch reproduces the scalar trajectory
/// bit-for-bit (the conformance suite pins this).
#[derive(Clone, Debug)]
pub struct BatchUcb1 {
    alpha: f64,
    b: usize,
    k: usize,
    n: Vec<u64>,
    mean: Vec<f64>,
}

impl BatchUcb1 {
    pub fn new(b: usize, k: usize, alpha: f64) -> BatchUcb1 {
        assert!(b > 0 && k > 0 && alpha >= 0.0);
        BatchUcb1 { alpha, b, k, n: vec![0; b * k], mean: vec![0.0; b * k] }
    }
}

impl BatchPolicy for BatchUcb1 {
    fn name(&self) -> String {
        "UCB1".into()
    }

    fn b(&self) -> usize {
        self.b
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select_into(&mut self, t: u64, feasible: &[f32], sel: &mut [i32]) {
        ucb1_select_into(&self.n, &self.mean, self.alpha, t, feasible, self.k, sel);
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], _progress: &[f64], active: &[f32]) {
        for e in 0..sel.len() {
            if active[e] <= 0.0 {
                continue;
            }
            let idx = e * self.k + sel[e] as usize;
            self.n[idx] += 1;
            self.mean[idx] += (reward[e] - self.mean[idx]) / self.n[idx] as f64;
        }
    }

    fn reset(&mut self) {
        self.n.iter_mut().for_each(|x| *x = 0);
        self.mean.iter_mut().for_each(|x| *x = 0.0);
    }
}

/// Batched Sliding-Window UCB — f64, exactly the scalar
/// [`super::SlidingWindowUcb`] arithmetic per environment (per-env windows,
/// windowed sums kept in sync).
#[derive(Clone, Debug)]
pub struct BatchSwUcb {
    alpha: f64,
    lambda: f64,
    window: usize,
    b: usize,
    k: usize,
    hist: Vec<VecDeque<(usize, f64)>>,
    sum: Vec<f64>,
    n: Vec<u64>,
    prev: Vec<i32>,
}

impl BatchSwUcb {
    pub fn new(b: usize, k: usize, alpha: f64, lambda: f64, window: usize) -> BatchSwUcb {
        assert!(b > 0 && k > 0 && window > 0);
        BatchSwUcb {
            alpha,
            lambda,
            window,
            b,
            k,
            hist: (0..b).map(|_| VecDeque::with_capacity(window + 1)).collect(),
            sum: vec![0.0; b * k],
            n: vec![0; b * k],
            prev: vec![-1; b],
        }
    }
}

impl BatchPolicy for BatchSwUcb {
    fn name(&self) -> String {
        format!("SW-UCB(w={})", self.window)
    }

    fn b(&self) -> usize {
        self.b
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select_into(&mut self, t: u64, feasible: &[f32], sel: &mut [i32]) {
        let horizon = (t as f64).min(self.window as f64).max(2.0);
        swucb_select_into(
            &self.sum,
            &self.n,
            &self.prev,
            self.alpha,
            self.lambda,
            horizon,
            feasible,
            self.k,
            sel,
        );
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], _progress: &[f64], active: &[f32]) {
        let k = self.k;
        for e in 0..sel.len() {
            if active[e] <= 0.0 {
                continue;
            }
            let arm = sel[e] as usize;
            let r = reward[e];
            self.hist[e].push_back((arm, r));
            self.sum[e * k + arm] += r;
            self.n[e * k + arm] += 1;
            if self.hist[e].len() > self.window {
                let (old_arm, old_r) = self.hist[e].pop_front().unwrap();
                self.sum[e * k + old_arm] -= old_r;
                self.n[e * k + old_arm] -= 1;
            }
            self.prev[e] = sel[e];
        }
    }

    fn reset(&mut self) {
        self.hist.iter_mut().for_each(VecDeque::clear);
        self.sum.iter_mut().for_each(|x| *x = 0.0);
        self.n.iter_mut().for_each(|x| *x = 0);
        self.prev.iter_mut().for_each(|x| *x = -1);
    }
}

/// Batched ε-greedy — f64 + one RNG stream per environment (env `e` is
/// seeded `seed0 + e`, so env 0 of a B = 1 batch reproduces the scalar
/// policy seeded `seed0` bit-for-bit, including RNG consumption order).
#[derive(Clone, Debug)]
pub struct BatchEpsilonGreedy {
    eps0: f64,
    decay_c: f64,
    b: usize,
    k: usize,
    n: Vec<u64>,
    mean: Vec<f64>,
    rngs: Vec<Rng>,
    seed0: u64,
}

impl BatchEpsilonGreedy {
    pub fn new(b: usize, k: usize, eps0: f64, decay_c: f64, seed0: u64) -> BatchEpsilonGreedy {
        assert!(b > 0 && k > 0);
        assert!((0.0..=1.0).contains(&eps0));
        BatchEpsilonGreedy {
            eps0,
            decay_c,
            b,
            k,
            n: vec![0; b * k],
            mean: vec![0.0; b * k],
            rngs: (0..b).map(|e| Rng::new(seed0.wrapping_add(e as u64))).collect(),
            seed0,
        }
    }

    fn epsilon_at(&self, t: u64) -> f64 {
        if self.decay_c <= 0.0 {
            self.eps0
        } else {
            self.eps0.min(self.decay_c / t.max(1) as f64)
        }
    }
}

impl BatchPolicy for BatchEpsilonGreedy {
    fn name(&self) -> String {
        "ε-greedy".into()
    }

    fn b(&self) -> usize {
        self.b
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select_into(&mut self, t: u64, feasible: &[f32], sel: &mut [i32]) {
        let k = self.k;
        let eps = self.epsilon_at(t);
        for e in 0..self.b {
            let row = e * k;
            // One sample per (feasible) arm before going greedy.
            if let Some(i) = (0..k).find(|&i| feasible[row + i] > 0.0 && self.n[row + i] == 0) {
                sel[e] = i as i32;
                continue;
            }
            let n_feasible = (0..k).filter(|&i| feasible[row + i] > 0.0).count();
            if n_feasible == 0 {
                sel[e] = 0;
                continue;
            }
            if self.rngs[e].chance(eps) {
                // Uniform over the feasible arms with a single index draw
                // (identical RNG consumption to the scalar `index(k)` when
                // the mask is all-ones).
                let mut j = self.rngs[e].index(n_feasible);
                let mut pick = 0usize;
                for i in 0..k {
                    if feasible[row + i] > 0.0 {
                        if j == 0 {
                            pick = i;
                            break;
                        }
                        j -= 1;
                    }
                }
                sel[e] = pick as i32;
            } else {
                let mut best = 0usize;
                let mut best_v = f64::NEG_INFINITY;
                for i in 0..k {
                    if feasible[row + i] <= 0.0 {
                        continue;
                    }
                    if self.mean[row + i] > best_v {
                        best_v = self.mean[row + i];
                        best = i;
                    }
                }
                sel[e] = best as i32;
            }
        }
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], _progress: &[f64], active: &[f32]) {
        for e in 0..sel.len() {
            if active[e] <= 0.0 {
                continue;
            }
            let idx = e * self.k + sel[e] as usize;
            self.n[idx] += 1;
            self.mean[idx] += (reward[e] - self.mean[idx]) / self.n[idx] as f64;
        }
    }

    fn reset(&mut self) {
        self.n.iter_mut().for_each(|x| *x = 0);
        self.mean.iter_mut().for_each(|x| *x = 0.0);
        for (e, rng) in self.rngs.iter_mut().enumerate() {
            *rng = Rng::new(self.seed0.wrapping_add(e as u64));
        }
    }
}

// Forwarding impls so borrowed/boxed batch policies are themselves batch
// policies — the batch controller owns a `Box<dyn BatchPolicy + 'p>`, and
// callers that keep ownership (e.g. `fleet::policy_run`'s `&mut dyn
// BatchPolicy` argument) box a reborrow instead of moving the policy.
impl<P: BatchPolicy + ?Sized> BatchPolicy for &mut P {
    fn name(&self) -> String {
        (**self).name()
    }

    fn b(&self) -> usize {
        (**self).b()
    }

    fn k(&self) -> usize {
        (**self).k()
    }

    fn select_into(&mut self, t: u64, feasible: &[f32], sel: &mut [i32]) {
        (**self).select_into(t, feasible, sel)
    }

    fn select_into_ctx(&mut self, t: u64, feasible: &[f32], ctx: &[f64], d: usize, sel: &mut [i32]) {
        (**self).select_into_ctx(t, feasible, ctx, d, sel)
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], progress: &[f64], active: &[f32]) {
        (**self).update_batch(sel, reward, progress, active)
    }

    fn reset(&mut self) {
        (**self).reset()
    }
}

impl<P: BatchPolicy + ?Sized> BatchPolicy for Box<P> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn b(&self) -> usize {
        (**self).b()
    }

    fn k(&self) -> usize {
        (**self).k()
    }

    fn select_into(&mut self, t: u64, feasible: &[f32], sel: &mut [i32]) {
        (**self).select_into(t, feasible, sel)
    }

    fn select_into_ctx(&mut self, t: u64, feasible: &[f32], ctx: &[f64], d: usize, sel: &mut [i32]) {
        (**self).select_into_ctx(t, feasible, ctx, d, sel)
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], progress: &[f64], active: &[f32]) {
        (**self).update_batch(sel, reward, progress, active)
    }

    fn reset(&mut self) {
        (**self).reset()
    }
}

/// Bridge: run any scalar [`Policy`] — or a heterogeneous mix of them —
/// as a batch, one policy instance per environment. This is what makes
/// *every* policy (Thompson, static, round-robin, the RL baselines,
/// ablation configurations) fleet-runnable, and what mixed-policy fleets
/// are built from.
///
/// The caller's feasibility mask is ignored: scalar policies own their
/// feasibility (e.g. `ConstrainedEnergyUcb`). Frozen environments
/// (`active = 0`) still select (selection is discarded by the engine) but
/// never update.
pub struct Scalar<P: Policy> {
    envs: Vec<P>,
    k: usize,
}

impl<P: Policy> Scalar<P> {
    /// One scalar policy per environment; all must share the arm count.
    pub fn new(envs: Vec<P>) -> Scalar<P> {
        assert!(!envs.is_empty(), "Scalar bridge needs at least one environment");
        let k = envs[0].k();
        assert!(envs.iter().all(|p| p.k() == k), "Scalar bridge: mixed arm counts");
        Scalar { envs, k }
    }

    pub fn env(&self, e: usize) -> &P {
        &self.envs[e]
    }

    pub fn env_mut(&mut self, e: usize) -> &mut P {
        &mut self.envs[e]
    }

    pub fn into_inner(self) -> Vec<P> {
        self.envs
    }
}

impl<P: Policy> BatchPolicy for Scalar<P> {
    fn name(&self) -> String {
        let first = self.envs[0].name();
        if self.envs.iter().all(|p| p.name() == first) {
            return first;
        }
        let mut names: Vec<String> = Vec::new();
        for p in &self.envs {
            let n = p.name();
            if !names.contains(&n) {
                names.push(n);
            }
        }
        format!("Mixed[{}]", names.join(" + "))
    }

    fn b(&self) -> usize {
        self.envs.len()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select_into(&mut self, t: u64, _feasible: &[f32], sel: &mut [i32]) {
        for (e, p) in self.envs.iter_mut().enumerate() {
            sel[e] = p.select(t) as i32;
        }
    }

    fn select_into_ctx(
        &mut self,
        t: u64,
        _feasible: &[f32],
        ctx: &[f64],
        d: usize,
        sel: &mut [i32],
    ) {
        debug_assert_eq!(ctx.len(), self.envs.len() * d);
        for (e, p) in self.envs.iter_mut().enumerate() {
            sel[e] = p.select_ctx(t, &ctx[e * d..(e + 1) * d]) as i32;
        }
    }

    fn update_batch(&mut self, sel: &[i32], reward: &[f64], progress: &[f64], active: &[f32]) {
        for (e, p) in self.envs.iter_mut().enumerate() {
            if active[e] > 0.0 {
                p.update(sel[e] as usize, reward[e], progress[e]);
            }
        }
    }

    fn reset(&mut self) {
        self.envs.iter_mut().for_each(|p| p.reset());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::{RoundRobin, StaticPolicy, Ucb1};

    fn ones(b: usize, k: usize) -> Vec<f32> {
        vec![1.0; b * k]
    }

    /// Drive a batch policy for `steps` with rewards r(arm) = means[arm]
    /// (noise-free); returns the selection history, step-major.
    fn drive(
        p: &mut dyn BatchPolicy,
        means: &[f64],
        steps: u64,
        feasible: &[f32],
    ) -> Vec<Vec<i32>> {
        let b = p.b();
        let mut sel = vec![0i32; b];
        let mut reward = vec![0.0f64; b];
        let progress = vec![1e-3f64; b];
        let active = vec![1.0f32; b];
        let mut hist = Vec::new();
        for t in 1..=steps {
            p.select_into(t, feasible, &mut sel);
            for e in 0..b {
                reward[e] = means[sel[e] as usize];
            }
            p.update_batch(&sel, &reward, &progress, &active);
            hist.push(sel.clone());
        }
        hist
    }

    #[test]
    fn environments_are_independent() {
        // Identical envs fed identical rewards make identical choices.
        let means = [-1.3, -1.0, -1.2];
        let mut p = BatchUcb1::new(3, 3, 0.05);
        let hist = drive(&mut p, &means, 200, &ones(3, 3));
        for sel in &hist {
            assert!(sel.iter().all(|&s| s == sel[0]), "{sel:?}");
        }
        // And they converge on the best arm.
        assert!(hist[199].iter().all(|&s| s == 1));
    }

    #[test]
    fn feasibility_mask_is_honored() {
        let means = [-1.3, -1.0, -1.2];
        let mut feas = ones(2, 3);
        feas[1] = 0.0; // env 0: best arm masked
        feas[3] = 0.0; // env 1: arm 0 masked
        let mut ucb = BatchUcb1::new(2, 3, 0.05);
        for sel in drive(&mut ucb, &means, 300, &feas) {
            assert_ne!(sel[0], 1);
            assert_ne!(sel[1], 0);
        }
        let mut eg = BatchEpsilonGreedy::new(2, 3, 0.3, 0.0, 7);
        for sel in drive(&mut eg, &means, 300, &feas) {
            assert_ne!(sel[0], 1);
            assert_ne!(sel[1], 0);
        }
        let mut sw = BatchSwUcb::new(2, 3, 0.05, 0.0, 64);
        for sel in drive(&mut sw, &means, 300, &feas) {
            assert_ne!(sel[0], 1);
            assert_ne!(sel[1], 0);
        }
        let mut eu = BatchEnergyUcb::new(2, 3, SaUcbHyper::default());
        for sel in drive(&mut eu, &means, 300, &feas) {
            assert_ne!(sel[0], 1);
            assert_ne!(sel[1], 0);
        }
    }

    #[test]
    fn frozen_envs_do_not_learn() {
        let mut p = BatchEnergyUcb::new(2, 3, SaUcbHyper::default());
        let sel = [1i32, 1];
        let reward = [-1.0f64, -1.0];
        let progress = [1e-3f64; 2];
        p.update_batch(&sel, &reward, &progress, &[1.0, 0.0]);
        assert_eq!(p.counts()[1], 1.0);
        assert_eq!(p.counts()[3 + 1], 0.0);
        assert_eq!(p.prev()[0], 1);
        assert_eq!(p.prev()[1], -1);
    }

    #[test]
    fn reset_restores_fresh_state() {
        let means = [-1.1, -1.0];
        let mut p = BatchSwUcb::new(2, 2, 0.1, 0.01, 16);
        let first = drive(&mut p, &means, 50, &ones(2, 2));
        p.reset();
        let second = drive(&mut p, &means, 50, &ones(2, 2));
        assert_eq!(first, second);
    }

    #[test]
    fn scalar_bridge_reports_mixed_name() {
        let envs: Vec<Box<dyn Policy>> = vec![
            Box::new(StaticPolicy::new(3, 2)),
            Box::new(RoundRobin::new(3)),
            Box::new(StaticPolicy::new(3, 2)),
        ];
        let bridge = Scalar::new(envs);
        assert_eq!(bridge.b(), 3);
        assert!(bridge.name().starts_with("Mixed["), "{}", bridge.name());
        let uniform = Scalar::new(vec![Ucb1::new(3, 0.1), Ucb1::new(3, 0.1)]);
        assert_eq!(uniform.name(), "UCB1");
    }

    #[test]
    fn scalar_bridge_skips_frozen_updates() {
        let mut bridge = Scalar::new(vec![Ucb1::new(2, 0.1), Ucb1::new(2, 0.1)]);
        let sel = [0i32, 0];
        bridge.update_batch(&sel, &[-1.0, -1.0], &[0.0, 0.0], &[1.0, 0.0]);
        assert!(bridge.env(0).index(0, 5).is_finite());
        assert!(bridge.env(1).index(0, 5).is_infinite()); // still unplayed
    }

    #[test]
    fn all_infeasible_row_pins_arm_zero() {
        // Pinned fallback (module docs): a mask row with no feasible arm
        // deterministically selects arm 0 — on every kernel.
        let (b, k) = (2usize, 4usize);
        let n = vec![1.0f32; b * k];
        let mean = vec![-1.0f32; b * k];
        let prev = vec![-1i32; b];
        let feas = vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0];
        for kernel in Kernel::available() {
            let mut sel = vec![9i32; b];
            saucb_select_into_with(
                kernel,
                &n,
                &mean,
                &prev,
                5.0,
                &feas,
                &SaUcbHyper::default(),
                k,
                &mut sel,
            );
            assert_eq!(sel, vec![0, 2], "kernel {}", kernel.name());
        }
    }

    #[test]
    fn kernels_agree_on_a_short_trajectory() {
        // A compact end-to-end smoke check that every available kernel
        // walks the same select→update trajectory bit-for-bit (the full
        // fuzzed matrix lives in tests/simd_conformance.rs).
        let (b, k) = (11usize, 9usize);
        let feas = ones(b, k);
        let mut histories: Vec<(Vec<Vec<i32>>, Vec<u32>)> = Vec::new();
        for kernel in Kernel::available() {
            let mut n = vec![0.0f32; b * k];
            let mut mean = vec![0.0f32; b * k];
            let mut prev = vec![-1i32; b];
            let mut sel = vec![0i32; b];
            let mut hist = Vec::new();
            for t in 1..=40u64 {
                saucb_select_into_with(
                    kernel,
                    &n,
                    &mean,
                    &prev,
                    t as f32,
                    &feas,
                    &SaUcbHyper::default(),
                    k,
                    &mut sel,
                );
                let reward: Vec<f64> =
                    sel.iter().map(|&s| -1.0 - 0.05 * (k as f64 - s as f64)).collect();
                let active: Vec<f32> =
                    (0..b).map(|e| if e % 4 == 3 { 0.0 } else { 1.0 }).collect();
                grid_update_batch_with(
                    kernel, &mut n, &mut mean, &mut prev, &sel, &reward, &active, k,
                );
                hist.push(sel.clone());
            }
            let bits: Vec<u32> = mean.iter().map(|m| m.to_bits()).collect();
            histories.push((hist, bits));
        }
        for (h, bits) in &histories[1..] {
            assert_eq!(h, &histories[0].0);
            assert_eq!(bits, &histories[0].1);
        }
    }

    #[test]
    fn constrained_batch_excludes_measured_slow_arms() {
        // Arm progress follows a speedup curve; delta = 0.05 excludes the
        // slow low-frequency arms once measured.
        let k = 9;
        let progress_of =
            |arm: usize| 1e-3 / (0.5 + 0.5 * (1.6 / (0.8 + 0.1 * arm as f64)));
        let mut p = BatchConstrainedEnergyUcb::new(1, k, SaUcbHyper::default(), 0.05);
        let feas = ones(1, k);
        let mut sel = vec![0i32; 1];
        for t in 1..=600u64 {
            p.select_into(t, &feas, &mut sel);
            let arm = sel[0] as usize;
            // Cheap-at-low-frequency rewards: only the constraint keeps
            // the policy near the top arms.
            let reward = -1.0 - 0.03 * (k - 1 - arm) as f64;
            p.update_batch(&sel, &[reward], &[progress_of(arm)], &[1.0]);
        }
        // Late selections must be truly feasible arms (7, 8 on this curve).
        for t in 601..=700u64 {
            p.select_into(t, &feas, &mut sel);
            let arm = sel[0] as usize;
            let true_s = 1.0 - progress_of(arm) / progress_of(k - 1);
            p.update_batch(&sel, &[-1.0], &[progress_of(arm)], &[1.0]);
            assert!(true_s <= 0.07, "picked arm {arm} with slowdown {true_s}");
        }
    }
}
