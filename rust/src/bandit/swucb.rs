//! Sliding-Window UCB (Garivier & Moulines 2011) — a non-stationarity
//! extension beyond the paper, complementary to the discounted EnergyUCB:
//! estimates use only the last `window` observations, so the controller
//! tracks phase changes in the workload (see `workload::phase`) at the cost
//! of higher stationary regret.

use std::collections::VecDeque;

use super::Policy;

#[derive(Clone, Debug)]
pub struct SlidingWindowUcb {
    alpha: f64,
    lambda: f64,
    window: usize,
    /// Recent (arm, reward) observations, oldest first.
    history: VecDeque<(usize, f64)>,
    /// Windowed sums/counts per arm (kept in sync with `history`).
    sum: Vec<f64>,
    n: Vec<u64>,
    prev: Option<usize>,
}

impl SlidingWindowUcb {
    pub fn new(k: usize, alpha: f64, lambda: f64, window: usize) -> SlidingWindowUcb {
        assert!(k > 0 && window > 0);
        SlidingWindowUcb {
            alpha,
            lambda,
            window,
            history: VecDeque::with_capacity(window + 1),
            sum: vec![0.0; k],
            n: vec![0; k],
            prev: None,
        }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Windowed mean for arm `i` (None when unobserved in the window).
    pub fn windowed_mean(&self, i: usize) -> Option<f64> {
        (self.n[i] > 0).then(|| self.sum[i] / self.n[i] as f64)
    }

    fn index(&self, i: usize, t: u64) -> f64 {
        let horizon = (t as f64).min(self.window as f64).max(2.0);
        let bonus = self.alpha * (horizon.ln() / (self.n[i].max(1) as f64)).sqrt();
        let mean = self.windowed_mean(i).unwrap_or(0.0); // optimistic when unseen
        let penalty = match self.prev {
            Some(p) if p != i => self.lambda,
            _ => 0.0,
        };
        mean + bonus - penalty
    }
}

impl Policy for SlidingWindowUcb {
    fn name(&self) -> String {
        format!("SW-UCB(w={})", self.window)
    }

    fn k(&self) -> usize {
        self.sum.len()
    }

    fn select(&mut self, t: u64) -> usize {
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for i in 0..self.k() {
            let v = self.index(i, t);
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    fn update(&mut self, arm: usize, reward: f64, _progress: f64) {
        self.history.push_back((arm, reward));
        self.sum[arm] += reward;
        self.n[arm] += 1;
        if self.history.len() > self.window {
            let (old_arm, old_r) = self.history.pop_front().unwrap();
            self.sum[old_arm] -= old_r;
            self.n[old_arm] -= 1;
        }
        self.prev = Some(arm);
    }

    fn reset(&mut self) {
        self.history.clear();
        self.sum.iter_mut().for_each(|x| *x = 0.0);
        self.n.iter_mut().for_each(|x| *x = 0);
        self.prev = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn window_evicts_old_observations() {
        let mut p = SlidingWindowUcb::new(3, 0.05, 0.0, 4);
        for _ in 0..4 {
            p.update(0, -2.0, 0.0);
        }
        assert_eq!(p.windowed_mean(0), Some(-2.0));
        // Push 4 fresh observations on arm 1 — arm 0 falls out entirely.
        for _ in 0..4 {
            p.update(1, -1.0, 0.0);
        }
        assert_eq!(p.windowed_mean(0), None);
        assert_eq!(p.windowed_mean(1), Some(-1.0));
    }

    #[test]
    fn tracks_abrupt_change_faster_than_lifetime_means() {
        // Both policies get a long, balanced stationary history; then the
        // optimum flips. Lifetime means are anchored by thousands of stale
        // samples (and the bonus is too small to re-explore), while the
        // window forgets in ~300 steps.
        let mut sw = SlidingWindowUcb::new(2, 0.1, 0.0, 300);
        let mut lifetime = crate::bandit::Ucb1::new(2, 0.1);
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            for arm in 0..2usize {
                let r = rng.normal(if arm == 0 { -1.0 } else { -1.1 }, 0.05);
                sw.update(arm, r, 0.0);
                lifetime.update(arm, r, 0.0);
            }
        }
        // Post-flip free-running phase: arm 1 is now the optimum.
        let mut sw_late = 0u64;
        let mut lt_late = 0u64;
        for t in 4001..=6000u64 {
            let means = [-1.1, -1.0];
            for (pol, late) in [
                (&mut sw as &mut dyn Policy, &mut sw_late),
                (&mut lifetime as &mut dyn Policy, &mut lt_late),
            ] {
                let arm = pol.select(t);
                pol.update(arm, rng.normal(means[arm], 0.05), 0.0);
                if t > 4800 && arm == 1 {
                    *late += 1;
                }
            }
        }
        assert!(sw_late > 1000, "sw adapted only {sw_late}/1200");
        assert!(sw_late > lt_late + 200, "sw {sw_late} vs lifetime {lt_late}");
    }

    #[test]
    fn reset_clears_window() {
        let mut p = SlidingWindowUcb::new(2, 0.1, 0.0, 10);
        p.update(0, -1.0, 0.0);
        p.reset();
        assert_eq!(p.windowed_mean(0), None);
        assert!(p.history.is_empty());
    }

    #[test]
    fn unseen_arms_are_optimistic() {
        let mut p = SlidingWindowUcb::new(3, 0.05, 0.0, 8);
        p.update(0, -1.0, 0.0);
        // Arms 1, 2 unseen: mean 0 (optimistic) -> selected next.
        let arm = p.select(2);
        assert!(arm != 0);
    }
}
