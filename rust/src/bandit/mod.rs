//! Multi-armed-bandit controllers: the paper's EnergyUCB (§3.2), its
//! QoS-constrained variant (§3.3), and every dynamic baseline (§4.1).
//!
//! Frequencies are arms (ascending: arm 0 = 0.8 GHz ... arm K-1 = 1.6 GHz,
//! the system default). Policies consume *normalized* rewards
//! (≈ -1 at the starting frequency; see [`RewardNormalizer`]) so that the
//! hyper-parameters α, λ, μ_init are scale-free across applications.

pub mod batch;
pub mod constrained;
pub mod egreedy;
pub mod energyucb;
pub mod fault;
pub mod linucb;
pub mod oracle;
pub mod rrfreq;
pub mod static_;
pub mod swucb;
pub mod thompson;
pub mod ucb1;

pub use batch::{
    BatchConstrainedEnergyUcb, BatchEnergyUcb, BatchEpsilonGreedy, BatchPolicy, BatchSwUcb,
    BatchUcb1, SaUcbHyper, Scalar,
};
pub use constrained::ConstrainedEnergyUcb;
pub use egreedy::EpsilonGreedy;
pub use energyucb::{EnergyUcb, EnergyUcbConfig, InitStrategy};
pub use fault::PanicAfter;
pub use linucb::{BatchCLinUcb, BatchLinUcb, CLinUcb, LinUcb, CONTEXT_DIM};
pub use oracle::Oracle;
pub use rrfreq::RoundRobin;
pub use static_::StaticPolicy;
pub use swucb::SlidingWindowUcb;
pub use thompson::EnergyTs;
pub use ucb1::Ucb1;

/// A frequency-selection policy (bandit or otherwise). `Send` so the
/// cluster leader can move per-node controllers onto worker threads.
pub trait Policy: Send {
    /// Display name ("EnergyUCB", "RRFreq", ...).
    fn name(&self) -> String;

    /// Number of arms.
    fn k(&self) -> usize;

    /// Choose the arm for decision step `t` (1-based).
    fn select(&mut self, t: u64) -> usize;

    /// Context-carrying selection: choose the arm for step `t` given the
    /// per-step workload feature vector `ctx` (the serving tier's queue
    /// depth / token rate / occupancy / util ratio). Context-free
    /// policies ignore the context and fall through to [`select`], so
    /// every existing policy is trivially context-compatible and
    /// context-free paths stay byte-identical.
    ///
    /// [`select`]: Policy::select
    fn select_ctx(&mut self, t: u64, ctx: &[f64]) -> usize {
        let _ = ctx;
        self.select(t)
    }

    /// Feed back the observed (normalized) reward and the progress made
    /// under `arm` during the interval.
    fn update(&mut self, arm: usize, reward: f64, progress: f64);

    /// Reset all learned state (fresh run).
    fn reset(&mut self);
}

/// Forwarding impl so a borrowed policy can ride the [`batch::Scalar`]
/// bridge (the session wraps its `&mut dyn Policy` at B = 1).
impl<'a, P: Policy + ?Sized> Policy for &'a mut P {
    fn name(&self) -> String {
        (**self).name()
    }

    fn k(&self) -> usize {
        (**self).k()
    }

    fn select(&mut self, t: u64) -> usize {
        (**self).select(t)
    }

    fn select_ctx(&mut self, t: u64, ctx: &[f64]) -> usize {
        (**self).select_ctx(t, ctx)
    }

    fn update(&mut self, arm: usize, reward: f64, progress: f64) {
        (**self).update(arm, reward, progress)
    }

    fn reset(&mut self) {
        (**self).reset()
    }
}

/// Forwarding impl so config-built `Box<dyn Policy>` environments can ride
/// the [`batch::Scalar`] bridge (mixed-policy fleets).
impl<P: Policy + ?Sized> Policy for Box<P> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn k(&self) -> usize {
        (**self).k()
    }

    fn select(&mut self, t: u64) -> usize {
        (**self).select(t)
    }

    fn select_ctx(&mut self, t: u64, ctx: &[f64]) -> usize {
        (**self).select_ctx(t, ctx)
    }

    fn update(&mut self, arm: usize, reward: f64, progress: f64) {
        (**self).update(arm, reward, progress)
    }

    fn reset(&mut self) {
        (**self).reset()
    }
}

/// The paper's reward formulations (§4.5): the product of per-interval
/// energy and the core-to-uncore utilization ratio, plus the squared
/// variants evaluated in Fig. 5(a).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RewardForm {
    /// r = -E · R (the paper's default, Eq. 4).
    EnergyRatio,
    /// r = -E² · R (weights energy reduction harder).
    EnergySquaredRatio,
    /// r = -E · R² (weights completion speed harder).
    EnergyRatioSquared,
}

impl RewardForm {
    pub fn name(&self) -> &'static str {
        match self {
            RewardForm::EnergyRatio => "E*R",
            RewardForm::EnergySquaredRatio => "E^2*R",
            RewardForm::EnergyRatioSquared => "E*R^2",
        }
    }

    /// Raw (unnormalized) reward from counter-derived quantities.
    /// `energy_j` is the per-interval energy, `core`/`uncore` the engine
    /// utilizations. Always negative.
    pub fn raw(&self, energy_j: f64, core: f64, uncore: f64) -> f64 {
        let e = energy_j.max(0.0);
        let r = core.max(1e-6) / uncore.max(1e-6);
        match self {
            RewardForm::EnergyRatio => -e * r,
            RewardForm::EnergySquaredRatio => -e * e * r,
            RewardForm::EnergyRatioSquared => -e * r * r,
        }
    }
}

/// Scale-free reward normalization: divide raw rewards by the median
/// magnitude of the first few raw rewards, so every app's reward stream
/// sits near -1 regardless of its power draw. Median (not first-sample)
/// because the early window is noisy and heavy-tailed: a single spiked
/// reading must not set the scale 4x off. Purely online — no prior
/// profiling, preserving the paper's fully-online setting.
///
/// Normalized rewards are additionally winsorized at [`clamp_lo`]
/// (default -3: counter glitches are capped at 3x the typical magnitude
/// before any policy sees them — a controller robustness choice every
/// method benefits from equally). The clamp lives here, not in the
/// session loop, so every tier normalizing rewards applies the identical
/// rule instead of silently skipping it.
///
/// [`clamp_lo`]: RewardNormalizer::with_clamp
#[derive(Clone, Debug)]
pub struct RewardNormalizer {
    warmup: Vec<f64>,
    scale: Option<f64>,
    clamp_lo: f64,
}

/// Number of samples the scale estimate is based on.
const NORM_WARMUP: usize = 11;

/// Default winsorization floor in normalized units (3x the typical
/// reward magnitude; rewards are negative, so this is a lower clamp).
const NORM_CLAMP_LO: f64 = -3.0;

impl Default for RewardNormalizer {
    fn default() -> Self {
        RewardNormalizer { warmup: Vec::new(), scale: None, clamp_lo: NORM_CLAMP_LO }
    }
}

impl RewardNormalizer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Override the winsorization floor (normalized units). Use
    /// `f64::NEG_INFINITY` to disable clamping entirely.
    pub fn with_clamp(clamp_lo: f64) -> Self {
        assert!(!clamp_lo.is_nan(), "clamp_lo must not be NaN");
        RewardNormalizer { clamp_lo, ..Self::default() }
    }

    /// The active winsorization floor.
    pub fn clamp_lo(&self) -> f64 {
        self.clamp_lo
    }

    pub fn normalize(&mut self, raw: f64) -> f64 {
        let scale = match self.scale {
            Some(s) => s,
            None => {
                self.warmup.push(raw.abs());
                let mut sorted = self.warmup.clone();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let med = sorted[sorted.len() / 2].max(1e-12);
                if self.warmup.len() >= NORM_WARMUP {
                    self.scale = Some(med);
                    self.warmup = Vec::new();
                }
                med
            }
        };
        (raw / scale).max(self.clamp_lo)
    }

    /// The established scale, if fixed yet (median of the warm-up window).
    pub fn scale(&self) -> Option<f64> {
        self.scale
    }

    pub fn reset(&mut self) {
        self.scale = None;
        self.warmup.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reward_forms_are_negative_and_ordered() {
        let (e, c, u) = (25.0, 0.9, 0.45);
        let r1 = RewardForm::EnergyRatio.raw(e, c, u);
        let r2 = RewardForm::EnergySquaredRatio.raw(e, c, u);
        let r3 = RewardForm::EnergyRatioSquared.raw(e, c, u);
        assert!(r1 < 0.0 && r2 < 0.0 && r3 < 0.0);
        assert!((r1 - (-50.0)).abs() < 1e-9);
        assert!((r2 - (-1250.0)).abs() < 1e-9);
        assert!((r3 - (-100.0)).abs() < 1e-9);
    }

    #[test]
    fn reward_guards_div_by_zero() {
        let r = RewardForm::EnergyRatio.raw(10.0, 0.5, 0.0);
        assert!(r.is_finite());
    }

    #[test]
    fn normalizer_settles_near_minus_one() {
        let mut n = RewardNormalizer::new();
        for _ in 0..NORM_WARMUP {
            n.normalize(-50.0);
        }
        assert_eq!(n.scale(), Some(50.0));
        assert!((n.normalize(-25.0) - (-0.5)).abs() < 1e-12);
        n.reset();
        assert_eq!(n.scale(), None);
    }

    #[test]
    fn normalizer_rejects_spiked_first_sample() {
        let mut n = RewardNormalizer::new();
        // First reading is a 4x glitch; the median must ignore it.
        n.normalize(-200.0);
        for _ in 0..NORM_WARMUP {
            n.normalize(-50.0);
        }
        assert_eq!(n.scale(), Some(50.0));
    }

    #[test]
    fn normalizer_handles_zero_first_sample() {
        let mut n = RewardNormalizer::new();
        assert!(n.normalize(0.0).is_finite());
        assert!(n.normalize(-3.0).is_finite());
    }

    #[test]
    fn normalizer_winsorizes_at_clamp_lo() {
        // Settle the scale at 50, then feed a 10x glitch: the normalized
        // value is capped at the default -3 floor.
        let mut n = RewardNormalizer::new();
        for _ in 0..NORM_WARMUP {
            n.normalize(-50.0);
        }
        assert_eq!(n.clamp_lo(), -3.0);
        assert_eq!(n.normalize(-500.0), -3.0);
        // In-range values pass through untouched.
        assert!((n.normalize(-25.0) - (-0.5)).abs() < 1e-12);
        // Custom floor.
        let mut n = RewardNormalizer::with_clamp(-1.5);
        for _ in 0..NORM_WARMUP {
            n.normalize(-50.0);
        }
        assert_eq!(n.normalize(-500.0), -1.5);
        // Disabled floor lets the glitch through.
        let mut n = RewardNormalizer::with_clamp(f64::NEG_INFINITY);
        for _ in 0..NORM_WARMUP {
            n.normalize(-50.0);
        }
        assert_eq!(n.normalize(-500.0), -10.0);
    }
}
