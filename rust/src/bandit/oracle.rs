//! Oracle policy: always plays the arm with the highest true expected
//! reward (equivalently, the energy-optimal static frequency). Defines the
//! regret baseline (paper §2.2, Eq. 3) — usable only in simulation, where
//! ground truth is known.

use super::Policy;

#[derive(Clone, Debug)]
pub struct Oracle {
    k: usize,
    best: usize,
}

impl Oracle {
    /// Build from the true per-arm expected rewards.
    pub fn from_true_rewards(true_means: &[f64]) -> Oracle {
        Oracle { k: true_means.len(), best: crate::util::stats::argmax(true_means) }
    }

    /// Build directly from a calibrated app model (energy argmin).
    pub fn for_app(app: &crate::workload::model::AppModel) -> Oracle {
        Oracle { k: app.energy_kj.len(), best: app.optimal_arm() }
    }

    pub fn best_arm(&self) -> usize {
        self.best
    }
}

impl Policy for Oracle {
    fn name(&self) -> String {
        "Oracle".into()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select(&mut self, _t: u64) -> usize {
        self.best
    }

    fn update(&mut self, _arm: usize, _reward: f64, _progress: f64) {}

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    #[test]
    fn picks_argmax_of_true_rewards() {
        let mut o = Oracle::from_true_rewards(&[-1.2, -1.0, -1.1]);
        assert_eq!(o.select(1), 1);
    }

    #[test]
    fn for_app_matches_energy_argmin() {
        let app = calibration::app("sph_exa").unwrap();
        let o = Oracle::for_app(&app);
        assert_eq!(o.best_arm(), 0); // 0.8 GHz
        let app = calibration::app("lbm").unwrap();
        assert_eq!(Oracle::for_app(&app).best_arm(), 7); // 1.5 GHz
    }
}
