//! ε-greedy baseline (paper §4.1): explore uniformly with probability ε_t,
//! exploit the empirical best otherwise. Supports the classic `c/t` decay.

use super::Policy;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct EpsilonGreedy {
    /// Cap on the exploration probability.
    eps0: f64,
    /// Decay constant: ε_t = min(eps0, decay_c / t); 0 disables decay.
    decay_c: f64,
    n: Vec<u64>,
    mean: Vec<f64>,
    rng: Rng,
    /// Construction seed, so `reset()` restores fresh-run behavior
    /// byte-for-byte (the policy-contract suite pins this).
    seed: u64,
}

impl EpsilonGreedy {
    pub fn new(k: usize, eps0: f64, decay_c: f64, seed: u64) -> EpsilonGreedy {
        assert!(k > 0);
        assert!((0.0..=1.0).contains(&eps0));
        EpsilonGreedy {
            eps0,
            decay_c,
            n: vec![0; k],
            mean: vec![0.0; k],
            rng: Rng::new(seed),
            seed,
        }
    }

    pub fn epsilon_at(&self, t: u64) -> f64 {
        if self.decay_c <= 0.0 {
            self.eps0
        } else {
            self.eps0.min(self.decay_c / t.max(1) as f64)
        }
    }
}

impl Policy for EpsilonGreedy {
    fn name(&self) -> String {
        "ε-greedy".into()
    }

    fn k(&self) -> usize {
        self.n.len()
    }

    fn select(&mut self, t: u64) -> usize {
        // Ensure every arm has one sample before going greedy.
        if let Some(i) = self.n.iter().position(|&n| n == 0) {
            return i;
        }
        if self.rng.chance(self.epsilon_at(t)) {
            self.rng.index(self.k())
        } else {
            crate::util::stats::argmax(&self.mean)
        }
    }

    fn update(&mut self, arm: usize, reward: f64, _progress: f64) {
        self.n[arm] += 1;
        self.mean[arm] += (reward - self.mean[arm]) / self.n[arm] as f64;
    }

    fn reset(&mut self) {
        self.n.iter_mut().for_each(|x| *x = 0);
        self.mean.iter_mut().for_each(|x| *x = 0.0);
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn epsilon_decays() {
        let p = EpsilonGreedy::new(3, 0.2, 20.0, 1);
        assert!((p.epsilon_at(1) - 0.2).abs() < 1e-12);
        assert!((p.epsilon_at(1000) - 0.02).abs() < 1e-12);
    }

    #[test]
    fn constant_epsilon_without_decay() {
        let p = EpsilonGreedy::new(3, 0.1, 0.0, 1);
        assert_eq!(p.epsilon_at(1), p.epsilon_at(100_000));
    }

    #[test]
    fn mostly_exploits_best_arm() {
        let means = [-1.3, -1.0, -1.2];
        let mut p = EpsilonGreedy::new(3, 0.1, 0.0, 2);
        let mut rng = Rng::new(5);
        let mut pulls = [0u64; 3];
        for t in 1..=5000u64 {
            let arm = p.select(t);
            pulls[arm] += 1;
            p.update(arm, rng.normal(means[arm], 0.05), 0.0);
        }
        assert!(pulls[1] > 4000, "{pulls:?}");
        // But it keeps exploring (~5% of steps split over other arms).
        assert!(pulls[0] + pulls[2] > 100, "{pulls:?}");
    }
}
