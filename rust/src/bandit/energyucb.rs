//! EnergyUCB — the paper's Algorithm 1.
//!
//! A switching-aware UCB controller:
//!
//! ```text
//! SA-UCB_{i,t} = μ̂_{i,t} + α √(ln t / max(1, n_{i,t})) − λ·1{i ≠ I_prev}
//! I_t = argmax_i SA-UCB_{i,t}
//! ```
//!
//! with **optimistic initialization** μ̂_{i,0} = μ_init. Rewards are
//! negative (−energy × core-to-uncore ratio, normalized to ≈ −1), so
//! μ_init = 0 is optimistic. The prior carries a pseudo-count `prior_n`,
//! which is what makes the initialization *useful* under noisy counters:
//! early (high-variance) samples are shrunk toward the prior instead of
//! being trusted outright, so each arm keeps being revisited until it has
//! real evidence — the adaptive accumulation the paper contrasts with a
//! fixed round-robin warm-up (§3.2).
//!
//! Setting `lambda = 0` recovers standard UCB; `discount < 1` yields the
//! non-stationary (phased-workload) extension.

use super::Policy;

/// Initialization strategy (the Table-2 ablation axis).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitStrategy {
    /// Optimistic prior μ_init with pseudo-count `prior_n` (the paper's
    /// design; `prior_n` controls how long the optimism persists).
    Optimistic,
    /// "w/o Opt. Ini.": the naive warm-up the paper criticizes — test each
    /// frequency once in a fixed round-robin pass, trust those (noisy,
    /// early-window) single samples, no prior shrinkage afterwards.
    WarmupRoundRobin,
}

/// EnergyUCB hyper-parameters (normalized-reward scale).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyUcbConfig {
    /// Confidence-bonus weight α.
    pub alpha: f64,
    /// Switching penalty λ (≥ 0; 0 disables — the "w/o Penalty" ablation).
    pub lambda: f64,
    /// Optimistic prior mean (0 is optimistic for negative rewards).
    pub mu_init: f64,
    /// Prior pseudo-count for the optimistic mean.
    pub prior_n: f64,
    /// Initialization strategy.
    pub init: InitStrategy,
    /// Reward discount γ ∈ (0, 1]; < 1 tracks non-stationary workloads.
    pub discount: f64,
}

impl Default for EnergyUcbConfig {
    fn default() -> Self {
        EnergyUcbConfig {
            alpha: 0.035,
            lambda: 0.01,
            mu_init: 0.0,
            // Small persistent optimism: decays as prior_n/n, which keeps
            // early (noisy-window) samples from being trusted outright
            // while costing only ~prior_n/gap revisits per arm.
            prior_n: 1.0,
            init: InitStrategy::Optimistic,
            discount: 1.0,
        }
    }
}

/// The EnergyUCB controller state.
#[derive(Clone, Debug)]
pub struct EnergyUcb {
    cfg: EnergyUcbConfig,
    k: usize,
    /// Discounted pull counts (plain counts when discount = 1).
    n: Vec<f64>,
    /// Discounted empirical mean reward per arm (without prior).
    mean: Vec<f64>,
    prev: Option<usize>,
    t_seen: u64,
    /// All-true feasibility buffer reused by unconstrained `select` calls
    /// (this used to be a fresh `vec![true; k]` every decision step — the
    /// one allocation on the session hot loop).
    all_arms: Vec<bool>,
}

impl EnergyUcb {
    pub fn new(k: usize, cfg: EnergyUcbConfig) -> EnergyUcb {
        assert!(k > 0);
        assert!(cfg.alpha >= 0.0 && cfg.lambda >= 0.0);
        assert!(cfg.discount > 0.0 && cfg.discount <= 1.0);
        assert!(cfg.prior_n >= 0.0);
        EnergyUcb {
            cfg,
            k,
            n: vec![0.0; k],
            mean: vec![0.0; k],
            prev: None,
            t_seen: 0,
            all_arms: vec![true; k],
        }
    }

    pub fn config(&self) -> &EnergyUcbConfig {
        &self.cfg
    }

    /// Prior-shrunk mean estimate for arm `i`:
    /// (prior_n·μ_init + n_i·mean_i) / (prior_n + n_i).
    pub fn mu_hat(&self, i: usize) -> f64 {
        let (pn, n) = (self.prior_weight(), self.n[i]);
        if pn + n <= 0.0 {
            self.cfg.mu_init
        } else {
            (pn * self.cfg.mu_init + n * self.mean[i]) / (pn + n)
        }
    }

    fn prior_weight(&self) -> f64 {
        match self.cfg.init {
            InitStrategy::Optimistic => self.cfg.prior_n,
            InitStrategy::WarmupRoundRobin => 0.0,
        }
    }

    /// Pull count of arm `i`.
    pub fn count(&self, i: usize) -> f64 {
        self.n[i]
    }

    /// The switching-aware index (Eq. 5).
    pub fn sa_ucb(&self, i: usize, t: u64) -> f64 {
        let bonus =
            self.cfg.alpha * ((t.max(2) as f64).ln() / self.n[i].max(1.0)).sqrt();
        let penalty = match self.prev {
            Some(p) if p != i => self.cfg.lambda,
            _ => 0.0,
        };
        self.mu_hat(i) + bonus - penalty
    }

    /// Select over a restricted feasible set (used by the constrained
    /// variant). Panics if `feasible` is all-false.
    pub fn select_within(&mut self, t: u64, feasible: &[bool]) -> usize {
        assert_eq!(feasible.len(), self.k);
        self.t_seen = t;
        // Warm-up: one fixed round-robin pass over the feasible arms.
        if self.cfg.init == InitStrategy::WarmupRoundRobin {
            if let Some(arm) = (0..self.k).find(|&i| feasible[i] && self.n[i] == 0.0) {
                return arm;
            }
        }
        let mut best: Option<(usize, f64)> = None;
        for i in 0..self.k {
            if !feasible[i] {
                continue;
            }
            let v = self.sa_ucb(i, t);
            match best {
                Some((_, bv)) if v <= bv => {}
                _ => best = Some((i, v)),
            }
        }
        best.expect("select_within: empty feasible set").0
    }

    pub fn prev_arm(&self) -> Option<usize> {
        self.prev
    }
}

impl Policy for EnergyUcb {
    fn name(&self) -> String {
        let mut parts = vec!["EnergyUCB".to_string()];
        if self.cfg.init == InitStrategy::WarmupRoundRobin {
            parts.push("w/o Opt. Ini.".into());
        }
        if self.cfg.lambda == 0.0 {
            parts.push("w/o Penalty".into());
        }
        if self.cfg.discount < 1.0 {
            parts.push(format!("γ={}", self.cfg.discount));
        }
        parts.join(" ")
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select(&mut self, t: u64) -> usize {
        // Reuse the all-true buffer (select_within needs `&mut self`, so
        // it is temporarily moved out rather than borrowed).
        let all = std::mem::take(&mut self.all_arms);
        let arm = self.select_within(t, &all);
        self.all_arms = all;
        arm
    }

    fn update(&mut self, arm: usize, reward: f64, _progress: f64) {
        debug_assert!(arm < self.k);
        let g = self.cfg.discount;
        if g < 1.0 {
            for i in 0..self.k {
                self.n[i] *= g;
            }
        }
        // Incremental (discounted) mean, Algorithm 1 line 12.
        self.n[arm] += 1.0;
        self.mean[arm] += (reward - self.mean[arm]) / self.n[arm];
        self.prev = Some(arm);
    }

    fn reset(&mut self) {
        self.n.iter_mut().for_each(|x| *x = 0.0);
        self.mean.iter_mut().for_each(|x| *x = 0.0);
        self.prev = None;
        self.t_seen = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg() -> EnergyUcbConfig {
        EnergyUcbConfig::default()
    }

    /// Simulate a bandit environment with the given true means and noise;
    /// return (pulls per arm, switches, cumulative regret).
    fn run_env(
        policy: &mut EnergyUcb,
        means: &[f64],
        sigma: f64,
        steps: u64,
        seed: u64,
    ) -> (Vec<f64>, u64, f64) {
        let mut rng = Rng::new(seed);
        let best = crate::util::stats::argmax(&means.to_vec());
        let mut switches = 0;
        let mut prev = None;
        let mut regret = 0.0;
        for t in 1..=steps {
            let arm = policy.select(t);
            if prev.is_some() && prev != Some(arm) {
                switches += 1;
            }
            prev = Some(arm);
            let r = rng.normal(means[arm], sigma);
            policy.update(arm, r, 0.001);
            regret += means[best] - means[arm];
        }
        ((0..policy.k()).map(|i| policy.count(i)).collect(), switches, regret)
    }

    #[test]
    fn converges_to_best_arm() {
        let means = [-1.3, -1.2, -1.1, -1.0, -1.05, -1.15, -1.25, -1.3, -1.35];
        let mut p = EnergyUcb::new(9, cfg());
        let (pulls, _, regret) = run_env(&mut p, &means, 0.05, 4000, 1);
        let best_pulls = pulls[3];
        assert!(best_pulls > 3000.0, "pulls={pulls:?}");
        assert!(regret < 60.0, "regret={regret}");
    }

    #[test]
    fn optimistic_init_tries_every_arm() {
        let means = [-1.0; 9];
        let mut p = EnergyUcb::new(9, cfg());
        let (pulls, _, _) = run_env(&mut p, &means, 0.02, 200, 2);
        assert!(pulls.iter().all(|&n| n > 0.0), "{pulls:?}");
    }

    #[test]
    fn switching_penalty_reduces_switches() {
        let means = [-1.05, -1.0, -1.01, -1.02, -1.04, -1.06, -1.03, -1.05, -1.07];
        let mut with = EnergyUcb::new(9, EnergyUcbConfig { lambda: 0.03, ..cfg() });
        let mut without = EnergyUcb::new(9, EnergyUcbConfig { lambda: 0.0, ..cfg() });
        let (_, sw_with, _) = run_env(&mut with, &means, 0.08, 6000, 3);
        let (_, sw_without, _) = run_env(&mut without, &means, 0.08, 6000, 3);
        assert!(
            (sw_with as f64) < 0.5 * sw_without as f64,
            "with={sw_with} without={sw_without}"
        );
    }

    #[test]
    fn lambda_zero_is_plain_ucb_index() {
        let mut p = EnergyUcb::new(3, EnergyUcbConfig { lambda: 0.0, ..cfg() });
        p.update(0, -1.0, 0.0);
        p.update(1, -1.0, 0.0);
        p.update(2, -1.0, 0.0);
        // With λ=0 the index must not depend on prev.
        let idx: Vec<f64> = (0..3).map(|i| p.sa_ucb(i, 10)).collect();
        assert!((idx[0] - idx[1]).abs() < 1e-12);
        assert!((idx[1] - idx[2]).abs() < 1e-12);
    }

    #[test]
    fn sa_index_penalizes_non_current() {
        let mut p = EnergyUcb::new(3, cfg());
        p.update(1, -1.0, 0.0);
        let with_pen = p.sa_ucb(0, 5);
        let stay = p.sa_ucb(1, 5);
        // Arm 1 has a real (worse) mean but arm 0's index carries -λ.
        let mut q = EnergyUcb::new(3, EnergyUcbConfig { lambda: 0.0, ..cfg() });
        q.update(1, -1.0, 0.0);
        assert!((q.sa_ucb(0, 5) - with_pen - cfg().lambda).abs() < 1e-12);
        let _ = stay;
    }

    #[test]
    fn warmup_visits_arms_in_order() {
        let mut p = EnergyUcb::new(4, EnergyUcbConfig { init: InitStrategy::WarmupRoundRobin, ..cfg() });
        for t in 1..=4u64 {
            let arm = p.select(t);
            assert_eq!(arm, (t - 1) as usize);
            p.update(arm, -1.0, 0.0);
        }
        // After warm-up, selection is free (index-based).
        let arm = p.select(5);
        assert!(arm < 4);
    }

    #[test]
    fn optimistic_prior_shrinks_corrupted_early_samples() {
        // The mechanism behind the Table-2 ablation: a glitched early
        // sample (heavy-tail counter noise) is shrunk toward the prior by
        // the optimistic variant, keeping the arm recoverable; the naive
        // warm-up variant trusts the single sample outright and buries it.
        let mut opt = EnergyUcb::new(3, cfg());
        let mut warm =
            EnergyUcb::new(3, EnergyUcbConfig { init: InitStrategy::WarmupRoundRobin, ..cfg() });
        // Arm 0's one early sample is a -3.0 glitch (true mean ~ -1).
        opt.update(0, -3.0, 0.0);
        warm.update(0, -3.0, 0.0);
        // Optimistic shrinkage: (prior_n*0 + 1*(-3)) / (prior_n + 1).
        let pn = cfg().prior_n;
        assert!((opt.mu_hat(0) - (-3.0 / (pn + 1.0))).abs() < 1e-12);
        assert!((warm.mu_hat(0) - (-3.0)).abs() < 1e-12);
        assert!(opt.mu_hat(0) > warm.mu_hat(0) + 0.5);
        // Hence the optimistic variant retries the glitched arm far
        // sooner: its index at matched t/counts is strictly higher.
        assert!(opt.sa_ucb(0, 100) > warm.sa_ucb(0, 100) + 0.5);
    }

    #[test]
    fn discounted_tracks_changing_optimum() {
        let mut p = EnergyUcb::new(2, EnergyUcbConfig { discount: 0.995, alpha: 0.1, ..cfg() });
        let mut rng = Rng::new(9);
        // Phase 1: arm 0 best.
        for t in 1..=2000u64 {
            let arm = p.select(t);
            let mean = if arm == 0 { -1.0 } else { -1.2 };
            p.update(arm, rng.normal(mean, 0.05), 0.0);
        }
        // Phase 2: arm 1 best.
        let mut arm1_pulls = 0;
        for t in 2001..=6000u64 {
            let arm = p.select(t);
            let mean = if arm == 0 { -1.2 } else { -1.0 };
            p.update(arm, rng.normal(mean, 0.05), 0.0);
            if t > 4000 && arm == 1 {
                arm1_pulls += 1;
            }
        }
        assert!(arm1_pulls > 1600, "discounted policy failed to adapt: {arm1_pulls}");
    }

    #[test]
    fn reset_clears_state() {
        let mut p = EnergyUcb::new(3, cfg());
        p.update(1, -0.5, 0.0);
        p.reset();
        assert_eq!(p.count(1), 0.0);
        assert_eq!(p.prev_arm(), None);
        assert_eq!(p.mu_hat(1), 0.0);
    }

    #[test]
    fn name_reflects_ablations() {
        assert_eq!(EnergyUcb::new(2, cfg()).name(), "EnergyUCB");
        assert!(EnergyUcb::new(2, EnergyUcbConfig { lambda: 0.0, ..cfg() })
            .name()
            .contains("w/o Penalty"));
        assert!(EnergyUcb::new(
            2,
            EnergyUcbConfig { init: InitStrategy::WarmupRoundRobin, ..cfg() }
        )
        .name()
        .contains("w/o Opt. Ini."));
    }
}
