//! RRFreq baseline (paper §4.1): cycle through every frequency in a fixed
//! circular order, one per decision interval. Pure exploration — its regret
//! grows linearly (Fig. 3's upper curve) and it switches every step.

use super::Policy;

#[derive(Clone, Debug)]
pub struct RoundRobin {
    k: usize,
    next: usize,
}

impl RoundRobin {
    pub fn new(k: usize) -> RoundRobin {
        assert!(k > 0);
        RoundRobin { k, next: 0 }
    }
}

impl Policy for RoundRobin {
    fn name(&self) -> String {
        "RRFreq".into()
    }

    fn k(&self) -> usize {
        self.k
    }

    fn select(&mut self, _t: u64) -> usize {
        let arm = self.next;
        self.next = (self.next + 1) % self.k;
        arm
    }

    fn update(&mut self, _arm: usize, _reward: f64, _progress: f64) {}

    fn reset(&mut self) {
        self.next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_in_order() {
        let mut p = RoundRobin::new(3);
        let picks: Vec<usize> = (1..=7u64).map(|t| p.select(t)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn reset_restarts_cycle() {
        let mut p = RoundRobin::new(3);
        p.select(1);
        p.select(2);
        p.reset();
        assert_eq!(p.select(3), 0);
    }

    #[test]
    fn uniform_visits_over_full_cycles() {
        let mut p = RoundRobin::new(9);
        let mut counts = [0u64; 9];
        for t in 1..=900u64 {
            counts[p.select(t)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }
}
