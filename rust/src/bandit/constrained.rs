//! Constrained EnergyUCB (paper §3.3): QoS-aware frequency selection.
//!
//! Maintains per-arm progress estimates p̂_i and restricts the SA-UCB
//! argmax to the feasible set K_δ = { i : s_i ≤ δ } with estimated relative
//! slowdown s_i = 1 − p̂_i / p̂_max (p̂_max = estimate at the maximum
//! frequency). Arms without progress samples are treated optimistically
//! (feasible) so each gets probed; the maximum-frequency arm is always
//! feasible by definition.

use super::energyucb::{EnergyUcb, EnergyUcbConfig};
use super::Policy;

/// Constrained EnergyUCB with slowdown budget δ.
#[derive(Clone, Debug)]
pub struct ConstrainedEnergyUcb {
    inner: EnergyUcb,
    delta: f64,
    /// Running mean of observed per-interval progress per arm.
    p_hat: Vec<f64>,
    p_count: Vec<u64>,
    /// Feasibility buffer reused across `select` calls (previously a fresh
    /// `Vec<bool>` every decision step).
    feas_buf: Vec<bool>,
}

impl ConstrainedEnergyUcb {
    pub fn new(k: usize, cfg: EnergyUcbConfig, delta: f64) -> ConstrainedEnergyUcb {
        assert!((0.0..1.0).contains(&delta), "delta must be in [0,1)");
        ConstrainedEnergyUcb {
            inner: EnergyUcb::new(k, cfg),
            delta,
            p_hat: vec![0.0; k],
            p_count: vec![0; k],
            feas_buf: Vec::with_capacity(k),
        }
    }

    pub fn delta(&self) -> f64 {
        self.delta
    }

    /// Estimated relative slowdown of arm `i` (None until both this arm
    /// and the max-frequency arm have progress samples).
    pub fn slowdown_estimate(&self, i: usize) -> Option<f64> {
        let max_arm = self.inner.k() - 1;
        if self.p_count[i] == 0 || self.p_count[max_arm] == 0 {
            return None;
        }
        let p_max = self.p_hat[max_arm];
        if p_max <= 0.0 {
            return None;
        }
        Some(1.0 - self.p_hat[i] / p_max)
    }

    /// The current feasible set K_δ.
    pub fn feasible_set(&self) -> Vec<bool> {
        let mut out = Vec::with_capacity(self.inner.k());
        self.feasible_set_into(&mut out);
        out
    }

    /// Fill `out` with the current feasible set (allocation-free after the
    /// buffer's first growth).
    fn feasible_set_into(&self, out: &mut Vec<bool>) {
        let k = self.inner.k();
        let max_arm = k - 1;
        out.clear();
        out.extend((0..k).map(|i| {
            if i == max_arm {
                return true; // f_max has zero slowdown by definition
            }
            match self.slowdown_estimate(i) {
                // Optimism: unknown arms are feasible until measured.
                None => true,
                Some(s) => s <= self.delta,
            }
        }));
    }
}

impl Policy for ConstrainedEnergyUcb {
    fn name(&self) -> String {
        format!("Constrained EnergyUCB (δ={})", self.delta)
    }

    fn k(&self) -> usize {
        self.inner.k()
    }

    fn select(&mut self, t: u64) -> usize {
        // Measurement dwell: an arm just switched to has no clean
        // (non-switching) progress sample yet — hold it one more interval
        // so its slowdown estimate comes from a steady-state reading
        // (pairs with the switch-taint filter in `update`).
        if let Some(p) = self.inner.prev_arm() {
            if self.p_count[p] == 0 {
                return p;
            }
        }
        let mut feasible = std::mem::take(&mut self.feas_buf);
        self.feasible_set_into(&mut feasible);
        let arm = self.inner.select_within(t, &feasible);
        self.feas_buf = feasible;
        arm
    }

    fn update(&mut self, arm: usize, reward: f64, progress: f64) {
        // Record progress only from NON-switching intervals: a switching
        // step loses the 150 µs stall (~1.5 % of the interval), and since
        // the first visit to any arm is always a switch, using it would
        // bias ŝ upward and permanently exclude arms whose true slowdown
        // sits just under the budget (e.g. llama's 1.5 GHz at 4.3 % under
        // δ = 5 %). Arms without clean samples stay optimistically
        // feasible, so each gets revisited until a steady-state sample
        // lands.
        let clean = self.inner.prev_arm() == Some(arm);
        self.inner.update(arm, reward, progress);
        if clean && progress > 0.0 {
            self.p_count[arm] += 1;
            let n = self.p_count[arm] as f64;
            self.p_hat[arm] += (progress - self.p_hat[arm]) / n;
        }
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.p_hat.iter_mut().for_each(|x| *x = 0.0);
        self.p_count.iter_mut().for_each(|x| *x = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk(delta: f64) -> ConstrainedEnergyUcb {
        ConstrainedEnergyUcb::new(9, EnergyUcbConfig::default(), delta)
    }

    /// Progress rates mimicking an Amdahl curve (arm 8 fastest).
    fn progress_of(arm: usize) -> f64 {
        let f = 0.8 + 0.1 * arm as f64;
        let ratio = 0.5 + 0.5 * (1.6 / f);
        0.001 / ratio
    }

    #[test]
    fn max_arm_always_feasible() {
        let p = mk(0.0);
        assert!(p.feasible_set()[8]);
    }

    #[test]
    fn unknown_arms_start_feasible() {
        let p = mk(0.05);
        assert!(p.feasible_set().iter().all(|&f| f));
    }

    #[test]
    fn infeasible_arms_get_excluded_after_measurement() {
        let mut p = mk(0.05);
        let mut rng = Rng::new(1);
        for t in 1..=500u64 {
            let arm = p.select(t);
            // Reward favors LOW frequency (cheap), so only the constraint
            // keeps the policy high.
            let reward = -1.0 - 0.03 * (8 - arm) as f64;
            p.update(arm, rng.normal(reward, 0.02), progress_of(arm));
        }
        let feas = p.feasible_set();
        // Arm 0 (0.8 GHz): slowdown = 1 - (1/1.5)/(1/1.0) = 0.333 >> 0.05.
        assert!(!feas[0], "{feas:?}");
        // Arm 8: always feasible.
        assert!(feas[8]);
        // With delta = 0.05 and this curve, only arms with
        // s_i = 1 - ratio_max/ratio_i <= 0.05 survive: arms 7, 8.
        let s7 = p.slowdown_estimate(7).unwrap();
        assert!(s7 <= 0.06, "{s7}");
    }

    #[test]
    fn selection_respects_feasible_set() {
        let mut p = mk(0.05);
        let mut rng = Rng::new(2);
        let mut late_arms = Vec::new();
        for t in 1..=2000u64 {
            let arm = p.select(t);
            if t > 1000 {
                late_arms.push(arm);
            }
            let reward = -1.0 - 0.03 * (8 - arm) as f64;
            p.update(arm, rng.normal(reward, 0.02), progress_of(arm));
        }
        // After the estimates settle, every selection must be feasible
        // under the true slowdown curve (true s_i <= ~0.06 allows 7..=8).
        for &arm in &late_arms {
            let true_s = 1.0 - progress_of(arm) / progress_of(8);
            assert!(true_s <= 0.07, "picked arm {arm} with slowdown {true_s}");
        }
    }

    #[test]
    fn wide_budget_behaves_like_unconstrained() {
        let mut p = mk(0.9);
        let mut rng = Rng::new(3);
        let mut pulls = vec![0u64; 9];
        for t in 1..=3000u64 {
            let arm = p.select(t);
            pulls[arm] += 1;
            // Arm 2 is the energy optimum.
            let mean = if arm == 2 { -0.95 } else { -1.05 };
            p.update(arm, rng.normal(mean, 0.05), progress_of(arm));
        }
        assert!(pulls[2] > 2000, "{pulls:?}");
    }

    #[test]
    fn reset_clears_progress_estimates() {
        let mut p = mk(0.05);
        p.update(3, -1.0, 0.001);
        p.reset();
        assert_eq!(p.slowdown_estimate(3), None);
    }
}
