//! GPU core-frequency domain and the DVFS state machine.
//!
//! Frequencies are the bandit arms: Aurora's PVC exposes software-settable
//! core frequencies 0.8–1.6 GHz in 0.1 GHz steps (K = 9). Arms are indexed
//! ascending (arm 0 = 0.8 GHz, arm K-1 = 1.6 GHz = the system default).

/// The set of selectable GPU core frequencies, plus the cost charged per
/// node-level DVFS transition between them. Carrying the cost here makes it
/// a single source of truth: the node simulator, the fleet parameter
/// export, and the config surface all read it from the domain instead of
/// re-stating the paper's constants.
#[derive(Clone, Debug, PartialEq)]
pub struct FreqDomain {
    ghz: Vec<f64>,
    switch_cost: SwitchCost,
}

impl FreqDomain {
    /// Aurora PVC: {0.8, 0.9, ..., 1.6} GHz.
    pub fn aurora() -> FreqDomain {
        FreqDomain::new((8..=16).map(|i| i as f64 / 10.0).collect())
    }

    /// Custom ascending frequency set (with the paper's measured default
    /// switch cost; see [`Self::with_switch_cost`]).
    pub fn new(ghz: Vec<f64>) -> FreqDomain {
        FreqDomain::try_new(ghz).expect("valid frequency domain")
    }

    /// Fallible counterpart of [`Self::new`] for untrusted inputs (config
    /// files, wire frames): returns the validation failure instead of
    /// panicking.
    pub fn try_new(ghz: Vec<f64>) -> Result<FreqDomain, String> {
        if ghz.is_empty() {
            return Err("empty frequency domain".into());
        }
        if !ghz.windows(2).all(|w| w[0] < w[1]) {
            return Err("frequencies must be strictly ascending".into());
        }
        if !ghz.iter().all(|f| f.is_finite() && *f > 0.0) {
            return Err("frequencies must be positive and finite".into());
        }
        Ok(FreqDomain { ghz, switch_cost: SwitchCost::default() })
    }

    /// The arm frequencies, GHz (ascending).
    pub fn ghz_all(&self) -> &[f64] {
        &self.ghz
    }

    /// Override the per-transition cost (custom hardware calibration).
    pub fn with_switch_cost(mut self, cost: SwitchCost) -> FreqDomain {
        assert!(cost.latency_s >= 0.0 && cost.energy_j >= 0.0);
        self.switch_cost = cost;
        self
    }

    /// Cost of one node-level frequency transition in this domain.
    #[inline]
    pub fn switch_cost(&self) -> SwitchCost {
        self.switch_cost
    }

    /// Number of arms K.
    #[inline]
    pub fn k(&self) -> usize {
        self.ghz.len()
    }

    /// Frequency of arm `i`, GHz.
    #[inline]
    pub fn ghz(&self, i: usize) -> f64 {
        self.ghz[i]
    }

    /// The maximum (default) frequency, GHz.
    #[inline]
    pub fn max_ghz(&self) -> f64 {
        *self.ghz.last().unwrap()
    }

    /// Arm index of the maximum frequency.
    #[inline]
    pub fn max_arm(&self) -> usize {
        self.k() - 1
    }

    /// Find the arm with the given frequency (within 1e-9 GHz).
    pub fn index_of_ghz(&self, f: f64) -> Option<usize> {
        self.ghz.iter().position(|g| (g - f).abs() < 1e-9)
    }

    /// All arm indices.
    pub fn arms(&self) -> std::ops::Range<usize> {
        0..self.k()
    }

    /// Human label for an arm ("1.6 GHz").
    pub fn label(&self, i: usize) -> String {
        format!("{:.1} GHz", self.ghz(i))
    }
}

/// Cost of one frequency transition, as measured on Aurora through the
/// GEOPM runtime interface (paper §4.4): ~150 µs of stall and ~0.3 J.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SwitchCost {
    pub latency_s: f64,
    pub energy_j: f64,
}

impl Default for SwitchCost {
    fn default() -> Self {
        SwitchCost { latency_s: 150e-6, energy_j: 0.3 }
    }
}

/// DVFS state machine for one device: tracks the applied frequency and
/// accounts transition overheads.
#[derive(Clone, Debug)]
pub struct DvfsState {
    current: usize,
    cost: SwitchCost,
    switches: u64,
    switch_energy_j: f64,
    switch_time_s: f64,
}

impl DvfsState {
    /// Start at the domain's default (maximum) frequency.
    pub fn new(freqs: &FreqDomain, cost: SwitchCost) -> DvfsState {
        DvfsState {
            current: freqs.max_arm(),
            cost,
            switches: 0,
            switch_energy_j: 0.0,
            switch_time_s: 0.0,
        }
    }

    /// Request arm `target`. Returns the overhead charged for this decision
    /// interval (zero when the frequency is unchanged).
    pub fn request(&mut self, target: usize) -> SwitchCost {
        if target == self.current {
            return SwitchCost { latency_s: 0.0, energy_j: 0.0 };
        }
        self.current = target;
        self.switches += 1;
        self.switch_energy_j += self.cost.energy_j;
        self.switch_time_s += self.cost.latency_s;
        self.cost
    }

    #[inline]
    pub fn current(&self) -> usize {
        self.current
    }

    /// Number of transitions performed so far.
    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Total energy charged to transitions, Joules.
    pub fn switch_energy_j(&self) -> f64 {
        self.switch_energy_j
    }

    /// Total stall time charged to transitions, seconds.
    pub fn switch_time_s(&self) -> f64 {
        self.switch_time_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aurora_domain() {
        let f = FreqDomain::aurora();
        assert_eq!(f.k(), 9);
        assert!((f.ghz(0) - 0.8).abs() < 1e-12);
        assert!((f.max_ghz() - 1.6).abs() < 1e-12);
        assert_eq!(f.index_of_ghz(1.1), Some(3));
        assert_eq!(f.index_of_ghz(0.75), None);
        assert_eq!(f.label(8), "1.6 GHz");
    }

    #[test]
    #[should_panic]
    fn rejects_unsorted() {
        FreqDomain::new(vec![1.0, 0.9]);
    }

    #[test]
    fn try_new_reports_instead_of_panicking() {
        assert!(FreqDomain::try_new(vec![]).is_err());
        assert!(FreqDomain::try_new(vec![1.0, 0.9]).is_err());
        assert!(FreqDomain::try_new(vec![1.0, 1.0]).is_err());
        assert!(FreqDomain::try_new(vec![-1.0, 1.0]).is_err());
        assert!(FreqDomain::try_new(vec![f64::NAN]).is_err());
        let f = FreqDomain::try_new(vec![0.9, 1.2, 1.5]).unwrap();
        assert_eq!(f.k(), 3);
        assert_eq!(f.ghz_all(), &[0.9, 1.2, 1.5]);
    }

    #[test]
    fn switch_cost_carried_by_domain() {
        let f = FreqDomain::aurora();
        assert_eq!(f.switch_cost(), SwitchCost::default());
        let custom = SwitchCost { latency_s: 300e-6, energy_j: 1.2 };
        let f = FreqDomain::aurora().with_switch_cost(custom);
        assert_eq!(f.switch_cost(), custom);
        // The cost override leaves the arm set untouched.
        assert_eq!(f.k(), 9);
    }

    #[test]
    fn dvfs_accounts_switch_costs() {
        let f = FreqDomain::aurora();
        let mut d = DvfsState::new(&f, SwitchCost::default());
        assert_eq!(d.current(), f.max_arm());
        // No-op request: free.
        let c = d.request(f.max_arm());
        assert_eq!(c.energy_j, 0.0);
        assert_eq!(d.switches(), 0);
        // Real switch: charged.
        let c = d.request(0);
        assert!((c.energy_j - 0.3).abs() < 1e-12);
        assert!((c.latency_s - 150e-6).abs() < 1e-15);
        assert_eq!(d.switches(), 1);
        // Toggle back and forth.
        d.request(1);
        d.request(0);
        assert_eq!(d.switches(), 3);
        assert!((d.switch_energy_j() - 0.9).abs() < 1e-12);
        assert!((d.switch_time_s() - 450e-6).abs() < 1e-12);
    }
}
