//! Node power breakdown helpers (Fig. 1(a) accounting).
//!
//! The component split of a node's draw while an application runs:
//! GPUs (frequency-dependent, from the calibrated app model), CPUs, and
//! "other" (HBM, NICs, fabric). Used by the motivation experiment and by
//! telemetry summaries.

use crate::sim::freq::FreqDomain;
use crate::workload::model::AppModel;

/// Power split of one node at a given GPU frequency arm, kW.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PowerBreakdown {
    pub gpu_kw: f64,
    pub cpu_kw: f64,
    pub other_kw: f64,
}

impl PowerBreakdown {
    pub fn of(app: &AppModel, freqs: &FreqDomain, arm: usize) -> PowerBreakdown {
        PowerBreakdown {
            gpu_kw: app.power_kw(freqs, arm),
            cpu_kw: app.cpu_kw,
            other_kw: app.other_kw,
        }
    }

    pub fn total_kw(&self) -> f64 {
        self.gpu_kw + self.cpu_kw + self.other_kw
    }

    /// Fractions (gpu, cpu, other) summing to 1.
    pub fn fractions(&self) -> (f64, f64, f64) {
        let t = self.total_kw();
        (self.gpu_kw / t, self.cpu_kw / t, self.other_kw / t)
    }

    /// Energy split over an execution of `time_s` seconds, kJ.
    pub fn energy_kj(&self, time_s: f64) -> (f64, f64, f64) {
        (self.gpu_kw * time_s, self.cpu_kw * time_s, self.other_kw * time_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    #[test]
    fn fractions_sum_to_one() {
        let f = FreqDomain::aurora();
        for app in calibration::all_apps() {
            let b = PowerBreakdown::of(&app, &f, f.max_arm());
            let (g, c, o) = b.fractions();
            assert!((g + c + o - 1.0).abs() < 1e-12);
            assert!(g > c && c > o, "{}: {g} {c} {o}", app.name);
        }
    }

    #[test]
    fn gpu_power_drops_with_frequency() {
        let f = FreqDomain::aurora();
        let app = calibration::app("pot3d").unwrap();
        let hi = PowerBreakdown::of(&app, &f, f.max_arm()).gpu_kw;
        let lo = PowerBreakdown::of(&app, &f, 0).gpu_kw;
        assert!(lo < hi, "lo={lo} hi={hi}");
    }

    #[test]
    fn energy_split_scales_with_time() {
        let f = FreqDomain::aurora();
        let app = calibration::app("pot3d").unwrap();
        let b = PowerBreakdown::of(&app, &f, f.max_arm());
        let (g, _, _) = b.energy_kj(app.t_max_s);
        // Must reproduce the Table-1 energy at 1.6 GHz.
        assert!((g - 131.13).abs() < 1e-6, "{g}");
    }
}
