//! Measurement-noise model for the GPU hardware counters.
//!
//! On large HPC systems, clock synchronization, temperature drift, and
//! network congestion make counter readings unstable — especially right
//! after job start (the paper's §3.2 motivation for optimistic
//! initialization). We model this as Gaussian perturbation of per-interval
//! energy and utilization readings with an inflated-variance early window.

use crate::util::Rng;
use crate::workload::model::NoiseSpec;

/// Stateful noise source for one device's counters.
#[derive(Clone, Debug)]
pub struct CounterNoise {
    spec: NoiseSpec,
    rng: Rng,
    elapsed_s: f64,
}

impl CounterNoise {
    pub fn new(spec: NoiseSpec, rng: Rng) -> CounterNoise {
        CounterNoise { spec, rng, elapsed_s: 0.0 }
    }

    /// Variance multiplier in effect at the current sim time.
    fn mult(&self) -> f64 {
        if self.elapsed_s < self.spec.early_window_s {
            self.spec.early_mult
        } else {
            1.0
        }
    }

    /// Whether the early high-variance window is still active.
    pub fn in_early_window(&self) -> bool {
        self.elapsed_s < self.spec.early_window_s
    }

    /// Perturb a per-interval energy reading (Joules). Never negative.
    /// Gaussian counter noise plus a heavy-tail glitch component (DVFS
    /// transients / sampling races occasionally inflate a reading).
    pub fn energy(&mut self, true_j: f64) -> f64 {
        let sigma = self.spec.energy_frac * self.mult() * true_j;
        let mut reading = true_j + self.rng.normal(0.0, sigma);
        if self.spec.spike_prob > 0.0 && self.rng.chance(self.spec.spike_prob) {
            reading *= self.spec.spike_mult;
        }
        reading.max(0.0)
    }

    /// Perturb a utilization reading, clamped to (0, 1].
    pub fn util(&mut self, true_u: f64) -> f64 {
        let sigma = self.spec.util_std * self.mult();
        (true_u + self.rng.normal(0.0, sigma)).clamp(1e-4, 1.0)
    }

    /// Advance the noise clock by one interval.
    pub fn tick(&mut self, dt_s: f64) {
        self.elapsed_s += dt_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    fn spec() -> NoiseSpec {
        NoiseSpec {
            energy_frac: 0.03,
            util_std: 0.02,
            early_mult: 3.0,
            early_window_s: 0.5,
            spike_prob: 0.0, // gaussian-only for the moment tests below
            spike_mult: 4.0,
        }
    }

    #[test]
    fn spikes_inflate_tail() {
        let mut n = CounterNoise::new(
            NoiseSpec { spike_prob: 0.05, ..spec() },
            Rng::new(11),
        );
        for _ in 0..100 {
            n.tick(0.01);
        }
        let readings: Vec<f64> = (0..20_000).map(|_| n.energy(20.0)).collect();
        let spikes = readings.iter().filter(|&&r| r > 60.0).count();
        // ~5% of readings land near 4x.
        let frac = spikes as f64 / readings.len() as f64;
        assert!((frac - 0.05).abs() < 0.01, "{frac}");
    }

    #[test]
    fn energy_noise_is_unbiased() {
        let mut n = CounterNoise::new(spec(), Rng::new(1));
        // Move past the early window first.
        for _ in 0..100 {
            n.tick(0.01);
        }
        let mut w = Welford::new();
        for _ in 0..20_000 {
            w.push(n.energy(20.0));
        }
        assert!((w.mean() - 20.0).abs() < 0.05, "{}", w.mean());
        assert!((w.std() - 0.6).abs() < 0.05, "{}", w.std()); // 3% of 20
    }

    #[test]
    fn early_window_has_higher_variance() {
        let mut early = CounterNoise::new(spec(), Rng::new(2));
        let mut late = CounterNoise::new(spec(), Rng::new(3));
        for _ in 0..100 {
            late.tick(0.01);
        }
        assert!(early.in_early_window());
        assert!(!late.in_early_window());
        let mut we = Welford::new();
        let mut wl = Welford::new();
        for _ in 0..20_000 {
            we.push(early.energy(20.0));
            wl.push(late.energy(20.0));
        }
        assert!(we.std() > 2.0 * wl.std(), "early {} vs late {}", we.std(), wl.std());
    }

    #[test]
    fn util_clamped_to_unit_range() {
        let mut n = CounterNoise::new(
            NoiseSpec { util_std: 0.5, ..spec() }, // absurdly noisy
            Rng::new(4),
        );
        for _ in 0..1000 {
            let u = n.util(0.9);
            assert!(u > 0.0 && u <= 1.0, "{u}");
        }
    }

    #[test]
    fn energy_never_negative() {
        let mut n = CounterNoise::new(
            NoiseSpec { energy_frac: 2.0, ..spec() },
            Rng::new(5),
        );
        for _ in 0..1000 {
            assert!(n.energy(1.0) >= 0.0);
        }
    }
}
