//! Single-GPU device model (Intel Data Center GPU Max / "Ponte Vecchio").
//!
//! Each [`Gpu`] owns its DVFS state, its hardware-counter block, and a
//! counter-noise stream. The device does not know about workloads; the
//! [`crate::sim::node::Node`] drives it with per-interval true quantities
//! and the GPU turns them into (noisy) counter increments, exactly the view
//! the controller gets on the real machine.

use super::counters::{EngineGroup, EngineStats, GpuCounters};
use super::freq::{DvfsState, FreqDomain, SwitchCost};
use super::noise::CounterNoise;
use crate::util::Rng;
use crate::workload::model::NoiseSpec;

/// True (noise-free) per-interval quantities for one GPU, produced by the
/// node/workload layer.
#[derive(Clone, Copy, Debug)]
pub struct GpuInterval {
    pub dt_s: f64,
    /// True energy drawn by this GPU in the interval, Joules (excluding
    /// switch overhead, which the GPU adds itself).
    pub energy_j: f64,
    pub core_util: f64,
    pub uncore_util: f64,
}

/// What actually happened in the interval, after DVFS accounting.
#[derive(Clone, Copy, Debug)]
pub struct GpuIntervalOutcome {
    /// Energy recorded by the counter (noisy, includes switch energy).
    pub measured_energy_j: f64,
    /// True energy including switch overhead.
    pub true_energy_j: f64,
    /// Stall time charged by a frequency transition this interval.
    pub stall_s: f64,
}

/// One simulated PVC device.
#[derive(Clone, Debug)]
pub struct Gpu {
    pub id: usize,
    dvfs: DvfsState,
    counters: GpuCounters,
    noise: CounterNoise,
}

impl Gpu {
    pub fn new(
        id: usize,
        freqs: &FreqDomain,
        cost: SwitchCost,
        noise_spec: NoiseSpec,
        rng: Rng,
    ) -> Gpu {
        Gpu {
            id,
            dvfs: DvfsState::new(freqs, cost),
            counters: GpuCounters::new(),
            noise: CounterNoise::new(noise_spec, rng),
        }
    }

    /// Apply a frequency request for the coming interval. Returns the stall
    /// time incurred (0 when unchanged).
    pub fn set_frequency(&mut self, arm: usize) -> f64 {
        self.dvfs.request(arm).latency_s
    }

    /// Current frequency arm.
    pub fn frequency(&self) -> usize {
        self.dvfs.current()
    }

    /// Advance the device by one decision interval.
    pub fn advance(&mut self, iv: GpuInterval, switch_energy_j: f64, stall_s: f64) -> GpuIntervalOutcome {
        let true_energy = iv.energy_j + switch_energy_j;
        let measured = self.noise.energy(true_energy);
        let core = self.noise.util(iv.core_util);
        let uncore = self.noise.util(iv.uncore_util);
        self.counters.advance(iv.dt_s, measured, core, uncore);
        self.noise.tick(iv.dt_s);
        GpuIntervalOutcome {
            measured_energy_j: measured,
            true_energy_j: true_energy,
            stall_s,
        }
    }

    /// Counter reads (what GEOPM exposes).
    pub fn energy_j(&self) -> f64 {
        self.counters.energy.read()
    }

    pub fn timestamp_s(&self) -> f64 {
        self.counters.timestamp.read()
    }

    pub fn engine_stats(&self, group: EngineGroup) -> EngineStats {
        self.counters.engine_stats(group)
    }

    pub fn switches(&self) -> u64 {
        self.dvfs.switches()
    }

    pub fn switch_energy_j(&self) -> f64 {
        self.dvfs.switch_energy_j()
    }

    pub fn switch_time_s(&self) -> f64 {
        self.dvfs.switch_time_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_gpu() -> (Gpu, FreqDomain) {
        let f = FreqDomain::aurora();
        let g = Gpu::new(0, &f, SwitchCost::default(), NoiseSpec::default(), Rng::new(1));
        (g, f)
    }

    #[test]
    fn starts_at_max_frequency() {
        let (g, f) = mk_gpu();
        assert_eq!(g.frequency(), f.max_arm());
    }

    #[test]
    fn switch_charges_stall_and_energy() {
        let (mut g, _) = mk_gpu();
        let stall = g.set_frequency(0);
        assert!((stall - 150e-6).abs() < 1e-12);
        assert_eq!(g.switches(), 1);
        // Same arm again: free.
        let stall = g.set_frequency(0);
        assert_eq!(stall, 0.0);
        assert_eq!(g.switches(), 1);
    }

    #[test]
    fn advance_accumulates_counters() {
        let (mut g, _) = mk_gpu();
        let iv = GpuInterval { dt_s: 0.01, energy_j: 4.0, core_util: 0.9, uncore_util: 0.5 };
        let mut total_measured = 0.0;
        for _ in 0..200 {
            total_measured += g.advance(iv, 0.0, 0.0).measured_energy_j;
        }
        // Counter equals the sum of measured increments.
        assert!((g.energy_j() - total_measured).abs() < 1e-2, "{}", g.energy_j());
        assert!((g.timestamp_s() - 2.0).abs() < 1e-6);
        // Measured total close to the true total (noise is unbiased).
        assert!((total_measured - 800.0).abs() < 40.0, "{total_measured}");
    }

    #[test]
    fn switch_energy_shows_in_outcome() {
        let (mut g, _) = mk_gpu();
        let iv = GpuInterval { dt_s: 0.01, energy_j: 4.0, core_util: 0.9, uncore_util: 0.5 };
        let out = g.advance(iv, 0.3, 150e-6);
        assert!((out.true_energy_j - 4.3).abs() < 1e-12);
        assert!((out.stall_s - 150e-6).abs() < 1e-15);
    }

    #[test]
    fn deterministic_given_seed() {
        let f = FreqDomain::aurora();
        let mut a = Gpu::new(0, &f, SwitchCost::default(), NoiseSpec::default(), Rng::new(9));
        let mut b = Gpu::new(0, &f, SwitchCost::default(), NoiseSpec::default(), Rng::new(9));
        let iv = GpuInterval { dt_s: 0.01, energy_j: 4.0, core_util: 0.9, uncore_util: 0.5 };
        for _ in 0..50 {
            let oa = a.advance(iv, 0.0, 0.0);
            let ob = b.advance(iv, 0.0, 0.0);
            assert_eq!(oa.measured_energy_j, ob.measured_energy_j);
        }
    }
}
