//! Aurora compute-node model: 6× PVC GPUs + 2× SPR CPUs + "other"
//! components (HBM, NICs, ...), running one workload to completion.
//!
//! The node is the unit the paper controls: one frequency decision per
//! 10 ms interval is applied to all six GPUs (SPMD workloads advance in
//! lockstep). Calibrated app models are node-level aggregates, so each GPU
//! draws 1/6 of the node GPU power with small static per-device imbalance,
//! and the controller observes the *aggregate* counters — exactly what the
//! GEOPM service exposes.

use super::freq::{FreqDomain, SwitchCost};
use super::gpu::{Gpu, GpuInterval};
use crate::util::Rng;
use crate::workload::model::AppModel;

pub const GPUS_PER_NODE: usize = 6;

/// Observation returned to the control plane after each interval.
#[derive(Clone, Copy, Debug)]
pub struct NodeObservation {
    /// Measured (noisy) GPU energy over the interval, all GPUs, Joules.
    pub gpu_energy_j: f64,
    /// Aggregate core-engine utilization in [0, 1] (noisy).
    pub core_util: f64,
    /// Aggregate uncore (copy-engine) utilization in [0, 1] (noisy).
    pub uncore_util: f64,
    /// Progress made this interval (fraction of the whole app).
    pub progress: f64,
    /// Remaining work (1 → 0).
    pub remaining: f64,
    /// True GPU energy this interval (ground truth, for metrics only).
    pub true_gpu_energy_j: f64,
    /// Whether the app finished during this interval.
    pub done: bool,
}

/// Final accounting for a completed run.
#[derive(Clone, Copy, Debug)]
pub struct NodeTotals {
    pub gpu_energy_kj: f64,
    pub cpu_energy_kj: f64,
    pub other_energy_kj: f64,
    pub exec_time_s: f64,
    pub switches: u64,
    pub switch_energy_j: f64,
    pub switch_time_s: f64,
    pub steps: u64,
}

impl NodeTotals {
    pub fn total_energy_kj(&self) -> f64 {
        self.gpu_energy_kj + self.cpu_energy_kj + self.other_energy_kj
    }
}

/// One Aurora node executing one application.
#[derive(Clone, Debug)]
pub struct Node {
    freqs: FreqDomain,
    app: AppModel,
    gpus: Vec<Gpu>,
    /// Static per-GPU power imbalance factors (mean 1.0).
    gpu_share: Vec<f64>,
    dt_s: f64,
    remaining: f64,
    elapsed_s: f64,
    true_gpu_energy_j: f64,
    cpu_energy_j: f64,
    other_energy_j: f64,
    steps: u64,
}

impl Node {
    pub fn new(app: AppModel, freqs: FreqDomain, dt_s: f64, seed: u64) -> Node {
        let mut rng = Rng::new(seed);
        // The switch cost (paper default: 150 µs, 0.3 J) is per node-level
        // transition event; split the energy across the six devices.
        let node_cost = freqs.switch_cost();
        let per_gpu_cost = SwitchCost {
            latency_s: node_cost.latency_s,
            energy_j: node_cost.energy_j / GPUS_PER_NODE as f64,
        };
        let gpus: Vec<Gpu> = (0..GPUS_PER_NODE)
            .map(|id| {
                Gpu::new(id, &freqs, per_gpu_cost, app.noise, rng.fork(0x6750_0000 + id as u64))
            })
            .collect();
        // Small fixed manufacturing variation between devices (±2 %),
        // normalized to mean exactly 1 so node totals match calibration.
        let mut share: Vec<f64> =
            (0..GPUS_PER_NODE).map(|_| 1.0 + rng.normal(0.0, 0.02)).collect();
        let mean: f64 = share.iter().sum::<f64>() / GPUS_PER_NODE as f64;
        for s in share.iter_mut() {
            *s /= mean;
        }
        Node {
            freqs,
            app,
            gpus,
            gpu_share: share,
            dt_s,
            remaining: 1.0,
            elapsed_s: 0.0,
            true_gpu_energy_j: 0.0,
            cpu_energy_j: 0.0,
            other_energy_j: 0.0,
            steps: 0,
        }
    }

    pub fn app(&self) -> &AppModel {
        &self.app
    }

    pub fn freqs(&self) -> &FreqDomain {
        &self.freqs
    }

    pub fn dt_s(&self) -> f64 {
        self.dt_s
    }

    pub fn done(&self) -> bool {
        self.remaining <= 0.0
    }

    pub fn remaining(&self) -> f64 {
        self.remaining
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed_s
    }

    /// Current frequency arm (all GPUs share it).
    pub fn frequency(&self) -> usize {
        self.gpus[0].frequency()
    }

    /// Execute one decision interval at frequency arm `arm`.
    ///
    /// Applies the DVFS request to all GPUs (charging switch overhead),
    /// advances workload progress (discounted by switch stall), burns GPU /
    /// CPU / other energy, and returns the aggregate noisy observation.
    pub fn step(&mut self, arm: usize) -> NodeObservation {
        assert!(!self.done(), "step() after completion");
        assert!(arm < self.freqs.k(), "arm {arm} out of range");
        let switched = arm != self.frequency();
        let cost = self.freqs.switch_cost();
        let stall_s = if switched { cost.latency_s } else { 0.0 };
        // Node-level switch energy split across the six devices.
        let switch_energy_per_gpu =
            if switched { cost.energy_j / GPUS_PER_NODE as f64 } else { 0.0 };

        // True node-level quantities at this frequency.
        let node_power_kw = self.app.power_kw(&self.freqs, arm);
        let node_energy_j = node_power_kw * 1_000.0 * self.dt_s;
        let core_util = self.app.uc(&self.freqs, arm);
        let uncore_util = self.app.uu(&self.freqs, arm);

        // Progress: the switch stall eats into the useful interval (clamped
        // at 0 — a stall longer than dt must not run progress backwards).
        let useful_frac = ((self.dt_s - stall_s) / self.dt_s).max(0.0);
        let progress =
            (self.app.progress_per_step(&self.freqs, arm, self.dt_s) * useful_frac)
                .min(self.remaining);

        // Core-engine stats snapshot before, to compute aggregate noisy
        // utilization from the counters (the controller-visible path).
        let mut measured_energy = 0.0;
        let mut true_energy = 0.0;
        let mut core_sum = 0.0;
        let mut uncore_sum = 0.0;
        for (g, share) in self.gpus.iter_mut().zip(&self.gpu_share) {
            g.set_frequency(arm);
            let before_core = g.engine_stats(super::counters::EngineGroup::Compute);
            let before_uncore = g.engine_stats(super::counters::EngineGroup::Copy);
            let iv = GpuInterval {
                dt_s: self.dt_s,
                energy_j: node_energy_j * share / GPUS_PER_NODE as f64,
                core_util,
                uncore_util,
            };
            let out = g.advance(iv, switch_energy_per_gpu, stall_s);
            measured_energy += out.measured_energy_j;
            true_energy += out.true_energy_j;
            let after_core = g.engine_stats(super::counters::EngineGroup::Compute);
            let after_uncore = g.engine_stats(super::counters::EngineGroup::Copy);
            core_sum += after_core.utilization_since(&before_core).unwrap_or(core_util);
            uncore_sum += after_uncore.utilization_since(&before_uncore).unwrap_or(uncore_util);
        }

        self.true_gpu_energy_j += true_energy;
        self.cpu_energy_j += self.app.cpu_kw * 1_000.0 * self.dt_s;
        self.other_energy_j += self.app.other_kw * 1_000.0 * self.dt_s;
        self.remaining = (self.remaining - progress).max(0.0);
        self.elapsed_s += self.dt_s;
        self.steps += 1;

        NodeObservation {
            gpu_energy_j: measured_energy,
            core_util: core_sum / GPUS_PER_NODE as f64,
            uncore_util: uncore_sum / GPUS_PER_NODE as f64,
            progress,
            remaining: self.remaining,
            true_gpu_energy_j: true_energy,
            done: self.remaining <= 0.0,
        }
    }

    /// Sum of the per-GPU monotonic energy counters (measured, noisy), J.
    pub fn counter_energy_j(&self) -> f64 {
        self.gpus.iter().map(|g| g.energy_j()).sum()
    }

    /// Mean per-GPU active time for an engine group, seconds.
    pub fn engine_active_s(&self, group: super::counters::EngineGroup) -> f64 {
        let total: f64 = self
            .gpus
            .iter()
            .map(|g| g.engine_stats(group).active_time_us as f64 / 1e6)
            .sum();
        total / GPUS_PER_NODE as f64
    }

    /// Final accounting (valid any time; complete once `done()`).
    pub fn totals(&self) -> NodeTotals {
        NodeTotals {
            gpu_energy_kj: self.true_gpu_energy_j / 1_000.0,
            cpu_energy_kj: self.cpu_energy_j / 1_000.0,
            other_energy_kj: self.other_energy_j / 1_000.0,
            exec_time_s: self.elapsed_s,
            switches: self.gpus[0].switches(),
            switch_energy_j: self.gpus.iter().map(|g| g.switch_energy_j()).sum(),
            switch_time_s: self.gpus[0].switch_time_s(),
            steps: self.steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    fn mk(name: &str, seed: u64) -> Node {
        Node::new(calibration::app(name).unwrap(), FreqDomain::aurora(), 0.01, seed)
    }

    /// Run the node to completion at a fixed arm; returns totals.
    fn run_static(name: &str, arm: usize, seed: u64) -> NodeTotals {
        let mut n = mk(name, seed);
        let cap = 200_000;
        for _ in 0..cap {
            if n.done() {
                break;
            }
            n.step(arm);
        }
        assert!(n.done(), "did not finish");
        n.totals()
    }

    #[test]
    fn static_max_freq_reproduces_table1_lbm() {
        let t = run_static("lbm", 8, 42);
        // Table 1: lbm @ 1.6 GHz = 93.94 kJ; one switchless static run.
        assert!((t.gpu_energy_kj - 93.94).abs() < 0.5, "{}", t.gpu_energy_kj);
        assert_eq!(t.switches, 0);
        assert!((t.exec_time_s - 35.0).abs() < 0.05, "{}", t.exec_time_s);
    }

    #[test]
    fn static_low_freq_reproduces_table1_miniswp() {
        let t = run_static("miniswp", 0, 7);
        // One switch down to 0.8 GHz at t=0, then static: 158.74 kJ.
        assert!((t.gpu_energy_kj - 158.74).abs() < 1.0, "{}", t.gpu_energy_kj);
        assert_eq!(t.switches, 1);
    }

    #[test]
    fn execution_time_scales_with_frequency() {
        let fast = run_static("clvleaf", 8, 1).exec_time_s;
        let slow = run_static("clvleaf", 0, 1).exec_time_s;
        // theta = 0.5 -> T(0.8) = 1.5 * T(1.6).
        assert!((slow / fast - 1.5).abs() < 0.02, "{}", slow / fast);
    }

    #[test]
    fn observation_ratio_reflects_boundedness() {
        let mut compute = mk("lbm", 3);
        let mut memory = mk("sph_exa", 3);
        let mut rc = 0.0;
        let mut rm = 0.0;
        let n = 100;
        for _ in 0..n {
            let oc = compute.step(8);
            let om = memory.step(8);
            rc += oc.core_util / oc.uncore_util;
            rm += om.core_util / om.uncore_util;
        }
        // Compute-bound lbm has a much higher core-to-uncore ratio.
        assert!(rc / n as f64 > 2.0 * rm / n as f64, "rc={rc} rm={rm}");
    }

    #[test]
    fn switch_overheads_accumulate() {
        let mut n = mk("tealeaf", 5);
        // Oscillate every step for 100 steps.
        for i in 0..100 {
            n.step(i % 2);
        }
        let t = n.totals();
        assert_eq!(t.switches, 100); // first step switches 8 -> 0 too
        // 0.3 J per node-level switch event (paper S4.4).
        assert!((t.switch_energy_j - 100.0 * 0.3).abs() < 1e-6);
        assert!((t.switch_time_s - 100.0 * 150e-6).abs() < 1e-9);
    }

    #[test]
    fn progress_reaches_done_and_stops() {
        let mut n = mk("clvleaf", 11);
        let mut steps = 0;
        while !n.done() {
            n.step(8);
            steps += 1;
            assert!(steps < 10_000, "runaway");
        }
        assert!(n.remaining() <= 0.0);
        // ~40 s / 10 ms = ~4000 steps.
        assert!((steps as f64 - 4000.0).abs() < 40.0, "{steps}");
    }

    #[test]
    fn cpu_and_other_energy_accounted() {
        let t = run_static("pot3d", 8, 13);
        let total = t.total_energy_kj();
        let gpu_share = t.gpu_energy_kj / total;
        // Fig. 1(a): pot3d GPU share about 75 %.
        assert!((gpu_share - 0.751).abs() < 0.02, "{gpu_share}");
    }

    #[test]
    fn custom_switch_cost_takes_effect() {
        // Regression: Node used to hard-code SwitchCost::default() in both
        // new() and step(), silently ignoring any configured cost.
        let custom = SwitchCost { latency_s: 300e-6, energy_j: 1.2 };
        let freqs = FreqDomain::aurora().with_switch_cost(custom);
        let mut n =
            Node::new(calibration::app("tealeaf").unwrap(), freqs, 0.01, 5);
        for i in 0..100 {
            n.step(i % 2);
        }
        let t = n.totals();
        assert_eq!(t.switches, 100);
        // 1.2 J and 300 µs per node-level switch event.
        assert!((t.switch_energy_j - 100.0 * 1.2).abs() < 1e-6, "{}", t.switch_energy_j);
        assert!((t.switch_time_s - 100.0 * 300e-6).abs() < 1e-9, "{}", t.switch_time_s);
    }

    #[test]
    fn deterministic_across_same_seed() {
        let a = run_static("weather", 4, 99);
        let b = run_static("weather", 4, 99);
        assert_eq!(a.gpu_energy_kj, b.gpu_energy_kj);
        assert_eq!(a.steps, b.steps);
    }
}
