//! Hardware counter models.
//!
//! The controller only ever observes the GPU through counters, exactly as
//! on the real system: a monotonic energy counter (µJ), a timestamp counter
//! (µs), and per-engine-group active-time counters (µs) in the style of
//! Level-Zero's `zes_engine_stats_t`. All counters are monotonic u64 and
//! wrap-free over any realistic run; consumers diff successive readings.

/// Engine groups exposed by the PVC sysman interface that we model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineGroup {
    /// Compute (vector + matrix) engines — "core".
    Compute,
    /// Copy engines (data movement) — "uncore".
    Copy,
}

/// One monotonic counter with µ-unit integer resolution.
#[derive(Clone, Debug, Default)]
pub struct MonotonicCounter {
    raw: u64,
}

impl MonotonicCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `amount` in micro-units; saturates instead of wrapping.
    pub fn add_micro(&mut self, amount: u64) {
        self.raw = self.raw.saturating_add(amount);
    }

    /// Add a floating amount expressed in base units (J or s), converted to
    /// micro-units with rounding.
    pub fn add(&mut self, base_units: f64) {
        debug_assert!(base_units >= 0.0, "monotonic counter cannot decrease");
        self.add_micro((base_units * 1e6).round() as u64)
    }

    /// Raw micro-unit reading.
    pub fn read_micro(&self) -> u64 {
        self.raw
    }

    /// Reading in base units (J or s).
    pub fn read(&self) -> f64 {
        self.raw as f64 / 1e6
    }
}

/// A timestamped snapshot of one engine group's activity, mirroring
/// `zes_engine_stats_t { activeTime, timestamp }`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineStats {
    pub active_time_us: u64,
    pub timestamp_us: u64,
}

impl EngineStats {
    /// Utilization between two snapshots: Δactive / Δtimestamp.
    /// Returns `None` when no time elapsed.
    pub fn utilization_since(&self, earlier: &EngineStats) -> Option<f64> {
        let dt = self.timestamp_us.checked_sub(earlier.timestamp_us)?;
        if dt == 0 {
            return None;
        }
        let da = self.active_time_us.saturating_sub(earlier.active_time_us);
        Some(da as f64 / dt as f64)
    }
}

/// The full counter block of one GPU.
#[derive(Clone, Debug)]
pub struct GpuCounters {
    /// Monotonic energy, µJ.
    pub energy: MonotonicCounter,
    /// Device timestamp, µs.
    pub timestamp: MonotonicCounter,
    /// Compute-engine active time, µs.
    pub core_active: MonotonicCounter,
    /// Copy-engine active time, µs.
    pub uncore_active: MonotonicCounter,
}

impl GpuCounters {
    pub fn new() -> GpuCounters {
        GpuCounters {
            energy: MonotonicCounter::new(),
            timestamp: MonotonicCounter::new(),
            core_active: MonotonicCounter::new(),
            uncore_active: MonotonicCounter::new(),
        }
    }

    /// Advance all counters by one interval.
    ///
    /// * `dt_s` — wall time elapsed;
    /// * `energy_j` — energy consumed in the interval (including switch
    ///   overhead, as the real counter would see it);
    /// * `core_util` / `uncore_util` — active fractions in [0, 1].
    pub fn advance(&mut self, dt_s: f64, energy_j: f64, core_util: f64, uncore_util: f64) {
        debug_assert!((0.0..=1.0 + 1e-9).contains(&core_util));
        debug_assert!((0.0..=1.0 + 1e-9).contains(&uncore_util));
        self.timestamp.add(dt_s);
        self.energy.add(energy_j.max(0.0));
        self.core_active.add(dt_s * core_util.clamp(0.0, 1.0));
        self.uncore_active.add(dt_s * uncore_util.clamp(0.0, 1.0));
    }

    pub fn engine_stats(&self, group: EngineGroup) -> EngineStats {
        let active = match group {
            EngineGroup::Compute => &self.core_active,
            EngineGroup::Copy => &self.uncore_active,
        };
        EngineStats {
            active_time_us: active.read_micro(),
            timestamp_us: self.timestamp.read_micro(),
        }
    }
}

impl Default for GpuCounters {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotonic() {
        let mut c = GpuCounters::new();
        let mut last_e = 0;
        let mut last_t = 0;
        for i in 0..100 {
            c.advance(0.01, 20.0 + (i % 7) as f64, 0.9, 0.5);
            assert!(c.energy.read_micro() >= last_e);
            assert!(c.timestamp.read_micro() > last_t);
            last_e = c.energy.read_micro();
            last_t = c.timestamp.read_micro();
        }
    }

    #[test]
    fn energy_diff_reconstructs_interval() {
        let mut c = GpuCounters::new();
        let before = c.energy.read();
        c.advance(0.01, 23.25, 0.9, 0.5);
        let after = c.energy.read();
        assert!((after - before - 23.25).abs() < 1e-5);
    }

    #[test]
    fn utilization_from_engine_stats() {
        let mut c = GpuCounters::new();
        let s0 = c.engine_stats(EngineGroup::Compute);
        let u0 = c.engine_stats(EngineGroup::Copy);
        for _ in 0..10 {
            c.advance(0.01, 20.0, 0.9, 0.45);
        }
        let s1 = c.engine_stats(EngineGroup::Compute);
        let u1 = c.engine_stats(EngineGroup::Copy);
        let core = s1.utilization_since(&s0).unwrap();
        let copy = u1.utilization_since(&u0).unwrap();
        assert!((core - 0.9).abs() < 1e-3, "{core}");
        assert!((copy - 0.45).abs() < 1e-3, "{copy}");
    }

    #[test]
    fn zero_elapsed_yields_none() {
        let c = GpuCounters::new();
        let s = c.engine_stats(EngineGroup::Compute);
        assert_eq!(s.utilization_since(&s), None);
    }

    #[test]
    fn negative_energy_clamped() {
        let mut c = GpuCounters::new();
        c.advance(0.01, -5.0, 0.5, 0.5); // noisy reading below zero
        assert_eq!(c.energy.read_micro(), 0);
    }
}
