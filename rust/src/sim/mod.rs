//! Hardware substrate simulators.
//!
//! The paper evaluates on an Aurora node (6× Intel PVC GPUs) driven through
//! GEOPM; neither is available here, so this module provides the
//! trace-calibrated equivalents (see DESIGN.md §3): frequency domain + DVFS
//! state machine, hardware counters, measurement noise, single-GPU device
//! model, and the six-GPU node.

pub mod counters;
pub mod freq;
pub mod gpu;
pub mod node;
pub mod noise;
pub mod power;

pub use freq::{DvfsState, FreqDomain, SwitchCost};
pub use node::{Node, NodeObservation, NodeTotals};
