//! Evaluation metrics (paper §4.1): Saved Energy, Energy Regret, slowdown,
//! switching overhead, and reward-space cumulative regret.

use crate::sim::freq::FreqDomain;
use crate::workload::model::AppModel;

/// Final metrics of one controlled run of one app.
#[derive(Clone, Debug, PartialEq)]
pub struct RunMetrics {
    pub app: String,
    pub policy: String,
    /// Total GPU energy, kJ (the paper's Table-1 quantity).
    pub gpu_energy_kj: f64,
    /// Execution time, seconds.
    pub exec_time_s: f64,
    /// Frequency transitions performed.
    pub switches: u64,
    /// Energy charged to transitions, J.
    pub switch_energy_j: f64,
    /// Stall time charged to transitions, s.
    pub switch_time_s: f64,
    /// Final cumulative reward-space regret (raw reward units).
    pub cumulative_regret: f64,
    /// Decision steps taken.
    pub steps: u64,
    /// Work fraction completed (1.0 = ran to job completion; < 1.0 when
    /// the run was cut off by a step budget).
    pub completed: f64,
    /// Fraction of active intervals violating the serving tier's
    /// TTFT-style QoS budget (normalized queue depth above budget).
    /// `None` for context-free runs and runs without a budget — the
    /// report surface only grows a QoS column when this is populated.
    pub qos_violation_frac: Option<f64>,
}

impl RunMetrics {
    /// Work fraction clamped to [0, 1] (guards degenerate zero-step runs).
    fn completed_frac(&self) -> f64 {
        self.completed.clamp(0.0, 1.0)
    }

    /// Saved Energy vs the default maximum frequency (kJ; positive =
    /// saved). Budget-capped runs (`completed < 1`) completed only part of
    /// the job, so they compare against the same fraction of the
    /// default-frequency run — the full-job baseline used to overstate
    /// savings for cut-off nodes (the cluster merge fixed this in PR 2;
    /// the metric itself now owns the scaling).
    pub fn saved_energy_kj(&self, app: &AppModel, freqs: &FreqDomain) -> f64 {
        app.energy_kj[freqs.max_arm()] * self.completed_frac() - self.gpu_energy_kj
    }

    /// Energy Regret vs the best static configuration (kJ; >= 0 for any
    /// honest online method, up to simulation noise).
    pub fn energy_regret_kj(&self, app: &AppModel) -> f64 {
        self.gpu_energy_kj - app.optimal_energy_kj()
    }

    /// Relative slowdown vs the max-frequency execution time. Budget-capped
    /// runs compare against the max-frequency time for the *same completed
    /// work fraction* — dividing partial-work time by the full-job
    /// `t_max_s` used to understate slowdown for cut-off nodes.
    pub fn slowdown(&self, app: &AppModel) -> f64 {
        let frac = self.completed_frac().max(1e-12);
        self.exec_time_s / (app.t_max_s * frac) - 1.0
    }
}

/// Aggregate of repeated runs (mean ± sample std), Table-2 style.
#[derive(Clone, Debug)]
pub struct RepeatedMetrics {
    pub app: String,
    pub policy: String,
    pub reps: usize,
    pub energy_mean_kj: f64,
    pub energy_std_kj: f64,
    pub time_mean_s: f64,
    pub switches_mean: f64,
    pub switch_energy_mean_j: f64,
    pub switch_time_mean_s: f64,
    pub regret_mean: f64,
}

impl RepeatedMetrics {
    pub fn from_runs(runs: &[RunMetrics]) -> RepeatedMetrics {
        assert!(!runs.is_empty());
        let energies: Vec<f64> = runs.iter().map(|r| r.gpu_energy_kj).collect();
        let times: Vec<f64> = runs.iter().map(|r| r.exec_time_s).collect();
        RepeatedMetrics {
            app: runs[0].app.clone(),
            policy: runs[0].policy.clone(),
            reps: runs.len(),
            energy_mean_kj: crate::util::stats::mean(&energies),
            energy_std_kj: crate::util::stats::sample_std(&energies),
            time_mean_s: crate::util::stats::mean(&times),
            switches_mean: crate::util::stats::mean(
                &runs.iter().map(|r| r.switches as f64).collect::<Vec<_>>(),
            ),
            switch_energy_mean_j: crate::util::stats::mean(
                &runs.iter().map(|r| r.switch_energy_j).collect::<Vec<_>>(),
            ),
            switch_time_mean_s: crate::util::stats::mean(
                &runs.iter().map(|r| r.switch_time_s).collect::<Vec<_>>(),
            ),
            regret_mean: crate::util::stats::mean(
                &runs.iter().map(|r| r.cumulative_regret).collect::<Vec<_>>(),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    fn run(kj: f64, time: f64) -> RunMetrics {
        RunMetrics {
            app: "tealeaf".into(),
            policy: "test".into(),
            gpu_energy_kj: kj,
            exec_time_s: time,
            switches: 10,
            switch_energy_j: 3.0,
            switch_time_s: 0.0015,
            cumulative_regret: 100.0,
            steps: 4500,
            completed: 1.0,
            qos_violation_frac: None,
        }
    }

    #[test]
    fn saved_energy_vs_default() {
        let app = calibration::app("tealeaf").unwrap();
        let f = FreqDomain::aurora();
        let m = run(99.06, 50.0);
        // Paper: tealeaf default 109.79, EnergyUCB 99.06 => saved 10.73.
        assert!((m.saved_energy_kj(&app, &f) - 10.73).abs() < 1e-9);
    }

    #[test]
    fn energy_regret_vs_best_static() {
        let app = calibration::app("tealeaf").unwrap();
        let m = run(99.06, 50.0);
        // Best static 98.61 @1.0 GHz => regret 0.45 (the paper's row).
        assert!((m.energy_regret_kj(&app) - 0.45).abs() < 1e-9);
    }

    #[test]
    fn slowdown_vs_tmax() {
        let app = calibration::app("tealeaf").unwrap();
        let m = run(99.06, 49.5);
        assert!((m.slowdown(&app) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn budget_capped_runs_scale_baselines_by_completed_work() {
        // Regression: a run cut off at half the job used to be compared
        // against the FULL-job max-frequency baselines, overstating saved
        // energy and understating slowdown.
        let app = calibration::app("tealeaf").unwrap();
        let f = FreqDomain::aurora();
        let default_kj = app.energy_kj[f.max_arm()]; // 109.79
        // Half the job, at 10 % real slowdown, using half of 99.06 kJ.
        let m = RunMetrics { completed: 0.5, ..run(99.06 / 2.0, app.t_max_s * 0.5 * 1.1) };
        assert!((m.saved_energy_kj(&app, &f) - (default_kj * 0.5 - 99.06 / 2.0)).abs() < 1e-9);
        assert!((m.slowdown(&app) - 0.1).abs() < 1e-9);
        // Pre-fix values for contrast: saved would read ~65 kJ (vs the
        // honest ~5.4), slowdown would read -45 % (vs the honest +10 %).
        assert!(default_kj - 99.06 / 2.0 > 55.0);
        assert!(app.t_max_s * 0.55 / app.t_max_s - 1.0 < 0.0);
        // Full completion is untouched (exact same arithmetic).
        let full = run(99.06, 49.5);
        assert!((full.saved_energy_kj(&app, &f) - (default_kj - 99.06)).abs() < 1e-12);
        // Degenerate zero-completion runs stay finite.
        let zero = RunMetrics { completed: 0.0, exec_time_s: 0.0, ..run(0.0, 0.0) };
        assert!(zero.slowdown(&app).is_finite());
        assert_eq!(zero.saved_energy_kj(&app, &f), 0.0);
    }

    #[test]
    fn repeated_metrics_aggregate() {
        let runs = vec![run(100.0, 50.0), run(102.0, 52.0), run(98.0, 48.0)];
        let agg = RepeatedMetrics::from_runs(&runs);
        assert_eq!(agg.reps, 3);
        assert!((agg.energy_mean_kj - 100.0).abs() < 1e-9);
        assert!((agg.energy_std_kj - 2.0).abs() < 1e-9);
        assert!((agg.time_mean_s - 50.0).abs() < 1e-9);
    }
}
