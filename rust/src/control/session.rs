//! A control session: one policy driving one application on one node,
//! from job start to completion — the paper's experimental unit.
//!
//! Since the sans-IO redesign the session is a thin composition:
//! [`run_session`] builds a [`SimBackend`] (the simulated GEOPM stack)
//! and a [`Controller`] (the pure decision core owning the B = 1
//! [`Scalar`][crate::bandit::Scalar] policy bridge, reward normalization,
//! regret accounting, and checkpoint bookkeeping), then hands both to
//! [`drive`]. Pointing the same controller at a
//! [`ReplayBackend`](super::replay::ReplayBackend) instead replays
//! recorded telemetry; wrapping the backend in
//! [`Recording`](super::backend::Recording) tees the run to disk. See
//! EXPERIMENTS.md §Controller.

use crate::bandit::Policy;
use crate::bandit::RewardForm;
use crate::sim::freq::{FreqDomain, SwitchCost};
use crate::telemetry::Recorder;
use crate::workload::model::AppModel;
use crate::workload::serving::{ServingCfg, ServingModel};
use crate::workload::trace::Trace;

use super::backend::SimBackend;
use super::controller::{drive, Controller};
use super::metrics::RunMetrics;

/// Session configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionCfg {
    /// Decision/sampling interval, seconds (paper: 10 ms).
    pub dt_s: f64,
    /// Simulation seed.
    pub seed: u64,
    /// Record the full per-step trace (memory-heavy on long runs).
    pub record_trace: bool,
    /// Safety cap on decision steps.
    pub max_steps: u64,
    /// Reward formulation (Fig. 5(a) axis).
    pub reward_form: RewardForm,
    /// Number of progress checkpoints for phase-energy accounting.
    pub checkpoints: usize,
    /// Selectable frequency arms (default: Aurora PVC, K = 9). The
    /// calibrated app tables are indexed per arm, so the domain length
    /// must match the app's calibration (9 for the shipped suite).
    pub freqs: FreqDomain,
    /// Per-transition DVFS cost (paper default: 150 µs, 0.3 J).
    pub switch_cost: SwitchCost,
}

impl Default for SessionCfg {
    fn default() -> Self {
        SessionCfg {
            dt_s: 0.01,
            seed: 0,
            record_trace: false,
            max_steps: 2_000_000,
            reward_form: RewardForm::EnergyRatio,
            checkpoints: 100,
            freqs: FreqDomain::aurora(),
            switch_cost: SwitchCost::default(),
        }
    }
}

impl SessionCfg {
    /// The resolved frequency domain: the configured arm set carrying the
    /// configured switch cost (single source of truth for the node
    /// simulator and the regret ground truth).
    pub fn domain(&self) -> FreqDomain {
        self.freqs.clone().with_switch_cost(self.switch_cost)
    }
}

/// Everything a completed session yields.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub metrics: RunMetrics,
    pub trace: Option<Trace>,
    /// Cumulative true GPU energy (J) at each progress checkpoint
    /// i/checkpoints, i = 1..=checkpoints (for the DRLCap 20 %/80 %
    /// protocol).
    pub energy_checkpoints_j: Vec<f64>,
    /// Operational telemetry: `controller.switch_rate` gauge,
    /// `controller.steps`/`controller.switches` counters (deterministic),
    /// and the driver's `controller.decide_latency_us` gauge (wall
    /// clock, sampled every 64th decision). Hw-backend runs add the
    /// `hw.apply_latency_us`/`hw.sample_latency_us` gauges and the
    /// `hw.driver_errors`/`hw.dwell_deferred`/`hw.clamped`/
    /// `hw.watchdog_trips` counters (see `hw::HwBackend::export_telemetry`).
    pub telemetry: Recorder,
}

impl RunResult {
    /// True GPU energy consumed up to progress fraction `frac`, Joules
    /// (linear interpolation between checkpoints).
    pub fn energy_at_progress_j(&self, frac: f64) -> f64 {
        let n = self.energy_checkpoints_j.len();
        if n == 0 {
            return 0.0;
        }
        let pos = (frac.clamp(0.0, 1.0) * n as f64) - 1.0;
        if pos <= 0.0 {
            return self.energy_checkpoints_j[0] * (frac.clamp(0.0, 1.0) * n as f64);
        }
        let lo = pos.floor() as usize;
        let hi = (lo + 1).min(n - 1);
        let t = pos - lo as f64;
        self.energy_checkpoints_j[lo] * (1.0 - t) + self.energy_checkpoints_j[hi] * t
    }
}

/// Run one session to completion: the pure [`Controller`] driven against
/// the simulated GEOPM [`SimBackend`]. Byte-identical to the historical
/// monolithic loop (pinned by `tests/controller_parity.rs`).
pub fn run_session(app: &AppModel, policy: &mut dyn Policy, cfg: &SessionCfg) -> RunResult {
    let mut backend = SimBackend::new(app, cfg);
    let controller = Controller::new(app, policy, cfg);
    drive(controller, &mut backend)
        .expect("simulated backend is infallible")
        .pop()
        .expect("B = 1 drive yields exactly one result")
}

/// [`run_session`] under an inference-serving workload: the backend
/// carries a [`ServingModel`] whose feature vector rides every sample as
/// context, and the controller scores the TTFT-style QoS budget
/// ([`RunMetrics::qos_violation_frac`]). Context-free policies behave
/// exactly as in [`run_session`] modulo the serving model's samples —
/// the decision plane only *offers* the context.
pub fn run_session_serving(
    app: &AppModel,
    policy: &mut dyn Policy,
    cfg: &SessionCfg,
    serving: &ServingCfg,
) -> RunResult {
    let mut backend = SimBackend::new(app, cfg).with_serving(ServingModel::new(serving.clone()));
    let controller =
        Controller::new(app, policy, cfg).with_qos_budget(Some(serving.ttft_budget));
    drive(controller, &mut backend)
        .expect("simulated backend is infallible")
        .pop()
        .expect("B = 1 drive yields exactly one result")
}

/// [`run_repeated`] under a serving workload: rep `r` shifts both the
/// session seed and the serving arrival-process seed by `r`, so reps see
/// independent-but-reproducible traffic.
pub fn run_repeated_serving(
    app: &AppModel,
    policy: &mut dyn Policy,
    cfg: &SessionCfg,
    serving: &ServingCfg,
    reps: usize,
    seed0: u64,
) -> Vec<RunResult> {
    (0..reps)
        .map(|r| {
            policy.reset();
            let cfg = SessionCfg { seed: seed0 + r as u64, ..cfg.clone() };
            let srv = ServingCfg { seed: serving.seed + r as u64, ..serving.clone() };
            run_session_serving(app, policy, &cfg, &srv)
        })
        .collect()
}

/// Run `reps` sessions with seeds `seed0..seed0+reps`, resetting the policy
/// between runs.
pub fn run_repeated(
    app: &AppModel,
    policy: &mut dyn Policy,
    cfg: &SessionCfg,
    reps: usize,
    seed0: u64,
) -> Vec<RunResult> {
    (0..reps)
        .map(|r| {
            policy.reset();
            let cfg = SessionCfg { seed: seed0 + r as u64, ..cfg.clone() };
            run_session(app, policy, &cfg)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::{EnergyUcb, EnergyUcbConfig, RoundRobin, StaticPolicy};
    use crate::workload::calibration;

    #[test]
    fn static_session_reproduces_table1() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = StaticPolicy::new(9, 8);
        let res = run_session(&app, &mut policy, &SessionCfg::default());
        assert!((res.metrics.gpu_energy_kj - 100.65).abs() < 0.8, "{}", res.metrics.gpu_energy_kj);
        assert_eq!(res.metrics.switches, 0);
        assert_eq!(res.metrics.cumulative_regret > 0.0, true);
    }

    #[test]
    fn energyucb_beats_default_frequency() {
        let app = calibration::app("tealeaf").unwrap();
        let mut policy = EnergyUcb::new(9, EnergyUcbConfig::default());
        let res = run_session(
            &app,
            &mut policy,
            &SessionCfg { seed: 3, ..SessionCfg::default() },
        );
        // Default 1.6 GHz = 109.79 kJ; EnergyUCB must save energy.
        assert!(
            res.metrics.gpu_energy_kj < 105.0,
            "energy {}",
            res.metrics.gpu_energy_kj
        );
        // And not be below the physically-optimal static config minus noise.
        assert!(res.metrics.gpu_energy_kj > 95.0);
    }

    #[test]
    fn rrfreq_has_linear_regret() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = RoundRobin::new(9);
        let cfg = SessionCfg { record_trace: true, ..SessionCfg::default() };
        let res = run_session(&app, &mut policy, &cfg);
        let trace = res.trace.unwrap();
        let cum = trace.cumulative_regret();
        // Regret at the halfway point should be ~half the final value.
        let half = cum[cum.len() / 2];
        let fin = *cum.last().unwrap();
        assert!((half / fin - 0.5).abs() < 0.05, "half={half} fin={fin}");
    }

    #[test]
    fn checkpoints_monotone_and_complete() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = StaticPolicy::new(9, 4);
        let res = run_session(&app, &mut policy, &SessionCfg::default());
        let cps = &res.energy_checkpoints_j;
        assert_eq!(cps.len(), 100);
        assert!(cps.windows(2).all(|w| w[1] >= w[0]));
        // Final checkpoint equals total energy.
        assert!(
            (cps[99] / 1000.0 - res.metrics.gpu_energy_kj).abs() < 0.5,
            "{} vs {}",
            cps[99] / 1000.0,
            res.metrics.gpu_energy_kj
        );
        // 20 % checkpoint is ~20 % of total (static run, constant power).
        let e20 = res.energy_at_progress_j(0.2);
        assert!((e20 / cps[99] - 0.2).abs() < 0.02, "{}", e20 / cps[99]);
    }

    #[test]
    fn capped_run_reports_partial_completion() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = StaticPolicy::new(9, 8);
        let cfg = SessionCfg { max_steps: 500, ..SessionCfg::default() };
        let res = run_session(&app, &mut policy, &cfg);
        assert_eq!(res.metrics.steps, 500);
        assert!(
            res.metrics.completed > 0.0 && res.metrics.completed < 1.0,
            "{}",
            res.metrics.completed
        );
        // Uncapped runs report full completion.
        let full = run_session(&app, &mut StaticPolicy::new(9, 8), &SessionCfg::default());
        assert!((full.metrics.completed - 1.0).abs() < 1e-9, "{}", full.metrics.completed);
    }

    #[test]
    fn repeated_runs_vary_by_seed_only() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = EnergyUcb::new(9, EnergyUcbConfig::default());
        let results = run_repeated(&app, &mut policy, &SessionCfg::default(), 3, 100);
        assert_eq!(results.len(), 3);
        // Different seeds -> different trajectories (energy differs).
        let e: Vec<f64> = results.iter().map(|r| r.metrics.gpu_energy_kj).collect();
        assert!(e[0] != e[1] || e[1] != e[2], "{e:?}");
        // All in a sane band.
        for v in &e {
            assert!(*v > 85.0 && *v < 105.0, "{v}");
        }
    }

    #[test]
    fn session_honors_custom_switch_cost() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = RoundRobin::new(9);
        let cfg = SessionCfg {
            switch_cost: SwitchCost { latency_s: 150e-6, energy_j: 0.9 },
            ..SessionCfg::default()
        };
        let res = run_session(&app, &mut policy, &cfg);
        assert!(res.metrics.switches > 0);
        // 0.9 J per node-level transition, end to end through the service.
        assert!(
            (res.metrics.switch_energy_j - res.metrics.switches as f64 * 0.9).abs() < 1e-6,
            "{} switches, {} J",
            res.metrics.switches,
            res.metrics.switch_energy_j
        );
    }

    #[test]
    fn trace_switches_match_metrics() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = RoundRobin::new(9);
        let cfg = SessionCfg { record_trace: true, ..SessionCfg::default() };
        let res = run_session(&app, &mut policy, &cfg);
        assert_eq!(res.trace.unwrap().switch_count(), res.metrics.switches);
    }

    #[test]
    fn session_honors_custom_frequency_domain() {
        // A like-for-like 9-arm domain at shifted clocks: the domain is
        // plumbed end to end (policy arity, node model, regret ground
        // truth) with no Aurora hard-coding left in the path.
        let app = calibration::app("clvleaf").unwrap();
        let shifted = FreqDomain::new((9..=17).map(|i| i as f64 / 10.0).collect());
        let cfg = SessionCfg {
            freqs: shifted.clone(),
            max_steps: 400,
            ..SessionCfg::default()
        };
        assert_eq!(cfg.domain().k(), 9);
        let mut policy = StaticPolicy::new(9, 8);
        let res = run_session(&app, &mut policy, &cfg);
        assert_eq!(res.metrics.steps, 400);
        assert!(res.metrics.gpu_energy_kj > 0.0);
        // Same seed, same arm set length, different clocks: the default
        // domain's run differs (time curve is a function of f_max / f).
        let default_run = run_session(
            &app,
            &mut StaticPolicy::new(9, 4),
            &SessionCfg { max_steps: 400, ..SessionCfg::default() },
        );
        let shifted_run = run_session(
            &app,
            &mut StaticPolicy::new(9, 4),
            &SessionCfg { freqs: shifted, max_steps: 400, ..SessionCfg::default() },
        );
        assert_ne!(default_run.metrics.gpu_energy_kj, shifted_run.metrics.gpu_energy_kj);
    }

    #[test]
    fn run_result_exposes_session_telemetry() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = RoundRobin::new(9);
        let cfg = SessionCfg { max_steps: 300, ..SessionCfg::default() };
        let res = run_session(&app, &mut policy, &cfg);
        // Deterministic gauges/counters from the controller...
        assert_eq!(res.telemetry.counter_value("controller.steps"), Some(300));
        assert_eq!(
            res.telemetry.counter_value("controller.switches"),
            Some(res.metrics.switches)
        );
        let rate = res.telemetry.gauge_mean("controller.switch_rate").unwrap();
        assert!(
            (rate - res.metrics.switches as f64 / 300.0).abs() < 1e-9,
            "{rate}"
        );
        // ...plus the driver's wall-clock decision-latency gauge,
        // sampled every 64th decision (t = 0, 64, 128, 192, 256).
        let lat = res.telemetry.gauge_get("controller.decide_latency_us").unwrap();
        assert_eq!(lat.count(), 5);
        assert!(lat.mean() >= 0.0);
    }
}
