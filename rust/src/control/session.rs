//! A control session: one policy driving one application on one node,
//! from job start to completion — the paper's experimental unit.
//!
//! The session wires policy ↔ GEOPM: each interval it reads the previous
//! observation, forms the reward from counters (Eq. 4 or a Fig.-5a
//! variant), normalizes it, lets the policy pick the next arm, and applies
//! it through the service. Ground-truth regret accounting happens here
//! (simulation-only knowledge, never shown to the policy).
//!
//! Policy driving goes through the batch policy core: the scalar policy is
//! wrapped in a B = 1 [`Scalar`] bridge and stepped through the same
//! `select_into`/`update_batch` surface the fleet and cluster tiers use
//! (stack buffers — the trace-off hot loop performs no per-step
//! allocations).

use crate::bandit::batch::{BatchPolicy, Scalar};
use crate::bandit::{Policy, RewardForm, RewardNormalizer};
use crate::geopm::{Control, Service};
use crate::sim::freq::{FreqDomain, SwitchCost};
use crate::sim::node::Node;
use crate::workload::model::AppModel;
use crate::workload::trace::{Trace, TraceStep};

use super::metrics::RunMetrics;

/// Session configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionCfg {
    /// Decision/sampling interval, seconds (paper: 10 ms).
    pub dt_s: f64,
    /// Simulation seed.
    pub seed: u64,
    /// Record the full per-step trace (memory-heavy on long runs).
    pub record_trace: bool,
    /// Safety cap on decision steps.
    pub max_steps: u64,
    /// Reward formulation (Fig. 5(a) axis).
    pub reward_form: RewardForm,
    /// Number of progress checkpoints for phase-energy accounting.
    pub checkpoints: usize,
    /// Per-transition DVFS cost (paper default: 150 µs, 0.3 J).
    pub switch_cost: SwitchCost,
}

impl Default for SessionCfg {
    fn default() -> Self {
        SessionCfg {
            dt_s: 0.01,
            seed: 0,
            record_trace: false,
            max_steps: 2_000_000,
            reward_form: RewardForm::EnergyRatio,
            checkpoints: 100,
            switch_cost: SwitchCost::default(),
        }
    }
}

/// Everything a completed session yields.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub metrics: RunMetrics,
    pub trace: Option<Trace>,
    /// Cumulative true GPU energy (J) at each progress checkpoint
    /// i/checkpoints, i = 1..=checkpoints (for the DRLCap 20 %/80 %
    /// protocol).
    pub energy_checkpoints_j: Vec<f64>,
}

impl RunResult {
    /// True GPU energy consumed up to progress fraction `frac`, Joules
    /// (linear interpolation between checkpoints).
    pub fn energy_at_progress_j(&self, frac: f64) -> f64 {
        let n = self.energy_checkpoints_j.len();
        if n == 0 {
            return 0.0;
        }
        let pos = (frac.clamp(0.0, 1.0) * n as f64) - 1.0;
        if pos <= 0.0 {
            return self.energy_checkpoints_j[0] * (frac.clamp(0.0, 1.0) * n as f64);
        }
        let lo = pos.floor() as usize;
        let hi = (lo + 1).min(n - 1);
        let t = pos - lo as f64;
        self.energy_checkpoints_j[lo] * (1.0 - t) + self.energy_checkpoints_j[hi] * t
    }
}

/// Run one session to completion.
pub fn run_session(app: &AppModel, policy: &mut dyn Policy, cfg: &SessionCfg) -> RunResult {
    let freqs = FreqDomain::aurora().with_switch_cost(cfg.switch_cost);
    assert_eq!(policy.k(), freqs.k(), "policy arity must match frequency domain");
    let k = freqs.k();
    let node = Node::new(app.clone(), freqs.clone(), cfg.dt_s, cfg.seed);
    let mut service = Service::new(node);
    let mut normalizer = RewardNormalizer::new();
    let mut trace = cfg.record_trace.then(Trace::new);

    // B = 1 bridge onto the shared batch stepping core. The feasibility
    // buffer is all-ones (the bridge delegates feasibility to the wrapped
    // policy); selection/reward buffers live on the stack.
    let mut driver = Scalar::new(vec![policy]);
    let all_feasible = vec![1.0f32; k];
    let mut sel = [0i32; 1];

    // Ground truth for regret accounting (raw reward units).
    let true_rewards: Vec<f64> =
        (0..freqs.k()).map(|i| app.true_reward(&freqs, i, cfg.dt_s)).collect();
    let mu_star = true_rewards.iter().cloned().fold(f64::NEG_INFINITY, f64::max);

    let mut cumulative_regret = 0.0;
    let mut t: u64 = 0;
    let mut checkpoints = vec![0.0f64; cfg.checkpoints];
    let mut next_cp = 0usize;
    let mut cum_true_energy_j = 0.0;
    let mut final_completed = 0.0;

    while !service.done() && t < cfg.max_steps {
        t += 1;
        driver.select_into(t, &all_feasible, &mut sel);
        let arm = sel[0] as usize;
        service.write(Control::GpuFrequency(arm)).expect("valid arm");
        let sample = service.sample().expect("not done");
        let obs = sample.obs;

        // Reward from counter-visible quantities only (Eq. 4).
        let raw =
            cfg.reward_form.raw(obs.gpu_energy_j, obs.core_util, obs.uncore_util);
        // Winsorize: counter glitches (heavy-tail spikes) are capped at 3x
        // the typical magnitude before any policy sees them — a controller
        // robustness choice every method benefits from equally.
        let reward = normalizer.normalize(raw).max(-3.0);
        driver.update_batch(&sel, &[reward], &[obs.progress], &[1.0]);

        cumulative_regret += mu_star - true_rewards[arm];
        cum_true_energy_j += obs.true_gpu_energy_j;

        // Progress checkpoints.
        let completed = 1.0 - obs.remaining;
        final_completed = completed;
        while next_cp < cfg.checkpoints
            && completed >= (next_cp + 1) as f64 / cfg.checkpoints as f64 - 1e-12
        {
            checkpoints[next_cp] = cum_true_energy_j;
            next_cp += 1;
        }

        if let Some(tr) = trace.as_mut() {
            tr.push(TraceStep {
                t,
                arm,
                reward,
                energy_j: obs.true_gpu_energy_j,
                regret: mu_star - true_rewards[arm],
                switched: sample.switched,
            });
        }
    }
    // Fill any remaining checkpoints (e.g. run hit max_steps).
    for cp in checkpoints.iter_mut().skip(next_cp) {
        *cp = cum_true_energy_j;
    }

    let totals = service.totals();
    let metrics = RunMetrics {
        app: app.name.to_string(),
        policy: driver.name(),
        gpu_energy_kj: totals.gpu_energy_kj,
        exec_time_s: totals.exec_time_s,
        switches: totals.switches,
        switch_energy_j: totals.switch_energy_j,
        switch_time_s: totals.switch_time_s,
        cumulative_regret,
        steps: t,
        completed: final_completed.clamp(0.0, 1.0),
    };
    RunResult { metrics, trace, energy_checkpoints_j: checkpoints }
}

/// Run `reps` sessions with seeds `seed0..seed0+reps`, resetting the policy
/// between runs.
pub fn run_repeated(
    app: &AppModel,
    policy: &mut dyn Policy,
    cfg: &SessionCfg,
    reps: usize,
    seed0: u64,
) -> Vec<RunResult> {
    (0..reps)
        .map(|r| {
            policy.reset();
            let cfg = SessionCfg { seed: seed0 + r as u64, ..cfg.clone() };
            run_session(app, policy, &cfg)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::{EnergyUcb, EnergyUcbConfig, RoundRobin, StaticPolicy};
    use crate::workload::calibration;

    #[test]
    fn static_session_reproduces_table1() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = StaticPolicy::new(9, 8);
        let res = run_session(&app, &mut policy, &SessionCfg::default());
        assert!((res.metrics.gpu_energy_kj - 100.65).abs() < 0.8, "{}", res.metrics.gpu_energy_kj);
        assert_eq!(res.metrics.switches, 0);
        assert_eq!(res.metrics.cumulative_regret > 0.0, true);
    }

    #[test]
    fn energyucb_beats_default_frequency() {
        let app = calibration::app("tealeaf").unwrap();
        let mut policy = EnergyUcb::new(9, EnergyUcbConfig::default());
        let res = run_session(
            &app,
            &mut policy,
            &SessionCfg { seed: 3, ..SessionCfg::default() },
        );
        // Default 1.6 GHz = 109.79 kJ; EnergyUCB must save energy.
        assert!(
            res.metrics.gpu_energy_kj < 105.0,
            "energy {}",
            res.metrics.gpu_energy_kj
        );
        // And not be below the physically-optimal static config minus noise.
        assert!(res.metrics.gpu_energy_kj > 95.0);
    }

    #[test]
    fn rrfreq_has_linear_regret() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = RoundRobin::new(9);
        let cfg = SessionCfg { record_trace: true, ..SessionCfg::default() };
        let res = run_session(&app, &mut policy, &cfg);
        let trace = res.trace.unwrap();
        let cum = trace.cumulative_regret();
        // Regret at the halfway point should be ~half the final value.
        let half = cum[cum.len() / 2];
        let fin = *cum.last().unwrap();
        assert!((half / fin - 0.5).abs() < 0.05, "half={half} fin={fin}");
    }

    #[test]
    fn checkpoints_monotone_and_complete() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = StaticPolicy::new(9, 4);
        let res = run_session(&app, &mut policy, &SessionCfg::default());
        let cps = &res.energy_checkpoints_j;
        assert_eq!(cps.len(), 100);
        assert!(cps.windows(2).all(|w| w[1] >= w[0]));
        // Final checkpoint equals total energy.
        assert!(
            (cps[99] / 1000.0 - res.metrics.gpu_energy_kj).abs() < 0.5,
            "{} vs {}",
            cps[99] / 1000.0,
            res.metrics.gpu_energy_kj
        );
        // 20 % checkpoint is ~20 % of total (static run, constant power).
        let e20 = res.energy_at_progress_j(0.2);
        assert!((e20 / cps[99] - 0.2).abs() < 0.02, "{}", e20 / cps[99]);
    }

    #[test]
    fn capped_run_reports_partial_completion() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = StaticPolicy::new(9, 8);
        let cfg = SessionCfg { max_steps: 500, ..SessionCfg::default() };
        let res = run_session(&app, &mut policy, &cfg);
        assert_eq!(res.metrics.steps, 500);
        assert!(
            res.metrics.completed > 0.0 && res.metrics.completed < 1.0,
            "{}",
            res.metrics.completed
        );
        // Uncapped runs report full completion.
        let full = run_session(&app, &mut StaticPolicy::new(9, 8), &SessionCfg::default());
        assert!((full.metrics.completed - 1.0).abs() < 1e-9, "{}", full.metrics.completed);
    }

    #[test]
    fn repeated_runs_vary_by_seed_only() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = EnergyUcb::new(9, EnergyUcbConfig::default());
        let results = run_repeated(&app, &mut policy, &SessionCfg::default(), 3, 100);
        assert_eq!(results.len(), 3);
        // Different seeds -> different trajectories (energy differs).
        let e: Vec<f64> = results.iter().map(|r| r.metrics.gpu_energy_kj).collect();
        assert!(e[0] != e[1] || e[1] != e[2], "{e:?}");
        // All in a sane band.
        for v in &e {
            assert!(*v > 85.0 && *v < 105.0, "{v}");
        }
    }

    #[test]
    fn session_honors_custom_switch_cost() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = RoundRobin::new(9);
        let cfg = SessionCfg {
            switch_cost: SwitchCost { latency_s: 150e-6, energy_j: 0.9 },
            ..SessionCfg::default()
        };
        let res = run_session(&app, &mut policy, &cfg);
        assert!(res.metrics.switches > 0);
        // 0.9 J per node-level transition, end to end through the service.
        assert!(
            (res.metrics.switch_energy_j - res.metrics.switches as f64 * 0.9).abs() < 1e-6,
            "{} switches, {} J",
            res.metrics.switches,
            res.metrics.switch_energy_j
        );
    }

    #[test]
    fn trace_switches_match_metrics() {
        let app = calibration::app("clvleaf").unwrap();
        let mut policy = RoundRobin::new(9);
        let cfg = SessionCfg { record_trace: true, ..SessionCfg::default() };
        let res = run_session(&app, &mut policy, &cfg);
        assert_eq!(res.trace.unwrap().switch_count(), res.metrics.switches);
    }
}
