//! Recorded-telemetry log: the JSONL grammar the [`Recording`] tee
//! writes and the [`ReplayBackend`] that feeds it back to a controller.
//!
//! One [`TelemetryFrame`] per line (`util::wire` lossless float/integer
//! codecs, `util::io::Json` framing — the same substrate as the cluster
//! shard wire):
//!
//! ```text
//! header   exactly once, first      {"kind":"header","header":{"app":..,"policy":..,"session":..}}
//! step     once per interval        {"kind":"step","arm":..,"sample":{..}}
//! end      exactly once, last       {"kind":"end","totals":{..}}
//! ```
//!
//! Round-trips are exact (floats ride shortest round-trip formatting),
//! so replaying a recording under the policy that produced it reproduces
//! the original `RunMetrics` bit-for-bit; replaying under a *different*
//! policy is open-loop counterfactual evaluation — decisions no longer
//! influence the samples, which stay whatever the recorded run saw
//! (EXPERIMENTS.md §Controller).
//!
//! [`Recording`]: super::backend::Recording

use std::io::BufRead;
use std::path::Path;

use anyhow::Context as _;

use crate::config::PolicyConfig;
use crate::util::io::Json;
use crate::util::wire::{
    err, f64_to_json, field, str_field, u64_to_json, usize_field, WireCodec, WireError,
};

use super::backend::TelemetryBackend;
use super::controller::{BackendTotals, StepSample};
use super::session::SessionCfg;

/// Run provenance carried at the head of a telemetry log: enough to
/// rebuild the controller (app, session config including the frequency
/// domain) and — when the recorder knew it — the policy.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplayHeader {
    /// Calibrated app name (resolved through `workload::calibration`).
    pub app: String,
    /// Policy configuration that produced the recording, when known (the
    /// CLI records it so `energyucb replay` can rebuild the same policy
    /// without a `--policy` flag).
    pub policy: Option<PolicyConfig>,
    /// Session configuration of the recorded run (seed, dt, frequency
    /// domain, reward form, step budget).
    pub session: SessionCfg,
}

impl WireCodec for ReplayHeader {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("app", self.app.as_str());
        j.set(
            "policy",
            match &self.policy {
                Some(p) => p.to_wire(),
                None => Json::Null,
            },
        );
        j.set("session", self.session.to_wire());
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        let policy = match field(v, "policy")? {
            Json::Null => None,
            x => Some(PolicyConfig::from_wire(x)?),
        };
        Ok(ReplayHeader {
            app: str_field(v, "app")?,
            policy,
            session: SessionCfg::from_wire(field(v, "session")?)?,
        })
    }
}

impl WireCodec for StepSample {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("gpu_energy_j", f64_to_json(self.gpu_energy_j));
        j.set("core_util", f64_to_json(self.core_util));
        j.set("uncore_util", f64_to_json(self.uncore_util));
        j.set("progress", f64_to_json(self.progress));
        j.set("remaining", f64_to_json(self.remaining));
        j.set("true_gpu_energy_j", f64_to_json(self.true_gpu_energy_j));
        j.set("switched", self.switched);
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        use crate::util::wire::{bool_field, f64_field};
        Ok(StepSample {
            gpu_energy_j: f64_field(v, "gpu_energy_j")?,
            core_util: f64_field(v, "core_util")?,
            uncore_util: f64_field(v, "uncore_util")?,
            progress: f64_field(v, "progress")?,
            remaining: f64_field(v, "remaining")?,
            true_gpu_energy_j: f64_field(v, "true_gpu_energy_j")?,
            switched: bool_field(v, "switched")?,
        })
    }
}

impl WireCodec for BackendTotals {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("gpu_energy_kj", f64_to_json(self.gpu_energy_kj));
        j.set("exec_time_s", f64_to_json(self.exec_time_s));
        j.set("switches", u64_to_json(self.switches));
        j.set("switch_energy_j", f64_to_json(self.switch_energy_j));
        j.set("switch_time_s", f64_to_json(self.switch_time_s));
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        use crate::util::wire::{f64_field, u64_field};
        Ok(BackendTotals {
            gpu_energy_kj: f64_field(v, "gpu_energy_kj")?,
            exec_time_s: f64_field(v, "exec_time_s")?,
            switches: u64_field(v, "switches")?,
            switch_energy_j: f64_field(v, "switch_energy_j")?,
            switch_time_s: f64_field(v, "switch_time_s")?,
        })
    }
}

/// One line of a telemetry log (see module docs for the grammar).
#[derive(Clone, Debug, PartialEq)]
pub enum TelemetryFrame {
    /// Run provenance; must be the first frame.
    Header(ReplayHeader),
    /// One decision interval: the arm that was applied and what the
    /// backend sampled under it.
    Step { arm: usize, sample: StepSample },
    /// Terminal accounting; must be the last frame.
    End { totals: BackendTotals },
}

impl TelemetryFrame {
    /// Encode as one JSONL line (no trailing newline).
    pub fn encode_line(&self) -> String {
        self.to_wire().render_compact()
    }

    /// Decode one JSONL line.
    pub fn decode_line(line: &str) -> Result<TelemetryFrame, WireError> {
        let v = Json::parse(line).map_err(|e| WireError(e.to_string()))?;
        TelemetryFrame::from_wire(&v)
    }
}

impl WireCodec for TelemetryFrame {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        match self {
            TelemetryFrame::Header(h) => {
                // The payload nests under its own key like step/end, so
                // encode and decode are symmetric ReplayHeader-codec
                // one-liners that can never drift.
                j.set("kind", "header");
                j.set("header", h.to_wire());
            }
            TelemetryFrame::Step { arm, sample } => {
                j.set("kind", "step");
                j.set("arm", *arm);
                j.set("sample", sample.to_wire());
            }
            TelemetryFrame::End { totals } => {
                j.set("kind", "end");
                j.set("totals", totals.to_wire());
            }
        }
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        Ok(match str_field(v, "kind")?.as_str() {
            "header" => TelemetryFrame::Header(ReplayHeader::from_wire(field(v, "header")?)?),
            "step" => TelemetryFrame::Step {
                arm: usize_field(v, "arm")?,
                sample: StepSample::from_wire(field(v, "sample")?)?,
            },
            "end" => TelemetryFrame::End { totals: BackendTotals::from_wire(field(v, "totals")?)? },
            other => return err(format!("unknown telemetry frame kind: {other}")),
        })
    }
}

/// A telemetry backend that feeds a recorded run back to a controller.
///
/// Open-loop by construction: [`apply`](TelemetryBackend::apply) only
/// range-checks and records the requested arm; samples come verbatim
/// from the log in recorded order. Replaying with the recording's own
/// policy (same config, same seed) therefore reproduces the original
/// decisions and metrics exactly; replaying with a different policy is
/// counterfactual evaluation over a frozen telemetry stream.
#[derive(Clone, Debug)]
pub struct ReplayBackend {
    header: ReplayHeader,
    steps: Vec<(usize, StepSample)>,
    totals: BackendTotals,
    pos: usize,
}

impl ReplayBackend {
    /// Parse a complete telemetry log. Rejects logs with a missing or
    /// duplicated header, frames after `end`, or no terminal `end` frame
    /// (a truncated recording must not silently replay short).
    pub fn from_reader(reader: impl BufRead) -> anyhow::Result<ReplayBackend> {
        let mut header: Option<ReplayHeader> = None;
        let mut steps: Vec<(usize, StepSample)> = Vec::new();
        let mut totals: Option<BackendTotals> = None;
        for (i, line) in reader.lines().enumerate() {
            let line = line.context("reading telemetry log")?;
            if line.trim().is_empty() {
                continue;
            }
            let frame = TelemetryFrame::decode_line(&line)
                .with_context(|| format!("telemetry log line {}", i + 1))?;
            if totals.is_some() {
                anyhow::bail!("telemetry log line {}: frame after the end frame", i + 1);
            }
            match frame {
                TelemetryFrame::Header(h) => {
                    if header.is_some() {
                        anyhow::bail!("telemetry log line {}: duplicate header", i + 1);
                    }
                    if !steps.is_empty() {
                        anyhow::bail!("telemetry log line {}: header after steps", i + 1);
                    }
                    header = Some(h);
                }
                TelemetryFrame::Step { arm, sample } => {
                    if header.is_none() {
                        anyhow::bail!("telemetry log line {}: step before header", i + 1);
                    }
                    steps.push((arm, sample));
                }
                TelemetryFrame::End { totals: t } => {
                    if header.is_none() {
                        anyhow::bail!("telemetry log line {}: end before header", i + 1);
                    }
                    totals = Some(t);
                }
            }
        }
        let header = header.context("telemetry log has no header frame")?;
        let totals = totals.context("truncated telemetry log: no end frame")?;
        Ok(ReplayBackend { header, steps, totals, pos: 0 })
    }

    /// Parse from an in-memory log.
    pub fn from_text(text: &str) -> anyhow::Result<ReplayBackend> {
        ReplayBackend::from_reader(text.as_bytes())
    }

    /// Open and parse a telemetry log file.
    pub fn open(path: &Path) -> anyhow::Result<ReplayBackend> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening telemetry log {}", path.display()))?;
        ReplayBackend::from_reader(std::io::BufReader::new(file))
    }

    /// The recording's provenance header.
    pub fn header(&self) -> &ReplayHeader {
        &self.header
    }

    /// Number of recorded decision intervals.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// The arm the *recorded* run applied at interval `i` (0-based) —
    /// for auditing counterfactual replays against the original.
    pub fn recorded_arm(&self, i: usize) -> Option<usize> {
        self.steps.get(i).map(|(arm, _)| *arm)
    }
}

impl TelemetryBackend for ReplayBackend {
    fn k(&self) -> usize {
        self.header.session.freqs.k()
    }

    fn apply(&mut self, arm: usize) -> anyhow::Result<()> {
        if arm >= self.k() {
            anyhow::bail!("replay: arm {arm} out of range (K = {})", self.k());
        }
        Ok(())
    }

    fn sample(&mut self) -> anyhow::Result<StepSample> {
        let Some((_, sample)) = self.steps.get(self.pos) else {
            anyhow::bail!("replay: sample past the end of the recording");
        };
        self.pos += 1;
        Ok(*sample)
    }

    fn done(&self) -> bool {
        self.pos >= self.steps.len()
    }

    fn totals(&self) -> BackendTotals {
        self.totals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(x: f64) -> StepSample {
        StepSample {
            gpu_energy_j: x,
            core_util: 0.9,
            uncore_util: 1.0 / 3.0,
            progress: 1e-4,
            remaining: 1.0 - x * 1e-4,
            true_gpu_energy_j: x * 0.99,
            switched: x as u64 % 2 == 0,
        }
    }

    fn log_text(steps: usize) -> String {
        let header = ReplayHeader {
            app: "tealeaf".into(),
            policy: Some(PolicyConfig::Static { arm: 8 }),
            session: SessionCfg { seed: 42, ..SessionCfg::default() },
        };
        let mut text = format!("{}\n", TelemetryFrame::Header(header).encode_line());
        for i in 0..steps {
            let f = TelemetryFrame::Step { arm: 8, sample: sample(i as f64 + 1.0) };
            text.push_str(&f.encode_line());
            text.push('\n');
        }
        let end = TelemetryFrame::End {
            totals: BackendTotals {
                gpu_energy_kj: 1.25,
                exec_time_s: steps as f64 * 0.01,
                switches: 1,
                switch_energy_j: 0.3,
                switch_time_s: 150e-6,
            },
        };
        text.push_str(&end.encode_line());
        text.push('\n');
        text
    }

    #[test]
    fn frames_round_trip_exactly() {
        let frames = [
            TelemetryFrame::Header(ReplayHeader {
                app: "clvleaf".into(),
                policy: None,
                session: SessionCfg { seed: u64::MAX - 1, ..SessionCfg::default() },
            }),
            TelemetryFrame::Step { arm: 3, sample: sample(25.0) },
            TelemetryFrame::End { totals: BackendTotals::default() },
        ];
        for f in frames {
            let line = f.encode_line();
            assert!(!line.contains('\n'), "{line}");
            assert_eq!(TelemetryFrame::decode_line(&line).unwrap(), f, "{line}");
        }
    }

    #[test]
    fn replay_backend_feeds_samples_in_order() {
        let mut b = ReplayBackend::from_text(&log_text(3)).unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(b.k(), 9);
        assert_eq!(b.recorded_arm(0), Some(8));
        assert!(!b.done());
        b.apply(0).unwrap();
        assert!(b.apply(9).is_err());
        for i in 0..3 {
            let s = b.sample().unwrap();
            assert_eq!(s.gpu_energy_j, i as f64 + 1.0);
        }
        assert!(b.done());
        assert!(b.sample().is_err());
        assert_eq!(b.totals().gpu_energy_kj, 1.25);
        assert_eq!(b.header().app, "tealeaf");
    }

    #[test]
    fn malformed_logs_are_rejected() {
        // No header.
        let no_header = log_text(2).lines().skip(1).collect::<Vec<_>>().join("\n");
        assert!(ReplayBackend::from_text(&no_header).is_err());
        // No end frame (truncated recording).
        let text = log_text(2);
        let truncated: Vec<&str> = text.lines().collect();
        let truncated = truncated[..truncated.len() - 1].join("\n");
        assert!(ReplayBackend::from_text(&truncated).is_err());
        // Frames after end.
        let mut after_end = log_text(1);
        after_end.push_str(&log_text(1));
        assert!(ReplayBackend::from_text(&after_end).is_err());
        // Junk line.
        assert!(ReplayBackend::from_text("not json\n").is_err());
        // Empty input.
        assert!(ReplayBackend::from_text("").is_err());
        // Unknown kind.
        assert!(TelemetryFrame::decode_line("{\"kind\":\"bogus\"}").is_err());
    }
}
