//! Recorded-telemetry log: the JSONL grammar the [`Recording`] tee
//! writes and the [`ReplayBackend`] that feeds it back to a controller.
//!
//! One [`TelemetryFrame`] per line (`util::wire` lossless float/integer
//! codecs, `util::io::Json` framing — the same substrate as the cluster
//! shard wire). Scalar (B = 1) runs keep the original shapes; batch runs
//! carry row arrays:
//!
//! ```text
//! header   exactly once, first   {"kind":"header","header":{"app":..,"policy":..,"session":..[,"envs":[..],"feasible":[..]]}}
//! step     once per interval     {"kind":"step","arm":..,"sample":{..}}            (B = 1)
//!                                {"kind":"step","arms":[..],"samples":[{..},..]}   (B > 1)
//! end      exactly once, last    {"kind":"end","totals":{..},"steps":..}           (B = 1)
//!                                {"kind":"end","totals":[{..},..],"steps":..}      (B > 1)
//! ```
//!
//! The `end` frame carries the achieved step count and, when the
//! recording was abandoned mid-run, a `"truncated":true` marker (written
//! by [`Recording`]'s drop path) — [`ReplayBackend`] rejects truncated
//! logs with an actionable error instead of silently replaying short.
//!
//! Round-trips are exact (floats ride shortest round-trip formatting),
//! so replaying a recording under the policy that produced it reproduces
//! the original `RunMetrics` bit-for-bit; replaying under a *different*
//! policy is open-loop counterfactual evaluation — decisions no longer
//! influence the samples, which stay whatever the recorded run saw
//! (EXPERIMENTS.md §Controller, §Sweeps).
//!
//! [`Recording`]: super::backend::Recording

use std::io::BufRead;
use std::path::Path;

use anyhow::Context as _;

use crate::config::PolicyConfig;
use crate::util::io::Json;
use crate::util::wire::{
    err, f64_from_json, f64_to_json, f64s_from_json, f64s_to_json, field, str_field, u64_from_json,
    u64_to_json, usize_field, WireCodec, WireError,
};

use crate::bandit::CONTEXT_DIM;

use super::backend::TelemetryBackend;
use super::controller::{BackendTotals, StepSample};
use super::session::SessionCfg;

/// Grammar-version marker for contextual recordings: declares the
/// per-step context width (today always [`CONTEXT_DIM`]) and the
/// TTFT-style QoS budget the recorded run evaluated against, so
/// counterfactual sweeps over a frozen contextual trace score QoS the
/// same way the live run did. Context-free recordings omit the whole
/// block — their header bytes are untouched.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ContextSpec {
    /// Context feature-vector width (per-step, per-row).
    pub dim: usize,
    /// QoS budget on the queue-depth feature, when the run had one.
    pub qos_budget: Option<f64>,
}

impl WireCodec for ContextSpec {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("dim", self.dim);
        if let Some(q) = self.qos_budget {
            j.set("qos_budget", f64_to_json(q));
        }
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        let qos_budget = match v.get("qos_budget") {
            None => None,
            Some(x) => Some(f64_from_json(x)?),
        };
        Ok(ContextSpec { dim: usize_field(v, "dim")?, qos_budget })
    }
}

/// Run provenance carried at the head of a telemetry log: enough to
/// rebuild the controller (app or fleet roster, session config including
/// the frequency domain) and — when the recorder knew it — the policy.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplayHeader {
    /// Calibrated app name (resolved through `workload::calibration`);
    /// `"fleet"` for batch recordings (see [`envs`](Self::envs)).
    pub app: String,
    /// Policy configuration that produced the recording, when known (the
    /// CLI records it so `energyucb replay` / `energyucb sweep` can
    /// rebuild the same policy without a `--policy` flag).
    pub policy: Option<PolicyConfig>,
    /// Session configuration of the recorded run (seed, dt, frequency
    /// domain, reward form, step budget).
    pub session: SessionCfg,
    /// Fleet-tier roster: the calibrated app name of each environment
    /// row, in row order. Empty for scalar (B = 1) session recordings.
    pub envs: Vec<String>,
    /// Fleet-tier QoS feasibility mask, row-major (B, K), when the
    /// recorded run was constrained. `None` = all arms feasible.
    pub feasible: Option<Vec<f64>>,
    /// Contextual-grammar marker: present iff the recording carries
    /// per-step context blocks (the serving tier). `None` keeps the
    /// legacy context-free header bytes.
    pub context: Option<ContextSpec>,
}

impl ReplayHeader {
    /// Header for a scalar (B = 1) session recording.
    pub fn session(app: String, policy: Option<PolicyConfig>, session: SessionCfg) -> ReplayHeader {
        ReplayHeader { app, policy, session, envs: Vec::new(), feasible: None, context: None }
    }

    /// Header for a batch fleet recording: one env name per row.
    pub fn fleet(
        envs: Vec<String>,
        policy: Option<PolicyConfig>,
        session: SessionCfg,
        feasible: Option<Vec<f64>>,
    ) -> ReplayHeader {
        ReplayHeader { app: "fleet".to_string(), policy, session, envs, feasible, context: None }
    }

    /// Mark the recording as contextual (see [`ContextSpec`]).
    pub fn with_context(mut self, qos_budget: Option<f64>) -> ReplayHeader {
        self.context = Some(ContextSpec { dim: CONTEXT_DIM, qos_budget });
        self
    }

    /// Batch size of the recording (1 for scalar session logs).
    pub fn b(&self) -> usize {
        self.envs.len().max(1)
    }
}

impl WireCodec for ReplayHeader {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("app", self.app.as_str());
        j.set(
            "policy",
            match &self.policy {
                Some(p) => p.to_wire(),
                None => Json::Null,
            },
        );
        j.set("session", self.session.to_wire());
        // Batch-only fields are omitted for scalar recordings, keeping
        // the legacy B = 1 log shape byte-stable.
        if !self.envs.is_empty() {
            j.set(
                "envs",
                Json::Arr(self.envs.iter().map(|e| Json::Str(e.clone())).collect()),
            );
        }
        if let Some(f) = &self.feasible {
            j.set("feasible", f64s_to_json(f));
        }
        if let Some(c) = &self.context {
            j.set("context", c.to_wire());
        }
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        let policy = match field(v, "policy")? {
            Json::Null => None,
            x => Some(PolicyConfig::from_wire(x)?),
        };
        let envs = match v.get("envs") {
            None => Vec::new(),
            Some(x) => {
                let Some(arr) = x.as_arr() else {
                    return err("field `envs` must be an array of strings");
                };
                arr.iter()
                    .map(|e| {
                        e.as_str().map(str::to_string).ok_or_else(|| {
                            WireError("field `envs` must be an array of strings".into())
                        })
                    })
                    .collect::<Result<Vec<_>, _>>()?
            }
        };
        let feasible = match v.get("feasible") {
            None => None,
            Some(x) => Some(f64s_from_json(x)?),
        };
        let context = match v.get("context") {
            None => None,
            Some(x) => Some(ContextSpec::from_wire(x)?),
        };
        Ok(ReplayHeader {
            app: str_field(v, "app")?,
            policy,
            session: SessionCfg::from_wire(field(v, "session")?)?,
            envs,
            feasible,
            context,
        })
    }
}

impl WireCodec for StepSample {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("gpu_energy_j", f64_to_json(self.gpu_energy_j));
        j.set("core_util", f64_to_json(self.core_util));
        j.set("uncore_util", f64_to_json(self.uncore_util));
        j.set("progress", f64_to_json(self.progress));
        j.set("remaining", f64_to_json(self.remaining));
        j.set("true_gpu_energy_j", f64_to_json(self.true_gpu_energy_j));
        j.set("switched", self.switched);
        // Batch-only fields ride only when non-default, so scalar session
        // samples keep the legacy shape.
        if let Some(r) = self.reward {
            j.set("reward", f64_to_json(r));
        }
        if !self.active {
            j.set("active", false);
        }
        // Contextual (serving-tier) samples append their feature
        // vector; context-free samples keep the legacy byte shape.
        if let Some(c) = &self.context {
            j.set("context", f64s_to_json(&c[..]));
        }
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        use crate::util::wire::{bool_field, f64_field};
        let reward = match v.get("reward") {
            None => None,
            Some(x) => Some(f64_from_json(x)?),
        };
        let active = match v.get("active") {
            None => true,
            Some(x) => x
                .as_bool()
                .ok_or_else(|| WireError("field `active` must be a bool".into()))?,
        };
        let context = match v.get("context") {
            None => None,
            Some(x) => {
                let vals = f64s_from_json(x)?;
                let arr: [f64; CONTEXT_DIM] = vals.as_slice().try_into().map_err(|_| {
                    WireError(format!(
                        "field `context` must carry exactly {CONTEXT_DIM} features, got {}",
                        vals.len()
                    ))
                })?;
                Some(arr)
            }
        };
        Ok(StepSample {
            gpu_energy_j: f64_field(v, "gpu_energy_j")?,
            core_util: f64_field(v, "core_util")?,
            uncore_util: f64_field(v, "uncore_util")?,
            progress: f64_field(v, "progress")?,
            remaining: f64_field(v, "remaining")?,
            true_gpu_energy_j: f64_field(v, "true_gpu_energy_j")?,
            switched: bool_field(v, "switched")?,
            reward,
            active,
            context,
        })
    }
}

impl WireCodec for BackendTotals {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        j.set("gpu_energy_kj", f64_to_json(self.gpu_energy_kj));
        j.set("exec_time_s", f64_to_json(self.exec_time_s));
        j.set("switches", u64_to_json(self.switches));
        j.set("switch_energy_j", f64_to_json(self.switch_energy_j));
        j.set("switch_time_s", f64_to_json(self.switch_time_s));
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        use crate::util::wire::{f64_field, u64_field};
        Ok(BackendTotals {
            gpu_energy_kj: f64_field(v, "gpu_energy_kj")?,
            exec_time_s: f64_field(v, "exec_time_s")?,
            switches: u64_field(v, "switches")?,
            switch_energy_j: f64_field(v, "switch_energy_j")?,
            switch_time_s: f64_field(v, "switch_time_s")?,
        })
    }
}

/// One line of a telemetry log (see module docs for the grammar).
#[derive(Clone, Debug, PartialEq)]
pub enum TelemetryFrame {
    /// Run provenance; must be the first frame.
    Header(ReplayHeader),
    /// One decision interval: the arm applied per environment and what
    /// the backend sampled under it (parallel arrays, length B).
    Step { arms: Vec<i32>, samples: Vec<StepSample> },
    /// Terminal accounting; must be the last frame. `steps` is the
    /// achieved interval count when the writer knew it; `truncated`
    /// marks a recording abandoned before its clean finish.
    End { totals: Vec<BackendTotals>, steps: Option<u64>, truncated: bool },
}

impl TelemetryFrame {
    /// Encode as one JSONL line (no trailing newline).
    pub fn encode_line(&self) -> String {
        self.to_wire().render_compact()
    }

    /// Decode one JSONL line.
    pub fn decode_line(line: &str) -> Result<TelemetryFrame, WireError> {
        let v = Json::parse(line).map_err(|e| WireError(e.to_string()))?;
        TelemetryFrame::from_wire(&v)
    }
}

impl WireCodec for TelemetryFrame {
    fn to_wire(&self) -> Json {
        let mut j = Json::obj();
        match self {
            TelemetryFrame::Header(h) => {
                // The payload nests under its own key like step/end, so
                // encode and decode are symmetric ReplayHeader-codec
                // one-liners that can never drift.
                j.set("kind", "header");
                j.set("header", h.to_wire());
            }
            TelemetryFrame::Step { arms, samples } => {
                j.set("kind", "step");
                if arms.len() == 1 {
                    // Scalar recordings keep the legacy one-object shape.
                    j.set("arm", arms[0] as usize);
                    j.set("sample", samples[0].to_wire());
                } else {
                    j.set(
                        "arms",
                        Json::Arr(arms.iter().map(|&a| u64_to_json(a as u64)).collect()),
                    );
                    j.set("samples", Json::Arr(samples.iter().map(WireCodec::to_wire).collect()));
                }
            }
            TelemetryFrame::End { totals, steps, truncated } => {
                j.set("kind", "end");
                if totals.len() == 1 {
                    j.set("totals", totals[0].to_wire());
                } else {
                    j.set("totals", Json::Arr(totals.iter().map(WireCodec::to_wire).collect()));
                }
                if let Some(n) = steps {
                    j.set("steps", u64_to_json(*n));
                }
                if *truncated {
                    j.set("truncated", true);
                }
            }
        }
        j
    }

    fn from_wire(v: &Json) -> Result<Self, WireError> {
        Ok(match str_field(v, "kind")?.as_str() {
            "header" => TelemetryFrame::Header(ReplayHeader::from_wire(field(v, "header")?)?),
            "step" => {
                if v.get("arm").is_some() {
                    TelemetryFrame::Step {
                        arms: vec![usize_field(v, "arm")? as i32],
                        samples: vec![StepSample::from_wire(field(v, "sample")?)?],
                    }
                } else {
                    let arms_j = field(v, "arms")?
                        .as_arr()
                        .ok_or_else(|| WireError("field `arms` must be an array".into()))?;
                    let arms = arms_j
                        .iter()
                        .map(|a| u64_from_json(a).map(|x| x as i32))
                        .collect::<Result<Vec<_>, _>>()?;
                    let samples_j = field(v, "samples")?
                        .as_arr()
                        .ok_or_else(|| WireError("field `samples` must be an array".into()))?;
                    let samples = samples_j
                        .iter()
                        .map(StepSample::from_wire)
                        .collect::<Result<Vec<_>, _>>()?;
                    if arms.len() != samples.len() {
                        return err(format!(
                            "step frame row mismatch: {} arms vs {} samples",
                            arms.len(),
                            samples.len()
                        ));
                    }
                    if arms.is_empty() {
                        return err("step frame has no rows");
                    }
                    TelemetryFrame::Step { arms, samples }
                }
            }
            "end" => {
                let totals_j = field(v, "totals")?;
                let totals = match totals_j.as_arr() {
                    Some(arr) => arr
                        .iter()
                        .map(BackendTotals::from_wire)
                        .collect::<Result<Vec<_>, _>>()?,
                    None => vec![BackendTotals::from_wire(totals_j)?],
                };
                let steps = match v.get("steps") {
                    None => None,
                    Some(x) => Some(u64_from_json(x)?),
                };
                let truncated = match v.get("truncated") {
                    None => false,
                    Some(x) => x
                        .as_bool()
                        .ok_or_else(|| WireError("field `truncated` must be a bool".into()))?,
                };
                TelemetryFrame::End { totals, steps, truncated }
            }
            other => return err(format!("unknown telemetry frame kind: {other}")),
        })
    }
}

/// A telemetry backend that feeds a recorded run back to a controller.
///
/// Open-loop by construction: [`apply`](TelemetryBackend::apply) only
/// range-checks the requested arms; samples come verbatim from the log
/// in recorded order. Replaying with the recording's own policy (same
/// config, same seed) therefore reproduces the original decisions and
/// metrics exactly; replaying with a different policy is counterfactual
/// evaluation over a frozen telemetry stream — the record-once/
/// evaluate-many discipline the sweep tier fans out over.
#[derive(Clone, Debug)]
pub struct ReplayBackend {
    header: ReplayHeader,
    b: usize,
    steps: Vec<(Vec<i32>, Vec<StepSample>)>,
    totals: Vec<BackendTotals>,
    pos: usize,
}

impl ReplayBackend {
    /// Parse a complete telemetry log. Rejects logs with a missing or
    /// duplicated header, frames after `end`, no terminal `end` frame or
    /// an `end` carrying the truncation marker (a truncated recording
    /// must not silently replay short), batch-width drift between
    /// frames, arms outside the header's frequency domain, and step
    /// counts that contradict the terminal frame.
    pub fn from_reader(reader: impl BufRead) -> anyhow::Result<ReplayBackend> {
        let mut header: Option<ReplayHeader> = None;
        let mut b = 1usize;
        let mut k = 0usize;
        let mut has_ctx = false;
        let mut steps: Vec<(Vec<i32>, Vec<StepSample>)> = Vec::new();
        let mut end: Option<(Vec<BackendTotals>, Option<u64>, bool)> = None;
        for (i, line) in reader.lines().enumerate() {
            let line = line.context("reading telemetry log")?;
            if line.trim().is_empty() {
                continue;
            }
            let frame = TelemetryFrame::decode_line(&line)
                .with_context(|| format!("telemetry log line {}", i + 1))?;
            if end.is_some() {
                anyhow::bail!("telemetry log line {}: frame after the end frame", i + 1);
            }
            match frame {
                TelemetryFrame::Header(h) => {
                    if header.is_some() {
                        anyhow::bail!("telemetry log line {}: duplicate header", i + 1);
                    }
                    if !steps.is_empty() {
                        anyhow::bail!("telemetry log line {}: header after steps", i + 1);
                    }
                    b = h.b();
                    k = h.session.freqs.k();
                    if let Some(spec) = &h.context {
                        if spec.dim != crate::bandit::CONTEXT_DIM {
                            anyhow::bail!(
                                "telemetry log line {}: context spec declares dim = {}, this \
                                 build replays dim = {} contexts only",
                                i + 1,
                                spec.dim,
                                crate::bandit::CONTEXT_DIM
                            );
                        }
                        has_ctx = true;
                    }
                    header = Some(h);
                }
                TelemetryFrame::Step { arms, samples } => {
                    if header.is_none() {
                        anyhow::bail!("telemetry log line {}: step before header", i + 1);
                    }
                    if arms.len() != b {
                        anyhow::bail!(
                            "telemetry log line {}: step frame has {} rows, header declares B = {b}",
                            i + 1,
                            arms.len()
                        );
                    }
                    for &a in &arms {
                        if a < 0 || a as usize >= k {
                            anyhow::bail!(
                                "telemetry log line {}: recorded arm {a} outside the header's \
                                 frequency domain (K = {k})",
                                i + 1
                            );
                        }
                    }
                    if !has_ctx && samples.iter().any(|s| s.context.is_some()) {
                        anyhow::bail!(
                            "telemetry log line {}: step carries a context block but the header \
                             declares no context spec — the recording is malformed",
                            i + 1
                        );
                    }
                    steps.push((arms, samples));
                }
                TelemetryFrame::End { totals, steps: n, truncated } => {
                    if header.is_none() {
                        anyhow::bail!("telemetry log line {}: end before header", i + 1);
                    }
                    if totals.len() != b {
                        anyhow::bail!(
                            "telemetry log line {}: end frame has {} totals, header declares B = {b}",
                            i + 1,
                            totals.len()
                        );
                    }
                    end = Some((totals, n, truncated));
                }
            }
        }
        let header = header.context("telemetry log has no header frame")?;
        let (totals, declared_steps, truncated) =
            end.context("truncated telemetry log: no end frame")?;
        if truncated {
            anyhow::bail!(
                "truncated telemetry log: the recording was abandoned after {} of an unknown \
                 number of intervals (its end frame carries the truncation marker) — re-record \
                 the run to completion before replaying",
                declared_steps.unwrap_or(steps.len() as u64)
            );
        }
        if let Some(n) = declared_steps {
            if n != steps.len() as u64 {
                anyhow::bail!(
                    "telemetry log is inconsistent: end frame declares {n} intervals but {} step \
                     frames are present",
                    steps.len()
                );
            }
        }
        Ok(ReplayBackend { header, b, steps, totals, pos: 0 })
    }

    /// Parse from an in-memory log.
    pub fn from_text(text: &str) -> anyhow::Result<ReplayBackend> {
        ReplayBackend::from_reader(text.as_bytes())
    }

    /// Open and parse a telemetry log file.
    pub fn open(path: &Path) -> anyhow::Result<ReplayBackend> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening telemetry log {}", path.display()))?;
        ReplayBackend::from_reader(std::io::BufReader::new(file))
    }

    /// The recording's provenance header.
    pub fn header(&self) -> &ReplayHeader {
        &self.header
    }

    /// Number of recorded decision intervals.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// The arm the *recorded* run applied at interval `i` (0-based) for
    /// environment row 0 — for auditing counterfactual replays against
    /// the original.
    pub fn recorded_arm(&self, i: usize) -> Option<usize> {
        self.steps.get(i).map(|(arms, _)| arms[0] as usize)
    }

    /// The full row of arms the recorded run applied at interval `i`.
    pub fn recorded_arms(&self, i: usize) -> Option<&[i32]> {
        self.steps.get(i).map(|(arms, _)| arms.as_slice())
    }

    /// Rewind to the first interval (a cloned backend can be reused for
    /// several counterfactual candidates; clones start wherever the
    /// source stood, so sweeps rewind explicitly).
    pub fn rewind(&mut self) {
        self.pos = 0;
    }
}

impl TelemetryBackend for ReplayBackend {
    fn b(&self) -> usize {
        self.b
    }

    fn k(&self) -> usize {
        self.header.session.freqs.k()
    }

    fn apply(&mut self, sel: &[i32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            sel.len() == self.b,
            "replay: {} selections for a B = {} recording",
            sel.len(),
            self.b
        );
        for &arm in sel {
            if arm < 0 || arm as usize >= self.k() {
                anyhow::bail!("replay: arm {arm} out of range (K = {})", self.k());
            }
        }
        Ok(())
    }

    fn sample_into(&mut self, out: &mut [StepSample]) -> anyhow::Result<()> {
        anyhow::ensure!(
            out.len() == self.b,
            "replay: {} sample slots for a B = {} recording",
            out.len(),
            self.b
        );
        let Some((_, samples)) = self.steps.get(self.pos) else {
            anyhow::bail!("replay: sample past the end of the recording");
        };
        out.copy_from_slice(samples);
        self.pos += 1;
        Ok(())
    }

    fn done(&self) -> bool {
        self.pos >= self.steps.len()
    }

    fn totals(&self) -> Vec<BackendTotals> {
        self.totals.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(x: f64) -> StepSample {
        StepSample {
            gpu_energy_j: x,
            core_util: 0.9,
            uncore_util: 1.0 / 3.0,
            progress: 1e-4,
            remaining: 1.0 - x * 1e-4,
            true_gpu_energy_j: x * 0.99,
            switched: x as u64 % 2 == 0,
            ..StepSample::default()
        }
    }

    fn log_text(steps: usize) -> String {
        let header = ReplayHeader::session(
            "tealeaf".into(),
            Some(PolicyConfig::Static { arm: 8 }),
            SessionCfg { seed: 42, ..SessionCfg::default() },
        );
        let mut text = format!("{}\n", TelemetryFrame::Header(header).encode_line());
        for i in 0..steps {
            let f = TelemetryFrame::Step { arms: vec![8], samples: vec![sample(i as f64 + 1.0)] };
            text.push_str(&f.encode_line());
            text.push('\n');
        }
        let end = TelemetryFrame::End {
            totals: vec![BackendTotals {
                gpu_energy_kj: 1.25,
                exec_time_s: steps as f64 * 0.01,
                switches: 1,
                switch_energy_j: 0.3,
                switch_time_s: 150e-6,
            }],
            steps: Some(steps as u64),
            truncated: false,
        };
        text.push_str(&end.encode_line());
        text.push('\n');
        text
    }

    #[test]
    fn frames_round_trip_exactly() {
        let frames = [
            TelemetryFrame::Header(ReplayHeader::session(
                "clvleaf".into(),
                None,
                SessionCfg { seed: u64::MAX - 1, ..SessionCfg::default() },
            )),
            TelemetryFrame::Header(ReplayHeader::fleet(
                vec!["tealeaf".into(), "lbm".into()],
                Some(PolicyConfig::Static { arm: 3 }),
                SessionCfg::default(),
                Some(vec![1.0, 0.0, 1.0, 1.0]),
            )),
            TelemetryFrame::Step { arms: vec![3], samples: vec![sample(25.0)] },
            TelemetryFrame::Step {
                arms: vec![3, 7],
                samples: vec![
                    StepSample { reward: Some(-0.75), ..sample(2.0) },
                    StepSample { active: false, ..sample(3.0) },
                ],
            },
            TelemetryFrame::End {
                totals: vec![BackendTotals::default()],
                steps: None,
                truncated: false,
            },
            TelemetryFrame::End {
                totals: vec![BackendTotals::default(), BackendTotals::default()],
                steps: Some(77),
                truncated: true,
            },
        ];
        for f in frames {
            let line = f.encode_line();
            assert!(!line.contains('\n'), "{line}");
            assert_eq!(TelemetryFrame::decode_line(&line).unwrap(), f, "{line}");
        }
    }

    #[test]
    fn scalar_frames_keep_the_legacy_shape() {
        // B = 1 recordings must stay byte-compatible with pre-batch logs:
        // singular keys, no batch-only fields.
        let step =
            TelemetryFrame::Step { arms: vec![5], samples: vec![sample(1.0)] }.encode_line();
        assert!(step.contains("\"arm\":"), "{step}");
        assert!(!step.contains("\"arms\""), "{step}");
        assert!(!step.contains("\"reward\""), "{step}");
        assert!(!step.contains("\"active\""), "{step}");
        let end = TelemetryFrame::End {
            totals: vec![BackendTotals::default()],
            steps: None,
            truncated: false,
        }
        .encode_line();
        assert!(!end.contains("\"truncated\""), "{end}");
        assert!(!end.contains('['), "{end}");
        // And legacy lines (no steps count) still decode.
        let legacy = "{\"kind\":\"end\",\"totals\":{\"gpu_energy_kj\":1.0,\"exec_time_s\":2.0,\
                      \"switches\":3,\"switch_energy_j\":0.9,\"switch_time_s\":0.1}}";
        match TelemetryFrame::decode_line(legacy).unwrap() {
            TelemetryFrame::End { totals, steps, truncated } => {
                assert_eq!(totals.len(), 1);
                assert_eq!(totals[0].switches, 3);
                assert_eq!(steps, None);
                assert!(!truncated);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn replay_backend_feeds_samples_in_order() {
        let mut b = ReplayBackend::from_text(&log_text(3)).unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(b.b(), 1);
        assert_eq!(b.k(), 9);
        assert_eq!(b.recorded_arm(0), Some(8));
        assert_eq!(b.recorded_arms(0), Some(&[8i32][..]));
        assert!(!b.done());
        b.apply(&[0]).unwrap();
        assert!(b.apply(&[9]).is_err());
        assert!(b.apply(&[0, 1]).is_err());
        let mut out = [StepSample::default()];
        for i in 0..3 {
            b.sample_into(&mut out).unwrap();
            assert_eq!(out[0].gpu_energy_j, i as f64 + 1.0);
        }
        assert!(b.done());
        assert!(b.sample_into(&mut out).is_err());
        b.rewind();
        assert!(!b.done());
        assert_eq!(b.totals()[0].gpu_energy_kj, 1.25);
        assert_eq!(b.header().app, "tealeaf");
    }

    #[test]
    fn malformed_logs_are_rejected() {
        // No header.
        let no_header = log_text(2).lines().skip(1).collect::<Vec<_>>().join("\n");
        assert!(ReplayBackend::from_text(&no_header).is_err());
        // No end frame (mid-stream cut).
        let text = log_text(2);
        let truncated: Vec<&str> = text.lines().collect();
        let truncated = truncated[..truncated.len() - 1].join("\n");
        let err = ReplayBackend::from_text(&truncated).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        // Frames after end.
        let mut after_end = log_text(1);
        after_end.push_str(&log_text(1));
        assert!(ReplayBackend::from_text(&after_end).is_err());
        // Junk line.
        assert!(ReplayBackend::from_text("not json\n").is_err());
        // Empty input.
        assert!(ReplayBackend::from_text("").is_err());
        // Unknown kind.
        assert!(TelemetryFrame::decode_line("{\"kind\":\"bogus\"}").is_err());
    }

    #[test]
    fn inconsistent_and_truncated_logs_are_rejected() {
        // Truncation marker in the end frame.
        let mut marked: Vec<String> = log_text(2).lines().map(str::to_string).collect();
        let n = marked.len();
        marked[n - 1] = TelemetryFrame::End {
            totals: vec![BackendTotals::default()],
            steps: Some(2),
            truncated: true,
        }
        .encode_line();
        let err = ReplayBackend::from_text(&marked.join("\n")).unwrap_err().to_string();
        assert!(err.contains("truncation marker"), "{err}");
        // Step count contradicting the end frame (a cut with the end
        // frame still intact).
        let mut cut: Vec<String> = log_text(3).lines().map(str::to_string).collect();
        cut.remove(2);
        let err = ReplayBackend::from_text(&cut.join("\n")).unwrap_err().to_string();
        assert!(err.contains("declares 3 intervals"), "{err}");
        // Recorded arm outside the header's domain.
        let mut bad_arm: Vec<String> = log_text(1).lines().map(str::to_string).collect();
        bad_arm[1] =
            TelemetryFrame::Step { arms: vec![12], samples: vec![sample(1.0)] }.encode_line();
        let err = ReplayBackend::from_text(&bad_arm.join("\n")).unwrap_err().to_string();
        assert!(err.contains("outside the header's frequency domain"), "{err}");
        // Batch-width drift: a 2-row step frame in a B = 1 log.
        let mut wide: Vec<String> = log_text(1).lines().map(str::to_string).collect();
        wide[1] = TelemetryFrame::Step {
            arms: vec![1, 2],
            samples: vec![sample(1.0), sample(2.0)],
        }
        .encode_line();
        let err = ReplayBackend::from_text(&wide.join("\n")).unwrap_err().to_string();
        assert!(err.contains("header declares B = 1"), "{err}");
    }
}
