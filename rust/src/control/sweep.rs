//! The counterfactual sweep tier: evaluate many policies against one
//! frozen telemetry recording (record once, evaluate many).
//!
//! [`sweep_replay`] rebuilds a controller per candidate from the
//! recording's own provenance header — the scalar session tier (B = 1)
//! through [`Controller::new`], the fleet tier (B = N) through
//! [`fleet_controller`][crate::fleet::fleet_controller] — and drives each
//! against its own rewound clone of the [`ReplayBackend`], fanned out on
//! the deterministic `exec` pool. Every candidate sees the identical
//! sample stream, so results are a pure function of (recording,
//! candidate) and byte-identical at any `--jobs` (the same contract as
//! the experiment executor, EXPERIMENTS.md §Sweeps).
//!
//! Replay is open-loop: a counterfactual policy's decisions cannot change
//! the recorded samples, so energy totals stay the recorded run's and the
//! comparison signal is the decision trajectory itself (selections,
//! regret, switch accounting).

use anyhow::{bail, ensure, Context as _, Result};

use crate::config::PolicyConfig;
use crate::exec::run_indexed;
use crate::fleet::{fleet_controller, FleetParams};
use crate::workload::calibration;

use super::controller::{drive, Controller};
use super::replay::{ReplayBackend, ReplayHeader};
use super::session::RunResult;

/// One policy to evaluate against the frozen recording.
#[derive(Clone, Debug)]
pub struct SweepCandidate {
    /// Report label; `None` uses the built policy's display name (so a
    /// single-candidate sweep renders exactly like `energyucb replay`).
    pub label: Option<String>,
    pub policy: PolicyConfig,
}

impl SweepCandidate {
    pub fn new(policy: PolicyConfig) -> SweepCandidate {
        SweepCandidate { label: None, policy }
    }

    pub fn labeled(label: impl Into<String>, policy: PolicyConfig) -> SweepCandidate {
        SweepCandidate { label: Some(label.into()), policy }
    }

    fn policy_name(&self) -> String {
        format!("{:?}", self.policy)
    }
}

/// One candidate's evaluation: per-environment results in row order
/// (length 1 for session recordings).
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub label: String,
    pub results: Vec<RunResult>,
}

/// Validate a candidate against the recording's header before any thread
/// fan-out, so malformed sweeps fail fast with the offending candidate
/// named instead of surfacing as a mid-pool controller assert.
fn validate_candidate(header: &ReplayHeader, cand: &SweepCandidate, idx: usize) -> Result<()> {
    let k = header.session.freqs.k();
    if let PolicyConfig::Static { arm } = &cand.policy {
        ensure!(
            *arm < k,
            "sweep candidate {idx}: static arm {arm} out of range for the recording's \
             frequency domain (K = {k})"
        );
    }
    Ok(())
}

/// Evaluate one candidate against its own rewound clone of the trace.
fn run_candidate(
    trace: &ReplayBackend,
    cand: &SweepCandidate,
    idx: usize,
) -> Result<SweepOutcome> {
    let header = trace.header();
    let scfg = &header.session;
    let k = scfg.freqs.k();
    let mut backend = trace.clone();
    backend.rewind();

    let results = if header.envs.is_empty() {
        // Session tier: one app, the scalar policy path (f64 cores —
        // identical arithmetic to `energyucb run` / `energyucb replay`).
        let app = calibration::app(&header.app)
            .with_context(|| format!("recording references unknown app {}", header.app))?;
        ensure!(
            app.energy_kj.len() == k,
            "recording's frequency domain has {k} arms but app {} is calibrated for {}",
            header.app,
            app.energy_kj.len()
        );
        let mut policy = cand.policy.build(k, scfg.seed);
        // Fresh-run contract: reset == freshly built, matching the
        // recorded session's starting state.
        policy.reset();
        // Contextual recordings carry their QoS budget in the header, so
        // every counterfactual candidate scores QoS the way the live run
        // did (context-free recordings leave it None — no QoS column).
        let controller = Controller::new(&app, policy.as_mut(), scfg)
            .with_qos_budget(header.context.and_then(|c| c.qos_budget));
        drive(controller, &mut backend)
            .with_context(|| format!("sweep candidate {idx} ({})", cand.policy_name()))?
    } else {
        // Fleet tier: rebuild the calibrated parameter block from the
        // header roster — the same derivation the recorded run used — and
        // drive the candidate's batch policy over the frozen samples.
        let b = header.b();
        let apps = header
            .envs
            .iter()
            .map(|n| {
                calibration::app(n)
                    .with_context(|| format!("recording references unknown app {n}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&_> = apps.iter().collect();
        let freqs = scfg.domain();
        ensure!(freqs.k() == k, "frequency domain arity drift");
        let mut params = FleetParams::from_apps(&refs, &freqs, scfg.dt_s);
        if let Some(f) = &header.feasible {
            ensure!(
                f.len() == b * k,
                "recording's feasibility mask has {} entries, expected B*K = {}",
                f.len(),
                b * k
            );
            params.feasible = f.iter().map(|&x| x as f32).collect();
        }
        let driver = cand.policy.build_batch(b, k, scfg.seed);
        let controller = fleet_controller(&params, driver, scfg.max_steps)
            .with_qos_budget(header.context.and_then(|c| c.qos_budget));
        drive(controller, &mut backend)
            .with_context(|| format!("sweep candidate {idx} ({})", cand.policy_name()))?
    };

    let label = match &cand.label {
        Some(l) => l.clone(),
        None => results[0].metrics.policy.clone(),
    };
    Ok(SweepOutcome { label, results })
}

/// Evaluate every candidate against the frozen recording, fanned out
/// across at most `jobs` worker threads. Results come back in candidate
/// order and are byte-identical at any `jobs` value: each cell clones and
/// rewinds the trace, derives everything else from (header, candidate),
/// and performs no I/O.
pub fn sweep_replay(
    trace: &ReplayBackend,
    candidates: &[SweepCandidate],
    jobs: usize,
) -> Result<Vec<SweepOutcome>> {
    if candidates.is_empty() {
        bail!("sweep: no candidates to evaluate");
    }
    for (i, cand) in candidates.iter().enumerate() {
        validate_candidate(trace.header(), cand, i)?;
    }
    run_indexed(jobs, candidates.len(), |i| run_candidate(trace, &candidates[i], i))
        .into_iter()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::EnergyUcbConfig;
    use crate::control::{Recording, SessionCfg, SimBackend, TelemetryFrame};

    /// Record a real tealeaf session (static arm 8, 400 steps) into an
    /// in-memory log.
    fn recorded_session() -> String {
        let app = calibration::app("tealeaf").unwrap();
        let scfg = SessionCfg { seed: 11, max_steps: 400, ..SessionCfg::default() };
        let header = ReplayHeader::session(
            "tealeaf".into(),
            Some(PolicyConfig::Static { arm: 8 }),
            scfg.clone(),
        );
        let mut sink = Vec::new();
        {
            let mut policy = crate::bandit::StaticPolicy::new(9, 8);
            let mut backend =
                Recording::new(SimBackend::new(&app, &scfg), &mut sink, &header).unwrap();
            let controller = Controller::new(&app, &mut policy, &scfg);
            drive(controller, &mut backend).unwrap();
            backend.finish().unwrap();
        }
        String::from_utf8(sink).unwrap()
    }

    fn candidates() -> Vec<SweepCandidate> {
        vec![
            SweepCandidate::new(PolicyConfig::Static { arm: 8 }),
            SweepCandidate::new(PolicyConfig::RoundRobin),
            SweepCandidate::labeled(
                "eucb",
                PolicyConfig::EnergyUcb(EnergyUcbConfig::default()),
            ),
        ]
    }

    #[test]
    fn sweep_is_deterministic_across_jobs() {
        let trace = ReplayBackend::from_text(&recorded_session()).unwrap();
        let seq = sweep_replay(&trace, &candidates(), 1).unwrap();
        let par = sweep_replay(&trace, &candidates(), 4).unwrap();
        assert_eq!(seq.len(), 3);
        assert_eq!(par.len(), 3);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.results.len(), b.results.len());
            for (ra, rb) in a.results.iter().zip(&b.results) {
                assert_eq!(ra.metrics, rb.metrics);
                assert_eq!(ra.energy_checkpoints_j, rb.energy_checkpoints_j);
            }
        }
    }

    #[test]
    fn sweep_is_counterfactual_over_a_frozen_stream() {
        let trace = ReplayBackend::from_text(&recorded_session()).unwrap();
        let out = sweep_replay(&trace, &candidates(), 2).unwrap();
        // Open loop: every candidate reports the recorded run's energy
        // totals (decisions cannot change the frozen samples)...
        let kj: Vec<f64> = out.iter().map(|o| o.results[0].metrics.gpu_energy_kj).collect();
        assert!(kj.iter().all(|&x| x == kj[0]), "{kj:?}");
        // ...and the recorded step count.
        assert!(out.iter().all(|o| o.results[0].metrics.steps == 400));
        // The decision trajectories differ: static-8 never switches and
        // earns a different regret than round-robin.
        assert_eq!(out[0].results[0].metrics.switches, 0);
        assert_ne!(
            out[0].results[0].metrics.cumulative_regret,
            out[1].results[0].metrics.cumulative_regret
        );
        // Labels: policy display names unless overridden.
        assert_eq!(out[2].label, "eucb");
        assert_ne!(out[0].label, out[1].label);
    }

    #[test]
    fn sweeping_the_recorded_policy_reproduces_the_replay() {
        // A single-candidate sweep of the recording's own policy must
        // equal a plain replay exactly (the CLI byte-compares reports on
        // top of this).
        let text = recorded_session();
        let trace = ReplayBackend::from_text(&text).unwrap();
        let header = trace.header().clone();
        let app = calibration::app(&header.app).unwrap();
        let mut policy = header.policy.clone().unwrap().build(9, header.session.seed);
        policy.reset();
        let mut backend = trace.clone();
        let controller = Controller::new(&app, policy.as_mut(), &header.session);
        let direct = drive(controller, &mut backend).unwrap().pop().unwrap();
        let swept = sweep_replay(
            &trace,
            &[SweepCandidate::new(header.policy.clone().unwrap())],
            1,
        )
        .unwrap();
        assert_eq!(swept[0].results[0].metrics, direct.metrics);
        assert_eq!(swept[0].label, direct.metrics.policy);
    }

    #[test]
    fn sweep_rejects_bad_candidates() {
        let trace = ReplayBackend::from_text(&recorded_session()).unwrap();
        assert!(sweep_replay(&trace, &[], 1).is_err());
        let err = sweep_replay(
            &trace,
            &[SweepCandidate::new(PolicyConfig::Static { arm: 12 })],
            1,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("out of range"), "{err}");
        // Unknown app in the header surfaces as a clear error.
        let mut lines: Vec<String> =
            recorded_session().lines().map(str::to_string).collect();
        lines[0] = TelemetryFrame::Header(ReplayHeader::session(
            "not-an-app".into(),
            None,
            SessionCfg { seed: 11, max_steps: 400, ..SessionCfg::default() },
        ))
        .encode_line();
        let trace = ReplayBackend::from_text(&lines.join("\n")).unwrap();
        let err = sweep_replay(&trace, &[SweepCandidate::new(PolicyConfig::RoundRobin)], 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown app"), "{err}");
    }
}
