//! Control plane: binds policies to the GEOPM stack and accounts metrics.

pub mod metrics;
pub mod session;

pub use metrics::{RepeatedMetrics, RunMetrics};
pub use session::{run_repeated, run_session, RunResult, SessionCfg};
