//! Control plane: the sans-IO decision core, the pluggable telemetry
//! backends it runs against, and the paper-metric accounting.
//!
//! * [`controller`] — [`Controller`], the pure `decide`/`observe` step
//!   machine, and [`drive`], the one loop pairing it with a backend.
//! * [`backend`] — the [`TelemetryBackend`] trait plus [`SimBackend`]
//!   (simulated GEOPM) and the [`Recording`] tee.
//! * [`replay`] — the JSONL telemetry grammar and [`ReplayBackend`]
//!   (record/replay + counterfactual policy evaluation).
//! * [`session`] — [`run_session`]/[`run_repeated`], the thin composition
//!   every experiment and the cluster worker call.

pub mod backend;
pub mod controller;
pub mod metrics;
pub mod replay;
pub mod session;

pub use backend::{Recording, SimBackend, TelemetryBackend};
pub use controller::{drive, BackendTotals, Controller, StepSample};
pub use metrics::{RepeatedMetrics, RunMetrics};
pub use replay::{ReplayBackend, ReplayHeader, TelemetryFrame};
pub use session::{run_repeated, run_session, RunResult, SessionCfg};
