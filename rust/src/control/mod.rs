//! Control plane: the batch-native sans-IO decision core, the pluggable
//! telemetry backends it runs against, and the paper-metric accounting.
//!
//! * [`controller`] — [`Controller`], the pure `decide`/`observe` step
//!   machine over B environments, and [`drive`], the one loop pairing it
//!   with a backend (the session tier at B = 1, the fleet tier at
//!   B = N).
//! * [`backend`] — the [`TelemetryBackend`] trait plus [`SimBackend`]
//!   (simulated GEOPM) and the [`Recording`] tee.
//! * [`replay`] — the JSONL telemetry grammar and [`ReplayBackend`]
//!   (record/replay + counterfactual policy evaluation).
//! * [`sweep`] — the counterfactual sweep tier: evaluate many policies
//!   against one frozen recording, fanned out on the `exec` pool.
//! * [`session`] — [`run_session`]/[`run_repeated`], the thin composition
//!   every experiment and the cluster worker call.

pub mod backend;
pub mod controller;
pub mod metrics;
pub mod replay;
pub mod session;
pub mod sweep;

pub use backend::{Recording, SimBackend, TelemetryBackend};
pub use controller::{drive, drive_hooked, BackendTotals, BatchOpts, Controller, EnvSpec, StepSample};
pub use metrics::{RepeatedMetrics, RunMetrics};
pub use replay::{ContextSpec, ReplayBackend, ReplayHeader, TelemetryFrame};
pub use session::{
    run_repeated, run_repeated_serving, run_session, run_session_serving, RunResult, SessionCfg,
};
pub use sweep::{sweep_replay, SweepCandidate, SweepOutcome};
