//! Pluggable telemetry backends: where a controller's samples come from
//! and where its arms go.
//!
//! [`TelemetryBackend`] is the session tier's I/O boundary. The
//! [`Controller`][super::Controller] never touches it directly — the
//! [`drive`][super::drive] loop mediates — so swapping the backend swaps
//! the *world* without touching a line of decision logic:
//!
//! * [`SimBackend`] — the simulated GEOPM [`Service`] owning a
//!   calibrated [`Node`] (the paper's experimental setup; what
//!   `run_session` wires up).
//! * [`ReplayBackend`][super::replay::ReplayBackend] — recorded per-step
//!   telemetry from JSONL, for deterministic replay and counterfactual
//!   policy evaluation (`energyucb replay`).
//! * [`Recording`] — a tee: wraps any backend and mirrors every sample
//!   to a JSONL sink in the replay grammar
//!   (EXPERIMENTS.md §Controller).
//!
//! A live NVML/GEOPM binding slots in as a fourth implementation without
//! touching the controller.

use std::io::Write;

use crate::geopm::{Control, Service};
use crate::sim::node::Node;
use crate::workload::model::AppModel;

use super::controller::{BackendTotals, StepSample};
use super::replay::{ReplayHeader, TelemetryFrame};
use super::session::SessionCfg;

/// A source of per-step telemetry and a sink for frequency decisions.
///
/// Contract (checked by the drive loop's usage pattern): `apply(arm)`
/// then `sample()` advances exactly one decision interval; `done()` is
/// stable between samples; `totals()` reflects every interval sampled so
/// far. Implementations must be deterministic for a fixed construction
/// (seed / recording) — the backend determinism guarantee that makes
/// record→replay exact (EXPERIMENTS.md §Controller).
pub trait TelemetryBackend {
    /// Number of frequency arms the backend accepts.
    fn k(&self) -> usize;

    /// Request arm `arm` for the next interval.
    fn apply(&mut self, arm: usize) -> anyhow::Result<()>;

    /// Advance one interval under the last applied arm and return its
    /// telemetry.
    fn sample(&mut self) -> anyhow::Result<StepSample>;

    /// Whether the underlying job has completed (no further samples).
    fn done(&self) -> bool;

    /// End-of-run accounting over every interval sampled so far.
    fn totals(&self) -> BackendTotals;
}

/// The simulated-GEOPM backend: today's `run_session` world, wrapped.
#[derive(Debug)]
pub struct SimBackend {
    service: Service,
}

impl SimBackend {
    /// Build the node + service stack for `app` under `cfg` (frequency
    /// domain and switch cost from [`SessionCfg::domain`]).
    pub fn new(app: &AppModel, cfg: &SessionCfg) -> SimBackend {
        let freqs = cfg.domain();
        assert_eq!(
            app.energy_kj.len(),
            freqs.k(),
            "app calibration table must match frequency domain"
        );
        let node = Node::new(app.clone(), freqs, cfg.dt_s, cfg.seed);
        SimBackend { service: Service::new(node) }
    }

    /// The underlying service (signal reads, diagnostics).
    pub fn service(&self) -> &Service {
        &self.service
    }
}

impl TelemetryBackend for SimBackend {
    fn k(&self) -> usize {
        self.service.k()
    }

    fn apply(&mut self, arm: usize) -> anyhow::Result<()> {
        self.service.write(Control::GpuFrequency(arm))?;
        Ok(())
    }

    fn sample(&mut self) -> anyhow::Result<StepSample> {
        let s = self.service.sample()?;
        Ok(StepSample {
            gpu_energy_j: s.obs.gpu_energy_j,
            core_util: s.obs.core_util,
            uncore_util: s.obs.uncore_util,
            progress: s.obs.progress,
            remaining: s.obs.remaining,
            true_gpu_energy_j: s.obs.true_gpu_energy_j,
            switched: s.switched,
        })
    }

    fn done(&self) -> bool {
        self.service.done()
    }

    fn totals(&self) -> BackendTotals {
        let t = self.service.totals();
        BackendTotals {
            gpu_energy_kj: t.gpu_energy_kj,
            exec_time_s: t.exec_time_s,
            switches: t.switches,
            switch_energy_j: t.switch_energy_j,
            switch_time_s: t.switch_time_s,
        }
    }
}

/// Tee wrapper: forwards to any inner backend while mirroring the run to
/// a JSONL sink in the replay grammar (header written at construction,
/// one `step` line per sample, terminal `end` line from
/// [`finish`](Self::finish)).
pub struct Recording<B, W: Write> {
    inner: B,
    sink: W,
    last_arm: usize,
}

impl<B: TelemetryBackend, W: Write> Recording<B, W> {
    /// Wrap `inner`, writing the header line immediately.
    pub fn new(inner: B, mut sink: W, header: &ReplayHeader) -> anyhow::Result<Recording<B, W>> {
        writeln!(sink, "{}", TelemetryFrame::Header(header.clone()).encode_line())?;
        Ok(Recording { inner, sink, last_arm: 0 })
    }

    /// Write the terminal totals frame, flush, and return the inner
    /// backend. Must be called after the drive loop — a recording without
    /// its `end` frame is rejected by the replay reader as truncated.
    pub fn finish(mut self) -> anyhow::Result<B> {
        let totals = self.inner.totals();
        writeln!(self.sink, "{}", TelemetryFrame::End { totals }.encode_line())?;
        self.sink.flush()?;
        Ok(self.inner)
    }
}

impl<B: TelemetryBackend, W: Write> TelemetryBackend for Recording<B, W> {
    fn k(&self) -> usize {
        self.inner.k()
    }

    fn apply(&mut self, arm: usize) -> anyhow::Result<()> {
        self.last_arm = arm;
        self.inner.apply(arm)
    }

    fn sample(&mut self) -> anyhow::Result<StepSample> {
        let sample = self.inner.sample()?;
        let frame = TelemetryFrame::Step { arm: self.last_arm, sample };
        writeln!(self.sink, "{}", frame.encode_line())?;
        Ok(sample)
    }

    fn done(&self) -> bool {
        self.inner.done()
    }

    fn totals(&self) -> BackendTotals {
        self.inner.totals()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::StaticPolicy;
    use crate::control::{drive, Controller};

    #[test]
    fn sim_backend_mirrors_service_semantics() {
        let app = crate::workload::calibration::app("tealeaf").unwrap();
        let cfg = SessionCfg::default();
        let mut b = SimBackend::new(&app, &cfg);
        assert_eq!(b.k(), 9);
        assert!(!b.done());
        // Out-of-range arms are backend errors, not panics.
        assert!(b.apply(99).is_err());
        b.apply(2).unwrap();
        let s = b.sample().unwrap();
        assert!(s.switched);
        assert!(s.gpu_energy_j > 0.0);
        assert!(s.remaining < 1.0);
        let t = b.totals();
        assert_eq!(t.switches, 1);
        assert!(t.exec_time_s > 0.0);
    }

    #[test]
    fn recording_tees_a_parseable_log() {
        let app = crate::workload::calibration::app("clvleaf").unwrap();
        let cfg = SessionCfg { max_steps: 25, ..SessionCfg::default() };
        let mut policy = StaticPolicy::new(9, 8);
        let header = ReplayHeader { app: app.name.to_string(), policy: None, session: cfg.clone() };
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut backend =
                Recording::new(SimBackend::new(&app, &cfg), &mut buf, &header).unwrap();
            let controller = Controller::new(&app, &mut policy, &cfg);
            let res = drive(controller, &mut backend).unwrap();
            assert_eq!(res.metrics.steps, 25);
            backend.finish().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // header + 25 steps + end.
        assert_eq!(lines.len(), 27, "{text}");
        assert!(matches!(
            TelemetryFrame::decode_line(lines[0]).unwrap(),
            TelemetryFrame::Header(_)
        ));
        assert!(matches!(
            TelemetryFrame::decode_line(lines[1]).unwrap(),
            TelemetryFrame::Step { arm: 8, .. }
        ));
        assert!(matches!(
            TelemetryFrame::decode_line(lines[26]).unwrap(),
            TelemetryFrame::End { .. }
        ));
    }
}
