//! Pluggable telemetry backends: where a controller's samples come from
//! and where its arms go.
//!
//! [`TelemetryBackend`] is the control tier's I/O boundary, batch-native:
//! a backend serves B environments per decision interval (B = 1 for the
//! scalar session tier). The [`Controller`][super::Controller] never
//! touches it directly — the [`drive`][super::drive] loop mediates — so
//! swapping the backend swaps the *world* without touching a line of
//! decision logic:
//!
//! * [`SimBackend`] — the simulated GEOPM [`Service`] owning a
//!   calibrated [`Node`] (the paper's experimental setup; what
//!   `run_session` wires up; B = 1).
//! * [`FleetBackend`][crate::fleet::FleetBackend] — the vectorized fleet
//!   dynamics (`fleet::native::apply_env_dynamics`) at B = N.
//! * [`ReplayBackend`][super::replay::ReplayBackend] — recorded per-step
//!   telemetry from JSONL, for deterministic replay and counterfactual
//!   policy evaluation (`energyucb replay` / `energyucb sweep --replay`).
//! * [`Recording`] — a tee: wraps any backend and mirrors every sample
//!   batch to a JSONL sink in the replay grammar
//!   (EXPERIMENTS.md §Controller).
//! * [`HwBackend`][crate::hw::HwBackend] — the live-hardware tier: one
//!   row per detected GPU behind the [`GpuDriver`][crate::hw::GpuDriver]
//!   trait (deterministic fault-scriptable mock by default, dlopen'd
//!   libnvidia-ml behind `--features nvml`), with safety rails the
//!   controller never sees (reset-on-drop, dwell limiting, an error
//!   watchdog that degrades rows instead of crashing).

use std::io::Write;

use crate::geopm::{Control, Service};
use crate::sim::node::Node;
use crate::workload::model::AppModel;
use crate::workload::serving::ServingModel;

use super::controller::{BackendTotals, StepSample};
use super::replay::{ReplayHeader, TelemetryFrame};
use super::session::SessionCfg;

/// A source of per-step telemetry and a sink for frequency decisions
/// over a batch of B environments.
///
/// Contract (checked by the drive loop's usage pattern): `apply(&sel)`
/// then `sample_into(&mut samples)` advances exactly one decision
/// interval for every environment; `done()` is stable between samples;
/// `totals()` reflects every interval sampled so far, one record per
/// environment. Implementations must be deterministic for a fixed
/// construction (seed / recording) — the backend determinism guarantee
/// that makes record→replay exact (EXPERIMENTS.md §Controller).
pub trait TelemetryBackend {
    /// Number of environments served per interval.
    fn b(&self) -> usize {
        1
    }

    /// Number of frequency arms the backend accepts.
    fn k(&self) -> usize;

    /// Request arm `sel[e]` for environment `e` for the next interval
    /// (`sel.len() == b()`).
    fn apply(&mut self, sel: &[i32]) -> anyhow::Result<()>;

    /// Advance one interval under the last applied arms and write each
    /// environment's telemetry into `out` (`out.len() == b()`).
    fn sample_into(&mut self, out: &mut [StepSample]) -> anyhow::Result<()>;

    /// Whether the underlying jobs have all completed (no further
    /// samples).
    fn done(&self) -> bool;

    /// End-of-run accounting over every interval sampled so far, one
    /// record per environment.
    fn totals(&self) -> Vec<BackendTotals>;
}

/// The simulated-GEOPM backend: today's `run_session` world, wrapped
/// (B = 1).
#[derive(Debug)]
pub struct SimBackend {
    service: Service,
    // Serving tier: an arrival-process model whose feature vector rides
    // each sample as the optional context block. `None` (the default)
    // emits context-free samples — every legacy byte contract holds by
    // construction.
    serving: Option<ServingModel>,
    last_arm: usize,
}

impl SimBackend {
    /// Build the node + service stack for `app` under `cfg` (frequency
    /// domain and switch cost from [`SessionCfg::domain`]).
    pub fn new(app: &AppModel, cfg: &SessionCfg) -> SimBackend {
        let freqs = cfg.domain();
        assert_eq!(
            app.energy_kj.len(),
            freqs.k(),
            "app calibration table must match frequency domain"
        );
        let node = Node::new(app.clone(), freqs, cfg.dt_s, cfg.seed);
        SimBackend { service: Service::new(node), serving: None, last_arm: 0 }
    }

    /// Attach a serving workload: every sample now carries the model's
    /// feature vector, stepped under the applied arm's relative
    /// throughput (`(arm + 1) / K`).
    pub fn with_serving(mut self, model: ServingModel) -> SimBackend {
        self.serving = Some(model);
        self
    }

    /// The underlying service (signal reads, diagnostics).
    pub fn service(&self) -> &Service {
        &self.service
    }
}

impl TelemetryBackend for SimBackend {
    fn k(&self) -> usize {
        self.service.k()
    }

    fn apply(&mut self, sel: &[i32]) -> anyhow::Result<()> {
        anyhow::ensure!(sel.len() == 1, "SimBackend serves B = 1, got {} selections", sel.len());
        anyhow::ensure!(sel[0] >= 0, "negative arm {}", sel[0]);
        self.service.write(Control::GpuFrequency(sel[0] as usize))?;
        self.last_arm = sel[0] as usize;
        Ok(())
    }

    fn sample_into(&mut self, out: &mut [StepSample]) -> anyhow::Result<()> {
        anyhow::ensure!(out.len() == 1, "SimBackend serves B = 1, got {} slots", out.len());
        let s = self.service.sample()?;
        out[0] = StepSample {
            gpu_energy_j: s.obs.gpu_energy_j,
            core_util: s.obs.core_util,
            uncore_util: s.obs.uncore_util,
            progress: s.obs.progress,
            remaining: s.obs.remaining,
            true_gpu_energy_j: s.obs.true_gpu_energy_j,
            switched: s.switched,
            reward: None,
            active: true,
            context: None,
        };
        if let Some(model) = self.serving.as_mut() {
            let scale = (self.last_arm + 1) as f64 / self.service.k() as f64;
            out[0].context = Some(model.step(scale));
        }
        Ok(())
    }

    fn done(&self) -> bool {
        self.service.done()
    }

    fn totals(&self) -> Vec<BackendTotals> {
        let t = self.service.totals();
        vec![BackendTotals {
            gpu_energy_kj: t.gpu_energy_kj,
            exec_time_s: t.exec_time_s,
            switches: t.switches,
            switch_energy_j: t.switch_energy_j,
            switch_time_s: t.switch_time_s,
        }]
    }
}

/// Tee wrapper: forwards to any inner backend while mirroring the run to
/// a JSONL sink in the replay grammar (header written at construction,
/// one `step` line per sampled interval, terminal `end` line).
///
/// The terminal frame is never lost: [`finish`](Self::finish) writes a
/// clean `end` with the achieved step count; if the recording is dropped
/// without `finish` — the drive loop aborted mid-run — `Drop` writes an
/// `end` frame carrying the truncation marker instead, so the log stays
/// diagnosable and [`ReplayBackend`][super::replay::ReplayBackend]
/// rejects it with an actionable error rather than replaying short.
pub struct Recording<B: TelemetryBackend, W: Write> {
    inner: B,
    sink: Option<W>,
    last_sel: Vec<i32>,
    steps_written: u64,
}

impl<B: TelemetryBackend, W: Write> Recording<B, W> {
    /// Wrap `inner`, writing the header line immediately.
    pub fn new(inner: B, mut sink: W, header: &ReplayHeader) -> anyhow::Result<Recording<B, W>> {
        writeln!(sink, "{}", TelemetryFrame::Header(header.clone()).encode_line())?;
        let b = inner.b();
        Ok(Recording { inner, sink: Some(sink), last_sel: vec![0i32; b], steps_written: 0 })
    }

    fn write_end(&mut self, truncated: bool) -> anyhow::Result<()> {
        let Some(mut sink) = self.sink.take() else {
            return Ok(());
        };
        let frame = TelemetryFrame::End {
            totals: self.inner.totals(),
            steps: Some(self.steps_written),
            truncated,
        };
        writeln!(sink, "{}", frame.encode_line())?;
        sink.flush()?;
        Ok(())
    }

    /// Write the clean terminal totals frame and flush. Must be called
    /// after a successful drive loop — dropping the recording instead
    /// marks the log truncated.
    pub fn finish(mut self) -> anyhow::Result<()> {
        self.write_end(false)
    }

    /// The wrapped backend, for post-drive inspection (e.g. the hw tier
    /// exports its driver-health instruments before `finish`).
    pub fn inner(&self) -> &B {
        &self.inner
    }
}

impl<B: TelemetryBackend, W: Write> Drop for Recording<B, W> {
    fn drop(&mut self) {
        // Abort path (`finish` was never reached): best-effort terminal
        // frame with the truncation marker and the achieved step count.
        let _ = self.write_end(true);
    }
}

impl<B: TelemetryBackend, W: Write> TelemetryBackend for Recording<B, W> {
    fn b(&self) -> usize {
        self.inner.b()
    }

    fn k(&self) -> usize {
        self.inner.k()
    }

    fn apply(&mut self, sel: &[i32]) -> anyhow::Result<()> {
        self.last_sel.resize(sel.len(), 0);
        self.last_sel.copy_from_slice(sel);
        self.inner.apply(sel)
    }

    fn sample_into(&mut self, out: &mut [StepSample]) -> anyhow::Result<()> {
        self.inner.sample_into(out)?;
        let frame =
            TelemetryFrame::Step { arms: self.last_sel.clone(), samples: out.to_vec() };
        let Some(sink) = self.sink.as_mut() else {
            anyhow::bail!("recording already finished");
        };
        writeln!(sink, "{}", frame.encode_line())?;
        self.steps_written += 1;
        Ok(())
    }

    fn done(&self) -> bool {
        self.inner.done()
    }

    fn totals(&self) -> Vec<BackendTotals> {
        self.inner.totals()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::StaticPolicy;
    use crate::control::{drive, Controller, ReplayBackend};

    #[test]
    fn sim_backend_mirrors_service_semantics() {
        let app = crate::workload::calibration::app("tealeaf").unwrap();
        let cfg = SessionCfg::default();
        let mut b = SimBackend::new(&app, &cfg);
        assert_eq!(b.b(), 1);
        assert_eq!(b.k(), 9);
        assert!(!b.done());
        // Out-of-range arms are backend errors, not panics.
        assert!(b.apply(&[99]).is_err());
        assert!(b.apply(&[-1]).is_err());
        b.apply(&[2]).unwrap();
        let mut out = [StepSample::default()];
        b.sample_into(&mut out).unwrap();
        let s = out[0];
        assert!(s.switched);
        assert!(s.active);
        assert_eq!(s.reward, None);
        assert!(s.gpu_energy_j > 0.0);
        assert!(s.remaining < 1.0);
        let t = b.totals();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].switches, 1);
        assert!(t[0].exec_time_s > 0.0);
    }

    #[test]
    fn recording_tees_a_parseable_log() {
        let app = crate::workload::calibration::app("clvleaf").unwrap();
        let cfg = SessionCfg { max_steps: 25, ..SessionCfg::default() };
        let mut policy = StaticPolicy::new(9, 8);
        let header = ReplayHeader::session(app.name.to_string(), None, cfg.clone());
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut backend =
                Recording::new(SimBackend::new(&app, &cfg), &mut buf, &header).unwrap();
            let controller = Controller::new(&app, &mut policy, &cfg);
            let res = drive(controller, &mut backend).unwrap();
            assert_eq!(res[0].metrics.steps, 25);
            backend.finish().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // header + 25 steps + end.
        assert_eq!(lines.len(), 27, "{text}");
        assert!(matches!(
            TelemetryFrame::decode_line(lines[0]).unwrap(),
            TelemetryFrame::Header(_)
        ));
        match TelemetryFrame::decode_line(lines[1]).unwrap() {
            TelemetryFrame::Step { arms, samples } => {
                assert_eq!(arms, vec![8]);
                assert_eq!(samples.len(), 1);
            }
            other => panic!("expected step frame, got {other:?}"),
        }
        match TelemetryFrame::decode_line(lines[26]).unwrap() {
            TelemetryFrame::End { steps, truncated, .. } => {
                assert_eq!(steps, Some(25));
                assert!(!truncated);
            }
            other => panic!("expected end frame, got {other:?}"),
        }
    }

    #[test]
    fn dropped_recording_marks_the_log_truncated() {
        let app = crate::workload::calibration::app("tealeaf").unwrap();
        let cfg = SessionCfg { max_steps: 10, ..SessionCfg::default() };
        let header = ReplayHeader::session(app.name.to_string(), None, cfg.clone());
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut backend =
                Recording::new(SimBackend::new(&app, &cfg), &mut buf, &header).unwrap();
            // Advance a few intervals, then abandon the recording without
            // finish() — as the drive loop does when it aborts on error.
            let mut out = [StepSample::default()];
            for _ in 0..3 {
                backend.apply(&[4]).unwrap();
                backend.sample_into(&mut out).unwrap();
            }
        }
        let text = String::from_utf8(buf).unwrap();
        let last = text.lines().last().unwrap();
        match TelemetryFrame::decode_line(last).unwrap() {
            TelemetryFrame::End { steps, truncated, .. } => {
                assert_eq!(steps, Some(3));
                assert!(truncated, "drop must mark the log truncated");
            }
            other => panic!("expected end frame, got {other:?}"),
        }
        // The replay reader refuses it with an actionable message.
        let err = ReplayBackend::from_text(&text).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }
}
