//! The sans-IO control core: a pure decision/observation step machine,
//! batch-native over B environments.
//!
//! [`Controller`] is everything that used to live inline in
//! `run_session`'s loop between `service.sample()` and the policy update —
//! the [`BatchPolicy`] driver, reward formation and winsorized
//! normalization, ground-truth regret accounting, progress checkpoints,
//! and trace bookkeeping — with no clock, no I/O, and no knowledge of
//! where telemetry comes from. All per-env bookkeeping is row-indexed
//! over the batch: one [`RewardNormalizer`] per environment, checkpoints
//! in a row-major (B, n_cp) grid, one optional [`Trace`] per row.
//! Drivers own the loop: [`drive`] pairs a controller with any
//! [`TelemetryBackend`][super::backend::TelemetryBackend] (live
//! simulation at B = 1, the fleet dynamics at B = N, recorded trace
//! replay at either) and is the only place wall-clock time is read (the
//! decision-latency gauge).
//!
//! The protocol per decision interval is strict alternation: `decide()`,
//! apply [`selections`][Controller::selections] through the backend,
//! `sample_into` a batch of [`StepSample`]s from the backend,
//! `observe(&samples)`. `finish(&totals)` consumes the controller and
//! yields one [`RunResult`] per environment. Determinism contract: for a
//! fixed policy state and sample stream, every controller output —
//! selections, metrics, checkpoints, traces — is a pure function of the
//! inputs (EXPERIMENTS.md §Controller).

use crate::bandit::batch::{BatchPolicy, Scalar};
use crate::bandit::{Policy, RewardForm, RewardNormalizer, CONTEXT_DIM};
use crate::telemetry::{Counter, Gauge, Recorder};
use crate::workload::model::AppModel;
use crate::workload::trace::{Trace, TraceStep};

use super::backend::TelemetryBackend;
use super::metrics::RunMetrics;
use super::session::{RunResult, SessionCfg};

/// One decision interval's telemetry for one environment,
/// backend-agnostic: the counter-visible quantities the controller
/// consumes (plus the ground-truth energy used only for metrics, never
/// shown to the policy).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepSample {
    /// Measured (noisy) GPU energy over the interval, Joules.
    pub gpu_energy_j: f64,
    /// Aggregate core-engine utilization in [0, 1].
    pub core_util: f64,
    /// Aggregate uncore (copy-engine) utilization in [0, 1].
    pub uncore_util: f64,
    /// Progress made this interval (fraction of the whole job).
    pub progress: f64,
    /// Remaining work (1 → 0).
    pub remaining: f64,
    /// True GPU energy this interval (ground truth, metrics only).
    pub true_gpu_energy_j: f64,
    /// Whether the interval performed a frequency transition.
    pub switched: bool,
    /// Preformed reward for this interval, when the backend synthesizes
    /// rewards itself (the fleet tier's normalized expected-reward
    /// model). `None` = derive the reward from the counter-visible
    /// fields through the controller's [`RewardForm`] and the
    /// environment's [`RewardNormalizer`] (the session tier).
    pub reward: Option<f64>,
    /// Whether the environment was still running this interval.
    /// Inactive rows' samples must not move policy statistics, regret,
    /// energy accounting, or traces.
    pub active: bool,
    /// Workload context observed this interval (the serving tier's
    /// feature vector: queue depth, arrival rate, batch occupancy,
    /// recent util ratio — see `workload::serving`). `None` = the
    /// backend is context-free. The controller stages an observed
    /// context for the *next* decision, so the first decision of every
    /// run is context-free on every path — live and replay alike.
    pub context: Option<[f64; CONTEXT_DIM]>,
}

impl Default for StepSample {
    fn default() -> StepSample {
        StepSample {
            gpu_energy_j: 0.0,
            core_util: 0.0,
            uncore_util: 0.0,
            progress: 0.0,
            remaining: 1.0,
            true_gpu_energy_j: 0.0,
            switched: false,
            reward: None,
            active: true,
            context: None,
        }
    }
}

/// End-of-run accounting a backend must provide per environment (the
/// `RunMetrics` fields the controller cannot derive from per-step samples
/// alone without re-accumulating rounding differences).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BackendTotals {
    pub gpu_energy_kj: f64,
    pub exec_time_s: f64,
    pub switches: u64,
    pub switch_energy_j: f64,
    pub switch_time_s: f64,
}

/// Ground truth for one environment's regret accounting: the calibrated
/// app identity and its per-arm true rewards (simulation-only knowledge,
/// never shown to the policy).
#[derive(Clone, Debug)]
pub struct EnvSpec {
    /// Calibrated app name (carried into the env's `RunMetrics`).
    pub app: String,
    /// True expected reward per arm, raw reward units.
    pub true_rewards: Vec<f64>,
}

impl EnvSpec {
    /// Build the ground truth for one app under a session configuration
    /// (the same derivation the scalar session tier has always used).
    pub fn from_app(app: &AppModel, cfg: &SessionCfg) -> EnvSpec {
        let freqs = cfg.domain();
        EnvSpec {
            app: app.name.to_string(),
            true_rewards: (0..freqs.k()).map(|i| app.true_reward(&freqs, i, cfg.dt_s)).collect(),
        }
    }
}

/// Batch-construction knobs shared by every controller tier.
#[derive(Clone, Debug)]
pub struct BatchOpts {
    /// Reward formulation for samples without a preformed reward.
    pub reward_form: RewardForm,
    /// Safety cap on decision steps.
    pub max_steps: u64,
    /// Record a full per-step [`Trace`] per environment.
    pub record_trace: bool,
    /// Progress checkpoints per environment (0 = none).
    pub checkpoints: usize,
    /// Row-major (B, K) feasibility mask handed to the policy on every
    /// `select_into`; `None` = all arms feasible. Regret's per-env
    /// optimum is taken over the feasible arms only.
    pub feasible: Option<Vec<f32>>,
}

impl BatchOpts {
    /// The session tier's options (B = 1, all arms feasible).
    pub fn from_session(cfg: &SessionCfg) -> BatchOpts {
        BatchOpts {
            reward_form: cfg.reward_form,
            max_steps: cfg.max_steps,
            record_trace: cfg.record_trace,
            checkpoints: cfg.checkpoints,
            feasible: None,
        }
    }
}

/// The sans-IO controller for a batch of environments (see module docs).
pub struct Controller<'p> {
    driver: Box<dyn BatchPolicy + 'p>,
    b: usize,
    k: usize,
    feasible: Vec<f32>,
    sel: Vec<i32>,
    // Per-step staging for the batched policy update (allocation-free
    // hot loop).
    reward_buf: Vec<f64>,
    progress_buf: Vec<f64>,
    active_buf: Vec<f32>,
    normalizers: Vec<RewardNormalizer>,
    reward_form: RewardForm,
    max_steps: u64,
    traces: Vec<Option<Trace>>,
    envs: Vec<EnvSpec>,
    mu_star: Vec<f64>,
    t: u64,
    cumulative_regret: Vec<f64>,
    cum_true_energy_j: Vec<f64>,
    final_completed: Vec<f64>,
    /// Row-major (B, n_cp) cumulative-energy checkpoints.
    checkpoints: Vec<f64>,
    n_cp: usize,
    next_cp: Vec<usize>,
    // Operational telemetry accumulates in plain fields (a `Recorder`
    // name lookup allocates per call — the hot loop stays
    // allocation-free) and is merged into the `RunResult` Recorders once
    // in `finish`.
    switch_rate: Vec<Gauge>,
    switch_counter: Vec<Counter>,
    decide_latency_us: Gauge,
    // Context plumbing: the last observed per-row context, staged for
    // the next decision (row-major (B, D)); `has_ctx` flips once any
    // backend sample carries a context block and stays set.
    ctx: Vec<f64>,
    has_ctx: bool,
    // TTFT-style QoS accounting (serving tier): a budget on the queue-
    // depth context feature, violations counted per env over active
    // context-carrying intervals.
    qos_budget: Option<f64>,
    qos_violations: Vec<u64>,
    qos_steps: Vec<u64>,
}

impl<'p> Controller<'p> {
    /// Bind one scalar policy to one app's session configuration — the
    /// B = 1 tier, bridged onto the batch core via [`Scalar`]. The
    /// frequency domain comes from `cfg` ([`SessionCfg::domain`]); the
    /// policy's arity and the app's calibration table must both match it.
    pub fn new(app: &AppModel, policy: &'p mut dyn Policy, cfg: &SessionCfg) -> Controller<'p> {
        let freqs = cfg.domain();
        assert_eq!(policy.k(), freqs.k(), "policy arity must match frequency domain");
        assert_eq!(
            app.energy_kj.len(),
            freqs.k(),
            "app calibration table must match frequency domain"
        );
        let env = EnvSpec::from_app(app, cfg);
        Controller::new_batch(
            vec![env],
            Box::new(Scalar::new(vec![policy])),
            &BatchOpts::from_session(cfg),
        )
    }

    /// Bind a batch policy to B environments' ground truth. `driver.b()`
    /// must equal `envs.len()` and every env's true-reward table must
    /// match the policy arity.
    pub fn new_batch(
        envs: Vec<EnvSpec>,
        driver: Box<dyn BatchPolicy + 'p>,
        opts: &BatchOpts,
    ) -> Controller<'p> {
        let b = envs.len();
        assert!(b > 0, "controller needs at least one environment");
        assert_eq!(driver.b(), b, "policy batch must match environment count");
        let k = driver.k();
        for env in &envs {
            assert_eq!(env.true_rewards.len(), k, "env ground truth must match policy arity");
        }
        let feasible = match &opts.feasible {
            Some(f) => {
                assert_eq!(f.len(), b * k, "feasibility mask must be row-major (B, K)");
                f.clone()
            }
            None => vec![1.0f32; b * k],
        };
        // Regret baseline: the best *feasible* arm per env (identical to
        // the global optimum when the mask is all-ones, i.e. always for
        // the session tier).
        let mu_star = envs
            .iter()
            .enumerate()
            .map(|(e, env)| {
                env.true_rewards
                    .iter()
                    .zip(&feasible[e * k..(e + 1) * k])
                    .filter(|(_, &f)| f > 0.0)
                    .map(|(r, _)| *r)
                    .fold(f64::NEG_INFINITY, f64::max)
            })
            .collect();
        Controller {
            driver,
            b,
            k,
            feasible,
            sel: vec![0i32; b],
            reward_buf: vec![0.0f64; b],
            progress_buf: vec![0.0f64; b],
            active_buf: vec![0.0f32; b],
            normalizers: (0..b).map(|_| RewardNormalizer::new()).collect(),
            reward_form: opts.reward_form,
            max_steps: opts.max_steps,
            traces: (0..b).map(|_| opts.record_trace.then(Trace::new)).collect(),
            envs,
            mu_star,
            t: 0,
            cumulative_regret: vec![0.0f64; b],
            cum_true_energy_j: vec![0.0f64; b],
            final_completed: vec![0.0f64; b],
            checkpoints: vec![0.0f64; b * opts.checkpoints],
            n_cp: opts.checkpoints,
            next_cp: vec![0usize; b],
            switch_rate: vec![Gauge::default(); b],
            switch_counter: vec![Counter::default(); b],
            decide_latency_us: Gauge::default(),
            ctx: vec![0.0f64; b * CONTEXT_DIM],
            has_ctx: false,
            qos_budget: None,
            qos_violations: vec![0u64; b],
            qos_steps: vec![0u64; b],
        }
    }

    /// Attach a TTFT-style QoS budget on the queue-depth context
    /// feature: active context-carrying intervals whose normalized
    /// queue depth exceeds `budget` count as QoS violations, reported
    /// per env through `RunMetrics::qos_violation_frac`. `None` (the
    /// default) reports no QoS figure — context-free runs are
    /// untouched.
    pub fn with_qos_budget(mut self, budget: Option<f64>) -> Self {
        self.qos_budget = budget;
        self
    }

    /// Batch size (environments).
    pub fn b(&self) -> usize {
        self.b
    }

    /// Arm count.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Decision steps taken so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// Whether the step budget allows another decision.
    pub fn wants_step(&self) -> bool {
        self.t < self.max_steps
    }

    /// Cumulative ground-truth regret so far, summed over the batch (raw
    /// reward units; equals the single env's regret at B = 1).
    pub fn cumulative_regret(&self) -> f64 {
        self.cumulative_regret.iter().sum()
    }

    /// Completed work fraction observed so far for environment `e` (the
    /// latest active sample's `1 - remaining`). Read by step hooks
    /// ([`drive_hooked`]) that stream live progress — e.g. the cluster
    /// worker's in-run heartbeats.
    pub fn completed(&self, e: usize) -> f64 {
        self.final_completed[e]
    }

    /// Cumulative ground-truth GPU energy (J) accumulated so far for
    /// environment `e`. Read by step hooks ([`drive_hooked`]) alongside
    /// [`completed`](Self::completed).
    pub fn true_energy_j(&self, e: usize) -> f64 {
        self.cum_true_energy_j[e]
    }

    /// Record one decision's wall-clock latency (µs). Called by drivers
    /// ([`drive`]) — the controller itself never reads a clock.
    pub fn record_decide_latency_us(&mut self, us: f64) {
        self.decide_latency_us.record(us);
    }

    /// Choose each environment's arm for the next decision interval;
    /// read the result from [`selections`](Self::selections).
    pub fn decide(&mut self) {
        self.t += 1;
        if self.has_ctx {
            self.driver.select_into_ctx(
                self.t,
                &self.feasible,
                &self.ctx,
                CONTEXT_DIM,
                &mut self.sel,
            );
        } else {
            self.driver.select_into(self.t, &self.feasible, &mut self.sel);
        }
    }

    /// The arms chosen by the last [`decide`](Self::decide), one per
    /// environment.
    pub fn selections(&self) -> &[i32] {
        &self.sel
    }

    /// Feed back the interval's telemetry (one sample per environment)
    /// for the arms chosen by the last [`decide`](Self::decide).
    pub fn observe(&mut self, samples: &[StepSample]) {
        assert_eq!(samples.len(), self.b, "one sample per environment");
        for (e, s) in samples.iter().enumerate() {
            // Reward from counter-visible quantities only (Eq. 4) unless
            // the backend preformed it; the per-env normalizer winsorizes
            // heavy-tail spikes (its `clamp_lo`).
            self.reward_buf[e] = match s.reward {
                Some(r) => r,
                None => {
                    let raw = self.reward_form.raw(s.gpu_energy_j, s.core_util, s.uncore_util);
                    self.normalizers[e].normalize(raw)
                }
            };
            self.progress_buf[e] = s.progress;
            self.active_buf[e] = if s.active { 1.0 } else { 0.0 };
            if let Some(c) = &s.context {
                self.ctx[e * CONTEXT_DIM..(e + 1) * CONTEXT_DIM].copy_from_slice(c);
                self.has_ctx = true;
            }
        }
        self.driver.update_batch(&self.sel, &self.reward_buf, &self.progress_buf, &self.active_buf);

        for (e, s) in samples.iter().enumerate() {
            if !s.active {
                continue;
            }
            let arm = self.sel[e] as usize;
            let regret = self.mu_star[e] - self.envs[e].true_rewards[arm];
            self.cumulative_regret[e] += regret;
            self.cum_true_energy_j[e] += s.true_gpu_energy_j;

            // Progress checkpoints (row e of the (B, n_cp) grid).
            let completed = 1.0 - s.remaining;
            self.final_completed[e] = completed;
            let row = e * self.n_cp;
            while self.next_cp[e] < self.n_cp
                && completed >= (self.next_cp[e] + 1) as f64 / self.n_cp as f64 - 1e-12
            {
                self.checkpoints[row + self.next_cp[e]] = self.cum_true_energy_j[e];
                self.next_cp[e] += 1;
            }

            self.switch_rate[e].record(if s.switched { 1.0 } else { 0.0 });
            if s.switched {
                self.switch_counter[e].inc();
            }

            if let (Some(budget), Some(c)) = (self.qos_budget, &s.context) {
                self.qos_steps[e] += 1;
                if c[0] > budget {
                    self.qos_violations[e] += 1;
                }
            }

            if let Some(tr) = self.traces[e].as_mut() {
                tr.push(TraceStep {
                    t: self.t,
                    arm,
                    reward: self.reward_buf[e],
                    energy_j: s.true_gpu_energy_j,
                    regret,
                    switched: s.switched,
                });
            }
        }
    }

    /// Close the run: fill any remaining checkpoints (e.g. the run hit
    /// `max_steps`) and assemble one [`RunResult`] per environment from
    /// the backend's final accounting. The wall-clock decide-latency
    /// gauge measures the whole batched decision, so it is attached to
    /// row 0's telemetry only.
    pub fn finish(mut self, totals: &[BackendTotals]) -> Vec<RunResult> {
        assert_eq!(totals.len(), self.b, "one totals record per environment");
        let name = self.driver.name();
        let mut out = Vec::with_capacity(self.b);
        for e in 0..self.b {
            let row = e * self.n_cp;
            for i in self.next_cp[e]..self.n_cp {
                self.checkpoints[row + i] = self.cum_true_energy_j[e];
            }
            let mut telemetry = Recorder::new();
            telemetry.counter("controller.steps").add(self.t);
            telemetry
                .insert_counter("controller.switches", std::mem::take(&mut self.switch_counter[e]));
            telemetry
                .insert_gauge("controller.switch_rate", std::mem::take(&mut self.switch_rate[e]));
            if e == 0 && self.decide_latency_us.count() > 0 {
                telemetry
                    .insert_gauge("controller.decide_latency_us", self.decide_latency_us.clone());
            }
            let metrics = RunMetrics {
                app: std::mem::take(&mut self.envs[e].app),
                policy: name.clone(),
                gpu_energy_kj: totals[e].gpu_energy_kj,
                exec_time_s: totals[e].exec_time_s,
                switches: totals[e].switches,
                switch_energy_j: totals[e].switch_energy_j,
                switch_time_s: totals[e].switch_time_s,
                cumulative_regret: self.cumulative_regret[e],
                steps: self.t,
                completed: self.final_completed[e].clamp(0.0, 1.0),
                qos_violation_frac: match self.qos_budget {
                    Some(_) if self.qos_steps[e] > 0 => {
                        Some(self.qos_violations[e] as f64 / self.qos_steps[e] as f64)
                    }
                    _ => None,
                },
            };
            out.push(RunResult {
                metrics,
                trace: self.traces[e].take(),
                energy_checkpoints_j: self.checkpoints[row..row + self.n_cp].to_vec(),
                telemetry,
            });
        }
        out
    }
}

/// Drive a controller against a telemetry backend to completion: the one
/// loop every tier shares (`run_session` and the cluster worker at
/// B = 1, `fleet::policy_run` at B = N, `energyucb replay` and the sweep
/// tier over recordings). This is the only place the control tier reads
/// a clock — the per-decision latency gauge
/// (`controller.decide_latency_us`) lives here so the controller core
/// stays sans-IO.
pub fn drive(
    controller: Controller<'_>,
    backend: &mut dyn TelemetryBackend,
) -> anyhow::Result<Vec<RunResult>> {
    drive_hooked(controller, backend, &mut |_| {})
}

/// [`drive`] with a per-step observer: `on_step` runs after every
/// `observe`, with read access to the controller's live accounting
/// ([`Controller::steps`], [`Controller::completed`],
/// [`Controller::true_energy_j`], ...). This is how the cluster worker
/// emits heartbeats *during* the run instead of synthesizing them after
/// the fact. The hook cannot mutate the controller, so a hooked drive is
/// byte-identical to a plain [`drive`] — the hook only taps the stream.
pub fn drive_hooked(
    mut controller: Controller<'_>,
    backend: &mut dyn TelemetryBackend,
    on_step: &mut dyn FnMut(&Controller),
) -> anyhow::Result<Vec<RunResult>> {
    anyhow::ensure!(
        controller.b() == backend.b(),
        "controller batch B = {} does not match backend B = {}",
        controller.b(),
        backend.b()
    );
    anyhow::ensure!(
        controller.k() == backend.k(),
        "controller arity K = {} does not match backend K = {}",
        controller.k(),
        backend.k()
    );
    let mut samples = vec![StepSample::default(); controller.b()];
    while !backend.done() && controller.wants_step() {
        // The latency gauge samples every 64th decision: statistically
        // meaningful without paying two clock reads on every iteration
        // of a loop that is otherwise allocation- and syscall-free.
        let timed = controller.steps() & 63 == 0;
        let t0 = timed.then(std::time::Instant::now);
        controller.decide();
        if let Some(t0) = t0 {
            controller.record_decide_latency_us(t0.elapsed().as_secs_f64() * 1e6);
        }
        backend.apply(controller.selections())?;
        backend.sample_into(&mut samples)?;
        controller.observe(&samples);
        on_step(&controller);
    }
    let totals = backend.totals();
    Ok(controller.finish(&totals))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::batch::BatchUcb1;
    use crate::bandit::{RoundRobin, StaticPolicy};
    use crate::workload::calibration;

    fn sample(progress: f64, remaining: f64, switched: bool) -> StepSample {
        StepSample {
            gpu_energy_j: 25.0,
            core_util: 0.9,
            uncore_util: 0.45,
            progress,
            remaining,
            true_gpu_energy_j: 24.0,
            switched,
            ..StepSample::default()
        }
    }

    /// Hand-feed a synthetic sample stream: the controller is fully
    /// exercisable without any backend (the sans-IO acceptance check).
    #[test]
    fn controller_steps_without_any_backend() {
        let app = calibration::app("tealeaf").unwrap();
        let cfg = SessionCfg { checkpoints: 4, record_trace: true, ..SessionCfg::default() };
        let mut policy = RoundRobin::new(9);
        let mut c = Controller::new(&app, &mut policy, &cfg);
        let n = 10u64;
        for i in 0..n {
            assert!(c.wants_step());
            c.decide();
            assert!((c.selections()[0] as usize) < 9);
            let remaining = 1.0 - (i + 1) as f64 / n as f64;
            c.observe(&[sample(1.0 / n as f64, remaining, i > 0)]);
        }
        assert_eq!(c.steps(), n);
        let res = c
            .finish(&[BackendTotals {
                gpu_energy_kj: 0.24,
                exec_time_s: 0.1,
                switches: n - 1,
                switch_energy_j: 0.3 * (n - 1) as f64,
                switch_time_s: 150e-6 * (n - 1) as f64,
            }])
            .pop()
            .unwrap();
        assert_eq!(res.metrics.steps, n);
        assert_eq!(res.metrics.switches, n - 1);
        assert!((res.metrics.completed - 1.0).abs() < 1e-12);
        // Checkpoints: 24 J per step, 4 checkpoints over 10 steps.
        assert_eq!(res.energy_checkpoints_j.len(), 4);
        assert!((res.energy_checkpoints_j[3] - 240.0).abs() < 1e-9);
        assert!(res.energy_checkpoints_j.windows(2).all(|w| w[1] >= w[0]));
        // Trace recorded every step.
        assert_eq!(res.trace.unwrap().len(), n as usize);
        // Switch-rate gauge: 9 of 10 intervals switched.
        let rate = res.telemetry.gauge_mean("controller.switch_rate").unwrap();
        assert!((rate - 0.9).abs() < 1e-12, "{rate}");
        assert_eq!(res.telemetry.counter_value("controller.switches"), Some(n - 1));
        assert_eq!(res.telemetry.counter_value("controller.steps"), Some(n));
    }

    #[test]
    fn step_budget_is_enforced_by_wants_step() {
        let app = calibration::app("clvleaf").unwrap();
        let cfg = SessionCfg { max_steps: 3, ..SessionCfg::default() };
        let mut policy = StaticPolicy::new(9, 8);
        let mut c = Controller::new(&app, &mut policy, &cfg);
        let mut steps = 0;
        while c.wants_step() {
            c.decide();
            c.observe(&[sample(1e-4, 1.0 - 1e-4 * (steps + 1) as f64, false)]);
            steps += 1;
        }
        assert_eq!(steps, 3);
        let res = c.finish(&[BackendTotals::default()]).pop().unwrap();
        assert_eq!(res.metrics.steps, 3);
        assert!(res.metrics.completed < 1.0);
    }

    #[test]
    fn regret_accounting_matches_ground_truth() {
        let app = calibration::app("clvleaf").unwrap();
        let cfg = SessionCfg::default();
        let freqs = cfg.domain();
        let true_rewards: Vec<f64> =
            (0..9).map(|i| app.true_reward(&freqs, i, cfg.dt_s)).collect();
        let mu_star = true_rewards.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut policy = StaticPolicy::new(9, 0);
        let mut c = Controller::new(&app, &mut policy, &cfg);
        for i in 0..5 {
            c.decide();
            assert_eq!(c.selections(), &[0]);
            c.observe(&[sample(1e-4, 1.0 - 1e-4 * (i + 1) as f64, i == 0)]);
        }
        let expected = 5.0 * (mu_star - true_rewards[0]);
        assert!((c.cumulative_regret() - expected).abs() < 1e-12);
    }

    /// Batch semantics: per-row accounting is independent, and inactive
    /// rows are frozen (no regret, no energy, no checkpoints, no trace).
    #[test]
    fn batch_rows_account_independently_and_inactive_rows_freeze() {
        let envs = vec![
            EnvSpec { app: "a".into(), true_rewards: vec![-1.0, -0.5, -2.0] },
            EnvSpec { app: "b".into(), true_rewards: vec![-0.25, -1.5, -0.75] },
        ];
        let driver = Box::new(BatchUcb1::new(2, 3, 0.05));
        let opts = BatchOpts {
            reward_form: RewardForm::EnergyRatio,
            max_steps: 100,
            record_trace: true,
            checkpoints: 2,
            feasible: None,
        };
        let mut c = Controller::new_batch(envs, driver, &opts);
        assert_eq!(c.b(), 2);
        assert_eq!(c.k(), 3);
        // Env 1 goes inactive after 2 steps; env 0 runs 4.
        for i in 0..4u64 {
            c.decide();
            let active1 = i < 2;
            c.observe(&[
                StepSample {
                    true_gpu_energy_j: 10.0,
                    progress: 0.25,
                    remaining: 1.0 - 0.25 * (i + 1) as f64,
                    ..StepSample::default()
                },
                StepSample {
                    true_gpu_energy_j: 7.0,
                    progress: if active1 { 0.5 } else { 0.0 },
                    remaining: if active1 { 1.0 - 0.5 * (i + 1) as f64 } else { 0.0 },
                    active: active1,
                    ..StepSample::default()
                },
            ]);
        }
        let res = c.finish(&[BackendTotals::default(), BackendTotals::default()]);
        assert_eq!(res.len(), 2);
        assert_eq!(res[0].metrics.app, "a");
        assert_eq!(res[1].metrics.app, "b");
        // Both rows report the shared step counter...
        assert_eq!(res[0].metrics.steps, 4);
        assert_eq!(res[1].metrics.steps, 4);
        // ...but row 1's accounting froze after its 2 active intervals.
        assert_eq!(res[1].trace.as_ref().unwrap().len(), 2);
        assert_eq!(res[0].trace.as_ref().unwrap().len(), 4);
        assert!((res[1].metrics.completed - 1.0).abs() < 1e-12);
        // Checkpoint rows are independent: env 1 banked 7 J per active
        // step, env 0 banked 10 J per step.
        assert_eq!(res[1].energy_checkpoints_j, vec![7.0, 14.0]);
        assert_eq!(res[0].energy_checkpoints_j, vec![20.0, 40.0]);
    }

    /// The regret baseline respects the feasibility mask: masked-out arms
    /// cannot define the per-env optimum.
    #[test]
    fn regret_baseline_is_the_best_feasible_arm() {
        let envs =
            vec![EnvSpec { app: "a".into(), true_rewards: vec![-0.1, -0.5, -1.0] }];
        let driver = Box::new(BatchUcb1::new(1, 3, 0.05));
        let opts = BatchOpts {
            reward_form: RewardForm::EnergyRatio,
            max_steps: 10,
            record_trace: false,
            checkpoints: 0,
            // Arm 0 (the global optimum) is infeasible.
            feasible: Some(vec![0.0, 1.0, 1.0]),
        };
        let mut c = Controller::new_batch(envs, driver, &opts);
        c.decide();
        let arm = c.selections()[0] as usize;
        assert!(arm == 1 || arm == 2, "mask must exclude arm 0, got {arm}");
        c.observe(&[StepSample { progress: 0.1, remaining: 0.9, ..StepSample::default() }]);
        // mu_star = -0.5 (best feasible), so picking arm 1 is zero regret.
        let expected = if arm == 1 { 0.0 } else { 0.5 };
        assert!((c.cumulative_regret() - expected).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "policy arity")]
    fn mismatched_arity_is_rejected() {
        let app = calibration::app("tealeaf").unwrap();
        let mut policy = StaticPolicy::new(4, 0);
        let _ = Controller::new(&app, &mut policy, &SessionCfg::default());
    }

    #[test]
    #[should_panic(expected = "policy batch")]
    fn mismatched_batch_is_rejected() {
        let envs = vec![EnvSpec { app: "a".into(), true_rewards: vec![0.0; 3] }];
        let driver = Box::new(BatchUcb1::new(2, 3, 0.05));
        let opts = BatchOpts {
            reward_form: RewardForm::EnergyRatio,
            max_steps: 10,
            record_trace: false,
            checkpoints: 0,
            feasible: None,
        };
        let _ = Controller::new_batch(envs, driver, &opts);
    }
}
