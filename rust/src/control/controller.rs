//! The sans-IO control core: a pure decision/observation step machine.
//!
//! [`Controller`] is everything that used to live inline in
//! `run_session`'s loop between `service.sample()` and the policy update —
//! the B = 1 [`Scalar`] policy bridge, reward formation and
//! winsorized normalization, ground-truth regret accounting, progress
//! checkpoints, and trace bookkeeping — with no clock, no I/O, and no
//! knowledge of where telemetry comes from. Drivers own the loop:
//! [`drive`] pairs a controller with any
//! [`TelemetryBackend`][super::backend::TelemetryBackend] (live
//! simulation, recorded trace replay, a future NVML/GEOPM binding) and is
//! the only place wall-clock time is read (the decision-latency gauge).
//!
//! The protocol per decision interval is strict alternation:
//! `decide() -> arm`, apply the arm through the backend, sample the
//! backend, `observe(sample)`. `finish(totals)` consumes the controller
//! and yields the [`RunResult`]. Determinism contract: for a fixed
//! policy state and sample stream, every controller output —
//! selections, metrics, checkpoints, trace — is a pure function of the
//! inputs (EXPERIMENTS.md §Controller).

use crate::bandit::batch::{BatchPolicy, Scalar};
use crate::bandit::{Policy, RewardForm, RewardNormalizer};
use crate::telemetry::{Counter, Gauge, Recorder};
use crate::workload::model::AppModel;
use crate::workload::trace::{Trace, TraceStep};

use super::backend::TelemetryBackend;
use super::metrics::RunMetrics;
use super::session::{RunResult, SessionCfg};

/// One decision interval's telemetry, backend-agnostic: the
/// counter-visible quantities the controller consumes (plus the
/// ground-truth energy used only for metrics, never shown to the policy).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepSample {
    /// Measured (noisy) GPU energy over the interval, Joules.
    pub gpu_energy_j: f64,
    /// Aggregate core-engine utilization in [0, 1].
    pub core_util: f64,
    /// Aggregate uncore (copy-engine) utilization in [0, 1].
    pub uncore_util: f64,
    /// Progress made this interval (fraction of the whole job).
    pub progress: f64,
    /// Remaining work (1 → 0).
    pub remaining: f64,
    /// True GPU energy this interval (ground truth, metrics only).
    pub true_gpu_energy_j: f64,
    /// Whether the interval performed a frequency transition.
    pub switched: bool,
}

/// End-of-run accounting a backend must provide (the `RunMetrics` fields
/// the controller cannot derive from per-step samples alone without
/// re-accumulating rounding differences).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BackendTotals {
    pub gpu_energy_kj: f64,
    pub exec_time_s: f64,
    pub switches: u64,
    pub switch_energy_j: f64,
    pub switch_time_s: f64,
}

/// The sans-IO controller for one session (see module docs).
pub struct Controller<'p> {
    driver: Scalar<&'p mut dyn Policy>,
    all_feasible: Vec<f32>,
    sel: [i32; 1],
    normalizer: RewardNormalizer,
    reward_form: RewardForm,
    max_steps: u64,
    trace: Option<Trace>,
    app_name: String,
    /// Ground truth for regret accounting (raw reward units;
    /// simulation-only knowledge, never shown to the policy).
    true_rewards: Vec<f64>,
    mu_star: f64,
    t: u64,
    cumulative_regret: f64,
    cum_true_energy_j: f64,
    final_completed: f64,
    checkpoints: Vec<f64>,
    next_cp: usize,
    // Operational telemetry accumulates in plain fields (a `Recorder`
    // name lookup allocates per call — the hot loop stays
    // allocation-free) and is merged into the `RunResult` Recorder once
    // in `finish`.
    switch_rate: Gauge,
    switch_counter: Counter,
    decide_latency_us: Gauge,
}

impl<'p> Controller<'p> {
    /// Bind a policy to an app's session configuration. The frequency
    /// domain comes from `cfg` ([`SessionCfg::domain`]); the policy's
    /// arity and the app's calibration table must both match it.
    pub fn new(app: &AppModel, policy: &'p mut dyn Policy, cfg: &SessionCfg) -> Controller<'p> {
        let freqs = cfg.domain();
        assert_eq!(policy.k(), freqs.k(), "policy arity must match frequency domain");
        assert_eq!(
            app.energy_kj.len(),
            freqs.k(),
            "app calibration table must match frequency domain"
        );
        let k = freqs.k();
        let true_rewards: Vec<f64> =
            (0..k).map(|i| app.true_reward(&freqs, i, cfg.dt_s)).collect();
        let mu_star = true_rewards.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Controller {
            // B = 1 bridge onto the shared batch stepping core. The
            // feasibility buffer is all-ones (the bridge delegates
            // feasibility to the wrapped policy); selection/reward
            // buffers live inline — no per-step allocations.
            driver: Scalar::new(vec![policy]),
            all_feasible: vec![1.0f32; k],
            sel: [0i32; 1],
            normalizer: RewardNormalizer::new(),
            reward_form: cfg.reward_form,
            max_steps: cfg.max_steps,
            trace: cfg.record_trace.then(Trace::new),
            app_name: app.name.to_string(),
            true_rewards,
            mu_star,
            t: 0,
            cumulative_regret: 0.0,
            cum_true_energy_j: 0.0,
            final_completed: 0.0,
            checkpoints: vec![0.0f64; cfg.checkpoints],
            next_cp: 0,
            switch_rate: Gauge::default(),
            switch_counter: Counter::default(),
            decide_latency_us: Gauge::default(),
        }
    }

    /// Decision steps taken so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// Whether the step budget allows another decision.
    pub fn wants_step(&self) -> bool {
        self.t < self.max_steps
    }

    /// Cumulative ground-truth regret so far (raw reward units).
    pub fn cumulative_regret(&self) -> f64 {
        self.cumulative_regret
    }

    /// Record one decision's wall-clock latency (µs). Called by drivers
    /// ([`drive`]) — the controller itself never reads a clock.
    pub fn record_decide_latency_us(&mut self, us: f64) {
        self.decide_latency_us.record(us);
    }

    /// Choose the arm for the next decision interval.
    pub fn decide(&mut self) -> usize {
        self.t += 1;
        self.driver.select_into(self.t, &self.all_feasible, &mut self.sel);
        self.sel[0] as usize
    }

    /// Feed back the interval's telemetry for the arm chosen by the last
    /// [`decide`](Self::decide).
    pub fn observe(&mut self, s: &StepSample) {
        let arm = self.sel[0] as usize;
        // Reward from counter-visible quantities only (Eq. 4); the
        // normalizer winsorizes heavy-tail spikes (its `clamp_lo`).
        let raw = self.reward_form.raw(s.gpu_energy_j, s.core_util, s.uncore_util);
        let reward = self.normalizer.normalize(raw);
        self.driver.update_batch(&self.sel, &[reward], &[s.progress], &[1.0]);

        self.cumulative_regret += self.mu_star - self.true_rewards[arm];
        self.cum_true_energy_j += s.true_gpu_energy_j;

        // Progress checkpoints.
        let completed = 1.0 - s.remaining;
        self.final_completed = completed;
        let n_cp = self.checkpoints.len();
        while self.next_cp < n_cp
            && completed >= (self.next_cp + 1) as f64 / n_cp as f64 - 1e-12
        {
            self.checkpoints[self.next_cp] = self.cum_true_energy_j;
            self.next_cp += 1;
        }

        self.switch_rate.record(if s.switched { 1.0 } else { 0.0 });
        if s.switched {
            self.switch_counter.inc();
        }

        if let Some(tr) = self.trace.as_mut() {
            tr.push(TraceStep {
                t: self.t,
                arm,
                reward,
                energy_j: s.true_gpu_energy_j,
                regret: self.mu_star - self.true_rewards[arm],
                switched: s.switched,
            });
        }
    }

    /// Close the session: fill any remaining checkpoints (e.g. the run
    /// hit `max_steps`) and assemble the [`RunResult`] from the backend's
    /// final accounting.
    pub fn finish(mut self, totals: BackendTotals) -> RunResult {
        for cp in self.checkpoints.iter_mut().skip(self.next_cp) {
            *cp = self.cum_true_energy_j;
        }
        let mut telemetry = Recorder::new();
        telemetry.counter("controller.steps").add(self.t);
        telemetry.insert_counter("controller.switches", self.switch_counter);
        telemetry.insert_gauge("controller.switch_rate", self.switch_rate);
        if self.decide_latency_us.count() > 0 {
            telemetry.insert_gauge("controller.decide_latency_us", self.decide_latency_us);
        }
        let metrics = RunMetrics {
            app: self.app_name,
            policy: self.driver.name(),
            gpu_energy_kj: totals.gpu_energy_kj,
            exec_time_s: totals.exec_time_s,
            switches: totals.switches,
            switch_energy_j: totals.switch_energy_j,
            switch_time_s: totals.switch_time_s,
            cumulative_regret: self.cumulative_regret,
            steps: self.t,
            completed: self.final_completed.clamp(0.0, 1.0),
        };
        RunResult { metrics, trace: self.trace, energy_checkpoints_j: self.checkpoints, telemetry }
    }
}

/// Drive a controller against a telemetry backend to completion: the one
/// loop every session surface shares (`run_session`, the cluster worker,
/// `energyucb replay`). This is the only place the session tier reads a
/// clock — the per-decision latency gauge
/// (`controller.decide_latency_us`) lives here so the controller core
/// stays sans-IO.
pub fn drive(
    mut controller: Controller<'_>,
    backend: &mut dyn TelemetryBackend,
) -> anyhow::Result<RunResult> {
    while !backend.done() && controller.wants_step() {
        // The latency gauge samples every 64th decision: statistically
        // meaningful without paying two clock reads on every iteration
        // of a loop that is otherwise allocation- and syscall-free.
        let timed = controller.steps() & 63 == 0;
        let t0 = timed.then(std::time::Instant::now);
        let arm = controller.decide();
        if let Some(t0) = t0 {
            controller.record_decide_latency_us(t0.elapsed().as_secs_f64() * 1e6);
        }
        backend.apply(arm)?;
        let sample = backend.sample()?;
        controller.observe(&sample);
    }
    Ok(controller.finish(backend.totals()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bandit::{RoundRobin, StaticPolicy};
    use crate::workload::calibration;

    fn sample(progress: f64, remaining: f64, switched: bool) -> StepSample {
        StepSample {
            gpu_energy_j: 25.0,
            core_util: 0.9,
            uncore_util: 0.45,
            progress,
            remaining,
            true_gpu_energy_j: 24.0,
            switched,
        }
    }

    /// Hand-feed a synthetic sample stream: the controller is fully
    /// exercisable without any backend (the sans-IO acceptance check).
    #[test]
    fn controller_steps_without_any_backend() {
        let app = calibration::app("tealeaf").unwrap();
        let cfg = SessionCfg { checkpoints: 4, record_trace: true, ..SessionCfg::default() };
        let mut policy = RoundRobin::new(9);
        let mut c = Controller::new(&app, &mut policy, &cfg);
        let n = 10u64;
        for i in 0..n {
            assert!(c.wants_step());
            let arm = c.decide();
            assert!(arm < 9);
            let remaining = 1.0 - (i + 1) as f64 / n as f64;
            c.observe(&sample(1.0 / n as f64, remaining, i > 0));
        }
        assert_eq!(c.steps(), n);
        let res = c.finish(BackendTotals {
            gpu_energy_kj: 0.24,
            exec_time_s: 0.1,
            switches: n - 1,
            switch_energy_j: 0.3 * (n - 1) as f64,
            switch_time_s: 150e-6 * (n - 1) as f64,
        });
        assert_eq!(res.metrics.steps, n);
        assert_eq!(res.metrics.switches, n - 1);
        assert!((res.metrics.completed - 1.0).abs() < 1e-12);
        // Checkpoints: 24 J per step, 4 checkpoints over 10 steps.
        assert_eq!(res.energy_checkpoints_j.len(), 4);
        assert!((res.energy_checkpoints_j[3] - 240.0).abs() < 1e-9);
        assert!(res.energy_checkpoints_j.windows(2).all(|w| w[1] >= w[0]));
        // Trace recorded every step.
        assert_eq!(res.trace.unwrap().len(), n as usize);
        // Switch-rate gauge: 9 of 10 intervals switched.
        let rate = res.telemetry.gauge_mean("controller.switch_rate").unwrap();
        assert!((rate - 0.9).abs() < 1e-12, "{rate}");
        assert_eq!(res.telemetry.counter_value("controller.switches"), Some(n - 1));
        assert_eq!(res.telemetry.counter_value("controller.steps"), Some(n));
    }

    #[test]
    fn step_budget_is_enforced_by_wants_step() {
        let app = calibration::app("clvleaf").unwrap();
        let cfg = SessionCfg { max_steps: 3, ..SessionCfg::default() };
        let mut policy = StaticPolicy::new(9, 8);
        let mut c = Controller::new(&app, &mut policy, &cfg);
        let mut steps = 0;
        while c.wants_step() {
            c.decide();
            c.observe(&sample(1e-4, 1.0 - 1e-4 * (steps + 1) as f64, false));
            steps += 1;
        }
        assert_eq!(steps, 3);
        let res = c.finish(BackendTotals::default());
        assert_eq!(res.metrics.steps, 3);
        assert!(res.metrics.completed < 1.0);
    }

    #[test]
    fn regret_accounting_matches_ground_truth() {
        let app = calibration::app("clvleaf").unwrap();
        let cfg = SessionCfg::default();
        let freqs = cfg.domain();
        let true_rewards: Vec<f64> =
            (0..9).map(|i| app.true_reward(&freqs, i, cfg.dt_s)).collect();
        let mu_star = true_rewards.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut policy = StaticPolicy::new(9, 0);
        let mut c = Controller::new(&app, &mut policy, &cfg);
        for i in 0..5 {
            assert_eq!(c.decide(), 0);
            c.observe(&sample(1e-4, 1.0 - 1e-4 * (i + 1) as f64, i == 0));
        }
        let expected = 5.0 * (mu_star - true_rewards[0]);
        assert!((c.cumulative_regret() - expected).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "policy arity")]
    fn mismatched_arity_is_rejected() {
        let app = calibration::app("tealeaf").unwrap();
        let mut policy = StaticPolicy::new(4, 0);
        let _ = Controller::new(&app, &mut policy, &SessionCfg::default());
    }
}
