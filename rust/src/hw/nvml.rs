//! Dynamically-loaded libnvidia-ml binding (feature `nvml`).
//!
//! The binding dlopen's `libnvidia-ml.so.1` at *runtime* — there is no
//! link-time dependency, so `cargo build --features nvml` succeeds on a
//! GPU-less host and only [`NvmlDriver::open`] reports whether the
//! library (and a device) is actually present. Symbols are resolved
//! individually; a missing one is a [`DriverError::NotLoaded`] with the
//! symbol name, never a crash.
//!
//! Counter mapping (see [`DeviceCounters`]):
//!
//! * `nvmlDeviceGetTotalEnergyConsumption` (mJ) → `energy_j`
//! * `nvmlDeviceGetPowerUsage` (mW) → `power_w`
//! * `nvmlDeviceGetUtilizationRates` → `core_util` (`.gpu`) and
//!   `uncore_util` (`.memory`, the copy-engine proxy)
//! * `nvmlDeviceGetClockInfo(NVML_CLOCK_SM)` → `sm_mhz`
//! * active-time signals are integrated driver-side (`util × Δt`)
//! * `progress` / `cpu_energy_j` have no NVML source and read 0.0
//!
//! Clock control uses `nvmlDeviceSetGpuLockedClocks` /
//! `nvmlDeviceResetGpuLockedClocks` — the same capability
//! `nvidia-smi -lgc` needs; without it the driver returns
//! [`DriverError::NoPermission`] and the backend's watchdog degrades
//! the row instead of crashing.
//!
//! `wall_pacing` is `true`: NVML counters integrate wall time, so the
//! backend sleeps one decision interval between reads.

use std::ffi::CStr;
use std::os::raw::{c_char, c_int, c_uint, c_ulonglong, c_void};
use std::time::Instant;

use super::driver::{DeviceCounters, DeviceInfo, DriverError, GpuDriver};

const RTLD_NOW: c_int = 2;

extern "C" {
    fn dlopen(filename: *const c_char, flags: c_int) -> *mut c_void;
    fn dlsym(handle: *mut c_void, symbol: *const c_char) -> *mut c_void;
    fn dlclose(handle: *mut c_void) -> c_int;
}

/// `nvmlUtilization_t`.
#[repr(C)]
#[derive(Clone, Copy, Default)]
struct NvmlUtilization {
    gpu: c_uint,
    memory: c_uint,
}

/// `nvmlClockType_t` NVML_CLOCK_SM.
const NVML_CLOCK_SM: c_int = 1;

type NvmlDevice = *mut c_void;

type InitFn = unsafe extern "C" fn() -> c_int;
type ShutdownFn = unsafe extern "C" fn() -> c_int;
type GetCountFn = unsafe extern "C" fn(*mut c_uint) -> c_int;
type GetHandleFn = unsafe extern "C" fn(c_uint, *mut NvmlDevice) -> c_int;
type GetNameFn = unsafe extern "C" fn(NvmlDevice, *mut c_char, c_uint) -> c_int;
type SupportedMemClocksFn = unsafe extern "C" fn(NvmlDevice, *mut c_uint, *mut c_uint) -> c_int;
type SupportedGfxClocksFn =
    unsafe extern "C" fn(NvmlDevice, c_uint, *mut c_uint, *mut c_uint) -> c_int;
type SetLockedFn = unsafe extern "C" fn(NvmlDevice, c_uint, c_uint) -> c_int;
type ResetLockedFn = unsafe extern "C" fn(NvmlDevice) -> c_int;
type EnergyFn = unsafe extern "C" fn(NvmlDevice, *mut c_ulonglong) -> c_int;
type MilliwattFn = unsafe extern "C" fn(NvmlDevice, *mut c_uint) -> c_int;
type UtilFn = unsafe extern "C" fn(NvmlDevice, *mut NvmlUtilization) -> c_int;
type ClockInfoFn = unsafe extern "C" fn(NvmlDevice, c_int, *mut c_uint) -> c_int;

/// Map an `nvmlReturn_t` status to a [`DriverError`] (success → `Ok`).
fn check(code: c_int, call: &'static str, dev: usize) -> Result<(), DriverError> {
    match code {
        0 => Ok(()),
        2 => Err(DriverError::InvalidArgument(format!("{call} (device {dev})"))),
        3 => Err(DriverError::NotSupported(format!("{call} (device {dev})"))),
        4 => Err(DriverError::NoPermission(format!(
            "{call} needs the clock-management capability (the privilege `nvidia-smi -lgc` uses)"
        ))),
        15 => Err(DriverError::DeviceLost { device: dev }),
        code => Err(DriverError::Api { call, code }),
    }
}

macro_rules! sym {
    ($handle:expr, $name:literal, $ty:ty) => {{
        let p = dlsym($handle, concat!($name, "\0").as_ptr() as *const c_char);
        if p.is_null() {
            dlclose($handle);
            return Err(DriverError::NotLoaded(concat!(
                "libnvidia-ml: missing symbol ",
                $name
            )
            .into()));
        }
        std::mem::transmute::<*mut c_void, $ty>(p)
    }};
}

struct Lib {
    handle: *mut c_void,
    init: InitFn,
    shutdown: ShutdownFn,
    device_count: GetCountFn,
    device_handle: GetHandleFn,
    device_name: GetNameFn,
    supported_mem_clocks: SupportedMemClocksFn,
    supported_gfx_clocks: SupportedGfxClocksFn,
    set_locked: SetLockedFn,
    reset_locked: ResetLockedFn,
    total_energy: EnergyFn,
    power_usage: MilliwattFn,
    power_limit: MilliwattFn,
    utilization: UtilFn,
    clock_info: ClockInfoFn,
}

impl Lib {
    /// dlopen the library and resolve every symbol the driver uses.
    ///
    /// # Safety
    /// Trusts that a library named libnvidia-ml exposes the NVML ABI.
    unsafe fn load() -> Result<Lib, DriverError> {
        let mut handle = std::ptr::null_mut();
        for name in ["libnvidia-ml.so.1\0", "libnvidia-ml.so\0"] {
            handle = dlopen(name.as_ptr() as *const c_char, RTLD_NOW);
            if !handle.is_null() {
                break;
            }
        }
        if handle.is_null() {
            return Err(DriverError::NotLoaded(
                "libnvidia-ml.so not found (is the NVIDIA driver installed?)".into(),
            ));
        }
        Ok(Lib {
            handle,
            init: sym!(handle, "nvmlInit_v2", InitFn),
            shutdown: sym!(handle, "nvmlShutdown", ShutdownFn),
            device_count: sym!(handle, "nvmlDeviceGetCount_v2", GetCountFn),
            device_handle: sym!(handle, "nvmlDeviceGetHandleByIndex_v2", GetHandleFn),
            device_name: sym!(handle, "nvmlDeviceGetName", GetNameFn),
            supported_mem_clocks: sym!(
                handle,
                "nvmlDeviceGetSupportedMemoryClocks",
                SupportedMemClocksFn
            ),
            supported_gfx_clocks: sym!(
                handle,
                "nvmlDeviceGetSupportedGraphicsClocks",
                SupportedGfxClocksFn
            ),
            set_locked: sym!(handle, "nvmlDeviceSetGpuLockedClocks", SetLockedFn),
            reset_locked: sym!(handle, "nvmlDeviceResetGpuLockedClocks", ResetLockedFn),
            total_energy: sym!(handle, "nvmlDeviceGetTotalEnergyConsumption", EnergyFn),
            power_usage: sym!(handle, "nvmlDeviceGetPowerUsage", MilliwattFn),
            power_limit: sym!(handle, "nvmlDeviceGetPowerManagementLimit", MilliwattFn),
            utilization: sym!(handle, "nvmlDeviceGetUtilizationRates", UtilFn),
            clock_info: sym!(handle, "nvmlDeviceGetClockInfo", ClockInfoFn),
        })
    }
}

impl Drop for Lib {
    fn drop(&mut self) {
        unsafe {
            dlclose(self.handle);
        }
    }
}

/// Per-device active-time integrator (NVML exposes instantaneous
/// utilization only; GEOPM's active-time signals are `∫ util dt`).
#[derive(Clone, Copy, Default)]
struct Accum {
    last_t: f64,
    core_active_s: f64,
    uncore_active_s: f64,
}

/// The live NVML driver (see module docs).
pub struct NvmlDriver {
    lib: Lib,
    devices: Vec<NvmlDevice>,
    start: Instant,
    accum: Vec<Accum>,
}

impl NvmlDriver {
    /// dlopen libnvidia-ml, initialize NVML, and enumerate devices.
    pub fn open() -> Result<NvmlDriver, DriverError> {
        let lib = unsafe { Lib::load()? };
        check(unsafe { (lib.init)() }, "nvmlInit_v2", 0)?;
        let mut count: c_uint = 0;
        check(unsafe { (lib.device_count)(&mut count) }, "nvmlDeviceGetCount_v2", 0)?;
        let mut devices = Vec::with_capacity(count as usize);
        for i in 0..count {
            let mut h: NvmlDevice = std::ptr::null_mut();
            check(
                unsafe { (lib.device_handle)(i, &mut h) },
                "nvmlDeviceGetHandleByIndex_v2",
                i as usize,
            )?;
            devices.push(h);
        }
        let n = devices.len();
        Ok(NvmlDriver { lib, devices, start: Instant::now(), accum: vec![Accum::default(); n] })
    }

    fn dev(&self, dev: usize) -> Result<NvmlDevice, DriverError> {
        self.devices.get(dev).copied().ok_or_else(|| {
            DriverError::InvalidArgument(format!("device {dev} of {}", self.devices.len()))
        })
    }
}

impl Drop for NvmlDriver {
    fn drop(&mut self) {
        // Shutdown before the Lib field drops (which dlcloses).
        unsafe {
            (self.lib.shutdown)();
        }
    }
}

impl GpuDriver for NvmlDriver {
    fn name(&self) -> &'static str {
        "nvml"
    }

    fn device_count(&self) -> Result<usize, DriverError> {
        Ok(self.devices.len())
    }

    fn device_info(&self, dev: usize) -> Result<DeviceInfo, DriverError> {
        let h = self.dev(dev)?;
        let mut buf = [0 as c_char; 96];
        check(
            unsafe { (self.lib.device_name)(h, buf.as_mut_ptr(), buf.len() as c_uint) },
            "nvmlDeviceGetName",
            dev,
        )?;
        let name = unsafe { CStr::from_ptr(buf.as_ptr()) }.to_string_lossy().into_owned();
        let mut limit_mw: c_uint = 0;
        check(
            unsafe { (self.lib.power_limit)(h, &mut limit_mw) },
            "nvmlDeviceGetPowerManagementLimit",
            dev,
        )?;
        let clocks = self.supported_core_clocks_mhz(dev)?;
        Ok(DeviceInfo {
            index: dev,
            name,
            min_core_mhz: *clocks.first().unwrap(),
            max_core_mhz: *clocks.last().unwrap(),
            power_limit_w: limit_mw as f64 / 1000.0,
        })
    }

    fn supported_core_clocks_mhz(&self, dev: usize) -> Result<Vec<u32>, DriverError> {
        let h = self.dev(dev)?;
        let mut mem_n: c_uint = 128;
        let mut mem = [0 as c_uint; 128];
        check(
            unsafe { (self.lib.supported_mem_clocks)(h, &mut mem_n, mem.as_mut_ptr()) },
            "nvmlDeviceGetSupportedMemoryClocks",
            dev,
        )?;
        if mem_n == 0 {
            return Err(DriverError::Counter {
                device: dev,
                reason: "no supported memory clocks reported".into(),
            });
        }
        // Graphics clocks are enumerated per memory clock; take the
        // highest memory clock's set (the normal operating point).
        let top_mem = mem[..mem_n as usize].iter().copied().max().unwrap();
        let mut gfx_n: c_uint = 512;
        let mut gfx = [0 as c_uint; 512];
        check(
            unsafe { (self.lib.supported_gfx_clocks)(h, top_mem, &mut gfx_n, gfx.as_mut_ptr()) },
            "nvmlDeviceGetSupportedGraphicsClocks",
            dev,
        )?;
        let mut clocks: Vec<u32> = gfx[..gfx_n as usize].to_vec();
        clocks.sort_unstable();
        clocks.dedup();
        if clocks.is_empty() {
            return Err(DriverError::Counter {
                device: dev,
                reason: "no supported graphics clocks reported".into(),
            });
        }
        Ok(clocks)
    }

    fn set_locked_clocks(&mut self, dev: usize, mhz: u32) -> Result<u32, DriverError> {
        let h = self.dev(dev)?;
        check(
            unsafe { (self.lib.set_locked)(h, mhz, mhz) },
            "nvmlDeviceSetGpuLockedClocks",
            dev,
        )?;
        // NVML accepts the request silently; the backend snapped `mhz`
        // to the supported list already, so report it as applied.
        Ok(mhz)
    }

    fn reset_locked_clocks(&mut self, dev: usize) -> Result<(), DriverError> {
        let h = self.dev(dev)?;
        check(
            unsafe { (self.lib.reset_locked)(h) },
            "nvmlDeviceResetGpuLockedClocks",
            dev,
        )
    }

    fn read_counters(&mut self, dev: usize) -> Result<DeviceCounters, DriverError> {
        let h = self.dev(dev)?;
        let mut energy_mj: c_ulonglong = 0;
        check(
            unsafe { (self.lib.total_energy)(h, &mut energy_mj) },
            "nvmlDeviceGetTotalEnergyConsumption",
            dev,
        )?;
        let mut power_mw: c_uint = 0;
        check(
            unsafe { (self.lib.power_usage)(h, &mut power_mw) },
            "nvmlDeviceGetPowerUsage",
            dev,
        )?;
        let mut util = NvmlUtilization::default();
        check(
            unsafe { (self.lib.utilization)(h, &mut util) },
            "nvmlDeviceGetUtilizationRates",
            dev,
        )?;
        let mut sm: c_uint = 0;
        check(
            unsafe { (self.lib.clock_info)(h, NVML_CLOCK_SM, &mut sm) },
            "nvmlDeviceGetClockInfo",
            dev,
        )?;
        let t = self.start.elapsed().as_secs_f64();
        let core_util = (util.gpu as f64 / 100.0).clamp(0.0, 1.0);
        let uncore_util = (util.memory as f64 / 100.0).clamp(0.0, 1.0);
        let a = &mut self.accum[dev];
        let dt = (t - a.last_t).max(0.0);
        a.last_t = t;
        a.core_active_s += core_util * dt;
        a.uncore_active_s += uncore_util * dt;
        Ok(DeviceCounters {
            timestamp_s: t,
            energy_j: energy_mj as f64 / 1000.0,
            power_w: power_mw as f64 / 1000.0,
            sm_mhz: sm,
            core_util,
            uncore_util,
            core_active_s: a.core_active_s,
            uncore_active_s: a.uncore_active_s,
            progress: 0.0,
            cpu_energy_j: 0.0,
        })
    }

    fn wall_pacing(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The deferred-dlopen contract: on a GPU-less host `open` must
    /// return a descriptive error, never panic or fail to link; on a
    /// GPU host it must enumerate. Either way this test passes — the
    /// point is that `--features nvml` is green without hardware.
    #[test]
    fn open_is_a_clean_result_without_a_gpu() {
        match NvmlDriver::open() {
            Ok(d) => {
                let n = d.device_count().unwrap();
                assert!(n < 4096, "implausible device count {n}");
            }
            Err(e) => {
                let msg = e.to_string();
                assert!(!msg.is_empty());
            }
        }
    }

    #[test]
    fn status_codes_map_to_typed_errors() {
        assert!(check(0, "x", 0).is_ok());
        assert!(matches!(check(3, "x", 1), Err(DriverError::NotSupported(_))));
        assert!(matches!(check(4, "x", 1), Err(DriverError::NoPermission(_))));
        assert!(matches!(check(15, "x", 2), Err(DriverError::DeviceLost { device: 2 })));
        assert!(matches!(check(99, "x", 0), Err(DriverError::Api { code: 99, .. })));
        let msg = check(4, "nvmlDeviceSetGpuLockedClocks", 0).unwrap_err().to_string();
        assert!(msg.contains("nvidia-smi -lgc"), "{msg}");
    }
}
