//! Deterministic mock GPU driver with scripted fault injection.
//!
//! [`MockDriver`] implements [`GpuDriver`] over the same calibrated
//! [`AppModel`] curves the simulator uses: each device advances its own
//! virtual clock by one decision interval per counter read, synthesizing
//! power/utilization/progress from the app's per-arm calibration (plus
//! the app's deterministic noise model). A fixed `(app, freqs, devices,
//! dt, seed)` construction therefore yields a bit-reproducible counter
//! stream — the property that lets CI prove the live-hardware stack's
//! record→replay contract without a GPU.
//!
//! Faults are scripted as [`Fault`] entries (`kind@call[/dev]`, see
//! [`parse_fault`]) and fire on exact driver-call indices:
//!
//! | kind     | fires on                  | effect                                   |
//! |----------|---------------------------|------------------------------------------|
//! | `reject` | Nth `set_locked_clocks`   | request refused ([`DriverError::Rejected`]) |
//! | `clamp`  | Nth `set_locked_clocks`   | locks the lowest supported clock instead |
//! | `stale`  | Nth `read_counters`       | returns the previous snapshot unchanged  |
//! | `nan`    | Nth `read_counters`       | energy counter reads NaN                 |
//! | `lost`   | Nth `read_counters` onward| device vanishes: every later call errors |
//!
//! Call indices are 1-based and count every call on that device —
//! including the baseline `read_counters` that
//! [`HwBackend::new`][super::HwBackend] performs per device.
//!
//! A [`MockHandle`] (cloned `Arc` over the shared state) lets tests
//! observe the device after the driver was moved into a backend — the
//! reset-on-drop rail is asserted exactly this way.

use std::sync::{Arc, Mutex, MutexGuard};

use crate::sim::freq::FreqDomain;
use crate::util::Rng;
use crate::workload::model::AppModel;

use super::driver::{DeviceCounters, DeviceInfo, DriverError, GpuDriver};

/// Scripted fault classes (see module docs for the matrix).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Reject,
    Clamp,
    Stale,
    Nan,
    DeviceLost,
}

/// One scripted fault: `kind` fires at driver-call index `at` (1-based)
/// on device `device`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fault {
    pub kind: FaultKind,
    pub at: u64,
    pub device: usize,
}

/// Parse a fault spec, grammar `kind@call[/dev]` with kind one of
/// `reject | clamp | stale | nan | lost` (device defaults to 0):
/// `"reject@5"`, `"lost@30/1"`.
pub fn parse_fault(spec: &str) -> Result<Fault, String> {
    let Some((kind_s, rest)) = spec.split_once('@') else {
        return Err(format!("fault {spec:?}: expected kind@call[/dev]"));
    };
    let kind = match kind_s {
        "reject" => FaultKind::Reject,
        "clamp" => FaultKind::Clamp,
        "stale" => FaultKind::Stale,
        "nan" => FaultKind::Nan,
        "lost" => FaultKind::DeviceLost,
        other => {
            return Err(format!(
                "fault {spec:?}: unknown kind {other:?} (reject|clamp|stale|nan|lost)"
            ))
        }
    };
    let (at_s, dev_s) = match rest.split_once('/') {
        Some((a, d)) => (a, Some(d)),
        None => (rest, None),
    };
    let at: u64 = at_s
        .parse()
        .map_err(|_| format!("fault {spec:?}: bad call index {at_s:?}"))?;
    if at == 0 {
        return Err(format!("fault {spec:?}: call indices are 1-based"));
    }
    let device: usize = match dev_s {
        Some(d) => d.parse().map_err(|_| format!("fault {spec:?}: bad device {d:?}"))?,
        None => 0,
    };
    Ok(Fault { kind, at, device })
}

struct MockDev {
    name: String,
    supported_mhz: Vec<u32>,
    power_limit_w: f64,
    locked_mhz: Option<u32>,
    cur_mhz: u32,
    applies: u64,
    reads: u64,
    resets: u64,
    lost: bool,
    // Virtual device state, advanced one dt per counter read.
    now_s: f64,
    energy_j: f64,
    core_active_s: f64,
    uncore_active_s: f64,
    cpu_energy_j: f64,
    progress: f64,
    last: DeviceCounters,
    rng: Rng,
}

struct MockState {
    app: AppModel,
    freqs: FreqDomain,
    dt_s: f64,
    faults: Vec<Fault>,
    devs: Vec<MockDev>,
}

/// The deterministic, fault-scriptable in-process GPU driver.
pub struct MockDriver {
    state: Arc<Mutex<MockState>>,
}

/// Test probe into a [`MockDriver`]'s shared state — stays valid after
/// the driver is moved into a backend (and after that backend drops).
#[derive(Clone)]
pub struct MockHandle {
    state: Arc<Mutex<MockState>>,
}

fn lock(state: &Arc<Mutex<MockState>>) -> MutexGuard<'_, MockState> {
    // A panicking policy must not wedge the Drop-path clock reset, so a
    // poisoned lock is recovered rather than propagated.
    state.lock().unwrap_or_else(|p| p.into_inner())
}

fn fault_at(faults: &[Fault], kind: FaultKind, dev: usize, call: u64) -> bool {
    faults.iter().any(|f| f.kind == kind && f.device == dev && f.at == call)
}

fn lost_by(faults: &[Fault], dev: usize, read: u64) -> bool {
    faults.iter().any(|f| f.kind == FaultKind::DeviceLost && f.device == dev && f.at <= read)
}

fn nearest_index(ghz_of: &FreqDomain, mhz: u32) -> usize {
    let ghz = mhz as f64 / 1000.0;
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for i in 0..ghz_of.k() {
        let d = (ghz_of.ghz(i) - ghz).abs();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

impl MockDriver {
    /// A `devices`-GPU host calibrated to `app` under `freqs`: supported
    /// core clocks are exactly the domain's arms (in MHz), and each read
    /// advances that device by `dt_s` of virtual time at its current
    /// clock. Per-device RNGs are forked from `seed`, so devices are
    /// decorrelated but the whole host is reproducible.
    pub fn calibrated(
        app: &AppModel,
        freqs: &FreqDomain,
        devices: usize,
        dt_s: f64,
        seed: u64,
    ) -> MockDriver {
        assert!(devices >= 1, "mock host needs at least one device");
        assert_eq!(
            app.energy_kj.len(),
            freqs.k(),
            "app calibration table must match frequency domain"
        );
        let supported: Vec<u32> =
            (0..freqs.k()).map(|i| (freqs.ghz(i) * 1000.0).round() as u32).collect();
        let mut root = Rng::new(seed ^ 0x6877_6d6f_636b); // "hwmock"
        let devs = (0..devices)
            .map(|d| MockDev {
                name: format!("Mock PVC GPU {d}"),
                supported_mhz: supported.clone(),
                power_limit_w: 600.0,
                locked_mhz: None,
                cur_mhz: *supported.last().unwrap(),
                applies: 0,
                reads: 0,
                resets: 0,
                lost: false,
                now_s: 0.0,
                energy_j: 0.0,
                core_active_s: 0.0,
                uncore_active_s: 0.0,
                cpu_energy_j: 0.0,
                progress: 0.0,
                last: DeviceCounters::default(),
                rng: root.fork(d as u64),
            })
            .collect();
        MockDriver {
            state: Arc::new(Mutex::new(MockState {
                app: app.clone(),
                freqs: freqs.clone(),
                dt_s,
                faults: Vec::new(),
                devs,
            })),
        }
    }

    /// Replace device `dev`'s supported clock list (ascending MHz) —
    /// the snap/collapse validation tests drive mismatched domains
    /// through this.
    pub fn with_supported_clocks(self, dev: usize, mut mhz: Vec<u32>) -> MockDriver {
        assert!(!mhz.is_empty(), "supported clock list cannot be empty");
        mhz.sort_unstable();
        mhz.dedup();
        {
            let mut st = lock(&self.state);
            let d = &mut st.devs[dev];
            d.cur_mhz = *mhz.last().unwrap();
            d.supported_mhz = mhz;
        }
        self
    }

    /// Install the fault script.
    pub fn with_faults(self, faults: Vec<Fault>) -> MockDriver {
        lock(&self.state).faults = faults;
        self
    }

    /// A probe into this driver's shared state.
    pub fn handle(&self) -> MockHandle {
        MockHandle { state: Arc::clone(&self.state) }
    }
}

impl MockHandle {
    /// Currently locked clock of device `dev` (`None` after a reset).
    pub fn locked_mhz(&self, dev: usize) -> Option<u32> {
        lock(&self.state).devs[dev].locked_mhz
    }

    /// `reset_locked_clocks` attempts on device `dev` (counted even if
    /// the device was lost and the call errored).
    pub fn resets(&self, dev: usize) -> u64 {
        lock(&self.state).devs[dev].resets
    }

    /// `set_locked_clocks` calls on device `dev`.
    pub fn applies(&self, dev: usize) -> u64 {
        lock(&self.state).devs[dev].applies
    }

    /// `read_counters` calls on device `dev`.
    pub fn reads(&self, dev: usize) -> u64 {
        lock(&self.state).devs[dev].reads
    }
}

impl MockState {
    fn dev(&mut self, dev: usize) -> Result<&mut MockDev, DriverError> {
        let n = self.devs.len();
        self.devs
            .get_mut(dev)
            .ok_or_else(|| DriverError::InvalidArgument(format!("device {dev} of {n}")))
    }
}

impl GpuDriver for MockDriver {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn device_count(&self) -> Result<usize, DriverError> {
        Ok(lock(&self.state).devs.len())
    }

    fn device_info(&self, dev: usize) -> Result<DeviceInfo, DriverError> {
        let mut st = lock(&self.state);
        let d = st.dev(dev)?;
        if d.lost {
            return Err(DriverError::DeviceLost { device: dev });
        }
        Ok(DeviceInfo {
            index: dev,
            name: d.name.clone(),
            min_core_mhz: *d.supported_mhz.first().unwrap(),
            max_core_mhz: *d.supported_mhz.last().unwrap(),
            power_limit_w: d.power_limit_w,
        })
    }

    fn supported_core_clocks_mhz(&self, dev: usize) -> Result<Vec<u32>, DriverError> {
        let mut st = lock(&self.state);
        let d = st.dev(dev)?;
        if d.lost {
            return Err(DriverError::DeviceLost { device: dev });
        }
        Ok(d.supported_mhz.clone())
    }

    fn set_locked_clocks(&mut self, dev: usize, mhz: u32) -> Result<u32, DriverError> {
        let mut st = lock(&self.state);
        let MockState { faults, devs, .. } = &mut *st;
        let n = devs.len();
        let d = devs
            .get_mut(dev)
            .ok_or_else(|| DriverError::InvalidArgument(format!("device {dev} of {n}")))?;
        d.applies += 1;
        if d.lost {
            return Err(DriverError::DeviceLost { device: dev });
        }
        if fault_at(faults, FaultKind::Reject, dev, d.applies) {
            return Err(DriverError::Rejected {
                device: dev,
                reason: "scripted rejection".into(),
            });
        }
        let applied = if fault_at(faults, FaultKind::Clamp, dev, d.applies) {
            // The driver refused the requested ceiling and pinned the
            // floor instead — visibly different from what was asked.
            *d.supported_mhz.first().unwrap()
        } else {
            // Real drivers accept any value and snap to a supported
            // step; mirror that so off-grid requests are observable.
            *d.supported_mhz
                .iter()
                .min_by_key(|s| s.abs_diff(mhz))
                .unwrap()
        };
        d.locked_mhz = Some(applied);
        d.cur_mhz = applied;
        Ok(applied)
    }

    fn reset_locked_clocks(&mut self, dev: usize) -> Result<(), DriverError> {
        let mut st = lock(&self.state);
        let d = st.dev(dev)?;
        d.resets += 1;
        if d.lost {
            return Err(DriverError::DeviceLost { device: dev });
        }
        d.locked_mhz = None;
        d.cur_mhz = *d.supported_mhz.last().unwrap();
        Ok(())
    }

    fn read_counters(&mut self, dev: usize) -> Result<DeviceCounters, DriverError> {
        let mut st = lock(&self.state);
        let MockState { app, freqs, dt_s, faults, devs } = &mut *st;
        let n = devs.len();
        let d = devs
            .get_mut(dev)
            .ok_or_else(|| DriverError::InvalidArgument(format!("device {dev} of {n}")))?;
        d.reads += 1;
        if d.lost || lost_by(faults, dev, d.reads) {
            d.lost = true;
            return Err(DriverError::DeviceLost { device: dev });
        }
        if fault_at(faults, FaultKind::Stale, dev, d.reads) {
            // Frozen snapshot: identical timestamp, no state advance.
            return Ok(d.last);
        }
        // Advance one interval of virtual time at the current clock.
        let arm = nearest_index(freqs, d.cur_mhz);
        let dt = *dt_s;
        let power_w = app.power_kw(freqs, arm) * 1000.0;
        let e_j = (power_w * dt * (1.0 + app.noise.energy_frac * d.rng.gaussian())).max(0.0);
        let core = (app.uc(freqs, arm) + app.noise.util_std * d.rng.gaussian()).clamp(0.0, 1.0);
        let uncore = (app.uu(freqs, arm) + app.noise.util_std * d.rng.gaussian()).clamp(0.0, 1.0);
        d.now_s += dt;
        d.energy_j += e_j;
        d.core_active_s += core * dt;
        d.uncore_active_s += uncore * dt;
        d.cpu_energy_j += app.cpu_kw * 1000.0 * dt;
        d.progress = (d.progress + app.progress_per_step(freqs, arm, dt)).min(1.0);
        let mut c = DeviceCounters {
            timestamp_s: d.now_s,
            energy_j: d.energy_j,
            power_w,
            sm_mhz: d.cur_mhz,
            core_util: core,
            uncore_util: uncore,
            core_active_s: d.core_active_s,
            uncore_active_s: d.uncore_active_s,
            progress: d.progress,
            cpu_energy_j: d.cpu_energy_j,
        };
        if fault_at(faults, FaultKind::Nan, dev, d.reads) {
            // Corrupt the snapshot without corrupting the device state:
            // the next read is clean again.
            c.energy_j = f64::NAN;
        } else {
            d.last = c;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    fn mock(devices: usize) -> MockDriver {
        let app = calibration::app("tealeaf").unwrap();
        MockDriver::calibrated(&app, &FreqDomain::aurora(), devices, 0.01, 7)
    }

    #[test]
    fn fault_grammar_parses_and_rejects() {
        assert_eq!(
            parse_fault("reject@5").unwrap(),
            Fault { kind: FaultKind::Reject, at: 5, device: 0 }
        );
        assert_eq!(
            parse_fault("lost@30/1").unwrap(),
            Fault { kind: FaultKind::DeviceLost, at: 30, device: 1 }
        );
        assert_eq!(parse_fault("clamp@1").unwrap().kind, FaultKind::Clamp);
        assert_eq!(parse_fault("stale@2").unwrap().kind, FaultKind::Stale);
        assert_eq!(parse_fault("nan@3").unwrap().kind, FaultKind::Nan);
        for bad in ["reject", "explode@1", "reject@0", "reject@x", "reject@1/x"] {
            assert!(parse_fault(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn calibrated_counter_stream_is_deterministic() {
        let mut a = mock(2);
        let mut b = mock(2);
        for _ in 0..50 {
            for dev in 0..2 {
                assert_eq!(a.read_counters(dev).unwrap(), b.read_counters(dev).unwrap());
            }
        }
        // Monotone cumulative counters, plausible magnitudes.
        let c = a.read_counters(0).unwrap();
        assert!(c.energy_j > 0.0 && c.timestamp_s > 0.0);
        assert!(c.progress > 0.0 && c.progress < 1.0);
        assert!((0.0..=1.0).contains(&c.core_util));
    }

    #[test]
    fn devices_are_decorrelated_but_reproducible() {
        let mut a = mock(2);
        let c0 = a.read_counters(0).unwrap();
        let c1 = a.read_counters(1).unwrap();
        // Same calibration, different noise draws.
        assert_ne!(c0.energy_j, c1.energy_j);
    }

    #[test]
    fn lock_snap_and_reset() {
        let mut m = mock(1);
        let h = m.handle();
        assert_eq!(m.set_locked_clocks(0, 1200).unwrap(), 1200);
        assert_eq!(h.locked_mhz(0), Some(1200));
        // Off-grid request snaps to the nearest supported step.
        assert_eq!(m.set_locked_clocks(0, 1190).unwrap(), 1200);
        m.reset_locked_clocks(0).unwrap();
        assert_eq!(h.locked_mhz(0), None);
        assert_eq!(h.resets(0), 1);
        // Back at the default (max) clock.
        assert_eq!(m.read_counters(0).unwrap().sm_mhz, 1600);
    }

    #[test]
    fn scripted_faults_fire_on_exact_calls() {
        let app = calibration::app("tealeaf").unwrap();
        let mut m = MockDriver::calibrated(&app, &FreqDomain::aurora(), 1, 0.01, 7).with_faults(
            vec![
                parse_fault("reject@2").unwrap(),
                parse_fault("clamp@3").unwrap(),
                parse_fault("stale@2").unwrap(),
                parse_fault("nan@3").unwrap(),
                parse_fault("lost@5").unwrap(),
            ],
        );
        // Applies: 1 ok, 2 rejected, 3 clamped to the floor.
        assert_eq!(m.set_locked_clocks(0, 1400).unwrap(), 1400);
        assert!(matches!(
            m.set_locked_clocks(0, 1400),
            Err(DriverError::Rejected { device: 0, .. })
        ));
        assert_eq!(m.set_locked_clocks(0, 1400).unwrap(), 800);
        // Reads: 1 ok, 2 stale (same timestamp), 3 NaN energy, 4 clean,
        // 5+ lost.
        let c1 = m.read_counters(0).unwrap();
        let c2 = m.read_counters(0).unwrap();
        assert_eq!(c1.timestamp_s, c2.timestamp_s, "stale read must not advance");
        let c3 = m.read_counters(0).unwrap();
        assert!(c3.energy_j.is_nan());
        let c4 = m.read_counters(0).unwrap();
        assert!(c4.energy_j.is_finite() && c4.timestamp_s > c1.timestamp_s);
        assert!(matches!(m.read_counters(0), Err(DriverError::DeviceLost { device: 0 })));
        // Lost is sticky, and control calls fail too.
        assert!(m.read_counters(0).is_err());
        assert!(m.set_locked_clocks(0, 800).is_err());
        assert!(m.reset_locked_clocks(0).is_err());
        assert!(m.device_info(0).is_err());
    }

    #[test]
    fn faults_are_per_device() {
        let app = calibration::app("tealeaf").unwrap();
        let mut m = MockDriver::calibrated(&app, &FreqDomain::aurora(), 2, 0.01, 7)
            .with_faults(vec![parse_fault("lost@1/1").unwrap()]);
        assert!(m.read_counters(0).is_ok());
        assert!(m.read_counters(1).is_err());
        assert!(m.read_counters(0).is_ok(), "device 0 unaffected by device 1's loss");
    }
}
