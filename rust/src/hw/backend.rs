//! [`HwBackend`]: the live-hardware [`TelemetryBackend`].
//!
//! Maps one controller row per detected GPU (B = device count, so a
//! multi-device host is a single batched backend), converts the session
//! [`FreqDomain`] arms to driver clocks (snapping to the device's
//! supported steps — see [`HwBackend::new`]), and derives the paper's
//! reward inputs (per-interval energy, core/uncore utilization) by
//! differencing consecutive [`DeviceCounters`] snapshots.
//!
//! Live control gets three safety rails the simulator never needed:
//!
//! * **Reset on drop** — `Drop` best-effort releases the clock lock on
//!   every device, including panic-unwind paths, so an aborted run never
//!   leaves a GPU pinned at a frequency.
//! * **Minimum dwell** — a per-device rate limiter on [`apply`]: a
//!   switch request arriving sooner than `min_dwell_steps` intervals
//!   after the last one is deferred (the previous clock is kept), which
//!   bounds DVFS churn against a driver that is slower than `dt_s`.
//! * **Watchdog** — after `watchdog_errors` *consecutive* driver errors
//!   on one device (rejected requests, failed/stale/NaN counter reads,
//!   device loss), that row degrades to a frozen arm and reports
//!   inactive samples instead of crashing the controller; healthy
//!   devices keep running.
//!
//! Degraded telemetry is absorbed, never invented: a failed or invalid
//! counter read repeats the row's last good sample (with `switched =
//! false`) so the policy keeps stepping, but [`totals`] only accumulate
//! measured deltas.
//!
//! [`apply`]: TelemetryBackend::apply
//! [`totals`]: TelemetryBackend::totals

use std::time::Instant;

use crate::control::{BackendTotals, SessionCfg, StepSample, TelemetryBackend};
use crate::sim::freq::FreqDomain;
use crate::telemetry::{Counter, Gauge, Recorder};

use super::driver::{DeviceCounters, GpuDriver};

/// Safety-rail tuning (the `[hw]` config table's knobs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HwTuning {
    /// Minimum decision intervals between clock switches per device
    /// (1 = switch every interval, the simulator's behavior).
    pub min_dwell_steps: u64,
    /// Consecutive driver errors before a device degrades to its frozen
    /// arm.
    pub watchdog_errors: u32,
}

impl Default for HwTuning {
    fn default() -> Self {
        HwTuning { min_dwell_steps: 1, watchdog_errors: 3 }
    }
}

struct DevState {
    /// Session arm index → driver clock (MHz), snapped to supported.
    arm_mhz: Vec<u32>,
    /// Clock currently applied on the device.
    cur_mhz: u32,
    /// Arm the device currently runs (nearest to `cur_mhz`).
    cur_arm: usize,
    /// A successful clock change happened since the last sample.
    switched_pending: bool,
    /// Intervals since the last clock change (dwell limiter input).
    steps_since_switch: u64,
    /// Last good counter snapshot (the differencing baseline).
    base: DeviceCounters,
    /// Last good sample, repeated verbatim when a read fails.
    last_sample: StepSample,
    consec_errors: u32,
    degraded: bool,
    totals: BackendTotals,
}

impl DevState {
    fn finished(&self) -> bool {
        self.base.progress >= 1.0
    }

    fn inactive_sample(&self) -> StepSample {
        StepSample {
            active: false,
            remaining: (1.0 - self.base.progress).max(0.0),
            ..StepSample::default()
        }
    }
}

fn nearest_arm(arm_mhz: &[u32], mhz: u32) -> usize {
    let mut best = 0;
    let mut best_d = u32::MAX;
    for (i, &a) in arm_mhz.iter().enumerate() {
        let d = a.abs_diff(mhz);
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

/// A valid snapshot is finite, strictly advances the driver clock (a
/// repeated timestamp is a stale read), and keeps the energy counter
/// monotone.
fn counters_ok(c: &DeviceCounters, base: &DeviceCounters) -> bool {
    let finite = c.timestamp_s.is_finite()
        && c.energy_j.is_finite()
        && c.power_w.is_finite()
        && c.core_util.is_finite()
        && c.uncore_util.is_finite()
        && c.progress.is_finite();
    finite && c.timestamp_s > base.timestamp_s && c.energy_j >= base.energy_j - 1e-9
}

fn note_driver_error(
    d: &mut DevState,
    driver_errors: &mut Counter,
    watchdog_trips: &mut Counter,
    watchdog_errors: u32,
) {
    driver_errors.inc();
    d.consec_errors += 1;
    if !d.degraded && d.consec_errors >= watchdog_errors {
        d.degraded = true;
        watchdog_trips.inc();
    }
}

/// The live-hardware telemetry backend (see module docs).
pub struct HwBackend {
    driver: Box<dyn GpuDriver>,
    freqs: FreqDomain,
    dt_s: f64,
    tuning: HwTuning,
    devs: Vec<DevState>,
    warnings: Vec<String>,
    apply_latency_us: Gauge,
    sample_latency_us: Gauge,
    driver_errors: Counter,
    dwell_deferred: Counter,
    clamped: Counter,
    watchdog_trips: Counter,
}

impl HwBackend {
    /// Enumerate the driver's devices and validate the session
    /// [`FreqDomain`] against each device's supported core clocks: every
    /// arm snaps to the nearest supported step (with a warning when the
    /// snap moved it), and two arms collapsing onto the same step is a
    /// hard error — the policy would have two indistinguishable arms.
    /// Reads one baseline counter snapshot per device.
    pub fn new(
        driver: Box<dyn GpuDriver>,
        cfg: &SessionCfg,
        tuning: HwTuning,
    ) -> anyhow::Result<HwBackend> {
        anyhow::ensure!(tuning.min_dwell_steps >= 1, "hw: min_dwell_steps must be >= 1");
        anyhow::ensure!(tuning.watchdog_errors >= 1, "hw: watchdog_errors must be >= 1");
        let mut driver = driver;
        let freqs = cfg.domain();
        let n = driver.device_count()?;
        anyhow::ensure!(n >= 1, "hw: driver {} reports no GPUs", driver.name());
        let mut warnings = Vec::new();
        let mut devs = Vec::with_capacity(n);
        for e in 0..n {
            let mut supported = driver.supported_core_clocks_mhz(e)?;
            anyhow::ensure!(
                !supported.is_empty(),
                "hw: device {e} reports no supported core clocks"
            );
            supported.sort_unstable();
            supported.dedup();
            let mut arm_mhz: Vec<u32> = Vec::with_capacity(freqs.k());
            for i in 0..freqs.k() {
                let target = freqs.ghz(i) * 1000.0;
                let snapped = *supported
                    .iter()
                    .min_by(|a, b| {
                        let da = (**a as f64 - target).abs();
                        let db = (**b as f64 - target).abs();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                if (snapped as f64 - target).abs() > 0.5 {
                    warnings.push(format!(
                        "hw: device {e}: arm {i} ({:.3} GHz) snapped to supported {snapped} MHz",
                        freqs.ghz(i)
                    ));
                }
                if let Some(&prev) = arm_mhz.last() {
                    anyhow::ensure!(
                        snapped > prev,
                        "hw: device {e}: arms {} and {i} both snap to {snapped} MHz — \
                         thin the [freq] domain to the device's supported clocks",
                        i - 1
                    );
                }
                arm_mhz.push(snapped);
            }
            let base = driver.read_counters(e)?;
            let cur_arm = nearest_arm(&arm_mhz, base.sm_mhz);
            devs.push(DevState {
                cur_mhz: base.sm_mhz,
                cur_arm,
                arm_mhz,
                switched_pending: false,
                // MAX: the first switch is never dwell-deferred.
                steps_since_switch: u64::MAX,
                base,
                last_sample: StepSample::default(),
                consec_errors: 0,
                degraded: false,
                totals: BackendTotals::default(),
            });
        }
        Ok(HwBackend {
            driver,
            freqs,
            dt_s: cfg.dt_s,
            tuning,
            devs,
            warnings,
            apply_latency_us: Gauge::default(),
            sample_latency_us: Gauge::default(),
            driver_errors: Counter::default(),
            dwell_deferred: Counter::default(),
            clamped: Counter::default(),
            watchdog_trips: Counter::default(),
        })
    }

    /// Human-readable construction warnings (arm snapping).
    pub fn warnings(&self) -> &[String] {
        &self.warnings
    }

    /// Whether device `e`'s watchdog has tripped (row frozen/inactive).
    pub fn degraded(&self, e: usize) -> bool {
        self.devs[e].degraded
    }

    /// Total driver errors absorbed so far (all devices).
    pub fn driver_errors(&self) -> u64 {
        self.driver_errors.get()
    }

    /// Switch requests deferred by the minimum-dwell limiter.
    pub fn dwell_deferred(&self) -> u64 {
        self.dwell_deferred.get()
    }

    /// Applies where the driver clamped the requested clock.
    pub fn clamped(&self) -> u64 {
        self.clamped.get()
    }

    /// Devices degraded by the watchdog.
    pub fn watchdog_trips(&self) -> u64 {
        self.watchdog_trips.get()
    }

    /// The underlying driver (diagnostics).
    pub fn driver(&self) -> &dyn GpuDriver {
        self.driver.as_ref()
    }

    /// Merge the hw-path instruments into a run's [`Recorder`] —
    /// `hw.apply_latency_us` / `hw.sample_latency_us` gauges plus the
    /// `hw.driver_errors` / `hw.dwell_deferred` / `hw.clamped` /
    /// `hw.watchdog_trips` counters — surfacing them through
    /// `RunResult::telemetry` next to `controller.decide_latency_us`.
    pub fn export_telemetry(&self, rec: &mut Recorder) {
        rec.insert_gauge("hw.apply_latency_us", self.apply_latency_us.clone());
        rec.insert_gauge("hw.sample_latency_us", self.sample_latency_us.clone());
        rec.insert_counter("hw.driver_errors", self.driver_errors.clone());
        rec.insert_counter("hw.dwell_deferred", self.dwell_deferred.clone());
        rec.insert_counter("hw.clamped", self.clamped.clone());
        rec.insert_counter("hw.watchdog_trips", self.watchdog_trips.clone());
    }
}

impl TelemetryBackend for HwBackend {
    fn b(&self) -> usize {
        self.devs.len()
    }

    fn k(&self) -> usize {
        self.freqs.k()
    }

    fn apply(&mut self, sel: &[i32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            sel.len() == self.devs.len(),
            "hw: {} selections for B = {}",
            sel.len(),
            self.devs.len()
        );
        let t0 = Instant::now();
        let k = self.freqs.k();
        let sc = self.freqs.switch_cost();
        let Self {
            driver,
            devs,
            tuning,
            driver_errors,
            watchdog_trips,
            dwell_deferred,
            clamped,
            ..
        } = self;
        for (e, (&a, d)) in sel.iter().zip(devs.iter_mut()).enumerate() {
            if d.degraded || d.finished() {
                continue;
            }
            anyhow::ensure!(a >= 0 && (a as usize) < k, "hw: arm {a} out of range (K = {k})");
            let arm = a as usize;
            if arm == d.cur_arm {
                continue;
            }
            if d.steps_since_switch < tuning.min_dwell_steps {
                dwell_deferred.inc();
                continue;
            }
            let want = d.arm_mhz[arm];
            match driver.set_locked_clocks(e, want) {
                Ok(applied) => {
                    d.consec_errors = 0;
                    if applied != want {
                        clamped.inc();
                    }
                    if applied != d.cur_mhz {
                        d.switched_pending = true;
                        d.steps_since_switch = 0;
                        d.totals.switches += 1;
                        d.totals.switch_energy_j += sc.energy_j;
                        d.totals.switch_time_s += sc.latency_s;
                    }
                    d.cur_mhz = applied;
                    d.cur_arm = nearest_arm(&d.arm_mhz, applied);
                }
                Err(_) => {
                    // Request refused: keep the previous clock and count
                    // toward the watchdog.
                    note_driver_error(d, driver_errors, watchdog_trips, tuning.watchdog_errors);
                }
            }
        }
        self.apply_latency_us.record(t0.elapsed().as_secs_f64() * 1e6);
        Ok(())
    }

    fn sample_into(&mut self, out: &mut [StepSample]) -> anyhow::Result<()> {
        anyhow::ensure!(
            out.len() == self.devs.len(),
            "hw: {} sample slots for B = {}",
            out.len(),
            self.devs.len()
        );
        if self.driver.wall_pacing() {
            // Live counters integrate wall time; let one decision
            // interval elapse. (Coarse pacing: the interval is dt_s plus
            // driver-call latency, which the latency gauges quantify.)
            std::thread::sleep(std::time::Duration::from_secs_f64(self.dt_s));
        }
        let t0 = Instant::now();
        let Self { driver, devs, tuning, driver_errors, watchdog_trips, .. } = self;
        for (e, (d, slot)) in devs.iter_mut().zip(out.iter_mut()).enumerate() {
            if d.degraded || d.finished() {
                *slot = d.inactive_sample();
                continue;
            }
            let read = driver.read_counters(e);
            match read {
                Ok(c) if counters_ok(&c, &d.base) => {
                    d.consec_errors = 0;
                    let de = (c.energy_j - d.base.energy_j).max(0.0);
                    let dt = c.timestamp_s - d.base.timestamp_s;
                    let s = StepSample {
                        gpu_energy_j: de,
                        core_util: c.core_util,
                        uncore_util: c.uncore_util,
                        progress: (c.progress - d.base.progress).max(0.0),
                        remaining: (1.0 - c.progress).max(0.0),
                        true_gpu_energy_j: de,
                        switched: d.switched_pending,
                        reward: None,
                        active: true,
                        context: None,
                    };
                    d.totals.gpu_energy_kj += de / 1000.0;
                    d.totals.exec_time_s += dt;
                    d.base = c;
                    d.last_sample = s;
                    *slot = s;
                }
                _ => {
                    // Failed, stale, or non-finite read: repeat the last
                    // good sample so the policy keeps stepping (totals
                    // untouched — only measured deltas accumulate), and
                    // count toward the watchdog.
                    note_driver_error(d, driver_errors, watchdog_trips, tuning.watchdog_errors);
                    *slot = if d.degraded {
                        d.inactive_sample()
                    } else {
                        StepSample { switched: false, ..d.last_sample }
                    };
                }
            }
            d.switched_pending = false;
            d.steps_since_switch = d.steps_since_switch.saturating_add(1);
        }
        self.sample_latency_us.record(t0.elapsed().as_secs_f64() * 1e6);
        Ok(())
    }

    fn done(&self) -> bool {
        self.devs.iter().all(|d| d.degraded || d.finished())
    }

    fn totals(&self) -> Vec<BackendTotals> {
        self.devs.iter().map(|d| d.totals).collect()
    }
}

impl Drop for HwBackend {
    fn drop(&mut self) {
        // The reset-on-drop rail: best-effort clock release on every
        // device — including panic unwinds and degraded/lost devices
        // (a device that comes back should not come back locked).
        for e in 0..self.devs.len() {
            let _ = self.driver.reset_locked_clocks(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::mock::{parse_fault, MockDriver};
    use crate::workload::calibration;

    fn scfg() -> SessionCfg {
        SessionCfg { seed: 7, ..SessionCfg::default() }
    }

    fn mock(devices: usize) -> MockDriver {
        let app = calibration::app("tealeaf").unwrap();
        let cfg = scfg();
        MockDriver::calibrated(&app, &cfg.domain(), devices, cfg.dt_s, cfg.seed)
    }

    #[test]
    fn maps_one_row_per_device() {
        let b = HwBackend::new(Box::new(mock(3)), &scfg(), HwTuning::default()).unwrap();
        assert_eq!(b.b(), 3);
        assert_eq!(b.k(), 9);
        assert!(b.warnings().is_empty(), "exact-match clocks must not warn");
        assert!(!b.done());
    }

    #[test]
    fn arms_snap_to_supported_clocks_with_warnings() {
        // Device steps offset 7 MHz from the session arms: every arm
        // snaps, none collapse.
        let clocks: Vec<u32> = (0..9).map(|i| 807 + 100 * i).collect();
        let m = mock(1).with_supported_clocks(0, clocks);
        let b = HwBackend::new(Box::new(m), &scfg(), HwTuning::default()).unwrap();
        assert_eq!(b.warnings().len(), 9);
        assert!(b.warnings()[0].contains("snapped"), "{}", b.warnings()[0]);
    }

    #[test]
    fn collapsing_arms_is_a_construction_error() {
        // Two supported steps for nine arms: neighbors must collide.
        let m = mock(1).with_supported_clocks(0, vec![800, 1600]);
        let err = HwBackend::new(Box::new(m), &scfg(), HwTuning::default())
            .err()
            .expect("collapsed arms must fail")
            .to_string();
        assert!(err.contains("snap to"), "{err}");
    }

    #[test]
    fn bad_tuning_is_rejected() {
        let t = HwTuning { min_dwell_steps: 0, ..HwTuning::default() };
        assert!(HwBackend::new(Box::new(mock(1)), &scfg(), t).is_err());
        let t = HwTuning { watchdog_errors: 0, ..HwTuning::default() };
        assert!(HwBackend::new(Box::new(mock(1)), &scfg(), t).is_err());
    }

    #[test]
    fn apply_validates_arity_and_range() {
        let mut b = HwBackend::new(Box::new(mock(1)), &scfg(), HwTuning::default()).unwrap();
        assert!(b.apply(&[0, 1]).is_err());
        assert!(b.apply(&[99]).is_err());
        assert!(b.apply(&[-1]).is_err());
        b.apply(&[2]).unwrap();
        let mut out = [StepSample::default()];
        b.sample_into(&mut out).unwrap();
        assert!(out[0].switched);
        assert!(out[0].active);
        assert!(out[0].gpu_energy_j > 0.0);
        assert_eq!(b.totals()[0].switches, 1);
    }

    #[test]
    fn dwell_limiter_defers_rapid_switches() {
        let t = HwTuning { min_dwell_steps: 4, ..HwTuning::default() };
        let mut b = HwBackend::new(Box::new(mock(1)), &scfg(), t).unwrap();
        let mut out = [StepSample::default()];
        // Alternate arms every interval; only every 4th change can land.
        for step in 0..16i32 {
            b.apply(&[step % 2]).unwrap();
            b.sample_into(&mut out).unwrap();
        }
        assert!(b.dwell_deferred() > 0, "limiter never engaged");
        // First switch plus at most one per 4 intervals.
        assert!(b.totals()[0].switches <= 1 + 16 / 4, "{}", b.totals()[0].switches);
    }

    #[test]
    fn stale_and_nan_reads_repeat_last_good_sample() {
        let app = calibration::app("tealeaf").unwrap();
        let cfg = scfg();
        // Construction does read 1; session reads start at 2.
        let m = MockDriver::calibrated(&app, &cfg.domain(), 1, cfg.dt_s, cfg.seed)
            .with_faults(vec![parse_fault("stale@3").unwrap(), parse_fault("nan@5").unwrap()]);
        let mut b = HwBackend::new(Box::new(m), &cfg, HwTuning::default()).unwrap();
        let mut out = [StepSample::default()];
        b.apply(&[4]).unwrap();
        b.sample_into(&mut out).unwrap(); // read 2: good
        let good = out[0];
        b.apply(&[4]).unwrap();
        b.sample_into(&mut out).unwrap(); // read 3: stale
        assert_eq!(out[0].gpu_energy_j, good.gpu_energy_j, "stale read must repeat");
        assert!(!out[0].switched);
        assert_eq!(b.driver_errors(), 1);
        b.apply(&[4]).unwrap();
        b.sample_into(&mut out).unwrap(); // read 4: good again
        assert_eq!(b.driver_errors(), 1, "clean read resets nothing extra");
        let before = out[0];
        b.apply(&[4]).unwrap();
        b.sample_into(&mut out).unwrap(); // read 5: NaN energy
        assert_eq!(out[0].gpu_energy_j, before.gpu_energy_j);
        assert!(out[0].gpu_energy_j.is_finite(), "NaN must never reach the policy");
        assert_eq!(b.driver_errors(), 2);
        assert!(!b.degraded(0), "isolated glitches must not trip the watchdog");
    }

    #[test]
    fn totals_skip_unmeasured_intervals() {
        let app = calibration::app("tealeaf").unwrap();
        let cfg = scfg();
        let m = MockDriver::calibrated(&app, &cfg.domain(), 1, cfg.dt_s, cfg.seed)
            .with_faults(vec![parse_fault("stale@3").unwrap()]);
        let mut b = HwBackend::new(Box::new(m), &cfg, HwTuning::default()).unwrap();
        let mut out = [StepSample::default()];
        for _ in 0..3 {
            b.apply(&[8]).unwrap();
            b.sample_into(&mut out).unwrap();
        }
        // 3 intervals sampled, 1 stale: totals integrate 2 measured dts.
        let t = b.totals()[0];
        assert!((t.exec_time_s - 2.0 * cfg.dt_s).abs() < 1e-12, "{}", t.exec_time_s);
    }

    #[test]
    fn watchdog_degrades_after_consecutive_errors() {
        let app = calibration::app("tealeaf").unwrap();
        let cfg = scfg();
        let m = MockDriver::calibrated(&app, &cfg.domain(), 1, cfg.dt_s, cfg.seed)
            .with_faults(vec![parse_fault("lost@4").unwrap()]);
        let t = HwTuning { watchdog_errors: 3, ..HwTuning::default() };
        let mut b = HwBackend::new(Box::new(m), &cfg, t).unwrap();
        let mut out = [StepSample::default()];
        let mut steps = 0;
        while !b.done() && steps < 100 {
            b.apply(&[8]).unwrap();
            b.sample_into(&mut out).unwrap();
            steps += 1;
        }
        assert!(b.degraded(0), "watchdog never tripped");
        assert_eq!(b.watchdog_trips(), 1);
        assert!(b.driver_errors() >= 3);
        assert!(b.done(), "a fully degraded backend is done");
        assert!(!out[0].active, "degraded rows report inactive samples");
        // Further drive calls are absorbed without touching the driver.
        b.apply(&[0]).unwrap();
        b.sample_into(&mut out).unwrap();
        assert!(!out[0].active);
    }

    #[test]
    fn clocks_reset_on_drop() {
        let m = mock(1);
        let h = m.handle();
        {
            let mut b = HwBackend::new(Box::new(m), &scfg(), HwTuning::default()).unwrap();
            b.apply(&[1]).unwrap();
            assert_eq!(h.locked_mhz(0), Some(900));
        }
        assert_eq!(h.locked_mhz(0), None, "drop must release the clock lock");
        assert_eq!(h.resets(0), 1);
    }

    #[test]
    fn clocks_reset_on_panic_unwind() {
        let m = mock(1);
        let h = m.handle();
        let cfg = scfg();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut b = HwBackend::new(Box::new(m), &cfg, HwTuning::default()).unwrap();
            b.apply(&[2]).unwrap();
            assert_eq!(h.locked_mhz(0), Some(1000));
            panic!("scripted mid-run panic");
        }));
        assert!(result.is_err());
        assert_eq!(h.locked_mhz(0), None, "unwind must release the clock lock");
        assert!(h.resets(0) >= 1);
    }

    #[test]
    fn telemetry_export_carries_the_hw_instruments() {
        let app = calibration::app("tealeaf").unwrap();
        let cfg = scfg();
        let m = MockDriver::calibrated(&app, &cfg.domain(), 1, cfg.dt_s, cfg.seed)
            .with_faults(vec![parse_fault("reject@1").unwrap()]);
        let mut b = HwBackend::new(Box::new(m), &cfg, HwTuning::default()).unwrap();
        let mut out = [StepSample::default()];
        for step in 0..4i32 {
            b.apply(&[step % 2]).unwrap();
            b.sample_into(&mut out).unwrap();
        }
        let mut rec = Recorder::default();
        b.export_telemetry(&mut rec);
        assert_eq!(rec.counter_value("hw.driver_errors"), Some(1));
        assert!(rec.gauge_get("hw.apply_latency_us").is_some());
        assert!(rec.gauge_get("hw.sample_latency_us").is_some());
        assert_eq!(rec.counter_value("hw.watchdog_trips"), Some(0));
    }
}
