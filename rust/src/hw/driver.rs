//! The device boundary of the live-hardware subsystem: [`GpuDriver`].
//!
//! Everything above this trait — [`HwBackend`][super::HwBackend], the
//! CLI, record→replay — is driver-agnostic. Two implementations ship:
//! the deterministic, fault-scriptable [`MockDriver`][super::MockDriver]
//! (default features; what CI drives), and the dlopen'd libnvidia-ml
//! binding [`NvmlDriver`][super::nvml::NvmlDriver] behind `--features
//! nvml` (no link-time dependency, so a GPU-less build stays green).
//!
//! Counter snapshots use the GEOPM signal vocabulary from
//! [`geopm::signals`][crate::geopm::signals]: each [`DeviceCounters`]
//! field maps to exactly one [`Signal`][crate::geopm::Signal] via
//! [`signal_value`][super::signal_value], so the simulated and live
//! worlds report the same names.

use std::fmt;

/// Errors a device driver can surface. Every variant is survivable at
/// the backend layer: [`HwBackend`][super::HwBackend] counts them toward
/// the per-device watchdog instead of failing the controller.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DriverError {
    /// The driver library could not be loaded/initialized (or a symbol
    /// is missing). Construction-time only.
    NotLoaded(String),
    /// The device does not support the requested operation.
    NotSupported(String),
    /// The calling process lacks the capability (e.g. clock locking
    /// needs the `nvidia-smi -lgc` privilege).
    NoPermission(String),
    /// Malformed request (device index out of range, bad clock).
    InvalidArgument(String),
    /// The device fell off the bus / stopped responding.
    DeviceLost { device: usize },
    /// The driver refused a control request (policy, thermal, ...).
    Rejected { device: usize, reason: String },
    /// A counter read failed.
    Counter { device: usize, reason: String },
    /// Unmapped driver API status code.
    Api { call: &'static str, code: i32 },
}

impl fmt::Display for DriverError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DriverError::NotLoaded(m) => write!(f, "driver not loaded: {m}"),
            DriverError::NotSupported(m) => write!(f, "not supported: {m}"),
            DriverError::NoPermission(m) => write!(f, "no permission: {m}"),
            DriverError::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            DriverError::DeviceLost { device } => write!(f, "device {device} lost"),
            DriverError::Rejected { device, reason } => {
                write!(f, "device {device} rejected request: {reason}")
            }
            DriverError::Counter { device, reason } => {
                write!(f, "device {device} counter read failed: {reason}")
            }
            DriverError::Api { call, code } => write!(f, "{call} returned status {code}"),
        }
    }
}

impl std::error::Error for DriverError {}

/// Static device identity, reported by `energyucb devices`.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceInfo {
    pub index: usize,
    pub name: String,
    /// Lowest supported core (graphics) clock, MHz.
    pub min_core_mhz: u32,
    /// Highest supported core clock, MHz.
    pub max_core_mhz: u32,
    /// Board power limit, Watts.
    pub power_limit_w: f64,
}

/// One counter snapshot for one device. Cumulative fields are monotone
/// from an arbitrary per-driver epoch; the backend differences
/// consecutive snapshots, so only deltas matter.
///
/// Field ↔ signal mapping (see [`signal_value`][super::signal_value]):
/// `energy_j` = `GPU::ENERGY`, `core_active_s` = `GPU::CORE_ACTIVE_TIME`,
/// `uncore_active_s` = `GPU::UNCORE_ACTIVE_TIME`, `timestamp_s` = `TIME`,
/// `progress` = `EPOCH::PROGRESS`, `cpu_energy_j` = `CPU::ENERGY`.
/// Drivers without an application progress or CPU energy source report
/// 0.0 there (NVML does); the mock fills every field.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceCounters {
    /// Monotone driver timestamp, seconds ("TIME"). Must strictly
    /// advance between reads — a repeated timestamp is how the backend
    /// detects a stale snapshot.
    pub timestamp_s: f64,
    /// Cumulative GPU energy, Joules ("GPU::ENERGY").
    pub energy_j: f64,
    /// Instantaneous board power, Watts.
    pub power_w: f64,
    /// Current core (SM) clock, MHz.
    pub sm_mhz: u32,
    /// Instantaneous compute-engine utilization in [0, 1].
    pub core_util: f64,
    /// Instantaneous copy-engine utilization in [0, 1].
    pub uncore_util: f64,
    /// Cumulative compute-engine active time, s ("GPU::CORE_ACTIVE_TIME").
    pub core_active_s: f64,
    /// Cumulative copy-engine active time, s ("GPU::UNCORE_ACTIVE_TIME").
    pub uncore_active_s: f64,
    /// Cumulative application progress in [0, 1] ("EPOCH::PROGRESS");
    /// 0.0 where no progress source exists.
    pub progress: f64,
    /// Cumulative CPU package energy, Joules ("CPU::ENERGY"); 0.0 where
    /// unmeasured.
    pub cpu_energy_j: f64,
}

impl Default for DeviceCounters {
    fn default() -> Self {
        DeviceCounters {
            timestamp_s: 0.0,
            energy_j: 0.0,
            power_w: 0.0,
            sm_mhz: 0,
            core_util: 0.0,
            uncore_util: 0.0,
            core_active_s: 0.0,
            uncore_active_s: 0.0,
            progress: 0.0,
            cpu_energy_j: 0.0,
        }
    }
}

/// The abstract GPU device surface: enumerate devices, query supported
/// core clocks, lock/reset clocks, read counters.
///
/// Mirrors the slice of NVML the paper's control loop needs (AGFT's
/// nvidia-smi/pynvml loop): `nvmlDeviceGetSupportedGraphicsClocks`,
/// `nvmlDeviceSetGpuLockedClocks`, `nvmlDeviceResetGpuLockedClocks`,
/// and the energy/power/utilization/clock counter reads.
///
/// Any call may fail; callers must treat errors as per-device, transient
/// events (the backend's watchdog decides when a device is gone for
/// good). Implementations are NOT required to be deterministic — only
/// [`MockDriver`][super::MockDriver] is, which is what makes the CI
/// record→replay contract testable without hardware.
pub trait GpuDriver {
    /// Short driver identity ("mock", "nvml").
    fn name(&self) -> &'static str;

    /// Number of GPUs on the host.
    fn device_count(&self) -> Result<usize, DriverError>;

    /// Static identity of device `dev`.
    fn device_info(&self, dev: usize) -> Result<DeviceInfo, DriverError>;

    /// Supported core-clock steps for device `dev`, MHz, ascending.
    fn supported_core_clocks_mhz(&self, dev: usize) -> Result<Vec<u32>, DriverError>;

    /// Lock device `dev`'s core clock to `mhz`. Returns the clock the
    /// driver actually applied — drivers may clamp a request to the
    /// nearest supported step, and callers must observe that.
    fn set_locked_clocks(&mut self, dev: usize, mhz: u32) -> Result<u32, DriverError>;

    /// Release the clock lock on device `dev` (back to driver default).
    fn reset_locked_clocks(&mut self, dev: usize) -> Result<(), DriverError>;

    /// Read one counter snapshot from device `dev`.
    fn read_counters(&mut self, dev: usize) -> Result<DeviceCounters, DriverError>;

    /// Whether counters track wall-clock time (live hardware), in which
    /// case the backend must let one decision interval of real time pass
    /// between reads. The mock advances its own virtual clock per read
    /// and keeps the default `false`, so tests and CI never sleep.
    fn wall_pacing(&self) -> bool {
        false
    }
}
