//! Live-hardware telemetry subsystem (EXPERIMENTS.md §Live hardware).
//!
//! The layer cake, bottom-up:
//!
//! * [`GpuDriver`] — the abstract device surface (enumerate, supported
//!   clocks, lock/reset clocks, read counters).
//! * [`MockDriver`] — a deterministic, app-calibrated driver with
//!   scripted fault injection (reject / clamp / stale / NaN / device
//!   loss); what CI and the default test suite drive.
//! * `nvml` (feature `nvml`) — a dlopen'd libnvidia-ml binding with no
//!   link-time dependency: the feature builds and unit-tests green on a
//!   GPU-less host, and only [`nvml_driver`] at runtime reports whether
//!   the library is actually present.
//! * [`HwBackend`] — the [`TelemetryBackend`][crate::control::TelemetryBackend]
//!   over any driver: one controller row per GPU, arm→clock conversion
//!   with snap validation, and the live-control safety rails
//!   (reset-on-drop, minimum dwell, error watchdog).
//!
//! The hw layer is also where the GEOPM signal vocabulary from
//! [`geopm::signals`][crate::geopm::signals] becomes canonical for
//! counters: [`signal_value`] maps every [`Signal`] onto a
//! [`DeviceCounters`] field (a total mapping, test-asserted), so the
//! simulated service and the live driver report the same names.
//!
//! Wired through `energyucb run --backend sim|mock|nvml` (plus the
//! `[hw]` config table) and `energyucb devices`; a mock or live session
//! records through the standard [`Recording`][crate::control::Recording]
//! tee, and `replay` / `sweep --replay` consume the trace unchanged.

pub mod backend;
pub mod driver;
pub mod mock;
#[cfg(feature = "nvml")]
pub mod nvml;

pub use backend::{HwBackend, HwTuning};
pub use driver::{DeviceCounters, DeviceInfo, DriverError, GpuDriver};
pub use mock::{parse_fault, Fault, FaultKind, MockDriver, MockHandle};

// The canonical counter-name vocabulary, shared verbatim with the
// simulated GEOPM service: one source of names for both worlds.
pub use crate::geopm::signals::{Control, Signal};

use crate::util::table::{fnum, Table};

/// Value of GEOPM signal `s` in a driver counter snapshot. The match is
/// total over [`Signal::ALL`] by construction (no wildcard arm), so the
/// hw layer can never silently drop a signal the sim service exposes —
/// asserted by `signal_vocabulary_is_total`.
pub fn signal_value(c: &DeviceCounters, s: Signal) -> f64 {
    match s {
        Signal::GpuEnergy => c.energy_j,
        Signal::GpuCoreActiveTime => c.core_active_s,
        Signal::GpuUncoreActiveTime => c.uncore_active_s,
        Signal::Time => c.timestamp_s,
        Signal::AppProgress => c.progress,
        Signal::CpuEnergy => c.cpu_energy_j,
    }
}

/// Open the dlopen'd libnvidia-ml driver. Without `--features nvml`
/// this fails fast with a rebuild hint (the binding is compiled out);
/// with the feature it fails at runtime only if the library or a GPU is
/// actually missing.
pub fn nvml_driver() -> anyhow::Result<Box<dyn GpuDriver>> {
    #[cfg(feature = "nvml")]
    {
        Ok(Box::new(nvml::NvmlDriver::open()?))
    }
    #[cfg(not(feature = "nvml"))]
    {
        anyhow::bail!(
            "nvml backend requires building with `--features nvml` \
             (libnvidia-ml is dlopen'd at runtime; no GPU needed to build)"
        )
    }
}

/// Render the `energyucb devices` enumeration table for any driver:
/// index, name, core-clock range, supported-step count, power limit.
/// Deterministic under [`MockDriver`] (pinned by CLI tests).
pub fn devices_table(driver: &dyn GpuDriver) -> anyhow::Result<String> {
    let n = driver.device_count()?;
    let mut t = Table::new(vec!["gpu", "name", "core clocks (MHz)", "steps", "power limit (W)"]);
    for i in 0..n {
        let info = driver.device_info(i)?;
        let clocks = driver.supported_core_clocks_mhz(i)?;
        t.row(vec![
            i.to_string(),
            info.name.clone(),
            format!("{}-{}", info.min_core_mhz, info.max_core_mhz),
            clocks.len().to_string(),
            fnum(info.power_limit_w, 0),
        ]);
    }
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geopm::Service;
    use crate::sim::freq::FreqDomain;
    use crate::sim::node::Node;
    use crate::workload::calibration;

    #[test]
    fn signal_vocabulary_is_total() {
        // Distinct sentinels per field prove each signal maps to its own
        // counter (a collapsed mapping would alias two sentinels).
        let c = DeviceCounters {
            timestamp_s: 1.0,
            energy_j: 2.0,
            power_w: 3.0,
            sm_mhz: 4,
            core_util: 5.0,
            uncore_util: 6.0,
            core_active_s: 7.0,
            uncore_active_s: 8.0,
            progress: 9.0,
            cpu_energy_j: 10.0,
        };
        let mut seen = Vec::new();
        for s in Signal::ALL {
            let v = signal_value(&c, s);
            assert!(v.is_finite(), "{s} unmapped");
            assert!(!seen.contains(&v.to_bits()), "{s} aliases another signal");
            seen.push(v.to_bits());
        }
        assert_eq!(seen.len(), Signal::ALL.len());
        assert_eq!(signal_value(&c, Signal::GpuEnergy), 2.0);
        assert_eq!(signal_value(&c, Signal::Time), 1.0);
        assert_eq!(signal_value(&c, Signal::AppProgress), 9.0);
    }

    #[test]
    fn sim_service_and_hw_share_the_signal_vocabulary() {
        // Every name the hw layer maps must be readable from the
        // simulated service too — same vocabulary, two worlds.
        let app = calibration::app("tealeaf").unwrap();
        let node = Node::new(app, FreqDomain::aurora(), 0.01, 1);
        let service = Service::new(node);
        for s in Signal::ALL {
            assert!(Signal::from_name(s.name()).is_some());
            let v = service.read(s);
            assert!(v.is_finite(), "sim service cannot read {s}");
        }
        // And the control name both sides write under.
        assert_eq!(Control::GpuFrequency(0).name(), "GPU::FREQUENCY_CONTROL");
    }

    #[test]
    fn devices_table_is_pinned_and_deterministic() {
        let app = calibration::app("tealeaf").unwrap();
        let freqs = FreqDomain::aurora();
        let make = || MockDriver::calibrated(&app, &freqs, 2, 0.01, 0);
        let a = devices_table(&make()).unwrap();
        let b = devices_table(&make()).unwrap();
        assert_eq!(a, b, "enumeration must be deterministic");
        assert!(a.contains("Mock PVC GPU 0"), "{a}");
        assert!(a.contains("Mock PVC GPU 1"), "{a}");
        assert!(a.contains("800-1600"), "{a}");
        assert!(a.contains("600"), "{a}");
        // Header + rule + one row per device.
        assert!(a.lines().count() >= 4, "{a}");
    }

    #[cfg(not(feature = "nvml"))]
    #[test]
    fn nvml_driver_requires_the_feature() {
        let err = nvml_driver().err().expect("gated out by default").to_string();
        assert!(err.contains("--features nvml"), "{err}");
    }
}
