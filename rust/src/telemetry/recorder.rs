//! Run-time telemetry recorder: named counters and gauges with
//! per-interval snapshots, plus CSV export. The coordinator uses this to
//! expose operational metrics (decision latency, switch counts, energy
//! rate) without entangling them with the paper-metric accounting in
//! `control::metrics`.

use std::collections::BTreeMap;

use crate::util::io::Csv;
use crate::util::stats::Welford;

/// A monotonically-increasing counter.
#[derive(Clone, Debug, Default)]
pub struct Counter {
    value: u64,
}

impl Counter {
    pub fn inc(&mut self) {
        self.value += 1;
    }

    pub fn add(&mut self, n: u64) {
        self.value += n;
    }

    pub fn get(&self) -> u64 {
        self.value
    }
}

/// A sampled statistic (latency, energy rate, ...).
#[derive(Clone, Debug, Default)]
pub struct Gauge {
    stats: Welford,
    last: f64,
}

impl Gauge {
    pub fn record(&mut self, x: f64) {
        self.stats.push(x);
        self.last = x;
    }

    pub fn last(&self) -> f64 {
        self.last
    }

    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    pub fn std(&self) -> f64 {
        self.stats.std()
    }

    pub fn count(&self) -> u64 {
        self.stats.count()
    }
}

/// Named metric registry.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn counter(&mut self, name: &str) -> &mut Counter {
        self.counters.entry(name.to_string()).or_default()
    }

    pub fn gauge(&mut self, name: &str) -> &mut Gauge {
        self.gauges.entry(name.to_string()).or_default()
    }

    pub fn counter_value(&self, name: &str) -> Option<u64> {
        self.counters.get(name).map(Counter::get)
    }

    pub fn gauge_mean(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).map(Gauge::mean)
    }

    /// Read-only access to a gauge (count/mean/std/last inspection).
    pub fn gauge_get(&self, name: &str) -> Option<&Gauge> {
        self.gauges.get(name)
    }

    /// Install a pre-accumulated counter under `name` (replacing any
    /// existing one). Hot loops accumulate into a plain [`Counter`] local
    /// and merge once — `counter()`'s name lookup allocates per call.
    pub fn insert_counter(&mut self, name: &str, counter: Counter) {
        self.counters.insert(name.to_string(), counter);
    }

    /// Install a pre-accumulated gauge under `name` (replacing any
    /// existing one); see [`Recorder::insert_counter`].
    pub fn insert_gauge(&mut self, name: &str, gauge: Gauge) {
        self.gauges.insert(name.to_string(), gauge);
    }

    /// Render all metrics as CSV (name, kind, count, mean, std, last).
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new();
        csv.row(&["name", "kind", "count", "mean", "std", "last"]);
        for (name, c) in &self.counters {
            csv.row(&[
                name.clone(),
                "counter".into(),
                c.get().to_string(),
                String::new(),
                String::new(),
                String::new(),
            ]);
        }
        for (name, g) in &self.gauges {
            csv.row(&[
                name.clone(),
                "gauge".into(),
                g.count().to_string(),
                format!("{:.6}", g.mean()),
                format!("{:.6}", g.std()),
                format!("{:.6}", g.last()),
            ]);
        }
        csv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut r = Recorder::new();
        r.counter("switches").inc();
        r.counter("switches").add(4);
        assert_eq!(r.counter_value("switches"), Some(5));
        assert_eq!(r.counter_value("missing"), None);
    }

    #[test]
    fn gauges_track_stats() {
        let mut r = Recorder::new();
        for x in [1.0, 2.0, 3.0] {
            r.gauge("latency_us").record(x);
        }
        assert_eq!(r.gauge_mean("latency_us"), Some(2.0));
        assert_eq!(r.gauges["latency_us"].last(), 3.0);
    }

    #[test]
    fn csv_has_all_metrics() {
        let mut r = Recorder::new();
        r.counter("a").inc();
        r.gauge("b").record(1.5);
        let text = r.to_csv().render();
        assert!(text.contains("a,counter,1"));
        assert!(text.contains("b,gauge,1"));
    }
}
