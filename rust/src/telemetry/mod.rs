//! Operational telemetry (counters/gauges + CSV export), separate from the
//! paper-metric accounting in `control::metrics`.

pub mod recorder;

pub use recorder::{Counter, Gauge, Recorder};
