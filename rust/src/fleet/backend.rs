//! The fleet tier's [`TelemetryBackend`]: the vectorized environment
//! dynamics behind the batch-native control loop.
//!
//! [`FleetBackend`] owns one decision interval's world-side work —
//! noise draw, [`apply_env_dynamics`][native::apply_env_dynamics]
//! (verbatim the bit-pinned EnergyUCB arithmetic), sample capture, and
//! the previous-arm/clock advance — so `fleet::policy_run` is a thin
//! wrapper over the one [`drive`][crate::control::drive] loop the
//! session tier uses. Bit-identity with `native_run` is pinned by the
//! fleet policy tests and the batch-controller conformance suite: the
//! noise stream position, the operation order inside the dynamics, and
//! the pre-advance `prev` read for switch accounting are all unchanged;
//! only the policy's `update_batch` moves after the dynamics (into
//! `Controller::observe`), which is safe because the policy grids and
//! [`FleetState`] are disjoint and `state.t` is only read at the next
//! selection.

use crate::bandit::batch::BatchPolicy;
use crate::bandit::RewardForm;
use crate::control::{BackendTotals, BatchOpts, Controller, EnvSpec, StepSample, TelemetryBackend};
use crate::util::Rng;
use crate::workload::serving::ServingModel;

use super::native::{self, StepScratch};
use super::state::{FleetParams, FleetState};

/// Batch telemetry source over B fleet environments (see module docs).
pub struct FleetBackend<'a> {
    state: &'a mut FleetState,
    params: &'a FleetParams,
    rng: &'a mut Rng,
    scratch: StepScratch,
    noise: Vec<f32>,
    samples: Vec<StepSample>,
    steps: u64,
    // Serving tier: one arrival-process model per row, stepped after
    // the bit-pinned dynamics so the HLO contract is untouched. `None`
    // (the default) emits context-free samples.
    serving: Option<Vec<ServingModel>>,
}

impl<'a> FleetBackend<'a> {
    pub fn new(
        state: &'a mut FleetState,
        params: &'a FleetParams,
        rng: &'a mut Rng,
    ) -> FleetBackend<'a> {
        assert_eq!(state.b, params.b, "state/params batch mismatch");
        assert_eq!(state.k, params.k, "state/params arity mismatch");
        let b = state.b;
        FleetBackend {
            state,
            params,
            rng,
            scratch: StepScratch::new(b),
            noise: vec![0.0f32; b],
            samples: vec![StepSample::default(); b],
            steps: 0,
            serving: None,
        }
    }

    /// Attach one serving workload per row: every row's sample then
    /// carries its model's feature vector, stepped under the applied
    /// arm's relative throughput (`(arm + 1) / K`).
    pub fn with_serving(mut self, models: Vec<ServingModel>) -> FleetBackend<'a> {
        assert_eq!(models.len(), self.state.b, "one serving model per fleet row");
        self.serving = Some(models);
        self
    }

    /// Decision intervals advanced so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }
}

impl TelemetryBackend for FleetBackend<'_> {
    fn b(&self) -> usize {
        self.state.b
    }

    fn k(&self) -> usize {
        self.state.k
    }

    fn apply(&mut self, sel: &[i32]) -> anyhow::Result<()> {
        let (b, k) = (self.state.b, self.state.k);
        anyhow::ensure!(sel.len() == b, "fleet backend: {} selections for B = {b}", sel.len());
        for &s in sel {
            anyhow::ensure!(
                s >= 0 && (s as usize) < k,
                "fleet backend: arm {s} out of range (K = {k})"
            );
        }
        self.scratch.sel.copy_from_slice(sel);
        // Same noise stream position as `native_run`: one draw per
        // interval, 0-based early-window index.
        native::step_noise_into(self.params, self.steps, self.rng, &mut self.noise);
        native::apply_env_dynamics(self.state, self.params, &self.noise, &mut self.scratch);
        // Capture samples before advancing `prev` — the switch flag reads
        // the pre-update previous arm, exactly as the dynamics did.
        for e in 0..b {
            let row = e * k;
            let s = sel[e] as usize;
            let active = self.scratch.active[e] > 0.0;
            let switched = active && sel[e] != self.state.prev[e];
            // Per-step energy recomputed from the parameters (not as a
            // delta of the growing f32 accumulator, which would lose
            // low bits).
            let energy = ((self.params.energy_step[row + s]
                + self.params.switch_energy_j * if switched { 1.0 } else { 0.0 })
                * self.scratch.active[e]) as f64;
            self.samples[e] = StepSample {
                gpu_energy_j: energy,
                core_util: 0.0,
                uncore_util: 0.0,
                progress: self.scratch.progress[e],
                remaining: self.state.remaining[e] as f64,
                true_gpu_energy_j: energy,
                switched,
                // The fleet model synthesizes normalized rewards directly
                // (f32 widened exactly to f64) — no RewardForm pass.
                reward: Some(self.scratch.reward[e]),
                active,
                context: None,
            };
            if let Some(models) = self.serving.as_mut() {
                let scale = (s + 1) as f64 / k as f64;
                self.samples[e].context = Some(models[e].step(scale));
            }
        }
        for e in 0..b {
            if self.scratch.active[e] > 0.0 {
                self.state.prev[e] = sel[e];
            }
        }
        self.state.t += 1.0;
        self.steps += 1;
        Ok(())
    }

    fn sample_into(&mut self, out: &mut [StepSample]) -> anyhow::Result<()> {
        anyhow::ensure!(
            out.len() == self.samples.len(),
            "fleet backend: {} sample slots for B = {}",
            out.len(),
            self.samples.len()
        );
        out.copy_from_slice(&self.samples);
        Ok(())
    }

    fn done(&self) -> bool {
        self.state.all_done()
    }

    fn totals(&self) -> Vec<BackendTotals> {
        let dt = self.params.dt_s;
        (0..self.state.b)
            .map(|e| BackendTotals {
                gpu_energy_kj: self.state.energy_kj(e),
                exec_time_s: self.steps as f64 * dt,
                switches: self.state.switches[e] as u64,
                switch_energy_j: self.state.switches[e] as f64
                    * self.params.switch_energy_j as f64,
                switch_time_s: self.state.switches[e] as f64
                    * self.params.switch_stall_frac as f64
                    * dt,
            })
            .collect()
    }
}

/// Build the batch controller for a fleet drive: per-row ground truth
/// from the calibrated parameter block (names, f32 reward means widened
/// exactly to f64, best-feasible regret baseline matching
/// [`FleetParams::best_reward`]), no traces or checkpoints — the fleet
/// tier's accounting of record lives in [`FleetState`].
pub fn fleet_controller<'p>(
    params: &FleetParams,
    driver: Box<dyn BatchPolicy + 'p>,
    max_steps: u64,
) -> Controller<'p> {
    let (b, k) = (params.b, params.k);
    assert_eq!(driver.b(), b, "policy batch != fleet batch");
    assert_eq!(driver.k(), k, "policy arity != fleet arity");
    let envs = (0..b)
        .map(|e| EnvSpec {
            app: params.names.get(e).cloned().unwrap_or_else(|| format!("env{e}")),
            true_rewards: params.reward_mean[e * k..(e + 1) * k]
                .iter()
                .map(|&x| x as f64)
                .collect(),
        })
        .collect();
    let opts = BatchOpts {
        // Unused: fleet samples carry preformed rewards.
        reward_form: RewardForm::EnergyRatio,
        max_steps,
        record_trace: false,
        checkpoints: 0,
        feasible: Some(params.feasible.clone()),
    };
    Controller::new_batch(envs, driver, &opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::freq::FreqDomain;
    use crate::workload::calibration;

    fn setup(names: &[&str]) -> (FleetState, FleetParams) {
        let freqs = FreqDomain::aurora();
        let apps: Vec<_> = names.iter().map(|n| calibration::app(n).unwrap()).collect();
        let refs: Vec<&_> = apps.iter().collect();
        let params = FleetParams::from_apps(&refs, &freqs, 0.01);
        (FleetState::fresh(names.len(), 9), params)
    }

    #[test]
    fn backend_advances_state_like_the_native_dynamics() {
        let (mut state, params) = setup(&["tealeaf", "clvleaf"]);
        let mut rng = Rng::new(7);
        let mut backend = FleetBackend::new(&mut state, &params, &mut rng);
        assert_eq!(backend.b(), 2);
        assert_eq!(backend.k(), 9);
        assert!(!backend.done());
        assert!(backend.apply(&[9, 0]).is_err());
        assert!(backend.apply(&[0]).is_err());
        backend.apply(&[3, 8]).unwrap();
        let mut out = vec![StepSample::default(); 2];
        backend.sample_into(&mut out).unwrap();
        // Env 0 switched off the initial arm 8; env 1 stayed.
        assert!(out[0].switched);
        assert!(!out[1].switched);
        assert!(out[0].reward.is_some());
        assert!(out[0].gpu_energy_j > 0.0);
        assert_eq!(backend.steps(), 1);
        let totals = backend.totals();
        assert_eq!(totals.len(), 2);
        assert!((totals[0].exec_time_s - 0.01).abs() < 1e-12);
        assert_eq!(totals[0].switches, 1);
        assert_eq!(totals[1].switches, 0);
        drop(backend);
        assert_eq!(state.prev, vec![3, 8]);
        assert_eq!(state.t, 2.0);
    }

    #[test]
    fn fleet_controller_rows_carry_app_names() {
        let (_, params) = setup(&["tealeaf", "lbm"]);
        let driver = Box::new(crate::bandit::batch::BatchUcb1::new(2, 9, 0.05));
        let c = fleet_controller(&params, driver, 100);
        assert_eq!(c.b(), 2);
        assert_eq!(c.k(), 9);
    }
}
