//! Vectorized fleet Monte Carlo: B independent bandit environments
//! advanced in lockstep, either through the AOT-compiled HLO artifact
//! ([`engine::FleetEngine`], PJRT), the bit-compatible pure-Rust
//! EnergyUCB reference ([`native`]), or the generic batch-policy runner
//! ([`policy`] — any [`crate::bandit::BatchPolicy`], including mixed
//! fleets). Used for seed-variance studies, regret-curve averaging, and
//! the paper's fleet-scale energy extrapolation. All decision arithmetic
//! lives in the shared batch policy core (`bandit::batch`).

pub mod engine;
pub mod native;
pub mod policy;
pub mod state;

pub use engine::FleetEngine;
pub use native::StepScratch;
pub use policy::{build_fleet_policy, policy_run, policy_step};
pub use state::{FleetHyper, FleetParams, FleetState};
