//! Vectorized fleet Monte Carlo: B independent bandit environments
//! advanced in lockstep, either through the AOT-compiled HLO artifact
//! ([`engine::FleetEngine`], PJRT) or the bit-compatible pure-Rust
//! reference ([`native`]). Used for seed-variance studies, regret-curve
//! averaging, and the paper's fleet-scale energy extrapolation.

pub mod engine;
pub mod native;
pub mod state;

pub use engine::FleetEngine;
pub use state::{FleetHyper, FleetParams, FleetState};
