//! Vectorized fleet Monte Carlo: B independent bandit environments
//! advanced in lockstep, either through the AOT-compiled HLO artifact
//! ([`engine::FleetEngine`], PJRT), the bit-compatible pure-Rust
//! EnergyUCB reference ([`native`]), or the generic batch-policy runner
//! ([`policy`] — any [`crate::bandit::BatchPolicy`], including mixed
//! fleets, routed through the batch-native control loop via
//! [`backend::FleetBackend`]). Used for seed-variance studies,
//! regret-curve averaging, and the paper's fleet-scale energy
//! extrapolation. All decision arithmetic lives in the shared batch
//! policy core (`bandit::batch`).

pub mod backend;
pub mod engine;
pub mod native;
pub mod policy;
pub mod state;

pub use backend::{fleet_controller, FleetBackend};
pub use engine::FleetEngine;
pub use native::StepScratch;
pub use policy::{build_fleet_policy, policy_drive, policy_run};
pub use state::{FleetHyper, FleetParams, FleetState};
