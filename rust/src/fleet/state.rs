//! Fleet state and per-environment parameters for the vectorized Monte
//! Carlo engine.
//!
//! A fleet is B independent (app, seed) bandit environments advanced in
//! lockstep. The parameter block holds each environment's calibrated
//! per-arm quantities (normalized expected reward, reward noise, Joules and
//! progress per interval, QoS mask); the state block is the controllers'
//! learned state plus accounting. Layouts are row-major (B, K) f32,
//! matching the AOT artifact contract in `python/compile/model.py`.

use crate::config::PolicyConfig;
use crate::sim::freq::FreqDomain;
use crate::workload::model::AppModel;

/// Hyper-parameters fed to the step (matches `EnergyUcbConfig` semantics).
/// The definition lives in the batch policy core — the single source of
/// the SA-UCB arithmetic — and is re-exported here under its fleet name.
pub use crate::bandit::batch::SaUcbHyper as FleetHyper;

/// Per-environment calibrated parameters, row-major (B, K).
#[derive(Clone, Debug)]
pub struct FleetParams {
    pub b: usize,
    pub k: usize,
    pub reward_mean: Vec<f32>,
    pub reward_sigma: Vec<f32>,
    pub energy_step: Vec<f32>,
    pub progress: Vec<f32>,
    pub feasible: Vec<f32>,
    /// Early-window noise inflation per env (multiplier, steps).
    pub early_mult: Vec<f32>,
    pub early_steps: Vec<u32>,
    /// Fraction of a decision interval lost to one DVFS transition,
    /// derived from the domain's [`crate::sim::freq::SwitchCost`] (paper
    /// default: 150 µs of a 10 ms interval = 0.015). Shared with the
    /// python export (`python/compile/kernels/ref.py::SWITCH_STALL_FRAC`).
    pub switch_stall_frac: f32,
    /// Joules charged per node-level DVFS transition (paper default:
    /// 0.3 J; `ref.py::SWITCH_ENERGY_J`).
    pub switch_energy_j: f32,
    /// Decision interval the parameters were derived at, seconds (needed
    /// to reconstitute wall-clock totals from step counts).
    pub dt_s: f64,
    /// Calibrated app name per environment row (provenance for the
    /// controller tier's per-env metrics and the replay header roster).
    pub names: Vec<String>,
    /// Policy selector: empty = the classic EnergyUCB fleet (driven by
    /// [`FleetHyper`], the bit-pinned artifact path). One entry = that
    /// policy batched natively where an SoA implementation exists
    /// (`PolicyConfig::build_batch`). Multiple entries = a mixed-policy
    /// fleet, environment `e` running `policies[e % len]` through the
    /// scalar bridge. Consumed by `fleet::policy::build_fleet_policy`.
    pub policies: Vec<PolicyConfig>,
}

impl FleetParams {
    /// Build a fleet from `(app)` assignments, one env per entry; the
    /// reward normalization scale is |true reward at the max frequency|
    /// (the arm every run starts from).
    pub fn from_apps(apps: &[&AppModel], freqs: &FreqDomain, dt_s: f64) -> FleetParams {
        let b = apps.len();
        let k = freqs.k();
        let cost = freqs.switch_cost();
        let mut p = FleetParams {
            b,
            k,
            reward_mean: vec![0.0; b * k],
            reward_sigma: vec![0.0; b * k],
            energy_step: vec![0.0; b * k],
            progress: vec![0.0; b * k],
            feasible: vec![1.0; b * k],
            early_mult: vec![1.0; b],
            early_steps: vec![0; b],
            // Clamped to one interval: a stall >= dt would run work backwards.
            switch_stall_frac: (cost.latency_s / dt_s).min(1.0) as f32,
            switch_energy_j: cost.energy_j as f32,
            dt_s,
            names: apps.iter().map(|a| a.name.to_string()).collect(),
            policies: Vec::new(),
        };
        for (e, app) in apps.iter().enumerate() {
            let scale = app.true_reward(freqs, freqs.max_arm(), dt_s).abs();
            // Combined relative reward noise: energy counter noise plus the
            // utilization-ratio contribution (first-order).
            let rel_noise = app.noise.energy_frac
                + app.noise.util_std * (1.0 / app.core_util + 1.0);
            for i in 0..k {
                let idx = e * k + i;
                let mu = app.true_reward(freqs, i, dt_s) / scale;
                p.reward_mean[idx] = mu as f32;
                p.reward_sigma[idx] = (mu.abs() * rel_noise) as f32;
                p.energy_step[idx] = app.energy_per_step_j(freqs, i, dt_s) as f32;
                p.progress[idx] = app.progress_per_step(freqs, i, dt_s) as f32;
            }
            p.early_mult[e] = app.noise.early_mult as f32;
            p.early_steps[e] = (app.noise.early_window_s / dt_s).round() as u32;
        }
        p
    }

    /// Apply a QoS feasibility mask from a slowdown budget (oracle mask —
    /// the fleet engine models the constrained variant's steady state).
    pub fn constrain(&mut self, apps: &[&AppModel], freqs: &FreqDomain, delta: f64) {
        assert_eq!(apps.len(), self.b);
        for (e, app) in apps.iter().enumerate() {
            for i in 0..self.k {
                let feasible = i == self.k - 1 || app.slowdown(freqs, i) <= delta;
                self.feasible[e * self.k + i] = if feasible { 1.0 } else { 0.0 };
            }
        }
        // Arm k-1 is always kept, so every row stays selectable — guard
        // the invariant where the mask is built (see
        // `bandit::batch::saucb_select_into`'s all-infeasible contract).
        crate::bandit::batch::debug_assert_feasible_rows(&self.feasible, self.k);
    }

    /// Best (feasible) normalized reward per env.
    pub fn best_reward(&self, e: usize) -> f32 {
        let row = &self.reward_mean[e * self.k..(e + 1) * self.k];
        let feas = &self.feasible[e * self.k..(e + 1) * self.k];
        row.iter()
            .zip(feas)
            .filter(|(_, &f)| f > 0.0)
            .map(|(r, _)| *r)
            .fold(f32::NEG_INFINITY, f32::max)
    }
}

/// Mutable fleet state (controllers + accounting).
#[derive(Clone, Debug, PartialEq)]
pub struct FleetState {
    pub b: usize,
    pub k: usize,
    pub n: Vec<f32>,
    pub mean: Vec<f32>,
    pub prev: Vec<i32>,
    pub t: f32,
    pub remaining: Vec<f32>,
    pub cum_energy: Vec<f32>,
    pub cum_regret: Vec<f32>,
    pub switches: Vec<f32>,
}

impl FleetState {
    /// Fresh fleet: everything zero, previous arm = the max frequency
    /// (Aurora's default), full remaining work.
    pub fn fresh(b: usize, k: usize) -> FleetState {
        FleetState {
            b,
            k,
            n: vec![0.0; b * k],
            mean: vec![0.0; b * k],
            prev: vec![(k - 1) as i32; b],
            t: 1.0,
            remaining: vec![1.0; b],
            cum_energy: vec![0.0; b],
            cum_regret: vec![0.0; b],
            switches: vec![0.0; b],
        }
    }

    /// All environments finished?
    pub fn all_done(&self) -> bool {
        self.remaining.iter().all(|&r| r <= 0.0)
    }

    /// Number of still-running environments.
    pub fn active_count(&self) -> usize {
        self.remaining.iter().filter(|&&r| r > 0.0).count()
    }

    /// Total energy in kJ per env.
    pub fn energy_kj(&self, e: usize) -> f64 {
        self.cum_energy[e] as f64 / 1_000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    #[test]
    fn params_from_apps_shapes() {
        let freqs = FreqDomain::aurora();
        let a = calibration::app("tealeaf").unwrap();
        let b = calibration::app("lbm").unwrap();
        let apps = vec![&a, &b];
        let p = FleetParams::from_apps(&apps, &freqs, 0.01);
        assert_eq!(p.b, 2);
        assert_eq!(p.k, 9);
        assert_eq!(p.reward_mean.len(), 18);
        // Normalization: reward at max arm = -1.
        assert!((p.reward_mean[8] - (-1.0)).abs() < 1e-6);
        assert!((p.reward_mean[9 + 8] - (-1.0)).abs() < 1e-6);
    }

    #[test]
    fn switch_constants_derive_from_domain_cost() {
        // Regression: the native step used to hard-code 0.015 / 0.3, which
        // could silently drift from SwitchCost.
        let freqs = FreqDomain::aurora();
        let a = calibration::app("tealeaf").unwrap();
        let p = FleetParams::from_apps(&[&a], &freqs, 0.01);
        let cost = freqs.switch_cost();
        assert!((p.switch_stall_frac as f64 - cost.latency_s / 0.01).abs() < 1e-9);
        assert!((p.switch_stall_frac - 0.015).abs() < 1e-9);
        assert!((p.switch_energy_j as f64 - cost.energy_j).abs() < 1e-9);
        // A custom cost flows through.
        let custom = freqs
            .clone()
            .with_switch_cost(crate::sim::freq::SwitchCost { latency_s: 200e-6, energy_j: 0.6 });
        let p = FleetParams::from_apps(&[&a], &custom, 0.01);
        assert!((p.switch_stall_frac - 0.02).abs() < 1e-7);
        assert!((p.switch_energy_j - 0.6).abs() < 1e-7);
    }

    #[test]
    fn best_reward_is_energy_optimum() {
        let freqs = FreqDomain::aurora();
        let a = calibration::app("tealeaf").unwrap();
        let p = FleetParams::from_apps(&[&a], &freqs, 0.01);
        let best_arm = a.optimal_arm();
        let row = &p.reward_mean[0..9];
        let argmax = crate::util::stats::argmax(&row.iter().map(|x| *x as f64).collect::<Vec<_>>());
        assert_eq!(argmax, best_arm);
    }

    #[test]
    fn constrain_masks_slow_arms() {
        let freqs = FreqDomain::aurora();
        let a = calibration::app("clvleaf").unwrap();
        let mut p = FleetParams::from_apps(&[&a], &freqs, 0.01);
        p.constrain(&[&a], &freqs, 0.05);
        // clvleaf theta=0.5: arm 0 slowdown 0.5 -> masked; arm 8 always ok.
        assert_eq!(p.feasible[0], 0.0);
        assert_eq!(p.feasible[8], 1.0);
        // Some mid arm feasible: s(1.5GHz) = 0.5*(1.6/1.5-1) = 0.033.
        assert_eq!(p.feasible[7], 1.0);
    }

    #[test]
    fn fresh_state_invariants() {
        let s = FleetState::fresh(4, 9);
        assert!(!s.all_done());
        assert_eq!(s.active_count(), 4);
        assert!(s.prev.iter().all(|&p| p == 8));
        assert_eq!(s.t, 1.0);
    }
}
