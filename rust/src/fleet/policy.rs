//! Generic batch-policy fleet runner: drive B environments under *any*
//! [`BatchPolicy`] — native SoA implementations (EnergyUCB/SA-UCB, UCB1,
//! SW-UCB, ε-greedy, the QoS-constrained variant) or the scalar bridge
//! (Thompson, static, round-robin, the RL baselines, heterogeneous
//! mixed-policy fleets).
//!
//! Since the batch-native controller refactor this module holds no loop
//! of its own: [`policy_run`] composes
//! [`fleet_controller`][super::backend::fleet_controller] with
//! [`FleetBackend`][super::backend::FleetBackend] and hands both to the
//! one [`drive`][crate::control::drive] loop the session tier uses. The
//! environment dynamics are literally the ones the bit-pinned EnergyUCB
//! path uses (`native::apply_env_dynamics`). Driving a
//! [`BatchEnergyUcb`][crate::bandit::BatchEnergyUcb] built with
//! `with_initial_arm(k-1)` therefore reproduces `native::native_run`'s
//! accounting trajectory bit-for-bit (pinned below and by the
//! batch-controller conformance suite) — the policy owns its grids,
//! while `native_run` keeps them in `FleetState` for the HLO artifact
//! contract.

use super::backend::{fleet_controller, FleetBackend};
use super::state::{FleetHyper, FleetParams, FleetState};
use crate::bandit::batch::{BatchEnergyUcb, BatchPolicy, Scalar};
use crate::bandit::Policy as ScalarPolicy;
use crate::control::{drive, RunResult};
use crate::util::Rng;

/// Run the fleet under `policy` until every environment completes (or
/// `max_steps`), through the shared batch-native control loop, and
/// return the per-environment [`RunResult`]s (row order). `state` holds
/// the fleet-side accounting exactly as before; the results add the
/// controller tier's view (per-env metrics, regret, telemetry).
pub fn policy_drive(
    state: &mut FleetState,
    params: &FleetParams,
    policy: &mut dyn BatchPolicy,
    rng: &mut Rng,
    max_steps: u64,
) -> Vec<RunResult> {
    let controller = fleet_controller(params, Box::new(policy), max_steps);
    let mut backend = FleetBackend::new(state, params, rng);
    drive(controller, &mut backend).expect("fleet backend is infallible")
}

/// Run the fleet under `policy` until every environment completes (or
/// `max_steps`). Returns the steps taken. Thin wrapper over
/// [`policy_drive`] for callers that only consume [`FleetState`].
pub fn policy_run(
    state: &mut FleetState,
    params: &FleetParams,
    policy: &mut dyn BatchPolicy,
    rng: &mut Rng,
    max_steps: u64,
) -> u64 {
    policy_drive(state, params, policy, rng, max_steps)
        .first()
        .map(|r| r.metrics.steps)
        .unwrap_or(0)
}

/// Build the batch policy `params.policies` selects (see
/// [`FleetParams::policies`]): empty = the classic EnergyUCB fleet from
/// `hyper` (every environment starting pinned to the default-frequency
/// arm K-1, matching `FleetState::fresh`); one entry = that policy batched
/// natively where possible; several = a mixed fleet over the scalar
/// bridge, environment `e` running `policies[e % len]` seeded `seed + e`.
pub fn build_fleet_policy(
    params: &FleetParams,
    hyper: &FleetHyper,
    seed: u64,
) -> Box<dyn BatchPolicy> {
    let (b, k) = (params.b, params.k);
    match params.policies.len() {
        0 => Box::new(BatchEnergyUcb::with_initial_arm(b, k, *hyper, k - 1)),
        1 => params.policies[0].build_batch(b, k, seed),
        n => {
            let envs: Vec<Box<dyn ScalarPolicy>> = (0..b)
                .map(|e| params.policies[e % n].build(k, seed.wrapping_add(e as u64)))
                .collect();
            Box::new(Scalar::new(envs))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::fleet::native;
    use crate::sim::freq::FreqDomain;
    use crate::workload::calibration;

    fn setup(names: &[&str]) -> (FleetState, FleetParams) {
        let freqs = FreqDomain::aurora();
        let apps: Vec<_> = names.iter().map(|n| calibration::app(n).unwrap()).collect();
        let refs: Vec<&_> = apps.iter().collect();
        let params = FleetParams::from_apps(&refs, &freqs, 0.01);
        (FleetState::fresh(names.len(), 9), params)
    }

    /// The default selector reproduces the bit-pinned native EnergyUCB
    /// accounting trajectory exactly (the policy owns the grids, so
    /// `FleetState.n/mean` stay untouched — everything else must match).
    #[test]
    fn default_policy_matches_native_run_bit_for_bit() {
        let (mut nat, params) = setup(&["tealeaf", "clvleaf", "lbm"]);
        let mut gen = nat.clone();
        let hyper = FleetHyper::default();

        let mut r1 = Rng::new(11);
        native::native_run(&mut nat, &params, &hyper, &mut r1, 3_000);

        let mut policy = build_fleet_policy(&params, &hyper, 11);
        let mut r2 = Rng::new(11);
        policy_run(&mut gen, &params, policy.as_mut(), &mut r2, 3_000);

        assert_eq!(nat.t, gen.t);
        assert_eq!(nat.prev, gen.prev);
        assert_eq!(nat.remaining, gen.remaining);
        assert_eq!(nat.cum_energy, gen.cum_energy);
        assert_eq!(nat.cum_regret, gen.cum_regret);
        assert_eq!(nat.switches, gen.switches);
    }

    /// The drive path's per-env results agree with the fleet-state
    /// accounting they ride alongside.
    #[test]
    fn policy_drive_results_mirror_fleet_state() {
        let (mut state, params) = setup(&["tealeaf", "clvleaf"]);
        let mut policy = build_fleet_policy(&params, &FleetHyper::default(), 3);
        let results =
            policy_drive(&mut state, &params, policy.as_mut(), &mut Rng::new(3), 2_500);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].metrics.app, "tealeaf");
        assert_eq!(results[1].metrics.app, "clvleaf");
        for (e, r) in results.iter().enumerate() {
            assert_eq!(r.metrics.gpu_energy_kj, state.energy_kj(e), "env {e}");
            assert_eq!(r.metrics.switches, state.switches[e] as u64, "env {e}");
            assert_eq!(r.metrics.steps, 2_500);
        }
    }

    #[test]
    fn non_energyucb_policies_run_the_fleet() {
        for cfg in [
            PolicyConfig::Ucb1 { alpha: 0.05 },
            PolicyConfig::SwUcb { alpha: 0.05, lambda: 0.01, window: 500 },
            PolicyConfig::EpsilonGreedy { eps0: 0.05, decay_c: 20.0 },
            PolicyConfig::EnergyTs,
            PolicyConfig::Static { arm: 8 },
        ] {
            let (mut state, mut params) = setup(&["tealeaf", "clvleaf"]);
            params.policies = vec![cfg.clone()];
            let mut policy = build_fleet_policy(&params, &FleetHyper::default(), 5);
            let steps =
                policy_run(&mut state, &params, policy.as_mut(), &mut Rng::new(5), 2_000);
            assert!(steps > 0, "{cfg:?}");
            assert!(state.cum_energy.iter().all(|&e| e > 0.0), "{cfg:?}");
            // Deterministic given seed.
            let (mut again, _) = setup(&["tealeaf", "clvleaf"]);
            let mut policy2 = build_fleet_policy(&params, &FleetHyper::default(), 5);
            policy_run(&mut again, &params, policy2.as_mut(), &mut Rng::new(5), 2_000);
            assert_eq!(state.cum_energy, again.cum_energy, "{cfg:?}");
        }
    }

    #[test]
    fn mixed_policy_fleet_assigns_round_robin() {
        let (mut state, mut params) = setup(&["tealeaf", "tealeaf", "tealeaf"]);
        params.policies =
            vec![PolicyConfig::Static { arm: 8 }, PolicyConfig::RoundRobin];
        let mut policy = build_fleet_policy(&params, &FleetHyper::default(), 1);
        assert!(policy.name().starts_with("Mixed["), "{}", policy.name());
        policy_run(&mut state, &params, policy.as_mut(), &mut Rng::new(1), 500);
        // Env 0 and 2 hold the default arm (zero switches); env 1 cycles.
        assert_eq!(state.switches[0], 0.0);
        assert_eq!(state.switches[2], 0.0);
        assert!(state.switches[1] > 100.0);
    }

    #[test]
    fn static_fleet_energy_matches_calibration() {
        // Static arm 8 on tealeaf = the 1.6 GHz default: 109.79 kJ.
        let (mut state, mut params) = setup(&["tealeaf"]);
        params.policies = vec![PolicyConfig::Static { arm: 8 }];
        let mut policy = build_fleet_policy(&params, &FleetHyper::default(), 2);
        policy_run(&mut state, &params, policy.as_mut(), &mut Rng::new(2), 100_000);
        assert!(state.all_done());
        let kj = state.energy_kj(0);
        assert!((kj - 109.79).abs() < 2.0, "kj={kj}");
    }
}
