//! Generic batch-policy fleet runner: drive B environments under *any*
//! [`BatchPolicy`] — native SoA implementations (EnergyUCB/SA-UCB, UCB1,
//! SW-UCB, ε-greedy, the QoS-constrained variant) or the scalar bridge
//! (Thompson, static, round-robin, the RL baselines, heterogeneous
//! mixed-policy fleets).
//!
//! The environment dynamics are literally the ones the bit-pinned
//! EnergyUCB path uses (`native::apply_env_dynamics`); only the
//! select/update calls go through the trait. Driving a
//! [`BatchEnergyUcb`][crate::bandit::BatchEnergyUcb] built with
//! `with_initial_arm(k-1)` therefore reproduces `native::native_run`'s
//! accounting trajectory bit-for-bit (pinned by the policy-contract
//! suite) — the policy owns its grids, while `native_run` keeps them in
//! `FleetState` for the HLO artifact contract.

use super::native::{self, StepScratch};
use super::state::{FleetHyper, FleetParams, FleetState};
use crate::bandit::batch::{BatchEnergyUcb, BatchPolicy, Scalar};
use crate::bandit::Policy as ScalarPolicy;
use crate::util::Rng;

/// Advance the fleet one decision interval under `policy`
/// (allocation-free; buffers live in `scratch`).
pub fn policy_step(
    state: &mut FleetState,
    params: &FleetParams,
    policy: &mut dyn BatchPolicy,
    noise: &[f32],
    scratch: &mut StepScratch,
) {
    let (b, k) = (state.b, state.k);
    assert_eq!(policy.b(), b, "policy batch != fleet batch");
    assert_eq!(policy.k(), k, "policy arity != fleet arity");
    assert_eq!(noise.len(), b);
    scratch.ensure(b);
    policy.select_into(state.t as u64, &params.feasible, &mut scratch.sel);
    native::apply_env_dynamics(state, params, noise, scratch);
    // Advance the engine-side previous-arm record (switch accounting reads
    // it pre-update) — the policy keeps its own notion of prev internally.
    for e in 0..b {
        if scratch.active[e] > 0.0 {
            state.prev[e] = scratch.sel[e];
        }
    }
    policy.update_batch(&scratch.sel, &scratch.reward, &scratch.progress, &scratch.active);
    state.t += 1.0;
}

/// Run the fleet under `policy` until every environment completes (or
/// `max_steps`). Buffers are allocated once; returns the steps taken.
pub fn policy_run(
    state: &mut FleetState,
    params: &FleetParams,
    policy: &mut dyn BatchPolicy,
    rng: &mut Rng,
    max_steps: u64,
) -> u64 {
    let mut scratch = StepScratch::new(state.b);
    let mut noise = vec![0.0f32; state.b];
    let mut steps = 0;
    while !state.all_done() && steps < max_steps {
        native::step_noise_into(params, steps, rng, &mut noise);
        policy_step(state, params, policy, &noise, &mut scratch);
        steps += 1;
    }
    steps
}

/// Build the batch policy `params.policies` selects (see
/// [`FleetParams::policies`]): empty = the classic EnergyUCB fleet from
/// `hyper` (every environment starting pinned to the default-frequency
/// arm K-1, matching `FleetState::fresh`); one entry = that policy batched
/// natively where possible; several = a mixed fleet over the scalar
/// bridge, environment `e` running `policies[e % len]` seeded `seed + e`.
pub fn build_fleet_policy(
    params: &FleetParams,
    hyper: &FleetHyper,
    seed: u64,
) -> Box<dyn BatchPolicy> {
    let (b, k) = (params.b, params.k);
    match params.policies.len() {
        0 => Box::new(BatchEnergyUcb::with_initial_arm(b, k, *hyper, k - 1)),
        1 => params.policies[0].build_batch(b, k, seed),
        n => {
            let envs: Vec<Box<dyn ScalarPolicy>> = (0..b)
                .map(|e| params.policies[e % n].build(k, seed.wrapping_add(e as u64)))
                .collect();
            Box::new(Scalar::new(envs))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyConfig;
    use crate::sim::freq::FreqDomain;
    use crate::workload::calibration;

    fn setup(names: &[&str]) -> (FleetState, FleetParams) {
        let freqs = FreqDomain::aurora();
        let apps: Vec<_> = names.iter().map(|n| calibration::app(n).unwrap()).collect();
        let refs: Vec<&_> = apps.iter().collect();
        let params = FleetParams::from_apps(&refs, &freqs, 0.01);
        (FleetState::fresh(names.len(), 9), params)
    }

    /// The default selector reproduces the bit-pinned native EnergyUCB
    /// accounting trajectory exactly (the policy owns the grids, so
    /// `FleetState.n/mean` stay untouched — everything else must match).
    #[test]
    fn default_policy_matches_native_run_bit_for_bit() {
        let (mut nat, params) = setup(&["tealeaf", "clvleaf", "lbm"]);
        let mut gen = nat.clone();
        let hyper = FleetHyper::default();

        let mut r1 = Rng::new(11);
        native::native_run(&mut nat, &params, &hyper, &mut r1, 3_000);

        let mut policy = build_fleet_policy(&params, &hyper, 11);
        let mut r2 = Rng::new(11);
        policy_run(&mut gen, &params, policy.as_mut(), &mut r2, 3_000);

        assert_eq!(nat.t, gen.t);
        assert_eq!(nat.prev, gen.prev);
        assert_eq!(nat.remaining, gen.remaining);
        assert_eq!(nat.cum_energy, gen.cum_energy);
        assert_eq!(nat.cum_regret, gen.cum_regret);
        assert_eq!(nat.switches, gen.switches);
    }

    #[test]
    fn non_energyucb_policies_run_the_fleet() {
        for cfg in [
            PolicyConfig::Ucb1 { alpha: 0.05 },
            PolicyConfig::SwUcb { alpha: 0.05, lambda: 0.01, window: 500 },
            PolicyConfig::EpsilonGreedy { eps0: 0.05, decay_c: 20.0 },
            PolicyConfig::EnergyTs,
            PolicyConfig::Static { arm: 8 },
        ] {
            let (mut state, mut params) = setup(&["tealeaf", "clvleaf"]);
            params.policies = vec![cfg.clone()];
            let mut policy = build_fleet_policy(&params, &FleetHyper::default(), 5);
            let steps =
                policy_run(&mut state, &params, policy.as_mut(), &mut Rng::new(5), 2_000);
            assert!(steps > 0, "{cfg:?}");
            assert!(state.cum_energy.iter().all(|&e| e > 0.0), "{cfg:?}");
            // Deterministic given seed.
            let (mut again, _) = setup(&["tealeaf", "clvleaf"]);
            let mut policy2 = build_fleet_policy(&params, &FleetHyper::default(), 5);
            policy_run(&mut again, &params, policy2.as_mut(), &mut Rng::new(5), 2_000);
            assert_eq!(state.cum_energy, again.cum_energy, "{cfg:?}");
        }
    }

    #[test]
    fn mixed_policy_fleet_assigns_round_robin() {
        let (mut state, mut params) = setup(&["tealeaf", "tealeaf", "tealeaf"]);
        params.policies =
            vec![PolicyConfig::Static { arm: 8 }, PolicyConfig::RoundRobin];
        let mut policy = build_fleet_policy(&params, &FleetHyper::default(), 1);
        assert!(policy.name().starts_with("Mixed["), "{}", policy.name());
        policy_run(&mut state, &params, policy.as_mut(), &mut Rng::new(1), 500);
        // Env 0 and 2 hold the default arm (zero switches); env 1 cycles.
        assert_eq!(state.switches[0], 0.0);
        assert_eq!(state.switches[2], 0.0);
        assert!(state.switches[1] > 100.0);
    }

    #[test]
    fn static_fleet_energy_matches_calibration() {
        // Static arm 8 on tealeaf = the 1.6 GHz default: 109.79 kJ.
        let (mut state, mut params) = setup(&["tealeaf"]);
        params.policies = vec![PolicyConfig::Static { arm: 8 }];
        let mut policy = build_fleet_policy(&params, &FleetHyper::default(), 2);
        policy_run(&mut state, &params, policy.as_mut(), &mut Rng::new(2), 100_000);
        assert!(state.all_done());
        let kj = state.energy_kj(0);
        assert!((kj - 109.79).abs() < 2.0, "kj={kj}");
    }
}
