//! Pure-Rust fleet step — the bit-level reference for the HLO engine and
//! the fallback when artifacts are absent.
//!
//! Implements exactly the arithmetic of `python/compile/model.py::fleet_step`
//! in f32, same operation order, same tie-breaking (first index on argmax
//! ties), so the two engines can be cross-validated trajectory-by-
//! trajectory.

use super::state::{FleetHyper, FleetParams, FleetState};
use crate::util::Rng;

/// Effectively -inf for f32 masking (matches python NEG_LARGE).
pub const NEG_LARGE: f32 = -3.0e38;

/// Advance the fleet by one decision interval. `noise[e]` are standard
/// normal draws (already early-window-scaled by the caller). Returns the
/// selected arm per environment.
pub fn native_step(
    state: &mut FleetState,
    params: &FleetParams,
    hyper: &FleetHyper,
    noise: &[f32],
) -> Vec<i32> {
    let (b, k) = (state.b, state.k);
    assert_eq!(noise.len(), b);
    let ln_t = (state.t.max(2.0)).ln();
    let mut sel = vec![0i32; b];

    for e in 0..b {
        let row = e * k;
        let active = state.remaining[e] > 0.0;

        // SA-UCB index + argmax (first on ties via strict >).
        let mut best_arm = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for i in 0..k {
            let n = state.n[row + i];
            let mean = state.mean[row + i];
            let denom = hyper.prior_n + n;
            let mu_hat = if denom > 0.0 {
                (hyper.prior_n * hyper.mu_init + n * mean) / denom.max(1e-12)
            } else {
                hyper.mu_init
            };
            let bonus = hyper.alpha * (ln_t / n.max(1.0)).sqrt();
            let penalty =
                if i as i32 != state.prev[e] { hyper.lambda } else { 0.0 };
            let mut v = mu_hat + bonus - penalty;
            if params.feasible[row + i] <= 0.0 {
                v = NEG_LARGE;
            }
            if v > best_v {
                best_v = v;
                best_arm = i;
            }
        }
        let s = best_arm;
        sel[e] = s as i32;

        let a = if active { 1.0f32 } else { 0.0 };
        let r = params.reward_mean[row + s] + params.reward_sigma[row + s] * noise[e];
        let n_sel = state.n[row + s] + a;
        state.n[row + s] = n_sel;
        let delta = (r - state.mean[row + s]) / n_sel.max(1.0) * a;
        state.mean[row + s] += delta;

        let switched = if s as i32 != state.prev[e] { a } else { 0.0 };
        let useful = 1.0 - params.switch_stall_frac * switched;
        let prog = params.progress[row + s] * useful * a;
        state.remaining[e] = (state.remaining[e] - prog).max(0.0);
        state.cum_energy[e] +=
            (params.energy_step[row + s] + params.switch_energy_j * switched) * a;
        state.cum_regret[e] += (params.best_reward(e) - params.reward_mean[row + s]) * a;
        state.switches[e] += switched;
        if active {
            state.prev[e] = s as i32;
        }
    }
    state.t += 1.0;
    sel
}

/// Generate one step's noise vector: standard normals, inflated by each
/// env's early-window multiplier while `step_index` (0-based) is inside the
/// window.
pub fn step_noise(params: &FleetParams, step_index: u64, rng: &mut Rng) -> Vec<f32> {
    (0..params.b)
        .map(|e| {
            let z = rng.gaussian() as f32;
            if (step_index as u32) < params.early_steps[e] {
                z * params.early_mult[e]
            } else {
                z
            }
        })
        .collect()
}

/// Run the native fleet until all environments complete (or `max_steps`).
/// Returns the number of steps taken.
pub fn native_run(
    state: &mut FleetState,
    params: &FleetParams,
    hyper: &FleetHyper,
    rng: &mut Rng,
    max_steps: u64,
) -> u64 {
    let mut steps = 0;
    while !state.all_done() && steps < max_steps {
        let noise = step_noise(params, steps, rng);
        native_step(state, params, hyper, &noise);
        steps += 1;
    }
    steps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::freq::FreqDomain;
    use crate::workload::calibration;

    fn setup(names: &[&str]) -> (FleetState, FleetParams) {
        let freqs = FreqDomain::aurora();
        let apps: Vec<_> = names.iter().map(|n| calibration::app(n).unwrap()).collect();
        let refs: Vec<&_> = apps.iter().collect();
        let params = FleetParams::from_apps(&refs, &freqs, 0.01);
        (FleetState::fresh(names.len(), 9), params)
    }

    #[test]
    fn fleet_converges_to_optimal_arms() {
        let (mut state, params) = setup(&["tealeaf", "lbm", "miniswp", "sph_exa"]);
        let hyper = FleetHyper::default();
        let mut rng = Rng::new(1);
        for step in 0..4000u64 {
            let noise = step_noise(&params, step, &mut rng);
            native_step(&mut state, &params, &hyper, &noise);
        }
        for (e, name) in ["tealeaf", "lbm", "miniswp", "sph_exa"].iter().enumerate() {
            let app = calibration::app(name).unwrap();
            // The modal arm must be energy-near-optimal (adjacent arms can
            // be within <1 % of each other, e.g. tealeaf's 98.61 vs 99.10).
            let row = &state.n[e * 9..(e + 1) * 9];
            let modal = crate::util::stats::argmax(
                &row.iter().map(|x| *x as f64).collect::<Vec<_>>(),
            );
            let gap = app.energy_kj[modal] / app.optimal_energy_kj() - 1.0;
            assert!(gap < 0.015, "{name}: modal {modal}, gap {:.2}%, pulls {row:?}", gap * 100.0);
        }
    }

    #[test]
    fn energy_accounting_close_to_calibration() {
        // A completed tealeaf env's energy should land between the best
        // static (98.61) and the default (109.79).
        let (mut state, params) = setup(&["tealeaf"]);
        let hyper = FleetHyper::default();
        let mut rng = Rng::new(2);
        let steps = native_run(&mut state, &params, &hyper, &mut rng, 100_000);
        assert!(state.all_done(), "steps={steps}");
        let kj = state.energy_kj(0);
        assert!(kj > 95.0 && kj < 108.0, "kj={kj}");
    }

    #[test]
    fn regret_nonnegative_monotone() {
        let (mut state, params) = setup(&["clvleaf", "weather"]);
        let hyper = FleetHyper::default();
        let mut rng = Rng::new(3);
        let mut last = vec![0.0f32; 2];
        for step in 0..500u64 {
            let noise = step_noise(&params, step, &mut rng);
            native_step(&mut state, &params, &hyper, &noise);
            for e in 0..2 {
                assert!(state.cum_regret[e] >= last[e] - 1e-5);
                last[e] = state.cum_regret[e];
            }
        }
    }

    #[test]
    fn done_envs_freeze() {
        let (mut state, mut params) = setup(&["clvleaf"]);
        // Finish almost immediately.
        for p in params.progress.iter_mut() {
            *p = 0.5;
        }
        let hyper = FleetHyper::default();
        let mut rng = Rng::new(4);
        native_run(&mut state, &params, &hyper, &mut rng, 50);
        assert!(state.all_done());
        let energy_after_done = state.cum_energy[0];
        let n_after_done: f32 = state.n.iter().sum();
        let noise = step_noise(&params, 50, &mut rng);
        native_step(&mut state, &params, &hyper, &noise);
        assert_eq!(state.cum_energy[0], energy_after_done);
        assert_eq!(state.n.iter().sum::<f32>(), n_after_done);
    }

    #[test]
    fn early_window_scales_noise() {
        let (_, params) = setup(&["tealeaf"]);
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let early = step_noise(&params, 0, &mut rng_a);
        let late = step_noise(&params, 10_000, &mut rng_b);
        assert!((early[0] / late[0] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut s1, params) = setup(&["pot3d"]);
        let mut s2 = s1.clone();
        let hyper = FleetHyper::default();
        let mut r1 = Rng::new(6);
        let mut r2 = Rng::new(6);
        native_run(&mut s1, &params, &hyper, &mut r1, 1000);
        native_run(&mut s2, &params, &hyper, &mut r2, 1000);
        assert_eq!(s1, s2);
    }
}
