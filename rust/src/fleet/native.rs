//! Pure-Rust fleet step — the bit-level reference for the HLO engine and
//! the fallback when artifacts are absent.
//!
//! Implements exactly the arithmetic of `python/compile/model.py::fleet_step`
//! in f32, same operation order, same tie-breaking (first index on argmax
//! ties), so the two engines can be cross-validated trajectory-by-
//! trajectory. The decision arithmetic itself lives in the batch policy
//! core ([`crate::bandit::batch`]): this module contributes only the
//! environment dynamics (reward synthesis, progress/energy/regret
//! accounting) and calls [`saucb_select_into`] / [`grid_update_batch`] on
//! the `FleetState` grids — there is no inline UCB arithmetic here. Those
//! free functions dispatch to SIMD kernels at runtime, but every kernel
//! is pinned bit-identical to the scalar reference
//! (`tests/simd_conformance.rs`), so this module remains the bit-level
//! reference for the HLO engine on every host.
//!
//! The `*_into` variants write into caller-provided [`StepScratch`] /
//! noise buffers so the hot loop performs no per-step allocations; the
//! original allocating signatures survive as thin wrappers.

use super::state::{FleetHyper, FleetParams, FleetState};
use crate::bandit::batch::{grid_update_batch, saucb_select_into};
use crate::util::Rng;

/// Effectively -inf for f32 masking (matches python NEG_LARGE). Re-export
/// of the batch-core constant for source compatibility.
pub use crate::bandit::batch::NEG_LARGE;

/// Reusable per-step buffers for fleet stepping: selections, synthesized
/// rewards/progress (f64 at the policy boundary — exact for f32-sourced
/// values), and the active mask.
#[derive(Clone, Debug, Default)]
pub struct StepScratch {
    pub sel: Vec<i32>,
    pub reward: Vec<f64>,
    pub progress: Vec<f64>,
    pub active: Vec<f32>,
}

impl StepScratch {
    pub fn new(b: usize) -> StepScratch {
        let mut s = StepScratch::default();
        s.ensure(b);
        s
    }

    /// Resize every buffer to batch size `b` (no-op when already sized).
    pub fn ensure(&mut self, b: usize) {
        self.sel.resize(b, 0);
        self.reward.resize(b, 0.0);
        self.progress.resize(b, 0.0);
        self.active.resize(b, 0.0);
    }
}

/// Environment dynamics for one decision interval: synthesize rewards from
/// the calibrated parameters and the noise draw, account progress, energy,
/// regret, and switches against the *pre-update* previous arm, and fill
/// `scratch.{reward, progress, active}` for the policy update. Shared by
/// the bit-pinned EnergyUCB path ([`native_step_into`]) and the fleet
/// telemetry backend behind the batch-native control loop
/// (`fleet::backend::FleetBackend`). `scratch.sel` must already hold this
/// step's selections.
pub(crate) fn apply_env_dynamics(
    state: &mut FleetState,
    params: &FleetParams,
    noise: &[f32],
    scratch: &mut StepScratch,
) {
    let (b, k) = (state.b, state.k);
    for e in 0..b {
        let row = e * k;
        let s = scratch.sel[e] as usize;
        debug_assert!(s < k, "selection {s} out of range (k={k})");
        let active = state.remaining[e] > 0.0;
        let a = if active { 1.0f32 } else { 0.0 };
        scratch.active[e] = a;

        let r = params.reward_mean[row + s] + params.reward_sigma[row + s] * noise[e];
        scratch.reward[e] = r as f64;

        let switched = if s as i32 != state.prev[e] { a } else { 0.0 };
        let useful = 1.0 - params.switch_stall_frac * switched;
        let prog = params.progress[row + s] * useful * a;
        scratch.progress[e] = prog as f64;
        state.remaining[e] = (state.remaining[e] - prog).max(0.0);
        state.cum_energy[e] +=
            (params.energy_step[row + s] + params.switch_energy_j * switched) * a;
        state.cum_regret[e] += (params.best_reward(e) - params.reward_mean[row + s]) * a;
        state.switches[e] += switched;
    }
}

/// Advance the fleet by one decision interval, writing into `scratch`
/// (allocation-free). `noise[e]` are standard normal draws (already
/// early-window-scaled by the caller). `scratch.sel` holds the selected
/// arm per environment on return.
pub fn native_step_into(
    state: &mut FleetState,
    params: &FleetParams,
    hyper: &FleetHyper,
    noise: &[f32],
    scratch: &mut StepScratch,
) {
    let (b, k) = (state.b, state.k);
    assert_eq!(noise.len(), b);
    scratch.ensure(b);
    // Selection: SA-UCB over the FleetState grids, through the shared
    // batch core (the single source of the index arithmetic).
    saucb_select_into(
        &state.n,
        &state.mean,
        &state.prev,
        state.t,
        &params.feasible,
        hyper,
        k,
        &mut scratch.sel,
    );
    // Environment dynamics read the pre-update `prev` (switch accounting),
    // then the learned state advances through the shared grid update.
    apply_env_dynamics(state, params, noise, scratch);
    grid_update_batch(
        &mut state.n,
        &mut state.mean,
        &mut state.prev,
        &scratch.sel,
        &scratch.reward,
        &scratch.active,
        k,
    );
    state.t += 1.0;
}

/// Advance the fleet by one decision interval. Returns the selected arm
/// per environment. Allocating wrapper around [`native_step_into`], kept
/// for the cross-validation tests and one-shot callers.
pub fn native_step(
    state: &mut FleetState,
    params: &FleetParams,
    hyper: &FleetHyper,
    noise: &[f32],
) -> Vec<i32> {
    let mut scratch = StepScratch::new(state.b);
    native_step_into(state, params, hyper, noise, &mut scratch);
    scratch.sel
}

/// Fill `out` with one step's noise vector: standard normals, inflated by
/// each env's early-window multiplier while `step_index` (0-based) is
/// inside the window.
pub fn step_noise_into(params: &FleetParams, step_index: u64, rng: &mut Rng, out: &mut [f32]) {
    assert_eq!(out.len(), params.b);
    for (e, slot) in out.iter_mut().enumerate() {
        let z = rng.gaussian() as f32;
        *slot = if (step_index as u32) < params.early_steps[e] {
            z * params.early_mult[e]
        } else {
            z
        };
    }
}

/// Allocating wrapper around [`step_noise_into`].
pub fn step_noise(params: &FleetParams, step_index: u64, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0.0f32; params.b];
    step_noise_into(params, step_index, rng, &mut out);
    out
}

/// Run the native fleet until all environments complete (or `max_steps`).
/// Returns the number of steps taken. Noise and step buffers are allocated
/// once and reused across the whole run.
pub fn native_run(
    state: &mut FleetState,
    params: &FleetParams,
    hyper: &FleetHyper,
    rng: &mut Rng,
    max_steps: u64,
) -> u64 {
    let mut scratch = StepScratch::new(state.b);
    let mut noise = vec![0.0f32; state.b];
    let mut steps = 0;
    while !state.all_done() && steps < max_steps {
        step_noise_into(params, steps, rng, &mut noise);
        native_step_into(state, params, hyper, &noise, &mut scratch);
        steps += 1;
    }
    steps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::freq::FreqDomain;
    use crate::workload::calibration;

    fn setup(names: &[&str]) -> (FleetState, FleetParams) {
        let freqs = FreqDomain::aurora();
        let apps: Vec<_> = names.iter().map(|n| calibration::app(n).unwrap()).collect();
        let refs: Vec<&_> = apps.iter().collect();
        let params = FleetParams::from_apps(&refs, &freqs, 0.01);
        (FleetState::fresh(names.len(), 9), params)
    }

    #[test]
    fn fleet_converges_to_optimal_arms() {
        let (mut state, params) = setup(&["tealeaf", "lbm", "miniswp", "sph_exa"]);
        let hyper = FleetHyper::default();
        let mut rng = Rng::new(1);
        for step in 0..4000u64 {
            let noise = step_noise(&params, step, &mut rng);
            native_step(&mut state, &params, &hyper, &noise);
        }
        for (e, name) in ["tealeaf", "lbm", "miniswp", "sph_exa"].iter().enumerate() {
            let app = calibration::app(name).unwrap();
            // The modal arm must be energy-near-optimal (adjacent arms can
            // be within <1 % of each other, e.g. tealeaf's 98.61 vs 99.10).
            let row = &state.n[e * 9..(e + 1) * 9];
            let modal = crate::util::stats::argmax(
                &row.iter().map(|x| *x as f64).collect::<Vec<_>>(),
            );
            let gap = app.energy_kj[modal] / app.optimal_energy_kj() - 1.0;
            assert!(gap < 0.015, "{name}: modal {modal}, gap {:.2}%, pulls {row:?}", gap * 100.0);
        }
    }

    #[test]
    fn energy_accounting_close_to_calibration() {
        // A completed tealeaf env's energy should land between the best
        // static (98.61) and the default (109.79).
        let (mut state, params) = setup(&["tealeaf"]);
        let hyper = FleetHyper::default();
        let mut rng = Rng::new(2);
        let steps = native_run(&mut state, &params, &hyper, &mut rng, 100_000);
        assert!(state.all_done(), "steps={steps}");
        let kj = state.energy_kj(0);
        assert!(kj > 95.0 && kj < 108.0, "kj={kj}");
    }

    #[test]
    fn regret_nonnegative_monotone() {
        let (mut state, params) = setup(&["clvleaf", "weather"]);
        let hyper = FleetHyper::default();
        let mut rng = Rng::new(3);
        let mut last = vec![0.0f32; 2];
        for step in 0..500u64 {
            let noise = step_noise(&params, step, &mut rng);
            native_step(&mut state, &params, &hyper, &noise);
            for e in 0..2 {
                assert!(state.cum_regret[e] >= last[e] - 1e-5);
                last[e] = state.cum_regret[e];
            }
        }
    }

    #[test]
    fn done_envs_freeze() {
        let (mut state, mut params) = setup(&["clvleaf"]);
        // Finish almost immediately.
        for p in params.progress.iter_mut() {
            *p = 0.5;
        }
        let hyper = FleetHyper::default();
        let mut rng = Rng::new(4);
        native_run(&mut state, &params, &hyper, &mut rng, 50);
        assert!(state.all_done());
        let energy_after_done = state.cum_energy[0];
        let n_after_done: f32 = state.n.iter().sum();
        let noise = step_noise(&params, 50, &mut rng);
        native_step(&mut state, &params, &hyper, &noise);
        assert_eq!(state.cum_energy[0], energy_after_done);
        assert_eq!(state.n.iter().sum::<f32>(), n_after_done);
    }

    #[test]
    fn early_window_scales_noise() {
        let (_, params) = setup(&["tealeaf"]);
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let early = step_noise(&params, 0, &mut rng_a);
        let late = step_noise(&params, 10_000, &mut rng_b);
        assert!((early[0] / late[0] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut s1, params) = setup(&["pot3d"]);
        let mut s2 = s1.clone();
        let hyper = FleetHyper::default();
        let mut r1 = Rng::new(6);
        let mut r2 = Rng::new(6);
        native_run(&mut s1, &params, &hyper, &mut r1, 1000);
        native_run(&mut s2, &params, &hyper, &mut r2, 1000);
        assert_eq!(s1, s2);
    }

    #[test]
    fn step_into_matches_allocating_wrapper() {
        // Buffer-reuse regression: the `_into` path and the allocating
        // wrappers must produce identical trajectories and selections.
        let (mut s1, params) = setup(&["tealeaf", "clvleaf"]);
        let mut s2 = s1.clone();
        let hyper = FleetHyper::default();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let mut scratch = StepScratch::new(2);
        let mut noise = vec![0.0f32; 2];
        for step in 0..300u64 {
            step_noise_into(&params, step, &mut r1, &mut noise);
            native_step_into(&mut s1, &params, &hyper, &noise, &mut scratch);
            let wrapped = native_step(&mut s2, &params, &hyper, &step_noise(&params, step, &mut r2));
            assert_eq!(scratch.sel, wrapped, "step {step}");
        }
        assert_eq!(s1, s2);
    }
}
