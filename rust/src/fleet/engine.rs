//! HLO-backed fleet engine: drives the AOT-compiled `fleet_step` artifact
//! through PJRT, one `execute` per decision interval for the whole batch.
//!
//! The rust side owns the RNG (noise is an input), so a trajectory is fully
//! determined by (artifact, params, hyper, seed) and can be cross-validated
//! against [`super::native`].

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::state::{FleetHyper, FleetParams, FleetState};
use crate::runtime::{literal, Literal, LoadedModule, XlaRuntime};
use crate::util::Rng;

/// Scan chunk size the AOT export uses (aot.py --scan-steps).
pub const SCAN_STEPS: usize = 16;

/// The compiled fleet-step executable plus its constant input literals.
pub struct FleetEngine {
    module: LoadedModule,
    /// Multi-step (lax.scan) variant: S steps per execute. Loaded when the
    /// artifact exists; `run` prefers it (EXPERIMENTS.md §Perf: ~7x).
    scan_module: Option<LoadedModule>,
    params: FleetParams,
    hyper: FleetHyper,
    /// Pre-built constant literals (params + hyper), reused every step.
    const_inputs: Vec<Literal>,
}

impl FleetEngine {
    /// Load `fleet_step_b{B}.hlo.txt` (and the scan variant if present)
    /// for the batch size of `params`.
    pub fn load(
        runtime: &XlaRuntime,
        artifact_dir: &Path,
        params: FleetParams,
        hyper: FleetHyper,
    ) -> Result<FleetEngine> {
        let name = format!("fleet_step_b{}.hlo.txt", params.b);
        let path = artifact_dir.join(&name);
        if !path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` (batch sizes are fixed at export)",
                path.display()
            );
        }
        let module = runtime.load_hlo_text(&path).context("loading fleet_step")?;
        let scan_path =
            artifact_dir.join(format!("fleet_scan_b{}_s{SCAN_STEPS}.hlo.txt", params.b));
        let scan_module = if scan_path.exists() {
            Some(runtime.load_hlo_text(&scan_path).context("loading fleet_scan")?)
        } else {
            None
        };
        let const_inputs = Self::build_const_inputs(&params, &hyper)?;
        Ok(FleetEngine { module, scan_module, params, hyper, const_inputs })
    }

    /// Whether the multi-step scan artifact is available.
    pub fn has_scan(&self) -> bool {
        self.scan_module.is_some()
    }

    fn build_const_inputs(params: &FleetParams, hyper: &FleetHyper) -> Result<Vec<Literal>> {
        let (b, k) = (params.b, params.k);
        Ok(vec![
            literal::mat_f32(&params.reward_mean, b, k)?,
            literal::mat_f32(&params.reward_sigma, b, k)?,
            literal::mat_f32(&params.energy_step, b, k)?,
            literal::mat_f32(&params.progress, b, k)?,
            literal::mat_f32(&params.feasible, b, k)?,
            // noise is per-step; hyper scalars:
            literal::scalar_f32(hyper.alpha),
            literal::scalar_f32(hyper.lambda),
            literal::scalar_f32(hyper.mu_init),
            literal::scalar_f32(hyper.prior_n),
        ])
    }

    pub fn params(&self) -> &FleetParams {
        &self.params
    }

    pub fn hyper(&self) -> &FleetHyper {
        &self.hyper
    }

    /// Advance the fleet one interval through the compiled artifact.
    /// Input order must match python/compile/model.py.
    ///
    /// Perf note (§Perf in EXPERIMENTS.md): the five (B, K) parameter
    /// matrices and four hyper scalars are *borrowed* from the pre-built
    /// constant literals — only the state (~6 B·K f32) is re-packed per
    /// step. Cloning the constants per step cost ~35 % at B = 1024.
    pub fn step(&self, state: &mut FleetState, noise: &[f32]) -> Result<Vec<i32>> {
        let (b, k) = (state.b, state.k);
        assert_eq!(b, self.params.b, "state batch != engine batch");
        let state_lits: [Literal; 9] = [
            literal::mat_f32(&state.n, b, k)?,
            literal::mat_f32(&state.mean, b, k)?,
            literal::vec_i32(&state.prev),
            literal::scalar_f32(state.t),
            literal::vec_f32(&state.remaining),
            literal::vec_f32(&state.cum_energy),
            literal::vec_f32(&state.cum_regret),
            literal::vec_f32(&state.switches),
            literal::vec_f32(noise),
        ];
        let mut inputs: Vec<&Literal> = Vec::with_capacity(18);
        inputs.extend(&state_lits[0..8]);
        inputs.extend(&self.const_inputs[0..5]); // params, borrowed
        inputs.push(&state_lits[8]); // noise
        inputs.extend(&self.const_inputs[5..9]); // hyper scalars, borrowed

        let outputs = self.module.run_borrowed(&inputs)?;
        if outputs.len() != 9 {
            bail!("fleet_step returned {} outputs, expected 9", outputs.len());
        }
        state.n = literal::to_vec_f32(&outputs[0])?;
        state.mean = literal::to_vec_f32(&outputs[1])?;
        state.prev = literal::to_vec_i32(&outputs[2])?;
        state.t = literal::to_scalar_f32(&outputs[3])?;
        state.remaining = literal::to_vec_f32(&outputs[4])?;
        state.cum_energy = literal::to_vec_f32(&outputs[5])?;
        state.cum_regret = literal::to_vec_f32(&outputs[6])?;
        state.switches = literal::to_vec_f32(&outputs[7])?;
        literal::to_vec_i32(&outputs[8])
    }

    /// Advance `SCAN_STEPS` intervals in ONE execute via the scanned
    /// artifact. `noise_seq` is step-major (S × B). Returns the last
    /// step's selections.
    pub fn step_scan(&self, state: &mut FleetState, noise_seq: &[f32]) -> Result<Vec<i32>> {
        let Some(scan) = &self.scan_module else {
            bail!("scan artifact not loaded");
        };
        let (b, k) = (state.b, state.k);
        assert_eq!(noise_seq.len(), SCAN_STEPS * b, "noise must be (S, B)");
        let state_lits: [Literal; 9] = [
            literal::mat_f32(&state.n, b, k)?,
            literal::mat_f32(&state.mean, b, k)?,
            literal::vec_i32(&state.prev),
            literal::scalar_f32(state.t),
            literal::vec_f32(&state.remaining),
            literal::vec_f32(&state.cum_energy),
            literal::vec_f32(&state.cum_regret),
            literal::vec_f32(&state.switches),
            literal::mat_f32(noise_seq, SCAN_STEPS, b)?,
        ];
        let mut inputs: Vec<&Literal> = Vec::with_capacity(18);
        inputs.extend(&state_lits[0..8]);
        inputs.extend(&self.const_inputs[0..5]);
        inputs.push(&state_lits[8]);
        inputs.extend(&self.const_inputs[5..9]);
        let outputs = scan.run_borrowed(&inputs)?;
        if outputs.len() != 9 {
            bail!("fleet_scan returned {} outputs, expected 9", outputs.len());
        }
        state.n = literal::to_vec_f32(&outputs[0])?;
        state.mean = literal::to_vec_f32(&outputs[1])?;
        state.prev = literal::to_vec_i32(&outputs[2])?;
        state.t = literal::to_scalar_f32(&outputs[3])?;
        state.remaining = literal::to_vec_f32(&outputs[4])?;
        state.cum_energy = literal::to_vec_f32(&outputs[5])?;
        state.cum_regret = literal::to_vec_f32(&outputs[6])?;
        state.switches = literal::to_vec_f32(&outputs[7])?;
        literal::to_vec_i32(&outputs[8])
    }

    /// Run until every environment completes (or `max_steps`). Prefers the
    /// scanned artifact (S steps per execute) when available, finishing
    /// the tail with single steps. Returns the steps taken. Noise buffers
    /// are allocated once and reused across the whole run.
    pub fn run(&self, state: &mut FleetState, rng: &mut Rng, max_steps: u64) -> Result<u64> {
        let b = state.b;
        let mut steps = 0;
        if self.has_scan() {
            let mut noise_seq = vec![0.0f32; SCAN_STEPS * b];
            while !state.all_done() && steps + SCAN_STEPS as u64 <= max_steps {
                for s in 0..SCAN_STEPS {
                    super::native::step_noise_into(
                        &self.params,
                        steps + s as u64,
                        rng,
                        &mut noise_seq[s * b..(s + 1) * b],
                    );
                }
                self.step_scan(state, &noise_seq)?;
                steps += SCAN_STEPS as u64;
            }
        }
        let mut noise = vec![0.0f32; b];
        while !state.all_done() && steps < max_steps {
            super::native::step_noise_into(&self.params, steps, rng, &mut noise);
            self.step(state, &noise)?;
            steps += 1;
        }
        Ok(steps)
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent behavior is covered by rust/tests/fleet_cross.rs
    // (integration), which needs the artifacts built. Unit scope here is
    // limited to input packing arity.
    use super::*;
    use crate::sim::freq::FreqDomain;
    use crate::workload::calibration;

    #[test]
    fn const_inputs_have_expected_arity() {
        let freqs = FreqDomain::aurora();
        let app = calibration::app("tealeaf").unwrap();
        let params = FleetParams::from_apps(&[&app], &freqs, 0.01);
        let consts =
            FleetEngine::build_const_inputs(&params, &FleetHyper::default()).unwrap();
        assert_eq!(consts.len(), 9);
    }
}
