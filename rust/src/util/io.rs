//! Result serialization: CSV plus a small JSON writer *and reader*.
//!
//! serde is not in the vendored crate set, so experiments write their
//! machine-readable outputs through this hand-rolled substrate. Configs
//! are read through [`crate::config::toml`]; the JSON reader
//! ([`Json::parse`]) exists for the cluster wire protocol
//! ([`crate::cluster::wire`]), where shard workers receive their
//! assignment batches as framed JSONL over a pipe.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// A JSON value tree sufficient for experiment outputs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object. Panics on non-objects.
    pub fn set<S: Into<String>, V: Into<Json>>(&mut self, key: S, value: V) -> &mut Self {
        let key = key.into();
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| *k == key) {
                    p.1 = value.into();
                } else {
                    pairs.push((key, value.into()));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object-key lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric field lookup.
    pub fn get_num(&self, key: &str) -> Option<f64> {
        match self.get(key)? {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// String payload (None on non-strings).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload (None on non-numbers).
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Boolean payload (None on non-booleans).
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array payload (None on non-arrays).
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Single-line render for JSONL framing: one value per line, no
    /// whitespace. Escaped strings never contain raw newlines, so the
    /// output is guaranteed newline-free; [`Json::parse`] reads it back.
    pub fn render_compact(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
            scalar => scalar.write(out, 0),
        }
    }

    /// Parse a JSON document — the counterpart of [`Json::render`] and
    /// [`Json::render_compact`]. Rejects trailing garbage, truncated
    /// input, bad escapes, non-finite numbers, and nesting deeper than a
    /// fixed cap; returns an error (never panics) on malformed input.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    x.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in pairs.iter().enumerate() {
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < pairs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

/// Parse failure from [`Json::parse`]: what went wrong and where.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset into the input at the failure point.
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Nesting cap: adversarial frames (`[[[[…`) must fail with an error,
/// not exhaust the recursion stack.
const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected `{word}`")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            pairs.push((key, self.value(depth + 1)?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ if b < 0x20 => return Err(self.err("unescaped control character")),
                _ if b < 0x80 => out.push(b as char),
                _ => {
                    // Multi-byte UTF-8: the input is a &str, so a leading
                    // byte at a char boundary carries its sequence length;
                    // copy the whole char. Guards keep this panic-free
                    // even though valid UTF-8 can't violate them.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8 leading byte")),
                    };
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8 sequence"));
                    }
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return Err(self.err("invalid utf-8 in string")),
                    }
                }
            }
        }
    }

    /// `\uXXXX`, including surrogate pairs (`😀`).
    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        let hi = self.hex4()?;
        if (0xDC00..0xE000).contains(&hi) {
            return Err(self.err("lone low surrogate"));
        }
        if (0xD800..0xDC00).contains(&hi) {
            if self.peek() != Some(b'\\') {
                return Err(self.err("lone high surrogate"));
            }
            self.pos += 1;
            if self.peek() != Some(b'u') {
                return Err(self.err("lone high surrogate"));
            }
            self.pos += 1;
            let lo = self.hex4()?;
            if !(0xDC00..0xE000).contains(&lo) {
                return Err(self.err("invalid low surrogate"));
            }
            let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
            return char::from_u32(cp).ok_or_else(|| self.err("invalid surrogate pair"));
        }
        char::from_u32(hi).ok_or_else(|| self.err("invalid \\u escape"))
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let Some(b) = self.peek() else {
                return Err(self.err("truncated \\u escape"));
            };
            let d = match b {
                b'0'..=b'9' => u32::from(b - b'0'),
                b'a'..=b'f' => u32::from(b - b'a') + 10,
                b'A'..=b'F' => u32::from(b - b'A') + 10,
                _ => return Err(self.err("bad hex digit in \\u escape")),
            };
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let parsed = std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|t| t.parse::<f64>().ok());
        match parsed {
            // 1e999 overflows to inf: reject (JSON has no non-finite repr).
            Some(x) if x.is_finite() => Ok(Json::Num(x)),
            _ => {
                self.pos = start;
                Err(self.err("invalid number"))
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(xs: Vec<f64>) -> Json {
        Json::Arr(xs.into_iter().map(Json::from).collect())
    }
}
impl From<Vec<Json>> for Json {
    fn from(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }
}

/// CSV writer: quotes fields when needed (comma, quote, newline).
#[derive(Debug, Default)]
pub struct Csv {
    lines: Vec<String>,
}

impl Csv {
    pub fn new() -> Csv {
        Csv::default()
    }

    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) -> &mut Self {
        let line = cells.iter().map(|c| escape_csv(c.as_ref())).collect::<Vec<_>>().join(",");
        self.lines.push(line);
        self
    }

    pub fn row_mixed(&mut self, label: &str, values: &[f64], digits: usize) -> &mut Self {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format!("{v:.digits$}")));
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut s = self.lines.join("\n");
        s.push('\n');
        s
    }

    pub fn write_to(&self, path: &Path) -> io::Result<()> {
        write_file(path, &self.render())
    }
}

fn escape_csv(s: &str) -> String {
    // RFC 4180: carriage returns need quoting just like bare newlines —
    // an unquoted `\r` splits the record on CRLF-aware readers.
    if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Process-wide counter making every tmp path of [`write_file`] unique.
static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Create parent dirs and write a file atomically (unique tmp + rename).
///
/// The tmp name appends a `.{pid}.{n}.tmp` suffix to the full file name
/// rather than replacing the extension: `with_extension("tmp")` mapped
/// sibling outputs like `out.csv` and `out.json` onto the same `out.tmp`,
/// so concurrent writers (an experiment emitting both under `--jobs`)
/// could rename a half-written or wrong-format file into place.
pub fn write_file(path: &Path, contents: &str) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let Some(file_name) = path.file_name() else {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("write_file: no file name in {}", path.display()),
        ));
    };
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let tmp = path.with_file_name(tmp_name);
    // Remove the tmp on either failure: names are unique per call, so a
    // stray partial file would never be overwritten by a retry.
    fs::write(&tmp, contents).map_err(|e| {
        let _ = fs::remove_file(&tmp);
        e
    })?;
    fs::rename(&tmp, path).map_err(|e| {
        let _ = fs::remove_file(&tmp);
        e
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_shape() {
        let mut j = Json::obj();
        j.set("name", "table1");
        j.set("kj", 93.94);
        j.set("ok", true);
        j.set("series", vec![1.0, 2.5, 3.0]);
        let s = j.render();
        assert!(s.contains("\"name\": \"table1\""), "{s}");
        assert!(s.contains("\"kj\": 93.94"), "{s}");
        assert!(s.contains("[1, 2.5, 3]"), "{s}");
    }

    #[test]
    fn json_escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn json_set_replaces() {
        let mut j = Json::obj();
        j.set("k", 1.0);
        j.set("k", 2.0);
        match &j {
            Json::Obj(pairs) => assert_eq!(pairs.len(), 1),
            _ => unreachable!(),
        }
        assert!(j.render().contains("2"));
    }

    #[test]
    fn json_nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn csv_quoting() {
        let mut c = Csv::new();
        c.row(&["a,b", "plain", "q\"uote"]);
        assert_eq!(c.render(), "\"a,b\",plain,\"q\"\"uote\"\n");
        // RFC 4180: \r-bearing fields must be quoted like \n-bearing ones.
        let mut c = Csv::new();
        c.row(&["cr\rhere", "crlf\r\n", "nl\nonly"]);
        assert_eq!(c.render(), "\"cr\rhere\",\"crlf\r\n\",\"nl\nonly\"\n");
    }

    #[test]
    fn write_file_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("energyucb_io_test_{}", std::process::id()));
        let path = dir.join("nested/out.csv");
        write_file(&path, "x\n").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "x\n");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn concurrent_sibling_writes_do_not_collide() {
        // Regression: `with_extension("tmp")` gave `a.csv` and `a.json`
        // the same `a.tmp`, so one writer could rename the other's
        // half-written payload into place (or fail the rename outright).
        let dir =
            std::env::temp_dir().join(format!("energyucb_io_race_{}", std::process::id()));
        let csv = dir.join("a.csv");
        let json = dir.join("a.json");
        std::thread::scope(|s| {
            let csv = &csv;
            let json = &json;
            s.spawn(move || {
                for _ in 0..200 {
                    write_file(csv, "kind=csv\n").unwrap();
                }
            });
            s.spawn(move || {
                for _ in 0..200 {
                    write_file(json, "kind=json\n").unwrap();
                }
            });
        });
        assert_eq!(fs::read_to_string(&csv).unwrap(), "kind=csv\n");
        assert_eq!(fs::read_to_string(&json).unwrap(), "kind=json\n");
        // No stray tmp files left behind.
        let leftovers: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn parse_scalars_and_containers() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        let v = Json::parse("[1, [2, {\"k\": null}]]").unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parse_string_escapes() {
        assert_eq!(
            Json::parse(r#""a\"b\\c\nd\r\t\/\u0041""#).unwrap(),
            Json::Str("a\"b\\c\nd\r\t/A".into())
        );
        // Surrogate pair → one astral char; raw multi-byte UTF-8 passes through.
        assert_eq!(Json::parse(r#""\ud83d\ude00""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(Json::parse("\"héllo ☃\"").unwrap(), Json::Str("héllo ☃".into()));
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in [
            "",
            "   ",
            "nul",
            "truely",
            "\"unterminated",
            "\"bad \\q escape\"",
            "\"lone \\ud83d surrogate\"",
            "\"low \\ude00 first\"",
            "\"\\u12g4\"",
            "[1, 2",
            "[1 2]",
            "{\"k\" 1}",
            "{\"k\": }",
            "{k: 1}",
            "1e999",
            "--1",
            "1 trailing",
            "[1],",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?}");
        }
        // Control characters must be escaped inside strings.
        assert!(Json::parse("\"a\u{0001}b\"").is_err());
        // Deep nesting errors out instead of blowing the stack.
        let deep = "[".repeat(100_000);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn render_parse_round_trip() {
        let mut j = Json::obj();
        j.set("name", "tbl \"x\",\n1");
        j.set("kj", 93.94);
        j.set("count", 7.0);
        j.set("ok", true);
        j.set("none", Json::Null);
        j.set("series", vec![1.0, 2.5, 3.0]);
        let mut inner = Json::obj();
        inner.set("nested", "véry ☃");
        j.set("inner", inner);
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
        let compact = j.render_compact();
        assert!(!compact.contains('\n'), "{compact}");
        assert_eq!(Json::parse(&compact).unwrap(), j);
    }
}
