//! Result serialization: CSV and a small JSON writer.
//!
//! serde is not in the vendored crate set, so experiments write their
//! machine-readable outputs through this hand-rolled substrate. Only
//! *writing* is needed at runtime (configs are read through
//! [`crate::config::toml`]).

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// A JSON value tree sufficient for experiment outputs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object. Panics on non-objects.
    pub fn set<S: Into<String>, V: Into<Json>>(&mut self, key: S, value: V) -> &mut Self {
        let key = key.into();
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| *k == key) {
                    p.1 = value.into();
                } else {
                    pairs.push((key, value.into()));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object-key lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric field lookup.
    pub fn get_num(&self, key: &str) -> Option<f64> {
        match self.get(key)? {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    x.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in pairs.iter().enumerate() {
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < pairs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(xs: Vec<f64>) -> Json {
        Json::Arr(xs.into_iter().map(Json::from).collect())
    }
}
impl From<Vec<Json>> for Json {
    fn from(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }
}

/// CSV writer: quotes fields when needed (comma, quote, newline).
#[derive(Debug, Default)]
pub struct Csv {
    lines: Vec<String>,
}

impl Csv {
    pub fn new() -> Csv {
        Csv::default()
    }

    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) -> &mut Self {
        let line = cells.iter().map(|c| escape_csv(c.as_ref())).collect::<Vec<_>>().join(",");
        self.lines.push(line);
        self
    }

    pub fn row_mixed(&mut self, label: &str, values: &[f64], digits: usize) -> &mut Self {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format!("{v:.digits$}")));
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut s = self.lines.join("\n");
        s.push('\n');
        s
    }

    pub fn write_to(&self, path: &Path) -> io::Result<()> {
        write_file(path, &self.render())
    }
}

fn escape_csv(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Create parent dirs and write a file atomically (tmp + rename).
pub fn write_file(path: &Path, contents: &str) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    fs::write(&tmp, contents)?;
    fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_shape() {
        let mut j = Json::obj();
        j.set("name", "table1");
        j.set("kj", 93.94);
        j.set("ok", true);
        j.set("series", vec![1.0, 2.5, 3.0]);
        let s = j.render();
        assert!(s.contains("\"name\": \"table1\""), "{s}");
        assert!(s.contains("\"kj\": 93.94"), "{s}");
        assert!(s.contains("[1, 2.5, 3]"), "{s}");
    }

    #[test]
    fn json_escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn json_set_replaces() {
        let mut j = Json::obj();
        j.set("k", 1.0);
        j.set("k", 2.0);
        match &j {
            Json::Obj(pairs) => assert_eq!(pairs.len(), 1),
            _ => unreachable!(),
        }
        assert!(j.render().contains("2"));
    }

    #[test]
    fn json_nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn csv_quoting() {
        let mut c = Csv::new();
        c.row(&["a,b", "plain", "q\"uote"]);
        assert_eq!(c.render(), "\"a,b\",plain,\"q\"\"uote\"\n");
    }

    #[test]
    fn write_file_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("energyucb_io_test_{}", std::process::id()));
        let path = dir.join("nested/out.csv");
        write_file(&path, "x\n").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "x\n");
        fs::remove_dir_all(&dir).unwrap();
    }
}
