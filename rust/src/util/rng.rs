//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so the simulator carries its own
//! PRNG substrate: [`SplitMix64`] for seeding / cheap streams and
//! [`Xoshiro256pp`] (xoshiro256++) as the workhorse generator, plus
//! Box-Muller Gaussian sampling. Everything is reproducible from a `u64`
//! seed; independent sub-streams are derived with [`Rng::fork`] so that
//! adding a consumer never perturbs existing streams.

/// SplitMix64: tiny, good-quality generator used to seed xoshiro state and
/// to derive fork keys. Reference: Steele, Lea, Flood (2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, 256-bit state, passes BigCrush. This is the
/// simulator-wide default generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }
}

/// Simulator RNG: xoshiro core + distribution helpers + stream forking.
#[derive(Clone, Debug)]
pub struct Rng {
    core: Xoshiro256pp,
    /// Cached second Box-Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { core: Xoshiro256pp::seed_from_u64(seed), gauss_spare: None }
    }

    /// Derive an independent child stream keyed by `key`. Forking with
    /// distinct keys yields decorrelated streams and leaves `self` untouched
    /// except for one draw, so insertion of new consumers is cheap and
    /// stable.
    pub fn fork(&mut self, key: u64) -> Rng {
        let base = self.next_u64();
        let mut sm = SplitMix64::new(base ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        Rng::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.core.next_u64()
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses Lemire-style rejection to avoid
    /// modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via the Marsaglia polar method (with spare
    /// caching). Polar avoids the sin/cos of classic Box-Muller — the
    /// simulator draws ~20 normals per node decision interval, and this
    /// variant measured ~35 % faster on that path (EXPERIMENTS.md §Perf).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index proportionally to non-negative `weights`.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: weights sum to zero");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 1234567 (cross-checked against the
        // reference C implementation).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn fork_is_decorrelated_and_stable() {
        let mut parent = Rng::new(7);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let x1: Vec<u64> = (0..32).map(|_| c1.next_u64()).collect();
        let x2: Vec<u64> = (0..32).map(|_| c2.next_u64()).collect();
        assert_ne!(x1, x2);
        // Same parent seed + same fork keys reproduce the same children.
        let mut parent_b = Rng::new(7);
        let mut c1b = parent_b.fork(1);
        let y1: Vec<u64> = (0..32).map(|_| c1b.next_u64()).collect();
        assert_eq!(x1, y1);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Rng::new(11);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 1.0 / 7.0).abs() < 0.01, "frac={frac}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(5);
        let n = 100_000;
        let (mut m, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.gaussian();
            m += z;
            m2 += z * z;
        }
        let mean = m / n as f64;
        let var = m2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut rng = Rng::new(13);
        let w = [0.05, 0.9, 0.05];
        let mut hits = [0usize; 3];
        for _ in 0..10_000 {
            hits[rng.weighted_index(&w)] += 1;
        }
        assert!(hits[1] > 8_000);
        assert!(hits[0] > 100 && hits[2] > 100);
    }

    #[test]
    fn normal_scales() {
        let mut rng = Rng::new(17);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += rng.normal(10.0, 2.0);
        }
        assert!((sum / n as f64 - 10.0).abs() < 0.05);
    }
}
