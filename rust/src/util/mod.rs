//! Cross-cutting utility substrates (PRNG, statistics, tables, IO, math).
//!
//! The offline vendored crate set only covers the `xla` closure, so the
//! library carries its own implementations of what would normally come from
//! `rand`, `serde`/`serde_json`, and friends.

pub mod bench;
pub mod io;
pub mod math;
pub mod rng;
pub mod stats;
pub mod table;
pub mod wire;

pub use rng::Rng;
