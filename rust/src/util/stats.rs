//! Streaming statistics used by policies, metrics, and the bench harness.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    #[inline]
    pub fn count(&self) -> u64 {
        self.n
    }

    #[inline]
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 for n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (0 for n < 2).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn sample_std(&self) -> f64 {
        self.sample_variance().sqrt()
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64 / n as f64);
        self.n = n;
    }
}

/// Summary of a sample batch: mean, std, min, max, percentiles.
#[derive(Clone, Debug)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary of `xs`. Panics on empty input.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of empty slice");
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in summary"));
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Summary {
            count: xs.len(),
            mean: w.mean(),
            std: w.sample_std(),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation of a slice (0 for < 2 points).
pub fn sample_std(xs: &[f64]) -> f64 {
    let mut w = Welford::new();
    for &x in xs {
        w.push(x);
    }
    w.sample_std()
}

/// Index of the maximum element (first on ties). Panics on empty input.
pub fn argmax(xs: &[f64]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

/// Index of the minimum element (first on ties). Panics on empty input.
pub fn argmin(xs: &[f64]) -> usize {
    assert!(!xs.is_empty());
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] < xs[best] {
            best = i;
        }
    }
    best
}

/// Exponential moving average.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-9);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut seq = Welford::new();
        for &x in &xs {
            seq.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), seq.count());
        assert!((a.mean() - seq.mean()).abs() < 1e-10);
        assert!((a.variance() - seq.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let sorted: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&sorted, 0.5) - 50.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.9) - 90.0).abs() < 1e-12);
    }

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.count, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 3.0).abs() < 1e-12);
        assert!((s.p50 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_argmin_ties_take_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmin(&[1.0, 0.5, 0.5, 2.0]), 1);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..64 {
            e.push(10.0);
        }
        assert!((e.value().unwrap() - 10.0).abs() < 1e-6);
    }
}
