//! Micro-benchmark harness (criterion is not in the offline crate set).
//!
//! `cargo bench` targets use [`Bench`] for warmup + repeated timing with
//! mean/std/throughput reporting, and a black-box to defeat dead-code
//! elimination. Output format is one line per case:
//! `bench <name> ... mean <t> ± <std>  [<throughput>]`.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats::Welford;

/// Re-export of the std black box (stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark runner with shared settings.
pub struct Bench {
    /// Warmup time per case.
    pub warmup: Duration,
    /// Measured samples per case.
    pub samples: usize,
    /// Minimum time per sample (iterations are batched to reach it).
    pub sample_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            samples: 12,
            sample_time: Duration::from_millis(60),
        }
    }
}

/// Result of one case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub iters_total: u64,
}

impl Bench {
    pub fn quick() -> Bench {
        Bench {
            warmup: Duration::from_millis(20),
            samples: 5,
            sample_time: Duration::from_millis(10),
        }
    }

    /// Time `f` (called repeatedly); returns per-iteration stats and prints
    /// a line. `items_per_iter` (if > 0) adds a throughput column.
    pub fn case<F: FnMut()>(&self, name: &str, items_per_iter: f64, mut f: F) -> CaseResult {
        // Warmup + batch-size estimation.
        let mut iters_per_sample = 1u64;
        let warm_start = Instant::now();
        let mut single = Duration::ZERO;
        while warm_start.elapsed() < self.warmup {
            let t = Instant::now();
            f();
            single = t.elapsed();
        }
        if single > Duration::ZERO {
            let per = self.sample_time.as_nanos() / single.as_nanos().max(1);
            iters_per_sample = per.clamp(1, 1_000_000_000) as u64;
        }

        let mut w = Welford::new();
        let mut iters_total = 0u64;
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            let ns = t.elapsed().as_nanos() as f64 / iters_per_sample as f64;
            w.push(ns);
            iters_total += iters_per_sample;
        }
        let result = CaseResult {
            name: name.to_string(),
            mean_ns: w.mean(),
            std_ns: w.sample_std(),
            iters_total,
        };
        let thr = if items_per_iter > 0.0 {
            format!("  [{:>12} items/s]", human_rate(items_per_iter * 1e9 / w.mean()))
        } else {
            String::new()
        };
        println!(
            "bench {:<44} mean {:>12} ± {:>10}{}",
            result.name,
            human_time(w.mean()),
            human_time(w.sample_std()),
            thr
        );
        result
    }
}

/// Human-readable nanoseconds.
pub fn human_time(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Human-readable rate.
pub fn human_rate(per_s: f64) -> String {
    if per_s >= 1e9 {
        format!("{:.2} G", per_s / 1e9)
    } else if per_s >= 1e6 {
        format!("{:.2} M", per_s / 1e6)
    } else if per_s >= 1e3 {
        format!("{:.2} k", per_s / 1e3)
    } else {
        format!("{per_s:.1} ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_measures_something() {
        let b = Bench::quick();
        let mut acc = 0u64;
        let r = b.case("noop-ish", 0.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters_total > 0);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_time(12.3), "12.3 ns");
        assert!(human_time(4_500.0).contains("µs"));
        assert!(human_time(7.2e6).contains("ms"));
        assert!(human_rate(2.5e6).contains("M"));
    }
}
