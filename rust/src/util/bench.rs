//! Micro-benchmark harness (criterion is not in the offline crate set).
//!
//! `cargo bench` targets use [`Bench`] for warmup + repeated timing with
//! mean/std/throughput reporting, and a black-box to defeat dead-code
//! elimination. Output format is one line per case:
//! `bench <name> ... mean <t> ± <std>  [<throughput>]`.
//!
//! [`Summary`] collects the per-case results into a machine-readable
//! bench-summary JSON (`BENCH_<bench>.json`, or `$BENCH_SUMMARY_OUT`) so
//! perf runs can be recorded and diffed (EXPERIMENTS.md §Perf).

use std::ffi::OsString;
use std::hint::black_box as std_black_box;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::io::{write_file, Json};
use super::stats::Welford;

/// Re-export of the std black box (stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark runner with shared settings.
pub struct Bench {
    /// Warmup time per case.
    pub warmup: Duration,
    /// Measured samples per case.
    pub samples: usize,
    /// Minimum time per sample (iterations are batched to reach it).
    pub sample_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            samples: 12,
            sample_time: Duration::from_millis(60),
        }
    }
}

/// Result of one case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub iters_total: u64,
    /// Items processed per iteration (0 = throughput untracked).
    pub items_per_iter: f64,
}

impl Bench {
    pub fn quick() -> Bench {
        Bench {
            warmup: Duration::from_millis(20),
            samples: 5,
            sample_time: Duration::from_millis(10),
        }
    }

    /// Time `f` (called repeatedly); returns per-iteration stats and prints
    /// a line. `items_per_iter` (if > 0) adds a throughput column.
    pub fn case<F: FnMut()>(&self, name: &str, items_per_iter: f64, mut f: F) -> CaseResult {
        // Warmup + batch-size estimation.
        let mut iters_per_sample = 1u64;
        let warm_start = Instant::now();
        let mut single = Duration::ZERO;
        while warm_start.elapsed() < self.warmup {
            let t = Instant::now();
            f();
            single = t.elapsed();
        }
        if single > Duration::ZERO {
            let per = self.sample_time.as_nanos() / single.as_nanos().max(1);
            iters_per_sample = per.clamp(1, 1_000_000_000) as u64;
        }

        let mut w = Welford::new();
        let mut iters_total = 0u64;
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            let ns = t.elapsed().as_nanos() as f64 / iters_per_sample as f64;
            w.push(ns);
            iters_total += iters_per_sample;
        }
        let result = CaseResult {
            name: name.to_string(),
            mean_ns: w.mean(),
            std_ns: w.sample_std(),
            iters_total,
            items_per_iter,
        };
        let thr = if items_per_iter > 0.0 {
            format!("  [{:>12} items/s]", human_rate(items_per_iter * 1e9 / w.mean()))
        } else {
            String::new()
        };
        println!(
            "bench {:<44} mean {:>12} ± {:>10}{}",
            result.name,
            human_time(w.mean()),
            human_time(w.sample_std()),
            thr
        );
        result
    }
}

/// Machine-readable bench summary: collects [`CaseResult`]s plus
/// free-form context notes (kernel name, build flags, host facts) and
/// renders/writes them as JSON for recording perf runs.
#[derive(Clone, Debug)]
pub struct Summary {
    bench: String,
    notes: Vec<(String, String)>,
    cases: Vec<CaseResult>,
}

impl Summary {
    pub fn new(bench: &str) -> Summary {
        Summary { bench: bench.to_string(), notes: Vec::new(), cases: Vec::new() }
    }

    /// Attach a context note (insertion-ordered in the JSON).
    pub fn note(&mut self, key: &str, value: &str) -> &mut Self {
        self.notes.push((key.to_string(), value.to_string()));
        self
    }

    /// Record one case result.
    pub fn push(&mut self, r: CaseResult) -> &mut Self {
        self.cases.push(r);
        self
    }

    /// The summary as a JSON tree: `{bench, notes: {..}, cases: [..]}`.
    /// Cases with tracked throughput carry a derived `items_per_s`.
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("bench", self.bench.as_str());
        let mut notes = Json::obj();
        for (k, v) in &self.notes {
            notes.set(k.as_str(), v.as_str());
        }
        root.set("notes", notes);
        let cases: Vec<Json> = self
            .cases
            .iter()
            .map(|c| {
                let mut o = Json::obj();
                o.set("name", c.name.as_str());
                o.set("mean_ns", c.mean_ns);
                o.set("std_ns", c.std_ns);
                o.set("iters", c.iters_total as f64);
                o.set("items_per_iter", c.items_per_iter);
                if c.items_per_iter > 0.0 {
                    o.set("items_per_s", c.items_per_iter * 1e9 / c.mean_ns.max(1e-9));
                }
                o
            })
            .collect();
        root.set("cases", cases);
        root
    }

    /// Write the summary JSON (atomically) and return the path:
    /// `$BENCH_SUMMARY_OUT` when set and non-empty, else
    /// `BENCH_<bench>.json` in the working directory.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = summary_path(std::env::var_os("BENCH_SUMMARY_OUT"), &self.bench);
        write_file(&path, &self.to_json().render())?;
        Ok(path)
    }
}

/// Pure path resolution for [`Summary::write`] (testable without
/// touching the process environment).
fn summary_path(env_override: Option<OsString>, bench: &str) -> PathBuf {
    match env_override {
        Some(p) if !p.is_empty() => PathBuf::from(p),
        _ => PathBuf::from(format!("BENCH_{bench}.json")),
    }
}

/// Human-readable nanoseconds.
pub fn human_time(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Human-readable rate.
pub fn human_rate(per_s: f64) -> String {
    if per_s >= 1e9 {
        format!("{:.2} G", per_s / 1e9)
    } else if per_s >= 1e6 {
        format!("{:.2} M", per_s / 1e6)
    } else if per_s >= 1e3 {
        format!("{:.2} k", per_s / 1e3)
    } else {
        format!("{per_s:.1} ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_measures_something() {
        let b = Bench::quick();
        let mut acc = 0u64;
        let r = b.case("noop-ish", 0.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters_total > 0);
    }

    #[test]
    fn summary_renders_machine_readable_json() {
        let b = Bench::quick();
        let mut acc = 0u64;
        let mut s = Summary::new("unit");
        s.note("kernel", "scalar");
        s.push(b.case("spin", 64.0, || {
            acc = black_box(acc.wrapping_add(1));
        }));
        let parsed = Json::parse(&s.to_json().render()).unwrap();
        assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("unit"));
        assert_eq!(
            parsed.get("notes").and_then(|n| n.get("kernel")).and_then(Json::as_str),
            Some("scalar")
        );
        let cases = parsed.get("cases").and_then(Json::as_arr).unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").and_then(Json::as_str), Some("spin"));
        assert!(cases[0].get_num("mean_ns").unwrap() > 0.0);
        assert_eq!(cases[0].get_num("items_per_iter"), Some(64.0));
        assert!(cases[0].get_num("items_per_s").unwrap() > 0.0);
    }

    #[test]
    fn summary_path_prefers_nonempty_env_override() {
        assert_eq!(summary_path(None, "engine"), PathBuf::from("BENCH_engine.json"));
        assert_eq!(
            summary_path(Some(OsString::new()), "engine"),
            PathBuf::from("BENCH_engine.json")
        );
        assert_eq!(
            summary_path(Some(OsString::from("/tmp/out.json")), "engine"),
            PathBuf::from("/tmp/out.json")
        );
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_time(12.3), "12.3 ns");
        assert!(human_time(4_500.0).contains("µs"));
        assert!(human_time(7.2e6).contains("ms"));
        assert!(human_rate(2.5e6).contains("M"));
    }
}
