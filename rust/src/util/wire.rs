//! Lossless JSON wire primitives shared by every serialization surface:
//! the cluster's framed-JSONL shard protocol ([`crate::cluster::wire`])
//! and the controller's telemetry record/replay log
//! ([`crate::control::replay`]). serde is not in the offline crate set,
//! so codecs are hand-rolled on [`crate::util::io::Json`].
//!
//! Round-trips are exact: floats ride Rust's shortest round-trip
//! formatting (`Json::render*` / `Json::parse`), with string sentinels
//! for the values JSON numbers cannot carry (NaN/±inf/-0.0, see
//! [`f64_to_json`]), and integers above 2^53 fall back to decimal
//! strings (see [`u64_to_json`]) — so a decoded value re-runs its
//! computation bit-identically.

use super::io::Json;

/// Decode failure: the input was not valid JSON, or was valid JSON that
/// is not a well-formed message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireError(pub String);

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire decode error: {}", self.0)
    }
}

impl std::error::Error for WireError {}

pub(crate) fn err<T>(msg: impl Into<String>) -> Result<T, WireError> {
    Err(WireError(msg.into()))
}

/// Symmetric JSON codec for one wire type: `from_wire(&to_wire(x)) == x`.
pub trait WireCodec: Sized {
    fn to_wire(&self) -> Json;
    fn from_wire(v: &Json) -> Result<Self, WireError>;
}

/// Largest integer magnitude `Json::Num` (an f64) represents exactly.
const MAX_EXACT_INT: u64 = 1 << 53;

/// Encode an f64 losslessly. Ordinary values ride `Json::Num` (shortest
/// round-trip formatting); the values the JSON number grammar cannot
/// carry — NaN, ±inf (the writer renders them as `null`) and -0.0 (the
/// writer's integer path renders it as `0`) — ride string sentinels.
pub fn f64_to_json(x: f64) -> Json {
    if x.is_nan() {
        Json::Str("nan".to_string())
    } else if x == f64::INFINITY {
        Json::Str("inf".to_string())
    } else if x == f64::NEG_INFINITY {
        Json::Str("-inf".to_string())
    } else if x == 0.0 && x.is_sign_negative() {
        Json::Str("-0".to_string())
    } else {
        Json::Num(x)
    }
}

/// Decode the [`f64_to_json`] encoding (number or sentinel string).
pub fn f64_from_json(v: &Json) -> Result<f64, WireError> {
    match v {
        Json::Num(x) => Ok(*x),
        Json::Str(s) => match s.as_str() {
            "nan" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "-0" => Ok(-0.0),
            other => err(format!("bad float sentinel: {other:?}")),
        },
        _ => err("expected a number"),
    }
}

/// Encode a u64 losslessly: values up to 2^53 ride as JSON numbers, the
/// rest (hash-derived seeds, sentinel step caps) as decimal strings.
pub fn u64_to_json(x: u64) -> Json {
    if x <= MAX_EXACT_INT {
        Json::Num(x as f64)
    } else {
        Json::Str(x.to_string())
    }
}

/// Decode the [`u64_to_json`] encoding (number or decimal string).
pub fn u64_from_json(v: &Json) -> Result<u64, WireError> {
    match v {
        Json::Num(x) => {
            if x.is_finite() && *x >= 0.0 && x.trunc() == *x && *x <= MAX_EXACT_INT as f64 {
                Ok(*x as u64)
            } else {
                err(format!("not a non-negative integer: {x}"))
            }
        }
        Json::Str(s) => {
            s.parse::<u64>().map_err(|_| WireError(format!("bad integer string: {s:?}")))
        }
        _ => err("expected an integer"),
    }
}

pub(crate) fn field<'a>(v: &'a Json, key: &str) -> Result<&'a Json, WireError> {
    v.get(key).ok_or_else(|| WireError(format!("missing field `{key}`")))
}

pub(crate) fn str_field(v: &Json, key: &str) -> Result<String, WireError> {
    field(v, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| WireError(format!("field `{key}` must be a string")))
}

pub(crate) fn f64_field(v: &Json, key: &str) -> Result<f64, WireError> {
    f64_from_json(field(v, key)?).map_err(|e| WireError(format!("field `{key}`: {}", e.0)))
}

pub(crate) fn bool_field(v: &Json, key: &str) -> Result<bool, WireError> {
    field(v, key)?
        .as_bool()
        .ok_or_else(|| WireError(format!("field `{key}` must be a bool")))
}

pub(crate) fn u64_field(v: &Json, key: &str) -> Result<u64, WireError> {
    u64_from_json(field(v, key)?).map_err(|e| WireError(format!("field `{key}`: {}", e.0)))
}

pub(crate) fn usize_field(v: &Json, key: &str) -> Result<usize, WireError> {
    Ok(u64_field(v, key)? as usize)
}

/// Encode a float slice losslessly (element-wise [`f64_to_json`]).
pub fn f64s_to_json(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| f64_to_json(*x)).collect())
}

/// Decode the [`f64s_to_json`] encoding.
pub fn f64s_from_json(v: &Json) -> Result<Vec<f64>, WireError> {
    let Some(arr) = v.as_arr() else {
        return err("expected an array of numbers");
    };
    arr.iter().map(f64_from_json).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_codec_carries_what_json_numbers_cannot() {
        // The raw writer would fold these to `null` / `0`; the sentinel
        // path keeps them bit-faithful (NaN up to payload canonization).
        assert!(f64_from_json(&f64_to_json(f64::NAN)).unwrap().is_nan());
        assert_eq!(f64_from_json(&f64_to_json(f64::INFINITY)).unwrap(), f64::INFINITY);
        assert_eq!(f64_from_json(&f64_to_json(f64::NEG_INFINITY)).unwrap(), f64::NEG_INFINITY);
        let neg_zero = f64_from_json(&f64_to_json(-0.0)).unwrap();
        assert!(neg_zero == 0.0 && neg_zero.is_sign_negative());
        // Ordinary values stay plain numbers.
        assert_eq!(f64_to_json(0.035), Json::Num(0.035));
        assert_eq!(f64_from_json(&Json::Num(-2.5)).unwrap(), -2.5);
        assert!(f64_from_json(&Json::Str("fast".into())).is_err());
        assert!(f64_from_json(&Json::Null).is_err());
    }

    #[test]
    fn u64_codec_is_lossless_at_both_ends() {
        for x in [0, 1, 1 << 53, (1 << 53) + 1, u64::MAX] {
            assert_eq!(u64_from_json(&u64_to_json(x)).unwrap(), x);
        }
        assert!(u64_from_json(&Json::Num(-1.0)).is_err());
        assert!(u64_from_json(&Json::Num(1.5)).is_err());
        assert!(u64_from_json(&Json::Str("12x".into())).is_err());
        assert!(u64_from_json(&Json::Null).is_err());
    }

    #[test]
    fn f64_slice_round_trips_exactly() {
        let xs = vec![0.8, 0.9, 1.1, 1.6, -0.0, f64::INFINITY, 1.0 / 3.0];
        let back = f64s_from_json(&f64s_to_json(&xs)).unwrap();
        assert_eq!(xs.len(), back.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(f64s_from_json(&Json::Num(1.0)).is_err());
        assert!(f64s_from_json(&Json::Arr(vec![Json::Null])).is_err());
    }
}
