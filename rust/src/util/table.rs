//! ASCII table rendering for the experiment reports.
//!
//! The experiment harness prints the paper's tables (Table 1, Table 2, the
//! figure series) as aligned text tables; this module owns the layout.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
#[derive(Clone, Debug)]
pub struct Table {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    /// Row indices after which a horizontal rule is drawn.
    rules: Vec<usize>,
}

impl Table {
    /// Create a table with the given header; first column left-aligned,
    /// the rest right-aligned (the usual layout for metric tables).
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        let header: Vec<String> = header.into_iter().map(Into::into).collect();
        let mut aligns = vec![Align::Right; header.len()];
        if !aligns.is_empty() {
            aligns[0] = Align::Left;
        }
        Table { header, aligns, rows: Vec::new(), rules: Vec::new() }
    }

    pub fn align(mut self, col: usize, align: Align) -> Table {
        self.aligns[col] = align;
        self
    }

    /// Append a row; panics if the arity does not match the header.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Draw a horizontal rule after the most recent row.
    pub fn rule(&mut self) -> &mut Self {
        self.rules.push(self.rows.len());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s.push('\n');
            s
        };
        let fmt_row = |cells: &[String], out: &mut String| {
            out.push('|');
            for i in 0..ncols {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                match self.aligns[i] {
                    Align::Left => {
                        out.push(' ');
                        out.push_str(cell);
                        out.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        out.push_str(&" ".repeat(pad + 1));
                        out.push_str(cell);
                        out.push(' ');
                    }
                }
                out.push('|');
            }
            out.push('\n');
        };
        out.push_str(&sep);
        fmt_row(&self.header, &mut out);
        out.push_str(&sep);
        for (i, row) in self.rows.iter().enumerate() {
            fmt_row(row, &mut out);
            if self.rules.contains(&(i + 1)) {
                out.push_str(&sep);
            }
        }
        out.push_str(&sep);
        out
    }
}

/// Format a float with `digits` decimal places.
pub fn fnum(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format a float with thousands separators, paper-style ("1,353.41").
pub fn fnum_sep(x: f64, digits: usize) -> String {
    let s = format!("{:.*}", digits, x.abs());
    let (int_part, frac_part) = match s.split_once('.') {
        Some((a, b)) => (a.to_string(), Some(b.to_string())),
        None => (s, None),
    };
    let mut grouped = String::new();
    let bytes = int_part.as_bytes();
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            grouped.push(',');
        }
        grouped.push(*b as char);
    }
    let mut out = String::new();
    if x < 0.0 {
        out.push('-');
    }
    out.push_str(&grouped);
    if let Some(f) = frac_part {
        out.push('.');
        out.push_str(&f);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "kJ"]);
        t.row(vec!["lbm", "93.94"]);
        t.row(vec!["sph_exa", "1,353.41"]);
        let s = t.render();
        assert!(s.contains("| name    |"), "{s}");
        assert!(s.contains("| sph_exa | 1,353.41 |"), "{s}");
        // All lines equal width.
        let lens: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn thousands_separator() {
        assert_eq!(fnum_sep(1353.41, 2), "1,353.41");
        assert_eq!(fnum_sep(93.94, 2), "93.94");
        assert_eq!(fnum_sep(-1234567.5, 1), "-1,234,567.5");
        assert_eq!(fnum_sep(0.0, 2), "0.00");
        assert_eq!(fnum_sep(999.99, 2), "999.99");
        assert_eq!(fnum_sep(1000.0, 0), "1,000");
    }

    #[test]
    fn rules_inserted() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["1"]);
        t.rule();
        t.row(vec!["2"]);
        let s = t.render();
        let seps = s.lines().filter(|l| l.starts_with('+')).count();
        assert_eq!(seps, 4); // top, after header, mid rule, bottom
    }
}
