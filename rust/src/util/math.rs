//! Small numeric helpers shared across the simulator and policies.

/// Clamp `x` into [lo, hi].
#[inline]
pub fn clamp(x: f64, lo: f64, hi: f64) -> f64 {
    debug_assert!(lo <= hi);
    x.max(lo).min(hi)
}

/// Linear interpolation between `a` and `b` by `t` in [0,1].
#[inline]
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

/// Piecewise-linear interpolation through `(xs, ys)` points sorted by x.
/// Clamps outside the domain (flat extrapolation).
pub fn interp(xs: &[f64], ys: &[f64], x: f64) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    debug_assert!(xs.windows(2).all(|w| w[0] <= w[1]), "interp xs must be sorted");
    if x <= xs[0] {
        return ys[0];
    }
    if x >= xs[xs.len() - 1] {
        return ys[ys.len() - 1];
    }
    // Binary search for the segment.
    let mut lo = 0usize;
    let mut hi = xs.len() - 1;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if xs[mid] <= x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let t = (x - xs[lo]) / (xs[hi] - xs[lo]);
    lerp(ys[lo], ys[hi], t)
}

/// Approximately-equal with relative + absolute tolerance.
#[inline]
pub fn approx_eq(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    diff <= abs || diff <= rel * a.abs().max(b.abs())
}

/// Softmax over a slice (numerically stable).
pub fn softmax(xs: &[f64]) -> Vec<f64> {
    assert!(!xs.is_empty());
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = xs.iter().map(|x| (x - m).exp()).collect();
    let total: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / total).collect()
}

/// Round to `digits` decimal places.
#[inline]
pub fn round_to(x: f64, digits: u32) -> f64 {
    let p = 10f64.powi(digits as i32);
    (x * p).round() / p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamp_works() {
        assert_eq!(clamp(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clamp(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clamp(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn interp_segments() {
        let xs = [0.0, 1.0, 2.0];
        let ys = [0.0, 10.0, 40.0];
        assert!((interp(&xs, &ys, 0.5) - 5.0).abs() < 1e-12);
        assert!((interp(&xs, &ys, 1.5) - 25.0).abs() < 1e-12);
        // Flat extrapolation.
        assert!((interp(&xs, &ys, -1.0) - 0.0).abs() < 1e-12);
        assert!((interp(&xs, &ys, 3.0) - 40.0).abs() < 1e-12);
    }

    #[test]
    fn interp_hits_knots() {
        let xs = [1.0, 1.4545, 2.0];
        let ys = [1.0, 1.0596, 1.3297];
        for i in 0..xs.len() {
            assert!((interp(&xs, &ys, xs[i]) - ys[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // Stability with large values.
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn approx_eq_tolerances() {
        assert!(approx_eq(100.0, 100.4, 0.01, 0.0));
        assert!(!approx_eq(100.0, 102.0, 0.01, 0.0));
        assert!(approx_eq(1e-9, 0.0, 0.0, 1e-8));
    }

    #[test]
    fn round_to_digits() {
        assert_eq!(round_to(1.23456, 2), 1.23);
        assert_eq!(round_to(1.235, 2), 1.24);
    }
}
