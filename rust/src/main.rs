//! `energyucb` — the leader binary: experiment harness, single-node runs,
//! and the fleet engine, all behind subcommands (see `energyucb help`).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match energyucb::cli::dispatch(&argv) {
        Ok(code) => std::process::exit(code),
        Err(err) => {
            eprintln!("error: {err:#}");
            std::process::exit(1);
        }
    }
}
