//! # EnergyUCB — online GPU energy optimization with switching-aware bandits
//!
//! A full-system reproduction of *"Online GPU Energy Optimization with
//! Switching-Aware Bandits"* (WWW '26): the EnergyUCB controller
//! (switching-aware UCB + optimistic initialization + QoS-constrained
//! variant), every baseline the paper compares against, and the complete
//! substrate it runs on — a trace-calibrated Aurora-node simulator with
//! PVC GPU counter models driven through a GEOPM-like service/runtime
//! split.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the rust coordinator: policies ([`bandit`],
//!   [`rl`]), hardware substrate ([`sim`], [`workload`], [`geopm`]),
//!   control sessions ([`control`]), the experiment harness regenerating
//!   every table/figure of the paper, and the PJRT-backed fleet engine.
//! * **L2/L1 (python, build-time only)** — a vectorized bandit+environment
//!   step (JAX) whose SA-UCB hot loop is a Pallas kernel, AOT-lowered to
//!   `artifacts/*.hlo.txt` and executed from rust via PJRT.
//!
//! ## Quickstart
//!
//! ```no_run
//! use energyucb::bandit::{EnergyUcb, EnergyUcbConfig, Policy};
//! use energyucb::control::{run_session, SessionCfg};
//! use energyucb::workload;
//!
//! let app = workload::app("tealeaf").unwrap();
//! let mut policy = EnergyUcb::new(9, EnergyUcbConfig::default());
//! let result = run_session(&app, &mut policy, &SessionCfg::default());
//! println!("energy: {:.2} kJ", result.metrics.gpu_energy_kj);
//! ```

pub mod bandit;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod control;
pub mod exec;
pub mod experiments;
pub mod geopm;
pub mod fleet;
pub mod hw;
pub mod rl;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod testutil;
pub mod util;
pub mod workload;

/// Library version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
