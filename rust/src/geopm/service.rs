//! The (simulated) GEOPM service: secure, user-level access to hardware
//! telemetry and control.
//!
//! The service owns the [`Node`] and mediates every interaction: agents
//! read cumulative signals, write the frequency control, and ask the
//! service to advance one sampling interval. This is the same
//! service/runtime split as real GEOPM — the agent below never sees the
//! device model, only counters.

use super::signals::{Control, Signal};
use crate::sim::node::{Node, NodeObservation, NodeTotals};
use crate::sim::counters::EngineGroup;

/// Error type for signal/control access.
#[derive(Debug, PartialEq)]
pub enum ServiceError {
    UnknownSignal(String),
    ControlOutOfRange { arm: usize, k: usize },
    Completed,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::UnknownSignal(name) => write!(f, "unknown signal: {name}"),
            ServiceError::ControlOutOfRange { arm, k } => {
                write!(f, "control out of range: arm {arm} >= K {k}")
            }
            ServiceError::Completed => write!(f, "application already completed"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// One sampling interval's service-side record (what a `geopmread` batch
/// would return, already diffed for convenience).
#[derive(Clone, Copy, Debug)]
pub struct ServiceSample {
    pub obs: NodeObservation,
    /// Arm in effect during the interval.
    pub arm: usize,
    /// Whether the interval performed a frequency transition.
    pub switched: bool,
}

/// The simulated GEOPM service for one node.
#[derive(Debug)]
pub struct Service {
    node: Node,
    pending_arm: usize,
    cum_progress: f64,
}

impl Service {
    pub fn new(node: Node) -> Service {
        let pending_arm = node.frequency();
        Service { node, pending_arm, cum_progress: 0.0 }
    }

    /// Number of frequency arms.
    pub fn k(&self) -> usize {
        self.node.freqs().k()
    }

    /// Sampling period, seconds.
    pub fn period_s(&self) -> f64 {
        self.node.dt_s()
    }

    /// Cumulative signal read (PlatformIO style).
    pub fn read(&self, signal: Signal) -> f64 {
        match signal {
            // Sum of the per-GPU monotonic counters — the measured path.
            Signal::GpuEnergy => self.node.counter_energy_j(),
            Signal::GpuCoreActiveTime => self.node.engine_active_s(EngineGroup::Compute),
            Signal::GpuUncoreActiveTime => self.node.engine_active_s(EngineGroup::Copy),
            Signal::Time => self.node.elapsed_s(),
            Signal::AppProgress => self.cum_progress,
            Signal::CpuEnergy => self.node.totals().cpu_energy_kj * 1_000.0,
        }
    }

    /// Read by GEOPM signal name (CLI surface).
    pub fn read_by_name(&self, name: &str) -> Result<f64, ServiceError> {
        let s = Signal::from_name(name).ok_or_else(|| ServiceError::UnknownSignal(name.into()))?;
        Ok(self.read(s))
    }

    /// Write a control to take effect at the next sample.
    pub fn write(&mut self, control: Control) -> Result<(), ServiceError> {
        match control {
            Control::GpuFrequency(arm) => {
                if arm >= self.k() {
                    return Err(ServiceError::ControlOutOfRange { arm, k: self.k() });
                }
                self.pending_arm = arm;
                Ok(())
            }
        }
    }

    /// Advance one sampling interval under the pending control.
    pub fn sample(&mut self) -> Result<ServiceSample, ServiceError> {
        if self.node.done() {
            return Err(ServiceError::Completed);
        }
        let arm = self.pending_arm;
        let switched = arm != self.node.frequency();
        let obs = self.node.step(arm);
        self.cum_progress += obs.progress;
        Ok(ServiceSample { obs, arm, switched })
    }

    pub fn done(&self) -> bool {
        self.node.done()
    }

    pub fn totals(&self) -> NodeTotals {
        self.node.totals()
    }

    pub fn node(&self) -> &Node {
        &self.node
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::freq::FreqDomain;
    use crate::workload::calibration;

    fn mk() -> Service {
        let node = Node::new(
            calibration::app("tealeaf").unwrap(),
            FreqDomain::aurora(),
            0.01,
            1,
        );
        Service::new(node)
    }

    #[test]
    fn control_validation() {
        let mut s = mk();
        assert!(s.write(Control::GpuFrequency(0)).is_ok());
        assert_eq!(
            s.write(Control::GpuFrequency(99)),
            Err(ServiceError::ControlOutOfRange { arm: 99, k: 9 })
        );
    }

    #[test]
    fn sample_applies_pending_control() {
        let mut s = mk();
        s.write(Control::GpuFrequency(2)).unwrap();
        let smp = s.sample().unwrap();
        assert_eq!(smp.arm, 2);
        assert!(smp.switched);
        // Second sample at the same arm: no switch.
        let smp = s.sample().unwrap();
        assert_eq!(smp.arm, 2);
        assert!(!smp.switched);
    }

    #[test]
    fn signals_progress_monotonically() {
        let mut s = mk();
        let mut last_t = -1.0;
        let mut last_p = -1.0;
        for _ in 0..100 {
            s.sample().unwrap();
            let t = s.read(Signal::Time);
            let p = s.read(Signal::AppProgress);
            assert!(t > last_t);
            assert!(p > last_p);
            last_t = t;
            last_p = p;
        }
        assert!((last_t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn read_by_name() {
        let s = mk();
        assert!(s.read_by_name("TIME").is_ok());
        assert!(matches!(
            s.read_by_name("BOGUS"),
            Err(ServiceError::UnknownSignal(_))
        ));
    }

    #[test]
    fn sample_after_completion_errors() {
        let mut s = mk();
        while !s.done() {
            s.sample().unwrap();
        }
        assert_eq!(s.sample().unwrap_err(), ServiceError::Completed);
    }
}
