//! The (simulated) GEOPM runtime: the agent loop.
//!
//! Mirrors real GEOPM's runtime component: every sampling period (10 ms,
//! matching the paper) it reads the service's counters, derives the
//! per-interval observation an energy agent consumes (energy delta,
//! core/uncore utilization, progress delta), asks the agent for a frequency
//! decision, and writes the control back. Agents are the pluggable policy
//! surface — EnergyUCB, the baselines, and the RL controllers all implement
//! [`Agent`].

use super::service::{Service, ServiceError, ServiceSample};
use super::signals::Control;

/// Per-interval observation handed to the agent, derived purely from
/// service signals (the controller-visible world).
#[derive(Clone, Copy, Debug)]
pub struct AgentObs {
    /// Decision index, 1-based.
    pub t: u64,
    /// Measured GPU energy over the interval, Joules.
    pub energy_j: f64,
    /// Aggregate core-engine utilization in [0, 1].
    pub core_util: f64,
    /// Aggregate uncore-engine utilization in [0, 1].
    pub uncore_util: f64,
    /// Progress made this interval (fraction of the app).
    pub progress: f64,
    /// Arm in effect during the interval.
    pub arm: usize,
    /// Whether this interval paid a switch.
    pub switched: bool,
}

/// An energy-management agent: decides the next frequency arm.
pub trait Agent {
    /// Called once per interval with the previous interval's observation;
    /// returns the arm for the next interval. `obs` is `None` on the very
    /// first call (no telemetry yet).
    fn decide(&mut self, obs: Option<&AgentObs>, k: usize) -> usize;
}

/// Outcome of a completed agent-driven run.
#[derive(Clone, Debug)]
pub struct RuntimeReport {
    pub steps: u64,
    /// Every interval observation, in order (empty if recording disabled).
    pub observations: Vec<AgentObs>,
}

/// The runtime loop driving one agent against one service.
pub struct Runtime {
    service: Service,
    record: bool,
}

impl Runtime {
    pub fn new(service: Service) -> Runtime {
        Runtime { service, record: false }
    }

    /// Record all observations in the report (costs memory on long runs).
    pub fn recording(mut self, on: bool) -> Runtime {
        self.record = on;
        self
    }

    pub fn service(&self) -> &Service {
        &self.service
    }

    /// Drive the agent until application completion (or `max_steps`).
    pub fn run(&mut self, agent: &mut dyn Agent, max_steps: u64) -> Result<RuntimeReport, ServiceError> {
        let k = self.service.k();
        let mut t: u64 = 0;
        let mut last: Option<AgentObs> = None;
        let mut observations = Vec::new();
        while !self.service.done() && t < max_steps {
            t += 1;
            let arm = agent.decide(last.as_ref(), k);
            self.service.write(Control::GpuFrequency(arm))?;
            let ServiceSample { obs, arm, switched } = self.service.sample()?;
            let agent_obs = AgentObs {
                t,
                energy_j: obs.gpu_energy_j,
                core_util: obs.core_util,
                uncore_util: obs.uncore_util,
                progress: obs.progress,
                arm,
                switched,
            };
            if self.record {
                observations.push(agent_obs);
            }
            last = Some(agent_obs);
        }
        Ok(RuntimeReport { steps: t, observations })
    }

    /// Consume the runtime and return the service for final accounting.
    pub fn into_service(self) -> Service {
        self.service
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::freq::FreqDomain;
    use crate::sim::node::Node;
    use crate::workload::calibration;

    struct FixedAgent(usize);
    impl Agent for FixedAgent {
        fn decide(&mut self, _obs: Option<&AgentObs>, _k: usize) -> usize {
            self.0
        }
    }

    struct CyclingAgent;
    impl Agent for CyclingAgent {
        fn decide(&mut self, obs: Option<&AgentObs>, k: usize) -> usize {
            match obs {
                None => 0,
                Some(o) => (o.arm + 1) % k,
            }
        }
    }

    fn mk_runtime(app: &str, seed: u64) -> Runtime {
        let node = Node::new(calibration::app(app).unwrap(), FreqDomain::aurora(), 0.01, seed);
        Runtime::new(Service::new(node))
    }

    #[test]
    fn fixed_agent_runs_to_completion() {
        let mut rt = mk_runtime("clvleaf", 1);
        let mut agent = FixedAgent(8);
        let report = rt.run(&mut agent, 1_000_000).unwrap();
        assert!(rt.service().done());
        // clvleaf @1.6 GHz: ~40 s / 10 ms.
        assert!((report.steps as f64 - 4000.0).abs() < 40.0, "{}", report.steps);
        let totals = rt.service().totals();
        assert!((totals.gpu_energy_kj - 100.65).abs() < 0.8, "{}", totals.gpu_energy_kj);
    }

    #[test]
    fn cycling_agent_switches_every_step() {
        let mut rt = mk_runtime("tealeaf", 2);
        let mut agent = CyclingAgent;
        rt.run(&mut agent, 500).unwrap();
        let totals = rt.service().totals();
        // Every decision changes frequency (9-cycle).
        assert!(totals.switches >= 499, "{}", totals.switches);
    }

    #[test]
    fn recording_captures_observations() {
        let mut rt = mk_runtime("clvleaf", 3).recording(true);
        let mut agent = FixedAgent(4);
        let report = rt.run(&mut agent, 100).unwrap();
        assert_eq!(report.observations.len(), 100);
        let o = &report.observations[50];
        assert_eq!(o.arm, 4);
        assert!(o.energy_j > 0.0);
        assert!(o.core_util > 0.0 && o.core_util <= 1.0);
        assert!(o.progress > 0.0);
    }

    #[test]
    fn max_steps_bounds_run() {
        let mut rt = mk_runtime("sph_exa", 4);
        let mut agent = FixedAgent(8);
        let report = rt.run(&mut agent, 10).unwrap();
        assert_eq!(report.steps, 10);
        assert!(!rt.service().done());
    }
}
