//! Simulated GEOPM (Global Extensible Open Power Manager) stack.
//!
//! Mirrors the real tool's split (paper §4.1): the **service** grants
//! user-level access to hardware signals and controls; the **runtime**
//! drives an agent loop that adjusts settings from real-time telemetry.
//! All controller↔hardware interaction goes through here.

pub mod runtime;
pub mod service;
pub mod signals;

pub use runtime::{Agent, AgentObs, Runtime, RuntimeReport};
pub use service::{Service, ServiceError, ServiceSample};
pub use signals::{Control, Signal};
