//! Signal and control names exposed by the (simulated) GEOPM service.
//!
//! Mirrors the real GEOPM PlatformIO naming style: flat string-addressable
//! signals with board/GPU domains. The controller reads signals and writes
//! controls; it never touches the device model directly.

use std::fmt;

/// Telemetry signals the service exposes (cumulative counters unless noted).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Signal {
    /// Total GPU energy across the node, Joules ("GPU::ENERGY").
    GpuEnergy,
    /// Aggregate compute-engine active time, seconds ("GPU::CORE_ACTIVE_TIME").
    GpuCoreActiveTime,
    /// Aggregate copy-engine active time, seconds ("GPU::UNCORE_ACTIVE_TIME").
    GpuUncoreActiveTime,
    /// Node uptime, seconds ("TIME").
    Time,
    /// Application progress in [0,1] ("EPOCH::PROGRESS", via geopm_prof).
    AppProgress,
    /// CPU package energy, Joules ("CPU::ENERGY").
    CpuEnergy,
}

impl Signal {
    pub const ALL: [Signal; 6] = [
        Signal::GpuEnergy,
        Signal::GpuCoreActiveTime,
        Signal::GpuUncoreActiveTime,
        Signal::Time,
        Signal::AppProgress,
        Signal::CpuEnergy,
    ];

    /// GEOPM-style signal name.
    pub fn name(&self) -> &'static str {
        match self {
            Signal::GpuEnergy => "GPU::ENERGY",
            Signal::GpuCoreActiveTime => "GPU::CORE_ACTIVE_TIME",
            Signal::GpuUncoreActiveTime => "GPU::UNCORE_ACTIVE_TIME",
            Signal::Time => "TIME",
            Signal::AppProgress => "EPOCH::PROGRESS",
            Signal::CpuEnergy => "CPU::ENERGY",
        }
    }

    pub fn from_name(name: &str) -> Option<Signal> {
        Signal::ALL.iter().copied().find(|s| s.name() == name)
    }
}

impl fmt::Display for Signal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Controls the service accepts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Control {
    /// GPU core frequency for all devices, by arm index
    /// ("GPU::FREQUENCY_CONTROL").
    GpuFrequency(usize),
}

impl Control {
    pub fn name(&self) -> &'static str {
        match self {
            Control::GpuFrequency(_) => "GPU::FREQUENCY_CONTROL",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for s in Signal::ALL {
            assert_eq!(Signal::from_name(s.name()), Some(s));
        }
        assert_eq!(Signal::from_name("NOPE"), None);
    }

    #[test]
    fn display_matches_name() {
        assert_eq!(Signal::GpuEnergy.to_string(), "GPU::ENERGY");
        assert_eq!(Control::GpuFrequency(3).name(), "GPU::FREQUENCY_CONTROL");
    }
}
