//! Phased (non-stationary) workloads — an extension beyond the paper.
//!
//! Real HPC jobs interleave compute-heavy and data-movement-heavy phases.
//! The paper treats each benchmark as stationary; this module composes
//! calibrated [`AppModel`]s into a phase sequence so we can study how the
//! controller tracks a drifting optimum (see the `phased` ablation bench
//! and `examples/phased_workload.rs`). Discounted EnergyUCB
//! ([`crate::bandit::energyucb`] with `discount < 1`) is the matching
//! algorithmic extension.

use super::model::AppModel;

/// One phase: an app model and its share of the total work.
#[derive(Clone, Debug)]
pub struct Phase {
    pub model: AppModel,
    /// Fraction of total work done in this phase (phases must sum to 1).
    pub weight: f64,
}

/// A workload made of sequential phases.
#[derive(Clone, Debug)]
pub struct PhasedWorkload {
    pub name: String,
    phases: Vec<Phase>,
}

impl PhasedWorkload {
    pub fn new(name: impl Into<String>, phases: Vec<Phase>) -> PhasedWorkload {
        assert!(!phases.is_empty());
        let total: f64 = phases.iter().map(|p| p.weight).sum();
        assert!(
            (total - 1.0).abs() < 1e-9,
            "phase weights must sum to 1, got {total}"
        );
        assert!(phases.iter().all(|p| p.weight > 0.0));
        PhasedWorkload { name: name.into(), phases }
    }

    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }

    /// The phase active when `completed` fraction of total work is done,
    /// together with the index of that phase.
    pub fn phase_at(&self, completed: f64) -> (usize, &Phase) {
        let c = completed.clamp(0.0, 1.0);
        let mut acc = 0.0;
        for (i, p) in self.phases.iter().enumerate() {
            acc += p.weight;
            if c < acc - 1e-12 {
                return (i, p);
            }
        }
        (self.phases.len() - 1, self.phases.last().unwrap())
    }

    /// Remaining-work-weighted expected static energy at arm `i` (kJ):
    /// the oracle target for a phased run.
    pub fn static_energy_kj(&self, arm: usize) -> f64 {
        self.phases.iter().map(|p| p.model.energy_kj[arm] * p.weight).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    fn two_phase() -> PhasedWorkload {
        PhasedWorkload::new(
            "lbm+miniswp",
            vec![
                Phase { model: calibration::app("lbm").unwrap(), weight: 0.5 },
                Phase { model: calibration::app("miniswp").unwrap(), weight: 0.5 },
            ],
        )
    }

    #[test]
    fn phase_lookup_by_completion() {
        let w = two_phase();
        assert_eq!(w.phase_at(0.0).0, 0);
        assert_eq!(w.phase_at(0.49).0, 0);
        assert_eq!(w.phase_at(0.51).0, 1);
        assert_eq!(w.phase_at(1.0).0, 1);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_weights() {
        PhasedWorkload::new(
            "bad",
            vec![Phase { model: calibration::app("lbm").unwrap(), weight: 0.7 }],
        );
    }

    #[test]
    fn static_energy_blends_phases() {
        let w = two_phase();
        // Arm 8 = 1.6 GHz: (93.94 + 187.13)/2.
        assert!((w.static_energy_kj(8) - (93.94 + 187.13) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn phased_optimum_can_differ_from_either_phase() {
        let w = two_phase();
        let energies: Vec<f64> = (0..9).map(|i| w.static_energy_kj(i)).collect();
        let best = crate::util::stats::argmin(&energies);
        // lbm's optimum is arm 7 (1.5 GHz), miniswp's arm 0 (0.8 GHz); the
        // blend lands strictly between.
        assert!(best > 0 && best < 7, "best={best}");
    }
}
