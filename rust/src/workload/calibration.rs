//! Trace-calibrated application models.
//!
//! Per-frequency node-level GPU energies are the paper's **Table 1** static
//! rows, verbatim (kJ, one Aurora node = 6× PVC). Timing anchors come from
//! the paper where given:
//!
//! * pot3d — measured execution times 56.42 s @1.6 GHz, 59.78 s @1.1 GHz,
//!   75.02 s @0.8 GHz (Fig. 1(b));
//! * clvleaf / miniswp — §4.6 slowdowns of 14.46 % / 6.26 % at the
//!   1.2–1.3 GHz operating point fix their Amdahl memory-bound fractions;
//! * tealeaf — Fig. 3's "t = 4,000 ≈ 40 s" fixes the run length scale.
//!
//! The remaining T(f_max) values are chosen to give realistic node power
//! (≈ 1.7–3 kW of GPU draw) and the paper's step-count regime; powers are
//! then *derived* as P = E / T so the static-energy table reproduces
//! Table 1 exactly.

use super::model::{AppModel, Boundedness, NoiseSpec, TimeCurve};

/// Benchmark names in the paper's column order.
pub const APP_NAMES: [&str; 9] = [
    "lbm", "tealeaf", "clvleaf", "miniswp", "pot3d", "sph_exa", "weather", "llama", "diffusion",
];

/// Frequencies are indexed ascending: arm 0 = 0.8 GHz ... arm 8 = 1.6 GHz.
/// (The paper's Table 1 lists rows descending; transposed here.)
const E_LBM: [f64; 9] = [131.61, 124.28, 116.04, 109.59, 104.42, 99.88, 97.42, 93.71, 93.94];
const E_TEALEAF: [f64; 9] = [100.59, 99.10, 98.61, 99.81, 101.65, 105.37, 105.52, 107.09, 109.79];
const E_CLVLEAF: [f64; 9] = [91.23, 89.00, 88.41, 90.35, 90.99, 91.61, 94.72, 98.72, 100.65];
const E_MINISWP: [f64; 9] = [158.74, 160.15, 160.17, 161.72, 164.45, 167.25, 171.60, 177.10, 187.13];
const E_POT3D: [f64; 9] = [128.79, 125.45, 125.19, 123.38, 126.66, 125.75, 127.24, 129.11, 131.13];
const E_SPH_EXA: [f64; 9] =
    [1090.24, 1107.28, 1116.52, 1146.37, 1163.51, 1191.01, 1216.60, 1259.65, 1353.41];
const E_WEATHER: [f64; 9] = [122.97, 123.38, 122.52, 120.47, 121.75, 122.80, 125.52, 128.43, 134.61];
const E_LLAMA: [f64; 9] =
    [1210.13, 1360.93, 1114.29, 1202.81, 1177.68, 1294.05, 1211.42, 1257.58, 1277.71];
const E_DIFFUSION: [f64; 9] =
    [747.20, 805.50, 766.73, 751.82, 771.07, 766.59, 770.91, 771.50, 772.21];

fn amdahl(theta: f64) -> TimeCurve {
    TimeCurve::Amdahl { theta, gamma: 1.0 }
}

/// Build every calibrated app model.
pub fn all_apps() -> Vec<AppModel> {
    let noise = NoiseSpec::default();
    vec![
        AppModel {
            name: "lbm",
            class: Boundedness::ComputeBound,
            t_max_s: 35.0,
            time_curve: amdahl(0.12),
            energy_kj: E_LBM.to_vec(),
            r_base: 8.0,
            core_util: 0.96,
            cpu_kw: 0.45,
            other_kw: 0.24,
            noise,
        },
        AppModel {
            name: "tealeaf",
            class: Boundedness::Mixed,
            t_max_s: 45.0,
            time_curve: amdahl(0.55),
            energy_kj: E_TEALEAF.to_vec(),
            r_base: 3.0,
            core_util: 0.90,
            cpu_kw: 0.48,
            other_kw: 0.26,
            noise,
        },
        AppModel {
            name: "clvleaf",
            // theta = 0.50 reproduces the paper's 14.46 % slowdown at the
            // 1.2-1.3 GHz operating point (S4.6).
            class: Boundedness::Mixed,
            t_max_s: 40.0,
            time_curve: amdahl(0.50),
            energy_kj: E_CLVLEAF.to_vec(),
            r_base: 3.2,
            core_util: 0.91,
            cpu_kw: 0.46,
            other_kw: 0.25,
            noise,
        },
        AppModel {
            // theta = 0.78 reproduces the paper's 6.26 % slowdown at the
            // 1.2-1.3 GHz operating point (S4.6).
            name: "miniswp",
            class: Boundedness::MemoryBound,
            t_max_s: 65.0,
            time_curve: amdahl(0.78),
            energy_kj: E_MINISWP.to_vec(),
            r_base: 1.5,
            core_util: 0.85,
            cpu_kw: 0.52,
            other_kw: 0.28,
            noise,
        },
        AppModel {
            name: "pot3d",
            class: Boundedness::Mixed,
            t_max_s: 56.42,
            // Measured anchors from Fig. 1(b): x = f_max/f, y = T/T_max.
            time_curve: TimeCurve::Anchors {
                xs: vec![1.0, 1.6 / 1.1, 2.0],
                ys: vec![1.0, 59.78 / 56.42, 75.02 / 56.42],
            },
            energy_kj: E_POT3D.to_vec(),
            r_base: 2.8,
            core_util: 0.90,
            // Fig. 1(a): pot3d GPU share 75.10 %, CPU 16.55 %, other 8.35 %.
            // GPU P(1.6) = 131.13/56.42 = 2.3242 kW => CPU 0.512, other 0.258.
            cpu_kw: 0.512,
            other_kw: 0.258,
            noise,
        },
        AppModel {
            name: "sph_exa",
            class: Boundedness::MemoryBound,
            t_max_s: 480.0,
            time_curve: amdahl(0.80),
            energy_kj: E_SPH_EXA.to_vec(),
            r_base: 1.4,
            core_util: 0.85,
            cpu_kw: 0.55,
            other_kw: 0.30,
            noise,
        },
        AppModel {
            name: "weather",
            class: Boundedness::Mixed,
            t_max_s: 50.0,
            time_curve: amdahl(0.60),
            energy_kj: E_WEATHER.to_vec(),
            r_base: 2.6,
            core_util: 0.89,
            cpu_kw: 0.47,
            other_kw: 0.25,
            noise,
        },
        AppModel {
            name: "llama",
            class: Boundedness::ComputeBound,
            t_max_s: 420.0,
            time_curve: amdahl(0.35),
            energy_kj: E_LLAMA.to_vec(),
            r_base: 5.0,
            core_util: 0.94,
            // LLM inference keeps host busier (tokenization, KV paging).
            cpu_kw: 0.60,
            other_kw: 0.32,
            // The LLM rows in Table 1 are visibly noisier; widen the
            // counter noise accordingly.
            noise: NoiseSpec { energy_frac: 0.05, ..NoiseSpec::default() },
        },
        AppModel {
            name: "diffusion",
            class: Boundedness::MemoryBound,
            t_max_s: 280.0,
            time_curve: amdahl(0.70),
            energy_kj: E_DIFFUSION.to_vec(),
            r_base: 1.8,
            core_util: 0.87,
            cpu_kw: 0.50,
            other_kw: 0.28,
            noise: NoiseSpec { energy_frac: 0.04, ..NoiseSpec::default() },
        },
    ]
}

/// Look up one app model by name.
pub fn app(name: &str) -> Option<AppModel> {
    all_apps().into_iter().find(|a| a.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::freq::FreqDomain;

    #[test]
    fn nine_apps_in_paper_order() {
        let apps = all_apps();
        assert_eq!(apps.len(), 9);
        for (a, n) in apps.iter().zip(APP_NAMES) {
            assert_eq!(a.name, n);
        }
    }

    #[test]
    fn table1_best_static_arms() {
        // Best static frequency per app, read off the paper's Table 1.
        let expect = [
            ("lbm", 1.5),
            ("tealeaf", 1.0),
            ("clvleaf", 1.0),
            ("miniswp", 0.8),
            ("pot3d", 1.1),
            ("sph_exa", 0.8),
            ("weather", 1.1),
            ("llama", 1.0),
            ("diffusion", 0.8),
        ];
        let f = FreqDomain::aurora();
        for (name, ghz) in expect {
            let a = app(name).unwrap();
            assert!(
                (f.ghz(a.optimal_arm()) - ghz).abs() < 1e-9,
                "{name}: optimal {} GHz, expected {ghz}",
                f.ghz(a.optimal_arm())
            );
        }
    }

    #[test]
    fn pot3d_matches_fig1b_anchors() {
        let f = FreqDomain::aurora();
        let a = app("pot3d").unwrap();
        let t16 = a.time_s(&f, f.index_of_ghz(1.6).unwrap());
        let t11 = a.time_s(&f, f.index_of_ghz(1.1).unwrap());
        let t08 = a.time_s(&f, f.index_of_ghz(0.8).unwrap());
        assert!((t16 - 56.42).abs() < 1e-6, "{t16}");
        assert!((t11 - 59.78).abs() < 1e-2, "{t11}");
        assert!((t08 - 75.02).abs() < 1e-2, "{t08}");
        // Power at 1.6 close to the paper's 2.277 kW measurement (the small
        // Table-1/Fig-1b discrepancy is the paper's own).
        let p16 = a.power_kw(&f, f.index_of_ghz(1.6).unwrap());
        assert!((p16 - 2.324).abs() < 0.01, "{p16}");
    }

    #[test]
    fn qos_slowdowns_match_paper() {
        // clvleaf 14.46 % and miniswp 6.26 % at the 1.2-1.3 GHz operating
        // point (paper S4.6). Check at f = 1.25 equivalent: mean of arms.
        let f = FreqDomain::aurora();
        let clv = app("clvleaf").unwrap();
        let msw = app("miniswp").unwrap();
        let i12 = f.index_of_ghz(1.2).unwrap();
        let i13 = f.index_of_ghz(1.3).unwrap();
        let s_clv = 0.5 * (clv.slowdown(&f, i12) + clv.slowdown(&f, i13));
        let s_msw = 0.5 * (msw.slowdown(&f, i12) + msw.slowdown(&f, i13));
        assert!((s_clv - 0.1446).abs() < 0.02, "clvleaf slowdown {s_clv}");
        assert!((s_msw - 0.0626).abs() < 0.01, "miniswp slowdown {s_msw}");
    }

    #[test]
    fn powers_plausible_and_energy_exact() {
        let f = FreqDomain::aurora();
        for a in all_apps() {
            for i in 0..f.k() {
                let p = a.power_kw(&f, i);
                assert!(p > 1.0 && p < 4.0, "{} arm {i}: power {p} kW", a.name);
            }
            // Spot-check calibration round-trip at the extremes.
            assert!((a.power_kw(&f, 0) * a.time_s(&f, 0) - a.energy_kj[0]).abs() < 1e-9);
            assert!((a.power_kw(&f, 8) * a.time_s(&f, 8) - a.energy_kj[8]).abs() < 1e-9);
        }
    }

    #[test]
    fn gpu_dominates_node_power() {
        // Fig. 1(a): GPUs are the dominant consumer for every app.
        let f = FreqDomain::aurora();
        for a in all_apps() {
            let gpu = a.power_kw(&f, f.k() - 1);
            let total = gpu + a.cpu_kw + a.other_kw;
            let share = gpu / total;
            assert!(share > 0.60, "{}: GPU share {share}", a.name);
        }
    }

    #[test]
    fn pot3d_fig1a_shares() {
        let f = FreqDomain::aurora();
        let a = app("pot3d").unwrap();
        let gpu = a.power_kw(&f, f.k() - 1);
        let total = gpu + a.cpu_kw + a.other_kw;
        assert!((gpu / total - 0.7510).abs() < 0.01, "{}", gpu / total);
        assert!((a.cpu_kw / total - 0.1655).abs() < 0.01);
    }
}
