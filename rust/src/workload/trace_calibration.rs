//! Calibrate an [`AppModel`] from GEOPM-style telemetry traces.
//!
//! The paper's dataset collection (§4.1): run each application at every
//! static frequency, sample counters at 10 ms, keep the traces. This
//! module ingests such traces (CSV: `t_s,freq_ghz,energy_j,core_util,
//! uncore_util,progress`) and fits the per-frequency surfaces an
//! [`AppModel`] needs — so a user can point the controller at *their own*
//! hardware by replaying measured traces instead of our Table-1
//! calibration.

use std::collections::BTreeMap;

use crate::sim::freq::FreqDomain;
use crate::workload::model::{AppModel, Boundedness, NoiseSpec, TimeCurve};

/// One parsed trace record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRecord {
    pub t_s: f64,
    pub freq_ghz: f64,
    pub energy_j: f64,
    pub core_util: f64,
    pub uncore_util: f64,
    pub progress: f64,
}

/// Parse error with line number.
#[derive(Debug)]
pub enum TraceError {
    Line(usize, String),
    Incomplete(String),
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Line(line, msg) => write!(f, "trace line {line}: {msg}"),
            TraceError::Incomplete(what) => {
                write!(f, "trace covers no complete frequency: {what}")
            }
        }
    }
}

impl std::error::Error for TraceError {}

/// Parse a telemetry CSV (header optional).
pub fn parse_trace_csv(text: &str) -> Result<Vec<TraceRecord>, TraceError> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if i == 0 && line.chars().next().is_some_and(|c| c.is_alphabetic()) {
            continue; // header
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if fields.len() != 6 {
            return Err(TraceError::Line(i + 1, format!("expected 6 fields, got {}", fields.len())));
        }
        let parse = |j: usize| -> Result<f64, TraceError> {
            fields[j]
                .parse::<f64>()
                .map_err(|_| TraceError::Line(i + 1, format!("bad number: {:?}", fields[j])))
        };
        out.push(TraceRecord {
            t_s: parse(0)?,
            freq_ghz: parse(1)?,
            energy_j: parse(2)?,
            core_util: parse(3)?,
            uncore_util: parse(4)?,
            progress: parse(5)?,
        });
    }
    Ok(out)
}

/// Per-frequency aggregates fitted from a trace.
#[derive(Clone, Debug)]
pub struct FreqProfile {
    pub freq_ghz: f64,
    /// Mean power over the samples at this frequency, kW.
    pub power_kw: f64,
    /// Implied full-execution time at this frequency, seconds.
    pub exec_time_s: f64,
    pub core_util: f64,
    pub uncore_util: f64,
    pub samples: usize,
}

/// Fit per-frequency profiles: group samples by frequency, estimate power
/// from energy deltas and execution time from progress rate.
pub fn fit_profiles(records: &[TraceRecord], dt_s: f64) -> Vec<FreqProfile> {
    let mut groups: BTreeMap<i64, Vec<&TraceRecord>> = BTreeMap::new();
    for r in records {
        groups.entry((r.freq_ghz * 10.0).round() as i64).or_default().push(r);
    }
    let mut out = Vec::new();
    for (key, rs) in groups {
        if rs.len() < 2 {
            continue;
        }
        let n = rs.len() as f64;
        let power_kw = rs.iter().map(|r| r.energy_j).sum::<f64>() / n / dt_s / 1_000.0;
        let prog_rate = rs.iter().map(|r| r.progress).sum::<f64>() / n; // per interval
        let exec_time_s = if prog_rate > 0.0 { dt_s / prog_rate } else { f64::INFINITY };
        out.push(FreqProfile {
            freq_ghz: key as f64 / 10.0,
            power_kw,
            exec_time_s,
            core_util: rs.iter().map(|r| r.core_util).sum::<f64>() / n,
            uncore_util: rs.iter().map(|r| r.uncore_util).sum::<f64>() / n,
            samples: rs.len(),
        });
    }
    out
}

/// Build a calibrated [`AppModel`] from fitted profiles. The profiles must
/// cover every frequency of `freqs`.
pub fn app_model_from_profiles(
    name: &'static str,
    profiles: &[FreqProfile],
    freqs: &FreqDomain,
) -> Result<AppModel, TraceError> {
    let mut by_freq: BTreeMap<i64, &FreqProfile> = BTreeMap::new();
    for p in profiles {
        by_freq.insert((p.freq_ghz * 10.0).round() as i64, p);
    }
    let mut energy_kj = Vec::with_capacity(freqs.k());
    let mut times = Vec::with_capacity(freqs.k());
    for i in freqs.arms() {
        let key = (freqs.ghz(i) * 10.0).round() as i64;
        let p = by_freq
            .get(&key)
            .ok_or_else(|| TraceError::Incomplete(freqs.label(i)))?;
        if !p.exec_time_s.is_finite() || p.exec_time_s <= 0.0 {
            return Err(TraceError::Incomplete(format!("{} has no progress", freqs.label(i))));
        }
        energy_kj.push(p.power_kw * p.exec_time_s);
        times.push(p.exec_time_s);
    }
    let t_max = times[freqs.max_arm()];
    // Time curve from measured anchors (x = f_max/f ascending).
    let mut xs: Vec<f64> = freqs.arms().map(|i| freqs.max_ghz() / freqs.ghz(i)).collect();
    let mut ys: Vec<f64> = times.iter().map(|t| t / t_max).collect();
    xs.reverse();
    ys.reverse();
    let max_arm_profile = by_freq[&((freqs.max_ghz() * 10.0).round() as i64)];
    let ratio = max_arm_profile.core_util / max_arm_profile.uncore_util.max(1e-6);
    let class = if ratio > 4.0 {
        Boundedness::ComputeBound
    } else if ratio > 2.0 {
        Boundedness::Mixed
    } else {
        Boundedness::MemoryBound
    };
    Ok(AppModel {
        name,
        class,
        t_max_s: t_max,
        time_curve: TimeCurve::Anchors { xs, ys },
        energy_kj,
        r_base: ratio,
        core_util: max_arm_profile.core_util,
        cpu_kw: 0.5,
        other_kw: 0.27,
        noise: NoiseSpec::default(),
    })
}

/// Generate a synthetic trace from an existing model (round-trip tooling
/// and test fixture: model → trace → model must agree).
pub fn synthesize_trace(
    app: &AppModel,
    freqs: &FreqDomain,
    dt_s: f64,
    samples_per_freq: usize,
) -> Vec<TraceRecord> {
    let mut out = Vec::new();
    let mut t = 0.0;
    for i in freqs.arms() {
        for _ in 0..samples_per_freq {
            out.push(TraceRecord {
                t_s: t,
                freq_ghz: freqs.ghz(i),
                energy_j: app.energy_per_step_j(freqs, i, dt_s),
                core_util: app.uc(freqs, i),
                uncore_util: app.uu(freqs, i),
                progress: app.progress_per_step(freqs, i, dt_s),
            });
            t += dt_s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    #[test]
    fn csv_roundtrip() {
        let text = "t_s,freq_ghz,energy_j,core_util,uncore_util,progress\n\
                    0.00,1.6,23.2,0.90,0.45,0.0002\n\
                    0.01,1.6,23.4,0.91,0.46,0.0002\n\
                    # comment\n\
                    0.02,0.8,17.0,0.89,0.30,0.00013\n";
        let recs = parse_trace_csv(text).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[2].freq_ghz, 0.8);
    }

    #[test]
    fn bad_csv_reports_line() {
        let err = parse_trace_csv("0.0,1.6,oops,0.9,0.4,0.001").unwrap_err();
        assert!(matches!(err, TraceError::Line(1, _)), "{err}");
        let err = parse_trace_csv("0.0,1.6,1.0").unwrap_err();
        assert!(matches!(err, TraceError::Line(1, _)));
    }

    #[test]
    fn model_trace_model_roundtrip() {
        // Synthesize a noise-free trace from pot3d, refit, and compare the
        // recovered energy table to the original.
        let freqs = FreqDomain::aurora();
        let app = calibration::app("pot3d").unwrap();
        let trace = synthesize_trace(&app, &freqs, 0.01, 50);
        let profiles = fit_profiles(&trace, 0.01);
        assert_eq!(profiles.len(), 9);
        let refit = app_model_from_profiles("pot3d_refit", &profiles, &freqs).unwrap();
        for i in freqs.arms() {
            let orig = app.energy_kj[i];
            let got = refit.energy_kj[i];
            assert!(
                (got - orig).abs() / orig < 0.01,
                "arm {i}: {got} vs {orig}"
            );
        }
        // Optimal arm preserved.
        assert_eq!(refit.optimal_arm(), app.optimal_arm());
        // Timing anchors preserved.
        assert!((refit.t_max_s - app.t_max_s).abs() / app.t_max_s < 0.01);
    }

    #[test]
    fn incomplete_trace_rejected() {
        let freqs = FreqDomain::aurora();
        let app = calibration::app("tealeaf").unwrap();
        let mut trace = synthesize_trace(&app, &freqs, 0.01, 10);
        // Drop every 1.0 GHz sample.
        trace.retain(|r| (r.freq_ghz - 1.0).abs() > 1e-9);
        let profiles = fit_profiles(&trace, 0.01);
        let err = app_model_from_profiles("partial", &profiles, &freqs).unwrap_err();
        assert!(matches!(err, TraceError::Incomplete(_)), "{err}");
    }

    #[test]
    fn boundedness_classification_from_ratio() {
        let freqs = FreqDomain::aurora();
        for (name, expect) in [
            ("lbm", Boundedness::ComputeBound),
            ("sph_exa", Boundedness::MemoryBound),
        ] {
            let app = calibration::app(name).unwrap();
            let trace = synthesize_trace(&app, &freqs, 0.01, 20);
            let refit =
                app_model_from_profiles("x", &fit_profiles(&trace, 0.01), &freqs).unwrap();
            assert_eq!(refit.class, expect, "{name}");
        }
    }
}
