//! Workload substrate: calibrated application models (SPEChpc 2021 tiny,
//! Llama-2, Stable Diffusion XL), phased-workload composition, and run
//! traces. See DESIGN.md §3 for the calibration methodology.

pub mod calibration;
pub mod model;
pub mod phase;
pub mod serving;
pub mod trace;
pub mod trace_calibration;

pub use calibration::{all_apps, app, APP_NAMES};
pub use model::{AppModel, Boundedness, NoiseSpec, TimeCurve};
pub use serving::{ServingCfg, ServingModel};
