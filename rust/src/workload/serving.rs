//! Inference-serving workload model: a bursty Poisson/diurnal arrival
//! process standing in for live traffic, emitting the per-step feature
//! vector the contextual decision plane consumes.
//!
//! The model is a token-bucket queue in front of a server whose
//! throughput scales with the chosen frequency arm. Each decision
//! interval: requests arrive Poisson(λ(t)) where λ(t) carries a diurnal
//! sinusoid plus geometric-length burst episodes (the flash-crowd
//! pattern serving fleets see); each request enqueues a fixed token
//! budget; the server drains up to `capacity_tokens · service_scale`
//! tokens. The emitted features (all O(1) magnitude, capacity-relative):
//!
//! | index | feature                                                 |
//! |-------|---------------------------------------------------------|
//! | 0     | queue depth (tokens backlogged / full capacity)          |
//! | 1     | recent token arrival rate (EMA, capacity-relative)       |
//! | 2     | batch occupancy (tokens served / full capacity)          |
//! | 3     | recent server utilization (EMA of served / offered)      |
//!
//! Feature 0 doubles as the TTFT proxy: a backlog of q capacity-units
//! means a newly arrived request waits ≈ q intervals before its first
//! token, so the serving tier's QoS budget is expressed against it
//! (`RunMetrics::qos_violation_frac`).
//!
//! Determinism: the model owns its own [`Rng`] stream forked from
//! `cfg.seed`, independent of the node simulator's noise streams —
//! attaching a serving model to a backend cannot perturb any existing
//! context-free byte contract. The feature stream is a pure function of
//! (cfg, seed, the sequence of applied `service_scale`s).

use crate::util::rng::Rng;

/// Arrival-process and server-capacity knobs for [`ServingModel`].
#[derive(Clone, Debug, PartialEq)]
pub struct ServingCfg {
    /// Mean request arrivals per decision interval at the diurnal
    /// midpoint, outside bursts.
    pub base_rate: f64,
    /// Decision intervals per diurnal cycle.
    pub diurnal_period: u64,
    /// Diurnal modulation depth in [0, 1): λ swings between
    /// `base_rate·(1−amp)` and `base_rate·(1+amp)`.
    pub diurnal_amp: f64,
    /// Per-interval probability of entering a burst episode.
    pub burst_prob: f64,
    /// Mean burst length, intervals (episode lengths are uniform on
    /// `1..2·burst_mean`, mean ≈ `burst_mean`).
    pub burst_mean: f64,
    /// Arrival-rate multiplier while a burst is active.
    pub burst_boost: f64,
    /// Tokens enqueued per request.
    pub tokens_per_req: f64,
    /// Tokens the server drains per interval at the top frequency arm.
    pub capacity_tokens: f64,
    /// TTFT-style QoS budget on the queue-depth feature (capacity
    /// units of backlog a request may wait behind).
    pub ttft_budget: f64,
    /// Seed of the model's private arrival-noise stream.
    pub seed: u64,
}

impl Default for ServingCfg {
    fn default() -> ServingCfg {
        ServingCfg {
            base_rate: 4.0,
            diurnal_period: 2_000,
            diurnal_amp: 0.6,
            burst_prob: 0.02,
            burst_mean: 4.0,
            burst_boost: 3.0,
            tokens_per_req: 48.0,
            capacity_tokens: 256.0,
            ttft_budget: 2.0,
            seed: 0,
        }
    }
}

/// The serving workload state machine (see module docs).
#[derive(Clone, Debug)]
pub struct ServingModel {
    cfg: ServingCfg,
    rng: Rng,
    t: u64,
    queue_tokens: f64,
    burst_left: u64,
    arrival_ema: f64,
    util_ema: f64,
}

/// EMA retention for the rate/utilization features (≈ 5-interval
/// effective window: recent enough to track bursts, smooth enough that
/// the context is not raw noise).
const EMA_KEEP: f64 = 0.8;

/// Stream key of the serving model's private fork of the seed: keeps
/// its draws disjoint from every node-simulator noise stream.
const SERVING_STREAM: u64 = 0x5e12_71c0;

impl ServingModel {
    pub fn new(cfg: ServingCfg) -> ServingModel {
        assert!(cfg.base_rate > 0.0, "base_rate must be positive");
        assert!(cfg.diurnal_period > 0, "diurnal_period must be positive");
        assert!(
            (0.0..1.0).contains(&cfg.diurnal_amp),
            "diurnal_amp must lie in [0, 1)"
        );
        assert!((0.0..1.0).contains(&cfg.burst_prob), "burst_prob must lie in [0, 1)");
        assert!(cfg.burst_mean >= 1.0, "burst_mean must be >= 1");
        assert!(cfg.burst_boost >= 1.0, "burst_boost must be >= 1");
        assert!(cfg.tokens_per_req > 0.0, "tokens_per_req must be positive");
        assert!(cfg.capacity_tokens > 0.0, "capacity_tokens must be positive");
        assert!(cfg.ttft_budget > 0.0, "ttft_budget must be positive");
        let rng = Rng::new(cfg.seed).fork(SERVING_STREAM);
        ServingModel {
            cfg,
            rng,
            t: 0,
            queue_tokens: 0.0,
            burst_left: 0,
            arrival_ema: 0.0,
            util_ema: 0.0,
        }
    }

    /// The configured TTFT budget (queue-depth units).
    pub fn ttft_budget(&self) -> f64 {
        self.cfg.ttft_budget
    }

    /// Current arrival intensity λ(t): diurnal sinusoid times the burst
    /// boost when an episode is active.
    fn rate(&self) -> f64 {
        let phase = std::f64::consts::TAU * (self.t as f64 / self.cfg.diurnal_period as f64);
        let diurnal = 1.0 + self.cfg.diurnal_amp * phase.sin();
        let boost = if self.burst_left > 0 { self.cfg.burst_boost } else { 1.0 };
        self.cfg.base_rate * diurnal * boost
    }

    /// Poisson(λ) arrival count: Knuth's product method for small λ,
    /// clamped rounded-normal approximation above (λ > 30 makes the
    /// product method both slow and numerically degenerate).
    fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let n = self.rng.normal(lambda, lambda.sqrt()).round();
            return n.max(0.0) as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0f64;
        loop {
            p *= self.rng.uniform();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Advance one decision interval under a server throughput of
    /// `service_scale` (fraction of top-arm capacity, in (0, 1]) and
    /// return the emitted feature vector.
    pub fn step(&mut self, service_scale: f64) -> [f64; 4] {
        debug_assert!(
            service_scale > 0.0 && service_scale <= 1.0 + 1e-12,
            "service_scale must lie in (0, 1], got {service_scale}"
        );
        // Burst bookkeeping before sampling arrivals, so an episode's
        // first interval already sees the boosted rate.
        if self.burst_left > 0 {
            self.burst_left -= 1;
        } else if self.rng.chance(self.cfg.burst_prob) {
            self.burst_left = 1 + self.rng.below((2.0 * self.cfg.burst_mean) as u64);
        }
        let lambda = self.rate();
        self.t += 1;

        let arrivals = self.poisson(lambda) as f64;
        self.queue_tokens += arrivals * self.cfg.tokens_per_req;

        let offered = self.cfg.capacity_tokens * service_scale;
        let served = self.queue_tokens.min(offered);
        self.queue_tokens -= served;

        let arrival_rate = arrivals * self.cfg.tokens_per_req / self.cfg.capacity_tokens;
        self.arrival_ema = EMA_KEEP * self.arrival_ema + (1.0 - EMA_KEEP) * arrival_rate;
        let util = if offered > 0.0 { served / offered } else { 0.0 };
        self.util_ema = EMA_KEEP * self.util_ema + (1.0 - EMA_KEEP) * util;

        [
            self.queue_tokens / self.cfg.capacity_tokens,
            self.arrival_ema,
            served / self.cfg.capacity_tokens,
            self.util_ema,
        ]
    }

    /// Restore the fresh post-construction state (same seed, same
    /// future feature stream).
    pub fn reset(&mut self) {
        self.rng = Rng::new(self.cfg.seed).fork(SERVING_STREAM);
        self.t = 0;
        self.queue_tokens = 0.0;
        self.burst_left = 0;
        self.arrival_ema = 0.0;
        self.util_ema = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_stream_is_deterministic_per_seed() {
        let mut a = ServingModel::new(ServingCfg { seed: 7, ..ServingCfg::default() });
        let mut b = ServingModel::new(ServingCfg { seed: 7, ..ServingCfg::default() });
        let mut c = ServingModel::new(ServingCfg { seed: 8, ..ServingCfg::default() });
        let mut diverged = false;
        for i in 0..500 {
            let scale = 0.4 + 0.6 * ((i % 5) as f64 / 4.0).min(1.0);
            let fa = a.step(scale);
            assert_eq!(fa, b.step(scale), "same seed must agree at step {i}");
            if fa != c.step(scale) {
                diverged = true;
            }
        }
        assert!(diverged, "different seeds must not produce identical streams");
    }

    #[test]
    fn reset_replays_the_exact_stream() {
        let mut m = ServingModel::new(ServingCfg { seed: 3, ..ServingCfg::default() });
        let first: Vec<[f64; 4]> = (0..100).map(|_| m.step(0.75)).collect();
        m.reset();
        let second: Vec<[f64; 4]> = (0..100).map(|_| m.step(0.75)).collect();
        assert_eq!(first, second);
    }

    #[test]
    fn features_stay_finite_and_capacity_relative() {
        let mut m = ServingModel::new(ServingCfg::default());
        for i in 0..2_000 {
            let scale = if i % 7 == 0 { 0.2 } else { 1.0 };
            let f = m.step(scale);
            assert!(f.iter().all(|x| x.is_finite() && *x >= 0.0), "{f:?}");
            // Occupancy and utilization are bounded by construction.
            assert!(f[2] <= 1.0 + 1e-12, "{f:?}");
            assert!(f[3] <= 1.0 + 1e-12, "{f:?}");
        }
    }

    #[test]
    fn low_service_scale_backs_the_queue_up() {
        // Offered load ≈ 4·48 = 192 tokens/interval vs capacity 256:
        // serving at full scale keeps the queue near zero, serving at
        // half scale (128 tokens) cannot keep up and backlog grows.
        let steps = 400;
        let mut fast = ServingModel::new(ServingCfg { seed: 1, ..ServingCfg::default() });
        let mut slow = ServingModel::new(ServingCfg { seed: 1, ..ServingCfg::default() });
        let mut q_fast = 0.0;
        let mut q_slow = 0.0;
        for _ in 0..steps {
            q_fast = fast.step(1.0)[0];
            q_slow = slow.step(0.5)[0];
        }
        assert!(
            q_slow > q_fast + 1.0,
            "half-capacity service must backlog (fast {q_fast}, slow {q_slow})"
        );
    }

    #[test]
    fn bursts_raise_the_arrival_rate() {
        let mut m = ServingModel::new(ServingCfg::default());
        let base = m.rate();
        m.burst_left = 3;
        assert!((m.rate() - base * m.cfg.burst_boost).abs() < 1e-12);
    }

    #[test]
    fn poisson_sampler_tracks_its_mean() {
        let mut m = ServingModel::new(ServingCfg { seed: 11, ..ServingCfg::default() });
        for &lambda in &[0.5, 4.0, 25.0, 80.0] {
            let n = 4_000;
            let mean =
                (0..n).map(|_| m.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < 4.0 * (lambda / n as f64).sqrt() + 0.05,
                "λ = {lambda}: sample mean {mean}"
            );
        }
        assert_eq!(m.poisson(0.0), 0);
        assert_eq!(m.poisson(-1.0), 0);
    }

    #[test]
    #[should_panic(expected = "diurnal_amp")]
    fn out_of_range_amp_is_rejected() {
        let _ = ServingModel::new(ServingCfg { diurnal_amp: 1.0, ..ServingCfg::default() });
    }
}
