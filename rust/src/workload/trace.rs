//! Per-step run traces: record, summarize, and export what happened during
//! a controlled run (frequency choices, energy, progress). Used by the
//! figure experiments (regret curves, switching analysis) and by
//! `examples/trace_explorer`-style tooling.

use crate::util::io::{Csv, Json};
use std::path::Path;

/// One decision interval's record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceStep {
    /// Decision index t (1-based, like the paper's Algorithm 1).
    pub t: u64,
    /// Arm chosen this interval.
    pub arm: usize,
    /// Observed (noisy) reward fed to the policy.
    pub reward: f64,
    /// True GPU energy spent this interval, Joules.
    pub energy_j: f64,
    /// Instantaneous regret vs the oracle arm (reward units).
    pub regret: f64,
    /// Whether this interval performed a frequency switch.
    pub switched: bool,
}

/// A full run trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    steps: Vec<TraceStep>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    /// Recording can be disabled for bulk sweeps; push is then a no-op via
    /// the caller's choice not to construct a Trace.
    pub fn push(&mut self, step: TraceStep) {
        debug_assert!(
            self.steps.last().map_or(true, |s| step.t == s.t + 1),
            "trace steps must be consecutive"
        );
        self.steps.push(step);
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn steps(&self) -> &[TraceStep] {
        &self.steps
    }

    /// Cumulative-regret series (paper Fig. 3's y-axis).
    pub fn cumulative_regret(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.steps
            .iter()
            .map(|s| {
                acc += s.regret;
                acc
            })
            .collect()
    }

    /// Total number of frequency switches.
    pub fn switch_count(&self) -> u64 {
        self.steps.iter().filter(|s| s.switched).count() as u64
    }

    /// Arm-selection histogram.
    pub fn arm_histogram(&self, k: usize) -> Vec<u64> {
        let mut h = vec![0u64; k];
        for s in &self.steps {
            h[s.arm] += 1;
        }
        h
    }

    /// Downsample the cumulative regret to at most `n` evenly-spaced points
    /// (for figure export).
    pub fn regret_series(&self, n: usize) -> Vec<(u64, f64)> {
        let cum = self.cumulative_regret();
        if cum.is_empty() {
            return Vec::new();
        }
        let stride = (cum.len() / n.max(1)).max(1);
        let mut out: Vec<(u64, f64)> = cum
            .iter()
            .enumerate()
            .filter(|(i, _)| i % stride == 0)
            .map(|(i, r)| ((i + 1) as u64, *r))
            .collect();
        // Always include the final point.
        let last = (cum.len() as u64, *cum.last().unwrap());
        if out.last() != Some(&last) {
            out.push(last);
        }
        out
    }

    /// Export as CSV: t, arm, reward, energy_j, regret, switched.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut csv = Csv::new();
        csv.row(&["t", "arm", "reward", "energy_j", "regret", "switched"]);
        for s in &self.steps {
            csv.row(&[
                s.t.to_string(),
                s.arm.to_string(),
                format!("{:.6}", s.reward),
                format!("{:.6}", s.energy_j),
                format!("{:.6}", s.regret),
                (s.switched as u8).to_string(),
            ]);
        }
        csv.write_to(path)
    }

    /// Compact JSON summary.
    pub fn summary_json(&self, k: usize) -> Json {
        let mut j = Json::obj();
        j.set("steps", self.len());
        j.set("switches", self.switch_count() as i64);
        j.set(
            "final_regret",
            self.cumulative_regret().last().copied().unwrap_or(0.0),
        );
        j.set(
            "arm_histogram",
            Json::Arr(self.arm_histogram(k).iter().map(|c| Json::Num(*c as f64)).collect()),
        );
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_trace() -> Trace {
        let mut tr = Trace::new();
        for t in 1..=10u64 {
            tr.push(TraceStep {
                t,
                arm: (t % 3) as usize,
                reward: -1.0,
                energy_j: 20.0,
                regret: 0.5,
                switched: t % 2 == 0,
            });
        }
        tr
    }

    #[test]
    fn cumulative_regret_monotone() {
        let tr = mk_trace();
        let cum = tr.cumulative_regret();
        assert_eq!(cum.len(), 10);
        assert!((cum[9] - 5.0).abs() < 1e-12);
        assert!(cum.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn histogram_counts_all_steps() {
        let tr = mk_trace();
        let h = tr.arm_histogram(3);
        assert_eq!(h.iter().sum::<u64>(), 10);
    }

    #[test]
    fn switch_count() {
        assert_eq!(mk_trace().switch_count(), 5);
    }

    #[test]
    fn regret_series_includes_endpoint() {
        let tr = mk_trace();
        let s = tr.regret_series(4);
        assert_eq!(s.last().unwrap().0, 10);
        assert!((s.last().unwrap().1 - 5.0).abs() < 1e-12);
        assert!(s.len() <= 6);
    }

    #[test]
    fn csv_export_shape() {
        let tr = mk_trace();
        let dir = std::env::temp_dir().join(format!("energyucb_trace_{}", std::process::id()));
        let path = dir.join("trace.csv");
        tr.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 11);
        assert!(text.starts_with("t,arm,reward"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn summary_json_fields() {
        let j = mk_trace().summary_json(3);
        let s = j.render();
        assert!(s.contains("\"steps\": 10"), "{s}");
        assert!(s.contains("\"switches\": 5"), "{s}");
    }
}
