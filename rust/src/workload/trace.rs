//! Per-step run traces: record, summarize, and export what happened during
//! a controlled run (frequency choices, energy, progress). Used by the
//! figure experiments (regret curves, switching analysis) and by
//! `examples/trace_explorer`-style tooling.

use crate::util::io::{Csv, Json};
use std::path::Path;

/// Shortest round-trip float formatting (Rust's `{}` Display): the
/// decimal the standard parser maps back to the exact same bits. Non-
/// finite values print as `inf`/`-inf`/`NaN`, which [`parse_f64`]
/// accepts back.
fn fmt_f64(x: f64) -> String {
    format!("{x}")
}

fn parse_f64(s: &str) -> Option<f64> {
    s.parse::<f64>().ok()
}

/// One decision interval's record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceStep {
    /// Decision index t (1-based, like the paper's Algorithm 1).
    pub t: u64,
    /// Arm chosen this interval.
    pub arm: usize,
    /// Observed (noisy) reward fed to the policy.
    pub reward: f64,
    /// True GPU energy spent this interval, Joules.
    pub energy_j: f64,
    /// Instantaneous regret vs the oracle arm (reward units).
    pub regret: f64,
    /// Whether this interval performed a frequency switch.
    pub switched: bool,
}

/// A full run trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    steps: Vec<TraceStep>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    /// Recording can be disabled for bulk sweeps; push is then a no-op via
    /// the caller's choice not to construct a Trace.
    pub fn push(&mut self, step: TraceStep) {
        debug_assert!(
            self.steps.last().map_or(true, |s| step.t == s.t + 1),
            "trace steps must be consecutive"
        );
        self.steps.push(step);
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn steps(&self) -> &[TraceStep] {
        &self.steps
    }

    /// Cumulative-regret series (paper Fig. 3's y-axis).
    pub fn cumulative_regret(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.steps
            .iter()
            .map(|s| {
                acc += s.regret;
                acc
            })
            .collect()
    }

    /// Total number of frequency switches.
    pub fn switch_count(&self) -> u64 {
        self.steps.iter().filter(|s| s.switched).count() as u64
    }

    /// Arm-selection histogram.
    pub fn arm_histogram(&self, k: usize) -> Vec<u64> {
        let mut h = vec![0u64; k];
        for s in &self.steps {
            h[s.arm] += 1;
        }
        h
    }

    /// Downsample the cumulative regret to at most `n` evenly-spaced points
    /// (for figure export).
    pub fn regret_series(&self, n: usize) -> Vec<(u64, f64)> {
        let cum = self.cumulative_regret();
        if cum.is_empty() {
            return Vec::new();
        }
        let stride = (cum.len() / n.max(1)).max(1);
        let mut out: Vec<(u64, f64)> = cum
            .iter()
            .enumerate()
            .filter(|(i, _)| i % stride == 0)
            .map(|(i, r)| ((i + 1) as u64, *r))
            .collect();
        // Always include the final point.
        let last = (cum.len() as u64, *cum.last().unwrap());
        if out.last() != Some(&last) {
            out.push(last);
        }
        out
    }

    /// Export as CSV: t, arm, reward, energy_j, regret, switched.
    ///
    /// Floats are written in Rust's shortest round-trip formatting (the
    /// same contract as the cluster wire), so [`Trace::read_csv`] decodes
    /// the exact bit pattern back — a written trace is a lossless record,
    /// not a display rendering.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        self.to_csv().write_to(path)
    }

    /// The CSV rendering [`Trace::write_csv`] persists.
    pub fn to_csv(&self) -> Csv {
        let mut csv = Csv::new();
        csv.row(&["t", "arm", "reward", "energy_j", "regret", "switched"]);
        for s in &self.steps {
            csv.row(&[
                s.t.to_string(),
                s.arm.to_string(),
                fmt_f64(s.reward),
                fmt_f64(s.energy_j),
                fmt_f64(s.regret),
                (s.switched as u8).to_string(),
            ]);
        }
        csv
    }

    /// Parse the [`Trace::write_csv`] format back (exact float
    /// round-trip). Rejects a missing/odd header, short rows, and
    /// malformed fields with `InvalidData` — never panics on bad input.
    pub fn read_csv(path: &Path) -> std::io::Result<Trace> {
        Trace::from_csv_text(&std::fs::read_to_string(path)?)
    }

    /// [`Trace::read_csv`] over in-memory text.
    pub fn from_csv_text(text: &str) -> std::io::Result<Trace> {
        let bad = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let mut lines = text.lines();
        match lines.next() {
            Some("t,arm,reward,energy_j,regret,switched") => {}
            other => return Err(bad(format!("bad trace header: {other:?}"))),
        }
        let mut trace = Trace::new();
        for (i, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let cells: Vec<&str> = line.split(',').collect();
            let [t, arm, reward, energy_j, regret, switched] = cells[..] else {
                return Err(bad(format!("trace row {}: expected 6 fields", i + 2)));
            };
            let bad_field = |what: &str| bad(format!("trace row {}: bad {what}", i + 2));
            let step = TraceStep {
                t: t.parse().map_err(|_| bad_field("t"))?,
                arm: arm.parse().map_err(|_| bad_field("arm"))?,
                reward: parse_f64(reward).ok_or_else(|| bad_field("reward"))?,
                energy_j: parse_f64(energy_j).ok_or_else(|| bad_field("energy_j"))?,
                regret: parse_f64(regret).ok_or_else(|| bad_field("regret"))?,
                switched: match switched {
                    "0" => false,
                    "1" => true,
                    _ => return Err(bad_field("switched")),
                },
            };
            if let Some(last) = trace.steps.last() {
                // checked_add: t = u64::MAX in a hostile file must error,
                // not overflow-panic in debug builds.
                if last.t.checked_add(1) != Some(step.t) {
                    return Err(bad(format!(
                        "trace row {}: non-consecutive t {} after {}",
                        i + 2,
                        step.t,
                        last.t
                    )));
                }
            }
            trace.steps.push(step);
        }
        Ok(trace)
    }

    /// Compact JSON summary.
    pub fn summary_json(&self, k: usize) -> Json {
        let mut j = Json::obj();
        j.set("steps", self.len());
        j.set("switches", self.switch_count() as i64);
        j.set(
            "final_regret",
            self.cumulative_regret().last().copied().unwrap_or(0.0),
        );
        j.set(
            "arm_histogram",
            Json::Arr(self.arm_histogram(k).iter().map(|c| Json::Num(*c as f64)).collect()),
        );
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_trace() -> Trace {
        let mut tr = Trace::new();
        for t in 1..=10u64 {
            tr.push(TraceStep {
                t,
                arm: (t % 3) as usize,
                reward: -1.0,
                energy_j: 20.0,
                regret: 0.5,
                switched: t % 2 == 0,
            });
        }
        tr
    }

    #[test]
    fn cumulative_regret_monotone() {
        let tr = mk_trace();
        let cum = tr.cumulative_regret();
        assert_eq!(cum.len(), 10);
        assert!((cum[9] - 5.0).abs() < 1e-12);
        assert!(cum.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn histogram_counts_all_steps() {
        let tr = mk_trace();
        let h = tr.arm_histogram(3);
        assert_eq!(h.iter().sum::<u64>(), 10);
    }

    #[test]
    fn switch_count() {
        assert_eq!(mk_trace().switch_count(), 5);
    }

    #[test]
    fn regret_series_includes_endpoint() {
        let tr = mk_trace();
        let s = tr.regret_series(4);
        assert_eq!(s.last().unwrap().0, 10);
        assert!((s.last().unwrap().1 - 5.0).abs() < 1e-12);
        assert!(s.len() <= 6);
    }

    #[test]
    fn csv_export_shape() {
        let tr = mk_trace();
        let dir = std::env::temp_dir().join(format!("energyucb_trace_{}", std::process::id()));
        let path = dir.join("trace.csv");
        tr.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 11);
        assert!(text.starts_with("t,arm,reward"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn summary_json_fields() {
        let j = mk_trace().summary_json(3);
        let s = j.render();
        assert!(s.contains("\"steps\": 10"), "{s}");
        assert!(s.contains("\"switches\": 5"), "{s}");
    }

    #[test]
    fn csv_file_round_trip_is_exact() {
        let tr = mk_trace();
        let dir =
            std::env::temp_dir().join(format!("energyucb_trace_rt_{}", std::process::id()));
        let path = dir.join("trace.csv");
        tr.write_csv(&path).unwrap();
        let back = Trace::read_csv(&path).unwrap();
        assert_eq!(back.steps(), tr.steps());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Property: write → read reproduces every step bit-for-bit (the
    /// shortest-round-trip float contract).
    #[test]
    fn csv_text_round_trip_property() {
        use crate::testutil::proptest_lite::{forall_seeded, Gen};
        use crate::util::Rng;

        struct StepsGen;
        impl Gen for StepsGen {
            type Value = Vec<TraceStep>;
            fn generate(&self, rng: &mut Rng) -> Vec<TraceStep> {
                let n = rng.index(40);
                (0..n)
                    .map(|i| TraceStep {
                        t: (i + 1) as u64,
                        arm: rng.index(9),
                        // Full-precision mantissas; occasionally values a
                        // fixed-digit formatter would mangle.
                        reward: -rng.uniform_range(0.0, 3.0) * (1.0 / 3.0),
                        energy_j: rng.uniform_range(0.0, 1e3),
                        regret: rng.uniform_range(0.0, 5.0) * 1e-7,
                        switched: rng.chance(0.5),
                    })
                    .collect()
            }
        }
        forall_seeded(0x7_2ACE, 100, StepsGen, |steps| {
            let mut tr = Trace::new();
            for s in steps {
                tr.push(*s);
            }
            let text = tr.to_csv().render();
            match Trace::from_csv_text(&text) {
                Ok(back) => back.steps() == tr.steps(),
                Err(_) => false,
            }
        });
    }

    #[test]
    fn csv_reader_rejects_malformed_input() {
        for bad in [
            "",
            "wrong,header\n1,2,3,4,5,6\n",
            "t,arm,reward,energy_j,regret,switched\n1,0,0,0,0\n",
            "t,arm,reward,energy_j,regret,switched\n1,0,x,0,0,0\n",
            "t,arm,reward,energy_j,regret,switched\n1,0,0,0,0,2\n",
            // Non-consecutive t.
            "t,arm,reward,energy_j,regret,switched\n1,0,0,0,0,0\n3,0,0,0,0,0\n",
        ] {
            assert!(Trace::from_csv_text(bad).is_err(), "{bad:?}");
        }
        // The empty trace (header only) is valid.
        let empty = Trace::from_csv_text("t,arm,reward,energy_j,regret,switched\n").unwrap();
        assert!(empty.is_empty());
    }
}
