//! Application workload models.
//!
//! An [`AppModel`] describes how one benchmark behaves on the (simulated)
//! six-GPU Aurora node as a function of GPU core frequency: execution time,
//! node-level GPU power, and core/uncore engine utilization. The models are
//! *trace-calibrated*: per-frequency energies are taken directly from the
//! paper's Table 1 and timing anchors (pot3d's measured times, the QoS
//! slowdowns of clvleaf/miniswp), so every static-frequency experiment
//! reproduces the paper's numbers by construction, while dynamic controllers
//! interact with the same trade-off surface mechanistically.

use crate::sim::freq::FreqDomain;
use crate::util::math::interp;

/// Workload classification used for reporting and for choosing utilization
/// parameters (the paper's compute-bound vs memory-bound discussion, §4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Boundedness {
    ComputeBound,
    Mixed,
    MemoryBound,
}

/// Execution-time model: ratio T(f) / T(f_max) as a function of the
/// frequency ratio x = f_max / f >= 1.
#[derive(Clone, Debug)]
pub enum TimeCurve {
    /// Amdahl-style split: `ratio(x) = theta + (1 - theta) * x^gamma`.
    /// `theta` is the frequency-insensitive (memory-bound) time fraction.
    Amdahl { theta: f64, gamma: f64 },
    /// Piecewise-linear through measured anchors `(x_i, ratio_i)`,
    /// ascending in x and starting at (1.0, 1.0). Used for pot3d where the
    /// paper gives three measured execution times.
    Anchors { xs: Vec<f64>, ys: Vec<f64> },
}

impl TimeCurve {
    /// Slowdown ratio at frequency-ratio `x = f_max / f` (>= 1).
    pub fn ratio(&self, x: f64) -> f64 {
        debug_assert!(x >= 1.0 - 1e-9, "frequency ratio must be >= 1, got {x}");
        match self {
            TimeCurve::Amdahl { theta, gamma } => theta + (1.0 - theta) * x.powf(*gamma),
            TimeCurve::Anchors { xs, ys } => {
                // Linear extrapolation beyond the last anchor, flat below 1.
                let n = xs.len();
                if x > xs[n - 1] {
                    let slope = (ys[n - 1] - ys[n - 2]) / (xs[n - 1] - xs[n - 2]);
                    ys[n - 1] + slope * (x - xs[n - 1])
                } else {
                    interp(xs, ys, x)
                }
            }
        }
    }
}

/// Measurement-noise parameters for the hardware counters of this app's
/// runs (the paper's §3.2 motivation for optimistic initialization: early
/// readings are high-variance).
#[derive(Clone, Copy, Debug)]
pub struct NoiseSpec {
    /// Relative std-dev of the per-interval energy reading.
    pub energy_frac: f64,
    /// Absolute std-dev of the utilization readings.
    pub util_std: f64,
    /// Multiplier applied to both during the early window.
    pub early_mult: f64,
    /// Length of the early high-variance window, in seconds.
    pub early_window_s: f64,
    /// Probability of a heavy-tail counter glitch (DVFS transients,
    /// sampling races) inflating one energy reading ...
    pub spike_prob: f64,
    /// ... by this factor. Heavy tails are what make squared reward forms
    /// degrade (paper §4.5): outliers are amplified quadratically.
    pub spike_mult: f64,
}

impl Default for NoiseSpec {
    fn default() -> Self {
        NoiseSpec {
            energy_frac: 0.03,
            util_std: 0.02,
            early_mult: 3.0,
            early_window_s: 0.5,
            spike_prob: 0.01,
            spike_mult: 4.0,
        }
    }
}

/// A calibrated application model (node-level: the 6-GPU aggregate).
#[derive(Clone, Debug)]
pub struct AppModel {
    pub name: &'static str,
    pub class: Boundedness,
    /// Execution time at the maximum frequency, seconds.
    pub t_max_s: f64,
    /// Slowdown curve.
    pub time_curve: TimeCurve,
    /// Node-level GPU energy per frequency (kJ), ascending frequency order,
    /// calibrated to the paper's Table 1.
    pub energy_kj: Vec<f64>,
    /// Core-to-uncore utilization ratio at f_max (compute-bound => high).
    pub r_base: f64,
    /// Core-engine active fraction (roughly frequency-independent).
    pub core_util: f64,
    /// Node CPU power draw while the app runs (kW), for Fig. 1(a).
    pub cpu_kw: f64,
    /// Other node components (memory, NICs, ...), kW, for Fig. 1(a).
    pub other_kw: f64,
    pub noise: NoiseSpec,
}

impl AppModel {
    /// Execution time (s) if run statically at frequency index `i`.
    pub fn time_s(&self, freqs: &FreqDomain, i: usize) -> f64 {
        let x = freqs.max_ghz() / freqs.ghz(i);
        self.t_max_s * self.time_curve.ratio(x)
    }

    /// Node-level GPU power (kW) at frequency index `i`, derived from the
    /// calibrated energy table: P = E / T.
    pub fn power_kw(&self, freqs: &FreqDomain, i: usize) -> f64 {
        self.energy_kj[i] / self.time_s(freqs, i)
    }

    /// Fraction of total work completed per decision interval `dt_s` at
    /// frequency index `i` (the paper's progress p_i).
    pub fn progress_per_step(&self, freqs: &FreqDomain, i: usize, dt_s: f64) -> f64 {
        dt_s / self.time_s(freqs, i)
    }

    /// True (noise-free) GPU energy per decision interval, Joules.
    pub fn energy_per_step_j(&self, freqs: &FreqDomain, i: usize, dt_s: f64) -> f64 {
        self.power_kw(freqs, i) * 1_000.0 * dt_s
    }

    /// Core-engine utilization at frequency index `i` (≈ constant: compute
    /// engines stay busy at any clock while the app runs).
    pub fn uc(&self, _freqs: &FreqDomain, _i: usize) -> f64 {
        self.core_util
    }

    /// Uncore (copy-engine) utilization at frequency index `i`: data moved
    /// per wall-second scales with the progress rate, so
    /// `UU(f) = v * T(f_max)/T(f)` with `v = core_util / r_base`.
    pub fn uu(&self, freqs: &FreqDomain, i: usize) -> f64 {
        let v = self.core_util / self.r_base;
        v * self.t_max_s / self.time_s(freqs, i)
    }

    /// Core-to-uncore ratio R = UC / UU at frequency index `i`.
    pub fn ratio(&self, freqs: &FreqDomain, i: usize) -> f64 {
        self.uc(freqs, i) / self.uu(freqs, i)
    }

    /// True expected per-step reward r = -E_step * R at frequency `i`
    /// (Joules × ratio). Proportional to -E_total(i): the arm ordering under
    /// the paper's reward is the total-energy ordering.
    pub fn true_reward(&self, freqs: &FreqDomain, i: usize, dt_s: f64) -> f64 {
        -self.energy_per_step_j(freqs, i, dt_s) * self.ratio(freqs, i)
    }

    /// Index of the energy-optimal static frequency (the Oracle arm).
    pub fn optimal_arm(&self) -> usize {
        crate::util::stats::argmin(&self.energy_kj)
    }

    /// Energy of the best static frequency, kJ.
    pub fn optimal_energy_kj(&self) -> f64 {
        self.energy_kj[self.optimal_arm()]
    }

    /// Relative slowdown of arm `i` vs the maximum frequency.
    pub fn slowdown(&self, freqs: &FreqDomain, i: usize) -> f64 {
        self.time_s(freqs, i) / self.t_max_s - 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::calibration;

    fn freqs() -> FreqDomain {
        FreqDomain::aurora()
    }

    #[test]
    fn amdahl_ratio_monotone() {
        let c = TimeCurve::Amdahl { theta: 0.5, gamma: 1.0 };
        assert!((c.ratio(1.0) - 1.0).abs() < 1e-12);
        assert!(c.ratio(1.5) < c.ratio(2.0));
        assert!((c.ratio(2.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn anchors_hit_measured_points() {
        let c = TimeCurve::Anchors {
            xs: vec![1.0, 1.4545, 2.0],
            ys: vec![1.0, 1.0596, 1.3297],
        };
        assert!((c.ratio(1.0) - 1.0).abs() < 1e-9);
        assert!((c.ratio(1.4545) - 1.0596).abs() < 1e-9);
        assert!((c.ratio(2.0) - 1.3297).abs() < 1e-9);
    }

    #[test]
    fn reward_ordering_equals_energy_ordering() {
        // The designed property: argmax of the true reward is the
        // energy-optimal arm, for every calibrated app.
        let f = freqs();
        for app in calibration::all_apps() {
            let rewards: Vec<f64> =
                (0..f.k()).map(|i| app.true_reward(&f, i, 0.01)).collect();
            let best = crate::util::stats::argmax(&rewards);
            assert_eq!(
                best,
                app.optimal_arm(),
                "app {}: reward argmax {} != energy argmin {}",
                app.name,
                best,
                app.optimal_arm()
            );
        }
    }

    #[test]
    fn progress_sums_to_one_over_exec_time() {
        let f = freqs();
        let app = calibration::app("pot3d").unwrap();
        let i = f.k() - 1; // 1.6 GHz
        let steps = (app.time_s(&f, i) / 0.01).round() as usize;
        let total: f64 = (0..steps).map(|_| app.progress_per_step(&f, i, 0.01)).sum();
        assert!((total - 1.0).abs() < 0.01, "total={total}");
    }

    #[test]
    fn static_energy_matches_table1() {
        // E = P * T must round-trip the calibrated table exactly.
        let f = freqs();
        for app in calibration::all_apps() {
            for i in 0..f.k() {
                let e = app.power_kw(&f, i) * app.time_s(&f, i);
                assert!(
                    (e - app.energy_kj[i]).abs() < 1e-9,
                    "{} arm {i}: {e} != {}",
                    app.name,
                    app.energy_kj[i]
                );
            }
        }
    }

    #[test]
    fn utilizations_in_unit_range() {
        let f = freqs();
        for app in calibration::all_apps() {
            for i in 0..f.k() {
                let uc = app.uc(&f, i);
                let uu = app.uu(&f, i);
                assert!(uc > 0.0 && uc <= 1.0, "{} uc={uc}", app.name);
                assert!(uu > 0.0 && uu <= 1.0, "{} uu={uu}", app.name);
            }
        }
    }
}
